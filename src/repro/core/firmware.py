"""STM32F411 firmware emulation (paper §III-B).

Timing model (exactly the paper's arithmetic):

* ADC clock 24 MHz, 10-bit resolution, 15-cycle sampling time → 25 cycles
  per conversion = **1.0417 µs**;
* 8 channels (4 modules × current+voltage pair, consecutive channels to
  minimise skew) × **6-sample CPU averaging** → 50 µs frame interval =
  **20 kHz** output rate;
* per frame the device emits one 10-bit µs timestamp packet (captured after
  3 of the 6 averaged samples, i.e. mid-frame) followed by one 2-byte packet
  per enabled channel;
* USB 1.1 full-speed cap (12 Mbit/s) is honoured: 9 packets × 2 B / 50 µs =
  2.88 Mbit/s, comfortably inside the budget — the emulator asserts this
  invariant rather than modelling the bus.

The firmware is agnostic to module type: conversion constants live in the
virtual EEPROM (`SensorConfigBlock`) and are read by the host library.

Everything is generated vectorised per `advance_us` call so that the
simulation sustains millions of frames per second of wall time.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from . import protocol
from .dut import CompositeLoad, as_composite
from .protocol import (
    ADC_MAX,
    CMD_MARKER,
    CMD_READ_CONFIG,
    CMD_REBOOT,
    CMD_REBOOT_DFU,
    CMD_START_STREAM,
    CMD_STOP_STREAM,
    CMD_VERSION,
    CMD_WRITE_CONFIG,
    CONFIG_BLOCK_SIZE,
    SensorConfigBlock,
)
from .sensors import VREF, SensorModule, adc_quantize

FIRMWARE_VERSION = "ps3-sim 1.2.0"

ADC_CLOCK_HZ = 24e6
ADC_CYCLES_PER_CONV = 25  # 15 sampling + 10 conversion
N_CHANNELS = 8
N_AVG = 6
CONV_US = ADC_CYCLES_PER_CONV / (ADC_CLOCK_HZ / 1e6)  # 1.0417 µs
FRAME_US = CONV_US * N_CHANNELS * N_AVG  # 50 µs
SAMPLE_RATE_HZ = 1e6 / FRAME_US  # 20 kHz
USB_FS_BITS_PER_S = 12e6

_PACKETS_PER_FRAME = 1 + N_CHANNELS  # timestamp + 8 channels (when all enabled)
assert _PACKETS_PER_FRAME * 2 * 8 * SAMPLE_RATE_HZ < USB_FS_BITS_PER_S


@dataclass
class Firmware:
    """A virtual PowerSensor3: 4 module slots, streaming over byte FIFOs."""

    modules: list[SensorModule | None]
    dut: CompositeLoad
    seed: int = 0

    t_us: float = 0.0  # device clock
    streaming: bool = False
    pending_markers: int = 0
    booted_to_dfu: bool = False
    eeprom: list[SensorConfigBlock] = field(default_factory=list)
    _out: bytearray = field(default_factory=bytearray)
    _cmd_buf: bytearray = field(default_factory=bytearray)

    def __post_init__(self) -> None:
        while len(self.modules) < 4:
            self.modules.append(None)
        self.dut = as_composite(self.dut, n_modules=4)
        self.rng = np.random.default_rng(self.seed)
        if not self.eeprom:
            self.eeprom = []
            for k in range(4):
                mod = self.modules[k]
                if mod is None:
                    self.eeprom.append(SensorConfigBlock(name=f"empty{2*k}"))
                    self.eeprom.append(SensorConfigBlock(name=f"empty{2*k+1}"))
                else:
                    self.eeprom.append(
                        SensorConfigBlock(
                            name=f"{mod.spec.name[:9]}.i",
                            type_code=0,
                            enabled=True,
                            vref=VREF,
                            sensitivity=mod.spec.current_sensitivity,
                        )
                    )
                    self.eeprom.append(
                        SensorConfigBlock(
                            name=f"{mod.spec.name[:9]}.u",
                            type_code=1,
                            enabled=True,
                            vref=VREF,
                            sensitivity=mod.spec.divider_gain,
                        )
                    )

    # ------------------------------------------------------------------ host I/O
    def host_write(self, data: bytes) -> None:
        """Bytes arriving from the host (commands)."""
        self._cmd_buf.extend(data)
        self._drain_commands()

    def host_read(self, max_bytes: int | None = None) -> bytes:
        if max_bytes is None or max_bytes >= len(self._out):
            out = bytes(self._out)
            self._out.clear()
            return out
        out = bytes(self._out[:max_bytes])
        del self._out[:max_bytes]
        return out

    def _drain_commands(self) -> None:
        buf = self._cmd_buf
        while buf:
            cmd = bytes(buf[:1])
            if cmd == CMD_START_STREAM:
                self.streaming = True
                del buf[:1]
            elif cmd == CMD_STOP_STREAM:
                self.streaming = False
                del buf[:1]
            elif cmd == CMD_VERSION:
                self._out.extend(FIRMWARE_VERSION.encode() + b"\0")
                del buf[:1]
            elif cmd == CMD_MARKER:
                if len(buf) < 2:
                    return  # wait for the marker char
                self.pending_markers += 1
                del buf[:2]
            elif cmd == CMD_READ_CONFIG:
                if len(buf) < 2:
                    return
                sid = buf[1]
                if sid < len(self.eeprom):
                    self._out.extend(self.eeprom[sid].pack())
                del buf[:2]
            elif cmd == CMD_WRITE_CONFIG:
                if len(buf) < 2 + CONFIG_BLOCK_SIZE:
                    return
                sid = buf[1]
                block = SensorConfigBlock.unpack(bytes(buf[2 : 2 + CONFIG_BLOCK_SIZE]))
                if sid < len(self.eeprom):
                    self.eeprom[sid] = block
                del buf[: 2 + CONFIG_BLOCK_SIZE]
            elif cmd == CMD_REBOOT:
                self.streaming = False
                self.t_us = 0.0
                del buf[:1]
            elif cmd == CMD_REBOOT_DFU:
                self.streaming = False
                self.booted_to_dfu = True
                del buf[:1]
            else:  # unknown byte: discard (robustness)
                del buf[:1]

    # ------------------------------------------------------------------ sampling
    def advance_us(self, dt_us: float) -> None:
        """Advance the device clock, emitting frames if streaming."""
        t_end = self.t_us + dt_us
        if not self.streaming:
            self.t_us = t_end
            return
        # frames land on the 50 µs grid, strictly after the current clock
        first = int(np.floor(self.t_us / FRAME_US + 1e-9)) + 1
        last = int(np.floor(t_end / FRAME_US + 1e-9))
        if last < first:
            self.t_us = t_end
            return
        starts = np.arange(first, last + 1, dtype=np.float64) * FRAME_US
        self._emit_frames(starts)
        self.t_us = t_end

    def advance(self, dt_s: float) -> None:
        self.advance_us(dt_s * 1e6)

    def _emit_frames(self, starts_us: np.ndarray) -> None:
        n = len(starts_us)
        # mid-frame timestamps: captured after 3 of 6 averaged samples
        ts_vals = np.floor(starts_us + FRAME_US / 2.0).astype(np.int64) & 0x3FF

        # per-channel codes: (n, 8)
        codes = np.zeros((n, N_CHANNELS), dtype=np.int64)
        # sub-sample times per averaging slot: channels interleave; the skew
        # within a pair (~1 µs) is negligible vs signal bandwidth, so sample
        # the DUT once per averaging slot per module.
        sub = starts_us[:, None] / 1e6 + (np.arange(N_AVG)[None, :] * N_CHANNELS * CONV_US) / 1e6
        for k, mod in enumerate(self.modules):
            if mod is None:
                continue
            volts, amps = self.dut.sample_module(k, sub)  # (n, N_AVG)
            ci = adc_quantize(mod.current_pin_volts(amps, self.rng))
            cu = adc_quantize(mod.voltage_pin_volts(volts, self.rng))
            codes[:, 2 * k] = np.round(ci.mean(axis=1)).astype(np.int64)
            codes[:, 2 * k + 1] = np.round(cu.mean(axis=1)).astype(np.int64)

        enabled = np.array([blk.enabled for blk in self.eeprom[:N_CHANNELS]])
        ch_ids = np.flatnonzero(enabled)
        n_ch = len(ch_ids)

        # assemble packets: per frame [timestamp, ch0, ch1, ...]
        per_frame = 1 + n_ch
        ids = np.empty((n, per_frame), dtype=np.int64)
        vals = np.empty((n, per_frame), dtype=np.int64)
        marks = np.zeros((n, per_frame), dtype=np.int64)
        ids[:, 0] = protocol.TIMESTAMP_SENSOR_ID
        vals[:, 0] = ts_vals
        marks[:, 0] = 1  # timestamp flag: marker bit + id 7
        ids[:, 1:] = ch_ids[None, :]
        vals[:, 1:] = codes[:, ch_ids]
        # host-requested markers ride on sensor-0 data packets (paper §III-B)
        k = min(self.pending_markers, n)
        if k and 0 in ch_ids:
            col = 1 + int(np.flatnonzero(ch_ids == 0)[0])
            marks[:k, col] = 1
        ids_f, vals_f, marks_f = ids.ravel(), vals.ravel(), marks.ravel()
        if k:
            if 0 not in ch_ids:
                # ch0 disabled: markers must still reach the host, so emit
                # bare sensor-0 packets (the host extracts the marker bit
                # before its enabled-channel filter and ignores the value)
                pos = np.arange(k) * per_frame + 1  # right after timestamps
                ids_f = np.insert(ids_f, pos, 0)
                vals_f = np.insert(vals_f, pos, codes[:k, 0])
                marks_f = np.insert(marks_f, pos, 1)
            self.pending_markers -= k
        self._out.extend(protocol.encode_packets(ids_f, vals_f, marks_f))


@dataclass
class VirtualDevice:
    """Transport wrapper pairing a Firmware with host-side read/write.

    The host library talks to this object exactly as it would to
    ``/dev/ttyACM0``: `write` commands, `read` stream bytes, and — because
    this is a simulation — `advance` simulated time.
    """

    firmware: Firmware

    def write(self, data: bytes) -> None:
        self.firmware.host_write(data)

    def read(self, max_bytes: int | None = None) -> bytes:
        return self.firmware.host_read(max_bytes)

    def advance(self, dt_s: float) -> None:
        self.firmware.advance(dt_s)

    @property
    def t_s(self) -> float:
        return self.firmware.t_us / 1e6


def make_device(
    module_names: list[str | None],
    load,
    seed: int = 0,
) -> VirtualDevice:
    """Convenience: build a VirtualDevice from catalog module names."""
    from .sensors import MODULE_CATALOG

    modules: list[SensorModule | None] = []
    for i, name in enumerate(module_names):
        if name is None:
            modules.append(None)
        else:
            modules.append(SensorModule(MODULE_CATALOG[name], seed=seed * 101 + i))
    fw = Firmware(modules=modules, dut=as_composite(load, len(module_names)), seed=seed)
    return VirtualDevice(fw)
