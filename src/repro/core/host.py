"""PowerSensor3 host library (paper §III-C), Python edition.

Mirrors the C++ `PowerSensor` class API:

* on connect: reads the firmware version and the per-sensor EEPROM config,
  then starts streaming;
* a receiver (here: `poll()`, or a background thread via `start_thread()`)
  parses the 20 kHz stream and integrates **cumulative energy** per sensor
  pair;
* **interval mode**: `read()` returns a `State`; `Joules(a, b)`,
  `Watt(a, b)`, `seconds(a, b)` compute energy/average power between two
  states (this is what `psrun` uses);
* **continuous mode**: `set_dump_file()` streams every 20 kHz record to a
  file, including time-synced marker lines (`M <char> <t>`), active
  simultaneously with interval mode;
* config access: `get_config(i)` / `set_config(i, block)` (used by
  `psconfig` and the calibration procedure).
"""
from __future__ import annotations

import io
import threading
from dataclasses import dataclass, field

import numpy as np

from . import protocol
from .firmware import FRAME_US, N_CHANNELS, VirtualDevice
from .protocol import CMD_MARKER, CMD_READ_CONFIG, CMD_START_STREAM, CMD_STOP_STREAM, CMD_VERSION, CMD_WRITE_CONFIG, CONFIG_BLOCK_SIZE, SensorConfigBlock

MAX_PAIRS = N_CHANNELS // 2


@dataclass(frozen=True)
class State:
    """Snapshot of cumulative measurement state (interval-mode endpoint)."""

    time_s: float
    consumed_joules: tuple[float, ...]  # per module pair
    instant_watts: tuple[float, ...]
    instant_volts: tuple[float, ...]
    instant_amps: tuple[float, ...]
    n_samples: int

    @property
    def total_joules(self) -> float:
        return float(sum(self.consumed_joules))

    @property
    def total_watts(self) -> float:
        return float(sum(self.instant_watts))


def Joules(first: State, second: State, pair: int = -1) -> float:
    """Energy consumed between two states (all pairs if pair < 0)."""
    if pair < 0:
        return second.total_joules - first.total_joules
    return second.consumed_joules[pair] - first.consumed_joules[pair]


def seconds(first: State, second: State) -> float:
    return second.time_s - first.time_s


def Watt(first: State, second: State, pair: int = -1) -> float:
    dt = seconds(first, second)
    return Joules(first, second, pair) / dt if dt > 0 else 0.0


class PowerSensor:
    """Host-side driver for a (virtual) PowerSensor3 device."""

    def __init__(self, device: VirtualDevice, start: bool = True):
        self.device = device
        self._lock = threading.Lock()
        self._residual = b""
        self._pending_marker_chars: list[str] = []
        self._marker_events: list[tuple[str, float]] = []
        self._dump: io.TextIOBase | None = None
        self._dump_every = 1
        self._frame_count = 0
        self._device_time_us: float = 0.0
        self._last_ts10: int | None = None
        self._energy = np.zeros(MAX_PAIRS)
        self._inst_v = np.zeros(MAX_PAIRS)
        self._inst_i = np.zeros(MAX_PAIRS)
        self._n_samples = 0
        self._thread: threading.Thread | None = None
        self._thread_stop = threading.Event()

        # ---- connect handshake: version + config download ----
        self.device.write(CMD_VERSION)
        self.version = self._read_cstring()
        self.configs: list[SensorConfigBlock] = []
        for sid in range(N_CHANNELS):
            self.device.write(CMD_READ_CONFIG + bytes([sid]))
            raw = self.device.read(CONFIG_BLOCK_SIZE)
            self.configs.append(SensorConfigBlock.unpack(raw))
        if start:
            self.start_streaming()

    # ------------------------------------------------------------ config access
    def _read_cstring(self) -> str:
        out = bytearray()
        while True:
            b = self.device.read(1)
            if not b or b == b"\0":
                return out.decode()
            out.extend(b)

    def get_config(self, sid: int) -> SensorConfigBlock:
        return self.configs[sid]

    def set_config(self, sid: int, block: SensorConfigBlock) -> None:
        self.device.write(CMD_WRITE_CONFIG + bytes([sid]) + block.pack())
        self.configs[sid] = block

    # ------------------------------------------------------------ streaming
    def start_streaming(self) -> None:
        self.device.write(CMD_START_STREAM)

    def stop_streaming(self) -> None:
        self.device.write(CMD_STOP_STREAM)
        self.poll()

    def mark(self, char: str = "M") -> None:
        """Inject a time-synced marker into the continuous stream."""
        with self._lock:
            self._pending_marker_chars.append(char[0])
        self.device.write(CMD_MARKER + char[:1].encode())

    # ------------------------------------------------------------ dump file
    def set_dump_file(self, path_or_file, every: int = 1) -> None:
        """Continuous mode: write records as ``t pair V A W`` lines.

        `every` subsamples the dump (1 = full 20 kHz resolution).
        """
        if path_or_file is None:
            if self._dump:
                self._dump.flush()
            self._dump = None
            return
        self._dump = (
            open(path_or_file, "w") if isinstance(path_or_file, (str, bytes)) else path_or_file
        )
        self._dump_every = max(1, int(every))
        self._dump.write("# t_s pair volts amps watts\n")

    # ------------------------------------------------------------ the receiver
    def poll(self) -> int:
        """Parse everything the device has produced. Returns #frames seen."""
        with self._lock:
            buf = self._residual + self.device.read()
            ids, vals, marks, consumed = protocol.decode_packets(buf)
            self._residual = buf[consumed:]
            if ids.size == 0:
                return 0
            return self._process(ids, vals, marks)

    def _process(self, ids, vals, marks) -> int:
        is_ts = protocol.is_timestamp(ids, marks)
        ts_idx = np.flatnonzero(is_ts)
        if ts_idx.size == 0:
            return 0
        # device time reconstruction from 10-bit wrapping µs counter
        ts_vals = vals[ts_idx]
        if self._last_ts10 is None:
            base = float(ts_vals[0])
            self._device_time_us = base
            deltas = np.diff(ts_vals) % 1024
            times = base + np.concatenate([[0], np.cumsum(deltas)])
        else:
            d0 = (ts_vals[0] - self._last_ts10) % 1024
            deltas = np.concatenate([[d0], np.diff(ts_vals) % 1024])
            times = self._device_time_us + np.cumsum(deltas)
        self._last_ts10 = int(ts_vals[-1])
        self._device_time_us = float(times[-1])

        # frame boundaries: packets between consecutive timestamps
        n_frames = ts_idx.size
        dt_s = FRAME_US / 1e6

        # physical conversion for every data packet
        data_mask = ~is_ts
        d_ids = ids[data_mask]
        d_vals = vals[data_mask]
        d_marks = marks[data_mask]
        # frame index of each data packet
        frame_of = np.searchsorted(ts_idx, np.flatnonzero(data_mask)) - 1
        ok = frame_of >= 0
        d_ids, d_vals, d_marks, frame_of = (
            d_ids[ok], d_vals[ok], d_marks[ok], frame_of[ok],
        )

        volts = np.zeros((n_frames, MAX_PAIRS))
        amps = np.zeros((n_frames, MAX_PAIRS))
        for sid in range(N_CHANNELS):
            blk = self.configs[sid]
            if not blk.enabled:
                continue
            sel = d_ids == sid
            if not np.any(sel):
                continue
            phys = blk.raw_to_physical(d_vals[sel])
            tgt = amps if blk.type_code == 0 else volts
            tgt[frame_of[sel], sid // 2] = phys

        watts = volts * amps
        self._energy += watts.sum(axis=0) * dt_s
        self._inst_v = volts[-1]
        self._inst_i = amps[-1]
        self._n_samples += n_frames

        # markers: marker bit on sensor-0 data packets
        mk = (d_ids == 0) & (d_marks == 1)
        for fidx in frame_of[mk]:
            char = self._pending_marker_chars.pop(0) if self._pending_marker_chars else "?"
            t_mark = times[min(fidx, n_frames - 1)] / 1e6
            self._marker_events.append((char, t_mark))
            if self._dump:
                self._dump.write(f"M {char} {t_mark:.6f}\n")

        if self._dump:
            step = self._dump_every
            sel = np.arange(0, n_frames, step)
            lines = []
            for f in sel:
                t = times[f] / 1e6
                for p in range(MAX_PAIRS):
                    if self.configs[2 * p].enabled:
                        lines.append(
                            f"{t:.6f} {p} {volts[f, p]:.4f} {amps[f, p]:.4f} {watts[f, p]:.4f}\n"
                        )
            self._dump.write("".join(lines))
        self._frame_count += n_frames
        return n_frames

    # ------------------------------------------------------------ interval mode
    def read(self) -> State:
        self.poll()
        with self._lock:
            watts = self._inst_v * self._inst_i
            return State(
                time_s=self._device_time_us / 1e6,
                consumed_joules=tuple(self._energy),
                instant_watts=tuple(watts),
                instant_volts=tuple(self._inst_v),
                instant_amps=tuple(self._inst_i),
                n_samples=self._n_samples,
            )

    @property
    def markers(self) -> list[tuple[str, float]]:
        return list(self._marker_events)

    # ------------------------------------------------------------ sim helpers
    def run_for(self, seconds_: float, chunk_s: float = 0.5) -> None:
        """Advance simulated time, polling periodically (keeps buffers small)."""
        remaining = seconds_
        while remaining > 1e-12:
            step = min(chunk_s, remaining)
            self.device.advance(step)
            self.poll()
            remaining -= step

    # ------------------------------------------------------------ thread mode
    def start_thread(self, real_time_factor: float = 0.0, tick_s: float = 0.01) -> None:
        """Background receiver thread (the C++ library's lightweight thread).

        With ``real_time_factor > 0`` each wall-clock tick advances simulated
        time by ``tick * factor`` — useful for live `psinfo`-style displays.
        """
        if self._thread is not None:
            return
        self._thread_stop.clear()

        def _run() -> None:
            import time as _time

            while not self._thread_stop.is_set():
                if real_time_factor > 0:
                    self.device.advance(tick_s * real_time_factor)
                self.poll()
                _time.sleep(tick_s if real_time_factor > 0 else 0.001)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def stop_thread(self) -> None:
        if self._thread is None:
            return
        self._thread_stop.set()
        self._thread.join()
        self._thread = None

    def close(self) -> None:
        self.stop_thread()
        self.stop_streaming()
        if self._dump:
            self._dump.flush()
