"""PowerSensor3 host library (paper §III-C), Python edition.

Mirrors the C++ `PowerSensor` class API:

* on connect: reads the firmware version and the per-sensor EEPROM config,
  then starts streaming;
* a receiver (here: `poll()`, or a background thread via `start_thread()`)
  parses the 20 kHz stream and integrates **cumulative energy** per sensor
  pair;
* **interval mode**: `read()` returns a `State`; `Joules(a, b)`,
  `Watt(a, b)`, `seconds(a, b)` compute energy/average power between two
  states (this is what `psrun` uses);
* **continuous mode**: `set_dump_file()` streams every 20 kHz record to a
  file, including time-synced marker lines (`M <char> <t>`), active
  simultaneously with interval mode;
* config access: `get_config(i)` / `set_config(i, block)` (used by
  `psconfig` and the calibration procedure).
"""
from __future__ import annotations

import io
import threading
from dataclasses import dataclass, field

import numpy as np

from repro.obs import trace as obs_trace
from repro.stream.ring import FrameRing
from repro.stream.textio import format_dump_block

from . import protocol
from .firmware import FRAME_US, N_CHANNELS, VirtualDevice
from .protocol import CMD_MARKER, CMD_READ_CONFIG, CMD_START_STREAM, CMD_STOP_STREAM, CMD_VERSION, CMD_WRITE_CONFIG, CONFIG_BLOCK_SIZE, SensorConfigBlock

MAX_PAIRS = N_CHANNELS // 2

#: default ring capacity: 2^18 frames ≈ 13 s of 20 kHz history
DEFAULT_RING_CAPACITY = 1 << 18


@dataclass(frozen=True)
class State:
    """Snapshot of cumulative measurement state (interval-mode endpoint)."""

    time_s: float
    consumed_joules: tuple[float, ...]  # per module pair
    instant_watts: tuple[float, ...]
    instant_volts: tuple[float, ...]
    instant_amps: tuple[float, ...]
    n_samples: int

    @property
    def total_joules(self) -> float:
        return float(sum(self.consumed_joules))

    @property
    def total_watts(self) -> float:
        return float(sum(self.instant_watts))


def Joules(first: State, second: State, pair: int = -1) -> float:
    """Energy consumed between two states (all pairs if pair < 0)."""
    if pair < 0:
        return second.total_joules - first.total_joules
    return second.consumed_joules[pair] - first.consumed_joules[pair]


def seconds(first: State, second: State) -> float:
    return second.time_s - first.time_s


def Watt(first: State, second: State, pair: int = -1) -> float:
    dt = seconds(first, second)
    return Joules(first, second, pair) / dt if dt > 0 else 0.0


def _forward_fill(dense: np.ndarray, observed: np.ndarray, held: np.ndarray) -> np.ndarray:
    """Per-column forward fill of unobserved entries, seeded with `held`.

    ``dense`` is (n_frames, n_pairs) with zeros where ``observed`` is False;
    rows before the first observation of a column take that column's held
    value from the previous batch.
    """
    if observed.all():
        return dense
    n, p = dense.shape
    full = np.vstack([held[None, :], dense])
    ok = np.vstack([np.ones((1, p), dtype=bool), observed])
    idx = np.where(ok, np.arange(n + 1)[:, None], 0)
    np.maximum.accumulate(idx, axis=0, out=idx)
    return full[idx, np.arange(p)[None, :]][1:]


class PowerSensor:
    """Host-side driver for a (virtual) PowerSensor3 device."""

    def __init__(
        self,
        device: VirtualDevice,
        start: bool = True,
        ring_capacity: int = DEFAULT_RING_CAPACITY,
    ):
        self.device = device
        self._lock = threading.Lock()
        self._residual = b""
        self._pending_marker_chars: list[str] = []
        self._marker_events: list[tuple[str, float]] = []
        self._dump: io.TextIOBase | None = None
        self._dump_owns = False
        self._dump_every = 1
        self._frame_count = 0
        self._dropped_bytes = 0  # resync-discarded garbage bytes
        self._dropped_packets = 0  # decoded packets discarded as malformed
        self._device_time_us: float = 0.0
        self._last_ts10: int | None = None
        self._energy = np.zeros(MAX_PAIRS)
        # last *observed* value per pair — held across frames with no data
        # packets for that pair, so read() never flickers to 0
        self._inst_v = np.zeros(MAX_PAIRS)
        self._inst_i = np.zeros(MAX_PAIRS)
        self._n_samples = 0
        self._thread: threading.Thread | None = None
        self._thread_stop = threading.Event()
        self._thread_error: BaseException | None = None
        # receiver generation: each started thread captures the current
        # value; a thread detached past its join timeout (a "zombie"
        # wedged inside device.read) is fenced by bumping it, so any
        # batch the zombie eventually returns with is dropped, never
        # interleaved with the restarted receiver's stream
        self._recv_gen = 0
        self._fenced_bytes = 0
        # True while a PooledDecoder owns this sensor's current byte batch
        # (phase A took the residual; phase C publishes).  Direct polls
        # meanwhile are no-ops instead of interleaving a second decode.
        self._pool_batch = False
        self.ring = FrameRing(ring_capacity, MAX_PAIRS)

        # ---- connect handshake: version + config download ----
        self.device.write(CMD_VERSION)
        self.version = self._read_cstring()
        self.configs: list[SensorConfigBlock] = []
        for sid in range(N_CHANNELS):
            self.device.write(CMD_READ_CONFIG + bytes([sid]))
            raw = self.device.read(CONFIG_BLOCK_SIZE)
            self.configs.append(SensorConfigBlock.unpack(raw))
        self._refresh_conversion()
        if start:
            self.start_streaming()

    def _refresh_conversion(self) -> None:
        """Precompute per-channel affine raw→physical tables.

        `raw_to_physical` is affine in the ADC code for both channel types;
        flattening it to ``phys = a·code + b`` lets the receiver convert a
        whole poll batch with one fused multiply-add over all channels.
        The tables come from `protocol.conversion_tables`, shared with the
        trace archive so record→replay reproduces the exact floats.
        """
        self._lin_a, self._lin_b, self._ch_enabled, self._ch_is_volt = (
            protocol.conversion_tables(self.configs)
        )
        # bumped on every table refresh so the pooled decoder can cheaply
        # invalidate its stacked per-device conversion cache
        self._conv_gen = getattr(self, "_conv_gen", 0) + 1
        # pairs with an enabled voltage/current channel: only these may hold
        # a last-observed value — disabled pairs must read 0, not a stale hold
        self._pair_has_v = np.zeros(MAX_PAIRS, dtype=bool)
        self._pair_has_i = np.zeros(MAX_PAIRS, dtype=bool)
        for sid, blk in enumerate(self.configs):
            if blk.enabled:
                if blk.type_code != 0:
                    self._pair_has_v[sid // 2] = True
                else:
                    self._pair_has_i[sid // 2] = True

    # ------------------------------------------------------------ config access
    def _read_cstring(self) -> str:
        out = bytearray()
        while True:
            b = self.device.read(1)
            if not b or b == b"\0":
                return out.decode()
            out.extend(b)

    def get_config(self, sid: int) -> SensorConfigBlock:
        return self.configs[sid]

    def set_config(self, sid: int, block: SensorConfigBlock) -> None:
        self.device.write(CMD_WRITE_CONFIG + bytes([sid]) + block.pack())
        # mirror what the EEPROM actually stores: the packed block holds
        # 32-bit floats, so keep the round-tripped values — otherwise the
        # host converts with precision a config re-download (or a trace
        # archive, which stores the packed blocks) could never reproduce
        self.configs[sid] = SensorConfigBlock.unpack(block.pack())
        self._refresh_conversion()

    # ------------------------------------------------------------ streaming
    def start_streaming(self) -> None:
        self.device.write(CMD_START_STREAM)

    def stop_streaming(self) -> None:
        self.device.write(CMD_STOP_STREAM)
        self.poll()

    def mark(self, char: str = "M") -> None:
        """Inject a time-synced marker into the continuous stream."""
        with self._lock:
            self._pending_marker_chars.append(char[0])
        self.device.write(CMD_MARKER + char[:1].encode())

    def expect_markers(self, chars) -> None:
        """Queue marker chars for marker bits already in the stream.

        The transport seam for replay: a `repro.replay.ReplayDevice` serves
        a byte stream whose sensor-0 marker bits were recorded live, so no
        `mark()` call precedes them — seeding the pending-char queue here
        lets the receiver pair each replayed marker bit with its original
        char instead of ``"?"``.
        """
        with self._lock:
            self._pending_marker_chars.extend(c[0] for c in chars)

    # ------------------------------------------------------------ dump file
    def set_dump_file(self, path_or_file, every: int = 1) -> None:
        """Continuous mode: write records as ``t pair V A W`` lines.

        `every` subsamples the dump (1 = full 20 kHz resolution).  Handles
        opened here are owned here: replacing or clearing the dump target
        (or `close()`) closes them.  The header is written once per fresh
        file — streams handed in mid-use are not re-headed.
        """
        self._close_dump()
        if path_or_file is None:
            return
        if isinstance(path_or_file, (str, bytes)):
            self._dump = open(path_or_file, "w")
            self._dump_owns = True
            fresh = True
        else:
            self._dump = path_or_file
            try:
                fresh = self._dump.tell() == 0
            except (AttributeError, OSError, io.UnsupportedOperation):
                fresh = True  # unseekable sink: assume fresh
        self._dump_every = max(1, int(every))
        if fresh:
            self._dump.write("# t_s pair volts amps watts\n")

    def _close_dump(self) -> None:
        """Flush and detach the dump target, closing it if owned here."""
        if self._dump is not None:
            self._dump.flush()
            if self._dump_owns:
                self._dump.close()
            self._dump = None
            self._dump_owns = False

    # ------------------------------------------------------------ the receiver
    def poll(self) -> int:
        """Parse everything the device has produced. Returns #frames seen."""
        return max(self._poll_locked(None), 0)

    def _poll_locked(self, gen: int | None) -> int:
        """One receive pass under the lock, fenced by a generation token.

        ``gen`` is the receiver thread's captured generation (None for
        direct callers).  A stale token means this thread was detached by
        `stop_thread` while wedged — its batch is dropped (counted in
        ``fenced_bytes``), never interleaved — and -1 tells the thread
        loop to exit.  The token is re-checked *after* ``device.read()``
        because that is exactly where a zombie blocks while being fenced.
        """
        with self._lock:
            if gen is not None and gen != self._recv_gen:
                return -1
            if self._pool_batch:
                # a PooledDecoder holds this sensor's in-flight batch; a
                # second decode here would interleave with its publish
                return 0
            data = self.device.read()
            if gen is not None and gen != self._recv_gen:
                self._fenced_bytes += len(data)
                rec = obs_trace.active()
                if rec is not None and data:
                    rec.counter(
                        "rx.fenced_bytes", float(len(data)),
                        track=f"rx:{getattr(self, 'obs_name', 'dev')}",
                    )
                return -1
            return self._ingest(self._residual + data)

    def _ingest(self, buf: bytes) -> int:
        """Decode + frame-assemble one byte batch (receiver lock held).

        The single-device slow path shared by `poll()` and the pooled
        decoder's irregular-batch fallback: packet decode with resync
        accounting, trailing-incomplete-frame hold-back, then `_process`.
        The caller owns ``buf`` — any prior residual must already be
        prepended (and cleared), because the hold-back re-enters what it
        keeps through ``self._residual``.
        """
        ids, vals, marks, consumed = protocol.decode_packets(buf)
        self._residual = buf[consumed:]
        # bytes consumed without yielding packets were resync discards:
        # count them instead of silently swallowing the corruption
        junk = consumed - 2 * int(ids.size)
        if junk > 0:
            self._dropped_bytes += junk
            rec = obs_trace.active()
            if rec is not None:
                rec.counter(
                    "rx.dropped_bytes", float(junk),
                    track=f"rx:{getattr(self, 'obs_name', 'dev')}",
                )
        if ids.size == 0:
            return 0
        # A batch may end mid-frame (tiny transport reads split packets
        # across polls).  Data packets stranded *before* the next poll's
        # first timestamp used to be discarded; instead, hold the
        # trailing incomplete frame back in the residual so the next
        # poll completes it.  Full-frame polls — the steady state —
        # take the `tail >= expected` branch and pay nothing.
        is_ts = protocol.is_timestamp(ids, marks)
        ts_pos = np.flatnonzero(is_ts)
        if ts_pos.size:
            last_ts = int(ts_pos[-1])
            tail = ids.size - 1 - last_ts
            expected = int(self._ch_enabled.sum())
            # a disabled ch0 still carries markers as inserted bare
            # sensor-0 packets (right after the timestamp), making
            # those frames one packet longer than the enabled count
            if not self._ch_enabled[0] and np.any(ids[last_ts + 1 :] == 0):
                expected += 1
            if tail < expected:
                # With zero junk in this batch every decoded packet
                # sits at a 2-byte-aligned offset, so the held frame
                # is a straight byte slice — no decode→re-encode
                # round trip, and the discard accounting balances by
                # construction (the held bytes re-enter both
                # `consumed` and `2*ids.size` on the next poll).
                # Junk interleaving the batch loses the alignment;
                # only then re-encode the decoded packets.
                if junk == 0:
                    held = buf[2 * last_ts : consumed]
                else:
                    held = protocol.encode_packets(
                        ids[last_ts:], vals[last_ts:], marks[last_ts:]
                    )
                self._residual = held + self._residual
                ids, vals, marks, is_ts = (
                    ids[:last_ts], vals[:last_ts], marks[:last_ts], is_ts[:last_ts],
                )
                if ids.size == 0:
                    return 0
        return self._process(ids, vals, marks, is_ts)

    @property
    def dropped_bytes(self) -> int:
        """Garbage bytes discarded while resynchronising the packet stream."""
        return self._dropped_bytes

    @property
    def fenced_bytes(self) -> int:
        """Bytes read by a superseded (zombie) receiver thread and dropped.

        A receiver detached past its join timeout may return from a
        wedged ``device.read()`` much later; its batch is discarded to
        keep the restarted receiver's stream uninterleaved, and the
        discard is counted here instead of vanishing.
        """
        return self._fenced_bytes

    @property
    def dropped_frames(self) -> int:
        """Malformed frames discarded by the receiver (never silent).

        Counts packet-equivalents lost to byte-level resync plus decoded
        packets the frame assembler had to throw away (e.g. data packets
        with no preceding timestamp after a corruption or reconnect).
        """
        return self._dropped_packets + (self._dropped_bytes + 1) // 2

    def detach_residual(self) -> bytes:
        """Drop any half-assembled packet bytes; returns what was held.

        For transport reconnects (`repro.net.FleetHead`): a severed byte
        stream's trailing fragment no longer aligns with the fresh link's
        first bytes, so carrying it across would force a resync discard
        on the first post-reconnect poll.
        """
        with self._lock:
            out, self._residual = self._residual, b""
            return out

    def _convert_regular(self, ids, vals, marks, per, n_frames):
        """Reshape-based conversion for a frame-regular batch: no packet
        scatter, no per-packet frame search — pure column operations."""
        ch_ids = ids[1:per]
        codes = vals.reshape(-1, per)[:, 1:]
        phys = codes * self._lin_a[ch_ids][None, :] + self._lin_b[ch_ids][None, :]
        pair_of = ch_ids >> 1
        en = self._ch_enabled[ch_ids]
        vcols = np.flatnonzero(en & self._ch_is_volt[ch_ids])
        icols = np.flatnonzero(en & ~self._ch_is_volt[ch_ids])
        # unobserved-but-enabled pairs hold their last value (see
        # _forward_fill); pairs with no enabled channel read 0
        volts = np.empty((n_frames, MAX_PAIRS))
        amps = np.empty((n_frames, MAX_PAIRS))
        volts[:] = np.where(self._pair_has_v, self._inst_v, 0.0)[None, :]
        amps[:] = np.where(self._pair_has_i, self._inst_i, 0.0)[None, :]
        volts[:, pair_of[vcols]] = phys[:, vcols]
        amps[:, pair_of[icols]] = phys[:, icols]
        ch0 = np.flatnonzero(ch_ids == 0)
        if ch0.size:
            mk_frames = np.flatnonzero(marks.reshape(-1, per)[:, 1 + ch0[0]])
        else:
            mk_frames = np.empty(0, dtype=np.int64)
        return volts, amps, mk_frames

    def _convert_generic(self, ids, vals, marks, is_ts, ts_idx, n_frames):
        """Scatter-based conversion for irregular batches (resync, partial
        frames, mixed layouts)."""
        data_mask = ~is_ts
        d_ids = ids[data_mask]
        d_vals = vals[data_mask]
        d_marks = marks[data_mask]
        # frame index of each data packet
        frame_of = np.searchsorted(ts_idx, np.flatnonzero(data_mask)) - 1
        ok = frame_of >= 0
        if not ok.all():
            # data packets with no preceding timestamp (corruption ate the
            # frame header, or a reconnect started mid-frame): discard and
            # count, never silently absorb
            self._dropped_packets += int((~ok).sum())
            d_ids, d_vals, d_marks, frame_of = (
                d_ids[ok], d_vals[ok], d_marks[ok], frame_of[ok],
            )

        # markers: marker bit on sensor-0 data packets (extracted before the
        # enabled-channel filter so a disabled ch0 still carries markers)
        mk_frames = frame_of[(d_ids == 0) & (d_marks == 1)]

        # one fused multiply-add converts the whole batch to physical units
        phys = d_vals * self._lin_a[d_ids] + self._lin_b[d_ids]
        en = self._ch_enabled[d_ids]
        is_volt = self._ch_is_volt[d_ids]
        flat = frame_of * MAX_PAIRS + (d_ids >> 1)

        volts = np.zeros((n_frames, MAX_PAIRS))
        amps = np.zeros((n_frames, MAX_PAIRS))
        obs_v = np.zeros((n_frames, MAX_PAIRS), dtype=bool)
        obs_i = np.zeros((n_frames, MAX_PAIRS), dtype=bool)
        vsel = en & is_volt
        isel = en & ~is_volt
        volts.ravel()[flat[vsel]] = phys[vsel]
        obs_v.ravel()[flat[vsel]] = True
        amps.ravel()[flat[isel]] = phys[isel]
        obs_i.ravel()[flat[isel]] = True

        # hold the last observed value across frames that carried no data
        # packet for an *enabled* pair (instead of flickering to 0); pairs
        # with no enabled channel stay at 0
        volts = _forward_fill(volts, obs_v, np.where(self._pair_has_v, self._inst_v, 0.0))
        amps = _forward_fill(amps, obs_i, np.where(self._pair_has_i, self._inst_i, 0.0))
        return volts, amps, mk_frames

    def _frames_regular(self, ids, is_ts) -> bool:
        """Is this batch a whole number of [ts, ch, ch, ...] frames with a
        constant channel layout?  True for chunked polls of a steady stream
        (device emissions are frame-atomic), enabling the reshape fast path.
        """
        per = 1 + int(self._ch_enabled.sum())
        if per < 2 or ids.size == 0 or ids.size % per:
            return False
        is_ts_r = is_ts.reshape(-1, per)
        if not is_ts_r[:, 0].all() or is_ts_r[:, 1:].any():
            return False
        return bool((ids.reshape(-1, per)[:, 1:] == ids[1:per]).all())

    def _process(self, ids, vals, marks, is_ts=None) -> int:
        if is_ts is None:
            is_ts = protocol.is_timestamp(ids, marks)
        regular = self._frames_regular(ids, is_ts)
        if regular:
            per = 1 + int(self._ch_enabled.sum())
            n_frames = ids.size // per
            ts_vals = vals[::per]
        else:
            ts_idx = np.flatnonzero(is_ts)
            if ts_idx.size == 0:
                # a whole batch with no timestamp (corruption ate it):
                # discarded, but counted — never silent
                self._dropped_packets += int(ids.size)
                return 0
            n_frames = ts_idx.size
            ts_vals = vals[ts_idx]

        # device time reconstruction from 10-bit wrapping µs counter
        if self._last_ts10 is None:
            base = float(ts_vals[0])
            self._device_time_us = base
            deltas = np.diff(ts_vals) % 1024
            times = base + np.concatenate([[0], np.cumsum(deltas)])
        else:
            d0 = (ts_vals[0] - self._last_ts10) % 1024
            deltas = np.concatenate([[d0], np.diff(ts_vals) % 1024])
            times = self._device_time_us + np.cumsum(deltas)
        # The 10-bit counter wraps every 1.024 ms, so any delivery gap
        # longer than that (dropout, stall, disconnect→reconnect) loses
        # whole wraps and the reconstructed clock silently falls behind.
        # Re-anchor to the transport's arrival clock — the host-side time
        # a real driver would stamp each read with — whenever the batch
        # lags it by one wrap or more.  Only when the transport was
        # *drained*, though: a lag with bytes still pending is backlog
        # (delayed delivery, e.g. size-capped reads), where every frame is
        # present and the wrap arithmetic is already correct — re-stamping
        # those to arrival time would fabricate gaps out of latency.
        arrival_s = getattr(self.device, "t_s", None)
        if arrival_s is not None and not getattr(self.device, "pending_bytes", 0):
            wraps = int(np.floor((arrival_s * 1e6 - times[-1]) / 1024.0 + 0.5))
            if wraps > 0:
                times = times + wraps * 1024.0
        self._last_ts10 = int(ts_vals[-1])
        self._device_time_us = float(times[-1])

        times_s = times / 1e6

        if regular:
            volts, amps, mk_frames = self._convert_regular(ids, vals, marks, per, n_frames)
        else:
            volts, amps, mk_frames = self._convert_generic(ids, vals, marks, is_ts, ts_idx, n_frames)
        watts = volts * amps
        return self._commit_batch(times_s, volts, amps, watts, mk_frames)

    def _commit_batch(
        self,
        times_s: np.ndarray,
        volts: np.ndarray,
        amps: np.ndarray,
        watts: np.ndarray,
        mk_frames: np.ndarray,
        wtot: np.ndarray | None = None,
        e_seg: np.ndarray | None = None,
    ) -> int:
        """Publish one converted frame batch (receiver lock held).

        The shared tail of `_process` and the pooled decoder's phase C:
        energy integration, held instantaneous values, ring append, marker
        pairing, dump, and obs counters.  The arrays may be slices of a
        pooled fleet batch — everything here copies or reduces them, the
        per-device energy reduction runs over the contiguous per-device
        slice (identical summation order to a standalone batch).  ``wtot``
        and ``e_seg`` optionally carry that batch's per-frame totals and
        per-pair frame sum precomputed by the pooled decoder's fused
        reductions — same operands, same order, bit-identical values.
        """
        n_frames = len(times_s)
        self._inst_v = volts[-1].copy()
        self._inst_i = amps[-1].copy()

        dt_s = FRAME_US / 1e6
        self._energy += (watts.sum(axis=0) if e_seg is None else e_seg) * dt_s
        self._n_samples += n_frames
        self.ring.append(times_s, volts, amps, watts, wtot=wtot)

        if mk_frames.size:
            t_marks = times_s[np.minimum(mk_frames, n_frames - 1)]
            chars = [
                self._pending_marker_chars.pop(0) if self._pending_marker_chars else "?"
                for _ in range(mk_frames.size)
            ]
            events = list(zip(chars, t_marks.tolist()))
            self._marker_events.extend(events)
            if self._dump:
                self._dump.write("".join(f"M {c} {t:.6f}\n" for c, t in events))

        if self._dump:
            sel = np.arange(0, n_frames, self._dump_every)
            pairs = np.flatnonzero(self._ch_enabled[0::2])
            if sel.size and pairs.size:
                self._dump.write(
                    format_dump_block(
                        np.repeat(times_s[sel], pairs.size),
                        np.tile(pairs, sel.size),
                        volts[sel][:, pairs].ravel(),
                        amps[sel][:, pairs].ravel(),
                        watts[sel][:, pairs].ravel(),
                    )
                )
        self._frame_count += n_frames
        rec = obs_trace.active()
        if rec is not None:
            # one batch-level sample per poll, not per frame: the flight
            # recorder must stay off the per-frame fast path
            track = f"rx:{getattr(self, 'obs_name', 'dev')}"
            rec.anchor_once(float(times_s[-1]))
            rec.counter("rx.frames", float(n_frames), track=track)
            if mk_frames.size:
                rec.counter("rx.markers", float(mk_frames.size), track=track)
        return n_frames

    # ------------------------------------------------------------ interval mode
    def read(self) -> State:
        self.poll()
        with self._lock:
            # instantaneous values are the ring's newest frame — which by
            # construction holds the last observed V/I per pair
            if len(self.ring):
                newest = self.ring.latest(1)
                t_s = float(newest.times_s[-1])
                inst_v, inst_i = newest.volts[-1], newest.amps[-1]
                watts = newest.watts[-1]
            else:
                # nothing decoded yet: report the arrival clock (what the
                # wrap correction will anchor the first frames to), not the
                # 10-bit reconstruction's zero — otherwise the first
                # interval after a direct-drain (calibration) spans time
                # that was never streamed
                t_s = self._device_time_us / 1e6
                dev_now = getattr(self.device, "t_s", None)
                if dev_now is not None:
                    t_s = max(t_s, float(dev_now))
                inst_v, inst_i = self._inst_v, self._inst_i
                watts = inst_v * inst_i
            return State(
                time_s=t_s,
                consumed_joules=tuple(self._energy),
                instant_watts=tuple(watts),
                instant_volts=tuple(inst_v),
                instant_amps=tuple(inst_i),
                n_samples=self._n_samples,
            )

    def snapshot(self, window_s: float = 1.0, pct: float = 95.0):
        """Windowed stats (mean/peak/percentile/EWMA/energy) over the ring tail."""
        from repro.stream.aggregate import window_stats

        self.poll()
        with self._lock:
            return window_stats(self.ring.tail_window(window_s), pct=pct)

    @property
    def markers(self) -> list[tuple[str, float]]:
        return list(self._marker_events)

    # ------------------------------------------------------------ sim helpers
    def run_for(self, seconds_: float, chunk_s: float = 0.5) -> None:
        """Advance simulated time, polling periodically (keeps buffers small)."""
        remaining = seconds_
        while remaining > 1e-12:
            step = min(chunk_s, remaining)
            self.device.advance(step)
            self.poll()
            remaining -= step

    # ------------------------------------------------------------ thread mode
    def start_thread(self, real_time_factor: float = 0.0, tick_s: float = 0.01) -> None:
        """Background receiver thread (the C++ library's lightweight thread).

        With ``real_time_factor > 0`` each wall-clock tick advances simulated
        time by ``tick * factor`` — useful for live `psinfo`-style displays.
        """
        if self._thread is not None:
            return
        # fresh per-thread stop event and generation token: reusing the
        # previous event would let a detached-but-wedged zombie observe
        # the `clear()` and come back to life, and the bumped generation
        # fences any batch the zombie eventually returns with
        stop = threading.Event()
        self._thread_stop = stop
        self._thread_error = None
        self._recv_gen += 1
        gen = self._recv_gen

        def _run() -> None:
            import time as _time

            try:
                while not stop.is_set():
                    if gen != self._recv_gen:
                        return  # fenced before we even touch the device
                    if real_time_factor > 0:
                        self.device.advance(tick_s * real_time_factor)
                    if "poll" in self.__dict__:
                        # instance-patched poll (wrappers, fault tests):
                        # honour it — fencing only guards the stock path
                        self.poll()
                    elif self._poll_locked(gen) < 0:
                        return  # a newer receiver owns the stream now
                    _time.sleep(tick_s if real_time_factor > 0 else 0.001)
            except BaseException as exc:  # receiver died mid-poll: surface it
                if gen == self._recv_gen:
                    self._thread_error = exc

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    @property
    def thread_error(self) -> BaseException | None:
        """The exception that killed the receiver thread, if any."""
        return self._thread_error

    @property
    def receiver_ok(self) -> bool:
        """False when a started receiver thread died or failed to join.

        A dead poller means the ring stops advancing while reads keep
        answering from frozen data — consumers (`FleetMonitor` health)
        must treat this as a lost device, not a quiet one.
        """
        if self._thread_error is not None:
            return False
        t = self._thread
        return t is None or t.is_alive()

    def stop_thread(self, timeout_s: float = 5.0) -> BaseException | None:
        """Stop the receiver thread; returns its terminal error, if any.

        Joins with a timeout: a receiver wedged inside a poll is detached
        (it is a daemon) and surfaced as a `TimeoutError` instead of
        hanging the caller forever.  A receiver that died mid-poll has its
        exception returned (and kept on `thread_error`) rather than being
        silently discarded with the thread handle.

        A detached receiver is also *fenced*: the generation token is
        bumped — deliberately without taking ``self._lock``, which the
        wedged thread may hold inside ``device.read()`` — so whatever
        batch it eventually returns with is dropped, not interleaved
        with a subsequently restarted receiver's stream.
        """
        if self._thread is None:
            return self._thread_error
        self._thread_stop.set()
        self._thread.join(timeout_s)
        if self._thread.is_alive():
            self._recv_gen += 1
            self._thread_error = TimeoutError(
                f"receiver thread did not join within {timeout_s} s"
            )
        self._thread = None
        return self._thread_error

    def close(self) -> None:
        self.stop_thread()
        self.stop_streaming()
        self._close_dump()
