"""Device-under-test models — what the virtual sensor modules measure.

The paper's evaluation rig (Fig. 3) is a lab supply (Keysight N6705B) plus an
electronic load (Kniel E.Last).  Here the equivalent is a `Load`: a
vectorised function from simulation time to per-module (volts, amps).

Loads provided:

* `ConstantLoad`      — Fig 4 / Table II operating points
* `SweepLoad`         — stepped current sweep (Fig 4: −10 A → +10 A in 1 A steps)
* `SquareWaveLoad`    — Fig 5 step response (3.3 A ↔ 8 A at 100 Hz, 50 % duty)
* `TraceLoad`         — arbitrary (time, watts) playback: this is how the
                        TPU-chip power model from `repro.power` becomes a DUT
* `GpuKernelLoad`     — synthetic GPU-shaped profile (idle → ramp → phased
                        kernel → decay), the Fig 7 workload shape
* `CompositeLoad`     — different load per module (e.g. 3.3 V + 12 V rails)

All ``sample`` methods take an array of times (seconds) and return
``(volts, amps)`` arrays of the same shape.  An optional internal source
resistance models the voltage sag under load that the paper insists must be
measured per rail (V cannot be assumed stable).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


class Load:
    """Base: one rail. Subclasses override `_va`."""

    source_resistance: float = 0.0

    def sample(self, t_s: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        v, i = self._va(np.asarray(t_s, dtype=np.float64))
        if self.source_resistance:
            v = v - self.source_resistance * i
        return v, i

    def _va(self, t: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
        raise NotImplementedError


@dataclass
class ConstantLoad(Load):
    volts: float = 12.0
    amps: float = 0.0
    source_resistance: float = 0.0

    def _va(self, t):
        return np.full_like(t, self.volts), np.full_like(t, self.amps)


@dataclass
class SweepLoad(Load):
    """Stepped current sweep: hold each step for `dwell_s` (Fig 4)."""

    volts: float = 12.0
    steps: Sequence[float] = field(default_factory=lambda: np.arange(-10.0, 10.5, 1.0))
    dwell_s: float = 128_000 / 20_000.0  # 128k samples per step at 20 kHz
    source_resistance: float = 0.0

    def step_index(self, t: np.ndarray) -> np.ndarray:
        idx = np.floor(np.asarray(t) / self.dwell_s).astype(np.int64)
        return np.clip(idx, 0, len(self.steps) - 1)

    def _va(self, t):
        amps = np.asarray(self.steps, dtype=np.float64)[self.step_index(t)]
        return np.full_like(t, self.volts), amps


@dataclass
class SquareWaveLoad(Load):
    """100 Hz modulated e-load used for the step-response test (Fig 5)."""

    volts: float = 12.0
    amps_lo: float = 3.3
    amps_hi: float = 8.0
    freq_hz: float = 100.0
    duty: float = 0.5
    #: e-load slew: first-order settling time constant (s); 0 = ideal step
    slew_tau_s: float = 25e-6
    source_resistance: float = 0.0

    def _va(self, t):
        phase = (t * self.freq_hz) % 1.0
        hi = phase < self.duty
        if self.slew_tau_s > 0.0:
            # time since the most recent edge
            t_edge_hi = phase / self.freq_hz
            t_edge_lo = (phase - self.duty) / self.freq_hz
            settle = np.where(hi, t_edge_hi, np.where(t_edge_lo > 0, t_edge_lo, 0.0))
            frac = 1.0 - np.exp(-settle / self.slew_tau_s)
            base = np.where(hi, self.amps_lo, self.amps_hi)
            target = np.where(hi, self.amps_hi, self.amps_lo)
            amps = base + (target - base) * frac
        else:
            amps = np.where(hi, self.amps_hi, self.amps_lo)
        return np.full_like(t, self.volts), amps


@dataclass
class TraceLoad(Load):
    """Piecewise-linear power trace playback: P(t) watts on a fixed rail.

    This is the bridge from `repro.power` (TPU-chip phase traces derived
    from compiled HLO) into the faithful sensor stack: amps = P(t)/V.
    """

    times_s: np.ndarray = field(default_factory=lambda: np.array([0.0, 1.0]))
    watts: np.ndarray = field(default_factory=lambda: np.array([0.0, 0.0]))
    volts: float = 12.0
    source_resistance: float = 0.0
    repeat: bool = False
    #: playback starts at this simulation time (device clocks keep running
    #: across DUT swaps, e.g. calibration happens before the workload)
    t_offset_s: float = 0.0

    def _va(self, t):
        times = np.asarray(self.times_s, dtype=np.float64)
        t = np.maximum(np.asarray(t, dtype=np.float64) - self.t_offset_s, 0.0)
        if self.repeat and times[-1] > 0:
            t = np.mod(t, times[-1])
        p = np.interp(t, times, np.asarray(self.watts, dtype=np.float64))
        v = np.full_like(t, self.volts)
        return v, p / v


@dataclass
class GpuKernelLoad(Load):
    """Synthetic accelerator profile reproducing the Fig 7 shape:

    idle → clock ramp-up (power overshoot) → N sequential kernel phases with
    short inter-phase dips → post-kernel decay back to idle.
    """

    volts: float = 12.0
    idle_w: float = 18.0
    peak_w: float = 120.0
    overshoot_w: float = 150.0
    t_start_s: float = 0.25
    ramp_s: float = 0.15
    n_phases: int = 6
    phase_s: float = 0.30
    dip_w: float = 70.0
    dip_s: float = 0.004
    decay_tau_s: float = 0.35
    source_resistance: float = 0.0

    def _va(self, t):
        p = np.full_like(t, self.idle_w)
        t0 = self.t_start_s
        # ramp with brief overshoot
        ramp_frac = np.clip((t - t0) / self.ramp_s, 0.0, 1.0)
        over = self.overshoot_w * np.exp(-((t - t0) / (self.ramp_s * 0.4)) ** 2) * (
            t >= t0
        )
        in_run = (t >= t0) & (t < t0 + self.ramp_s + self.n_phases * self.phase_s)
        p = np.where(in_run, self.idle_w + (self.peak_w - self.idle_w) * ramp_frac, p)
        p = np.where(t >= t0, np.maximum(p, np.minimum(over + self.idle_w, self.overshoot_w)), p)
        # inter-phase dips
        t_run = t - (t0 + self.ramp_s)
        phase_pos = np.mod(t_run, self.phase_s)
        dip = (
            (t_run > 0)
            & (t_run < self.n_phases * self.phase_s)
            & (phase_pos < self.dip_s)
            & (np.floor(t_run / self.phase_s) > 0)
        )
        p = np.where(dip, self.dip_w, p)
        # decay after the workload
        t_end = t0 + self.ramp_s + self.n_phases * self.phase_s
        after = t >= t_end
        p = np.where(
            after,
            self.idle_w + (self.peak_w - self.idle_w) * np.exp(-(t - t_end) / self.decay_tau_s),
            p,
        )
        return np.full_like(t, self.volts), p / self.volts

    @property
    def t_total(self) -> float:
        return self.t_start_s + self.ramp_s + self.n_phases * self.phase_s + 4 * self.decay_tau_s


@dataclass
class CompositeLoad:
    """Assign an independent `Load` to each module slot (0..3).

    Mirrors the paper's GPU setup: slot 3.3 V + slot 12 V + external 12 V,
    each on its own sensor module.
    """

    loads: dict[int, Load] = field(default_factory=dict)

    def sample_module(self, module_idx: int, t_s: np.ndarray):
        load = self.loads.get(module_idx)
        if load is None:
            z = np.zeros_like(np.asarray(t_s, dtype=np.float64))
            return z, z
        return load.sample(t_s)


def as_composite(load: Load | CompositeLoad, n_modules: int = 1) -> CompositeLoad:
    if isinstance(load, CompositeLoad):
        return load
    return CompositeLoad({i: load for i in range(n_modules)})
