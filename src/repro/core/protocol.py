"""PowerSensor3 wire protocol (byte-exact reproduction of the paper's framing).

The paper (§III-B) specifies:

* 2 bytes per sensor reading; 10-bit sensor value + 6 bits of metadata:
  a 3-bit sensor index, a 1-bit marker, and one flag bit per byte that
  differentiates the first byte from the second.
* A 10-bit device timestamp (microseconds) generated after 3 of the 6
  averaged ADC samples, transmitted as a packet with sensor index 7
  (binary 111) and the marker bit set — "a marker bit set to one with a
  nonzero sensor index is unused and can be repurposed".
* A real marker (host-requested, correlating samples with code regions)
  can only be carried by sensor-0 data packets.

Concrete bit layout used here (documented contract for this repo)::

    byte0:  1  m  i2 i1 i0 v9 v8 v7      (bit7 = first-byte flag = 1)
    byte1:  0  v6 v5 v4 v3 v2 v1 v0      (bit7 = second-byte flag = 0)

where ``i`` is the 3-bit sensor index, ``m`` the marker bit and ``v`` the
10-bit ADC value.  A timestamp packet is ``i == 7 and m == 1`` with ``v``
the low 10 bits of the device microsecond counter.

Host → device commands are single ASCII bytes (optionally with payload):

    b'S'          start streaming sensor data
    b'X'          stop streaming
    b'M' + <char> set the marker bit on the next sensor-0 packet
    b'V'          reply with firmware version string (NUL-terminated)
    b'R' + <id>   reply with the 26-byte EEPROM config block of sensor <id>
    b'W' + <id> + block   write the EEPROM config block of sensor <id>
    b'B'          reboot
    b'D'          reboot to DFU (firmware upload) mode

Everything here is pure-numpy and vectorised: encoding/decoding operate on
arrays of packets, which is what lets the simulation sustain "20 kHz" for
millions of frames (Fig. 4 needs 21 x 128k samples).
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

import numpy as np

# ---------------------------------------------------------------------------
# commands
# ---------------------------------------------------------------------------
CMD_START_STREAM = b"S"
CMD_STOP_STREAM = b"X"
CMD_MARKER = b"M"
CMD_VERSION = b"V"
CMD_READ_CONFIG = b"R"
CMD_WRITE_CONFIG = b"W"
CMD_REBOOT = b"B"
CMD_REBOOT_DFU = b"D"

TIMESTAMP_SENSOR_ID = 7
ADC_BITS = 10
ADC_MAX = (1 << ADC_BITS) - 1  # 1023

# EEPROM config block: name(12s) type(B) enabled(B) vref(f) sensitivity(f)
# offset_cal(f) gain_cal(f)  -> 12 + 1 + 1 + 16 = 30 bytes
CONFIG_STRUCT = struct.Struct("<12sBBffff")
CONFIG_BLOCK_SIZE = CONFIG_STRUCT.size


@dataclass
class SensorConfigBlock:
    """Virtual-EEPROM contents for one ADC channel (paper §III-B1)."""

    name: str = ""
    type_code: int = 0  # 0 = current channel, 1 = voltage channel
    enabled: bool = False
    vref: float = 3.3
    #: V/A for current channels; divider gain (V_adc / V_rail) for voltage.
    sensitivity: float = 1.0
    #: additive correction (A for current, V for voltage), set by calibration
    offset_cal: float = 0.0
    #: multiplicative correction, set by calibration
    gain_cal: float = 1.0

    def pack(self) -> bytes:
        return CONFIG_STRUCT.pack(
            self.name.encode()[:12].ljust(12, b"\0"),
            self.type_code,
            int(self.enabled),
            self.vref,
            self.sensitivity,
            self.offset_cal,
            self.gain_cal,
        )

    @classmethod
    def unpack(cls, raw: bytes) -> "SensorConfigBlock":
        name, type_code, enabled, vref, sens, off, gain = CONFIG_STRUCT.unpack(raw)
        return cls(
            name=name.rstrip(b"\0").decode(),
            type_code=type_code,
            enabled=bool(enabled),
            vref=vref,
            sensitivity=sens,
            offset_cal=off,
            gain_cal=gain,
        )

    # -- host-side conversions ------------------------------------------------
    def raw_to_physical(self, code: np.ndarray | float) -> np.ndarray | float:
        """Convert 10-bit ADC code(s) to amps (current ch) or rail volts."""
        v_adc = (np.asarray(code, dtype=np.float64) / ADC_MAX) * self.vref
        if self.type_code == 0:  # current: mid-rail biased Hall output
            amps = (v_adc - self.vref / 2.0) / self.sensitivity
            return (amps - self.offset_cal) * self.gain_cal
        volts = v_adc / self.sensitivity  # sensitivity = divider gain here
        return (volts - self.offset_cal) * self.gain_cal


def conversion_tables(
    configs: "list[SensorConfigBlock]",
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Flatten `raw_to_physical` to per-channel affine ``phys = a·code + b``.

    Returns ``(lin_a, lin_b, enabled, is_volt)`` over the 8 channels.  This
    is THE conversion the host receiver applies (one fused multiply-add per
    batch) — the trace archive uses the same tables to invert physical
    values back to ADC codes, so a recorded frame re-played through the
    receiver decodes to bit-identical floats.
    """
    n = len(configs)
    lin_a = np.zeros(n)
    lin_b = np.zeros(n)
    enabled = np.zeros(n, dtype=bool)
    is_volt = np.zeros(n, dtype=bool)
    for sid, blk in enumerate(configs):
        enabled[sid] = blk.enabled
        is_volt[sid] = blk.type_code != 0
        lin_a[sid] = blk.vref / ADC_MAX / blk.sensitivity * blk.gain_cal
        if blk.type_code == 0:
            lin_b[sid] = (
                -blk.vref / 2.0 / blk.sensitivity - blk.offset_cal
            ) * blk.gain_cal
        else:
            lin_b[sid] = -blk.offset_cal * blk.gain_cal
    return lin_a, lin_b, enabled, is_volt


# ---------------------------------------------------------------------------
# packet encode / decode (vectorised)
# ---------------------------------------------------------------------------
def encode_packets(
    sensor_ids: np.ndarray, values: np.ndarray, markers: np.ndarray
) -> bytes:
    """Encode N packets -> 2N bytes.  All args are int arrays of equal length."""
    sensor_ids = np.asarray(sensor_ids, dtype=np.uint16)
    values = np.asarray(values, dtype=np.uint16)
    markers = np.asarray(markers, dtype=np.uint16)
    if np.any(values > ADC_MAX):
        raise ValueError("10-bit value out of range")
    if np.any(sensor_ids > 7):
        raise ValueError("3-bit sensor id out of range")
    b0 = 0x80 | (markers << 6) | (sensor_ids << 3) | (values >> 7)
    b1 = values & 0x7F
    out = np.empty((len(values), 2), dtype=np.uint8)
    out[:, 0] = b0.astype(np.uint8)
    out[:, 1] = b1.astype(np.uint8)
    return out.tobytes()


# NB bit layout realised above: byte0 = [1 | m | i2 i1 i0 | v9 v8 v7] with the
# marker at bit6 and the id at bits5..3.  The docstring layout is normative at
# the *field* level (1 flag, 1 marker, 3 id, 3 value bits); tests pin this
# exact packing so host and firmware can never drift apart.


def decode_packets(buf: bytes) -> tuple[np.ndarray, np.ndarray, np.ndarray, int]:
    """Decode a byte buffer into (sensor_ids, values, markers, n_consumed).

    Resynchronises on the first-byte flag: any second-byte without a first
    byte is dropped (robustness against partial reads).  A trailing first
    byte (incomplete packet) is left unconsumed.
    """
    arr = np.frombuffer(buf, dtype=np.uint8)
    if arr.size == 0:
        return (np.empty(0, np.int64),) * 3 + (0,)  # type: ignore[return-value]
    # fast path: perfectly aligned stream of (first, second) pairs
    n_pairs = arr.size // 2
    a0 = arr[: 2 * n_pairs : 2]
    a1 = arr[1 : 2 * n_pairs : 2]
    if n_pairs and np.all(a0 & 0x80) and not np.any(a1 & 0x80):
        consumed = 2 * n_pairs
    else:  # resync scan
        firsts = np.flatnonzero(arr & 0x80)
        valid = firsts[firsts + 1 < arr.size]
        valid = valid[(arr[valid + 1] & 0x80) == 0]
        a0, a1 = arr[valid], arr[valid + 1]
        consumed = int(valid[-1] + 2) if valid.size else (
            int(firsts[-1]) if firsts.size else arr.size
        )
    ids = ((a0 >> 3) & 0x7).astype(np.int64)
    markers = ((a0 >> 6) & 0x1).astype(np.int64)
    values = (((a0 & 0x7).astype(np.int64)) << 7) | (a1 & 0x7F)
    return ids, values, markers, consumed


def is_timestamp(ids: np.ndarray, markers: np.ndarray) -> np.ndarray:
    return (ids == TIMESTAMP_SENSOR_ID) & (markers == 1)


def unwrap_timestamps(ts_values: np.ndarray, start_us: int = 0) -> np.ndarray:
    """Reconstruct a monotonically increasing µs counter from 10-bit wraps.

    The device timestamp is 10 bits (wraps every 1024 µs; frames are 50 µs
    apart so wraps are unambiguous).
    """
    ts_values = np.asarray(ts_values, dtype=np.int64)
    if ts_values.size == 0:
        return ts_values
    deltas = np.diff(ts_values) % 1024
    out = np.empty_like(ts_values)
    out[0] = start_us + ts_values[0] % 1024
    out[1:] = out[0] + np.cumsum(deltas)
    return out
