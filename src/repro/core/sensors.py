"""Sensor-module physics models (paper §III-A, Table I).

Each PowerSensor3 sensor module carries a *pair* of channels:

* a differential Hall current sensor (Melexis MLX91221-class): output is
  mid-rail biased, ``V = vref/2 + sensitivity * I``, with datasheet rms
  noise (115 mA_rms for the 10 A variant) and a per-device offset that the
  one-time calibration removes;
* an optically isolated voltage sensor (Broadcom ACPL-C87B-class) behind a
  resistive divider, ``V_adc = divider_gain * V_rail``, with amplifier
  noise referred to the rail and a per-device gain error that calibration
  removes.

The worst-case accuracy model reproduces Table I of the paper:

    E_i = 3 sigma_hall + q_i / 2          (A)
    E_u = 3 sigma_v    + q_u / 2          (V)
    E_p = sqrt((U*E_i)^2 + (I*E_u)^2 + (E_i*E_u)^2)   (W)

with q the ADC LSB referred to the measured quantity.  Constants below are
chosen from the datasheet values quoted in the paper; the Table I benchmark
(`benchmarks/table1_accuracy.py`) asserts the model lands on the paper's
numbers (±4.2 W for the 12 V/10 A module, etc.).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field

import numpy as np

from .protocol import ADC_MAX

VREF = 3.3


@dataclass(frozen=True)
class ModuleSpec:
    """Static description of one sensor-module product."""

    name: str
    rail_volts: float  # nominal rail voltage (used for Table I worst case)
    max_amps: float  # bidirectional full scale (±)
    #: ADC full-scale rail voltage of the divider (V_rail at code 1023)
    volt_full_scale: float
    #: Hall sensor inherent noise, A rms, per raw ADC sample
    hall_noise_arms: float
    #: voltage-channel electrical noise referred to the rail, V rms
    volt_noise_vrms: float
    connector: str = "terminal"

    # -- derived ---------------------------------------------------------
    @property
    def current_sensitivity(self) -> float:
        """V per A at the ADC pin (mid-rail biased, ±max_amps spans vref)."""
        return (VREF / 2.0) / self.max_amps

    @property
    def divider_gain(self) -> float:
        """V_adc / V_rail for the voltage channel."""
        return VREF / self.volt_full_scale

    @property
    def current_lsb(self) -> float:
        return VREF / ADC_MAX / self.current_sensitivity

    @property
    def voltage_lsb(self) -> float:
        return VREF / ADC_MAX / self.divider_gain

    # -- Table I ---------------------------------------------------------
    @property
    def current_error(self) -> float:  # E_i, amps
        return 3.0 * self.hall_noise_arms + self.current_lsb / 2.0

    @property
    def voltage_error(self) -> float:  # E_u, volts
        return 3.0 * self.volt_noise_vrms + self.voltage_lsb / 2.0

    @property
    def power_error(self) -> float:  # E_p, watts (worst case: U_nom, I_max)
        ei, eu = self.current_error, self.voltage_error
        return math.sqrt(
            (self.rail_volts * ei) ** 2
            + (self.max_amps * eu) ** 2
            + (ei * eu) ** 2
        )


#: the five module designs shipped with PowerSensor3 (paper §III-A), plus
#: the 3.3 V slot variant of the 10 A module used in Table I.
MODULE_CATALOG: dict[str, ModuleSpec] = {
    "pcie8pin-20a": ModuleSpec(
        "pcie8pin-20a", 12.0, 20.0, 16.5, 0.130, 6.85e-3, connector="pcie-8pin"
    ),
    "slot-10a-12v": ModuleSpec(
        "slot-10a-12v", 12.0, 10.0, 16.5, 0.115, 6.85e-3, connector="riser"
    ),
    "slot-10a-3v3": ModuleSpec(
        "slot-10a-3v3", 3.3, 10.0, 4.125, 0.115, 5.97e-3, connector="riser"
    ),
    "usb-c": ModuleSpec("usb-c", 20.0, 10.0, 26.4, 0.115, 5.23e-3, connector="usb-c"),
    "gp-20a": ModuleSpec("gp-20a", 12.0, 20.0, 16.5, 0.130, 6.85e-3),
    "hc-50a": ModuleSpec("hc-50a", 12.0, 50.0, 16.5, 0.300, 6.85e-3),
}


@dataclass
class SensorModule:
    """One physical module instance: spec + per-device manufacturing errors.

    ``hall_offset_amps`` and ``divider_gain_error`` model the unit-to-unit
    spread that the paper's one-time calibration procedure (§III-D) removes.
    They are drawn once per instance from the given seed, so calibration
    tests are deterministic.
    """

    spec: ModuleSpec
    seed: int = 0
    hall_offset_amps: float = field(init=False)
    divider_gain_error: float = field(init=False)

    def __post_init__(self) -> None:
        rng = np.random.default_rng(self.seed + 0x5EED)
        # MLX91221-class offset spread: up to ~2% FS; ACPL-C87B gain: ~±1%
        self.hall_offset_amps = float(rng.uniform(-0.02, 0.02) * self.spec.max_amps)
        self.divider_gain_error = float(rng.uniform(-0.01, 0.01))

    # -- vectorised ADC-pin voltages --------------------------------------
    def current_pin_volts(self, amps: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        noise = rng.normal(0.0, self.spec.hall_noise_arms, size=np.shape(amps))
        i_seen = np.asarray(amps) + self.hall_offset_amps + noise
        return VREF / 2.0 + self.spec.current_sensitivity * i_seen

    def voltage_pin_volts(self, volts: np.ndarray, rng: np.random.Generator) -> np.ndarray:
        noise = rng.normal(0.0, self.spec.volt_noise_vrms, size=np.shape(volts))
        gain = self.spec.divider_gain * (1.0 + self.divider_gain_error)
        return gain * (np.asarray(volts) + noise)


def adc_quantize(pin_volts: np.ndarray) -> np.ndarray:
    """10-bit ADC transfer function (per-sample; firmware averages after)."""
    code = np.round(np.asarray(pin_volts) / VREF * ADC_MAX)
    return np.clip(code, 0, ADC_MAX)


def table1() -> list[dict[str, float | str]]:
    """Reproduce Table I (theoretical worst-case accuracy per module)."""
    rows = []
    order = ["slot-10a-12v", "slot-10a-3v3", "usb-c", "pcie8pin-20a", "hc-50a"]
    for key in order:
        spec = MODULE_CATALOG[key]
        rows.append(
            {
                "module": key,
                "rail": f"{spec.rail_volts:g} V / {spec.max_amps:g} A",
                "voltage_mV": spec.voltage_error * 1e3,
                "current_A": spec.current_error,
                "power_W": spec.power_error,
            }
        )
    return rows
