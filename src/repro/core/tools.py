"""Command-line utilities shipped with PowerSensor3 (paper §III-C).

* ``psrun``    — run a workload and report total energy + average power
* ``psconfig`` — read/write sensor configuration values
* ``psinfo``   — show config, latest measurements and total power
* ``pstest``   — measure power/energy at increasing intervals

Because the device is simulated, workloads are named entries from a small
registry (constant load, GPU-kernel profile, a TPU training-step trace from
`repro.power`, ...) instead of arbitrary subprocesses; `psrun` advances
simulated time while the workload "executes".

Usage (all through one entry point)::

    python -m repro.core.tools psrun   --workload gpu-kernel --modules slot-10a-12v
    python -m repro.core.tools psinfo  --modules slot-10a-12v,slot-10a-3v3
    python -m repro.core.tools psconfig --sensor 0 [--offset X] [--gain Y]
    python -m repro.core.tools pstest  --modules slot-10a-12v
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from . import dut
from .firmware import SAMPLE_RATE_HZ, make_device
from .host import Joules, PowerSensor, Watt, seconds


# --------------------------------------------------------------------------- workloads
def _workload(name: str):
    if name == "constant":
        return dut.ConstantLoad(volts=12.0, amps=8.0), 2.0
    if name == "gpu-kernel":
        g = dut.GpuKernelLoad()
        return g, g.t_total
    if name == "square":
        return dut.SquareWaveLoad(), 0.2
    if name == "tpu-train-step":
        from repro.power.demo import demo_train_trace

        times, watts = demo_train_trace()
        return dut.TraceLoad(times_s=times, watts=watts, repeat=True), float(times[-1] * 10)
    raise SystemExit(f"unknown workload '{name}'")


def _make_ps(args) -> PowerSensor:
    modules = args.modules.split(",") if args.modules else ["slot-10a-12v"]
    load, _ = _workload(args.workload)
    dev = make_device(modules, load, seed=args.seed)
    return PowerSensor(dev)


# --------------------------------------------------------------------------- psrun
def psrun(args) -> None:
    ps = _make_ps(args)
    load, duration = _workload(args.workload)
    if args.duration:
        duration = args.duration
    first = ps.read()
    ps.run_for(duration)
    second = ps.read()
    j, s, w = Joules(first, second), seconds(first, second), Watt(first, second)
    print(f"workload   : {args.workload}")
    print(f"runtime    : {s:.3f} s")
    print(f"energy     : {j:.3f} J")
    print(f"avg power  : {w:.3f} W")
    for p, jp in enumerate(second.consumed_joules):
        if ps.configs[2 * p].enabled:
            print(f"  pair {p} ({ps.configs[2*p].name:>12s}): {jp - first.consumed_joules[p]:.3f} J")


# --------------------------------------------------------------------------- psinfo
def psinfo(args) -> None:
    ps = _make_ps(args)
    ps.run_for(0.05)
    st = ps.read()
    print(f"firmware   : {ps.version}")
    print(f"sample rate: {SAMPLE_RATE_HZ:.0f} Hz")
    for sid, blk in enumerate(ps.configs):
        if not blk.enabled:
            continue
        kind = "I" if blk.type_code == 0 else "U"
        print(
            f"sensor {sid} [{kind}] {blk.name:>12s}: vref={blk.vref:.2f} "
            f"sens={blk.sensitivity:.4f} off={blk.offset_cal:+.4f} gain={blk.gain_cal:.4f}"
        )
    for p in range(len(st.instant_watts)):
        if ps.configs[2 * p].enabled:
            print(
                f"pair {p}: {st.instant_volts[p]:7.3f} V  {st.instant_amps[p]:7.3f} A  "
                f"{st.instant_watts[p]:8.3f} W"
            )
    print(f"total      : {st.total_watts:.3f} W")


# --------------------------------------------------------------------------- psconfig
def psconfig(args) -> None:
    ps = _make_ps(args)
    sid = args.sensor
    blk = ps.get_config(sid)
    changed = False
    if args.offset is not None:
        blk.offset_cal = args.offset
        changed = True
    if args.gain is not None:
        blk.gain_cal = args.gain
        changed = True
    if args.name is not None:
        blk.name = args.name
        changed = True
    if changed:
        ps.set_config(sid, blk)
        print(f"sensor {sid} updated")
    print(blk)
    if args.calibrate:
        from .calibration import calibrate

        pairs = {p: 12.0 for p in range(4) if ps.configs[2 * p].enabled}
        for rep in calibrate(ps, pairs, n_samples=args.cal_samples):
            print(
                f"pair {rep.pair}: offset {rep.current_offset_amps:+.4f} A, "
                f"gain {rep.voltage_gain:.5f}"
            )


# --------------------------------------------------------------------------- pstest
def pstest(args) -> None:
    """Measure at increasing intervals (the paper's accuracy-rig tool)."""
    ps = _make_ps(args)
    print("interval_s  samples  joules  avg_watt  min_w  max_w  std_w")
    for interval in (0.01, 0.02, 0.05, 0.1, 0.2, 0.5, 1.0, 2.0):
        # collect per-frame watts through a dump tap
        rows: list[float] = []

        class _Tap:
            def write(self, chunk: str) -> None:
                for line in chunk.splitlines():
                    parts = line.split()
                    if len(parts) == 5 and parts[0][0].isdigit():
                        rows.append(float(parts[4]))

            def flush(self) -> None: ...

        ps.set_dump_file(_Tap())
        a = ps.read()
        ps.run_for(interval)
        b = ps.read()
        ps.set_dump_file(None)
        w = np.asarray(rows) if rows else np.zeros(1)
        print(
            f"{interval:9.3f} {b.n_samples - a.n_samples:8d} {Joules(a, b):7.4f} "
            f"{Watt(a, b):8.4f} {w.min():6.2f} {w.max():6.2f} {w.std():6.3f}"
        )


def main(argv=None) -> None:
    parser = argparse.ArgumentParser(prog="repro.core.tools")
    sub = parser.add_subparsers(dest="tool", required=True)
    for name, fn in [("psrun", psrun), ("psinfo", psinfo), ("psconfig", psconfig), ("pstest", pstest)]:
        p = sub.add_parser(name)
        p.set_defaults(fn=fn)
        p.add_argument("--modules", default="slot-10a-12v")
        p.add_argument("--workload", default="constant")
        p.add_argument("--seed", type=int, default=0)
        if name == "psrun":
            p.add_argument("--duration", type=float, default=None)
        if name == "psconfig":
            p.add_argument("--sensor", type=int, default=0)
            p.add_argument("--offset", type=float, default=None)
            p.add_argument("--gain", type=float, default=None)
            p.add_argument("--name", default=None)
            p.add_argument("--calibrate", action="store_true")
            p.add_argument("--cal-samples", type=int, default=16_000)
    args = parser.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
