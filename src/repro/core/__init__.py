"""`repro.core` — faithful PowerSensor3 reproduction (paper §III).

Layers: sensor-module physics (`sensors`), DUT models (`dut`), STM32
firmware emulation + wire protocol (`protocol`, `firmware`), host library
(`host`), one-time calibration (`calibration`) and CLI tools (`tools`).
"""
from .calibration import CalibrationReport, calibrate
from .dut import (
    CompositeLoad,
    ConstantLoad,
    GpuKernelLoad,
    Load,
    SquareWaveLoad,
    SweepLoad,
    TraceLoad,
)
from .firmware import (
    FIRMWARE_VERSION,
    FRAME_US,
    SAMPLE_RATE_HZ,
    Firmware,
    VirtualDevice,
    make_device,
)
from .host import Joules, PowerSensor, State, Watt, seconds
from .protocol import SensorConfigBlock
from .sensors import MODULE_CATALOG, ModuleSpec, SensorModule, table1

__all__ = [
    "CalibrationReport",
    "calibrate",
    "CompositeLoad",
    "ConstantLoad",
    "GpuKernelLoad",
    "Load",
    "SquareWaveLoad",
    "SweepLoad",
    "TraceLoad",
    "FIRMWARE_VERSION",
    "FRAME_US",
    "SAMPLE_RATE_HZ",
    "Firmware",
    "VirtualDevice",
    "make_device",
    "Joules",
    "PowerSensor",
    "State",
    "Watt",
    "seconds",
    "SensorConfigBlock",
    "MODULE_CATALOG",
    "ModuleSpec",
    "SensorModule",
    "table1",
]
