"""One-time calibration procedure (paper §III-D).

With the sensor modules **unloaded** (no current flowing) and the rail at a
known reference voltage, take 128 k samples and compute:

* the Hall current sensor's **offset error** — the mean current reading at
  I = 0 (the MLX91221 mid-rail bias plus per-device offset);
* the voltage channel's **gain error** — mean measured voltage vs the known
  reference.

The corrections are written into the device's virtual EEPROM
(`offset_cal` on the current channel, `gain_cal` on the voltage channel),
after which they are applied transparently by the host-side conversion —
the user "does not need to keep track of the specific sensors used".

Per §IV-B (long-term stability: ±0.09 W over 50 h) calibration is required
only once at production; `benchmarks/stability.py` reproduces that claim.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .firmware import FRAME_US, N_CHANNELS, VirtualDevice
from .host import PowerSensor

CAL_SAMPLES = 128_000


@dataclass
class CalibrationReport:
    pair: int
    current_offset_amps: float
    voltage_gain: float
    residual_current_amps: float
    residual_voltage_volts: float


def _collect(ps: PowerSensor, n_samples: int) -> tuple[np.ndarray, np.ndarray]:
    """Collect per-frame (volts, amps) for all pairs over n_samples frames.

    Bypasses the energy accumulator and parses the raw stream directly —
    calibration needs every individual 20 kHz record.
    """
    from . import protocol

    rows_v: list[np.ndarray] = []
    rows_i: list[np.ndarray] = []

    remaining = n_samples
    residual = b""
    while remaining > 0:
        chunk_frames = min(remaining, 40_000)
        ps.device.advance(chunk_frames * FRAME_US / 1e6)
        buf = residual + ps.device.read()
        ids, vals, marks, consumed = protocol.decode_packets(buf)
        residual = buf[consumed:]
        is_ts = protocol.is_timestamp(ids, marks)
        n_frames = int(np.sum(is_ts))
        if n_frames == 0:
            continue
        ts_idx = np.flatnonzero(is_ts)
        frame_of = np.searchsorted(ts_idx, np.arange(len(ids))) - 1
        v = np.zeros((n_frames, N_CHANNELS // 2))
        a = np.zeros((n_frames, N_CHANNELS // 2))
        for sid in range(N_CHANNELS):
            blk = ps.configs[sid]
            if not blk.enabled:
                continue
            sel = (~is_ts) & (ids == sid) & (frame_of >= 0)
            phys = blk.raw_to_physical(vals[sel])
            (a if blk.type_code == 0 else v)[frame_of[sel], sid // 2] = phys
        rows_v.append(v)
        rows_i.append(a)
        remaining -= n_frames
    return np.concatenate(rows_v), np.concatenate(rows_i)


def calibrate(
    ps: PowerSensor,
    reference_volts: dict[int, float],
    n_samples: int = CAL_SAMPLES,
) -> list[CalibrationReport]:
    """Run the §III-D procedure. The DUT must present 0 A at a known voltage.

    `reference_volts` maps module pair index -> known rail voltage (from the
    lab supply / DMM in Fig 3).
    """
    volts, amps = _collect(ps, n_samples)
    reports = []
    for pair, v_ref in reference_volts.items():
        i_off = float(np.mean(amps[:, pair]))
        v_meas = float(np.mean(volts[:, pair]))
        gain = v_ref / v_meas if v_meas != 0 else 1.0

        cur_blk = ps.get_config(2 * pair)
        cur_blk.offset_cal += i_off / cur_blk.gain_cal
        ps.set_config(2 * pair, cur_blk)

        vol_blk = ps.get_config(2 * pair + 1)
        vol_blk.gain_cal *= gain
        ps.set_config(2 * pair + 1, vol_blk)

        reports.append(
            CalibrationReport(
                pair=pair,
                current_offset_amps=i_off,
                voltage_gain=gain,
                residual_current_amps=float(np.std(amps[:, pair]) / np.sqrt(len(amps))),
                residual_voltage_volts=float(np.std(volts[:, pair]) / np.sqrt(len(volts))),
            )
        )
    return reports
