"""Shared kernel plumbing.

All kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are
VALIDATED in interpret mode on CPU (the container has no TPU).  The
`interpret_default()` switch keeps `ops.py` wrappers runnable everywhere:
real lowering on TPU, interpreter elsewhere.  `REPRO_PALLAS_INTERPRET=0/1`
overrides.
"""
from __future__ import annotations

import os

import jax


def interpret_default() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b
