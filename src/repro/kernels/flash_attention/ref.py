"""Oracle for the flash-attention kernel: plain softmax attention (GQA)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import full_attention


def attention_ref(q, k, v, causal: bool = True):
    """q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D)."""
    return full_attention(q, k, v, causal=causal)
