from .ops import flash_attention, flash_attention_custom
from .ref import attention_ref

__all__ = ["flash_attention", "flash_attention_custom", "attention_ref"]
