"""Public flash-attention op: Pallas fwd + rematerialising custom-vjp bwd."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.common import interpret_default

from .flash_attention import flash_attention_fwd
from .ref import attention_ref


def flash_attention(q, k, v, causal: bool = True, bq: int = 128, bk: int = 128,
                    use_pallas: bool = True):
    if not use_pallas:
        return attention_ref(q, k, v, causal=causal)
    return flash_attention_fwd(
        q, k, v, causal=causal, bq=bq, bk=bk, interpret=interpret_default()
    )


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def flash_attention_custom(q, k, v, causal: bool = True):
    """Differentiable wrapper: Pallas forward, recompute-reference backward.

    The backward recomputes attention with the jnp reference and
    differentiates it — O(S²) compute in bwd but no stored probs, the
    standard memory/compute trade (DESIGN.md §7).
    """
    return flash_attention(q, k, v, causal=causal)


def _fwd(q, k, v, causal):
    return flash_attention(q, k, v, causal=causal), (q, k, v)


def _bwd(causal, res, g):
    q, k, v = res
    _, vjp = jax.vjp(lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal), q, k, v)
    return vjp(g)


flash_attention_custom.defvjp(_fwd, _bwd)
