"""Pallas TPU flash attention (forward), causal + GQA.

Grid = (B, Hq, Sq/bq, Sk/bk) with the KV dimension innermost: TPU grids
execute sequentially, so f32 VMEM scratch (acc, running max m, running
sum l) persists across KV steps — the classic online-softmax recurrence
with one VMEM-resident (bq, D) accumulator per q tile.

Block shapes are MXU-aligned by default (bq=bk=128, D up to 256 in one
tile).  Fully-masked causal blocks are skipped via `pl.when` (the grid
still visits them, but no MXU work is issued).

Training integration: `ops.flash_attention_custom` wires this forward
into `jax.custom_vjp` with a rematerialising XLA backward (flash-fwd +
recompute-bwd — the memory-saving pattern; a fused Pallas backward is
left as future work and documented in DESIGN.md §7).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc, m_ref, l_ref, *, scale, causal, n_k, bq, bk):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos <= q_pos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc[...] = acc[...] * corr[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    if causal:
        # skip kv blocks entirely above the causal diagonal for this q tile
        pl.when((ki * bk) <= (qi * bq + bq - 1))(_step)
    else:
        _step()

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[0, :, 0, :] = (acc[...] / l_ref[...][:, None]).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("causal", "bq", "bk", "interpret"))
def flash_attention_fwd(
    q, k, v, causal: bool = True, bq: int = 128, bk: int = 128, interpret: bool = True
):
    """q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D). Returns (B,Sq,Hq,D)."""
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    assert hq % hkv == 0
    group = hq // hkv
    bq = min(bq, sq)
    bk = min(bk, sk)
    assert sq % bq == 0 and sk % bk == 0
    n_k = sk // bk
    grid = (b, hq, sq // bq, n_k)
    scale = 1.0 / (d**0.5)

    q_spec = pl.BlockSpec((1, bq, 1, d), lambda bb, h, qi, ki: (bb, qi, h, 0))
    k_spec = pl.BlockSpec((1, bk, 1, d), lambda bb, h, qi, ki: (bb, ki, h // group, 0))
    o_spec = pl.BlockSpec((1, bq, 1, d), lambda bb, h, qi, ki: (bb, qi, h, 0))

    return pl.pallas_call(
        partial(_kernel, scale=scale, causal=causal, n_k=n_k, bq=bq, bk=bk),
        grid=grid,
        in_specs=[q_spec, k_spec, k_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, d), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
