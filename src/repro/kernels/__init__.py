"""`repro.kernels` — Pallas TPU kernels for the compute hot spots.

Each kernel ships three files: `<name>.py` (pl.pallas_call + BlockSpec),
`ops.py` (jit'd public wrapper; interpret-mode on CPU), `ref.py` (pure-jnp
oracle).  Tests sweep shapes/dtypes and assert_allclose vs the oracle.

The beamformer is the paper's own case-study kernel (§V-A2) re-thought
for the MXU; the others are the model zoo's hot spots (flash attention,
flash-decode, Mamba-2 SSD scan, RWKV-6 WKV, fused RMSNorm).
`paged_attention/` adds the serving-grade pair: a paged KV-cache pool
and a page-table-indirect ragged decode kernel, both checked against
the same ragged oracle as the dense flash-decode (`ragged_decode_ref`,
with `kv_len == 0` rows exact-zero).
"""
from .beamformer import beamform, beamform_ref, tuner_kernel_model
from .decode_attention import decode_attention, decode_attention_ref
from .flash_attention import attention_ref, flash_attention, flash_attention_custom
from .paged_attention import (
    PagedKVPool,
    paged_decode_attention,
    paged_decode_attention_ref,
    paged_tuner_model,
    ragged_decode_ref,
)
from .rmsnorm import rmsnorm, rmsnorm_ref
from .rwkv6 import wkv6, wkv6_ref
from .ssm_scan import ssd_scan, ssd_scan_ref

__all__ = [
    "beamform",
    "beamform_ref",
    "tuner_kernel_model",
    "decode_attention",
    "decode_attention_ref",
    "PagedKVPool",
    "paged_decode_attention",
    "paged_decode_attention_ref",
    "paged_tuner_model",
    "ragged_decode_ref",
    "attention_ref",
    "flash_attention",
    "flash_attention_custom",
    "rmsnorm",
    "rmsnorm_ref",
    "wkv6",
    "wkv6_ref",
    "ssd_scan",
    "ssd_scan_ref",
]
