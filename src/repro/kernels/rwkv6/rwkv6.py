"""Pallas TPU kernel: RWKV-6 WKV recurrence (per-channel decay + bonus).

Same VMEM-carried-state pattern as `ssm_scan`, but the decay is a
(C, K) per-channel matrix, so the intra-chunk term uses the factored
form (r·exp(L)) @ (k·exp(−L))ᵀ with the strict causal mask — exact under
the caller's decay bound (linear_scan.MAX_CHANNEL_DECAY with C=32 keeps
exp(−L) ≤ e^29, safely inside f32).  The bonus term u⊙(r·k)v is the
diagonal the strict mask excludes.

Grid = (B, H, T/C); state (K, K) f32 in VMEM scratch.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, fin_ref, st_ref, *, chunk, n_chunks):
    # parameter order: inputs, then BOTH outputs (o, fin), then scratch (st)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    r = r_ref[0, :, 0, :].astype(jnp.float32)  # (C, K)
    k = k_ref[0, :, 0, :].astype(jnp.float32)
    v = v_ref[0, :, 0, :].astype(jnp.float32)
    w = w_ref[0, :, 0, :].astype(jnp.float32)  # (C, K) log decay ≤ 0
    u = u_ref[0, :].astype(jnp.float32)  # (K,)

    L = jnp.cumsum(w, axis=0)  # (C, K)
    total = L[-1]  # (K,)
    r_eff = r * jnp.exp(L - w)  # o_t reads S_{t-1}
    k_eff = k * jnp.exp(-L)
    scores = jnp.dot(r_eff, k_eff.T, preferred_element_type=jnp.float32)  # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(ii > jj, scores, 0.0)  # strict: diagonal via bonus
    o = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    # bonus (current token)
    o = o + jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    # inter-chunk
    o = o + jnp.dot(r_eff, st_ref[...], preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)
    # state update
    k_carry = k * jnp.exp(total[None, :] - L)
    st_ref[...] = st_ref[...] * jnp.exp(total)[:, None] + jnp.dot(
        k_carry.T, v, preferred_element_type=jnp.float32
    )

    @pl.when(ci == n_chunks - 1)
    def _flush():
        fin_ref[0, 0, :, :] = st_ref[...]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, log_decay, bonus, chunk: int = 32, interpret: bool = True):
    """r,k,v,log_decay: (B,T,H,K); bonus: (H,K).

    Returns (out (B,T,H,K), final_state (B,H,K,K))."""
    b, t, h, kd = r.shape
    chunk = min(chunk, t)
    assert t % chunk == 0
    n_chunks = t // chunk
    grid = (b, h, n_chunks)

    x_spec = pl.BlockSpec((1, chunk, 1, kd), lambda bb, hh, ci: (bb, ci, hh, 0))
    u_spec = pl.BlockSpec((1, kd), lambda bb, hh, ci: (hh, 0))
    fin_spec = pl.BlockSpec((1, 1, kd, kd), lambda bb, hh, ci: (bb, hh, 0, 0))

    out, fin = pl.pallas_call(
        partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[x_spec, x_spec, x_spec, x_spec, u_spec],
        out_specs=[x_spec, fin_spec],
        out_shape=[
            jax.ShapeDtypeStruct(r.shape, r.dtype),
            jax.ShapeDtypeStruct((b, h, kd, kd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((kd, kd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_decay, bonus)
    return out, fin
