"""Public RWKV-6 WKV op."""
from __future__ import annotations

from repro.kernels.common import interpret_default

from .ref import wkv6_ref
from .rwkv6 import wkv6_pallas


def wkv6(r, k, v, log_decay, bonus, chunk: int = 32, use_pallas: bool = True):
    if not use_pallas:
        return wkv6_ref(r, k, v, log_decay, bonus)
    return wkv6_pallas(r, k, v, log_decay, bonus, chunk=chunk, interpret=interpret_default())
