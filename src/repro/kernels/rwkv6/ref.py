"""Oracle for the RWKV-6 WKV kernel: the jnp chunked engine in channel-decay
mode with the current-token bonus."""
from __future__ import annotations

from repro.models.linear_scan import chunked_linear_recurrence


def wkv6_ref(r, k, v, log_decay, bonus, initial_state=None):
    """r,k: (B,T,H,K); v: (B,T,H,K); log_decay: (B,T,H,K) (bounded, see
    linear_scan.MAX_CHANNEL_DECAY); bonus u: (H,K)."""
    return chunked_linear_recurrence(
        r, k, v, log_decay, chunk=min(32, r.shape[1]), include_current=False,
        bonus=bonus, initial_state=initial_state,
    )
