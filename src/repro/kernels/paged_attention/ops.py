"""Public paged decode-attention ops + the energy-tuner variant model.

`paged_decode_attention` dispatches the Pallas kernel (interpret on CPU)
or the gather-dense oracle; `pack_prefill_pages` scatters one admitted
request's prefilled dense K/V rows into its pool pages; and
`paged_tuner_model` is the (config → time, StepCost) hook consumed by
`repro.power.tuner` — the page-size × block × buffer-depth sweep that
`benchmarks/paged_decode.py` drives through the marker-free
`attribution_strategy` to trace the latency × J/token frontier.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import interpret_default
from repro.power.tpu_model import DvfsState, StepCost, TpuChipSpec

from .paged_attention import paged_decode_attention_pallas
from .ref import paged_decode_attention_ref

#: the tuner's knobs: page granularity, VMEM tile within a page, and the
#: DMA pipeline depth hiding the page-table-indirect issue latency
SEARCH_SPACE = {
    "page_size": (32, 64, 128, 256),
    "bk": (32, 128),
    "depth": (1, 2, 4),
}


def paged_decode_attention(
    q, k_pages, v_pages, page_table, kv_len, bk: int | None = None,
    use_pallas: bool = True,
):
    """q: (B,Hq,D); pages (P,ps,Hkv,D); page_table (B,max_pages); kv_len (B,).

    The table must cover every row's ``kv_len`` (unused entries point at
    the null page); ``kv_len == 0`` rows return exact zeros.
    """
    if not use_pallas:
        return paged_decode_attention_ref(q, k_pages, v_pages, page_table, kv_len)
    return paged_decode_attention_pallas(
        q, k_pages, v_pages, page_table, kv_len,
        bk=bk, interpret=interpret_default(),
    )


def init_page_arrays(n_pages, page_size, n_kv_heads, head_dim, dtype=jnp.bfloat16):
    """Zeroed device K and V page pools, ``(n_pages, ps, Hkv, Dh)`` each."""
    z = jnp.zeros((n_pages, page_size, n_kv_heads, head_dim), dtype)
    return z, z


@jax.jit
def pack_prefill_pages(k_pages, v_pages, k_dense, v_dense, page_ids):
    """Scatter one request's prefilled K/V into its pool pages.

    ``k_pages``/``v_pages``: (..., P, ps, Hkv, Dh) pools (a leading layer
    axis is fine); ``k_dense``/``v_dense``: (..., S, Hkv, Dh) the request's
    prefill rows; ``page_ids``: (n,) int32 with ``n * ps >= S`` (the tail
    of the last page is zero-filled — positions ``>= kv_len`` are masked
    by the kernel anyway).
    """
    ps = k_pages.shape[-3]
    s = k_dense.shape[-3]
    n = page_ids.shape[0]
    pad = [(0, 0)] * k_dense.ndim
    pad[-3] = (0, n * ps - s)

    def pack(pages, dense):
        lead = dense.shape[:-3]
        paged = jnp.pad(dense, pad).reshape(
            lead + (n, ps) + dense.shape[-2:]
        ).astype(pages.dtype)
        return pages.at[..., page_ids, :, :, :].set(paged)

    return pack(k_pages, k_dense), pack(v_pages, v_dense)


def apply_page_permutation(pages, perm):
    """Reorder device pages after `PagedKVPool.defrag` (``perm[new] = old``)."""
    return pages[..., jnp.asarray(perm), :, :, :]


# --------------------------------------------------------------------------
# modelled TPU cost (the autotuner's measurement target on this container)
# --------------------------------------------------------------------------
def paged_variant_time_cost(
    cfg: dict, chip: TpuChipSpec, dvfs: DvfsState,
    b: int = 64, hq: int = 8, hkv: int = 2, d: int = 128,
    kv_mean: float = 600.0, dtype_bytes: int = 2,
):
    """(time_s, StepCost) for one paged decode step of ``b`` sequences.

    Napkin model (what the sweep actually trades off):

    * **over-fetch** — whole pages stream through HBM regardless of tail
      occupancy, so bytes grow with ``page_size`` on ragged lengths
      (``ceil(kv/ps)·ps`` vs ``kv``): big pages buy speed with joules;
    * **issue latency** — every (row, kv-head, block) grid step pays a
      page-table-indirect DMA setup on the core clock; ``depth``-deep
      buffering overlaps it, ``bk`` sets how many blocks a page splits
      into;
    * **DVFS** — the DMA descriptors and part of the memory fabric live
      in the core clock domain, so downclocking stretches the step while
      dynamic energy drops with ``f·V²``: that is the real speed/joules
      axis the latency × J/token front trades along;
    * **VMEM** — ``depth`` in-flight (bk, D) K+V tiles plus the (group, D)
      q/acc tiles must fit; violations fall off a cliff.
    """
    ps = int(cfg["page_size"])
    bk = min(int(cfg["bk"]), ps)
    depth = int(cfg["depth"])
    group = hq // hkv

    pages_per_seq = np.ceil(kv_mean / ps)
    kv_bytes = 2.0 * b * pages_per_seq * ps * hkv * d * dtype_bytes  # K + V
    io_bytes = kv_bytes + 2.0 * b * hq * d * dtype_bytes  # + q in, o out
    flops = 2.0 * 2.0 * b * hq * d * kv_mean  # qk^T + pv

    n_blocks = b * hkv * pages_per_seq * (ps // bk)
    t_issue = n_blocks * 5e-8 / (depth * dvfs.scale)

    vmem = depth * 2 * bk * d * dtype_bytes + 4 * 3 * group * d
    fits = vmem <= chip.vmem_bytes
    # ~45% of the effective streaming bandwidth rides the core clock
    # domain (descriptor issue, on-chip interconnect), the rest is pure
    # HBM — so downclocking costs time even on a memory-bound kernel
    bw = chip.hbm_bw * (0.9 if fits else 0.25) * (0.55 + 0.45 * dvfs.scale)
    t_mem = io_bytes / bw
    # decode GQA runs skinny (group, bk) matmuls — far off MXU peak
    t_compute = flops / (chip.peak_flops_bf16 * 0.15 * dvfs.scale)
    time_s = max(t_mem, t_compute) + t_issue
    return time_s, StepCost(flops=flops, hbm_bytes=io_bytes, ici_bytes=0.0)


def paged_tuner_model(
    b: int = 64, hq: int = 8, hkv: int = 2, d: int = 128, kv_mean: float = 600.0,
):
    from repro.power.tuner import KernelVariantModel

    return KernelVariantModel(
        name="paged-decode-attention",
        useful_flops=2.0 * 2.0 * b * hq * d * kv_mean,
        model=partial(
            paged_variant_time_cost, b=b, hq=hq, hkv=hkv, d=d, kv_mean=kv_mean
        ),
        search_space=SEARCH_SPACE,
    )
