"""Oracles for paged flash-decode, shared with the dense kernel's tests.

`ragged_decode_ref` is THE oracle for ragged single-token decode — both
the dense `decode_attention` and the paged kernel are tested against it.
It extends `decode_attention_ref` with the ragged contract the serving
loop needs: rows with ``kv_len == 0`` (free/padded slots) are **exact
zeros**, where a naive masked softmax would emit a uniform average (or
NaN) instead.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.decode_attention.ref import decode_attention_ref


def ragged_decode_ref(q, k_cache, v_cache, kv_len):
    """q: (B,Hq,D); caches (B,S,Hkv,D); kv_len (B,) -> (B,Hq,D).

    Rows with ``kv_len == 0`` return exact zeros (nothing to attend to).
    """
    out = decode_attention_ref(q, k_cache, v_cache, jnp.maximum(kv_len, 1))
    return jnp.where((kv_len > 0)[:, None, None], out, 0.0).astype(q.dtype)


def gather_pages(pages, page_table):
    """(P,ps,Hkv,D) pages + (B,max_pages) table -> dense (B,max_pages*ps,Hkv,D)."""
    b, n = page_table.shape
    _, ps, hkv, d = pages.shape
    dense = pages[page_table.reshape(-1)]  # (B*n, ps, Hkv, D)
    return dense.reshape(b, n * ps, hkv, d)


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, kv_len):
    """Paged oracle: gather the pages dense, then `ragged_decode_ref`."""
    k_dense = gather_pages(k_pages, page_table)
    v_dense = gather_pages(v_pages, page_table)
    return ragged_decode_ref(
        q, k_dense.astype(q.dtype), v_dense.astype(q.dtype), kv_len
    )
