"""Host-side paged KV-cache pool: fixed-size pages, per-request page tables.

The pool owns page *ids* only — the actual K/V page arrays live on device
(``(L, n_pages, page_size, Hkv, Dh)``, see `ops.init_page_arrays` and the
model's ``init_paged_cache``).  Page 0 is reserved as the **null page**:
free table slots point at it, and padded batch rows (``kv_len == 0``)
write their dead token there, so a table is always fully populated with
valid indices and the kernel never needs a bounds branch.

Allocation is all-or-nothing (a request either gets every page it asked
for or ``None`` — no partial grants to unwind), frees return pages to a
LIFO free stack (hot reuse), and :meth:`defrag` compacts the in-use pages
to the low end of the pool, returning the gather permutation to apply to
the device arrays (``pages[perm]``).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

NULL_PAGE = 0


def pages_for(n_tokens: int, page_size: int) -> int:
    """Pages needed to hold ``n_tokens`` (0 tokens still owns 0 pages)."""
    return -(-int(n_tokens) // int(page_size)) if n_tokens > 0 else 0


@dataclass(frozen=True)
class PoolStats:
    n_pages: int  # total pages incl. the reserved null page
    page_size: int
    in_use: int
    free: int
    high_water: int  # max pages simultaneously in use over the pool's life
    allocs: int  # page grants
    frees: int  # pages returned
    alloc_failures: int  # all-or-nothing requests refused for capacity
    reused_pages: int  # grants of a page that had a previous owner
    defrags: int
    tokens: int  # tokens currently stored across all requests
    utilization: float  # tokens / (in_use * page_size); 1.0 when empty
    fragmentation: float  # 1 - in_use/(highest in-use id); 0 when compact


class PagedKVPool:
    """Page-table allocator for a paged KV cache.

    ``n_pages`` includes the reserved null page, so a pool built for ``k``
    usable pages needs ``n_pages = k + 1``.
    """

    def __init__(self, n_pages: int, page_size: int):
        if n_pages < 2:
            raise ValueError("need at least one usable page beside the null page")
        if page_size < 1:
            raise ValueError("page_size must be >= 1")
        self.n_pages = int(n_pages)
        self.page_size = int(page_size)
        # LIFO: low ids are handed out first, so a freshly built pool stays
        # compact until churn actually fragments it
        self._free: list[int] = list(range(self.n_pages - 1, 0, -1))
        self._tables: dict[int, list[int]] = {}
        self._lens: dict[int, int] = {}
        self._ever_used: set[int] = set()
        self.allocs = 0
        self.frees = 0
        self.alloc_failures = 0
        self.reused_pages = 0
        self.defrags = 0
        self.high_water = 0

    # ----------------------------------------------------------- queries
    @property
    def rids(self) -> set[int]:
        return set(self._tables)

    @property
    def in_use(self) -> int:
        return (self.n_pages - 1) - len(self._free)

    def kv_len(self, rid: int) -> int:
        return self._lens[rid]

    def pages_of(self, rid: int) -> list[int]:
        return list(self._tables[rid])

    def capacity_tokens(self, rid: int) -> int:
        return len(self._tables[rid]) * self.page_size

    # ----------------------------------------------------------- alloc/free
    def _grant(self, n: int) -> list[int] | None:
        if n > len(self._free):
            self.alloc_failures += 1
            return None
        pages = [self._free.pop() for _ in range(n)]
        self.allocs += n
        self.reused_pages += sum(1 for p in pages if p in self._ever_used)
        self._ever_used.update(pages)
        self.high_water = max(self.high_water, self.in_use)
        return pages

    def alloc(self, rid: int, n_tokens: int, extra_pages: int = 0) -> list[int] | None:
        """Admit ``rid`` with capacity for ``n_tokens`` (+ ``extra_pages``).

        All-or-nothing; returns the granted page list or ``None`` (counted
        in ``alloc_failures``) without side effects.  The request starts at
        ``kv_len == 0`` — use :meth:`note_tokens` / :meth:`append` as its
        cache actually fills.
        """
        if rid in self._tables:
            raise KeyError(f"rid {rid} already allocated")
        pages = self._grant(pages_for(n_tokens, self.page_size) + int(extra_pages))
        if pages is None:
            return None
        self._tables[rid] = pages
        self._lens[rid] = 0
        return pages

    def extend(self, rid: int, n_tokens: int) -> list[int] | None:
        """Grow ``rid``'s reservation to cover ``n_tokens`` total."""
        need = pages_for(n_tokens, self.page_size) - len(self._tables[rid])
        if need <= 0:
            return []
        pages = self._grant(need)
        if pages is None:
            return None
        self._tables[rid].extend(pages)
        return pages

    def note_tokens(self, rid: int, n_tokens: int) -> None:
        """Record that ``rid`` now holds ``n_tokens`` (within its reservation)."""
        if n_tokens > self.capacity_tokens(rid):
            raise ValueError(
                f"rid {rid}: {n_tokens} tokens exceeds the "
                f"{self.capacity_tokens(rid)}-token reservation"
            )
        self._lens[rid] = int(n_tokens)

    def append(self, rid: int, n_tokens: int = 1) -> bool:
        """Append decoded tokens, allocating pages on demand; False on OOM."""
        want = self._lens[rid] + int(n_tokens)
        if want > self.capacity_tokens(rid) and self.extend(rid, want) is None:
            return False
        self._lens[rid] = want
        return True

    def free(self, rid: int) -> int:
        """Release every page ``rid`` owns; returns how many came back."""
        pages = self._tables.pop(rid)
        del self._lens[rid]
        self._free.extend(reversed(pages))  # LIFO: freed pages are reused first
        self.frees += len(pages)
        return len(pages)

    # ----------------------------------------------------------- tables
    def table_row(self, rid: int | None, width: int) -> np.ndarray:
        """(width,) int32 page-table row, null-padded; all-null for ``None``."""
        row = np.full(width, NULL_PAGE, np.int32)
        if rid is not None:
            pages = self._tables[rid]
            if len(pages) > width:
                raise ValueError(f"rid {rid} owns {len(pages)} pages > width {width}")
            row[: len(pages)] = pages
        return row

    def table(self, slot_rids: list[int | None], width: int) -> np.ndarray:
        """(B, width) page table for a batch of slots (``None`` = free slot)."""
        return np.stack([self.table_row(r, width) for r in slot_rids])

    def kv_lens(self, slot_rids: list[int | None]) -> np.ndarray:
        return np.array(
            [0 if r is None else self._lens[r] for r in slot_rids], np.int32
        )

    # ----------------------------------------------------------- defrag
    def defrag(self) -> np.ndarray:
        """Compact in-use pages to ids ``1..in_use``; returns the gather perm.

        ``perm`` is a (n_pages,) array with ``perm[new_id] = old_id`` — apply
        it to the device page arrays as ``pages = pages[perm]`` (see
        `ops.apply_page_permutation`) *before* using any table built after
        the call.  The null page stays put.
        """
        perm = np.full(self.n_pages, -1, np.int64)
        perm[NULL_PAGE] = NULL_PAGE
        nxt = 1
        for rid in sorted(self._tables):
            pages = self._tables[rid]
            for i, old in enumerate(pages):
                perm[nxt] = old
                pages[i] = nxt
                nxt += 1
        leftover = [p for p in range(1, self.n_pages) if p not in set(perm[:nxt])]
        perm[nxt:] = leftover
        self._free = list(range(self.n_pages - 1, nxt - 1, -1))
        self.defrags += 1
        return perm

    # ----------------------------------------------------------- stats
    def stats(self) -> PoolStats:
        tokens = sum(self._lens.values())
        in_use = self.in_use
        highest = max((p for t in self._tables.values() for p in t), default=0)
        return PoolStats(
            n_pages=self.n_pages,
            page_size=self.page_size,
            in_use=in_use,
            free=len(self._free),
            high_water=self.high_water,
            allocs=self.allocs,
            frees=self.frees,
            alloc_failures=self.alloc_failures,
            reused_pages=self.reused_pages,
            defrags=self.defrags,
            tokens=tokens,
            utilization=tokens / (in_use * self.page_size) if in_use else 1.0,
            fragmentation=1.0 - in_use / highest if highest else 0.0,
        )
