"""Paged KV-cache pool + ragged paged decode-attention kernel."""
from .ops import (
    SEARCH_SPACE,
    apply_page_permutation,
    init_page_arrays,
    pack_prefill_pages,
    paged_decode_attention,
    paged_tuner_model,
    paged_variant_time_cost,
)
from .paged_attention import paged_decode_attention_pallas
from .pool import NULL_PAGE, PagedKVPool, PoolStats, pages_for
from .ref import gather_pages, paged_decode_attention_ref, ragged_decode_ref

__all__ = [
    "NULL_PAGE",
    "PagedKVPool",
    "PoolStats",
    "SEARCH_SPACE",
    "apply_page_permutation",
    "gather_pages",
    "init_page_arrays",
    "pack_prefill_pages",
    "paged_decode_attention",
    "paged_decode_attention_pallas",
    "paged_decode_attention_ref",
    "paged_tuner_model",
    "paged_variant_time_cost",
    "pages_for",
    "ragged_decode_ref",
]
