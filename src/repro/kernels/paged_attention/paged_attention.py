"""Pallas TPU ragged paged decode-attention: one query token vs a paged KV pool.

Same flash-decode shape as `repro.kernels.decode_attention` — grid
(B, Hkv, blocks) streaming the cache in (bk, D) VMEM tiles, all `group`
q-heads sharing a KV head processed as one (group, D) tile — except the
cache is a **page pool** ``(n_pages, page_size, Hkv, D)`` addressed
through per-row page tables instead of a dense ``(B, S, Hkv, D)`` slab.

The page table and per-row ragged lengths ride in as **scalar-prefetch**
arguments (`pltpu.PrefetchScalarGridSpec`), so the KV BlockSpec index map
can chase the indirection *before* the kernel body runs: block ``bi`` of
row ``b`` loads page ``table[b, bi // (ps // bk)]`` at sub-page offset
``bi % (ps // bk)`` — the DMA engine streams exactly the pages the row
owns, and the grid's block axis covers only ``table.shape[1]`` pages (the
longest *live* sequence), not a worst-case dense ``S_max``.

Ragged contract: positions ``>= kv_len[b]`` are masked, blocks past the
row's length are skipped (their table entries point at the reserved null
page and are never read into compute), and rows with ``kv_len == 0`` —
the serve loop's free/padded slots — flush **exact zeros** instead of the
0/0 NaN a dense softmax would produce.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    table_ref, len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_ref, l_ref,
    *, scale, bk, n_blk,
):
    bi = pl.program_id(2)

    @pl.when(bi == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[pl.program_id(0)]

    @pl.when(bi * bk < kv_len)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (group, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (group, bk)
        pos = bi * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc[...] = acc[...] * corr[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(bi == n_blk - 1)
    def _flush():
        # kv_len == 0 rows never ran `_step`; flush exact zeros, not 0/0
        l = l_ref[...]
        out = acc[...] / jnp.where(l > 0.0, l, 1.0)[:, None]
        out = jnp.where((l > 0.0)[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("bk", "interpret"))
def paged_decode_attention_pallas(
    q, k_pages, v_pages, page_table, kv_len, bk: int | None = None,
    interpret: bool = True,
):
    """q: (B,Hq,D); pages (P,ps,Hkv,D); page_table (B,max_pages) int32;
    kv_len (B,) int32 -> (B,Hq,D).
    """
    b, hq, d = q.shape
    _, ps, hkv, _ = k_pages.shape
    assert hq % hkv == 0
    group = hq // hkv
    bk = ps if bk is None else max(1, min(int(bk), ps))
    assert ps % bk == 0, "bk must divide the page size"
    sub = ps // bk  # KV blocks per page
    max_pages = page_table.shape[1]
    n_blk = max_pages * sub
    grid = (b, hkv, n_blk)

    # view q as (B, group, Hkv, D) so one KV-head block feeds `group` heads
    q4 = q.reshape(b, hkv, group, d).transpose(0, 2, 1, 3)
    q_spec = pl.BlockSpec((1, group, 1, d), lambda bb, h, bi, tab, ln: (bb, 0, h, 0))
    kv_spec = pl.BlockSpec(
        (1, bk, 1, d),
        lambda bb, h, bi, tab, ln: (tab[bb, bi // sub], bi % sub, h, 0),
    )
    o_spec = pl.BlockSpec((1, group, 1, d), lambda bb, h, bi, tab, ln: (bb, 0, h, 0))

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        partial(_kernel, scale=1.0 / (d**0.5), bk=bk, n_blk=n_blk),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, group, hkv, d), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), kv_len.astype(jnp.int32), q4, k_pages, v_pages)
    return out.transpose(0, 2, 1, 3).reshape(b, hq, d)
