"""Public SSD chunk-scan op."""
from __future__ import annotations

from repro.kernels.common import interpret_default

from .ref import ssd_scan_ref
from .ssm_scan import ssd_scan_pallas


def ssd_scan(q, k, v, log_decay, chunk: int = 64, use_pallas: bool = True):
    if not use_pallas:
        return ssd_scan_ref(q, k, v, log_decay)
    return ssd_scan_pallas(q, k, v, log_decay, chunk=chunk, interpret=interpret_default())
