"""Oracle for the SSD chunk-scan kernel: the jnp chunked engine (which is
itself tested against a naive sequential recurrence)."""
from __future__ import annotations

from repro.models.linear_scan import chunked_linear_recurrence


def ssd_scan_ref(q, k, v, log_decay, initial_state=None):
    """Scalar-decay (Mamba-2) recurrence. q,k: (B,T,H,N); v: (B,T,H,P);
    log_decay: (B,T,H). Returns (out, final_state)."""
    return chunked_linear_recurrence(
        q, k, v, log_decay, chunk=min(64, q.shape[1]), include_current=True,
        initial_state=initial_state,
    )
