"""Pallas TPU kernel: Mamba-2 SSD chunk scan (scalar per-head decay).

Grid = (B, H, T/C) with the chunk dimension innermost: TPU grids run
sequentially, so the (N, P) state lives in f32 VMEM scratch and carries
across chunk steps — the inter-chunk recurrence costs zero HBM traffic
(vs. the XLA `lax.scan` path, which round-trips the state through HBM
every chunk).  Intra-chunk work is two (C,N)×(N,P)-class MXU passes plus
a (C,C) masked decay matmul, i.e. the same math as
`repro.models.linear_scan` in scalar mode (its segsum formulation,
numerically exact for any decay).

Block shapes: C×N and C×P tiles with C=64..128, N=P=64 — MXU-aligned for
zamba2 (heads of 64, state 64).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(q_ref, k_ref, v_ref, w_ref, o_ref, fin_ref, st_ref, *, chunk, n_chunks):
    # parameter order: inputs, then BOTH outputs (o, fin), then scratch (st)
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        st_ref[...] = jnp.zeros_like(st_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32)  # (C, N)
    k = k_ref[0, :, 0, :].astype(jnp.float32)  # (C, N)
    v = v_ref[0, :, 0, :].astype(jnp.float32)  # (C, P)
    w = w_ref[0, :, 0].astype(jnp.float32)  # (C,)

    L = jnp.cumsum(w)  # (C,)
    total = L[-1]
    # intra-chunk: segsum difference matrix, exact (≤ 0 on the triangle)
    diff = L[:, None] - L[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay_ij = jnp.where(ii >= jj, jnp.exp(diff), 0.0)
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * decay_ij
    o = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    # inter-chunk: read carried state
    q_eff = q * jnp.exp(L)[:, None]
    o = o + jnp.dot(q_eff, st_ref[...], preferred_element_type=jnp.float32)
    o_ref[0, :, 0, :] = o.astype(o_ref.dtype)
    # state update
    k_carry = k * jnp.exp(total - L)[:, None]
    st_ref[...] = st_ref[...] * jnp.exp(total) + jnp.dot(
        k_carry.T, v, preferred_element_type=jnp.float32
    )

    @pl.when(ci == n_chunks - 1)
    def _flush():
        fin_ref[0, 0, :, :] = st_ref[...]


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(q, k, v, log_decay, chunk: int = 64, interpret: bool = True):
    """q,k: (B,T,H,N); v: (B,T,H,P); log_decay: (B,T,H).

    Returns (out (B,T,H,P), final_state (B,H,N,P)).
    """
    b, t, h, n = q.shape
    p = v.shape[-1]
    chunk = min(chunk, t)
    assert t % chunk == 0
    n_chunks = t // chunk
    grid = (b, h, n_chunks)

    qk_spec = pl.BlockSpec((1, chunk, 1, n), lambda bb, hh, ci: (bb, ci, hh, 0))
    v_spec = pl.BlockSpec((1, chunk, 1, p), lambda bb, hh, ci: (bb, ci, hh, 0))
    w_spec = pl.BlockSpec((1, chunk, 1), lambda bb, hh, ci: (bb, ci, hh))
    o_spec = v_spec
    fin_spec = pl.BlockSpec((1, 1, n, p), lambda bb, hh, ci: (bb, hh, 0, 0))

    out, fin = pl.pallas_call(
        partial(_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=grid,
        in_specs=[qk_spec, qk_spec, v_spec, w_spec],
        out_specs=[o_spec, fin_spec],
        out_shape=[
            jax.ShapeDtypeStruct(v.shape, v.dtype),
            jax.ShapeDtypeStruct((b, h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(q, k, v, log_decay)
    return out, fin
