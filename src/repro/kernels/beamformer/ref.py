"""Pure-jnp oracle for the Tensor-Core Beamformer kernel.

The paper's Kernel-Tuner case study (§V-A2): beamforming = complex matrix
multiply C[M,N] = A[M,K] · B[K,N] with 16-bit IO, M=N=K=4096 — "complex
matrix multiplications ... not supported by vendor libraries".
"""
from __future__ import annotations

import jax.numpy as jnp


def beamform_ref(a_re, a_im, b_re, b_im, out_dtype=jnp.float32):
    """Complex GEMM on split re/im planes (bf16 in, f32 accumulate)."""
    ar = a_re.astype(jnp.float32)
    ai = a_im.astype(jnp.float32)
    br = b_re.astype(jnp.float32)
    bi = b_im.astype(jnp.float32)
    c_re = ar @ br - ai @ bi
    c_im = ar @ bi + ai @ br
    return c_re.astype(out_dtype), c_im.astype(out_dtype)
