"""Pallas TPU kernel: complex GEMM (Tensor-Core Beamformer, MXU edition).

Hardware adaptation (DESIGN.md §2.3): the CUDA original tiles WMMA
fragments per warp; on TPU the unit is the 128×128 MXU pass, so the
tunables become VMEM block shapes (bm, bn, bk) and the complex-arithmetic
schedule:

* ``karatsuba=False`` — 4 real matmuls (arbr, aibi, arbi, aibr)
* ``karatsuba=True``  — 3-multiplication Gauss/Karatsuba form:
      t1 = ar·br, t2 = ai·bi, t3 = (ar+ai)·(br+bi)
      c_re = t1 − t2, c_im = t3 − t1 − t2
  (−25 % MXU work for three extra VPU adds — a real tuning axis.)

Grid = (M/bm, N/bn, K/bk), K innermost; f32 VMEM scratch accumulators
persist across the sequential K steps and are flushed at the last one.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_re, a_im, b_re, b_im, c_re, c_im, acc_re, acc_im, *, karatsuba, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_re[...] = jnp.zeros_like(acc_re)
        acc_im[...] = jnp.zeros_like(acc_im)

    ar = a_re[...]
    ai = a_im[...]
    br = b_re[...]
    bi = b_im[...]
    f32 = jnp.float32
    if karatsuba:
        t1 = jnp.dot(ar, br, preferred_element_type=f32)
        t2 = jnp.dot(ai, bi, preferred_element_type=f32)
        t3 = jnp.dot((ar + ai), (br + bi), preferred_element_type=f32)
        acc_re[...] += t1 - t2
        acc_im[...] += t3 - t1 - t2
    else:
        acc_re[...] += jnp.dot(ar, br, preferred_element_type=f32) - jnp.dot(
            ai, bi, preferred_element_type=f32
        )
        acc_im[...] += jnp.dot(ar, bi, preferred_element_type=f32) + jnp.dot(
            ai, br, preferred_element_type=f32
        )

    @pl.when(ki == n_k - 1)
    def _flush():
        c_re[...] = acc_re[...].astype(c_re.dtype)
        c_im[...] = acc_im[...].astype(c_im.dtype)


@partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "karatsuba", "out_dtype", "interpret"),
)
def beamform_pallas(
    a_re,
    a_im,
    b_re,
    b_im,
    bm: int = 256,
    bn: int = 256,
    bk: int = 512,
    karatsuba: bool = False,
    out_dtype=jnp.float32,
    interpret: bool = True,
):
    m, k = a_re.shape
    k2, n = b_re.shape
    assert k == k2
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    a_spec = pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk))
    b_spec = pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j))
    c_spec = pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j))
    out_shape = [
        jax.ShapeDtypeStruct((m, n), out_dtype),
        jax.ShapeDtypeStruct((m, n), out_dtype),
    ]
    return pl.pallas_call(
        partial(_kernel, karatsuba=karatsuba, n_k=n_k),
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[c_spec, c_spec],
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),  # acc_re
            pltpu.VMEM((bm, bn), jnp.float32),  # acc_im
        ],
        interpret=interpret,
    )(a_re, a_im, b_re, b_im)
