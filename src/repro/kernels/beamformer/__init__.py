from .ops import SEARCH_SPACE, beamform, tuner_kernel_model, variant_time_cost
from .ref import beamform_ref

__all__ = ["SEARCH_SPACE", "beamform", "beamform_ref", "tuner_kernel_model", "variant_time_cost"]
