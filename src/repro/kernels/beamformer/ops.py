"""jit'd public wrapper + TPU performance/energy model for the beamformer.

`beamform()` dispatches Pallas (interpret on CPU) or the jnp reference.
`variant_model()` is the (config → time, StepCost) hook consumed by
`repro.power.tuner` — the Fig 8 reproduction tunes exactly these knobs.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default
from repro.power.tpu_model import DvfsState, StepCost, TpuChipSpec

from .beamformer import beamform_pallas
from .ref import beamform_ref

SEARCH_SPACE = {
    "bm": (128, 256, 512),
    "bn": (128, 256, 512),
    "bk": (128, 256, 512),
    "karatsuba": (False, True),
    "double_buffer": (False, True),
}


def beamform(a_re, a_im, b_re, b_im, use_pallas: bool = True, **cfg):
    if not use_pallas:
        return beamform_ref(a_re, a_im, b_re, b_im)
    cfg.setdefault("interpret", interpret_default())
    cfg.pop("double_buffer", None)  # scheduling knob, no numeric effect
    return beamform_pallas(a_re, a_im, b_re, b_im, **cfg)


# --------------------------------------------------------------------------
# modelled TPU cost (the autotuner's measurement target on this container)
# --------------------------------------------------------------------------
def variant_time_cost(cfg: dict, chip: TpuChipSpec, dvfs: DvfsState,
                      m: int = 4096, n: int = 4096, k: int = 4096,
                      dtype_bytes: int = 2):
    """(time_s, StepCost) for one kernel launch under `cfg`.

    Napkin model (documented, used by §Perf):
    * useful FLOPs = 8·M·N·K (4 real matmuls) or 6·M·N·K (karatsuba);
    * MXU efficiency = alignment(bm,bn,bk vs 128) × pipeline factor
      (double buffering hides HBM latency: 0.92 vs 0.70);
    * HBM traffic = A·(N/bn) + B·(M/bm) + C  (classic blocked-GEMM reuse);
    * VMEM constraint: working set (a + b + 2×acc (+karatsuba temps))
      must fit; violations fall off a cliff (0.25× efficiency).
    """
    bm, bn, bk = cfg["bm"], cfg["bn"], cfg["bk"]
    kar = cfg.get("karatsuba", False)
    dbuf = cfg.get("double_buffer", False)

    matmul_flops = (6 if kar else 8) * m * n * k
    useful_flops = 8 * m * n * k  # reported TFLOP/s uses the mathematical op count

    align = 1.0
    for b in (bm, bn, bk):
        align *= 1.0 if b % chip.mxu_dim == 0 else 0.5
    pipe = 0.92 if dbuf else 0.70

    buffers = 2 if dbuf else 1
    vmem = dtype_bytes * buffers * 2 * (bm * bk + bk * bn) + 4 * 2 * bm * bn
    if kar:
        vmem += dtype_bytes * (bm * bk + bk * bn)  # (ar+ai), (br+bi) temps
    fits = vmem <= chip.vmem_bytes
    eff = align * pipe * (1.0 if fits else 0.25)

    hbm = dtype_bytes * 2 * (m * k * (n // bn) + k * n * (m // bm)) + 4 * 2 * m * n

    t_compute = matmul_flops / (chip.peak_flops_bf16 * eff * dvfs.scale)
    t_memory = hbm / chip.hbm_bw
    time_s = max(t_compute, t_memory) if dbuf else t_compute + 0.6 * t_memory
    return time_s, StepCost(flops=matmul_flops, hbm_bytes=hbm, ici_bytes=0.0)


def tuner_kernel_model(m: int = 4096, n: int = 4096, k: int = 4096):
    from functools import partial

    from repro.power.tuner import KernelVariantModel

    return KernelVariantModel(
        name="tensor-core-beamformer",
        useful_flops=8.0 * m * n * k,
        model=partial(variant_time_cost, m=m, n=n, k=k),
        search_space=SEARCH_SPACE,
    )
