"""Oracle for the fused RMSNorm kernel."""
from __future__ import annotations

from repro.models.layers import rmsnorm as rmsnorm_jnp


def rmsnorm_ref(x, w, eps: float = 1e-5):
    return rmsnorm_jnp(x, w, eps)
