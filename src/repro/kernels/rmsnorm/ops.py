"""Public fused-RMSNorm op."""
from __future__ import annotations

from repro.kernels.common import interpret_default

from .ref import rmsnorm_ref
from .rmsnorm import rmsnorm_pallas


def rmsnorm(x, w, eps: float = 1e-5, use_pallas: bool = True):
    if not use_pallas:
        return rmsnorm_ref(x, w, eps)
    return rmsnorm_pallas(x, w, eps=eps, interpret=interpret_default())
