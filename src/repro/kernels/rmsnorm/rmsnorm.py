"""Pallas TPU kernel: fused RMSNorm (norm + scale in one VMEM pass).

Grid over row blocks; each step loads a (bn, D) tile, computes the f32
row rms and writes the scaled tile — one HBM round trip instead of the
separate mean/rsqrt/mul kernels XLA sometimes emits around layer
boundaries.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("bn", "eps", "interpret"))
def rmsnorm_pallas(x, w, bn: int = 256, eps: float = 1e-5, interpret: bool = True):
    """x: (..., D); w: (D,)."""
    orig_shape = x.shape
    d = orig_shape[-1]
    x2 = x.reshape(-1, d)
    n = x2.shape[0]
    bn = min(bn, n)
    pad = (-n) % bn
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // bn,)
    out = pl.pallas_call(
        partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x.dtype),
        interpret=interpret,
    )(x2, w)
    if pad:
        out = out[:n]
    return out.reshape(orig_shape)
