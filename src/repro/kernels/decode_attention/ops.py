"""Public decode-attention op."""
from __future__ import annotations

from repro.kernels.common import interpret_default

from .decode_attention import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q, k_cache, v_cache, kv_len, bk: int = 256, use_pallas: bool = True):
    if not use_pallas:
        return decode_attention_ref(q, k_cache, v_cache, kv_len)
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_len, bk=bk, interpret=interpret_default()
    )
