"""Public decode-attention op."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.common import interpret_default

from .decode_attention import decode_attention_pallas
from .ref import decode_attention_ref


def decode_attention(q, k_cache, v_cache, kv_len, bk: int = 256, use_pallas: bool = True):
    if not use_pallas:
        return decode_attention_ref(q, k_cache, v_cache, kv_len)
    # tiny caches: a block must never exceed the cache (bk > S used to trip
    # the kernel's divisibility assert), and a non-multiple tail (S % bk)
    # is padded up to a whole block — padded positions sit at >= S >= kv_len
    # so the in-kernel length mask already excludes them.
    s = k_cache.shape[1]
    bk = max(1, min(int(bk), s))
    pad = -s % bk
    if pad:
        widths = [(0, 0), (0, pad), (0, 0), (0, 0)]
        k_cache = jnp.pad(k_cache, widths)
        v_cache = jnp.pad(v_cache, widths)
    return decode_attention_pallas(
        q, k_cache, v_cache, kv_len, bk=bk, interpret=interpret_default()
    )
