"""Oracle for flash-decode: single-token attention against a KV cache."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers import full_attention


def decode_attention_ref(q, k_cache, v_cache, kv_len):
    """q: (B,Hq,D); caches: (B,S,Hkv,D); kv_len: (B,) valid prefix.

    Returns (B,Hq,D).
    """
    o = full_attention(
        q[:, None], k_cache, v_cache, causal=False, kv_len=kv_len
    )
    return o[:, 0]
