"""Pallas TPU flash-decode: one query token vs a long KV cache.

The decode shape is memory-bound (the whole cache streams through HBM for
8–128 queries), so the kernel's job is bandwidth efficiency: grid =
(B, Hkv, S/bk) streams the cache in (bk, D) VMEM tiles; all `group`
q-heads sharing one KV head are processed together as a (group, D) tile
(one cache read feeds `group` MXU passes — the GQA bandwidth win).

Out-of-range cache positions (>= kv_len) are masked via a (B,) lengths
array carried in SMEM-like fashion (a (1,1) block per batch row).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, acc, m_ref, l_ref, *, scale, bk, n_k):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_len = len_ref[0]

    @pl.when(ki * bk < kv_len)
    def _step():
        q = q_ref[0, :, 0, :].astype(jnp.float32)  # (group, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale  # (group, bk)
        pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc[...] = acc[...] * corr[:, None] + jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _flush():
        # rows with kv_len == 0 never ran `_step`: l is 0 and dividing by it
        # would emit NaN.  A zero-length cache has a well-defined answer —
        # nothing to attend to — so those rows flush exact zeros (the
        # serve loop's free/padded slots rely on this contract).
        l = l_ref[...]
        out = acc[...] / jnp.where(l > 0.0, l, 1.0)[:, None]
        out = jnp.where((l > 0.0)[:, None], out, 0.0)
        o_ref[0, :, 0, :] = out.astype(o_ref.dtype)


@partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention_pallas(q, k_cache, v_cache, kv_len, bk: int = 256, interpret: bool = True):
    """q: (B,Hq,D); caches (B,S,Hkv,D); kv_len (B,) int32 -> (B,Hq,D)."""
    b, hq, d = q.shape
    _, s, hkv, _ = k_cache.shape
    assert hq % hkv == 0
    group = hq // hkv
    bk = min(bk, s)
    assert s % bk == 0
    n_k = s // bk
    grid = (b, hkv, n_k)

    # view q as (B, Hkv, group, D) blocks
    q4 = q.reshape(b, hkv, group, d).transpose(0, 2, 1, 3)  # (B, group, Hkv, D)
    len_spec = pl.BlockSpec((1,), lambda bb, h, ki: (bb,))
    q_spec = pl.BlockSpec((1, group, 1, d), lambda bb, h, ki: (bb, 0, h, 0))
    kv_spec = pl.BlockSpec((1, bk, 1, d), lambda bb, h, ki: (bb, ki, h, 0))
    o_spec = pl.BlockSpec((1, group, 1, d), lambda bb, h, ki: (bb, 0, h, 0))

    out = pl.pallas_call(
        partial(_kernel, scale=1.0 / (d**0.5), bk=bk, n_k=n_k),
        grid=grid,
        in_specs=[len_spec, q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, group, hkv, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, d), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ],
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q4, k_cache, v_cache)
    return out.transpose(0, 2, 1, 3).reshape(b, hq, d)
