"""Sharded, atomic, async, mesh-agnostic checkpointing.

Layout: one `.npy` per pytree leaf (path-encoded filename) + a JSON
manifest (a recursive tree *skeleton*, step metadata, data-pipeline
state).  Writes go to `<name>.tmp/` and are renamed atomically — a crash
mid-write never corrupts the previous checkpoint.

Elastic resume: leaves are stored *unsharded* (gathered via device_get),
so a checkpoint written under one mesh loads under any other —
`restore(..., shardings=...)` device_puts each leaf with the new mesh's
sharding.  At real multi-host scale the same manifest format extends to
per-host shard files; the single-process writer here is the degenerate
case (DESIGN.md §3).

`AsyncCheckpointer` snapshots on the caller thread (device_get = a
consistent cut) and writes on a background thread — training overlaps
the IO.  `keep_last` prunes old checkpoints; `latest_step` resumes.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time

import jax
import numpy as np

MANIFEST = "manifest.json"
_LEAF = "__leaf__"


def _sanitize(s: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", s)


def _skeletonize(tree, prefix: str, leaves: dict):
    if isinstance(tree, dict):
        return {k: _skeletonize(v, f"{prefix}.{k}" if prefix else str(k), leaves)
                for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        kind = "list" if isinstance(tree, list) else "tuple"
        return {
            "__seq__": kind,
            "items": [_skeletonize(v, f"{prefix}.{i}", leaves) for i, v in enumerate(tree)],
        }
    name = _sanitize(prefix or "leaf")
    assert name not in leaves, f"duplicate leaf {name}"
    leaves[name] = tree
    return {_LEAF: name}


def _rebuild(skel, loader):
    if isinstance(skel, dict) and _LEAF in skel:
        return loader(skel[_LEAF])
    if isinstance(skel, dict) and "__seq__" in skel:
        items = [_rebuild(s, loader) for s in skel["items"]]
        return items if skel["__seq__"] == "list" else tuple(items)
    return {k: _rebuild(v, loader) for k, v in skel.items()}


def save(path: str, tree, extra: dict | None = None) -> None:
    """Atomic synchronous save of a pytree (+ JSON-serialisable extras)."""
    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves: dict = {}
    skel = _skeletonize(tree, "", leaves)
    for name, leaf in leaves.items():
        np.save(os.path.join(tmp, name + ".npy"), np.asarray(jax.device_get(leaf)))
    manifest = {"skeleton": skel, "extra": extra or {}, "time": time.time()}
    with open(os.path.join(tmp, MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore(path: str, shardings=None):
    """Returns (tree, extra).

    `shardings`: optional pytree of NamedShardings (same structure) —
    each leaf is device_put with the *new* mesh's sharding, enabling
    elastic remesh on resume.
    """
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)

    def load(name):
        return np.load(os.path.join(path, name + ".npy"))

    tree = _rebuild(manifest["skeleton"], load)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(jax.numpy.asarray(x), s), tree, shardings
        )
    else:
        tree = jax.tree.map(jax.numpy.asarray, tree)
    return tree, manifest["extra"]


# ---------------------------------------------------------------------------
# checkpoint directories: step-numbered, pruned, resumable
# ---------------------------------------------------------------------------
def step_path(ckpt_dir: str, step: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step:08d}")


def available_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, MANIFEST)):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> int | None:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def prune(ckpt_dir: str, keep_last: int) -> None:
    steps = available_steps(ckpt_dir)
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(step_path(ckpt_dir, s), ignore_errors=True)


class AsyncCheckpointer:
    """Snapshot now, write later.  One in-flight write at a time (a second
    request waits — backpressure rather than unbounded host RAM)."""

    def __init__(self, ckpt_dir: str, keep_last: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep_last = keep_last
        self._thread: threading.Thread | None = None
        os.makedirs(ckpt_dir, exist_ok=True)

    def save_async(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        # consistent cut on the caller thread
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def _write():
            save(step_path(self.ckpt_dir, step), snapshot, extra)
            prune(self.ckpt_dir, self.keep_last)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def save_sync(self, step: int, tree, extra: dict | None = None) -> None:
        self.wait()
        save(step_path(self.ckpt_dir, step), tree, extra)
        prune(self.ckpt_dir, self.keep_last)

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
