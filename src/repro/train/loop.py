"""The training loop: jit'd step + telemetry + checkpoint/restart + faults.

Determinism contract (tested): `train()` interrupted at any step and
resumed from its checkpoint produces bitwise-identical parameters to an
uninterrupted run — the data pipeline is O(1)-indexable and the step is a
pure function.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.optim import AdamWConfig, init_opt_state

from . import checkpoint as ckpt
from .fault import FaultInjector, PreemptionHandler, SimulatedPreemption, StragglerWatchdog
from .step import TrainStepConfig, make_train_step


@dataclass
class LoopConfig:
    steps: int = 100
    log_every: int = 10
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep_last: int = 3
    async_checkpoint: bool = True
    resume: bool = True
    seed: int = 0
    accum_steps: int = 1


@dataclass
class TrainResult:
    params: dict
    opt_state: dict
    history: list = field(default_factory=list)
    stopped_at: int = 0
    preempted: bool = False
    straggler_events: list = field(default_factory=list)
    #: per-kernel `repro.attrib.EnergyLedger` (set when an attributor runs)
    energy_ledger: object | None = None


def train(
    model,
    data,
    opt_cfg: AdamWConfig,
    loop_cfg: LoopConfig,
    telemetry=None,
    fault_injector: FaultInjector | None = None,
    mesh=None,
    shardings=None,
    attributor=None,
) -> TrainResult:
    """Run (or resume) training.  `shardings`: optional dict with keys
    'params', 'opt', 'batch' (NamedSharding pytrees) for pjit execution.

    ``attributor``: an optional `repro.attrib.StepAttributor`.  Every step
    is bracketed with a time-synced marker on its virtual sensor and the
    modelled phase trace is played through the full 20 kHz chain; the
    resulting per-kernel energy ledger lands in ``result.energy_ledger``.
    """
    step_fn = make_train_step(model, opt_cfg, TrainStepConfig(loop_cfg.accum_steps))
    jit_kwargs = {}
    if shardings is not None:
        jit_kwargs = dict(
            in_shardings=(shardings["params"], shardings["opt"], shardings["batch"]),
            out_shardings=(shardings["params"], shardings["opt"], None),
        )
    step_jit = jax.jit(step_fn, donate_argnums=(0, 1), **jit_kwargs)

    # ---- init or resume ---------------------------------------------------
    start_step = 0
    params = opt_state = None
    if loop_cfg.resume and loop_cfg.ckpt_dir:
        latest = ckpt.latest_step(loop_cfg.ckpt_dir)
        if latest is not None:
            tree, extra = ckpt.restore(
                ckpt.step_path(loop_cfg.ckpt_dir, latest),
                shardings={"params": shardings["params"], "opt": shardings["opt"]}
                if shardings
                else None,
            )
            params, opt_state = tree["params"], tree["opt"]
            data.load_state_dict(extra["data_state"])
            start_step = extra["step"]
    if params is None:
        params = model.init(jax.random.PRNGKey(loop_cfg.seed))
        if shardings is not None:
            params = jax.tree.map(jax.device_put, params, shardings["params"])
        opt_state = init_opt_state(params)
        if shardings is not None:
            opt_state = jax.tree.map(jax.device_put, opt_state, shardings["opt"])
        data.step = 0

    saver = (
        ckpt.AsyncCheckpointer(loop_cfg.ckpt_dir, loop_cfg.keep_last)
        if loop_cfg.ckpt_dir
        else None
    )
    watchdog = StragglerWatchdog()
    history: list[dict] = []

    def checkpoint_now(step: int, sync: bool = False) -> None:
        if saver is None:
            return
        extra = {"step": step, "data_state": data.state_dict()}
        tree = {"params": params, "opt": opt_state}
        if sync or not loop_cfg.async_checkpoint:
            saver.save_sync(step, tree, extra)
        else:
            saver.save_async(step, tree, extra)

    result = TrainResult(params=params, opt_state=opt_state, history=history)
    with PreemptionHandler() as preempt:
        step = start_step
        try:
            while step < loop_cfg.steps:
                batch = data.batch_at(step)
                t0 = time.perf_counter()
                if fault_injector is not None:
                    fault_injector.check(step)
                params, opt_state, metrics = step_jit(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
                dt = time.perf_counter() - t0
                data.step = step + 1
                watchdog.observe(step, dt)
                rec = {
                    "step": step,
                    "loss": float(metrics["loss"]),
                    "grad_norm": float(metrics["grad_norm"]),
                    "lr": float(metrics["lr"]),
                    "step_time_s": dt,
                }
                if telemetry is not None:
                    tokens = int(np.prod(batch["tokens"].shape))
                    erec = telemetry.record_step(step, dt, tokens)
                    rec["joules"] = erec.joules
                    rec["j_per_token"] = erec.j_per_token
                if attributor is not None:
                    attributor.on_step()
                history.append(rec)
                if loop_cfg.log_every and step % loop_cfg.log_every == 0:
                    msg = f"step {step:6d} loss {rec['loss']:.4f} gnorm {rec['grad_norm']:.3f} {dt*1e3:.0f} ms"
                    if "joules" in rec:
                        msg += f" {rec['joules']:.1f} J/step(model)"
                    print(msg, flush=True)
                step += 1
                if preempt.requested:
                    checkpoint_now(step, sync=True)
                    result.preempted = True
                    break
                if loop_cfg.ckpt_every and step % loop_cfg.ckpt_every == 0:
                    checkpoint_now(step)
        except SimulatedPreemption:
            # a *real* preemption gives no chance to checkpoint: resume
            # must come from the last periodic checkpoint
            result.preempted = True
        if not result.preempted and step >= loop_cfg.steps:
            checkpoint_now(step, sync=True)
    if saver:
        saver.wait()
    if attributor is not None:
        result.energy_ledger = attributor.finish()
        if loop_cfg.log_every:
            from repro.attrib import render_text

            print(render_text(result.energy_ledger, top=8,
                              title="per-kernel energy (measured)"), flush=True)
    result.params = params
    result.opt_state = opt_state
    result.stopped_at = step
    result.straggler_events = watchdog.events
    return result
