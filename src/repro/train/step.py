"""Step builders: train_step / prefill_step / decode_step.

`make_train_step` closes over the model + optimizer config and returns a
pure function `(params, opt_state, batch) -> (params, opt_state, metrics)`
ready for `jax.jit` (with donation) under any mesh.  Microbatch gradient
accumulation (`accum_steps`) runs as a `lax.scan` over batch slices —
the standard memory lever when the global batch exceeds HBM.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.optim import AdamWConfig, apply_updates


@dataclass(frozen=True)
class TrainStepConfig:
    accum_steps: int = 1


def make_train_step(model, opt_cfg: AdamWConfig, step_cfg: TrainStepConfig = TrainStepConfig()):
    def loss_fn(params, batch):
        loss, metrics = model.loss_fn(params, batch)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state, batch):
        if step_cfg.accum_steps > 1:
            n = step_cfg.accum_steps

            def slice_batch(b, i):
                def sl(x):
                    mb = x.shape[0] // n
                    return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

                return jax.tree.map(sl, b)

            def body(carry, i):
                g_acc, l_acc = carry
                (loss, _), grads = grad_fn(params, slice_batch(batch, i))
                return (
                    jax.tree.map(jnp.add, g_acc, grads),
                    l_acc + loss,
                ), None

            g0 = jax.tree.map(jnp.zeros_like, params)
            (grads, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), jnp.arange(n))
            grads = jax.tree.map(lambda g: g / n, grads)
            loss = loss_sum / n
            metrics = {"ce": loss}
        else:
            (loss, metrics), grads = grad_fn(params, batch)
        new_params, new_opt, stats = apply_updates(params, grads, opt_state, opt_cfg)
        out = {"loss": loss, **metrics, **stats}
        return new_params, new_opt, out

    return train_step


def make_prefill_step(model, max_len: int | None = None):
    def prefill_step(params, batch):
        if model.cfg.is_encdec:
            return model.prefill(params, batch, max_len=max_len)
        return model.prefill(params, batch["tokens"], max_len=max_len)

    return prefill_step


def make_decode_step(model):
    def decode_step(params, cache, token):
        return model.decode_step(params, cache, token)

    return decode_step
