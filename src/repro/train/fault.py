"""Fault tolerance: preemption handling, straggler detection, fault injection.

At 1000+ nodes failures are routine, not exceptional:

* `PreemptionHandler` — SIGTERM/SIGUSR1 → checkpoint-and-exit-cleanly
  (the maintenance-event contract on cloud TPU fleets).
* `StragglerWatchdog` — EWMA step-time monitor; a step slower than
  `threshold ×` the EWMA flags a straggler (on a real fleet this feeds
  the re-slicing controller; here it feeds metrics + logs, and tests
  assert the detection logic).
* `FaultInjector` — deterministic crash at step N (`SimulatedPreemption`)
  so tests can prove checkpoint/resume is *bitwise* transparent.
"""
from __future__ import annotations

import signal
import threading
from dataclasses import dataclass, field


class SimulatedPreemption(Exception):
    """Raised by FaultInjector to emulate a node loss mid-training."""


@dataclass
class FaultInjector:
    crash_at_step: int = -1

    def check(self, step: int) -> None:
        if 0 <= self.crash_at_step == step:
            self.crash_at_step = -1  # one-shot
            raise SimulatedPreemption(f"injected preemption at step {step}")


class PreemptionHandler:
    """Install with `with PreemptionHandler() as h:` — `h.requested` flips
    on SIGTERM/SIGUSR1 and the loop checkpoints + exits at the next step
    boundary."""

    def __init__(self, signals=(signal.SIGTERM, signal.SIGUSR1)):
        self.requested = False
        self._signals = signals
        self._old = {}
        self._lock = threading.Lock()

    def _handler(self, signum, frame):
        with self._lock:
            self.requested = True

    def __enter__(self):
        if threading.current_thread() is threading.main_thread():
            for s in self._signals:
                self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, old in self._old.items():
            signal.signal(s, old)
        return False


@dataclass
class StragglerWatchdog:
    """EWMA step-time monitor (per-host; a controller aggregates across
    hosts in a real deployment)."""

    threshold: float = 2.0
    alpha: float = 0.1
    warmup: int = 5
    ewma: float = 0.0
    n: int = 0
    events: list = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        self.n += 1
        if self.n <= self.warmup:
            self.ewma = dt if self.ewma == 0 else (1 - self.alpha) * self.ewma + self.alpha * dt
            return False
        is_straggler = dt > self.threshold * self.ewma
        if is_straggler:
            self.events.append((step, dt, self.ewma))
        else:
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * dt
        return is_straggler
