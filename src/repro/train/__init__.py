from .checkpoint import AsyncCheckpointer, available_steps, latest_step, restore, save, step_path
from .fault import FaultInjector, PreemptionHandler, SimulatedPreemption, StragglerWatchdog
from .loop import LoopConfig, TrainResult, train
from .step import TrainStepConfig, make_decode_step, make_prefill_step, make_train_step

__all__ = [
    "AsyncCheckpointer",
    "available_steps",
    "latest_step",
    "restore",
    "save",
    "step_path",
    "FaultInjector",
    "PreemptionHandler",
    "SimulatedPreemption",
    "StragglerWatchdog",
    "LoopConfig",
    "TrainResult",
    "train",
    "TrainStepConfig",
    "make_decode_step",
    "make_prefill_step",
    "make_train_step",
]
