"""Scenario DSL: deterministic, seedable compositions of fault windows.

A :class:`Scenario` is a declarative bundle of `repro.faultlab.faults`
primitives plus an optional generated ``schedule`` (e.g. periodic sample
dropouts).  Scenarios are pure data — replaying one against a fleet is
`repro.faultlab.harness.ChaosRun`'s job — so the same scenario can be
thrown at any sensor stack and the injected ground truth compared against
what the stack reports.

``shipped_scenarios()`` enumerates the conformance set every release must
survive (the chaos test tier and ``benchmarks/governor_cap.py --chaos``
iterate over it).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Iterable, Sequence

from .faults import (
    ClockDrift,
    Corruption,
    Disconnect,
    Dropout,
    Fault,
    PartialReads,
    Stall,
)


def periodic(
    make: Callable[[float], Fault],
    period_s: float,
    n: int,
    start_s: float = 0.0,
) -> tuple[Fault, ...]:
    """``n`` copies of a fault, one per ``period_s``, from ``start_s``.

    ``make`` receives each window's start time and returns the fault —
    e.g. ``periodic(lambda t: Dropout(t, t + 2e-3), 0.05, 5, 0.1)`` is
    five 2 ms sample dropouts, 50 ms apart, starting at 100 ms.
    """
    if period_s <= 0:
        raise ValueError("period_s must be positive")
    return tuple(make(start_s + k * period_s) for k in range(int(n)))


@dataclass(frozen=True)
class Scenario:
    """A named, seedable composition of fault windows."""

    faults: tuple[Fault, ...] = ()
    #: generated faults (e.g. from :func:`periodic`) — kept separate so a
    #: scenario reads as "these one-off events plus this schedule"
    schedule: tuple[Fault, ...] = ()
    name: str = "scenario"
    #: seeds the per-device corruption RNG streams in the transport
    seed: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "faults", tuple(self.faults))
        object.__setattr__(self, "schedule", tuple(self.schedule))

    @property
    def all_faults(self) -> tuple[Fault, ...]:
        return self.faults + self.schedule

    def faults_for(self, device: str) -> tuple[Fault, ...]:
        """The subset of faults that applies to one named device."""
        return tuple(f for f in self.all_faults if f.applies_to(device))

    @property
    def end_s(self) -> float:
        """When the last fault window closes (0.0 for an empty scenario)."""
        return max((f.t1_s for f in self.all_faults), default=0.0)

    def scaled(self, factor: float) -> "Scenario":
        """The same scenario with every window time scaled by ``factor``."""
        import dataclasses

        def scale(f: Fault) -> Fault:
            return dataclasses.replace(
                f, t0_s=f.t0_s * factor, t1_s=f.t1_s * factor
            )

        return Scenario(
            faults=tuple(scale(f) for f in self.faults),
            schedule=tuple(scale(f) for f in self.schedule),
            name=self.name,
            seed=self.seed,
        )


def shipped_scenarios(duration_s: float = 0.4) -> dict[str, Scenario]:
    """The conformance scenario set, sized to a ``duration_s`` run.

    Every scenario here must satisfy the chaos conformance bound: the
    stack's reported fleet energy stays within (injected dropout fraction
    + 1 %) of the injected ground truth, every gap is surfaced (coverage /
    staleness flags), and nothing NaNs or goes negative.
    """
    d = float(duration_s)
    return {
        "clean": Scenario(name="clean", seed=1),
        "dropout-burst": Scenario(
            faults=(Dropout(0.30 * d, 0.45 * d),),
            name="dropout-burst",
            seed=2,
        ),
        "sample-dropouts": Scenario(
            schedule=periodic(
                lambda t: Dropout(t, t + 0.004 * d), 0.08 * d, 6, 0.2 * d
            ),
            name="sample-dropouts",
            seed=3,
        ),
        "stall-burst": Scenario(
            faults=(Stall(0.35 * d, 0.55 * d),),
            name="stall-burst",
            seed=4,
        ),
        "disconnect-cycle": Scenario(
            faults=(Disconnect(0.40 * d, 0.60 * d, devices=("dev0",)),),
            name="disconnect-cycle",
            seed=5,
        ),
        "partial-reads": Scenario(
            faults=(PartialReads(0.10 * d, 0.90 * d, max_chunk=3),),
            name="partial-reads",
            seed=6,
        ),
        "corruption-light": Scenario(
            faults=(Corruption(0.20 * d, 0.80 * d, rate=5e-4),),
            name="corruption-light",
            seed=7,
        ),
        "drift-skew": Scenario(
            faults=(ClockDrift(0.10 * d, 0.90 * d, factor=0.9, devices=("dev0",)),),
            name="drift-skew",
            seed=8,
        ),
        "kitchen-sink": Scenario(
            faults=(
                Dropout(0.20 * d, 0.26 * d),
                Stall(0.40 * d, 0.48 * d, devices=("dev0",)),
                Disconnect(0.60 * d, 0.72 * d, devices=("dev1",)),
                PartialReads(0.0, d, max_chunk=5),
            ),
            name="kitchen-sink",
            seed=9,
        ),
    }
