"""Transport-level fault primitives for the chaos lab.

Each fault is a frozen, declarative description of one degradation of a
device's byte link, active over a half-open window ``[t0_s, t1_s)`` of
*true* (transport) time and optionally scoped to named devices.  The
injection mechanics live in `repro.faultlab.transport.FaultyTransport`;
these objects only say *what* goes wrong and *when*, which is what makes
scenarios composable and replayable.

Fault taxonomy (what each models on real hardware):

* :class:`Dropout` — bytes produced by the device during the window never
  reach the host (USB FIFO overrun, EMI burst on the link): sample
  dropouts when short, sustained gaps when long;
* :class:`Disconnect` — the link itself is down: reads return nothing,
  produced bytes are lost *and* host commands (markers!) are dropped —
  a full unplug→replug cycle;
* :class:`Stall` — delivery freezes but nothing is lost: bytes buffer up
  and arrive in one burst when the stall ends (a hung USB poll);
* :class:`Corruption` — per-byte bit flips / zeroing / deletions at a
  seeded rate (signal integrity faults; deletions also misalign the
  2-byte packet framing, exercising resync);
* :class:`ClockDrift` — the device clock runs at ``factor`` × true time
  (crystal tolerance, thermal drift): inter-device skew;
* :class:`PartialReads` — every host read returns at most ``max_chunk``
  bytes (tiny USB transfers), splitting packets across reads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import ClassVar


@dataclass(frozen=True)
class Fault:
    """One degradation window on a device's transport."""

    t0_s: float
    t1_s: float
    #: device names this fault applies to; None = the whole fleet
    devices: tuple[str, ...] | None = None

    kind: ClassVar[str] = "fault"

    def __post_init__(self) -> None:
        if self.t1_s < self.t0_s:
            raise ValueError(f"{self.kind}: t1_s {self.t1_s} < t0_s {self.t0_s}")
        if self.devices is not None:
            object.__setattr__(self, "devices", tuple(self.devices))

    def active(self, t_s: float) -> bool:
        return self.t0_s <= t_s < self.t1_s

    def applies_to(self, name: str) -> bool:
        return self.devices is None or name in self.devices

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s


@dataclass(frozen=True)
class Dropout(Fault):
    """Bytes produced during the window are silently discarded."""

    kind: ClassVar[str] = "dropout"


@dataclass(frozen=True)
class Disconnect(Fault):
    """Link down: produced bytes lost, reads empty, host writes dropped."""

    kind: ClassVar[str] = "disconnect"


@dataclass(frozen=True)
class Stall(Fault):
    """Delivery freezes; buffered bytes arrive in a burst at ``t1_s``."""

    kind: ClassVar[str] = "stall"


@dataclass(frozen=True)
class Corruption(Fault):
    """Per-byte corruption at a seeded rate while active.

    ``mode``: ``"bitflip"`` XORs one random bit, ``"zero"`` clears the
    byte, ``"drop"`` deletes it (misaligning the 2-byte packet framing).
    """

    kind: ClassVar[str] = "corruption"

    rate: float = 0.01
    mode: str = "bitflip"

    def __post_init__(self) -> None:
        super().__post_init__()
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError("corruption rate must be in [0, 1]")
        if self.mode not in ("bitflip", "zero", "drop"):
            raise ValueError(f"unknown corruption mode {self.mode!r}")


@dataclass(frozen=True)
class ClockDrift(Fault):
    """Device clock advances at ``factor`` × true time while active."""

    kind: ClassVar[str] = "drift"

    factor: float = 1.0

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.factor <= 0:
            raise ValueError("drift factor must be positive")


@dataclass(frozen=True)
class PartialReads(Fault):
    """Every host read returns at most ``max_chunk`` bytes while active."""

    kind: ClassVar[str] = "partial-reads"

    max_chunk: int = 3

    def __post_init__(self) -> None:
        super().__post_init__()
        if self.max_chunk < 1:
            raise ValueError("max_chunk must be >= 1")
