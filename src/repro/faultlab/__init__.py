"""`repro.faultlab` — transport-level fault injection + chaos conformance.

The paper's headline claims are robustness ones; this package is how the
reproduction earns them.  It degrades the byte link between the virtual
firmware and the host library — the layer real interference attacks —
with deterministic, seedable, composable fault windows, and scores any
sensor stack against the injected ground truth:

* `faults` — the primitives: `Dropout`, `Disconnect`, `Stall`,
  `Corruption`, `ClockDrift`, `PartialReads`;
* `scenario` — the DSL: `Scenario(faults=..., schedule=...)`,
  `periodic()` schedules, and `shipped_scenarios()`, the conformance set;
* `transport` — `FaultyTransport` (the injector) + `FaultLedger` (the
  ground-truth record of what was injected), and `inject()` to wrap a
  live fleet in place;
* `harness` — `ChaosRun`: clean pass vs faulted pass over the same
  seeded fleet, `ChaosReport.check()` enforcing the conformance bound
  (energy deviation ≤ injected dropout fraction + 1 %, no NaNs, no
  negative joules); `churn_billing_run` + `ChurnBillingReport`: a
  continuous-batching step loop (staggered arrivals, mid-decode
  eviction, per-interval markers) driven over an injected fleet, with
  the billing-conformance contract (every interval settled-or-released,
  billed + overhead ≡ settled exactly, nothing non-finite) enforced
  under every shipped scenario.

The degradation *handling* lives with the consumers: `stream.FleetMonitor`
(health states, quorum power, holdover), `sched.PowerCapGovernor` (stale
telemetry as a safety event) and `attrib.attribute` (per-span coverage).
"""
from .faults import (
    ClockDrift,
    Corruption,
    Disconnect,
    Dropout,
    Fault,
    PartialReads,
    Stall,
)
from .harness import (
    ChaosReport,
    ChaosRun,
    ChurnBillingReport,
    DeviceOutcome,
    churn_billing_run,
)
from .scenario import Scenario, periodic, shipped_scenarios
from .transport import FaultLedger, FaultyTransport, inject

__all__ = [
    "ClockDrift",
    "Corruption",
    "Disconnect",
    "Dropout",
    "Fault",
    "PartialReads",
    "Stall",
    "ChaosReport",
    "ChaosRun",
    "ChurnBillingReport",
    "churn_billing_run",
    "DeviceOutcome",
    "Scenario",
    "periodic",
    "shipped_scenarios",
    "FaultLedger",
    "FaultyTransport",
    "inject",
]
