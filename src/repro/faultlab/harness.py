"""ChaosRun: replay a scenario against a sensor stack and score it.

The harness runs the *same* deterministic virtual fleet twice — once
clean, once with the scenario injected at the transport layer — so the
clean pass is the energy ground truth and the injector's
:class:`~repro.faultlab.transport.FaultLedger` is the degradation ground
truth.  ``ChaosReport.check()`` encodes the conformance contract every
shipped scenario must satisfy:

* reported per-device and fleet energy within
  ``(injected dropout fraction + tol)`` of the clean-pass truth (with an
  explicit allowance for corrupted and still-buffered bytes — nothing is
  silently absorbed into the bound);
* no NaNs, no negative joules;
* every injected delivery gap visible to consumers (the degradation
  tests assert on `FleetMonitor` health and `attrib` coverage on top).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable

import numpy as np

from .scenario import Scenario
from .transport import FaultLedger, FaultyTransport, inject


@dataclass(frozen=True)
class DeviceOutcome:
    """One device's clean-vs-faulted comparison."""

    name: str
    true_energy_j: float
    reported_energy_j: float
    dropped_frames: int
    delivered_frac: float

    @property
    def deviation_frac(self) -> float:
        """|reported − truth| as a fraction of the truth."""
        if self.true_energy_j <= 0:
            return abs(self.reported_energy_j)
        return abs(self.reported_energy_j - self.true_energy_j) / self.true_energy_j


@dataclass
class ChaosReport:
    """Everything a conformance test needs from one chaos run."""

    scenario: Scenario
    duration_s: float
    devices: dict[str, DeviceOutcome]
    ledgers: dict[str, FaultLedger]
    transports: dict[str, FaultyTransport]
    #: the faulted fleet, still open for post-run inspection (health,
    #: rings, markers); callers own closing it via ``close()``
    fleet: object = None
    stale_readings: int = 0
    min_quorum_frac: float = 1.0

    @property
    def fleet_true_energy_j(self) -> float:
        return sum(d.true_energy_j for d in self.devices.values())

    @property
    def fleet_reported_energy_j(self) -> float:
        return sum(d.reported_energy_j for d in self.devices.values())

    def energy_bound_frac(self, name: str, tol: float = 0.01) -> float:
        """The conformance bound for one device: dropout + explicit slack.

        ``dropped_frac`` is the injected ground truth; corrupted bytes can
        each poison a couple of frames *and* bias one sample's watts, and
        bytes still buffered in the transport (stall past run end) are
        delayed rather than lost — both get explicit allowances instead of
        being silently absorbed.
        """
        led = self.ledgers[name]
        denom = max(led.delivered_bytes, 1)
        corr_allow = 10.0 * led.corrupted_bytes / denom
        pend_allow = self.transports[name].pending_bytes / denom
        return led.dropped_frac + tol + corr_allow + pend_allow

    def check(self, tol: float = 0.01) -> list[str]:
        """Conformance violations (empty list = the scenario was survived)."""
        errs: list[str] = []
        for name, d in self.devices.items():
            if not math.isfinite(d.reported_energy_j):
                errs.append(f"{name}: non-finite reported energy")
                continue
            if d.reported_energy_j < -1e-9:
                errs.append(f"{name}: negative joules ({d.reported_energy_j:.3g})")
            bound = self.energy_bound_frac(name, tol)
            if d.deviation_frac > bound:
                errs.append(
                    f"{name}: energy deviation {d.deviation_frac:.3%} exceeds "
                    f"ledger bound {bound:.3%} (true {d.true_energy_j:.3f} J, "
                    f"reported {d.reported_energy_j:.3f} J)"
                )
        if self.fleet_true_energy_j > 0:
            fleet_dev = abs(
                self.fleet_reported_energy_j - self.fleet_true_energy_j
            ) / self.fleet_true_energy_j
            fleet_bound = max(
                self.energy_bound_frac(n, tol) for n in self.devices
            )
            if fleet_dev > fleet_bound:
                errs.append(
                    f"fleet: energy deviation {fleet_dev:.3%} exceeds {fleet_bound:.3%}"
                )
        return errs

    def close(self) -> None:
        if self.fleet is not None:
            self.fleet.close()
            self.fleet = None


class ChaosRun:
    """Replay one scenario against a virtual fleet and collect ground truth.

    ``load_factory(i)`` builds device ``i``'s DUT load; both passes build
    identical fleets from the same seeds, so the clean pass *is* the
    ground-truth energy for the faulted pass.
    """

    def __init__(
        self,
        scenario: Scenario,
        load_factory: Callable[[int], object] | None = None,
        n_devices: int = 2,
        module: str = "pcie8pin-20a",
        seed: int = 0,
        window_s: float = 0.02,
        ring_capacity: int = 1 << 16,
    ):
        self.scenario = scenario
        self.n_devices = int(n_devices)
        self.module = module
        self.seed = int(seed)
        self.window_s = float(window_s)
        self.ring_capacity = int(ring_capacity)
        if load_factory is None:
            from repro.core import ConstantLoad

            load_factory = lambda i: ConstantLoad(12.0, 3.0 + 0.5 * i)  # noqa: E731
        self.load_factory = load_factory

    def _build_fleet(self):
        from repro.stream import make_virtual_fleet

        return make_virtual_fleet(
            [self.load_factory(i) for i in range(self.n_devices)],
            module=self.module,
            seed=self.seed,
            window_s=self.window_s,
            ring_capacity=self.ring_capacity,
        )

    def run(
        self,
        duration_s: float,
        chunk_s: float = 0.002,
        on_tick: Callable[[float, object], None] | None = None,
        mark_every_s: float = 0.0,
    ) -> ChaosReport:
        """Clean pass then faulted pass; returns the comparison report.

        ``on_tick(t, fleet)`` is called after every faulted-pass chunk
        (health sampling, governor steps, ...); ``mark_every_s > 0``
        injects periodic ``"C"`` markers on every device in both passes
        (the marker-survives-corruption regression reads them back).
        """
        true_energy = self._run_pass(duration_s, chunk_s, mark_every_s)

        fleet = self._build_fleet()
        transports = inject(fleet, self.scenario)
        stale_readings = 0
        min_quorum = 1.0

        def tick(t: float, fl) -> None:
            nonlocal stale_readings, min_quorum
            reading = fl.fleet_power(poll=False)
            if reading.stale:
                stale_readings += 1
            min_quorum = min(min_quorum, reading.quorum_frac)
            if on_tick is not None:
                on_tick(t, fl)

        reported = self._drive(fleet, duration_s, chunk_s, tick, mark_every_s)
        devices = {
            name: DeviceOutcome(
                name=name,
                true_energy_j=true_energy[name],
                reported_energy_j=reported[name],
                dropped_frames=fleet[name].dropped_frames,
                delivered_frac=transports[name].ledger.delivered_frac,
            )
            for name in fleet.names
        }
        return ChaosReport(
            scenario=self.scenario,
            duration_s=duration_s,
            devices=devices,
            ledgers={n: tr.ledger for n, tr in transports.items()},
            transports=transports,
            fleet=fleet,
            stale_readings=stale_readings,
            min_quorum_frac=min_quorum,
        )

    def _run_pass(self, duration_s, chunk_s, mark_every_s):
        """The clean (ground-truth) pass: same fleet, no faults, no ticks."""
        fleet = self._build_fleet()
        try:
            return self._drive(fleet, duration_s, chunk_s, None, mark_every_s)
        finally:
            fleet.close()

    @staticmethod
    def _drive(fleet, duration_s, chunk_s, on_tick, mark_every_s) -> dict[str, float]:
        t = 0.0
        next_mark = 0.0 if mark_every_s > 0 else math.inf
        while t < duration_s - 1e-12:
            if t >= next_mark - 1e-12:
                fleet.mark_all("C")
                next_mark += mark_every_s
            h = min(chunk_s, duration_s - t)
            fleet.advance(h)
            t += h
            if on_tick is not None:
                on_tick(t, fleet)
        fleet.poll_all()
        return {name: fleet[name].read().total_joules for name in fleet.names}


# --------------------------------------------------------------------------
# continuous-batching billing under chaos
# --------------------------------------------------------------------------
@dataclass
class ChurnBillingReport:
    """Step-granularity billing scored under one injected scenario.

    The conformance contract is ledger *consistency*, not accuracy: faults
    may shift or swallow marker windows (that uncertainty is what the
    release-at-prediction rule is for), but the billing ledger must never
    leak, double-bill, or go non-finite — every sealed interval settles
    exactly once (measured or released), and per-request billed joules
    plus unbilled overhead reproduce the total settled energy exactly.
    """

    scenario: Scenario
    duration_s: float
    n_intervals: int
    settled: int
    released: int
    billed_j: float
    overhead_j: float
    spent_j: float
    finished: int
    evicted: int
    rows: list[dict] = field(default_factory=list)

    def check(self, rtol: float = 1e-9) -> list[str]:
        """Billing-conformance violations (empty list = survived)."""
        errs: list[str] = []
        if self.settled + self.released != self.n_intervals:
            errs.append(
                f"{self.settled} settled + {self.released} released != "
                f"{self.n_intervals} sealed intervals"
            )
        if not math.isfinite(self.spent_j) or self.spent_j < -1e-9:
            errs.append(f"non-finite/negative settled energy {self.spent_j!r}")
        leak = abs(self.billed_j + self.overhead_j - self.spent_j)
        if leak > rtol * max(abs(self.spent_j), 1.0):
            errs.append(
                f"billing leak: billed {self.billed_j!r} + overhead "
                f"{self.overhead_j!r} != settled {self.spent_j!r}"
            )
        for row in self.rows:
            if not math.isfinite(row["measured_j"]) or row["measured_j"] < -1e-12:
                errs.append(f"rid {row['rid']}: bad billed energy "
                            f"{row['measured_j']!r}")
        if not self.scenario.all_faults and self.released:
            errs.append(
                f"clean scenario released {self.released} interval(s) at "
                f"prediction — every span should have measured"
            )
        return errs


def churn_billing_run(
    scenario: Scenario,
    n_requests: int = 6,
    n_slots: int = 2,
    steps_per_interval: int = 3,
    step_dt_s: float = 0.003,
    arrive_every_steps: int = 2,
    evict_at_step: int = 7,
    n_devices: int = 2,
    module: str = "pcie8pin-20a",
    seed: int = 0,
    window_s: float = 0.02,
    ring_capacity: int = 1 << 15,
    mark_char: str = "B",
) -> ChurnBillingReport:
    """Drive a `ContinuousBatch` step loop over an injected fleet.

    A churn workload — staggered arrivals (one new request every
    ``arrive_every_steps`` decode steps), mixed ``gen_len``s, one
    deterministic mid-decode eviction — runs against ``n_devices``
    fault-injected virtual sensors, with one marker occurrence bracketing
    every step interval.  At the end every interval that still has an
    attributable marker window settles from measurement; intervals whose
    markers or frames the scenario swallowed are released at prediction
    (the degraded-telemetry billing rule).  The returned report's
    ``check()`` enforces the billing-conformance contract.
    """
    from repro.attrib import attribute_intervals
    from repro.core import ConstantLoad
    from repro.sched import ContinuousBatch, EnergyPricer, Request, get_policy
    from repro.stream import make_virtual_fleet

    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 3.0 + 0.5 * i) for i in range(n_devices)],
        module=module,
        seed=seed,
        window_s=window_s,
        ring_capacity=ring_capacity,
    )
    inject(fleet, scenario)
    total_w = 12.0 * sum(3.0 + 0.5 * i for i in range(n_devices))
    pricer = EnergyPricer(j_per_token=total_w * step_dt_s / max(n_slots, 1))
    batch = ContinuousBatch(pricer, get_policy("throughput-max"), n_slots=n_slots)

    t = 0.0
    step = 0
    next_rid = 0
    while True:
        while next_rid < n_requests and step >= next_rid * arrive_every_steps:
            batch.submit(Request(
                rid=next_rid,
                client=f"c{next_rid % 2}",
                gen_len=3 + (next_rid % 3),
                arrival_s=t,
            ))
            next_rid += 1
        batch.admit(t)
        if not batch.live_rids:
            if next_rid < n_requests:
                step = next_rid * arrive_every_steps  # idle to next arrival
                continue
            break
        fleet.mark_all(mark_char)
        for _ in range(max(steps_per_interval, 1)):
            if not batch.live_rids:
                break
            batch.step_billing(1)
            fleet.advance(step_dt_s)
            t += step_dt_s
            step += 1
            if step == evict_at_step and batch.live_rids:
                batch.retire(batch.live_rids[0])  # mid-decode eviction
        batch.seal_interval()
    fleet.mark_all(mark_char)  # closing bracket of the last interval
    fleet.advance(step_dt_s)
    t += step_dt_s
    fleet.poll_all()

    # settle every interval a device still measured; release the rest
    energies: dict[int, float] = {}
    for name in fleet.names:
        ps = fleet[name]
        block = fleet._locked_ring_read(ps, lambda ps=ps: ps.ring.latest())
        for k, e in attribute_intervals(
            block, ps.markers, mark_char, min_coverage=0.5
        ).items():
            energies[k] = energies.get(k, 0.0) + e.energy_j
    settled = released = 0
    for k in list(batch.unsettled()):
        if energies.get(k, 0.0) > 0.0:
            batch.settle_interval(k, energies[k])
            settled += 1
        else:
            batch.release_interval(k)
            released += 1
    report = ChurnBillingReport(
        scenario=scenario,
        duration_s=t,
        n_intervals=len(batch.intervals),
        settled=settled,
        released=released,
        billed_j=batch.billed_j,
        overhead_j=batch.overhead_j,
        spent_j=batch.spent_j,
        finished=len(batch.finished),
        evicted=len(batch.evicted),
        rows=batch.report_rows(),
    )
    fleet.close()
    return report
