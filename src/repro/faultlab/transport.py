"""FaultyTransport: the byte-level fault injector, plus its ground truth.

Wraps any device with the `VirtualDevice` surface (``write`` / ``read`` /
``advance`` / ``t_s``) and applies the active faults of a scenario to the
byte stream *between* the firmware and the host library — the same layer
a flaky USB cable attacks.  Every injection is recorded in a
:class:`FaultLedger`, the ground truth the chaos conformance tests
compare the stack's reports against.

Timebase contract: the transport owns **true time** (``t_s``).  The
wrapped device's clock may drift away from it (`ClockDrift`), which is
exactly the skew the host's arrival-clock wrap correction has to absorb.
``advance`` splits every step at fault-window boundaries so each
sub-step sees a constant active-fault set.

Fault windows are **relative to the injection epoch** — the device's
clock when the transport wrapped it — so ``Dropout(0.25, 0.35)`` always
means "0.25 s into the chaos run", regardless of how much simulated time
(connect handshake, calibration) the stack burned beforehand.  The
ledger records spans on the same relative timeline.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .faults import (
    ClockDrift,
    Corruption,
    Disconnect,
    Dropout,
    Fault,
    PartialReads,
    Stall,
)

_EPS = 1e-12


def _merge_span(spans: list[tuple[float, float]], t0: float, t1: float) -> None:
    """Append [t0, t1) to a span list, coalescing with the last span."""
    if t1 <= t0:
        return
    if spans and t0 <= spans[-1][1] + _EPS:
        spans[-1] = (spans[-1][0], max(spans[-1][1], t1))
    else:
        spans.append((t0, t1))


@dataclass
class FaultLedger:
    """Ground truth of everything injected into one device's transport."""

    device: str
    #: true seconds observed while the wrapped device was streaming
    total_s: float = 0.0
    #: device-clock seconds' worth of produced bytes actually delivered
    #: (drift scales production, so this is Σ step · drift over delivering
    #: steps — ``delivered_frac`` is the expected received-data fraction)
    delivered_s: float = 0.0
    delivered_bytes: int = 0
    corrupted_bytes: int = 0
    deleted_bytes: int = 0
    lost_writes: int = 0
    dropped_spans: list[tuple[float, float]] = field(default_factory=list)
    stall_spans: list[tuple[float, float]] = field(default_factory=list)
    disconnect_spans: list[tuple[float, float]] = field(default_factory=list)
    drift_spans: list[tuple[float, float, float]] = field(default_factory=list)

    @property
    def dropped_s(self) -> float:
        return sum(b - a for a, b in self.dropped_spans)

    @property
    def delivered_frac(self) -> float:
        """Expected fraction of true-time data the host should have seen."""
        return self.delivered_s / self.total_s if self.total_s > 0 else 1.0

    @property
    def dropped_frac(self) -> float:
        return 1.0 - self.delivered_frac

    def gap_spans(self) -> list[tuple[float, float]]:
        """All injected delivery gaps (dropouts + disconnects), merged."""
        out: list[tuple[float, float]] = []
        for a, b in sorted(self.dropped_spans + self.disconnect_spans):
            _merge_span(out, a, b)
        return out

    # ------------------------------------------------------------ archiving
    def to_json_dict(self) -> dict:
        """JSON-safe dict for trace archives (`repro.replay.archive`)."""
        d = dataclasses.asdict(self)
        for key in ("dropped_spans", "stall_spans", "disconnect_spans", "drift_spans"):
            d[key] = [list(s) for s in d[key]]
        return d

    @classmethod
    def from_json_dict(cls, d: dict) -> "FaultLedger":
        kw = dict(d)
        for key in ("dropped_spans", "stall_spans", "disconnect_spans", "drift_spans"):
            kw[key] = [tuple(s) for s in kw.get(key, [])]
        return cls(**kw)

    # ------------------------------------------------------------ obs overlay
    def record_obs(
        self,
        rec: "obs_trace.TraceRecorder | None" = None,
        epoch_s: float = 0.0,
        track: str | None = None,
    ) -> int:
        """Export the ledger's fault windows as a device-time trace track.

        Each injected window becomes one span on ``faults:<device>``,
        stamped in absolute device seconds (``epoch_s`` + the ledger's
        relative window times) so the exporter lines it up against
        receiver activity and attribution intervals — the ground-truth
        overlay for a flight-recorder timeline.  Returns spans written.
        """
        if rec is None:
            rec = obs_trace.active()
        if rec is None:
            return 0
        track = track or f"faults:{self.device}"
        n = 0
        for kind, spans in (
            ("dropout", self.dropped_spans),
            ("stall", self.stall_spans),
            ("disconnect", self.disconnect_spans),
        ):
            for t0, t1 in spans:
                rec.device_span(f"fault:{kind}", epoch_s + t0, epoch_s + t1,
                                track=track)
                n += 1
        for t0, t1, factor in self.drift_spans:
            rec.device_span(f"fault:drift x{factor:g}", epoch_s + t0,
                            epoch_s + t1, track=track, value=factor)
            n += 1
        return n


class FaultyTransport:
    """Apply a scenario's faults to one device's byte link.

    Drop-in for the wrapped device everywhere the host library looks:
    ``write``/``read``/``advance``/``t_s`` plus a ``firmware``
    pass-through for consumers (plant actuation, calibration) that reach
    into the virtual hardware.
    """

    def __init__(
        self,
        device,
        faults: Sequence[Fault],
        name: str = "dev",
        seed: int = 0,
    ):
        self.inner = device
        self.name = name
        self.faults = [f for f in faults if f.applies_to(name)]
        self.rng = np.random.default_rng(seed)
        self.ledger = FaultLedger(device=name)
        #: injection epoch: fault windows count from here, not from boot
        self.epoch_s = float(getattr(device, "t_s", 0.0))
        self._t_s = self.epoch_s
        self._buf = bytearray()
        # fault-window edges (relative time), for sub-stepping advance()
        self._edges = sorted(
            {f.t0_s for f in self.faults} | {f.t1_s for f in self.faults}
        )

    # ------------------------------------------------------------ passthrough
    @property
    def t_s(self) -> float:
        """True (host-side) time — the arrival clock the host anchors to."""
        return self._t_s

    @property
    def rel_t_s(self) -> float:
        """Time since injection — the scenario's timeline."""
        return self._t_s - self.epoch_s

    @property
    def firmware(self):
        return self.inner.firmware

    @property
    def pending_bytes(self) -> int:
        """Bytes produced and retained but not yet read by the host."""
        return len(self._buf)

    # ------------------------------------------------------------ fault query
    def _active(self, kind: type, t_s: float) -> list[Fault]:
        return [f for f in self.faults if isinstance(f, kind) and f.active(t_s)]

    # ------------------------------------------------------------ host surface
    def write(self, data: bytes) -> None:
        if self._active(Disconnect, self.rel_t_s):
            self.ledger.lost_writes += 1
            reg = obs_metrics.active()
            if reg is not None:
                reg.counter(
                    "fault_lost_writes_total",
                    "host writes swallowed by a disconnect window",
                    device=self.name,
                ).inc()
            return
        self.inner.write(data)

    def read(self, max_bytes: int | None = None) -> bytes:
        t = self.rel_t_s
        if self._active(Disconnect, t) or self._active(Stall, t):
            return b""
        for f in self._active(PartialReads, t):
            cap = f.max_chunk
            max_bytes = cap if max_bytes is None else min(max_bytes, cap)
        if max_bytes is None or max_bytes >= len(self._buf):
            out = bytes(self._buf)
            self._buf.clear()
            return out
        out = bytes(self._buf[:max_bytes])
        del self._buf[:max_bytes]
        return out

    def advance(self, dt_s: float) -> None:
        """Advance true time, sub-stepping at fault-window boundaries."""
        end = self.rel_t_s + dt_s
        while self.rel_t_s < end - _EPS:
            nxt = end
            for e in self._edges:
                if e > self.rel_t_s + _EPS:
                    nxt = min(nxt, e)
                    break
            self._step(nxt - self.rel_t_s)

    # ------------------------------------------------------------ the injector
    def _step(self, h: float) -> None:
        t = self.rel_t_s
        tm = t + 0.5 * h  # faults are constant over the sub-step
        led = self.ledger
        drift = 1.0
        for f in self._active(ClockDrift, tm):
            drift *= f.factor
            led.drift_spans.append((t, t + h, f.factor))
        self.inner.advance(h * drift)
        produced = self.inner.read()
        streaming = getattr(getattr(self.inner, "firmware", None), "streaming", True)
        if streaming:
            led.total_s += h
        self._t_s = self.epoch_s + t + h

        if self._active(Disconnect, tm):
            _merge_span(led.disconnect_spans, t, t + h)
            if produced:
                _merge_span(led.dropped_spans, t, t + h)
            return
        if self._active(Dropout, tm):
            if produced:
                _merge_span(led.dropped_spans, t, t + h)
            return
        if self._active(Stall, tm):
            _merge_span(led.stall_spans, t, t + h)
            # delivery is blocked in read(); production continues unharmed
        data = produced
        for f in self._active(Corruption, tm):
            data = self._corrupt(data, f)
        if data:
            if streaming:
                led.delivered_s += h * drift
            led.delivered_bytes += len(data)
            self._buf.extend(data)
        elif streaming and not produced:
            # device produced nothing this step (stopped stream / sub-frame
            # step): nothing was droppable, count the time as delivered
            led.delivered_s += h * drift

    def _corrupt(self, data: bytes, f: Corruption) -> bytes:
        if not data or f.rate <= 0:
            return data
        arr = np.frombuffer(data, dtype=np.uint8).copy()
        hit = np.flatnonzero(self.rng.random(arr.size) < f.rate)
        if hit.size == 0:
            return data
        if f.mode == "bitflip":
            bits = self.rng.integers(0, 8, size=hit.size)
            arr[hit] ^= (1 << bits).astype(np.uint8)
            self.ledger.corrupted_bytes += int(hit.size)
        elif f.mode == "zero":
            arr[hit] = 0
            self.ledger.corrupted_bytes += int(hit.size)
        else:  # drop: delete the bytes, misaligning the framing
            arr = np.delete(arr, hit)
            self.ledger.deleted_bytes += int(hit.size)
            self.ledger.corrupted_bytes += int(hit.size)
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter(
                "fault_corrupted_bytes_total",
                "bytes corrupted in flight by injection",
                device=self.name,
            ).inc(int(hit.size))
        return arr.tobytes()

    # ------------------------------------------------------------ obs overlay
    def record_obs(self, rec: "obs_trace.TraceRecorder | None" = None) -> int:
        """Overlay this transport's ground-truth fault windows on the trace.

        Windows are exported in absolute device time (the injection epoch
        plus the ledger's relative spans).  Call after (or during) a run;
        returns the number of spans written.
        """
        return self.ledger.record_obs(rec, epoch_s=self.epoch_s)


def inject(fleet, scenario, seed: int | None = None) -> dict[str, FaultyTransport]:
    """Wrap every sensor's device in a fleet with the scenario's faults.

    Swaps each ``PowerSensor.device`` for a `FaultyTransport` in place —
    after the connect handshake, so scenarios degrade the *stream*, not
    the EEPROM download — and returns the transports by device name for
    ledger access.
    """
    seed = scenario.seed if seed is None else seed
    transports: dict[str, FaultyTransport] = {}
    for i, name in enumerate(fleet.names):
        ps = fleet[name]
        tr = FaultyTransport(
            ps.device, scenario.faults_for(name), name=name, seed=seed * 7919 + i
        )
        ps.device = tr
        transports[name] = tr
    return transports
