"""Mamba-2 (SSD) block — the SSM layer used by zamba2-7b.

Structure (simplified from the official SSD block, documented deviations):

    x ─ wx ─ causal depthwise conv(4) ─ SiLU ─┬─ heads (H, P=64)
    x ─ wz ───────────────────────────────────│────────────┐
    xc ─ wB/wC/wdt ─ B̃,C̃ (shared over heads), dt (per head)│
    SSD recurrence: S ← exp(−dt·e^{A_log})·S + dt·(B̃ ⊗ x_h) │
                    y_h = C̃·S + D_h·x_h                     │
    y = RMSNorm(y) ⊙ SiLU(z) ─ out ───────────────────────▶ +residual

Deviation from the reference CUDA block: B̃/C̃/dt are projected from the
*post-conv* activations (the official block convolves [x,B,C] jointly);
this keeps one conv and does not change cost structure.  The recurrence
runs through `linear_scan.chunked_linear_recurrence` (scalar-decay mode,
numerically exact — see that module).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rmsnorm
from .linear_scan import chunked_linear_recurrence, recurrence_step
from .params import dense_init

CONV_K = 4
HEAD_P = 64


def ssm_dims(d_model: int, ssm_state: int):
    d_inner = 2 * d_model
    n_heads = d_inner // HEAD_P
    return d_inner, n_heads, ssm_state


def init_ssm_block(key, d_model: int, ssm_state: int):
    d_in, h, n = ssm_dims(d_model, ssm_state)
    ks = jax.random.split(key, 8)
    return {
        "wz": dense_init(ks[0], d_model, d_in),
        "wx": dense_init(ks[1], d_model, d_in),
        "conv": 0.1 * jax.random.normal(ks[2], (CONV_K, d_in), jnp.float32),
        "conv_b": jnp.zeros((d_in,), jnp.float32),
        "wB": dense_init(ks[3], d_model, n),
        "wC": dense_init(ks[4], d_model, n),
        "wdt": dense_init(ks[5], d_model, h),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "A_log": jnp.zeros((h,), jnp.float32),  # A = -exp(A_log) ≈ -1
        "D": jnp.ones((h,), jnp.float32),
        "norm_y": jnp.ones((d_in,), jnp.float32),
        "out": dense_init(ks[6], d_in, d_model),
    }


def _conv_causal(xin: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over time. xin: (B,T,C); w: (K,C)."""
    k = w.shape[0]
    pad = jnp.pad(xin, ((0, 0), (k - 1, 0), (0, 0)))
    out = jnp.zeros_like(xin)
    for i in range(k):  # K=4 static taps — unrolled adds, no conv primitive
        out = out + pad[:, i : i + xin.shape[1]] * w[i].astype(xin.dtype)
    return out + b.astype(xin.dtype)


def _ssd_inputs(xc, x, p, dtype):
    """Project post-conv activations to (q=C̃, k=B̃·dt, v=x_h, log_decay)."""
    b, t, d_in = xc.shape
    h = d_in // HEAD_P
    n = p["wB"].shape[1]
    B_t = jnp.einsum("btd,dn->btn", x, p["wB"].astype(dtype))
    C_t = jnp.einsum("btd,dn->btn", x, p["wC"].astype(dtype))
    dt = jax.nn.softplus(
        jnp.einsum("btd,dh->bth", x, p["wdt"].astype(dtype)).astype(jnp.float32)
        + p["dt_bias"]
    )  # (B,T,H) ≥ 0
    log_decay = -dt * jnp.exp(p["A_log"])  # (B,T,H), ≤ 0
    xh = xc.reshape(b, t, h, HEAD_P)
    q = jnp.broadcast_to(C_t[:, :, None, :], (b, t, h, n))
    k = jnp.broadcast_to(B_t[:, :, None, :], (b, t, h, n)) * dt[..., None].astype(dtype)
    return q, k, xh, log_decay, xh


def ssm_block(x, p, ssm_state: int, chunk: int = 32, unroll: int = 1):
    """Train/prefill forward. x: (B,T,d). Returns (y, final_cache)."""
    dtype = x.dtype
    z = jnp.einsum("btd,de->bte", x, p["wz"].astype(dtype))
    xin = jnp.einsum("btd,de->bte", x, p["wx"].astype(dtype))
    xc = jax.nn.silu(_conv_causal(xin, p["conv"], p["conv_b"]))
    q, k, v, log_decay, xh = _ssd_inputs(xc, x, p, dtype)
    o, s_final = chunked_linear_recurrence(
        q, k, v, log_decay, chunk=chunk, include_current=True, unroll=unroll
    )
    o = o + p["D"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = o.reshape(x.shape[0], x.shape[1], -1).astype(dtype)
    y = rmsnorm(y, p["norm_y"]) * jax.nn.silu(z)
    y = jnp.einsum("bte,ed->btd", y, p["out"].astype(dtype))
    cache = {
        "conv": xin[:, -(CONV_K - 1) :, :],  # last K-1 pre-activation inputs
        "ssm": s_final,
    }
    return y, cache


def ssm_block_decode(x, p, cache, ssm_state: int):
    """Single-token step. x: (B,d); cache {'conv': (B,K-1,d_in), 'ssm': (B,H,N,P)}."""
    dtype = x.dtype
    b, d = x.shape
    z = x @ p["wz"].astype(dtype)
    xin = x @ p["wx"].astype(dtype)  # (B,d_in)
    conv_in = jnp.concatenate([cache["conv"], xin[:, None, :]], axis=1)  # (B,K,d_in)
    xc = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", conv_in, p["conv"].astype(dtype)) + p["conv_b"].astype(dtype)
    )
    q, k, v, log_decay, xh = _ssd_inputs(xc[:, None], x[:, None], p, dtype)
    o, s_new = recurrence_step(
        q[:, 0], k[:, 0], v[:, 0], log_decay[:, 0], cache["ssm"], include_current=True
    )
    o = o + p["D"].astype(jnp.float32)[None, :, None] * xh[:, 0].astype(jnp.float32)
    y = o.reshape(b, -1).astype(dtype)
    y = rmsnorm(y, p["norm_y"]) * jax.nn.silu(z)
    y = y @ p["out"].astype(dtype)
    new_cache = {"conv": conv_in[:, 1:], "ssm": s_new}
    return y, new_cache


def init_ssm_cache(batch: int, d_model: int, ssm_state: int, dtype=jnp.float32):
    d_in, h, n = ssm_dims(d_model, ssm_state)
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, d_in), dtype),
        "ssm": jnp.zeros((batch, h, n, HEAD_P), jnp.float32),
    }
