"""`repro.models` — the unified model zoo (DESIGN.md §3)."""
from .encdec import EncDecLM
from .registry import build_model
from .transformer import DecoderLM

__all__ = ["EncDecLM", "DecoderLM", "build_model"]
