"""Mixture-of-Experts layer: top-k routing with two dispatch strategies.

* ``einsum`` — GShard/gspmd-style one-hot dispatch/combine einsums with a
  per-group capacity.  Simple, sharding-friendly, but the dense one-hot
  dispatch tensors cost real FLOPs/bytes (visible in the roofline's
  MODEL_FLOPS/HLO_FLOPS ratio — deliberately kept as the baseline).
* ``sort``   — argsort-based dispatch: tokens are sorted by expert id and
  gathered into (E, capacity) slots without any dense one-hot product.
  The beyond-paper optimisation used in §Perf hillclimbing.

Both are capacity-based (static shapes; overflow tokens are dropped and
their residual passes through — standard practice at scale).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def router_topk(x, w_router, k: int):
    """x: (T, d) -> (gates (T,k) f32, idx (T,k) int32, logits (T,E))."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), w_router.astype(jnp.float32))
    gates, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    gates = gates / jnp.clip(gates.sum(-1, keepdims=True), 1e-9)
    return gates, idx, logits


def load_balancing_loss(logits: jax.Array, idx: jax.Array, n_experts: int) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    probs = jax.nn.softmax(logits, axis=-1)  # (T, E)
    counts = jnp.zeros((n_experts,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    f = counts / idx.size
    p = probs.mean(axis=0)
    return n_experts * jnp.sum(f * p)


def _expert_ffn(xe, wi, wg, wo):
    """xe: (E, C, d); expert weights (E, d, f) / (E, f, d)."""
    h = jnp.einsum("ecd,edf->ecf", xe, wi.astype(xe.dtype))
    g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(xe.dtype))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * h, wo.astype(xe.dtype))


def moe_einsum(x, params, n_experts: int, k: int, capacity_factor: float = 1.25,
               group_size: int = 512):
    """GShard-style dispatch. x: (B, S, d) -> (B, S, d), aux_loss.

    Memory-sane einsum form: the (g, gs, E, C) dispatch/combine one-hots
    are built per top-k slot (never materialising a 5-D (g,gs,k,E,C)
    tensor) and cast to the compute dtype.  The dense dispatch matmuls
    still cost real FLOPs — that is the measured baseline pathology the
    `sort` implementation removes in §Perf.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gates, idx, logits = router_topk(xf, params["router"], k)
    aux = load_balancing_loss(logits, idx, n_experts)

    g = max(1, t // group_size)
    gs = t // g
    cap = max(int(capacity_factor * k * gs / n_experts), 1)

    xg = xf.reshape(g, gs, d)
    idx_g = idx.reshape(g, gs, k)
    gates_g = gates.reshape(g, gs, k)

    # position of each (token, slot) within its expert's capacity: the
    # joint cumsum over the flattened (token, slot) order (small int math)
    onehot_e = jax.nn.one_hot(idx_g, n_experts, dtype=jnp.float32)  # (g,gs,k,E)
    flat = onehot_e.reshape(g, gs * k, n_experts)
    pos = (jnp.cumsum(flat, axis=1) - flat).reshape(g, gs, k, n_experts)
    pos_of_slot = jnp.sum(pos * onehot_e, axis=-1).astype(jnp.int32)  # (g,gs,k)
    in_cap = pos_of_slot < cap

    dt = x.dtype
    y = jnp.zeros_like(xg)
    xe_sum = jnp.zeros((g, n_experts, cap, d), dt)
    dispatches = []
    for j in range(k):  # per-slot (g,gs,E,C) one-hots, bf16
        d_j = (
            onehot_e[:, :, j, :, None]
            * jax.nn.one_hot(pos_of_slot[:, :, j], cap, dtype=jnp.float32)[:, :, None, :]
            * in_cap[:, :, j, None, None]
        ).astype(dt)
        dispatches.append(d_j)
        xe_sum = xe_sum + jnp.einsum("gsec,gsd->gecd", d_j, xg)
    ye = jax.vmap(_expert_ffn, in_axes=(0, None, None, None))(
        xe_sum, params["wi"], params["wg"], params["wo"]
    )  # (g,E,C,d)
    for j in range(k):
        combine_j = dispatches[j] * gates_g[:, :, j, None, None].astype(dt)
        y = y + jnp.einsum("gsec,gecd->gsd", combine_j, ye)
    return y.reshape(b, s, d), aux


def moe_sort(x, params, n_experts: int, k: int, capacity_factor: float = 1.25,
             group_size: int = 4096):
    """Sort-based dispatch: no dense one-hot matmuls.

    Within each group: flatten (token, slot) pairs, sort by expert id,
    scatter the first `cap` arrivals per expert into (E, cap) slots, run
    the grouped expert FFN, and scatter-add weighted results back.
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    gates, idx, logits = router_topk(xf, params["router"], k)
    aux = load_balancing_loss(logits, idx, n_experts)

    g = max(1, t // group_size)
    gs = t // g
    cap = max(int(capacity_factor * k * gs / n_experts), 1)

    def per_group(xg, idx_g, gates_g):
        # xg: (gs, d); idx_g/gates_g: (gs, k)
        flat_e = idx_g.reshape(-1)  # (gs*k,)
        flat_tok = jnp.repeat(jnp.arange(gs), k)
        flat_gate = gates_g.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        e_sorted = flat_e[order]
        tok_sorted = flat_tok[order]
        gate_sorted = flat_gate[order]
        # position within expert = rank - first-rank-of-expert
        first_of_e = jnp.searchsorted(e_sorted, jnp.arange(n_experts))
        pos_in_e = jnp.arange(gs * k) - first_of_e[e_sorted]
        keep = pos_in_e < cap
        slot = jnp.where(keep, e_sorted * cap + pos_in_e, n_experts * cap)  # overflow -> dump slot
        # gather tokens into (E*cap (+1 dump), d)
        xe = jnp.zeros((n_experts * cap + 1, d), xf.dtype).at[slot].set(xg[tok_sorted])
        xe = xe[:-1].reshape(n_experts, cap, d)
        ye = _expert_ffn(xe, params["wi"], params["wg"], params["wo"])  # (E,cap,d)
        ye_flat = jnp.concatenate([ye.reshape(n_experts * cap, d),
                                   jnp.zeros((1, d), ye.dtype)], axis=0)
        contrib = ye_flat[slot] * gate_sorted[:, None].astype(ye.dtype) * keep[:, None]
        y = jnp.zeros((gs, d), ye.dtype).at[tok_sorted].add(contrib)
        return y

    xg = xf.reshape(g, gs, d)
    y = jax.vmap(per_group)(xg, idx.reshape(g, gs, k), gates.reshape(g, gs, k))
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_layer(x, params, n_experts: int, k: int, capacity_factor: float = 1.25,
              impl: str = "einsum", group_size: int | None = None):
    if impl == "einsum":
        return moe_einsum(x, params, n_experts, k, capacity_factor,
                          group_size=group_size or 1024)
    if impl == "sort":
        return moe_sort(x, params, n_experts, k, capacity_factor,
                        group_size=group_size or 4096)
    raise ValueError(f"unknown moe impl {impl!r}")
