"""Core transformer layers: norms, RoPE, GQA attention (chunked), MLP.

Attention ships two XLA implementations (the Pallas kernels in
`repro.kernels` are TPU-target; the XLA paths are what the dry-run
compiles — see DESIGN.md §3):

* ``full``    — naive O(S²) materialised scores; fine for short seq.
* ``chunked`` — q-chunked with online (streamed) softmax over kv blocks:
  peak scores memory O(B·H·q_chunk·kv_chunk); the compile-safe default for
  32k-sequence cells.

Both are causal-aware and GQA-native (n_q heads grouped over n_kv heads).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    y = x32 * jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: (..., S) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
NEG_INF = -1e30


def _gqa_scores(q, k):
    """q: (B,Sq,Hq,D), k: (B,Sk,Hkv,D) -> scores (B,Hq,Sq,Sk)."""
    b, sq, hq, d = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, d)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32), k.astype(jnp.float32))
    return s.reshape(b, hkv * group, sq, k.shape[1])


def _gqa_combine(probs, v):
    """probs: (B,Hq,Sq,Sk), v: (B,Sk,Hkv,D) -> (B,Sq,Hq,D)."""
    b, hq, sq, sk = probs.shape
    hkv = v.shape[2]
    group = hq // hkv
    pg = probs.reshape(b, hkv, group, sq, sk)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", pg, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, v.shape[3])


def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_offset: int | jax.Array = 0,
    kv_len: jax.Array | None = None,
) -> jax.Array:
    """Naive attention. q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D).

    ``q_offset``: absolute position of q[0] (decode: cache length).
    ``kv_len``: valid kv prefix length (masks cache tail), scalar or (B,).
    """
    d = q.shape[-1]
    scores = _gqa_scores(q, k) / jnp.sqrt(d).astype(jnp.float32)
    sq, sk = scores.shape[-2], scores.shape[-1]
    q_pos = jnp.arange(sq) + q_offset
    k_pos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    mask = jnp.broadcast_to(mask, scores.shape[:2] + (sq, sk))
    if kv_len is not None:
        valid = k_pos[None, :] < jnp.reshape(kv_len, (-1, 1))  # (B, Sk)
        mask &= valid[:, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return _gqa_combine(probs, v).astype(q.dtype)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    causal: bool = True,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
    unroll: int = 1,
    skip_masked_blocks: bool = False,
) -> jax.Array:
    """q-chunked attention with streamed (online) softmax over kv blocks.

    Peak live scores tensor: (B, Hq, q_chunk, kv_chunk) — independent of
    sequence length.  ``skip_masked_blocks`` additionally halves causal
    compute by not visiting fully-masked kv blocks (hillclimb lever; the
    skip uses a `fori_loop` bound per q chunk, keeping HLO compact).
    """
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    assert s % q_chunk == 0, (s, q_chunk)
    assert s % kv_chunk == 0, (s, kv_chunk)
    n_q = s // q_chunk
    n_kv = s // kv_chunk
    scale = 1.0 / jnp.sqrt(d).astype(jnp.float32)

    qc = q.reshape(b, n_q, q_chunk, hq, d).transpose(1, 0, 2, 3, 4)  # (nq,B,qc,Hq,D)
    kc = k.reshape(b, n_kv, kv_chunk, hkv, d)
    vc = v.reshape(b, n_kv, kv_chunk, hkv, d)

    def q_block(qi, q_blk):
        # online softmax accumulation over kv blocks
        def kv_step(carry, kj):
            acc, m, l = carry
            k_blk = jax.lax.dynamic_index_in_dim(kc, kj, axis=1, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vc, kj, axis=1, keepdims=False)
            s_blk = _gqa_scores(q_blk, k_blk) * scale  # (B,Hq,qc,kc)
            if causal:
                q_pos = qi * q_chunk + jnp.arange(q_chunk)
                k_pos = kj * kv_chunk + jnp.arange(kv_chunk)
                mask = k_pos[None, :] <= q_pos[:, None]
                s_blk = jnp.where(mask[None, None], s_blk, NEG_INF)
            m_new = jnp.maximum(m, s_blk.max(axis=-1))
            p = jnp.exp(s_blk - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            pv = _gqa_combine(p, v_blk)  # (B,qc,Hq,D)
            acc_new = acc * corr[..., None] + pv.transpose(0, 2, 1, 3)
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((b, hq, q_chunk, d), jnp.float32)
        m0 = jnp.full((b, hq, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, q_chunk), jnp.float32)
        n_vis = n_kv
        if skip_masked_blocks and causal and isinstance(qi, int):
            # static triangular schedule: only kv blocks overlapping the
            # causal triangle of this q chunk (differentiable: static length)
            n_vis = min(n_kv, ((qi + 1) * q_chunk + kv_chunk - 1) // kv_chunk)
        (acc, m, l), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0), jnp.arange(n_vis), unroll=unroll
        )
        out = acc / l[..., None]
        return out.transpose(0, 2, 1, 3)  # (B,qc,Hq,D)

    if skip_masked_blocks and causal:
        # python loop: qi static per block -> per-block static kv lengths
        outs = jnp.stack([q_block(i, qc[i]) for i in range(n_q)])
    else:
        def scan_body(_, args):
            qi, q_blk = args
            return None, q_block(qi, q_blk)

        _, outs = jax.lax.scan(
            scan_body, None, (jnp.arange(n_q), qc), unroll=unroll
        )  # (nq,B,qc,Hq,D)
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, hq, d).astype(q.dtype)


def attention(q, k, v, impl: str = "chunked", **kw):
    if impl == "full":
        kw.pop("q_chunk", None)
        kw.pop("kv_chunk", None)
        kw.pop("unroll", None)
        kw.pop("skip_masked_blocks", None)
        return full_attention(q, k, v, **kw)
    if impl == "chunked":
        kw.pop("q_offset", None)
        kw.pop("kv_len", None)
        return chunked_attention(q, k, v, **kw)
    raise ValueError(f"unknown attention impl {impl!r}")


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def mlp_swiglu(x, wi, wg, wo, constrain: bool = False):
    h = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, wg.astype(x.dtype))
    hg = jax.nn.silu(g) * h
    if constrain:
        from . import sharding_ctx as sc

        hg = sc.constrain(hg, sc.dp_axes(), None, "model")
    return jnp.einsum("bsf,fd->bsd", hg, wo.astype(x.dtype))


def mlp_gelu(x, wi, wo, b1=None, b2=None):
    h = jnp.einsum("bsd,df->bsf", x, wi.astype(x.dtype))
    if b1 is not None:
        h = h + b1.astype(x.dtype)
    h = jax.nn.gelu(h)
    out = jnp.einsum("bsf,fd->bsd", h, wo.astype(x.dtype))
    if b2 is not None:
        out = out + b2.astype(x.dtype)
    return out
