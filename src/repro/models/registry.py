"""Model registry: build the right model class from an ArchConfig."""
from __future__ import annotations

from repro.configs import ArchConfig, RunConfig

from .encdec import EncDecLM
from .transformer import DecoderLM


def build_model(cfg: ArchConfig, run: RunConfig | None = None):
    run = run or RunConfig()
    if cfg.is_encdec:
        return EncDecLM(cfg, run)
    return DecoderLM(cfg, run)
