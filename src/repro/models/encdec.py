"""Whisper-style encoder-decoder (audio frontend stubbed per assignment).

Encoder: bidirectional attention over precomputed frame embeddings
(B, T_enc, d) — the conv1d×2 stem is a STUB supplied by `input_specs()`.
Decoder: causal self-attention + cross-attention + GELU MLP.
Sinusoidal positions on the encoder, learned on the decoder (whisper-
faithful); pre-LN layernorms (with bias, as whisper uses LayerNorm).

Serve path: ``encode`` (the enc-dec "prefill": encoder pass + cross-KV
precompute), then ``decode_step`` against self+cross caches.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, RunConfig

from .layers import attention, full_attention, layernorm, mlp_gelu
from .params import dense_init, embed_init, stack_layers
from .transformer import _dt, _qkv, init_attn


def _ln_init(d):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def init_enc_layer(key, cfg: ArchConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": _ln_init(cfg.d_model),
        "attn": init_attn(k1, cfg),
        "ln2": _ln_init(cfg.d_model),
        "wi": dense_init(k2, cfg.d_model, cfg.d_ff),
        "wo2": dense_init(k3, cfg.d_ff, cfg.d_model),
    }


def init_dec_layer(key, cfg: ArchConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "ln1": _ln_init(cfg.d_model),
        "self_attn": init_attn(k1, cfg),
        "ln_x": _ln_init(cfg.d_model),
        "cross_attn": init_attn(k2, cfg),
        "ln2": _ln_init(cfg.d_model),
        "wi": dense_init(k3, cfg.d_model, cfg.d_ff),
        "wo2": dense_init(k4, cfg.d_ff, cfg.d_model),
    }


def sinusoid_positions(t: int, d: int) -> jax.Array:
    pos = jnp.arange(t)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def _ln(x, p, eps):
    return layernorm(x, p["w"], p["b"], eps)


def _mha(x, kv_src, p, cfg, run, causal):
    """Attention where K/V come from kv_src (cross if != x)."""
    b, s, _ = x.shape
    q, _, _ = _qkv(x, p, cfg, None, rope=False)
    _, k, v = _qkv(kv_src, p, cfg, None, rope=False)
    if run.attn_impl == "full" or s % run.q_chunk or kv_src.shape[1] % run.kv_chunk or s != kv_src.shape[1]:
        o = full_attention(q, k, v, causal=causal)
    else:
        o = attention(
            q, k, v, impl="chunked", causal=causal,
            q_chunk=run.q_chunk, kv_chunk=run.kv_chunk, unroll=run.scan_unroll,
            skip_masked_blocks=run.skip_masked_blocks and causal,
        )
    return jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1), p["wo"].astype(x.dtype)), (k, v)


@dataclass
class EncDecLM:
    cfg: ArchConfig
    run: RunConfig = RunConfig()

    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        return {
            "enc_in": dense_init(ks[0], cfg.d_model, cfg.d_model),  # frame adapter (stub stem)
            "embed": embed_init(ks[1], cfg.vocab_padded, cfg.d_model),
            "dec_pos": 0.01 * jax.random.normal(ks[2], (32768, cfg.d_model), jnp.float32),
            "enc_layers": stack_layers(lambda k: init_enc_layer(k, cfg), ks[3], cfg.enc_layers),
            "dec_layers": stack_layers(lambda k: init_dec_layer(k, cfg), ks[4], cfg.dec_layers),
            "enc_norm": _ln_init(cfg.d_model),
            "dec_norm": _ln_init(cfg.d_model),
        }

    # ------------------------------------------------------------- encoder
    def encode(self, params, frames):
        """frames: (B, T_enc, d) stub embeddings. Returns encoder output."""
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        x = jnp.einsum("btd,de->bte", frames.astype(dtype), params["enc_in"].astype(dtype))
        x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(dtype)[None]

        def body(h, p_l):
            a, _ = _mha(_ln(h, p_l["ln1"], cfg.norm_eps), _ln(h, p_l["ln1"], cfg.norm_eps),
                        p_l["attn"], cfg, run, causal=False)
            h = h + a
            m = mlp_gelu(_ln(h, p_l["ln2"], cfg.norm_eps), p_l["wi"], p_l["wo2"])
            return h + m, None

        body_fn = jax.checkpoint(body) if run.remat == "layer" else body
        if run.scan_layers:
            x, _ = jax.lax.scan(lambda h, p: body_fn(h, p), x, params["enc_layers"])
        else:
            for i in range(cfg.enc_layers):
                x, _ = body_fn(x, jax.tree.map(lambda a: a[i], params["enc_layers"]))
        return _ln(x, params["enc_norm"], cfg.norm_eps)

    # ------------------------------------------------------------- decoder
    def _dec_stack(self, params, x, enc_out, collect_caches: bool):
        cfg, run = self.cfg, self.run

        def body(h, p_l):
            a, (sk, sv) = _mha(
                _ln(h, p_l["ln1"], cfg.norm_eps), _ln(h, p_l["ln1"], cfg.norm_eps),
                p_l["self_attn"], cfg, run, causal=True,
            )
            h = h + a
            c, (ck, cv) = _mha(
                _ln(h, p_l["ln_x"], cfg.norm_eps), enc_out, p_l["cross_attn"], cfg, run,
                causal=False,
            )
            h = h + c
            m = mlp_gelu(_ln(h, p_l["ln2"], cfg.norm_eps), p_l["wi"], p_l["wo2"])
            cdt = jnp.dtype(run.decode_cache_dtype)
            cache = {
                "self_k": sk.astype(cdt), "self_v": sv.astype(cdt),
                "cross_k": ck.astype(cdt), "cross_v": cv.astype(cdt),
            }
            return h + m, cache

        body_fn = jax.checkpoint(body) if run.remat == "layer" else body
        if run.scan_layers:
            x, caches = jax.lax.scan(body_fn, x, params["dec_layers"])
        else:
            accs = []
            for i in range(cfg.dec_layers):
                x, c = body_fn(x, jax.tree.map(lambda a: a[i], params["dec_layers"]))
                accs.append(c)
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *accs)
        return _ln(x, params["dec_norm"], cfg.norm_eps), caches

    def _dec_logits(self, params, x):
        return jnp.einsum("...d,vd->...v", x, params["embed"].astype(x.dtype))

    # ------------------------------------------------------------- train
    def loss_fn(self, params, batch):
        """batch: {'frames': (B,T_enc,d), 'tokens': (B,T_dec+1)}."""
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        x = params["embed"].astype(dtype)[inputs]
        x = x + params["dec_pos"][: x.shape[1]].astype(dtype)[None]
        x, _ = self._dec_stack(params, x, enc_out, collect_caches=False)
        logits = self._dec_logits(params, x).astype(jnp.float32)
        lz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
        loss = (lz - gold).mean()
        return loss, {"ce": loss}

    # ------------------------------------------------------------- serve
    def prefill(self, params, batch, max_len: int | None = None):
        """Encoder pass + decoder prefill over prompt tokens."""
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        max_len = max_len or s
        x = params["embed"].astype(dtype)[tokens]
        x = x + params["dec_pos"][:s].astype(dtype)[None]
        x, caches = self._dec_stack(params, x, enc_out, collect_caches=True)
        logits = self._dec_logits(params, x[:, -1]).astype(jnp.float32)

        def pad_self(a):
            if a.shape[2] == max_len:
                return a
            pad = [(0, 0)] * a.ndim
            pad[2] = (0, max_len - a.shape[2])
            return jnp.pad(a, pad)

        cache = {
            "self_k": pad_self(caches["self_k"]), "self_v": pad_self(caches["self_v"]),
            "cross_k": caches["cross_k"], "cross_v": caches["cross_v"],
            "pos": jnp.int32(s),
        }
        return logits, cache

    def init_cache(self, batch: int, max_len: int, enc_len: int):
        cfg, run = self.cfg, self.run
        cdt = jnp.dtype(run.decode_cache_dtype)
        hkv, hd, L = cfg.n_kv_heads, cfg.head_dim_, cfg.dec_layers
        return {
            "self_k": jnp.zeros((L, batch, max_len, hkv, hd), cdt),
            "self_v": jnp.zeros((L, batch, max_len, hkv, hd), cdt),
            "cross_k": jnp.zeros((L, batch, enc_len, hkv, hd), cdt),
            "cross_v": jnp.zeros((L, batch, enc_len, hkv, hd), cdt),
            "pos": jnp.int32(0),
        }

    def decode_step(self, params, cache, token):
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        b = token.shape[0]
        pos = cache["pos"]
        x = params["embed"].astype(dtype)[token]
        x = x + jax.lax.dynamic_index_in_dim(params["dec_pos"], pos, keepdims=False).astype(dtype)

        def body(h, xs):
            p_l, c_l = xs
            hn = _ln(h[:, None], p_l["ln1"], cfg.norm_eps)
            q, k, v = _qkv(hn, p_l["self_attn"], cfg, None, rope=False)
            cdt = c_l["self_k"].dtype
            sk = jax.lax.dynamic_update_slice_in_dim(c_l["self_k"], k.astype(cdt), pos, axis=1)
            sv = jax.lax.dynamic_update_slice_in_dim(c_l["self_v"], v.astype(cdt), pos, axis=1)
            o = full_attention(
                q, sk.astype(q.dtype), sv.astype(q.dtype), causal=False,
                kv_len=jnp.full((b,), pos + 1),
            ).reshape(b, -1)
            h = h + o @ p_l["self_attn"]["wo"].astype(dtype)
            hn = _ln(h[:, None], p_l["ln_x"], cfg.norm_eps)
            q, _, _ = _qkv(hn, p_l["cross_attn"], cfg, None, rope=False)
            o = full_attention(
                q, c_l["cross_k"].astype(q.dtype), c_l["cross_v"].astype(q.dtype), causal=False
            ).reshape(b, -1)
            h = h + o @ p_l["cross_attn"]["wo"].astype(dtype)
            m = mlp_gelu(_ln(h[:, None], p_l["ln2"], cfg.norm_eps), p_l["wi"], p_l["wo2"])[:, 0]
            return h + m, {"self_k": sk, "self_v": sv}

        if run.scan_layers:
            x, updates = jax.lax.scan(
                body, x, (params["dec_layers"],
                          {"self_k": cache["self_k"], "self_v": cache["self_v"],
                           "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]})
            )
        else:
            ups = []
            for i in range(cfg.dec_layers):
                xs = jax.tree.map(
                    lambda a: a[i],
                    (params["dec_layers"],
                     {"self_k": cache["self_k"], "self_v": cache["self_v"],
                      "cross_k": cache["cross_k"], "cross_v": cache["cross_v"]}),
                )
                x, u = body(x, xs)
                ups.append(u)
            updates = jax.tree.map(lambda *xs: jnp.stack(xs), *ups)
        x = _ln(x[:, None], params["dec_norm"], cfg.norm_eps)[:, 0]
        logits = self._dec_logits(params, x).astype(jnp.float32)
        new_cache = dict(cache)
        new_cache.update({"self_k": updates["self_k"], "self_v": updates["self_v"],
                          "pos": pos + 1})
        return logits, new_cache
