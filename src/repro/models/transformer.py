"""Unified decoder-only LM covering the dense / moe / ssm / hybrid families.

One model class, four layer families:

* dense   — GQA attention + SwiGLU MLP (qwen, phi3, granite, chameleon)
* moe     — GQA attention + top-k routed experts (phi3.5-moe, grok-1)
* ssm     — RWKV-6 layers (attention-free)
* hybrid  — Mamba-2 groups + one **shared** attention block applied after
            every `attn_every` SSM layers (zamba2)

Layers are stacked (leading L dim) and traversed with `lax.scan`
(`RunConfig.scan_layers=False` unrolls — used by the cost-exact dry-run
lowering).  `RunConfig.remat="layer"` wraps the layer body in
`jax.checkpoint` (required memory policy at the assigned shapes).

API: ``init``, ``loss_fn`` (train), ``prefill`` + ``decode_step`` (serve).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, RunConfig

from . import rwkv as rwkv_mod
from . import ssm as ssm_mod
from .layers import apply_rope, attention, full_attention, mlp_swiglu, rmsnorm
from .moe import moe_layer
from .params import dense_init, embed_init, stack_layers


def _dt(run: RunConfig):
    return jnp.dtype(run.compute_dtype)


# ---------------------------------------------------------------------------
# attention + mlp blocks (shared by dense/moe/hybrid/encdec)
# ---------------------------------------------------------------------------
def init_attn(key, cfg: ArchConfig):
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * hd),
        "wk": dense_init(ks[1], d, hkv * hd),
        "wv": dense_init(ks[2], d, hkv * hd),
        "wo": dense_init(ks[3], hq * hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), jnp.float32)
        p["bk"] = jnp.zeros((hkv * hd,), jnp.float32)
        p["bv"] = jnp.zeros((hkv * hd,), jnp.float32)
    return p


def _qkv(x, p, cfg: ArchConfig, positions, rope: bool = True):
    b, s, _ = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = x.dtype
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(dt))
    if "bq" in p:
        q, k, v = q + p["bq"].astype(dt), k + p["bk"].astype(dt), v + p["bv"].astype(dt)
    q = q.reshape(b, s, hq, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_block(x, p, cfg: ArchConfig, run: RunConfig, positions, causal=True, rope=True):
    """Full-sequence attention (train/prefill). Returns (out, (k, v))."""
    from . import sharding_ctx as sc

    b, s, _ = x.shape
    q, k, v = _qkv(x, p, cfg, positions, rope)
    if run.constrain_activations:
        dp = sc.dp_axes()
        q = sc.constrain(q, dp, None, "model", None)
        k = sc.constrain(k, dp, None, "model", None)
        v = sc.constrain(v, dp, None, "model", None)
    if run.attn_impl == "full" or s % run.q_chunk or s % run.kv_chunk:
        o = full_attention(q, k, v, causal=causal)
    else:
        o = attention(
            q, k, v, impl="chunked", causal=causal,
            q_chunk=run.q_chunk, kv_chunk=run.kv_chunk,
            unroll=run.scan_unroll, skip_masked_blocks=run.skip_masked_blocks,
        )
    o = o.reshape(b, s, -1)
    return jnp.einsum("bse,ed->bsd", o, p["wo"].astype(x.dtype)), (k, v)


def attn_block_decode_paged(
    x, p, cfg: ArchConfig, run: RunConfig, k_pages, v_pages, page_table, kv_len, live
):
    """Single-token attention against a paged KV pool.

    x: (B, d); pages: (P, ps, Hkv, Dh); page_table: (B, max_pages) int32;
    kv_len: (B,) tokens already cached per row; live: (B,) bool.  Each live
    row writes its new K/V at position ``kv_len[b]`` inside the page the
    table maps it to; dead rows (free slots) write to the reserved null
    page and attend over an empty cache — their output is exact zeros.
    Returns (out (B, d), new_k_pages, new_v_pages).
    """
    from repro.kernels.paged_attention import NULL_PAGE, paged_decode_attention

    b, _ = x.shape
    ps = k_pages.shape[1]
    q, k, v = _qkv(x[:, None], p, cfg, kv_len[:, None], rope=True)
    cdt = k_pages.dtype
    page = jnp.where(live, page_table[jnp.arange(b), kv_len // ps], NULL_PAGE)
    off = kv_len % ps
    k_pages = k_pages.at[page, off].set(k[:, 0].astype(cdt))
    v_pages = v_pages.at[page, off].set(v[:, 0].astype(cdt))
    new_len = jnp.where(live, kv_len + 1, 0)
    o = paged_decode_attention(q[:, 0], k_pages, v_pages, page_table, new_len)
    o = o.reshape(b, -1).astype(x.dtype)
    return jnp.einsum("be,ed->bd", o, p["wo"].astype(x.dtype)), k_pages, v_pages


def attn_block_decode(x, p, cfg: ArchConfig, run: RunConfig, k_cache, v_cache, pos):
    """Single-token attention against a cache.

    x: (B, d); k/v_cache: (B, Smax, Hkv, Dh); pos: scalar current length.
    Returns (out (B, d), new_k, new_v).
    """
    b, d = x.shape
    q, k, v = _qkv(x[:, None], p, cfg, jnp.full((b, 1), pos), rope=True)
    cdt = k_cache.dtype
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(cdt), pos, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(cdt), pos, axis=1)
    o = full_attention(
        q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
        causal=False, kv_len=jnp.full((b,), pos + 1),
    )
    o = o.reshape(b, -1)
    return jnp.einsum("be,ed->bd", o, p["wo"].astype(x.dtype)), k_cache, v_cache


def init_mlp(key, cfg: ArchConfig):
    d, ff = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "wi": dense_init(ks[0], d, ff),
        "wg": dense_init(ks[1], d, ff),
        "wo2": dense_init(ks[2], ff, d),
    }


def init_moe(key, cfg: ArchConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], d, e),
        "wi": jax.vmap(lambda k: dense_init(k, d, ff))(jax.random.split(ks[1], e)),
        "wg": jax.vmap(lambda k: dense_init(k, d, ff))(jax.random.split(ks[2], e)),
        "wo": jax.vmap(lambda k: dense_init(k, ff, d))(jax.random.split(ks[3], e)),
    }


# ---------------------------------------------------------------------------
# layer families
# ---------------------------------------------------------------------------
def init_layer(key, cfg: ArchConfig):
    if cfg.family in ("dense", "moe"):
        k1, k2 = jax.random.split(key)
        p = {
            "ln1": jnp.ones((cfg.d_model,), jnp.float32),
            "attn": init_attn(k1, cfg),
            "ln2": jnp.ones((cfg.d_model,), jnp.float32),
        }
        p["moe" if cfg.family == "moe" else "mlp"] = (
            init_moe(k2, cfg) if cfg.family == "moe" else init_mlp(k2, cfg)
        )
        return p
    if cfg.family == "ssm":
        return rwkv_mod.init_rwkv_layer(key, cfg.d_model, cfg.d_ff)
    if cfg.family == "hybrid":
        return {
            "ln": jnp.ones((cfg.d_model,), jnp.float32),
            "ssm": ssm_mod.init_ssm_block(key, cfg.d_model, cfg.ssm_state),
        }
    raise ValueError(cfg.family)


def apply_layer(x, p, cfg: ArchConfig, run: RunConfig, positions):
    """Train/prefill layer body. Returns (x, (aux_loss, cache))."""
    from . import sharding_ctx as sc

    if cfg.family in ("dense", "moe"):
        a, (k, v) = attn_block(rmsnorm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, run, positions)
        x = x + a
        if run.constrain_activations:
            x = sc.constrain(x, sc.dp_axes(), None, None)
        h = rmsnorm(x, p["ln2"], cfg.norm_eps)
        if cfg.family == "moe":
            m, aux = moe_layer(
                h, p["moe"], cfg.n_experts, cfg.experts_per_token,
                cfg.capacity_factor, impl=run.moe_impl, group_size=run.moe_group,
            )
        else:
            m = mlp_swiglu(h, p["mlp"]["wi"], p["mlp"]["wg"], p["mlp"]["wo2"],
                           constrain=run.constrain_activations)
            aux = 0.0
        x = x + m
        if run.constrain_activations:
            x = sc.constrain(x, sc.dp_axes(), None, None)
        cdt = jnp.dtype(run.decode_cache_dtype)
        return x, (jnp.asarray(aux, jnp.float32), {"k": k.astype(cdt), "v": v.astype(cdt)})
    if cfg.family == "ssm":
        y, cache = rwkv_mod.rwkv_layer(x, p, chunk=run.lr_chunk, eps=cfg.norm_eps,
                                       unroll=run.scan_unroll)
        return y, (jnp.asarray(0.0, jnp.float32), cache)
    if cfg.family == "hybrid":
        y, cache = ssm_mod.ssm_block(
            rmsnorm(x, p["ln"], cfg.norm_eps), p["ssm"], cfg.ssm_state,
            chunk=run.lr_chunk, unroll=run.scan_unroll,
        )
        return x + y, (jnp.asarray(0.0, jnp.float32), cache)
    raise ValueError(cfg.family)


def _decode_tail(x, a, p, cfg: ArchConfig, run: RunConfig):
    """Dense/moe decode-layer tail: attn residual + norm + mlp/moe residual."""
    x = x + a
    h = rmsnorm(x, p["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        # decode must never drop: capacity covers every (token, slot)
        m, _ = moe_layer(
            h[:, None], p["moe"], cfg.n_experts, cfg.experts_per_token,
            capacity_factor=float(cfg.n_experts), impl=run.moe_impl,
            group_size=min(x.shape[0], run.moe_group or x.shape[0]),
        )
        m = m[:, 0]
    else:
        m = mlp_swiglu(h[:, None], p["mlp"]["wi"], p["mlp"]["wg"], p["mlp"]["wo2"])[:, 0]
    return x + m


def apply_layer_decode(x, p, cache, cfg: ArchConfig, run: RunConfig, pos):
    """Single-token layer body. Returns (x, new_cache)."""
    if cfg.family in ("dense", "moe"):
        a, k, v = attn_block_decode(
            rmsnorm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, run,
            cache["k"], cache["v"], pos,
        )
        return _decode_tail(x, a, p, cfg, run), {"k": k, "v": v}
    if cfg.family == "ssm":
        return rwkv_mod.rwkv_layer_decode(x, p, cache, eps=cfg.norm_eps)
    if cfg.family == "hybrid":
        y, new_cache = ssm_mod.ssm_block_decode(
            rmsnorm(x, p["ln"], cfg.norm_eps), p["ssm"], cache, cfg.ssm_state
        )
        return x + y, new_cache
    raise ValueError(cfg.family)


def apply_layer_decode_paged(
    x, p, cache, cfg: ArchConfig, run: RunConfig, page_table, kv_len, live
):
    """Paged single-token layer body (dense/moe only). Returns (x, new_cache)."""
    a, k_pages, v_pages = attn_block_decode_paged(
        rmsnorm(x, p["ln1"], cfg.norm_eps), p["attn"], cfg, run,
        cache["k"], cache["v"], page_table, kv_len, live,
    )
    return _decode_tail(x, a, p, cfg, run), {"k": k_pages, "v": v_pages}


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------
@dataclass
class DecoderLM:
    cfg: ArchConfig
    run: RunConfig = RunConfig()

    # ----------------------------------------------------------- init
    def init(self, key) -> dict:
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params = {
            "embed": embed_init(ks[0], cfg.vocab_padded, cfg.d_model),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["head"] = dense_init(ks[1], cfg.d_model, cfg.vocab_padded)
        if cfg.family == "hybrid":
            g, gsz, tail = self._hybrid_layout()
            params["groups"] = stack_layers(
                lambda k: stack_layers(lambda k2: init_layer(k2, cfg), k, gsz), ks[2], g
            )
            if tail:
                params["tail"] = stack_layers(lambda k: init_layer(k, cfg), ks[3], tail)
            params["shared"] = {
                "ln1": jnp.ones((cfg.d_model,), jnp.float32),
                "attn": init_attn(ks[4], cfg),
                "ln2": jnp.ones((cfg.d_model,), jnp.float32),
                "mlp": init_mlp(ks[5], cfg),
            }
        else:
            params["layers"] = stack_layers(lambda k: init_layer(k, cfg), ks[2], cfg.n_layers)
        return params

    def _hybrid_layout(self):
        g = self.cfg.n_layers // self.cfg.attn_every
        return g, self.cfg.attn_every, self.cfg.n_layers % self.cfg.attn_every

    # ----------------------------------------------------------- forward
    def _embed(self, params, tokens, dtype):
        return params["embed"].astype(dtype)[tokens]

    def _logits(self, params, x):
        head = params.get("head")
        w = (head if head is not None else params["embed"].T).astype(x.dtype)
        if head is None:
            return jnp.einsum("...d,dv->...v", x, w)
        return jnp.einsum("...d,dv->...v", x, w)

    def _layer_scan(self, params, x, positions):
        """Run all layers; returns (x, aux_sum, cache_pytree)."""
        cfg, run = self.cfg, self.run

        def body(carry, p_l):
            h, aux = carry
            h2, (a, cache) = apply_layer(h, p_l, cfg, run, positions)
            return (h2, aux + a), cache

        body_fn = jax.checkpoint(body) if run.remat == "layer" else body

        def run_stack(x, stacked, length):
            if run.scan_layers:
                (x, aux), caches = jax.lax.scan(
                    body_fn, (x, jnp.float32(0.0)), stacked, length=length
                )
                return x, aux, caches
            aux = jnp.float32(0.0)
            caches = []
            for i in range(length):
                p_l = jax.tree.map(lambda a: a[i], stacked)
                (x, aux), cache = body_fn((x, aux), p_l)
                caches.append(cache)
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *caches)
            return x, aux, caches

        if cfg.family != "hybrid":
            return run_stack(x, params["layers"], cfg.n_layers)

        # hybrid: groups of SSM layers, shared attention block between groups
        g, gsz, tail = self._hybrid_layout()
        shared = params["shared"]

        def group_body(carry, p_group):
            h, aux = carry
            h, aux_g, ssm_caches = run_stack(h, p_group, gsz)
            a, (k, v) = attn_block(
                rmsnorm(h, shared["ln1"], cfg.norm_eps), shared["attn"], cfg, run, positions
            )
            h = h + a
            m = mlp_swiglu(
                rmsnorm(h, shared["ln2"], cfg.norm_eps),
                shared["mlp"]["wi"], shared["mlp"]["wg"], shared["mlp"]["wo2"],
            )
            cdt = jnp.dtype(run.decode_cache_dtype)
            return (h + m, aux + aux_g), (ssm_caches, {"k": k.astype(cdt), "v": v.astype(cdt)})

        if run.scan_layers:
            (x, aux), (ssm_caches, attn_caches) = jax.lax.scan(
                group_body, (x, jnp.float32(0.0)), params["groups"]
            )
        else:
            aux = jnp.float32(0.0)
            accs = []
            for i in range(g):
                p_g = jax.tree.map(lambda a: a[i], params["groups"])
                (x, aux), acc = group_body((x, aux), p_g)
                accs.append(acc)
            ssm_caches, attn_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *accs)
        cache = {"groups": ssm_caches, "shared_attn": attn_caches}
        if tail:
            x, aux_t, tail_caches = run_stack(x, params["tail"], tail)
            aux = aux + aux_t
            cache["tail"] = tail_caches
        return x, aux, cache

    # ----------------------------------------------------------- train
    def loss_fn(self, params, batch):
        """batch['tokens']: (B, S+1) int32. Returns (loss, metrics)."""
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        tokens = batch["tokens"]
        inputs, targets = tokens[:, :-1], tokens[:, 1:]
        b, s = inputs.shape
        if cfg.frontend == "vlm" and "frame_embeddings" in batch:
            x = batch["frame_embeddings"].astype(dtype)  # stub frontend path
        else:
            x = self._embed(params, inputs, dtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, aux, _ = self._layer_scan(params, x, positions)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        loss = self._ce(params, x, targets)
        if cfg.family == "moe":
            loss = loss + 0.01 * aux / cfg.n_layers
        return loss, {"ce": loss, "aux": aux}

    def _ce(self, params, x, targets):
        run = self.run
        v = self.cfg.vocab_padded

        def ce_of(xc, tc):
            logits = self._logits(params, xc).astype(jnp.float32)
            lz = jax.nn.logsumexp(logits, axis=-1)
            if run.ce_impl == "onehot":
                # vocab-sharding-friendly gold pick: a fused masked reduce
                # over the local vocab shard + tiny all-reduce, instead of
                # a gather across the sharded vocab dimension (§Perf)
                iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
                gold = jnp.where(iota == tc[..., None], logits, 0.0).sum(axis=-1)
            else:
                gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
            return (lz - gold).sum(), tc.size

        if run.ce_chunk and x.shape[1] % run.ce_chunk == 0:
            n = x.shape[1] // run.ce_chunk
            xc = x.reshape(x.shape[0], n, run.ce_chunk, -1).transpose(1, 0, 2, 3)
            tc = targets.reshape(targets.shape[0], n, run.ce_chunk).transpose(1, 0, 2)

            def body(tot, xs):
                l, c = ce_of(*xs)
                return tot + l, None

            total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, tc))
            return total / targets.size
        l, c = ce_of(x, targets)
        return l / c

    # ----------------------------------------------------------- serve
    def init_cache(self, batch: int, max_len: int):
        """Allocate the decode cache (used via eval_shape in the dry-run)."""
        cfg, run = self.cfg, self.run
        cdt = jnp.dtype(run.decode_cache_dtype)
        hkv, hd = cfg.n_kv_heads, cfg.head_dim_

        def kv(b, s):
            return {
                "k": jnp.zeros((b, s, hkv, hd), cdt),
                "v": jnp.zeros((b, s, hkv, hd), cdt),
            }

        if cfg.family in ("dense", "moe"):
            caches = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(),
                kv(batch, max_len),
            )
            return {"layers": caches, "pos": jnp.int32(0)}
        if cfg.family == "ssm":
            c = rwkv_mod.init_rwkv_cache(batch, cfg.d_model)
            return {
                "layers": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), c
                ),
                "pos": jnp.int32(0),
            }
        if cfg.family == "hybrid":
            g, gsz, tail = self._hybrid_layout()
            ssm_c = ssm_mod.init_ssm_cache(batch, cfg.d_model, cfg.ssm_state)
            out = {
                "groups": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (g, gsz) + x.shape).copy(), ssm_c
                ),
                "shared_attn": jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (g,) + x.shape).copy(), kv(batch, max_len)
                ),
                "pos": jnp.int32(0),
            }
            if tail:
                out["tail"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (tail,) + x.shape).copy(), ssm_c
                )
            return out
        raise ValueError(cfg.family)

    def init_paged_cache(self, n_pages: int, page_size: int):
        """Allocate the paged decode cache: per-layer K/V page pools.

        Returns ``{"layers": {"k": (L, P, ps, Hkv, Dh), "v": ...}}`` — no
        ``pos`` clock: position is per-row ragged ``kv_len``, owned by the
        host-side `repro.kernels.paged_attention.PagedKVPool`.  Dense/moe
        families only (ssm/hybrid keep recurrent state, nothing to page).
        """
        cfg, run = self.cfg, self.run
        if cfg.family not in ("dense", "moe"):
            raise ValueError(f"paged KV cache needs attention layers, not {cfg.family!r}")
        cdt = jnp.dtype(run.decode_cache_dtype)
        pool = jnp.zeros((cfg.n_layers, n_pages, page_size, cfg.n_kv_heads, cfg.head_dim_), cdt)
        return {"layers": {"k": pool, "v": pool.copy()}}

    def prefill(self, params, tokens, max_len: int | None = None):
        """tokens: (B, S). Returns (last-token logits (B, V), cache)."""
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        b, s = tokens.shape
        max_len = max_len or s
        x = self._embed(params, tokens, dtype)
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, _, caches = self._layer_scan(params, x, positions)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        logits = self._logits(params, x[:, -1]).astype(jnp.float32)
        cache = self._package_cache(caches, b, s, max_len)
        return logits, cache

    def _package_cache(self, caches, b, s, max_len):
        cfg = self.cfg

        def pad_kv(x):  # (L, B, S, H, D) -> (L, B, max_len, H, D)
            if x.shape[2] == max_len:
                return x
            pad = [(0, 0)] * x.ndim
            pad[2] = (0, max_len - x.shape[2])
            return jnp.pad(x, pad)

        if cfg.family in ("dense", "moe"):
            return {"layers": jax.tree.map(pad_kv, caches), "pos": jnp.int32(s)}
        if cfg.family == "ssm":
            return {"layers": caches, "pos": jnp.int32(s)}
        if cfg.family == "hybrid":
            out = dict(caches)
            out["shared_attn"] = jax.tree.map(pad_kv, caches["shared_attn"])
            out["pos"] = jnp.int32(s)
            return out
        raise ValueError(cfg.family)

    def decode_step(self, params, cache, token):
        """token: (B,) int32. Returns (logits (B, V), new cache)."""
        cfg, run = self.cfg, self.run
        dtype = _dt(run)
        x = self._embed(params, token, dtype)
        pos = cache["pos"]

        def stack_step(x, stacked_p, stacked_c, length):
            def body(h, xs):
                p_l, c_l = xs
                h, c_new = apply_layer_decode(h, p_l, c_l, cfg, run, pos)
                return h, c_new

            if run.scan_layers:
                return jax.lax.scan(body, x, (stacked_p, stacked_c), length=length)
            news = []
            for i in range(length):
                p_l = jax.tree.map(lambda a: a[i], stacked_p)
                c_l = jax.tree.map(lambda a: a[i], stacked_c)
                x, c_new = body(x, (p_l, c_l))
                news.append(c_new)
            return x, jax.tree.map(lambda *xs: jnp.stack(xs), *news)

        new_cache = {"pos": pos + 1}
        if cfg.family != "hybrid":
            x, caches = stack_step(x, params["layers"], cache["layers"], cfg.n_layers)
            new_cache["layers"] = caches
        else:
            g, gsz, tail = self._hybrid_layout()
            shared = params["shared"]

            def group_body(h, xs):
                p_g, ssm_c, attn_c = xs
                h, ssm_new = stack_step(h, p_g, ssm_c, gsz)
                a, k_new, v_new = attn_block_decode(
                    rmsnorm(h, shared["ln1"], cfg.norm_eps), shared["attn"], cfg, run,
                    attn_c["k"], attn_c["v"], pos,
                )
                h = h + a
                m = mlp_swiglu(
                    rmsnorm(h, shared["ln2"], cfg.norm_eps)[:, None],
                    shared["mlp"]["wi"], shared["mlp"]["wg"], shared["mlp"]["wo2"],
                )[:, 0]
                return h + m, (ssm_new, {"k": k_new, "v": v_new})

            if run.scan_layers:
                x, (ssm_caches, attn_caches) = jax.lax.scan(
                    group_body, x, (params["groups"], cache["groups"], cache["shared_attn"])
                )
            else:
                accs = []
                for i in range(g):
                    xs_i = jax.tree.map(
                        lambda a: a[i], (params["groups"], cache["groups"], cache["shared_attn"])
                    )
                    x, acc = group_body(x, xs_i)
                    accs.append(acc)
                ssm_caches, attn_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *accs)
            new_cache["groups"] = ssm_caches
            new_cache["shared_attn"] = attn_caches
            if tail:
                x, tail_caches = stack_step(x, params["tail"], cache["tail"], tail)
                new_cache["tail"] = tail_caches
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x).astype(jnp.float32), new_cache

    def decode_step_paged(self, params, cache, token, page_table, kv_len, live):
        """Paged decode step (dense/moe): token (B,), page_table (B, max_pages),
        kv_len (B,) tokens already cached per row, live (B,) bool.

        Every layer writes its new K/V at the same per-row position
        ``kv_len[b]`` — the caller (the serve loop's `PagedKVPool`) advances
        lengths once per step, after the step.  Dead rows (``live`` False)
        park their writes on the null page; their logits are garbage and the
        scheduler never bills them.  Returns (logits (B, V), new cache).
        """
        cfg, run = self.cfg, self.run
        x = self._embed(params, token, _dt(run))

        def body(h, xs):
            p_l, c_l = xs
            h, c_new = apply_layer_decode_paged(
                h, p_l, c_l, cfg, run, page_table, kv_len, live
            )
            return h, c_new

        if run.scan_layers:
            x, caches = jax.lax.scan(
                body, x, (params["layers"], cache["layers"]), length=cfg.n_layers
            )
        else:
            news = []
            for i in range(cfg.n_layers):
                p_l = jax.tree.map(lambda a: a[i], params["layers"])
                c_l = jax.tree.map(lambda a: a[i], cache["layers"])
                x, c_new = body(x, (p_l, c_l))
                news.append(c_new)
            caches = jax.tree.map(lambda *xs: jnp.stack(xs), *news)
        x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
        return self._logits(params, x).astype(jnp.float32), {"layers": caches}
