"""Parameter initialisation + pytree utilities (no flax — plain pytrees).

Params are nested dicts of jnp arrays.  Layer stacks carry a leading
``n_layers`` dim so the forward pass can `lax.scan` over them (O(1) HLO
size — essential for compiling 64-layer models in the dry-run).

All init functions are shaped so they can run under `jax.eval_shape`
(the dry-run never allocates real parameters).
"""
from __future__ import annotations

import math
from typing import Any, Callable

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree


def truncated_normal(key, shape, std: float, dtype=jnp.float32):
    return std * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32):
    """Fan-in scaled init (the default for all projection matrices)."""
    return truncated_normal(key, (d_in, d_out), std=1.0 / math.sqrt(d_in), dtype=dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32):
    # 1/sqrt(d): keeps tied-head logits O(1) at init
    return truncated_normal(key, (vocab, d), std=1.0 / math.sqrt(d), dtype=dtype)


def stack_layers(init_one: Callable[[jax.Array], Params], key, n: int) -> Params:
    """Init n layers and stack each leaf along a new leading axis.

    Uses vmap so it stays cheap under eval_shape.
    """
    keys = jax.random.split(key, n)
    return jax.vmap(init_one)(keys)


def param_count(params: Params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


def param_bytes(params: Params) -> int:
    return sum(int(x.size * x.dtype.itemsize) for x in jax.tree.leaves(params))


def cast_tree(params: Params, dtype) -> Params:
    """Cast floating-point leaves (cast-at-use mixed precision)."""
    def _cast(x):
        if jnp.issubdtype(x.dtype, jnp.floating):
            return x.astype(dtype)
        return x

    return jax.tree.map(_cast, params)


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def tree_zeros_like(tree: Params) -> Params:
    return jax.tree.map(jnp.zeros_like, tree)
