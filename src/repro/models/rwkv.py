"""RWKV-6 ("Finch") block — data-dependent decay, attention-free.

Per layer: a time-mix block (the WKV recurrence) and a channel-mix block.

Time-mix (faithful structure, simplified token-shift interpolation):

    xs        = token_shift(x)                  (previous token)
    x_i       = lerp(x, xs, µ_i)   i ∈ {r,k,v,g,w}   (static µ per channel)
    w         = −exp(w0 + tanh(x_w A) B)        (data-dependent log decay,
                 clamped to [−MAX_CHANNEL_DECAY, −1e−4] — see linear_scan)
    r,k,v,g   = projections; heads of 64
    wkv       = linear recurrence, o_t = r_t·S_{t−1} + u ⊙ (r_t·k_t) v_t
    out       = (per-head RMSNorm(wkv) ⊙ SiLU(g)) W_o

Channel-mix: k = ReLU(x_k W_k)²; out = σ(x_r W_r) ⊙ (k W_v).

Deviation from reference RWKV-6: the µ interpolators are static per
channel (reference uses an additional data-dependent LoRA on all five);
the decay LoRA — the architecturally load-bearing novelty of v6 — is kept.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import rmsnorm
from .linear_scan import MAX_CHANNEL_DECAY, chunked_linear_recurrence, recurrence_step
from .params import dense_init

HEAD_K = 64
DECAY_LORA = 64


def init_time_mix(key, d: int):
    h = d // HEAD_K
    ks = jax.random.split(key, 10)
    return {
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),  # r,k,v,g,w interpolators
        "w0": -2.0 * jnp.ones((d,), jnp.float32),
        "wA": dense_init(ks[0], d, DECAY_LORA),
        "wB": 0.1 * dense_init(ks[1], DECAY_LORA, d),
        "wr": dense_init(ks[2], d, d),
        "wk": dense_init(ks[3], d, d),
        "wv": dense_init(ks[4], d, d),
        "wg": dense_init(ks[5], d, d),
        "wo": dense_init(ks[6], d, d),
        "u": 0.1 * jax.random.normal(ks[7], (h, HEAD_K), jnp.float32),
        "ln_x": jnp.ones((d,), jnp.float32),
    }


def init_channel_mix(key, d: int, ff: int):
    ks = jax.random.split(key, 3)
    return {
        "mu": 0.5 * jnp.ones((2, d), jnp.float32),  # r,k
        "wr": dense_init(ks[0], d, d),
        "wk": dense_init(ks[1], d, ff),
        "wv": dense_init(ks[2], ff, d),
    }


def _lerp(x, xs, mu):
    return x + (xs - x) * mu.astype(x.dtype)


def _log_decay(xw, p):
    """Data-dependent per-channel log decay, bounded for the chunked engine."""
    lora = jnp.einsum(
        "btk,kd->btd",
        jnp.tanh(jnp.einsum("btd,dk->btk", xw, p["wA"].astype(xw.dtype))),
        p["wB"].astype(xw.dtype),
    )
    w = -jnp.exp(p["w0"].astype(jnp.float32) + lora.astype(jnp.float32))
    return jnp.clip(w, -MAX_CHANNEL_DECAY, -1e-4)


def time_mix(x, xs, p, chunk: int = 32, initial_state=None, unroll: int = 1):
    """x: (B,T,d); xs: token-shifted x. Returns (out, final_wkv_state)."""
    b, t, d = x.shape
    h = d // HEAD_K
    dtype = x.dtype
    xr, xk, xv, xg, xw = (_lerp(x, xs, p["mu"][i]) for i in range(5))
    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(dtype)).reshape(b, t, h, HEAD_K)
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(dtype)).reshape(b, t, h, HEAD_K)
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(dtype)).reshape(b, t, h, HEAD_K)
    g = jax.nn.silu(jnp.einsum("btd,de->bte", xg, p["wg"].astype(dtype)))
    w = _log_decay(xw, p).reshape(b, t, h, HEAD_K)
    o, s_final = chunked_linear_recurrence(
        r, k, v, w, chunk=chunk, include_current=False, bonus=p["u"],
        initial_state=initial_state, unroll=unroll,
    )
    o = o.reshape(b, t, d)
    # per-head group norm (RWKV uses GroupNorm(h)); rms per head + scale
    o = rmsnorm(o.reshape(b, t, h, HEAD_K), jnp.ones((HEAD_K,), jnp.float32)).reshape(b, t, d)
    o = o * p["ln_x"].astype(dtype) * g
    return jnp.einsum("btd,de->bte", o, p["wo"].astype(dtype)), s_final


def time_mix_step(x, x_prev, p, state):
    """Decode step. x: (B,d); state (B,H,K,K)."""
    b, d = x.shape
    h = d // HEAD_K
    dtype = x.dtype
    xr, xk, xv, xg, xw = (_lerp(x, x_prev, p["mu"][i]) for i in range(5))
    r = (xr @ p["wr"].astype(dtype)).reshape(b, h, HEAD_K)
    k = (xk @ p["wk"].astype(dtype)).reshape(b, h, HEAD_K)
    v = (xv @ p["wv"].astype(dtype)).reshape(b, h, HEAD_K)
    g = jax.nn.silu(xg @ p["wg"].astype(dtype))
    w = _log_decay(xw[:, None], p)[:, 0].reshape(b, h, HEAD_K)
    o, s_new = recurrence_step(r, k, v, w, state, include_current=False, bonus=p["u"])
    o = o.reshape(b, d)
    o = rmsnorm(o.reshape(b, h, HEAD_K), jnp.ones((HEAD_K,), jnp.float32)).reshape(b, d)
    o = o * p["ln_x"].astype(dtype) * g
    return o @ p["wo"].astype(dtype), s_new


def channel_mix(x, xs, p):
    dtype = x.dtype
    xr = _lerp(x, xs, p["mu"][0])
    xk = _lerp(x, xs, p["mu"][1])
    k = jnp.square(jax.nn.relu(jnp.einsum("...d,df->...f", xk, p["wk"].astype(dtype))))
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xr, p["wr"].astype(dtype)))
    return r * jnp.einsum("...f,fd->...d", k, p["wv"].astype(dtype))


def token_shift(x):
    """(B,T,d): position t sees x_{t-1}; position 0 sees zeros."""
    return jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]


def init_rwkv_layer(key, d: int, ff: int):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((d,), jnp.float32),
        "tm": init_time_mix(k1, d),
        "ln2": jnp.ones((d,), jnp.float32),
        "cm": init_channel_mix(k2, d, ff),
    }


def rwkv_layer(x, p, chunk: int = 32, eps: float = 1e-5, unroll: int = 1):
    """Full train/prefill layer. Returns (y, cache)."""
    h1 = rmsnorm(x, p["ln1"], eps)
    tm_out, s_final = time_mix(h1, token_shift(h1), p["tm"], chunk=chunk, unroll=unroll)
    x = x + tm_out
    h2 = rmsnorm(x, p["ln2"], eps)
    x = x + channel_mix(h2, token_shift(h2), p["cm"])
    cache = {
        "shift_tm": h1[:, -1],  # (B,d) last normed input of time-mix
        "shift_cm": h2[:, -1],
        "wkv": s_final,
    }
    return x, cache


def rwkv_layer_decode(x, p, cache, eps: float = 1e-5):
    """x: (B,d)."""
    dt = x.dtype  # keep the scan carry dtype stable across mixed-dtype caches
    h1 = rmsnorm(x, p["ln1"], eps)
    tm_out, s_new = time_mix_step(h1, cache["shift_tm"].astype(dt), p["tm"], cache["wkv"])
    x = (x + tm_out).astype(dt)
    h2 = rmsnorm(x, p["ln2"], eps)
    x = (x + channel_mix(h2[:, None], cache["shift_cm"].astype(dt)[:, None], p["cm"])[:, 0]).astype(dt)
    return x, {"shift_tm": h1.astype(cache["shift_tm"].dtype),
               "shift_cm": h2.astype(cache["shift_cm"].dtype),
               "wkv": s_new}


def init_rwkv_cache(batch: int, d: int, dtype=jnp.float32):
    h = d // HEAD_K
    return {
        "shift_tm": jnp.zeros((batch, d), dtype),
        "shift_cm": jnp.zeros((batch, d), dtype),
        "wkv": jnp.zeros((batch, h, HEAD_K, HEAD_K), jnp.float32),
    }
