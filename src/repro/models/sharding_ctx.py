"""Optional activation-sharding constraints (Megatron-style), context-set.

The baseline leaves intermediate shardings to XLA's propagation; §Perf
shows that at 16-way TP this lets the partitioner pick pathological
layouts (all-to-all resharding in the remat backward).  With
`RunConfig.constrain_activations=True` the model pins the canonical
layouts:

    residual stream x  : P(dp, None, None)
    mlp hidden h, g    : P(dp, None, model)      (ff sharded)
    attention heads    : P(dp, None, model, None) (fallback: head_dim)

`set_mesh` is called by the lowering entry points (specs/components);
without a mesh every `constrain` is a no-op, so tests/examples on one
device are unaffected.  Specs are divisibility-checked like the param
rules — a non-dividing dim falls back to unsharded.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


@contextmanager
def constraint_mesh(mesh):
    global _MESH
    prev = _MESH
    _MESH = mesh
    try:
        yield
    finally:
        _MESH = prev


def _axis_size(mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return int(mesh.shape[name]) if name in mesh.shape else 0


def dp_axes():
    if _MESH is None:
        return ("data",)
    return ("pod", "data") if "pod" in _MESH.shape else ("data",)


def constrain(x, *spec):
    """with_sharding_constraint with divisibility fallbacks; no-op w/o mesh."""
    if _MESH is None:
        return x
    fitted = []
    for dim, ax in zip(x.shape, spec):
        if ax is None:
            fitted.append(None)
            continue
        size = _axis_size(_MESH, ax)
        fitted.append(ax if size > 1 and dim % size == 0 else None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(_MESH, P(*fitted)))
