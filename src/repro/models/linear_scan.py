"""Generic chunked linear-recurrence engine (the TPU-native adaptation of
SSM/RWKV recurrences — DESIGN.md hardware-adaptation notes).

Both Mamba-2 (SSD) and RWKV-6 are instances of the gated linear
recurrence

    S_t = diag(d_t) · S_{t-1} + k_tᵀ v_t          S ∈ R^{K×V} per head
    o_t = q_t · S_{t-1 or t}  (+ u ⊙ (q_t·k_t) v_t   bonus, RWKV)

with data-dependent decay d_t.  A naive `lax.scan` over time is a long
chain of tiny ops — hostile to the MXU.  The **chunked** form processes C
tokens at a time with dense matmuls (intra-chunk attention-like term +
inter-chunk state carry): exactly the restructuring TPUs want.  The Pallas
kernels in `repro.kernels` implement the same algorithm with explicit VMEM
tiling; this module is their jnp oracle-of-record.

Two decay modes, selected by `log_decay` rank:

* **scalar** (B,T,H) — Mamba-2's per-head decay.  Intra-chunk scores use
  the pairwise difference matrix ``exp(L_i − L_j)`` (Mamba-2's "segsum"),
  which is ≤ 1 on the causal triangle → numerically exact for any decay.
* **channel** (B,T,H,K) — RWKV-6's per-channel decay.  The difference
  enters *inside* the K contraction, so the factored form
  ``(q·exp(L)) @ (k·exp(−L))ᵀ`` is used; ``exp(−L)`` grows with cumulative
  decay, so callers must bound per-step log-decay ≥ −MAX_CHANNEL_DECAY
  (the RWKV block clamps; with chunk=32 the intermediate stays ≤ e^29).

Shapes: q,k: (B,T,H,K); v: (B,T,H,V). Output (B,T,H,V).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

#: channel-mode per-step log-decay bound (see module docstring)
MAX_CHANNEL_DECAY = 0.9
DEFAULT_CHUNK = 32


def chunked_linear_recurrence(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    log_decay: jax.Array,
    chunk: int = DEFAULT_CHUNK,
    include_current: bool = True,
    bonus: jax.Array | None = None,
    initial_state: jax.Array | None = None,
    unroll: int = 1,
):
    """Returns (out (B,T,H,V), final_state (B,H,K,V)).

    ``include_current``: o_t reads S_t (Mamba) vs S_{t-1} (RWKV).
    ``bonus``: u (H, K) — RWKV's current-token term
    ``o_t += (q_t ⊙ u · k_t) v_t``.
    """
    b, t, h, kdim = q.shape
    vdim = v.shape[-1]
    scalar_decay = log_decay.ndim == 3
    t_orig = t
    if t % chunk:
        # pad to a chunk multiple: k=v=0 adds nothing to the state,
        # log_decay=0 leaves it untouched; padded outputs are sliced off
        pad = chunk - t % chunk
        padt = lambda a: jnp.pad(a, ((0, 0), (0, pad)) + ((0, 0),) * (a.ndim - 2))
        q, k, v, log_decay = padt(q), padt(k), padt(v), padt(log_decay)
        t = t + pad
    n = t // chunk

    f32 = jnp.float32
    qc = q.reshape(b, n, chunk, h, kdim).astype(f32)
    kc = k.reshape(b, n, chunk, h, kdim).astype(f32)
    vc = v.reshape(b, n, chunk, h, vdim).astype(f32)

    ii = jnp.arange(chunk)
    mask = (ii[:, None] >= ii[None, :]) if include_current else (ii[:, None] > ii[None, :])

    if scalar_decay:
        wc = log_decay.reshape(b, n, chunk, h).astype(f32)
        L = jnp.cumsum(wc, axis=2)  # (b,n,C,h)
        total = L[:, :, -1]  # (b,n,h)
        Li = L if include_current else L - wc
        # pairwise differences, ≤ 0 on the masked triangle → exp ≤ 1
        diff = Li[:, :, :, None, :] - L[:, :, None, :, :]  # (b,n,Ci,Cj,h)
        decay_ij = jnp.exp(jnp.where(mask[None, None, :, :, None], diff, -jnp.inf))
        qk = jnp.einsum("bnihk,bnjhk->bnijh", qc, kc)
        out_intra = jnp.einsum("bnijh,bnijh,bnjhv->bnihv", qk, decay_ij, vc)
        q_eff = qc * jnp.exp(Li)[..., None]
        k_carry = kc * jnp.exp(total[:, :, None] - L)[..., None]
        decay_state = total[..., None]  # broadcast over K
    else:
        wc = log_decay.reshape(b, n, chunk, h, kdim).astype(f32)
        L = jnp.cumsum(wc, axis=2)  # (b,n,C,h,K)
        total = L[:, :, -1]  # (b,n,h,K)
        Li = L if include_current else L - wc
        q_eff = qc * jnp.exp(Li)
        k_eff = kc * jnp.exp(-L)  # caller bounds decay: ≤ e^(C·MAX_CHANNEL_DECAY)
        scores = jnp.einsum("bnihk,bnjhk->bnhij", q_eff, k_eff)
        scores = jnp.where(mask[None, None, None], scores, 0.0)
        out_intra = jnp.einsum("bnhij,bnjhv->bnihv", scores, vc)
        k_carry = kc * jnp.exp(total[:, :, None] - L)
        decay_state = total  # (b,n,h,K)

    if bonus is not None:
        ub = bonus.astype(f32)  # (h, K)
        qkb = jnp.einsum("bnihk,hk,bnihk->bnih", qc, ub, kc)
        out_intra = out_intra + qkb[..., None] * vc

    chunk_state = jnp.einsum("bnjhk,bnjhv->bnhkv", k_carry, vc)

    s0 = (
        initial_state.astype(f32)
        if initial_state is not None
        else jnp.zeros((b, h, kdim, vdim), f32)
    )

    def body(state, xs):
        q_eff_n, decay_n, cs_n = xs  # (b,C,h,K), (b,h,K), (b,h,K,V)
        o_inter = jnp.einsum("bihk,bhkv->bihv", q_eff_n, state)
        state_new = state * jnp.exp(decay_n)[..., None] + cs_n
        return state_new, o_inter

    xs = (
        q_eff.transpose(1, 0, 2, 3, 4),
        jnp.broadcast_to(decay_state, (b, n, h, kdim)).transpose(1, 0, 2, 3),
        chunk_state.transpose(1, 0, 2, 3, 4),
    )
    final_state, o_inter = jax.lax.scan(body, s0, xs, unroll=unroll)
    o_inter = o_inter.transpose(1, 0, 2, 3, 4)  # (b,n,C,h,V)

    out = (out_intra + o_inter).reshape(b, t, h, vdim)[:, :t_orig]
    return out.astype(q.dtype), final_state


def recurrence_step(
    q: jax.Array,  # (B, H, K)
    k: jax.Array,
    v: jax.Array,  # (B, H, V)
    log_decay: jax.Array,  # (B, H) or (B, H, K)
    state: jax.Array,  # (B, H, K, V)
    include_current: bool = True,
    bonus: jax.Array | None = None,
):
    """Single-token decode step. Returns (out (B,H,V), new_state)."""
    f32 = jnp.float32
    qf, kf, vf = (x.astype(f32) for x in (q, k, v))
    wf = log_decay.astype(f32)
    if wf.ndim == 2:
        wf = wf[..., None]  # broadcast scalar decay over K
    kv = kf[..., :, None] * vf[..., None, :]  # (B,H,K,V)
    new_state = state.astype(f32) * jnp.exp(wf)[..., None] + kv
    read = new_state if include_current else state.astype(f32)
    out = jnp.einsum("bhk,bhkv->bhv", qf, read)
    if bonus is not None:
        qk = jnp.einsum("bhk,hk,bhk->bh", qf, bonus.astype(f32), kf)
        out = out + qk[..., None] * vf
    return out.astype(q.dtype), new_state


def naive_linear_recurrence(q, k, v, log_decay, include_current=True, bonus=None,
                            initial_state=None):
    """O(T) sequential oracle (tests compare the chunked form against this)."""
    b, t, h, kdim = q.shape
    vdim = v.shape[-1]
    s = (
        initial_state.astype(jnp.float32)
        if initial_state is not None
        else jnp.zeros((b, h, kdim, vdim), jnp.float32)
    )
    outs = []
    for i in range(t):
        o, s = recurrence_step(
            q[:, i], k[:, i], v[:, i], log_decay[:, i], s,
            include_current=include_current, bonus=bonus,
        )
        outs.append(o)
    return jnp.stack(outs, axis=1).astype(q.dtype), s
