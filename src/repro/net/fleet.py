"""FleetHead: N remote device links aggregated into one fleet view.

Builds a `SocketDevice` + unmodified `PowerSensor` per endpoint and owns
a `FleetMonitor` over them, so every fleet query — quorum power, health,
marker intervals, snapshots — works over the wire exactly as it does in
process.  On top of the monitor it adds the parts only a *networked*
fleet needs:

* **per-link health**: the monitor's healthy / stale / lost states apply
  unchanged; a link whose socket dies raises out of ``poll()`` and maps
  to ``lost`` via the monitor's ``_safe_poll`` contract (the error stays
  visible in ``poll_errors`` until the link reacquires);
* **reconnect with backoff**: ``poll()`` notices lost links and redials
  them (exponential backoff between attempts).  On reacquire the sensor's
  partial-frame residual is detached — bytes in flight at the disconnect
  are gone for good, and stitching a stale half-frame onto the new byte
  stream would desynchronise the decoder — and the stream restarts; the
  arrival-clock re-anchor then places the first new batch correctly from
  the link's fresh chunk stamps;
* **bounded buffers**: every link's receive queue is capped
  (``max_buffered_chunks``); a slow head stalls the link reader (counted
  in each device's ``backpressure_waits``) instead of dropping frames;
* **link stats**: one dict per link — endpoint, health, reconnects,
  backpressure, buffered chunks, received bytes — for dashboards and the
  `benchmarks/fleet_link.py` gate.
"""
from __future__ import annotations

import time
from typing import Mapping

from repro.stream.fleet import FleetMonitor

from .device import SocketDevice
from .link import LinkError


class FleetHead:
    """Aggregate N `DeviceServer` links into one `FleetMonitor` view."""

    def __init__(
        self,
        endpoints: Mapping[str, str],
        window_s: float = 1.0,
        ring_capacity: int = 1 << 16,
        reconnect: bool = True,
        backoff_s: float = 0.05,
        max_backoff_s: float = 2.0,
        max_buffered_chunks: int = 256,
        connect_timeout_s: float = 5.0,
        pooled: bool = True,
        **monitor_kwargs,
    ):
        from repro.core.host import PowerSensor  # lazy: mirrors stream.fleet

        self.endpoints = dict(endpoints)
        self.reconnect = bool(reconnect)
        self.backoff_s = float(backoff_s)
        self.max_backoff_s = float(max_backoff_s)
        self.max_buffered_chunks = int(max_buffered_chunks)
        self.connect_timeout_s = float(connect_timeout_s)
        self._ring_capacity = int(ring_capacity)
        self.reconnects: dict[str, int] = {name: 0 for name in self.endpoints}
        self._backoff: dict[str, float] = {}
        self._next_retry: dict[str, float] = {}
        self.monitor = FleetMonitor(window_s=window_s, **monitor_kwargs)
        self._PowerSensor = PowerSensor
        for name in self.endpoints:
            dev = self._dial(name)
            self.monitor.add(
                name, PowerSensor(dev, ring_capacity=self._ring_capacity)
            )
        if pooled:
            # the fused fleet-wide decode path: every poll drains all N
            # links' coalesced backlogs in one numpy pass (bit-identical
            # to per-device polling; see repro.stream.pool)
            self.monitor.enable_pool()

    def _dial(self, name: str) -> SocketDevice:
        return SocketDevice(
            self.endpoints[name],
            device=name,
            connect_timeout_s=self.connect_timeout_s,
            max_buffered_chunks=self.max_buffered_chunks,
        )

    # ------------------------------------------------------------ polling
    def poll(self) -> int:
        """Drain every link, then service any lost ones (reconnect path)."""
        n = self.monitor.poll_all()
        self._maintain()
        return n

    def run_for(self, seconds: float, tick_s: float = 0.001) -> int:
        """Wall-clock receive loop: poll all links every ``tick_s``."""
        total = 0
        deadline = time.monotonic() + float(seconds)
        while time.monotonic() < deadline:
            total += self.poll()
            time.sleep(tick_s)
        return total

    def _maintain(self) -> None:
        """Redial lost links, with exponential backoff per link."""
        if not self.reconnect:
            return
        errors = self.monitor.poll_errors
        if not errors:
            return
        now = time.monotonic()
        for name in errors:
            if name not in self.endpoints:
                continue
            if now < self._next_retry.get(name, 0.0):
                continue
            try:
                dev = self._dial(name)
            except (OSError, LinkError):
                backoff = self._backoff.get(name, self.backoff_s)
                self._next_retry[name] = now + backoff
                self._backoff[name] = min(backoff * 2.0, self.max_backoff_s)
                continue
            ps = self.monitor[name]
            old = ps.device
            try:
                old.close()
            except OSError:
                pass
            # bytes in flight at the disconnect are unrecoverable; a stale
            # partial frame stitched onto the fresh stream would shift the
            # decoder's packet alignment for the rest of the session
            ps.detach_residual()
            ps.device = dev
            ps.start_streaming()
            # reacquired: restart the health grace window so the link is
            # not still `lost` (old frozen frames) while the fresh stream
            # spins up — see FleetMonitor.note_attach
            self.monitor.note_attach(name)
            self.reconnects[name] += 1
            self._backoff.pop(name, None)
            self._next_retry.pop(name, None)

    # ------------------------------------------------------------ queries
    def device_health(self):
        return self.monitor.device_health()

    def fleet_power(self, window_s: float | None = None, poll: bool = True):
        reading = self.monitor.fleet_power(window_s, poll=poll)
        if poll:
            self._maintain()
        return reading

    def link_stats(self) -> dict[str, dict]:
        """Per-link transport counters + health, keyed by device name."""
        health = self.monitor.device_health()
        out: dict[str, dict] = {}
        for name in self.endpoints:
            ps = self.monitor[name]
            dev = ps.device
            out[name] = {
                "endpoint": self.endpoints[name],
                "state": health[name].state,
                "reconnects": self.reconnects[name],
                "backpressure_waits": int(
                    getattr(dev, "backpressure_waits", 0)
                ),
                "buffered_chunks": int(getattr(dev, "buffered_chunks", 0)),
                "rx_bytes": int(getattr(dev, "rx_bytes", 0)),
                "dropped_bytes": int(ps.dropped_bytes),
                "dropped_frames": int(ps.dropped_frames),
                "frames": len(ps.ring),
            }
        return out

    def __getitem__(self, name: str):
        return self.monitor[name]

    def __len__(self) -> int:
        return len(self.monitor)

    def close(self) -> None:
        for name in self.endpoints:
            ps = self.monitor[name]
            try:
                ps.stop_thread()
            except Exception:
                pass
            dev = ps.device
            close = getattr(dev, "close", None)
            if close is not None:
                try:
                    close()
                except OSError:
                    pass
