"""SocketDevice: the `VirtualDevice` transport surface over a socket.

The client end of a `DeviceServer` link.  It exposes exactly the surface
the host library already consumes — ``write`` / ``read`` / ``t_s`` /
``pending_bytes`` (plus a no-op ``advance``: time flows on the server) —
so `PowerSensor`, `FaultyTransport` and `SessionRecorder` run over the
wire unmodified.

Chunk discipline (what makes socket replay bit-identical to in-process):

* every server-side ``device.read()`` result travels as one ``DATA``
  frame and — for replayed streams — is served to the host as one
  chunk: ``read()`` never merges bytes across replay chunk boundaries,
  because the receiver's arrival-clock re-anchor shifts a *whole* poll
  batch uniformly and a chunk spanning a recorded wrap gap would be
  re-anchored wrongly.  Live (wall-clock-driven) links advertise a
  continuous byte stream in the WELCOME, and there ``read()`` *does*
  coalesce the queued backlog into one batch — decode cost then scales
  with frames, not server ticks, which is what lets one head sustain
  16 × 20 kHz links;
* ``t_s`` is the stamp of the chunk currently being served (set when the
  chunk is taken up, exactly when `ReplayDevice`'s cursor moves), so it
  vouches only for delivered data;
* ``pending_bytes`` reports the *remainder of the current chunk* only —
  queued future chunks are invisible, mirroring the in-process devices
  whose next chunk does not exist until the next ``read()``.

Reads **block** while the connect handshake is in flight (the host reads
version/config replies byte-by-byte and treats an empty read as a string
terminator) and turn non-blocking — permanently — once the first
``CMD_START_STREAM`` is written; the client tracks that by parsing the
command grammar it forwards.  (Config blocks are downloaded exactly once,
at connect; after that an empty read must mean "no frames yet", not
"wait 5 s", or every post-stop drain poll would stall.)

The receive queue is bounded: when full, the reader thread stops pulling
from the socket (kernel buffers fill, the server sees backpressure) and
the stall is counted in ``backpressure_waits`` — frames are delayed,
never dropped.
"""
from __future__ import annotations

import socket
import threading
from collections import deque

from repro.core.protocol import (
    CMD_MARKER,
    CMD_READ_CONFIG,
    CMD_START_STREAM,
    CMD_STOP_STREAM,
    CMD_VERSION,
    CMD_WRITE_CONFIG,
    CONFIG_BLOCK_SIZE,
)
from repro.obs import metrics as obs_metrics

from . import link


class SocketDevice:
    """Client transport: one remote device served by a `DeviceServer`."""

    def __init__(
        self,
        endpoint: str,
        device: str = "dev0",
        connect_timeout_s: float = 5.0,
        reply_timeout_s: float = 5.0,
        max_buffered_chunks: int = 256,
    ):
        self.endpoint = endpoint
        self.name = device
        self.reply_timeout_s = float(reply_timeout_s)
        self.max_buffered_chunks = int(max_buffered_chunks)
        self.backpressure_waits = 0  # reader stalls on the full queue
        self.rx_bytes = 0
        self.streaming = False
        self._handshake = True  # reads block until the first START_STREAM
        self._cmd_tail = bytearray()  # command-grammar parse carry-over
        self._chunks: deque[tuple[bytes, float]] = deque()
        self._cur = bytearray()  # remainder of the chunk being served
        self._t_s = 0.0
        self._eof = False
        self._error: BaseException | None = None
        self._cond = threading.Condition()
        self._stop = threading.Event()

        self._sock = link.connect(endpoint, timeout_s=connect_timeout_s)
        self._sock.sendall(link.pack_frame(link.T_HELLO, device.encode()))
        fr = link.recv_frame(self._sock)
        if fr is None:
            raise link.LinkError(f"server closed during handshake for {device!r}")
        ftype, payload = fr
        if ftype == link.T_ERR:
            raise link.LinkError(payload.decode(errors="replace"))
        if ftype != link.T_WELCOME:
            raise link.LinkError(f"expected WELCOME, got frame type {ftype}")
        # '\x00live' suffix: the served device is wall-clock driven, so its
        # byte stream is continuous and queued chunks may be coalesced into
        # one poll batch (the re-anchor stamps the batch end; the in-band
        # 10-bit timestamps place everything before it).  Replayed streams
        # never set it — their chunk boundaries carry recorded time gaps.
        self.coalesce = payload.endswith(b"\x00live")
        self._sock.settimeout(0.2)  # reader loop stays interruptible
        self._reader = threading.Thread(target=self._recv_loop, daemon=True)
        self._reader.start()

    # ------------------------------------------------------------ link reader
    def _recv_loop(self) -> None:
        # incremental framing (not recv_frame): a socket timeout mid-frame
        # must keep the partial bytes buffered, or the stream desyncs
        framer = link.Framer()
        try:
            while not self._stop.is_set():
                try:
                    data = self._sock.recv(1 << 16)
                except socket.timeout:
                    continue
                if not data:
                    if framer.pending:
                        raise link.LinkError(
                            f"server closed mid-frame to {self.name!r}"
                        )
                    raise ConnectionError(f"server closed link to {self.name!r}")
                for ftype, payload in framer.feed(data):
                    self._handle_frame(ftype, payload)
        except BaseException as exc:
            with self._cond:
                if not self._stop.is_set():
                    self._error = exc
                self._cond.notify_all()

    def _handle_frame(self, ftype: int, payload: bytes) -> None:
        if ftype == link.T_DATA:
            t_s, chunk = link.unpack_data(payload)
            self.rx_bytes += len(chunk)
            with self._cond:
                # bounded buffer: stop draining the socket instead of
                # dropping — the sender blocks, we count
                stalled = False
                while (
                    len(self._chunks) >= self.max_buffered_chunks
                    and not self._stop.is_set()
                ):
                    if not stalled:
                        stalled = True
                        self.backpressure_waits += 1
                        reg = obs_metrics.active()
                        if reg is not None:
                            reg.counter(
                                "link_backpressure_waits_total",
                                "reader stalls on a full receive queue",
                                device=self.name,
                            ).inc()
                    self._cond.wait(0.05)
                self._chunks.append((chunk, t_s))
                self._cond.notify_all()
        elif ftype in (link.T_EOF, link.T_BYE):
            with self._cond:
                self._eof = True
                self._cond.notify_all()
        elif ftype == link.T_ERR:
            raise ConnectionError(payload.decode(errors="replace"))

    # ------------------------------------------------------------ host surface
    def write(self, data: bytes) -> None:
        """Forward host command bytes; track the streaming state locally."""
        self._track_commands(data)
        if self._error is not None:
            raise self._error
        try:
            self._sock.sendall(link.pack_frame(link.T_CMD, data))
        except OSError as exc:
            self._error = exc
            raise

    def _track_commands(self, data: bytes) -> None:
        """Parse the forwarded command grammar just enough to know whether
        the host is mid-handshake (replies expected: reads must block) or
        streaming (reads must be non-blocking)."""
        buf = self._cmd_tail
        buf.extend(data)
        while buf:
            cmd = bytes(buf[:1])
            if cmd == CMD_START_STREAM:
                self.streaming = True
                self._handshake = False
                del buf[:1]
            elif cmd == CMD_STOP_STREAM:
                self.streaming = False
                del buf[:1]
            elif cmd in (CMD_VERSION,):
                del buf[:1]
            elif cmd in (CMD_READ_CONFIG, CMD_MARKER):
                if len(buf) < 2:
                    return
                del buf[:2]
            elif cmd == CMD_WRITE_CONFIG:
                if len(buf) < 2 + CONFIG_BLOCK_SIZE:
                    return
                del buf[: 2 + CONFIG_BLOCK_SIZE]
            else:
                del buf[:1]

    def read(self, max_bytes: int | None = None) -> bytes:
        with self._cond:
            if not self._cur:
                self._take_chunk(block=self._handshake)
            if self.coalesce and not self._handshake and self._chunks:
                # live link: fold the whole backlog into one poll batch so
                # decode cost scales with frames, not with server ticks
                while self._chunks and len(self._cur) < (1 << 22):
                    chunk, t_s = self._chunks.popleft()
                    self._cur.extend(chunk)
                    self._t_s = t_s
                self._cond.notify_all()  # frees a backpressured reader
            if not self._cur:
                # drained: a dead link surfaces only once delivered data
                # has been fully consumed — bytes outrun the error
                if self._error is not None:
                    raise self._error
                return b""
            if max_bytes is None or max_bytes >= len(self._cur):
                out = bytes(self._cur)
                self._cur.clear()
            else:
                out = bytes(self._cur[:max_bytes])
                del self._cur[:max_bytes]
            if max_bytes is not None and len(out) < max_bytes and self._handshake:
                # a handshake reply split across chunks: keep gathering —
                # there are no stream frames yet, so crossing chunk
                # boundaries cannot disturb the re-anchor contract
                while len(out) < max_bytes:
                    self._take_chunk(block=True)
                    if not self._cur:
                        break
                    need = max_bytes - len(out)
                    out += bytes(self._cur[:need])
                    del self._cur[:need]
            return out

    def _take_chunk(self, block: bool) -> None:
        """Pop the next queued chunk into the serving slot (cond held)."""
        if not self._chunks and block:
            deadline = self.reply_timeout_s
            while (
                not self._chunks
                and self._error is None
                and not self._eof
                and deadline > 0
            ):
                self._cond.wait(0.05)
                deadline -= 0.05
        if self._chunks:
            chunk, t_s = self._chunks.popleft()
            self._cur.extend(chunk)
            self._t_s = t_s
            self._cond.notify_all()  # frees a backpressured reader

    def read_batch(self) -> tuple[bytes, float, int]:
        """One atomic ``(data, t_s, pending_bytes)`` capture for pooled polls.

        `PooledDecoder` needs the arrival stamp and pending count that
        belong to *this* read's chunk; taking them as separate property
        reads after `read()` would race the reader thread queueing the
        next chunk.  One pass under the condition keeps the triple
        consistent — and saves two lock round-trips per device per tick.
        """
        with self._cond:
            data = self.read()
            return data, self._t_s, len(self._cur)

    def advance(self, dt_s: float) -> None:
        """No-op: a remote device's time flows on the server."""

    @property
    def t_s(self) -> float:
        """Device clock of the chunk being served (vouches for it only)."""
        return self._t_s

    @property
    def pending_bytes(self) -> int:
        """Unconsumed remainder of the *current* chunk (queued future
        chunks are invisible, mirroring the in-process transports)."""
        return len(self._cur)

    @property
    def buffered_chunks(self) -> int:
        """Chunks queued behind the current one (link-stats visibility)."""
        return len(self._chunks)

    @property
    def exhausted(self) -> bool:
        """The server signalled EOF and every delivered byte was consumed."""
        with self._cond:
            return self._eof and not self._chunks and not self._cur

    @property
    def error(self) -> BaseException | None:
        return self._error

    def close(self) -> None:
        self._stop.set()
        try:
            self._sock.sendall(link.pack_frame(link.T_BYE))
        except OSError:
            pass
        try:
            self._sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        self._sock.close()
        with self._cond:
            self._cond.notify_all()
        if self._reader.is_alive():
            self._reader.join(2.0)
