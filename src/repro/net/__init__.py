"""`repro.net` — the networked fleet control plane.

Moves the device link out of process memory: a :class:`DeviceServer`
serves any in-process `VirtualDevice` (live firmware, `ReplayDevice`,
`FaultyTransport`-wrapped, ...) over a framed TCP / Unix socket, and a
:class:`SocketDevice` client exposes the exact `VirtualDevice` transport
surface on the other end — so `PowerSensor`, `FaultyTransport` and
`SessionRecorder` work over the wire unmodified.  :class:`FleetHead`
aggregates N remote links into one `FleetMonitor` view with per-link
health, bounded buffers with backpressure accounting, and automatic
reconnect; :func:`run_plan` executes declarative measurement campaigns
with safety interlocks on top.
"""
from .device import SocketDevice
from .fleet import FleetHead
from .link import Framer, pack_frame, parse_endpoint
from .plan import Interlocks, MeasurementPlan, PlanDevice, PlanResult, run_plan
from .server import DeviceServer

__all__ = [
    "DeviceServer",
    "FleetHead",
    "Framer",
    "Interlocks",
    "MeasurementPlan",
    "PlanDevice",
    "PlanResult",
    "SocketDevice",
    "pack_frame",
    "parse_endpoint",
    "run_plan",
]
