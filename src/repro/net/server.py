"""DeviceServer: serve in-process `VirtualDevice`s over sockets.

One server owns a registry of named devices — live firmware devices,
`ReplayDevice`s, `FaultyTransport`-wrapped stacks, anything with the
``write`` / ``read`` / ``t_s`` transport surface — and serves each to at
most one connection at a time.  The connection loop

* forwards every ``CMD`` frame payload to ``device.write`` (the raw
  host→device command bytes, untouched);
* pumps ``device.read()`` results to the client as one ``DATA`` frame
  per chunk, stamped with the device clock *after* the chunk was
  produced — chunk boundaries are load-bearing (the receiver's
  arrival-clock re-anchor fires at them) and survive the wire exactly;
* optionally *drives* wall-clock devices: with ``drive=True`` a server
  clock thread advances **every** device by the elapsed wall time
  (scaled by ``real_time_factor``) whether or not a client is attached
  — a real sensor's clock does not stop when the host disconnects.
  Bytes a device emits while unserved are discarded, exactly like UART
  output nobody is reading, so a reconnecting client resumes at the
  *current* device clock instead of a stale one;
* applies slow-consumer backpressure: the outgoing queue is bounded by
  ``max_out_bytes`` and the pump *pauses reading the device* while it is
  full (counted per connection in ``backpressure_events``), so a slow
  client delays frames instead of dropping them;
* announces ``EOF`` once a replayed device reports ``exhausted``.

``drop(name)`` severs a device's active connection — the handle chaos
tests and benchmarks use to exercise the client's `lost` → reacquire
path.
"""
from __future__ import annotations

import os
import select
import socket
import tempfile
import threading
import time
from typing import Mapping

from . import link


class _Conn:
    """One client connection being served (internal bookkeeping)."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self.name: str | None = None
        self.backpressure_events = 0
        self.tx_bytes = 0
        self.out = bytearray()  # framed, not yet handed to the kernel
        self.dropped = False  # severed via DeviceServer.drop()


class DeviceServer:
    """Serve a registry of named in-process devices over one socket."""

    def __init__(
        self,
        devices: Mapping[str, object],
        endpoint: str = "tcp:127.0.0.1:0",
        tick_s: float = 0.001,
        drive: bool = False,
        real_time_factor: float = 1.0,
        max_out_bytes: int = 1 << 20,
    ):
        self.devices = dict(devices)
        self.tick_s = float(tick_s)
        self.drive = bool(drive)
        self.real_time_factor = float(real_time_factor)
        self.max_out_bytes = int(max_out_bytes)
        self._lock = threading.Lock()
        # one lock per device: the clock thread and the serving connection
        # both touch it (advance vs read/write)
        self._dev_locks = {name: threading.Lock() for name in self.devices}
        self._driving = bool(drive)
        self._busy: dict[str, _Conn] = {}
        self._conns: list[_Conn] = []
        self._threads: list[threading.Thread] = []
        self._stop = threading.Event()
        self._unix_path: str | None = None

        kind, addr = link.parse_endpoint(endpoint)
        if kind == "unix":
            path = addr[0]
            if path == "auto":
                fd, path = tempfile.mkstemp(prefix="repro-net-", suffix=".sock")
                os.close(fd)
                os.unlink(path)
            self._unix_path = path
            self._sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
            self._sock.bind(path)
            self.endpoint = f"unix:{path}"
        else:
            self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
            self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
            self._sock.bind(addr)
            host, port = self._sock.getsockname()[:2]
            self.endpoint = f"tcp:{host}:{port}"
        self._sock.listen(16)
        self._sock.settimeout(0.2)
        self._acceptor = threading.Thread(target=self._accept_loop, daemon=True)
        self._acceptor.start()
        self._driver = threading.Thread(target=self._drive_loop, daemon=True)
        self._driver.start()

    # ------------------------------------------------------------ clock
    def _drive_loop(self) -> None:
        """Advance every device by wall time while ``drive`` is set.

        Time flows here, not in the connection loops: a device keeps its
        clock (and keeps emitting, if streaming) across disconnects.
        Output produced while no connection is serving the device is
        read and discarded — unread UART bytes do not accumulate.

        Flipping ``drive`` off does *not* lose time: at fleet scale one
        sweep over every device can take a sizeable fraction of a
        second, so the sweep that observes the ``True → False`` edge
        still applies the full wall ``dt`` accrued up to that moment (a
        clock stops when it is stopped, not one tick earlier).  The
        ``driving`` property stays ``True`` until that catch-up sweep
        has finished.
        """
        last_wall = time.monotonic()
        driving = self.drive
        while not self._stop.is_set():
            time.sleep(self.tick_s)
            now = time.monotonic()
            dt = (now - last_wall) * self.real_time_factor
            last_wall = now
            want = self.drive
            if not want and not driving:
                self._driving = False
                continue
            if dt > 0:
                for name, dev in self.devices.items():
                    with self._dev_locks[name]:
                        # busy check under the device lock: a claim that
                        # happened-before this acquire is visible, so we
                        # never discard a served client's reply bytes
                        with self._lock:
                            served = name in self._busy
                        dev.advance(dt)
                        if not served:
                            while dev.read():
                                pass
            driving = want
            self._driving = want

    # ------------------------------------------------------------ accept
    def _accept_loop(self) -> None:
        while not self._stop.is_set():
            try:
                sock, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            if sock.family == socket.AF_INET:
                sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _Conn(sock)
            with self._lock:
                self._conns.append(conn)
                t = threading.Thread(
                    target=self._serve_conn, args=(conn,), daemon=True
                )
                self._threads.append(t)
            t.start()

    # ------------------------------------------------------------ one link
    def _claim(self, conn: _Conn, name: str) -> object | None:
        with self._lock:
            dev = self.devices.get(name)
            if dev is None:
                self._send_err(conn, f"unknown device {name!r}")
                return None
            if name in self._busy:
                self._send_err(conn, f"device {name!r} is busy")
                return None
            self._busy[name] = conn
            conn.name = name
            return dev

    @staticmethod
    def _send_err(conn: _Conn, msg: str) -> None:
        try:
            conn.sock.sendall(link.pack_frame(link.T_ERR, msg.encode()))
        except OSError:
            pass

    def _serve_conn(self, conn: _Conn) -> None:
        sock = conn.sock
        framer = link.Framer()
        out = conn.out  # shared so stats() can report the pending depth
        dev = None
        dev_lock = None
        eof_sent = False
        paused = False
        try:
            sock.setblocking(False)
            while not self._stop.is_set() and not conn.dropped:
                try:
                    r, w, _ = select.select(
                        [sock], [sock] if out else [], [], self.tick_s
                    )
                except (OSError, ValueError):
                    return
                if r:
                    try:
                        data = sock.recv(1 << 16)
                    except (BlockingIOError, InterruptedError):
                        data = None
                    except OSError:
                        return
                    else:
                        if not data:
                            return  # peer closed
                    for ftype, payload in framer.feed(data or b""):
                        if ftype == link.T_HELLO:
                            dev = self._claim(conn, payload.decode())
                            if dev is None:
                                return
                            dev_lock = self._dev_locks[conn.name]
                            # a driven (live) device's byte stream is
                            # continuous, so the client may coalesce
                            # chunks; replayed chunk boundaries are
                            # semantic (recorded gaps) and must survive
                            welcome = payload + (
                                b"\x00live" if self.drive else b""
                            )
                            out += link.pack_frame(link.T_WELCOME, welcome)
                        elif ftype == link.T_CMD and dev is not None:
                            with dev_lock:
                                dev.write(payload)
                        elif ftype == link.T_BYE:
                            return
                if dev is None:
                    continue
                # pump chunks — pausing, not dropping, when the client
                # (or the wire) cannot keep up
                if len(out) >= self.max_out_bytes:
                    if not paused:
                        paused = True
                        conn.backpressure_events += 1
                else:
                    paused = False
                    with dev_lock:
                        while len(out) < self.max_out_bytes:
                            chunk = dev.read()
                            if not chunk:
                                break
                            out += link.pack_data(
                                float(getattr(dev, "t_s", 0.0)), chunk
                            )
                        if not eof_sent and getattr(dev, "exhausted", False):
                            out += link.pack_frame(link.T_EOF)
                            eof_sent = True
                if out:
                    try:
                        n = sock.send(memoryview(out)[: 1 << 18])
                    except (BlockingIOError, InterruptedError):
                        n = 0
                    except OSError:
                        return
                    if n:
                        conn.tx_bytes += n
                        del out[:n]
        finally:
            with self._lock:
                if conn.name is not None and self._busy.get(conn.name) is conn:
                    del self._busy[conn.name]
                if conn in self._conns:
                    self._conns.remove(conn)
            try:
                sock.close()
            except OSError:
                pass

    # ------------------------------------------------------------ control
    def drop(self, name: str) -> bool:
        """Sever the active connection serving ``name`` (chaos handle)."""
        with self._lock:
            conn = self._busy.get(name)
        if conn is None:
            return False
        conn.dropped = True
        try:
            conn.sock.shutdown(socket.SHUT_RDWR)
        except OSError:
            pass
        return True

    def stats(self) -> dict[str, dict]:
        """Per-active-link counters, keyed by device name."""
        with self._lock:
            return {
                name: {
                    "backpressure_events": conn.backpressure_events,
                    "tx_bytes": conn.tx_bytes,
                    "pending_out_bytes": len(conn.out),
                }
                for name, conn in self._busy.items()
            }

    @property
    def driving(self) -> bool:
        """True while the clock thread still owes the devices drive time.

        Stays set after ``drive = False`` until the catch-up sweep that
        observed the edge has applied the final wall ``dt``.
        """
        return self._driving

    def serving(self, name: str) -> bool:
        with self._lock:
            return name in self._busy

    def close(self) -> None:
        self._stop.set()
        try:
            self._sock.close()
        except OSError:
            pass
        with self._lock:
            conns = list(self._conns)
        for conn in conns:
            try:
                conn.sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
        for t in list(self._threads):
            t.join(2.0)
        if self._acceptor.is_alive():
            self._acceptor.join(2.0)
        if self._driver.is_alive():
            self._driver.join(2.0)
        if self._unix_path and os.path.exists(self._unix_path):
            os.unlink(self._unix_path)
