"""Declarative measurement plans with safety interlocks.

A :class:`MeasurementPlan` is a JSON-serialisable description of one
measurement campaign — which devices (remote endpoints and/or local
virtual rigs), for how long, at what aggregation window, under which
fault scenario — plus :class:`Interlocks`, the hard safety envelope:

``vmax_v``
    any device reporting an instantaneous rail voltage above this trips
    an immediate abort (an over-voltage rail is a hardware event, not a
    data-quality question);
``max_hours``
    a wall-clock ceiling on the whole campaign, applied regardless of
    the plan's nominal duration (runaway campaigns stop themselves);
``abort_on_anomaly``
    wires the fleet to `repro.obs.SignatureWatchdog`: the first
    anomalous power segment (unknown signature, or a known kernel
    running at deviant power) aborts the run.  Requires a signature
    library — refusing to run is better than pretending to watch.

:func:`run_plan` executes a plan against a `FleetHead`: remote devices
dial their endpoints; virtual devices are served through an in-process
loopback `DeviceServer` (``drive=True``), so a campaign exercises the
*identical* socket path whether the rig is across the lab or in-process.
A plan's ``scenario`` names a `repro.faultlab` shipped scenario injected
on top of the (socket) transports — chaos campaigns over the wire.
"""
from __future__ import annotations

import json
import time
from dataclasses import asdict, dataclass, field

from .fleet import FleetHead
from .server import DeviceServer


@dataclass(frozen=True)
class Interlocks:
    """The safety envelope a running campaign must stay inside."""

    vmax_v: float | None = None
    max_hours: float | None = None
    abort_on_anomaly: bool = False

    @classmethod
    def from_dict(cls, d: dict) -> "Interlocks":
        return cls(
            vmax_v=d.get("vmax_v"),
            max_hours=d.get("max_hours"),
            abort_on_anomaly=bool(d.get("abort_on_anomaly", False)),
        )


@dataclass(frozen=True)
class PlanDevice:
    """One fleet member: a remote endpoint, or a local virtual rig."""

    name: str
    endpoint: str | None = None  # remote receiver; None → virtual rig
    module: str = "pcie8pin-20a"
    load: str = "constant"  # 'constant' | 'square' (virtual rigs only)
    volts: float = 12.0
    amps: float = 3.0

    @classmethod
    def from_dict(cls, d: dict) -> "PlanDevice":
        return cls(
            name=d["name"],
            endpoint=d.get("endpoint"),
            module=d.get("module", "pcie8pin-20a"),
            load=d.get("load", "constant"),
            volts=float(d.get("volts", 12.0)),
            amps=float(d.get("amps", 3.0)),
        )

    def make_load(self):
        from repro.core import ConstantLoad, SquareWaveLoad

        if self.load == "constant":
            return ConstantLoad(self.volts, self.amps)
        if self.load == "square":
            return SquareWaveLoad(
                volts=self.volts, amps_lo=0.3 * self.amps, amps_hi=self.amps
            )
        raise ValueError(f"unknown virtual load kind {self.load!r}")


@dataclass(frozen=True)
class MeasurementPlan:
    """A declarative, JSON-round-trippable measurement campaign."""

    name: str
    devices: tuple[PlanDevice, ...]
    duration_s: float = 1.0
    window_s: float = 0.25
    tick_s: float = 0.01
    interlocks: Interlocks = field(default_factory=Interlocks)
    scenario: str | None = None  # a repro.faultlab shipped scenario name

    def to_dict(self) -> dict:
        return asdict(self)

    def to_json(self, indent: int = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_dict(cls, d: dict) -> "MeasurementPlan":
        return cls(
            name=d["name"],
            devices=tuple(PlanDevice.from_dict(x) for x in d.get("devices", ())),
            duration_s=float(d.get("duration_s", 1.0)),
            window_s=float(d.get("window_s", 0.25)),
            tick_s=float(d.get("tick_s", 0.01)),
            interlocks=Interlocks.from_dict(d.get("interlocks", {})),
            scenario=d.get("scenario"),
        )

    @classmethod
    def from_json(cls, text: str) -> "MeasurementPlan":
        return cls.from_dict(json.loads(text))


@dataclass
class PlanResult:
    """What one campaign run produced, and whether it finished."""

    plan: str
    completed: bool
    aborted: bool
    reason: str | None
    elapsed_s: float
    n_readings: int
    mean_power_w: float
    peak_power_w: float
    n_anomalies: int
    health: dict[str, str]
    link_stats: dict[str, dict]

    def to_dict(self) -> dict:
        return asdict(self)


def run_plan(
    plan: MeasurementPlan,
    watchdog_library=None,
    real_time_factor: float = 1.0,
    on_reading=None,
) -> PlanResult:
    """Execute a plan: dial/serve the fleet, measure, enforce interlocks.

    ``watchdog_library`` (a `repro.attrib.SignatureLibrary`) is required
    when the plan sets ``abort_on_anomaly`` — the watchdog cannot judge
    power segments against nothing, and a silently-disarmed interlock is
    worse than an error.  ``on_reading(elapsed_s, reading)`` is called
    once per tick with the live `FleetPowerReading`.
    """
    locks = plan.interlocks
    if locks.abort_on_anomaly and watchdog_library is None:
        raise ValueError(
            "plan sets abort_on_anomaly but no signature library was given"
        )
    if not plan.devices:
        raise ValueError(f"plan {plan.name!r} has no devices")

    from repro.core import PowerSensor, make_device  # noqa: F401  (loads below)

    # virtual rigs are served through an in-process loopback server so the
    # campaign runs the identical socket path as a remote fleet
    server: DeviceServer | None = None
    virtual = [d for d in plan.devices if d.endpoint is None]
    endpoints: dict[str, str] = {}
    if virtual:
        devices = {
            d.name: make_device([d.module], d.make_load(), seed=i * 1009)
            for i, d in enumerate(virtual)
        }
        server = DeviceServer(
            devices, drive=True, real_time_factor=real_time_factor
        )
        for d in virtual:
            endpoints[d.name] = server.endpoint
    for d in plan.devices:
        if d.endpoint is not None:
            endpoints[d.name] = d.endpoint

    head = FleetHead(endpoints, window_s=plan.window_s)
    watchdog = None
    if locks.abort_on_anomaly:
        from repro.obs.watch import SignatureWatchdog

        watchdog = SignatureWatchdog(head.monitor, watchdog_library)
    if plan.scenario is not None:
        from repro.faultlab import inject, shipped_scenarios

        scenarios = shipped_scenarios(plan.duration_s)
        if plan.scenario not in scenarios:
            head.close()
            if server is not None:
                server.close()
            raise ValueError(
                f"unknown scenario {plan.scenario!r}; "
                f"shipped: {sorted(scenarios)}"
            )
        inject(head.monitor, scenarios[plan.scenario])

    aborted = False
    reason: str | None = None
    powers: list[float] = []
    n_anomalies = 0
    t0 = time.monotonic()
    last = t0
    try:
        while True:
            time.sleep(plan.tick_s)
            now = time.monotonic()
            dt, last = now - last, now
            elapsed = now - t0
            # drive fault windows (and any wall-clock transport shims);
            # a plain SocketDevice ignores this — time flows on the server
            for name in endpoints:
                head[name].device.advance(dt)
            head.poll()
            reading = head.fleet_power(plan.window_s, poll=False)
            if not reading.stale:
                powers.append(reading.power_w)
            if on_reading is not None:
                on_reading(elapsed, reading)
            # ---- interlocks ----
            if locks.vmax_v is not None:
                for name in endpoints:
                    volts = head[name].read().instant_volts
                    worst = max(volts) if volts else 0.0
                    if worst > locks.vmax_v:
                        aborted = True
                        reason = (
                            f"vmax interlock: {name} at {worst:.3f} V "
                            f"> {locks.vmax_v:.3f} V"
                        )
                        break
            if not aborted and locks.max_hours is not None:
                if elapsed > locks.max_hours * 3600.0:
                    aborted = True
                    reason = f"max_hours interlock: ran {elapsed:.1f} s"
            if not aborted and watchdog is not None:
                fresh = watchdog.check()
                n_anomalies += len(fresh)
                if fresh:
                    a = fresh[0]
                    aborted = True
                    reason = (
                        f"anomaly interlock: {a.kind} on {a.device} "
                        f"at {a.t0_s:.4f}s ({a.mean_w:.2f} W)"
                    )
            if aborted or elapsed >= plan.duration_s:
                break
        elapsed = time.monotonic() - t0
        health = {n: h.state for n, h in head.device_health().items()}
        links = head.link_stats()
    finally:
        head.close()
        if server is not None:
            server.close()
    return PlanResult(
        plan=plan.name,
        completed=not aborted,
        aborted=aborted,
        reason=reason,
        elapsed_s=elapsed,
        n_readings=len(powers),
        mean_power_w=sum(powers) / len(powers) if powers else 0.0,
        peak_power_w=max(powers) if powers else 0.0,
        n_anomalies=n_anomalies,
        health=health,
        link_stats=links,
    )
