"""Wire framing for the device link: typed length-prefixed frames.

The socket carries the *existing* device-link byte protocol — the 2-byte
sensor packets of `repro.core.protocol` — untouched inside ``DATA``
frames, plus a thin control vocabulary around it.  Each frame is

    ``<u8 type> <u32le payload_len> <payload>``

and a ``DATA`` payload is ``<f64le device_t_s> <raw stream bytes>``: the
server stamps every chunk with the serving device's clock *after* the
chunk was produced, so the client can mirror the in-process transport
contract exactly — ``t_s`` vouches only for delivered bytes, and chunk
boundaries (which the receiver's arrival-clock re-anchor keys on) survive
the wire bit-for-bit.

:class:`Framer` is the incremental parser both ends share: feed it
arbitrary byte dribbles (partial sends, coalesced sends) and complete
frames fall out in order.
"""
from __future__ import annotations

import socket
import struct

HDR = struct.Struct("<BI")
T_S = struct.Struct("<d")

#: frame types
T_HELLO = 1  #: client → server: payload = requested device name (utf-8)
T_WELCOME = 2  #: server → client: name being served (+ b"\0live" if driven)
T_CMD = 3  #: client → server: raw host→device command bytes
T_DATA = 4  #: server → client: f64le device t_s + raw stream bytes
T_EOF = 5  #: server → client: a replayed device is exhausted
T_BYE = 6  #: either side: orderly shutdown of the link
T_ERR = 7  #: server → client: utf-8 error message, link closes after

#: a frame bigger than this is a protocol violation, not a big read
MAX_PAYLOAD = 1 << 24


class LinkError(ConnectionError):
    """The peer violated the link framing or refused the handshake."""


def pack_frame(ftype: int, payload: bytes = b"") -> bytes:
    return HDR.pack(ftype, len(payload)) + payload


def pack_data(t_s: float, chunk: bytes) -> bytes:
    """One stream chunk stamped with the device clock that vouches for it."""
    return pack_frame(T_DATA, T_S.pack(t_s) + chunk)


def unpack_data(payload: bytes) -> tuple[float, bytes]:
    if len(payload) < T_S.size:
        raise LinkError(f"DATA frame too short: {len(payload)} bytes")
    return T_S.unpack_from(payload)[0], payload[T_S.size :]


class Framer:
    """Incremental frame parser: bytes in (any split), frames out."""

    def __init__(self):
        self._buf = bytearray()

    def feed(self, data: bytes) -> list[tuple[int, bytes]]:
        """Append raw bytes; return every frame completed by them."""
        self._buf.extend(data)
        out: list[tuple[int, bytes]] = []
        while len(self._buf) >= HDR.size:
            ftype, n = HDR.unpack_from(self._buf)
            if n > MAX_PAYLOAD:
                raise LinkError(f"frame payload {n} exceeds {MAX_PAYLOAD}")
            if len(self._buf) < HDR.size + n:
                break
            payload = bytes(self._buf[HDR.size : HDR.size + n])
            del self._buf[: HDR.size + n]
            out.append((ftype, payload))
        return out

    @property
    def pending(self) -> int:
        """Bytes buffered awaiting the rest of a frame."""
        return len(self._buf)


def recv_exact(sock: socket.socket, n: int) -> bytes | None:
    """Read exactly ``n`` bytes, riding out partial recvs; None on EOF."""
    buf = bytearray()
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None if not buf else _eof_mid_frame(len(buf), n)
        buf.extend(chunk)
    return bytes(buf)


def _eof_mid_frame(got: int, want: int) -> bytes:
    raise LinkError(f"peer closed mid-frame ({got}/{want} bytes)")


def recv_frame(sock: socket.socket) -> tuple[int, bytes] | None:
    """Blocking read of one whole frame; None on orderly EOF."""
    hdr = recv_exact(sock, HDR.size)
    if hdr is None:
        return None
    ftype, n = HDR.unpack(hdr)
    if n > MAX_PAYLOAD:
        raise LinkError(f"frame payload {n} exceeds {MAX_PAYLOAD}")
    payload = recv_exact(sock, n) if n else b""
    if payload is None:
        raise LinkError("peer closed between header and payload")
    return ftype, payload


# --------------------------------------------------------------- endpoints
def parse_endpoint(endpoint: str) -> tuple[str, tuple]:
    """``tcp:host:port`` or ``unix:/path`` → (family, connect address)."""
    if endpoint.startswith("unix:"):
        return "unix", (endpoint[len("unix:") :],)
    if endpoint.startswith("tcp:"):
        host, _, port = endpoint[len("tcp:") :].rpartition(":")
        if not host or not port:
            raise ValueError(f"malformed tcp endpoint {endpoint!r}")
        return "tcp", (host, int(port))
    raise ValueError(f"endpoint must be tcp:host:port or unix:/path, got {endpoint!r}")


def connect(endpoint: str, timeout_s: float = 5.0) -> socket.socket:
    """Open a client socket to a `DeviceServer` endpoint."""
    kind, addr = parse_endpoint(endpoint)
    if kind == "unix":
        sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    else:
        sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    sock.settimeout(timeout_s)
    try:
        sock.connect(addr if kind == "tcp" else addr[0])
    except OSError:
        sock.close()
        raise
    return sock
