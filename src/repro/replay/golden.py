"""Golden-corpus harness: shipped scenarios, recorded once, gated forever.

Each :class:`GoldenScenario` deterministically records a short live
session — a clean serve wave, a governor load step, and two fault-lab
chaos runs — into a trace archive, and defines the metric set that pins
its behaviour: per-device attributed energy and coverage, per-wave
marker energies, fleet window power, and the injected `FaultLedger`
ground truth.  Governor control-quality numbers (time-over-cap, settle
time, switch count) are **live-only** metrics: they score the actuation
log, which a sensor archive cannot reproduce, so they are pinned at
regeneration time and re-checked whenever the corpus is regenerated.

The committed corpus (``tests/goldens/``) is mini — every archive plus
the tolerance manifest must stay under :data:`MAX_CORPUS_BYTES` total —
and is enforced two ways:

* the ``replay`` test tier replays each committed archive through the
  real receiver and asserts every (non-live-only) metric against the
  committed tolerance manifest;
* ``tools/regen_goldens.py --check`` re-records every scenario live and
  fails when the fresh session drifts outside the manifest tolerances —
  stale goldens fail CI instead of rotting.

`write_goldens` additionally enforces the subsystem's round-trip
invariant at regeneration time: live metrics and replayed metrics must
agree within :data:`ROUNDTRIP_RTOL` for every scenario, chaos included.
"""
from __future__ import annotations

import json
import math
from dataclasses import dataclass
from pathlib import Path
from typing import Callable

from .archive import TraceArchive
from .recorder import SessionRecorder
from .replay import ReplayFleet

MANIFEST_NAME = "manifest.json"
MANIFEST_VERSION = 1
#: the whole committed corpus (archives + manifest) must stay mini
MAX_CORPUS_BYTES = 200_000
#: live ↔ replay agreement required of every scenario at regen time
ROUNDTRIP_RTOL = 1e-9


class GoldenError(RuntimeError):
    """A golden archive/manifest is missing, malformed, or stale."""


# --------------------------------------------------------------------------
# metrics
# --------------------------------------------------------------------------
def session_metrics(
    monitor,
    wave_char: str | None = None,
    window_s: float = 0.05,
    since: dict[str, float] | None = None,
) -> dict[str, float]:
    """The sensor-derived metric set, computable live *or* on replay.

    Everything here reads the same ring/markers surface on both sides of
    the round trip: whole-span attributed energy (gap-aware, so chaos
    coverage shows up as a pinned number), per-wave marker energies,
    trailing-window power, and the fleet windowed sum.  ``since`` clips
    each device's span to the recorded start (`archive_since`) so live
    rings holding pre-recording history (calibration) score the same
    frames the archive holds.
    """
    from repro.attrib import KernelSpan, attribute_block, marker_spans

    out: dict[str, float] = {}
    for name in monitor.names:
        ps = monitor[name]
        t0 = (since or {}).get(name)
        read = (
            (lambda ps=ps: ps.ring.latest())
            if t0 is None
            else (lambda ps=ps, t0=t0: ps.ring.window(t0, math.inf))
        )
        block = monitor._locked_ring_read(ps, read)
        out[f"{name}.n_frames"] = float(len(block))
        if len(block) >= 2:
            led = attribute_block(
                block,
                [KernelSpan("session", float(block.times_s[0]), float(block.times_s[-1]))],
            )
            ent = led.entries.get("session")
            if ent is not None:
                out[f"{name}.energy_j"] = ent.energy_j
                out[f"{name}.coverage"] = ent.coverage_frac
                out[f"{name}.peak_w"] = ent.peak_w
            out[f"{name}.tail_mean_w"] = monitor._locked_ring_read(
                ps, lambda ps=ps: ps.ring.tail_mean_watts(window_s)
            )
            if wave_char is not None:
                waves = attribute_block(block, marker_spans(ps.markers, wave_char))
                for wave_name, went in sorted(waves.entries.items()):
                    out[f"{name}.{wave_name}_j"] = went.energy_j
    out["fleet.window_power_w"] = monitor.window_power_w(window_s, poll=False)
    return out


def archive_since(archive: TraceArchive) -> dict[str, float]:
    """Per-device recorded-span start times, for `session_metrics`."""
    return {
        name: float(tr.times_s[0])
        for name, tr in archive.devices.items()
        if len(tr)
    }


def ledger_metrics(archive: TraceArchive) -> dict[str, float]:
    """Injected ground truth pinned from the archived `FaultLedger`s."""
    out: dict[str, float] = {}
    for name, tr in archive.devices.items():
        led = tr.fault_ledger
        if led is None:
            continue
        out[f"{name}.delivered_frac"] = led.delivered_frac
        out[f"{name}.dropped_s"] = led.dropped_s
        out[f"{name}.corrupted_bytes"] = float(led.corrupted_bytes)
        out[f"{name}.lost_writes"] = float(led.lost_writes)
    return out


# --------------------------------------------------------------------------
# the shipped scenarios
# --------------------------------------------------------------------------
@dataclass(frozen=True)
class GoldenScenario:
    name: str
    description: str
    wave_char: str | None
    window_s: float
    record: Callable[[], tuple[TraceArchive, dict[str, float]]]


def _record_serve_wave() -> tuple[TraceArchive, dict[str, float]]:
    """A clean serving session: 6 marker-bracketed request waves."""
    from repro.core import ConstantLoad, SquareWaveLoad
    from repro.stream import make_virtual_fleet

    fleet = make_virtual_fleet(
        [
            ConstantLoad(12.0, 3.2),
            SquareWaveLoad(amps_lo=2.0, amps_hi=6.5, freq_hz=120.0),
        ],
        window_s=0.05,
        seed=101,
        ring_capacity=1 << 13,
    )
    rec = SessionRecorder(fleet)
    for _ in range(6):
        fleet.mark_all("W")
        fleet.run_for(0.025, chunk_s=0.005)
        rec.capture()
    fleet.mark_all("W")  # closing bracket of the last wave
    fleet.run_for(0.005, chunk_s=0.005)
    archive = rec.finalize(extra_meta={"scenario": "serve-wave"})
    metrics = session_metrics(fleet, "W", 0.05)
    fleet.close()
    return archive, metrics


def _record_serve_churn() -> tuple[TraceArchive, dict[str, float]]:
    """Continuous-batching churn: a live `ContinuousBatch` drives the load.

    The churn workload (staggered arrivals onto 3 slots, mixed gen_lens,
    completions freeing slots mid-run) is executed first to derive the
    per-step slot-occupancy staircase; a `TraceLoad` device then plays
    ``20 + 12·occupancy`` watts over the recorded session, with one
    ``"I"`` marker occurrence bracketing every step interval.  The
    settled billing totals are pinned as live-only metrics, so the golden
    gates step-interval attribution *and* the billing ledger — not just
    the sensor stream.
    """
    import numpy as np

    from repro.attrib import attribute_intervals
    from repro.core import ConstantLoad, TraceLoad
    from repro.sched import ContinuousBatch, EnergyPricer, Request, get_policy
    from repro.stream import make_virtual_fleet

    step_dt = 0.005
    n_slots, n_requests = 3, 7
    batch = ContinuousBatch(
        EnergyPricer(j_per_token=(20.0 + 12.0 * n_slots) * step_dt / n_slots),
        get_policy("throughput-max"),
        n_slots=n_slots,
    )
    occupancy: list[int] = []
    t, step, next_rid = 0.0, 0, 0
    while True:
        while next_rid < n_requests and step >= next_rid * 2:
            batch.submit(Request(
                rid=next_rid, client=f"c{next_rid % 2}",
                gen_len=2 + (next_rid % 3), arrival_s=t,
            ))
            next_rid += 1
        batch.admit(t)
        if not batch.live_rids:
            if next_rid < n_requests:
                step = next_rid * 2
                continue
            break
        for _ in range(2):  # two decode steps per marker-bracketed interval
            if not batch.live_rids:
                break
            occupancy.append(batch.n_active)
            batch.step_billing(1)
            step += 1
            t += step_dt
        batch.seal_interval()

    # near-vertical staircase edges: each step holds its watts for the
    # whole step and jumps 10 µs before the next one
    times, watts = [], []
    for i, occ in enumerate(occupancy):
        times += [i * step_dt, (i + 1) * step_dt - 1e-5]
        watts += [20.0 + 12.0 * occ] * 2
    fleet = make_virtual_fleet(
        [
            TraceLoad(times_s=np.array(times), watts=np.array(watts), volts=12.0),
            ConstantLoad(12.0, 2.5),
        ],
        window_s=0.02,
        seed=103,
        ring_capacity=1 << 13,
    )
    rec = SessionRecorder(fleet)
    for iv in batch.intervals:
        fleet.mark_all("I")
        fleet.run_for(iv.steps * step_dt, chunk_s=0.005)
        rec.capture()
    fleet.mark_all("I")  # closing bracket of the last interval
    fleet.run_for(0.005, chunk_s=0.005)
    archive = rec.finalize(extra_meta={"scenario": "serve-churn"})

    # settle the billing ledger from the measured marker windows
    energies: dict[int, float] = {}
    for name in fleet.names:
        ps = fleet[name]
        block = fleet._locked_ring_read(ps, lambda ps=ps: ps.ring.latest())
        for k, e in attribute_intervals(block, ps.markers, "I").items():
            energies[k] = energies.get(k, 0.0) + e.energy_j
    released = 0
    for k in list(batch.unsettled()):
        if energies.get(k, 0.0) > 0.0:
            batch.settle_interval(k, energies[k])
        else:
            batch.release_interval(k)
            released += 1
    metrics = session_metrics(fleet, "I", 0.02)
    # live-only: the billing ledger is the scheduler's, not the sensors'
    metrics["live.billed_j"] = batch.billed_j
    metrics["live.overhead_j"] = batch.overhead_j
    metrics["live.spent_j"] = batch.spent_j
    metrics["live.released_intervals"] = float(released)
    metrics["live.finished"] = float(len(batch.finished))
    fleet.close()
    return archive, metrics


def _record_governor_step() -> tuple[TraceArchive, dict[str, float]]:
    """A power-cap governor riding out a load step on a calibrated plant."""
    from repro.sched import (
        GovernorConfig,
        OperatingGrid,
        PowerCapGovernor,
        VirtualPlant,
        decode_cost_of_batch,
        settle_time,
        time_over_cap,
    )

    cost = decode_cost_of_batch(2.0 * 20e6, 2.0 * 20e6, tokens_per_slot_step=4)
    grid = OperatingGrid(cost, n_layers=2, batches=(1, 2, 4, 8), tokens_per_slot_step=4)
    plant = VirtualPlant(grid, n_devices=2, seed=31, calibrate_samples=2000)
    cap_w = 0.72 * 2 * grid.max_watts
    cfg = GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0)
    rec = SessionRecorder(plant.fleet)
    gov = PowerCapGovernor(plant, cfg)
    duration_s, t_step_s = 0.2, 0.06
    gov.run(duration_s, demand_of_t=lambda t: 0 if t < t_step_s else 8)
    archive = rec.finalize(
        extra_meta={"scenario": "governor-step", "cap_w": cap_w}
    )
    metrics = session_metrics(plant.fleet, None, 0.005, since=archive_since(archive))
    # live-only: the plant's ground-truth actuation log does not replay
    metrics["live.time_over_cap"] = time_over_cap(
        plant.log, cap_w, 0.0, duration_s, tol=0.02
    )
    metrics["live.settle_s"] = settle_time(
        plant.log, cap_w, t_step_s, duration_s, tol=0.02
    )
    metrics["live.n_switches"] = float(gov.n_switches)
    plant.close()
    return archive, metrics


def _record_chaos(scenario_key: str, seed: int):
    """One fault-lab scenario injected into a recorded 2-device fleet."""
    from repro.core import ConstantLoad
    from repro.faultlab import inject, shipped_scenarios
    from repro.stream import make_virtual_fleet

    scen = shipped_scenarios(0.3)[scenario_key]
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 3.0), ConstantLoad(12.0, 4.2)],
        window_s=0.02,
        seed=seed,
        ring_capacity=1 << 14,
    )
    inject(fleet, scen)
    rec = SessionRecorder(fleet)
    t, next_mark = 0.0, 0.0
    while t < 0.3 - 1e-12:
        if t >= next_mark - 1e-12:
            fleet.mark_all("C")
            next_mark += 0.05
        fleet.advance(0.002)
        t += 0.002
        rec.capture()
    fleet.poll_all()
    archive = rec.finalize(extra_meta={"scenario": scenario_key})
    metrics = session_metrics(fleet, "C", 0.02)
    metrics.update(ledger_metrics(archive))
    fleet.close()
    return archive, metrics


SCENARIOS: dict[str, GoldenScenario] = {
    "serve-wave": GoldenScenario(
        name="serve-wave",
        description="clean serving session, 6 marker-bracketed waves",
        wave_char="W",
        window_s=0.05,
        record=_record_serve_wave,
    ),
    "serve-churn": GoldenScenario(
        name="serve-churn",
        description="continuous-batching churn: occupancy staircase with "
                    "per-interval markers and a settled billing ledger",
        wave_char="I",
        window_s=0.02,
        record=_record_serve_churn,
    ),
    "governor-step": GoldenScenario(
        name="governor-step",
        description="power-cap governor load step on a calibrated plant",
        wave_char=None,
        window_s=0.005,
        record=_record_governor_step,
    ),
    "chaos-dropout": GoldenScenario(
        name="chaos-dropout",
        description="faultlab dropout-burst with periodic markers",
        wave_char="C",
        window_s=0.02,
        record=lambda: _record_chaos("dropout-burst", 71),
    ),
    "chaos-disconnect": GoldenScenario(
        name="chaos-disconnect",
        description="faultlab disconnect-cycle with periodic markers",
        wave_char="C",
        window_s=0.02,
        record=lambda: _record_chaos("disconnect-cycle", 72),
    ),
}


# --------------------------------------------------------------------------
# replay / check / write
# --------------------------------------------------------------------------
def replay_session_metrics(
    scenario: GoldenScenario, archive: TraceArchive
) -> dict[str, float]:
    """Max-speed replay through the real receiver → the same metric set."""
    fleet = ReplayFleet(archive, window_s=scenario.window_s)
    try:
        fleet.drain()
        metrics = session_metrics(
            fleet.monitor,
            scenario.wave_char,
            scenario.window_s,
            since=archive_since(archive),
        )
    finally:
        fleet.close()
    metrics.update(ledger_metrics(archive))
    return metrics


def _tolerance(key: str) -> tuple[float, float]:
    """(rtol, atol) for one manifest metric.

    Sensor/ledger metrics replay deterministically — 1e-9 relative is
    the round-trip contract.  Live-only governor numbers are threshold
    metrics (a settle time jumps by whole control ticks), so they get
    physical tolerances instead.
    """
    if key.startswith("live."):
        atol = {
            "live.time_over_cap": 0.01,
            "live.settle_s": 2e-3,
            "live.n_switches": 1.0,
        }.get(key, 1e-6)
        return 1e-6, atol
    return ROUNDTRIP_RTOL, 1e-12


def _within(value: float, expected: float, rtol: float, atol: float) -> bool:
    if math.isnan(value) or math.isnan(expected):
        return False
    return abs(value - expected) <= atol + rtol * abs(expected)


def write_goldens(out_dir, names=None) -> dict:
    """Record every scenario, verify the round trip, commit the corpus.

    Raises :class:`GoldenError` if any scenario's live and replayed
    metrics disagree beyond :data:`ROUNDTRIP_RTOL`, or if the resulting
    corpus exceeds :data:`MAX_CORPUS_BYTES`.
    """
    out_dir = Path(out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    # partial regeneration merges into the committed manifest — a
    # --scenario run must never drop the other scenarios' pins
    if (out_dir / MANIFEST_NAME).exists():
        manifest = load_manifest(out_dir)
    else:
        manifest = {"version": MANIFEST_VERSION, "scenarios": {}}
    for name in names or SCENARIOS:
        scenario = SCENARIOS[name]
        archive, live = scenario.record()
        replayed = replay_session_metrics(scenario, archive)
        for key, rep_v in replayed.items():
            live_v = live.get(key)
            if live_v is not None and not _within(
                rep_v, live_v, ROUNDTRIP_RTOL, 1e-12
            ):
                raise GoldenError(
                    f"{name}: round-trip violation on {key}: "
                    f"live {live_v!r} vs replay {rep_v!r}"
                )
        archive_name = f"{name}.npz"
        archive.save(out_dir / archive_name)
        metrics = dict(replayed)
        metrics.update({k: v for k, v in live.items() if k.startswith("live.")})
        manifest["scenarios"][name] = {
            "archive": archive_name,
            "description": scenario.description,
            "metrics": {
                k: {"value": v, "rtol": _tolerance(k)[0], "atol": _tolerance(k)[1]}
                for k, v in sorted(metrics.items())
            },
        }
    (out_dir / MANIFEST_NAME).write_text(json.dumps(manifest, indent=1) + "\n")
    total = corpus_bytes(out_dir)
    if total > MAX_CORPUS_BYTES:
        raise GoldenError(
            f"golden corpus is {total} bytes — exceeds the "
            f"{MAX_CORPUS_BYTES}-byte mini-corpus budget"
        )
    return manifest


def corpus_bytes(golden_dir) -> int:
    golden_dir = Path(golden_dir)
    return sum(
        p.stat().st_size
        for p in list(golden_dir.glob("*.npz")) + [golden_dir / MANIFEST_NAME]
        if p.exists()
    )


def load_manifest(golden_dir) -> dict:
    path = Path(golden_dir) / MANIFEST_NAME
    if not path.exists():
        raise GoldenError(f"no golden manifest at {path}")
    manifest = json.loads(path.read_text())
    if manifest.get("version") != MANIFEST_VERSION:
        raise GoldenError(
            f"unsupported golden manifest version {manifest.get('version')!r}"
        )
    return manifest


def _compare(
    name: str, got: dict[str, float], entry: dict, skip_live: bool
) -> list[str]:
    errors = []
    for key, spec in entry["metrics"].items():
        if skip_live and key.startswith("live."):
            continue
        if key not in got:
            errors.append(f"{name}: metric {key} missing from session")
            continue
        if not _within(got[key], spec["value"], spec["rtol"], spec["atol"]):
            errors.append(
                f"{name}: {key} = {got[key]!r}, manifest pins "
                f"{spec['value']!r} (rtol {spec['rtol']:g}, atol {spec['atol']:g})"
            )
    extra = {
        k
        for k in got
        if k not in entry["metrics"] and not (skip_live and k.startswith("live."))
    }
    for key in sorted(extra):
        errors.append(f"{name}: unpinned metric {key} — regenerate the manifest")
    return errors


def check_goldens(golden_dir, names=None, rerecord: bool = False) -> list[str]:
    """Verify the committed corpus; returns a list of violations.

    Always: replay every committed archive through the real receiver and
    compare against the manifest.  With ``rerecord=True`` (the
    ``regen_goldens.py --check`` mode) each scenario is also re-recorded
    live and compared — catching goldens gone stale relative to the code
    that produced them, live-only governor metrics included.
    """
    golden_dir = Path(golden_dir)
    manifest = load_manifest(golden_dir)
    errors: list[str] = []
    wanted = set(names) if names is not None else None
    for name, entry in manifest["scenarios"].items():
        if wanted is not None and name not in wanted:
            continue
        scenario = SCENARIOS.get(name)
        if scenario is None:
            errors.append(f"{name}: manifest names an unknown scenario")
            continue
        path = golden_dir / entry["archive"]
        if not path.exists():
            errors.append(f"{name}: missing golden archive {path.name}")
            continue
        archive = TraceArchive.load(path)
        replayed = replay_session_metrics(scenario, archive)
        errors.extend(_compare(f"{name} (replay)", replayed, entry, skip_live=True))
        if rerecord:
            fresh_archive, fresh_live = scenario.record()
            fresh = replay_session_metrics(scenario, fresh_archive)
            fresh.update(
                {k: v for k, v in fresh_live.items() if k.startswith("live.")}
            )
            errors.extend(
                _compare(f"{name} (re-record)", fresh, entry, skip_live=False)
            )
    missing = set(names or SCENARIOS) - set(manifest["scenarios"])
    for name in sorted(missing):
        errors.append(f"{name}: scenario not in the committed manifest")
    total = corpus_bytes(golden_dir)
    if total > MAX_CORPUS_BYTES:
        errors.append(
            f"golden corpus is {total} bytes > {MAX_CORPUS_BYTES}-byte budget"
        )
    return errors


def default_golden_dir() -> Path:
    """``tests/goldens`` relative to the repo root this package lives in."""
    return Path(__file__).resolve().parents[3] / "tests" / "goldens"
