"""`repro.replay` — deterministic trace record/replay + golden corpus.

Turns live 20 kHz sensor sessions into replayable artifacts:

* `archive`  — versioned npz trace archives (`TraceArchive` /
  `DeviceTrace`): ADC codes + integer-µs times + markers + config /
  calibration blocks + optional faultlab `FaultLedger`, with loud
  `ArchiveError` validation — never garbage frames;
* `recorder` — `SessionRecorder`: taps `PowerSensor` / `FleetMonitor`
  ring buffers incrementally without perturbing the receive path;
* `replay`   — `ReplayDevice` (the `VirtualDevice` transport surface
  over an archive, played through the *real* host receiver at wall-clock
  or max speed), `replay_sensor`, and `ReplayFleet` (a reconstructed
  `FleetMonitor` session);
* `golden`   — the golden-corpus harness: shipped scenarios recorded
  once, metrics checked against committed tolerance manifests
  (`tools/regen_goldens.py` regenerates them).

The round-trip contract (enforced by the replay test tier and the
golden CI job): record → archive → replay reproduces per-kernel
attributed energy and fleet window power within 1e-9 relative for clean
*and* chaos sessions.
"""
from .archive import (
    ARCHIVE_VERSION,
    ArchiveError,
    DeviceTrace,
    TraceArchive,
    encode_device,
    load_bytes,
    save_bytes,
)
from .recorder import SessionRecorder
from .replay import ReplayDevice, ReplayFleet, replay_sensor

__all__ = [
    "ARCHIVE_VERSION",
    "ArchiveError",
    "DeviceTrace",
    "TraceArchive",
    "encode_device",
    "load_bytes",
    "save_bytes",
    "SessionRecorder",
    "ReplayDevice",
    "ReplayFleet",
    "replay_sensor",
]
