"""SessionRecorder: tap live ring buffers into a trace archive.

The recorder sits entirely on the consumer side of the 20 kHz pipeline:
it never touches the transport, never adds work to `PowerSensor.poll`,
and reads rings the same way every other consumer does — incremental
``ring.since(seq)`` blocks taken under the receiver lock.  ``capture()``
is called opportunistically (per request wave in `launch.serve`, per
step in `launch.train`, per drive chunk in the golden harness); anything
the ring evicted between captures is counted in ``lost_frames`` rather
than silently missing from the archive.

``finalize()`` encodes everything captured so far into a
:class:`~repro.replay.archive.TraceArchive` — codes + integer-µs times
via the shared conversion tables, the marker stream, each device's
config blocks (calibration included) and firmware version, and the
transport's `FaultLedger` when the device was wrapped by the fault
injector.
"""
from __future__ import annotations

from typing import Mapping

import numpy as np

from .archive import TraceArchive, encode_device


class _DeviceTap:
    """Incremental capture state for one sensor's ring."""

    def __init__(self, sensor, include_history: bool):
        self.sensor = sensor
        ring = sensor.ring
        self.seq = ring.head - len(ring) if include_history else ring.head
        self.seq0: int | None = None
        self.lost_frames = 0
        self.blocks: list = []
        self.n_frames = 0

    def capture(self) -> int:
        ring = self.sensor.ring
        lock = getattr(self.sensor, "_lock", None)
        if lock is not None:
            with lock:
                block = ring.since(self.seq)
        else:
            block = ring.since(self.seq)
        if len(block) == 0:
            return 0
        if block.seq0 > self.seq:
            # the ring evicted frames between captures: loud, not missing
            self.lost_frames += block.seq0 - self.seq
        if self.seq0 is None:
            self.seq0 = block.seq0
        self.seq = block.seq0 + len(block)
        self.blocks.append(block)
        self.n_frames += len(block)
        return len(block)


class SessionRecorder:
    """Record one or many `PowerSensor` sessions into a `TraceArchive`.

    ``source`` may be a `repro.stream.FleetMonitor`, a mapping of
    ``name -> PowerSensor``, or a single `PowerSensor` (recorded under
    ``name``).  By default recording starts at the *current* ring head —
    pass ``include_history=True`` to also archive whatever the rings
    still retain from before the recorder attached.
    """

    def __init__(
        self,
        source,
        name: str = "dev0",
        include_history: bool = False,
        meta: dict | None = None,
    ):
        self.meta = dict(meta or {})
        sensors: Mapping[str, object]
        if hasattr(source, "names") and hasattr(source, "__getitem__"):
            sensors = {n: source[n] for n in source.names}
            self.meta.setdefault("window_s", float(getattr(source, "window_s", 1.0)))
        elif isinstance(source, Mapping):
            sensors = dict(source)
        else:
            sensors = {name: source}
        if not sensors:
            raise ValueError("nothing to record: empty source")
        self._taps = {n: _DeviceTap(ps, include_history) for n, ps in sensors.items()}

    @property
    def frames_recorded(self) -> int:
        return sum(t.n_frames for t in self._taps.values())

    @property
    def lost_frames(self) -> int:
        return sum(t.lost_frames for t in self._taps.values())

    def capture(self) -> int:
        """Copy every device's new ring frames; returns frames captured."""
        return sum(tap.capture() for tap in self._taps.values())

    def finalize(self, extra_meta: dict | None = None) -> TraceArchive:
        """One last capture, then encode the whole session to an archive."""
        self.capture()
        archive = TraceArchive(meta={**self.meta, **(extra_meta or {})})
        for dev_name, tap in self._taps.items():
            ps = tap.sensor
            if tap.blocks:
                times_s = np.concatenate([b.times_s for b in tap.blocks])
                volts = np.concatenate([b.volts for b in tap.blocks])
                amps = np.concatenate([b.amps for b in tap.blocks])
            else:
                n_pairs = ps.ring.n_pairs
                times_s = np.empty(0)
                volts = np.empty((0, n_pairs))
                amps = np.empty((0, n_pairs))
            t0 = times_s[0] if times_s.size else np.inf
            t1 = times_s[-1] if times_s.size else -np.inf
            markers = [(c, t) for c, t in ps.markers if t0 <= t <= t1]
            n_outside = len(ps.markers) - len(markers)
            ledger = getattr(ps.device, "ledger", None)
            trace = encode_device(
                name=dev_name,
                configs=list(ps.configs),
                fw_version=getattr(ps, "version", ""),
                times_s=times_s,
                volts=volts,
                amps=amps,
                markers=markers,
                seq0=tap.seq0 or 0,
                lost_frames=tap.lost_frames,
                fault_ledger=ledger,
            )
            trace.dropped_markers += n_outside
            archive.add(trace)
        return archive

    def save(self, path, extra_meta: dict | None = None) -> TraceArchive:
        """``finalize()`` and write the archive to ``path``."""
        archive = self.finalize(extra_meta)
        archive.save(path)
        return archive
