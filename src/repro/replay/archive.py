"""Versioned binary trace archive: `FrameRing` contents, captured for keeps.

An archive is an npz container (``np.savez_compressed``) holding one
:class:`DeviceTrace` per recorded device plus a JSON header.  The design
goal is **bit-identical replay**: a recorded 20 kHz session must play back
through the *real* host receiver (`repro.replay.replay.ReplayDevice`) and
decode to exactly the floats the live run produced.  Two choices make
that possible:

* frames are stored as **10-bit ADC codes**, not physical floats.  Every
  value the receiver ever puts in a ring is ``a·code + b`` for an integer
  code and the per-channel affine tables of `protocol.conversion_tables`
  (forward-filled frames repeat the previous code) — so the inversion
  ``code = round((phys − b) / a)`` is exact, and re-applying the identical
  multiply-add on decode *or* on replay-through-the-receiver reproduces
  the float bit for bit.  Values that do not invert exactly (possible
  only for synthetic rings that never went through the receiver) are
  clamped to the nearest code and counted loudly in ``n_quantised``;
* frame times are stored as **integer microseconds** — exactly the
  device-timestamp reconstruction the receiver computes — so the replay
  transport can re-emit the original 10-bit timestamp chain and the
  receiver's wrap arithmetic (including its arrival-clock re-anchoring
  across delivery gaps) lands every frame back on its recorded time.

The header is versioned; anything short of a fully consistent archive —
truncated file, corrupted member, unknown version, out-of-range codes,
non-monotonic times, markers pointing at missing frames — raises
:class:`ArchiveError` instead of yielding garbage frames.

Sensor config blocks (which carry the calibration tables: ``offset_cal``
/ ``gain_cal`` per channel) and the firmware version string ride along
per device, so replay rebuilds the exact conversion the live host used.
A `repro.faultlab` :class:`~repro.faultlab.transport.FaultLedger` is
embedded per device when the recorded transport carried one.
"""
from __future__ import annotations

import io
import json
import zipfile
import zlib
from dataclasses import dataclass, field
from struct import error as struct_error

#: every low-level failure mode of reading a damaged npz member
_READ_ERRORS = (
    OSError,
    ValueError,
    EOFError,
    zipfile.BadZipFile,
    zlib.error,
    struct_error,
)

import numpy as np

from repro.core.protocol import (
    ADC_MAX,
    CONFIG_BLOCK_SIZE,
    SensorConfigBlock,
    conversion_tables,
)
from repro.stream.ring import FrameBlock

ARCHIVE_MAGIC = "ps3-trace"
ARCHIVE_VERSION = 1

#: pairs per device — mirrors `repro.core.host.MAX_PAIRS` without importing
#: the host (the archive layer must stay import-light for tools)
N_CHANNELS = 8
MAX_PAIRS = N_CHANNELS // 2


class ArchiveError(ValueError):
    """A trace archive could not be read/validated.  Always loud, never
    silently-degraded frames; carries the archive version when known."""

    def __init__(self, message: str, version: int | None = None):
        if version is not None:
            message = f"{message} (archive version {version})"
        super().__init__(message)
        self.version = version


@dataclass
class DeviceTrace:
    """One device's recorded session: frames, markers, config, ledger."""

    name: str
    configs: list[SensorConfigBlock]
    fw_version: str
    times_us: np.ndarray  # (n,) int64, the receiver's reconstructed clock
    codes: np.ndarray  # (n, n_enabled) uint16 ADC codes, one column per channel
    channel_ids: np.ndarray  # (n_enabled,) int64 sensor ids of the columns
    marker_chars: str = ""
    marker_times_us: np.ndarray = field(default_factory=lambda: np.empty(0, np.int64))
    seq0: int = 0  # ring sequence number of the first recorded frame
    lost_frames: int = 0  # frames evicted between recorder captures
    n_quantised: int = 0  # values that did not invert to a code exactly
    n_time_quantised: int = 0  # times that were not integer microseconds
    dropped_markers: int = 0  # marker events outside the recorded span
    fault_ledger: object | None = None  # repro.faultlab FaultLedger, if any

    def __len__(self) -> int:
        return int(self.times_us.size)

    @property
    def times_s(self) -> np.ndarray:
        # identical arithmetic to the receiver's `times / 1e6`
        return self.times_us / 1e6

    @property
    def markers(self) -> list[tuple[str, float]]:
        """The `PowerSensor.markers` view of the recorded marker stream."""
        t = self.marker_times_us / 1e6
        return list(zip(self.marker_chars, t.tolist()))

    @property
    def marker_frames(self) -> np.ndarray:
        """Frame index each marker bit rode on (validated at load time)."""
        return np.searchsorted(self.times_us, self.marker_times_us)

    def decode(self) -> FrameBlock:
        """Vectorised decode to a chronological `FrameBlock` (copies).

        Applies the exact receiver conversion (``codes · a + b`` per
        channel column) so a decoded archive equals the live ring bit for
        bit; ``watts`` is recomputed as ``volts · amps``, again matching
        the receiver.
        """
        n = len(self)
        lin_a, lin_b, _en, is_volt = conversion_tables(self.configs)
        volts = np.zeros((n, MAX_PAIRS))
        amps = np.zeros((n, MAX_PAIRS))
        codes = self.codes.astype(np.int64)
        for j, sid in enumerate(self.channel_ids.tolist()):
            col = codes[:, j] * lin_a[sid] + lin_b[sid]
            (volts if is_volt[sid] else amps)[:, sid >> 1] = col
        return FrameBlock(
            seq0=self.seq0,
            times_s=self.times_s,
            volts=volts,
            amps=amps,
            watts=volts * amps,
        )

    def to_ring(self, capacity: int | None = None):
        """Materialise a `FrameRing` holding the whole recorded session."""
        from repro.stream.ring import FrameRing

        block = self.decode()
        ring = FrameRing(capacity or max(len(self), 1), MAX_PAIRS)
        ring.append(block.times_s, block.volts, block.amps, block.watts)
        return ring


def encode_device(
    name: str,
    configs: list[SensorConfigBlock],
    fw_version: str,
    times_s: np.ndarray,
    volts: np.ndarray,
    amps: np.ndarray,
    markers: list[tuple[str, float]] | None = None,
    seq0: int = 0,
    lost_frames: int = 0,
    fault_ledger: object | None = None,
) -> DeviceTrace:
    """Vectorised encode of decoded frames back to codes + integer µs.

    The inverse of the receiver's affine conversion, per enabled channel.
    Inversions that do not reproduce the input float exactly are clamped
    to the nearest code and counted (``n_quantised`` / ``n_time_quantised``
    / ``dropped_markers``) — a lossy encode is always visible, never
    silent.
    """
    times_s = np.asarray(times_s, dtype=np.float64)
    volts = np.asarray(volts, dtype=np.float64)
    amps = np.asarray(amps, dtype=np.float64)
    n = times_s.size
    lin_a, lin_b, enabled, is_volt = conversion_tables(configs)
    ch_ids = np.flatnonzero(enabled)

    times_us = np.round(times_s * 1e6).astype(np.int64)
    n_time_quantised = int(np.count_nonzero(times_us / 1e6 != times_s))

    codes = np.zeros((n, ch_ids.size), dtype=np.uint16)
    n_quantised = 0
    for j, sid in enumerate(ch_ids.tolist()):
        phys = (volts if is_volt[sid] else amps)[:, sid >> 1]
        a, b = lin_a[sid], lin_b[sid]
        if a == 0.0:
            raw = np.zeros(n)
        else:
            raw = (phys - b) / a
        col = np.clip(np.round(raw), 0, ADC_MAX).astype(np.int64)
        n_quantised += int(np.count_nonzero(col * a + b != phys))
        codes[:, j] = col.astype(np.uint16)

    mk_chars: list[str] = []
    mk_times: list[int] = []
    dropped_markers = 0
    for c, t in markers or []:
        t_us = int(round(t * 1e6))
        i = int(np.searchsorted(times_us, t_us))
        if i < n and times_us[i] == t_us and (t_us / 1e6) == t:
            mk_chars.append(c[0])
            mk_times.append(t_us)
        else:
            # marker outside the recorded span (evicted before the first
            # capture) or off the frame grid: counted, not fabricated
            dropped_markers += 1

    return DeviceTrace(
        name=name,
        configs=list(configs),
        fw_version=fw_version,
        times_us=times_us,
        codes=codes,
        channel_ids=ch_ids.astype(np.int64),
        marker_chars="".join(mk_chars),
        marker_times_us=np.asarray(mk_times, dtype=np.int64),
        seq0=int(seq0),
        lost_frames=int(lost_frames),
        n_quantised=n_quantised,
        n_time_quantised=n_time_quantised,
        dropped_markers=dropped_markers,
        fault_ledger=fault_ledger,
    )


# --------------------------------------------------------------------------
# the archive container
# --------------------------------------------------------------------------
@dataclass
class TraceArchive:
    """A multi-device recorded session, save/load-able as one npz file."""

    devices: dict[str, DeviceTrace] = field(default_factory=dict)
    #: free-form session metadata (monitor window_s, launcher args, ...)
    meta: dict = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.devices)

    @property
    def n_frames(self) -> int:
        return sum(len(tr) for tr in self.devices.values())

    def add(self, trace: DeviceTrace) -> None:
        if trace.name in self.devices:
            raise ValueError(f"duplicate device {trace.name!r} in archive")
        self.devices[trace.name] = trace

    # ------------------------------------------------------------------ save
    def save(self, path_or_file) -> None:
        header: dict = {
            "magic": ARCHIVE_MAGIC,
            "version": ARCHIVE_VERSION,
            "meta": self.meta,
            "devices": [],
        }
        arrays: dict[str, np.ndarray] = {}
        for i, (name, tr) in enumerate(self.devices.items()):
            ledger = tr.fault_ledger
            header["devices"].append(
                {
                    "name": name,
                    "key": f"d{i}",
                    "fw_version": tr.fw_version,
                    "seq0": tr.seq0,
                    "lost_frames": tr.lost_frames,
                    "n_quantised": tr.n_quantised,
                    "n_time_quantised": tr.n_time_quantised,
                    "dropped_markers": tr.dropped_markers,
                    "marker_chars": tr.marker_chars,
                    "fault_ledger": (
                        ledger.to_json_dict() if ledger is not None else None
                    ),
                }
            )
            arrays[f"d{i}.times_us"] = tr.times_us
            arrays[f"d{i}.codes"] = tr.codes
            arrays[f"d{i}.channel_ids"] = tr.channel_ids
            arrays[f"d{i}.marker_times_us"] = tr.marker_times_us
            arrays[f"d{i}.config"] = np.frombuffer(
                b"".join(blk.pack() for blk in tr.configs), dtype=np.uint8
            ).reshape(len(tr.configs), CONFIG_BLOCK_SIZE)
        arrays["header"] = np.asarray(json.dumps(header))
        np.savez_compressed(path_or_file, **arrays)

    # ------------------------------------------------------------------ load
    @classmethod
    def load(cls, path_or_file) -> "TraceArchive":
        try:
            data = np.load(path_or_file, allow_pickle=False)
        except _READ_ERRORS as exc:
            raise ArchiveError(f"unreadable trace archive: {exc}") from exc
        if not hasattr(data, "files"):  # a bare .npy array, not an npz
            raise ArchiveError("not an npz container — not a ps3 trace archive")
        with data:
            return cls._from_npz(data)

    @classmethod
    def _from_npz(cls, data) -> "TraceArchive":
        if "header" not in data.files:
            raise ArchiveError("missing archive header — not a ps3 trace archive")
        try:
            header = json.loads(str(data["header"][()]))
        except _READ_ERRORS as exc:
            raise ArchiveError(f"corrupt archive header: {exc}") from exc
        if not isinstance(header, dict) or header.get("magic") != ARCHIVE_MAGIC:
            raise ArchiveError("bad magic — not a ps3 trace archive")
        version = header.get("version")
        if version != ARCHIVE_VERSION:
            raise ArchiveError(
                f"unsupported trace archive version {version!r} "
                f"(this reader supports version {ARCHIVE_VERSION})",
                version=version if isinstance(version, int) else None,
            )
        out = cls(meta=dict(header.get("meta", {})))
        from repro.faultlab.transport import FaultLedger

        for dev in header.get("devices", []):
            key, name = dev["key"], dev["name"]
            try:
                trace = cls._load_device(data, key, dev, FaultLedger)
            except ArchiveError:
                raise
            except KeyError as exc:
                raise ArchiveError(
                    f"device {name!r}: missing archive member {exc}", version
                ) from exc
            except _READ_ERRORS as exc:
                raise ArchiveError(
                    f"device {name!r}: corrupt archive member: {exc}", version
                ) from exc
            _validate_trace(trace, version)
            out.add(trace)
        return out

    @staticmethod
    def _load_device(data, key: str, dev: dict, FaultLedger) -> "DeviceTrace":
        times_us = data[f"{key}.times_us"]
        codes = data[f"{key}.codes"]
        channel_ids = data[f"{key}.channel_ids"]
        marker_times_us = data[f"{key}.marker_times_us"]
        config_raw = data[f"{key}.config"]
        name = dev["name"]
        ledger_d = dev.get("fault_ledger")
        return DeviceTrace(
            name=name,
            configs=[
                SensorConfigBlock.unpack(row.tobytes()) for row in config_raw
            ],
            fw_version=str(dev.get("fw_version", "")),
            times_us=times_us.astype(np.int64),
            codes=codes.astype(np.uint16),
            channel_ids=channel_ids.astype(np.int64),
            marker_chars=str(dev.get("marker_chars", "")),
            marker_times_us=marker_times_us.astype(np.int64),
            seq0=int(dev.get("seq0", 0)),
            lost_frames=int(dev.get("lost_frames", 0)),
            n_quantised=int(dev.get("n_quantised", 0)),
            n_time_quantised=int(dev.get("n_time_quantised", 0)),
            dropped_markers=int(dev.get("dropped_markers", 0)),
            fault_ledger=(
                FaultLedger.from_json_dict(ledger_d)
                if ledger_d is not None
                else None
            ),
        )


def _validate_trace(tr: DeviceTrace, version: int) -> None:
    """Consistency checks — a corrupt archive fails here, loudly."""
    n = tr.times_us.size
    if tr.times_us.ndim != 1 or tr.codes.ndim != 2:
        raise ArchiveError(f"device {tr.name!r}: malformed frame arrays", version)
    if tr.codes.shape != (n, tr.channel_ids.size):
        raise ArchiveError(
            f"device {tr.name!r}: codes shape {tr.codes.shape} does not match "
            f"{n} frames × {tr.channel_ids.size} channels",
            version,
        )
    if len(tr.configs) != N_CHANNELS:
        raise ArchiveError(
            f"device {tr.name!r}: expected {N_CHANNELS} sensor config blocks, "
            f"got {len(tr.configs)}",
            version,
        )
    if tr.channel_ids.size and (
        tr.channel_ids.min() < 0 or tr.channel_ids.max() >= N_CHANNELS
    ):
        raise ArchiveError(f"device {tr.name!r}: channel id out of range", version)
    if np.any(tr.codes > ADC_MAX):
        raise ArchiveError(
            f"device {tr.name!r}: ADC code above {ADC_MAX} — corrupt frames",
            version,
        )
    if n > 1 and np.any(np.diff(tr.times_us) <= 0):
        raise ArchiveError(
            f"device {tr.name!r}: non-monotonic frame times — corrupt clock",
            version,
        )
    if len(tr.marker_chars) != tr.marker_times_us.size:
        raise ArchiveError(
            f"device {tr.name!r}: marker chars/times length mismatch", version
        )
    if tr.marker_times_us.size:
        if n == 0:
            raise ArchiveError(
                f"device {tr.name!r}: markers present but no frames recorded",
                version,
            )
        idx = np.searchsorted(tr.times_us, tr.marker_times_us)
        ok = (idx < n) & (tr.times_us[np.minimum(idx, n - 1)] == tr.marker_times_us)
        if not bool(np.all(ok)):
            raise ArchiveError(
                f"device {tr.name!r}: marker time not on a recorded frame",
                version,
            )


def save_bytes(archive: TraceArchive) -> bytes:
    """The archive as npz bytes (tests, in-memory round-trips)."""
    buf = io.BytesIO()
    archive.save(buf)
    return buf.getvalue()


def load_bytes(raw: bytes) -> TraceArchive:
    return TraceArchive.load(io.BytesIO(raw))
