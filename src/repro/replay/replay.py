"""ReplayDevice / ReplayFleet: archives back through the *real* receiver.

A :class:`ReplayDevice` implements the `VirtualDevice` transport surface
(``write`` / ``read`` / ``advance`` / ``t_s`` / ``pending_bytes``) over a
recorded :class:`~repro.replay.archive.DeviceTrace`: it answers the
connect handshake from the archived firmware version + config blocks,
then re-emits the recorded frames as wire packets — so the bytes flow
through the unmodified `PowerSensor` receiver, exercising decode, frame
assembly, conversion, ring append and marker pairing exactly as a live
device would.

Bit-identical playback falls out of three invariants:

* codes are archived, so the receiver's ``code · a + b`` reproduces each
  recorded float exactly;
* the emitted 10-bit timestamps are ``times_us & 0x3FF`` — the same
  chain the live device produced — and chunks never span a **wrap gap**
  (a recorded inter-frame step ≥ 1024 µs, i.e. anywhere the live clock
  reconstruction re-anchored): each gap-crossing chunk starts a fresh
  ``read()`` whose ``t_s`` equals its last frame's recorded time, so the
  receiver's arrival-clock wrap correction lands the chunk back on the
  recorded times exactly;
* recorded marker bits ride sensor-0 packets of their original frames,
  and `PowerSensor.expect_markers` (seeded by `ReplayFleet` /
  :func:`replay_sensor`) pairs them with their original chars.

Two speeds: **max speed** (default) makes every frame available
immediately — each ``poll()`` drains one gap-delimited chunk — while
``realtime=True`` gates frame release on ``advance()``, so existing
drivers (`FleetMonitor.advance`, governor loops) pace the session at its
recorded rate.  ``t_s`` always vouches only for frames already
delivered; with paced multi-device replay, fleet staleness during a
recorded dropout is still visible because healthy devices keep the
fleet's ``now`` moving.
"""
from __future__ import annotations

import numpy as np

from repro.core import protocol
from repro.core.protocol import (
    CMD_MARKER,
    CMD_READ_CONFIG,
    CMD_START_STREAM,
    CMD_STOP_STREAM,
    CMD_VERSION,
    CMD_WRITE_CONFIG,
    CONFIG_BLOCK_SIZE,
    TIMESTAMP_SENSOR_ID,
)

from .archive import DeviceTrace, TraceArchive

#: the 10-bit device-timestamp wrap period: any recorded inter-frame step
#: this long or longer crossed at least one whole wrap and needs the
#: receiver's arrival-clock re-anchor — chunks must break there
WRAP_US = 1024


class ReplayDevice:
    """Serve one recorded `DeviceTrace` over the wire-transport surface."""

    def __init__(
        self,
        trace: DeviceTrace,
        realtime: bool = False,
        chunk_frames: int | None = None,
    ):
        self.trace = trace
        self.realtime = bool(realtime)
        self.chunk_frames = chunk_frames
        self.streaming = False
        n = len(trace)
        self._times_us = trace.times_us
        # chunk boundaries: frame 0, plus every frame following a wrap gap
        if n > 1:
            gap_starts = 1 + np.flatnonzero(np.diff(self._times_us) >= WRAP_US)
        else:
            gap_starts = np.empty(0, dtype=np.int64)
        self._breaks = np.concatenate([[0], gap_starts, [n]]).astype(np.int64)
        # marker bookkeeping (validated against the frame grid at load)
        self._marker_frames = trace.marker_frames
        self._ch_ids = trace.channel_ids
        self._ch0_col = (
            int(np.flatnonzero(self._ch_ids == 0)[0]) + 1
            if 0 in self._ch_ids
            else None
        )
        self._cursor = 0  # next frame to encode
        self._clock_us = float(self._times_us[0]) if n else 0.0
        self._ctrl = bytearray()  # handshake replies
        self._buf = bytearray()  # encoded frames awaiting (size-capped) reads
        self._cmd_buf = bytearray()
        self._preloaded: list[tuple[bytes, int]] | None = None

    # ------------------------------------------------------------ transport
    @property
    def t_s(self) -> float:
        """Recorded time of the newest frame handed to the host.

        The receiver anchors its wrap correction to this clock, so it
        must never run ahead of delivered data — a clock past the last
        delivered frame would fabricate extra 1024 µs wraps.
        """
        if self._cursor > 0:
            return float(self._times_us[self._cursor - 1]) / 1e6
        return self._clock_us / 1e6

    @property
    def pending_bytes(self) -> int:
        """Encoded-but-unread bytes (only size-capped reads leave any)."""
        return len(self._buf)

    @property
    def exhausted(self) -> bool:
        """Every recorded frame has been handed to the host."""
        return (
            self._cursor >= len(self.trace)
            and not self._buf
            and not self._ctrl
        )

    def write(self, data: bytes) -> None:
        """Host commands: the handshake subset a receiver actually sends."""
        buf = self._cmd_buf
        buf.extend(data)
        while buf:
            cmd = bytes(buf[:1])
            if cmd == CMD_START_STREAM:
                self.streaming = True
                del buf[:1]
            elif cmd == CMD_STOP_STREAM:
                self.streaming = False
                del buf[:1]
            elif cmd == CMD_VERSION:
                self._ctrl.extend(self.trace.fw_version.encode() + b"\0")
                del buf[:1]
            elif cmd == CMD_READ_CONFIG:
                if len(buf) < 2:
                    return
                sid = buf[1]
                if sid < len(self.trace.configs):
                    self._ctrl.extend(self.trace.configs[sid].pack())
                del buf[:2]
            elif cmd == CMD_MARKER:
                if len(buf) < 2:
                    return
                # replayed streams carry their recorded marker bits; live
                # marks during replay have no frame to ride on — ignored
                del buf[:2]
            elif cmd == CMD_WRITE_CONFIG:
                if len(buf) < 2 + CONFIG_BLOCK_SIZE:
                    return
                # a recording's conversion is frozen; the whole payload
                # must still be consumed or its bytes re-parse as commands
                del buf[: 2 + CONFIG_BLOCK_SIZE]
            else:  # reboot / unknown: no-op on a recording
                del buf[:1]

    def advance(self, dt_s: float) -> None:
        """Move the replay clock (releases frames in realtime mode)."""
        self._clock_us += dt_s * 1e6

    def release_all(self) -> None:
        """Release every remaining frame (ends realtime pacing)."""
        if len(self.trace):
            self._clock_us = max(self._clock_us, float(self._times_us[-1]) + 1.0)

    def read(self, max_bytes: int | None = None) -> bytes:
        if self._ctrl:
            return self._take(self._ctrl, max_bytes)
        if not self._buf:
            self._refill()
        return self._take(self._buf, max_bytes)

    # ------------------------------------------------------------ internals
    @staticmethod
    def _take(buf: bytearray, max_bytes: int | None) -> bytes:
        if max_bytes is None or max_bytes >= len(buf):
            out = bytes(buf)
            buf.clear()
            return out
        out = bytes(buf[:max_bytes])
        del buf[:max_bytes]
        return out

    def _released_end(self) -> int:
        if not self.realtime:
            return len(self.trace)
        return int(np.searchsorted(self._times_us, self._clock_us, side="right"))

    def _refill(self) -> None:
        """Encode the next chunk: up to the next wrap gap, never across."""
        if not self.streaming or self._cursor >= len(self.trace):
            return
        if self._preloaded is not None:
            if self._preloaded:
                raw, end = self._preloaded.pop(0)
                self._buf.extend(raw)
                self._cursor = end
            return
        lo = self._cursor
        seg_end = int(self._breaks[np.searchsorted(self._breaks, lo, side="right")])
        hi = min(seg_end, self._released_end())
        if self.chunk_frames is not None:
            hi = min(hi, lo + int(self.chunk_frames))
        if hi <= lo:
            return
        self._buf.extend(self._encode(lo, hi))
        self._cursor = hi

    def preload(self) -> int:
        """Pre-encode every remaining chunk (benchmarks: isolates the
        receiver path from encode cost).  Returns total preloaded bytes."""
        chunks: list = []
        saved = self._cursor
        while self._cursor < len(self.trace):
            lo = self._cursor
            seg_end = int(
                self._breaks[np.searchsorted(self._breaks, lo, side="right")]
            )
            hi = seg_end
            if self.chunk_frames is not None:
                hi = min(hi, lo + int(self.chunk_frames))
            chunks.append((self._encode(lo, hi), hi))
            self._cursor = hi
        self._cursor = saved
        self._preloaded = chunks
        return sum(len(c) for c, _ in chunks)

    def _encode(self, lo: int, hi: int) -> bytes:
        """Vectorised wire encoding of frames [lo, hi): per frame one
        timestamp packet + one packet per recorded channel, plus recorded
        marker bits on sensor-0 packets (inserted bare when ch0 is not a
        recorded column, mirroring the firmware)."""
        n = hi - lo
        ch_ids = self._ch_ids
        per = 1 + ch_ids.size
        ids = np.empty((n, per), dtype=np.int64)
        vals = np.empty((n, per), dtype=np.int64)
        marks = np.zeros((n, per), dtype=np.int64)
        ids[:, 0] = TIMESTAMP_SENSOR_ID
        vals[:, 0] = self._times_us[lo:hi] & (WRAP_US - 1)
        marks[:, 0] = 1
        ids[:, 1:] = ch_ids[None, :]
        vals[:, 1:] = self.trace.codes[lo:hi].astype(np.int64)

        mf = self._marker_frames
        sel = mf[(mf >= lo) & (mf < hi)] - lo
        ids_f, vals_f, marks_f = ids.ravel(), vals.ravel(), marks.ravel()
        if sel.size:
            if self._ch0_col is not None:
                marks.reshape(n, per)[sel, self._ch0_col] = 1
                marks_f = marks.ravel()
            else:
                # ch0 was not recorded (disabled): bare sensor-0 packets
                # right after the timestamps, exactly like the firmware
                pos = sel * per + 1
                ids_f = np.insert(ids_f, pos, 0)
                vals_f = np.insert(vals_f, pos, 0)
                marks_f = np.insert(marks_f, pos, 1)
        return protocol.encode_packets(ids_f, vals_f, marks_f)


def replay_sensor(
    trace: DeviceTrace,
    realtime: bool = False,
    ring_capacity: int | None = None,
    chunk_frames: int | None = None,
):
    """A `PowerSensor` wired to one replayed trace, markers pre-seeded.

    The default ring capacity retains the whole recorded session, so
    whole-span queries (attribution, golden metrics) never lose frames
    to eviction during replay.
    """
    from repro.core.host import PowerSensor

    dev = ReplayDevice(trace, realtime=realtime, chunk_frames=chunk_frames)
    if ring_capacity is None:
        ring_capacity = max(1 << max(len(trace) - 1, 1).bit_length(), 1024)
    ps = PowerSensor(dev, ring_capacity=ring_capacity)
    ps.expect_markers(trace.marker_chars)
    return ps


class ReplayFleet:
    """Reconstruct a full `FleetMonitor` session from a multi-device archive."""

    def __init__(
        self,
        archive: TraceArchive,
        realtime: bool = False,
        ring_capacity: int | None = None,
        window_s: float | None = None,
        chunk_frames: int | None = None,
        **monitor_kwargs,
    ):
        from repro.stream.fleet import FleetMonitor

        if window_s is None:
            window_s = float(archive.meta.get("window_s", 1.0))
        self.archive = archive
        self.monitor = FleetMonitor(window_s=window_s, **monitor_kwargs)
        self.devices: dict[str, ReplayDevice] = {}
        for dev_name, trace in archive.devices.items():
            ps = replay_sensor(
                trace,
                realtime=realtime,
                ring_capacity=ring_capacity,
                chunk_frames=chunk_frames,
            )
            self.devices[dev_name] = ps.device
            self.monitor.add(dev_name, ps)

    @classmethod
    def from_file(cls, path, **kwargs) -> "ReplayFleet":
        return cls(TraceArchive.load(path), **kwargs)

    @property
    def names(self) -> list[str]:
        return self.monitor.names

    def __getitem__(self, name: str):
        return self.monitor[name]

    def advance(self, dt_s: float) -> None:
        """Paced replay: release `dt_s` of recorded time and drain it."""
        self.monitor.advance(dt_s)

    def drain(self) -> int:
        """Replay everything that remains, at max speed.

        On a ``realtime=True`` fleet this first releases every remaining
        frame (otherwise the loop would wait forever on a clock only
        `advance` moves).
        """
        total = 0
        for d in self.devices.values():
            d.release_all()
        while True:
            n = self.monitor.poll_all()
            total += n
            if n == 0 and all(
                d.exhausted or not d.streaming for d in self.devices.values()
            ):
                return total

    def close(self) -> None:
        self.monitor.close()
