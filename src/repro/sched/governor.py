"""Closed-loop fleet power-cap governor over 20 kHz telemetry.

The paper's speed argument, finally closed into a loop: a controller that
*consumes* the fast sensor stream in real time and actuates the workload.
"Part-time Power Measurements" (arXiv:2312.02741) shows why this is
impossible on builtin counters — a 10 Hz sample-and-hold reading leaves a
PI loop flying blind for 100 ms at a time; `benchmarks/governor_cap.py`
reproduces exactly that failure against this governor.

Pieces:

* :class:`OperatingGrid` — the modelled actuation space of one serving
  device: every (DVFS ladder point × decode-batch size) scored for average
  watts and tokens/s through `power.tpu_model.phases_for_step`;
* :class:`PiController` — textbook PI with clamped integrator and
  conditional anti-windup (integration freezes while the actuator is
  pinned at either end of the grid);
* :class:`PowerCapGovernor` — the loop: poll fleet power from the ring
  buffers (`FleetMonitor.fleet_power`, windowed over the per-frame
  totals the ring maintains), PI-correct a fleet power budget, pick the
  highest-throughput operating point that fits, with hysteresis + minimum
  dwell so quantised actuation cannot chatter; a *stale* fleet reading
  (quorum lost, holdover — see `repro.stream.fleet`) is a safety event:
  integrator frozen, plant shed to a conservative rung, recovery blanked
  like a switch transient once telemetry reacquires;
* :class:`VirtualPlant` — N virtual PowerSensor3 devices playing the
  selected operating point through the full firmware/host chain, with a
  per-device efficiency bias the governor does *not* know (that model
  error is what makes feedback necessary) and a ground-truth actuation
  log for scoring cap adherence;
* :class:`SampledPowerReader` — sample-and-hold wrapper degrading the
  governor's telemetry to builtin-counter rates (10–100 Hz);
* :func:`time_over_cap` / :func:`settle_time` — cap-adherence metrics
  over a piecewise-constant true-power log.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.power.tpu_model import (
    DEFAULT_LADDER,
    V5E,
    DvfsLadder,
    StepCost,
    TpuChipSpec,
    phases_for_step,
    step_duration,
    step_energy,
)


@dataclass(frozen=True)
class OperatingPoint:
    """One actuation choice for one device: a DVFS state + a batch size."""

    dvfs_index: int
    dvfs_scale: float
    batch: int
    watts: float  # modelled average device power at this point
    tokens_per_s: float  # modelled decode throughput at this point

    @property
    def j_per_token(self) -> float:
        return self.watts / self.tokens_per_s if self.tokens_per_s > 0 else math.inf


class OperatingGrid:
    """Modelled (DVFS × batch) actuation space of one serving device.

    ``cost_of_batch(b)`` returns the per-step `StepCost` of decoding a
    batch of ``b`` slots; every grid point is scored once through
    `phases_for_step` at construction, then `best_under` is a pair of
    vectorised masks per call.  An explicit idle point (batch 0, static
    power, zero throughput) anchors the floor so a governor can always
    park the plant.
    """

    def __init__(
        self,
        cost_of_batch: Callable[[int], StepCost],
        n_layers: int,
        batches: Sequence[int] = (1, 2, 4, 8, 16, 32),
        ladder: DvfsLadder = DEFAULT_LADDER,
        chip: TpuChipSpec = V5E,
        tokens_per_slot_step: int = 1,
    ):
        self.chip = chip
        self.ladder = ladder
        pts: list[OperatingPoint] = [
            OperatingPoint(0, ladder.scales[0], 0, chip.p_static, 0.0)
        ]
        for b in sorted(set(int(b) for b in batches if b > 0)):
            cost = cost_of_batch(b)
            for di, dvfs in enumerate(ladder.states()):
                phases = phases_for_step(cost, n_layers, chip, dvfs)
                t = step_duration(phases)
                if t <= 0:
                    continue
                e = step_energy(phases, chip, dvfs)
                pts.append(
                    OperatingPoint(
                        di, dvfs.scale, b, e / t, b * tokens_per_slot_step / t
                    )
                )
        self.points = pts
        self._watts = np.array([p.watts for p in pts])
        self._tps = np.array([p.tokens_per_s for p in pts])
        self._batch = np.array([p.batch for p in pts])

    def __len__(self) -> int:
        return len(self.points)

    @property
    def idle(self) -> OperatingPoint:
        return self.points[0]

    @property
    def max_watts(self) -> float:
        return float(self._watts.max())

    def best_under(
        self, budget_w: float, max_batch: int | None = None
    ) -> OperatingPoint:
        """Highest-throughput point with watts ≤ budget (ties: fewer watts).

        ``max_batch`` bounds the batch (offered load / queue depth); when
        no point fits the budget the lowest-power feasible point is
        returned — a governor can always shed to the floor.
        """
        ok = self._watts <= budget_w
        if max_batch is not None:
            ok &= self._batch <= max_batch
        if not ok.any():
            ok = (
                self._batch <= max_batch
                if max_batch is not None
                else np.ones_like(self._watts, dtype=bool)
            )
            if not ok.any():
                return self.idle
            return self.points[int(np.flatnonzero(ok)[np.argmin(self._watts[ok])])]
        idx = np.flatnonzero(ok)
        # argmax tokens/s; among equals prefer the cheapest watts
        tps = self._tps[idx]
        best_tps = tps.max()
        tied = idx[tps >= best_tps - 1e-12]
        return self.points[int(tied[np.argmin(self._watts[tied])])]

    def next_above(
        self, point: OperatingPoint, max_batch: int | None = None
    ) -> OperatingPoint | None:
        """The next rung up: cheapest strictly-faster point above ``point``.

        None when ``point`` already tops the (demand-bounded) frontier —
        the governor treats that as actuator saturation.
        """
        ok = (self._tps > point.tokens_per_s + 1e-12) & (self._watts > point.watts)
        if max_batch is not None:
            ok &= self._batch <= max_batch
        if not ok.any():
            return None
        idx = np.flatnonzero(ok)
        return self.points[int(idx[np.argmin(self._watts[idx])])]

    def next_below(
        self, point: OperatingPoint, max_batch: int | None = None
    ) -> OperatingPoint | None:
        """The next rung down the efficient frontier: the highest-throughput
        point strictly cheaper than ``point`` (ties: fewer watts).

        Selecting by watts adjacency instead would land on *dominated*
        points — e.g. a smaller-batch rung 1 W cheaper with half the
        tokens/s — shedding almost no power and destabilising the loop.
        """
        ok = self._watts < point.watts - 1e-12
        if max_batch is not None:
            ok &= self._batch <= max_batch
        if not ok.any():
            return None
        idx = np.flatnonzero(ok)
        tps = self._tps[idx]
        tied = idx[tps >= tps.max() - 1e-12]
        return self.points[int(tied[np.argmin(self._watts[tied])])]

    def power_of_batch(self, batch: int) -> float:
        """Full-clock modelled device watts for a batch (scheduler pricing)."""
        ok = self._batch == batch
        if not ok.any():
            return float(self.chip.p_static)
        full = np.flatnonzero(ok)
        return float(self._watts[full].max())


def decode_cost_of_batch(
    flops_per_token: float,
    hbm_bytes_per_step: float,
    ici_bytes_per_step: float = 0.0,
    tokens_per_slot_step: int = 1,
) -> Callable[[int], StepCost]:
    """Serving-step cost closure: flops scale with batch, weights stream once."""

    def cost(b: int) -> StepCost:
        return StepCost(
            flops_per_token * tokens_per_slot_step * b,
            hbm_bytes_per_step,
            ici_bytes_per_step,
        )

    return cost


class PiController:
    """PI loop with a clamped integrator and conditional anti-windup."""

    def __init__(self, kp: float, ki: float, i_lo: float, i_hi: float):
        self.kp = float(kp)
        self.ki = float(ki)
        self.i_lo = float(i_lo)
        self.i_hi = float(i_hi)
        self.integral = 0.0

    def update(
        self,
        error: float,
        dt_s: float,
        saturated_hi: bool = False,
        saturated_lo: bool = False,
    ) -> float:
        """One tick: returns the control output kp·e + ∫ki·e.

        Anti-windup is conditional integration: when the actuator is pinned
        at full throttle and the error still asks for more (or pinned at
        the floor and asked for less), the integrator freezes instead of
        accumulating demand it cannot deliver.
        """
        pushing_past = (saturated_hi and error > 0) or (saturated_lo and error < 0)
        if not pushing_past:
            self.integral = min(
                max(self.integral + self.ki * error * dt_s, self.i_lo), self.i_hi
            )
        return self.kp * error + self.integral

    def reset(self) -> None:
        self.integral = 0.0


class SampledPowerReader:
    """Sample-and-hold telemetry: what a builtin counter feeds a controller.

    Wraps any ``read(now_s) -> watts`` callable and only refreshes it at
    ``rate_hz``; between refreshes the stale value is returned, exactly the
    nvidia-smi-style failure mode of arXiv:2312.02741.
    """

    def __init__(self, read_fn: Callable[[float], float], rate_hz: float):
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        self._read = read_fn
        self.period_s = 1.0 / float(rate_hz)
        self._next_due_s = -math.inf
        self._held = 0.0
        self.n_reads = 0

    def __call__(self, now_s: float) -> float:
        if now_s >= self._next_due_s:
            self._held = self._read(now_s)
            self.n_reads += 1
            self._next_due_s = now_s + self.period_s
        return self._held


# --------------------------------------------------------------------- plant
class VirtualPlant:
    """N virtual sensor devices playing the governed operating point.

    The actuation surface for simulation: ``apply(point, now)`` reprograms
    every device's DUT load to the point's modelled watts, scaled by a
    per-device efficiency bias the governor never sees — its feedback loop
    has to discover and trim that model error, exactly as it would on real
    silicon.  Every actuation is logged as ``(time, true fleet watts)`` so
    benchmarks can score cap adherence against ground truth rather than
    against the sensor being tested.

    Each device's sensor is calibrated (§III-D) at construction — an
    uncalibrated Hall offset reads several watts low/high per device,
    which a cap governor would faithfully regulate to the wrong power.
    Pass ``calibrate_samples=0`` to skip (tests that only exercise loop
    dynamics and tolerate a few watts of instrument bias).
    """

    def __init__(
        self,
        grid: OperatingGrid,
        n_devices: int = 4,
        biases: Sequence[float] | None = None,
        seed: int = 0,
        volts: float = 12.0,
        module: str = "pcie8pin-20a",
        ring_capacity: int = 1 << 16,
        window_s: float = 0.005,
        calibrate_samples: int = 6000,
    ):
        from repro.core import ConstantLoad
        from repro.core.calibration import calibrate
        from repro.stream import make_virtual_fleet

        self.grid = grid
        self.volts = float(volts)
        if biases is None:
            rng = np.random.default_rng(seed + 7919)
            biases = 1.0 + rng.uniform(-0.06, 0.08, size=n_devices)
        self.biases = [float(b) for b in biases]
        if len(self.biases) != n_devices:
            raise ValueError("one bias per device")
        self.fleet = make_virtual_fleet(
            [ConstantLoad(self.volts, 0.0) for _ in range(n_devices)],
            module=module,
            seed=seed,
            window_s=window_s,
            ring_capacity=ring_capacity,
        )
        self._loads = [
            self.fleet[name].device.firmware.dut.loads[0] for name in self.fleet.names
        ]
        if calibrate_samples > 0:
            for name in self.fleet.names:
                calibrate(self.fleet[name], {0: self.volts}, n_samples=calibrate_samples)
        self.point = grid.idle
        self.demand_batch = 0
        self.log: list[tuple[float, float]] = []  # (t, true fleet watts)
        self.apply(grid.idle, 0.0)

    @property
    def n_devices(self) -> int:
        return len(self._loads)

    def true_device_watts(self, point: OperatingPoint) -> list[float]:
        """Per-device ground-truth watts at a point (bias on dynamic power)."""
        p_static = self.grid.chip.p_static
        dyn = max(point.watts - p_static, 0.0)
        return [p_static + dyn * b for b in self.biases]

    @property
    def true_fleet_w(self) -> float:
        return sum(self.true_device_watts(self.point))

    def set_demand(self, batch: int) -> None:
        """Offered load: the largest batch the queue can currently fill."""
        self.demand_batch = max(int(batch), 0)

    def apply(self, point: OperatingPoint, now_s: float) -> None:
        for load, w in zip(self._loads, self.true_device_watts(point)):
            load.amps = w / self.volts
        self.point = point
        self.log.append((float(now_s), self.true_fleet_w))

    def advance(self, dt_s: float) -> None:
        self.fleet.advance(dt_s)

    def close(self) -> None:
        self.fleet.close()


# ------------------------------------------------------------------ governor
@dataclass
class GovernorConfig:
    cap_w: float  # fleet-level power cap
    window_s: float = 0.003  # telemetry window per control tick
    dt_s: float = 0.001  # control tick period
    kp: float = 0.8
    ki: float = 60.0
    #: deadband: upshifts need this much fleet-watt headroom under budget
    hysteresis_w: float = 0.0  # 0 = auto (2 % of cap)
    #: minimum spacing between switches before the next *upshift* — must
    #: cover a full measurement-window refresh or stale telemetry re-fires
    #: the upshift and the loop chatters over the cap
    min_dwell_s: float = 0.0  # 0 = auto (2·window + tick)
    #: integrator clamp as a fraction of the cap (anti-windup bound)
    integral_span_frac: float = 0.3
    #: stale-telemetry safety rung: while the fleet reading is flagged
    #: stale (quorum lost, holdover) the governor sheds to the highest
    #: rung predicted to fit this fraction of the cap and freezes there —
    #: flying blind at full throttle is how caps get blown silently
    stale_shed_frac: float = 0.6

    def __post_init__(self) -> None:
        if self.cap_w <= 0:
            raise ValueError("cap_w must be positive")
        if self.hysteresis_w <= 0:
            self.hysteresis_w = 0.02 * self.cap_w
        if self.min_dwell_s <= 0:
            self.min_dwell_s = 2.0 * self.window_s + self.dt_s


@dataclass(frozen=True)
class GovernorStatus:
    """One control tick's record."""

    time_s: float
    measured_w: float
    budget_w: float
    point: OperatingPoint
    switched: bool
    #: this tick ran on a stale fleet reading (safety event, not control)
    stale: bool = False


class PowerCapGovernor:
    """PI power-cap controller actuating an `OperatingGrid` over a plant.

    Call :meth:`step` once per control tick *before* advancing the plant:
    it reads fleet power (via the injected reader, default the 20 kHz
    windowed ring hook), updates the PI budget, and — subject to
    hysteresis and minimum dwell — re-selects the operating point.
    Downshifts are never delayed: shedding power is a safety action.
    """

    def __init__(
        self,
        plant: VirtualPlant,
        config: GovernorConfig,
        read_power: Callable[[float], float] | None = None,
    ):
        self.plant = plant
        self.cfg = config
        # the fleet derives 'now' from its own device clocks — the loop's
        # t and the devices' absolute clocks need not share an epoch
        self.read_power = read_power or (
            lambda now_s: plant.fleet.fleet_power(config.window_s)
        )
        span = config.integral_span_frac * config.cap_w
        self.pi = PiController(config.kp, config.ki, -span, span)
        self._last_switch_s = -math.inf
        self._was_stale = False
        self.n_stale_ticks = 0
        #: EWMA of measured/modelled fleet power, the live model-bias
        #: estimate; updated only from *fresh* windows (see step())
        self._rho = 1.0
        self.history: list[GovernorStatus] = []
        self.n_switches = 0

    def step(self, now_s: float) -> GovernorStatus:
        cfg = self.cfg
        plant = self.plant
        reading = self.read_power(now_s)
        # readers may return a bare float (legacy / sampled readers) or a
        # FleetPowerReading carrying quorum + staleness flags
        stale = bool(getattr(reading, "stale", False))
        measured = float(getattr(reading, "power_w", reading))
        n = plant.n_devices
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter("governor_ticks_total", "governor control ticks").inc()
            reg.gauge("governor_measured_w", "latest fleet power seen").set(measured)
        if stale:
            # ---- safety event: telemetry lost or below quorum ----
            # Control on a held/extrapolated number is how caps get blown
            # while looking fine, so: freeze the integrator and the bias
            # estimate (no update at all), shed to a conservative rung
            # predicted to fit stale_shed_frac of the cap, and hold until
            # the fleet reading is trustworthy again.
            entered_stale = not self._was_stale
            self._was_stale = True
            self.n_stale_ticks += 1
            if reg is not None:
                reg.counter(
                    "governor_stale_ticks_total",
                    "ticks spent controlling on stale telemetry",
                ).inc()
            if entered_stale:
                rec = obs_trace.active()
                if rec is not None:
                    rec.device_instant(
                        "governor:stale-safety", now_s,
                        track="governor", value=measured,
                    )
            safe = plant.grid.best_under(
                cfg.stale_shed_frac * cfg.cap_w / max(n, 1),
                max_batch=plant.demand_batch,
            )
            switched = False
            if safe.watts < plant.point.watts - 1e-9:
                plant.apply(safe, now_s)
                self._last_switch_s = now_s
                self.n_switches += 1
                switched = True
                self._note_switch(safe, now_s, "stale-shed")
            status = GovernorStatus(
                now_s, measured, cfg.cap_w, plant.point, switched, stale=True
            )
            self.history.append(status)
            return status
        if self._was_stale:
            # reacquisition: the telemetry window is refilling with the
            # shed rung's power — blank like a post-switch transient
            self._was_stale = False
            self._last_switch_s = now_s
        err = cfg.cap_w - measured
        # the telemetry window lags a switch by one window length: reads
        # taken before it refreshes mix the old point's power in.  Blank
        # the integrator and the bias estimate until the window is fresh,
        # or every switch transient pumps the integrator with phantom error.
        fresh = now_s - self._last_switch_s >= cfg.window_s
        modelled = n * plant.point.watts
        if fresh and modelled > 0 and measured > 0:
            inst = min(max(measured / modelled, 0.6), 1.4)
            self._rho += 0.4 * (inst - self._rho)
        rho = self._rho
        # anti-windup saturation: "more" is unavailable when there is no rung
        # above (demand-bounded frontier topped out) or the next rung up is
        # predicted — via the live bias estimate — to land over the cap;
        # without this the integrator creeps through the quantisation
        # residual and periodically re-tries a rung it already knows blows
        # the cap (a permanent limit cycle)
        nxt = plant.grid.next_above(plant.point, max_batch=plant.demand_batch)
        at_ceiling = nxt is None or n * nxt.watts * rho > cfg.cap_w
        at_floor = plant.point is plant.grid.idle
        u = self.pi.update(
            err, cfg.dt_s if fresh else 0.0,
            saturated_hi=at_ceiling, saturated_lo=at_floor,
        )
        budget = min(max(cfg.cap_w + u, n * plant.grid.chip.p_static), 2.0 * cfg.cap_w)
        # selection budget: the PI budget, additionally clamped so no rung
        # *predicted* (via the live bias estimate) to blow the band is ever
        # selected — the multi-rung jump lands at the highest safe rung
        sel_budget = min(budget, (cfg.cap_w + cfg.hysteresis_w) / rho)
        cand = plant.grid.best_under(sel_budget / n, max_batch=plant.demand_batch)
        if err < -cfg.hysteresis_w and cand is plant.point:
            # measured beyond the promised band: shed a rung *now* rather
            # than waiting for the integrator to drain the budget past it
            down = plant.grid.next_below(plant.point, max_batch=plant.demand_batch)
            if down is not None:
                cand = down
        switched = False
        if cand is not plant.point:
            downshift = cand.watts < plant.point.watts - 1e-9 or (
                plant.demand_batch < plant.point.batch
            )
            if downshift:
                switched = True  # shedding is always allowed, immediately
            elif (
                now_s - self._last_switch_s >= cfg.min_dwell_s
                and n * cand.watts <= budget - cfg.hysteresis_w
            ):
                switched = True
            if switched:
                plant.apply(cand, now_s)
                self._last_switch_s = now_s
                self.n_switches += 1
                self._note_switch(cand, now_s, "down" if downshift else "up")
        status = GovernorStatus(now_s, measured, budget, plant.point, switched)
        self.history.append(status)
        return status

    def _note_switch(self, point: OperatingPoint, now_s: float, reason: str) -> None:
        """Obs hooks for one rung switch (no-ops when tracing is disabled)."""
        rec = obs_trace.active()
        if rec is not None:
            rec.device_instant(
                f"governor:switch:{reason} dvfs={point.dvfs_index} b={point.batch}",
                now_s, track="governor", value=point.watts,
            )
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter(
                "governor_switches_total", "operating-point switches",
                reason=reason,
            ).inc()

    def run(
        self,
        duration_s: float,
        t0_s: float = 0.0,
        demand_of_t: Callable[[float], int] | None = None,
    ) -> list[GovernorStatus]:
        """Drive the closed loop for a duration (convenience for sims)."""
        t = t0_s
        end = t0_s + duration_s
        while t < end - 1e-12:
            if demand_of_t is not None:
                self.plant.set_demand(demand_of_t(t))
            self.step(t)
            self.plant.advance(self.cfg.dt_s)
            t += self.cfg.dt_s
        return self.history


# ------------------------------------------------------------------- metrics
def _log_segments(
    log: Sequence[tuple[float, float]], t0_s: float, t1_s: float
) -> list[tuple[float, float, float]]:
    """Clip a piecewise-constant (t, w) log to [t0, t1) as (a, b, w) spans."""
    segs: list[tuple[float, float, float]] = []
    for i, (t, w) in enumerate(log):
        t_next = log[i + 1][0] if i + 1 < len(log) else t1_s
        a, b = max(t, t0_s), min(t_next, t1_s)
        if b > a:
            segs.append((a, b, w))
    return segs


def time_over_cap(
    log: Sequence[tuple[float, float]],
    cap_w: float,
    t0_s: float,
    t1_s: float,
    tol: float = 0.01,
) -> float:
    """Fraction of [t0, t1) the true power spent above cap·(1 + tol)."""
    if t1_s <= t0_s:
        return 0.0
    over = sum(b - a for a, b, w in _log_segments(log, t0_s, t1_s) if w > cap_w * (1.0 + tol))
    return over / (t1_s - t0_s)


def settle_time(
    log: Sequence[tuple[float, float]],
    cap_w: float,
    t_step_s: float,
    t_end_s: float,
    tol: float = 0.02,
) -> float:
    """Seconds after a load step until the last over-cap excursion ends.

    0.0 when the cap was never exceeded after the step; ``t_end - t_step``
    when the plant was still over cap at the end of the run (not settled).
    """
    last_over_end = t_step_s
    for a, b, w in _log_segments(log, t_step_s, t_end_s):
        if w > cap_w * (1.0 + tol):
            last_over_end = b
    return last_over_end - t_step_s
