"""Energy-SLO admission and batching over a priced request queue.

The scheduling half of the closed loop: where the governor holds a power
cap by actuating the plant, the scheduler decides *which work* runs by
pricing every queued request in joules before it is admitted and
reconciling those predictions against the energy the sensor fleet
actually measured (per-wave `EnergyLedger`s from `repro.attrib`).

* :class:`EnergyPricer` — predicted J/token for an architecture, built
  from per-kernel attribution artifacts (an attributed `EnergyLedger`, a
  `SignatureLibrary` of per-kernel waveforms, or the declared phase
  timeline of the TPU model) and corrected online by an EWMA of the
  measured/predicted ratio;
* :class:`Request` — one queued generation request with its predicted
  and measured energy accounting;
* :class:`EnergySloScheduler` — policy-driven wave selection under a
  joules budget, wave completion, and measured-energy reconciliation
  (wave energy is split across the wave's requests by token share, so
  per-request totals always sum to the ledger total).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from .policies import Policy, SchedContext

if TYPE_CHECKING:  # pragma: no cover
    from repro.attrib import EnergyLedger
    from repro.attrib.signatures import SignatureLibrary


@dataclass
class Request:
    """One generation request moving through the scheduler."""

    rid: int
    client: str = "default"
    prompt_len: int = 0
    gen_len: int = 0
    arrival_s: float = 0.0
    payload: object = None  # opaque (e.g. the prompt token array)
    predicted_j: float = 0.0
    measured_j: float = 0.0
    done_tokens: int = 0
    finished: bool = False

    @property
    def measured_mj_per_token(self) -> float:
        return self.measured_j / self.done_tokens * 1e3 if self.done_tokens else 0.0


@dataclass
class EnergyPricer:
    """Predicted J/token for one architecture, reconciled against reality.

    ``j_per_token`` is the base per-kernel prediction; ``correction`` is
    an EWMA of measured/base ratios fed back from attributed wave ledgers,
    so systematic model error (the same bias the governor's PI integrator
    absorbs) washes out of admission pricing after a few waves.
    """

    j_per_token: float
    alpha: float = 0.25
    correction: float = 1.0
    n_updates: int = 0

    def price_tokens(self, n_tokens: int) -> float:
        return self.j_per_token * self.correction * max(int(n_tokens), 0)

    def update(self, tokens: int, measured_j: float) -> float:
        """Fold one measured wave in; returns the instantaneous ratio."""
        base = self.j_per_token * tokens
        if base <= 0 or measured_j <= 0:
            return self.correction
        ratio = measured_j / base
        self.correction = (1.0 - self.alpha) * self.correction + self.alpha * ratio
        self.n_updates += 1
        return ratio

    # ------------------------------------------------------------ builders
    @classmethod
    def from_ledger(cls, ledger: "EnergyLedger", tokens: int, **kw) -> "EnergyPricer":
        """Price from an attributed ledger covering ``tokens`` of decode."""
        if tokens <= 0:
            raise ValueError("tokens must be positive")
        return cls(j_per_token=ledger.total_energy_j / tokens, **kw)

    @classmethod
    def from_signatures(
        cls,
        library: "SignatureLibrary",
        tokens_per_step: int,
        kernels: Sequence[str] | None = None,
        **kw,
    ) -> "EnergyPricer":
        """Price from per-kernel power signatures: Σ mean_w · duration per step.

        This is the `attrib.signatures` path: each kernel's signature
        carries its mean occurrence power and duration, so one modelled
        serving step costs the sum over its kernels — no markers needed on
        the pricing side.
        """
        names = list(kernels) if kernels is not None else list(library.signatures)
        step_j = 0.0
        for name in names:
            sig = library.signatures[name]
            step_j += sig.mean_w * sig.duration_s
        if tokens_per_step <= 0:
            raise ValueError("tokens_per_step must be positive")
        return cls(j_per_token=step_j / tokens_per_step, **kw)

    @classmethod
    def from_phases(cls, phases, chip, tokens_per_step: int, dvfs=None, **kw) -> "EnergyPricer":
        """Price from the declared per-kernel phase timeline (model-only)."""
        step_j = sum(p.power(chip, dvfs) * p.duration_s for p in phases)
        if tokens_per_step <= 0:
            raise ValueError("tokens_per_step must be positive")
        return cls(j_per_token=step_j / tokens_per_step, **kw)


@dataclass
class WaveRecord:
    """One scheduled wave and its energy accounting."""

    index: int
    rids: list[int]
    tokens: int = 0  # tokens credited to real requests (gen_len-clamped)
    #: tokens the hardware actually decoded, including padded batch slots —
    #: the denominator the pricer's J/token correction must use
    decoded_tokens: int = 0
    request_tokens: list[int] = field(default_factory=list)
    predicted_j: float = 0.0
    measured_j: float | None = None  # None until reconciled/released
    released: bool = False  # settled from prediction, not measurement


class EnergySloScheduler:
    """Policy-driven wave selection under a joules budget.

    Lifecycle per wave: :meth:`next_wave` (policy orders the queue, the
    scheduler admits a budget-feasible prefix), :meth:`complete_wave`
    (tokens decoded), :meth:`reconcile` (attributed wave energy lands,
    split across the wave's requests by token share, budget and pricer
    updated).  Reconciliation is allowed to lag by any number of waves —
    exactly how `launch.serve` resolves wave ``k`` one wave late, after
    its closing marker has flushed through the ring.
    """

    def __init__(
        self,
        pricer: EnergyPricer,
        policy: Policy,
        max_batch: int,
        budget_j: float = math.inf,
        cap_w: float | None = None,
        power_of_batch=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.pricer = pricer
        self.policy = policy
        self.max_batch = int(max_batch)
        self.budget_j = float(budget_j)
        self.cap_w = cap_w
        self.power_of_batch = power_of_batch
        self.queue: list[Request] = []
        self.waves: list[WaveRecord] = []
        self.finished: list[Request] = []
        self.rejected: list[Request] = []
        self.spent_j = 0.0  # reconciled measured energy
        self.committed_j = 0.0  # predicted energy of unreconciled waves
        self.client_energy_j: dict[str, float] = {}
        self._by_rid: dict[int, Request] = {}

    # ---------------------------------------------------------- admission
    @property
    def remaining_budget_j(self) -> float:
        return self.budget_j - self.spent_j - self.committed_j

    def submit(self, req: Request) -> None:
        req.predicted_j = self.pricer.price_tokens(req.gen_len)
        self.queue.append(req)
        self._by_rid[req.rid] = req
        self.client_energy_j.setdefault(req.client, 0.0)

    def _context(self, now_s: float) -> SchedContext:
        return SchedContext(
            max_batch=self.max_batch,
            remaining_budget_j=self.remaining_budget_j,
            cap_w=self.cap_w,
            power_of_batch=self.power_of_batch,
            client_energy_j=dict(self.client_energy_j),
            now_s=now_s,
        )

    def next_wave(self, now_s: float = 0.0) -> list[Request] | None:
        """Select the next wave, or None when the queue is empty / starved.

        The policy orders the queue and bounds the batch; the scheduler
        then walks that order admitting every request whose *re-priced*
        predicted energy still fits the remaining budget.  Admission is
        deliberately work-conserving: a request too expensive for the
        current remainder is skipped (not a barrier), so cheaper requests
        behind it keep the batch full — an expensive head-of-line request
        waits until commitments resolve or is eventually rejected as
        hopeless (predicted energy above the spent-adjusted budget alone),
        an SLO decision surfaced in ``rejected`` rather than a silent
        starve.
        """
        if not self.queue:
            return None
        ctx = self._context(now_s)
        order = self.policy.order(self.queue, ctx)
        limit = min(self.policy.batch_limit(self.queue, ctx), self.max_batch)
        if limit < 1:
            return None
        chosen: list[Request] = []
        predicted = 0.0
        remaining = self.remaining_budget_j
        for qi in order:
            if len(chosen) >= limit:
                break
            req = self.queue[qi]
            price = self.pricer.price_tokens(req.gen_len - req.done_tokens)
            if predicted + price > remaining:
                continue
            req.predicted_j = price
            chosen.append(req)
            predicted += price
        if not chosen:
            # Nothing fits *right now*.  Only requests that cannot fit the
            # budget even once every in-flight commitment resolves are
            # hopeless and rejected; the rest stay queued — the caller can
            # reconcile pending waves (freeing committed energy) and retry.
            hard_remaining = self.budget_j - self.spent_j
            for req in list(self.queue):
                if self.pricer.price_tokens(req.gen_len - req.done_tokens) > hard_remaining:
                    self.queue.remove(req)
                    self.rejected.append(req)
            return None
        for req in chosen:
            self.queue.remove(req)
        wave = WaveRecord(
            index=len(self.waves), rids=[r.rid for r in chosen], predicted_j=predicted
        )
        self.waves.append(wave)
        self.committed_j += predicted
        return chosen

    # --------------------------------------------------------- completion
    def complete_wave(
        self,
        wave_index: int,
        tokens_per_request: int,
        decoded_tokens: int | None = None,
    ) -> None:
        """Record the tokens a wave decoded.

        Per-request credit is clamped at each request's remaining
        ``gen_len`` (a short request padded into a long wave does not get
        phantom tokens); ``decoded_tokens`` is what the hardware actually
        ran — including padded batch slots — and defaults to
        ``tokens_per_request × n_requests`` when no padding happened.
        """
        wave = self.waves[wave_index]
        wave.request_tokens = []
        for rid in wave.rids:
            req = self._by_rid[rid]
            d = min(tokens_per_request, max(req.gen_len - req.done_tokens, 0))
            req.done_tokens += d
            wave.request_tokens.append(d)
            if req.done_tokens >= req.gen_len and not req.finished:
                req.finished = True
                self.finished.append(req)
        wave.tokens = sum(wave.request_tokens)
        wave.decoded_tokens = (
            decoded_tokens
            if decoded_tokens is not None
            else tokens_per_request * len(wave.rids)
        )

    def _settle(self, wave: WaveRecord, energy_j: float, from_measurement: bool) -> None:
        wave.measured_j = float(energy_j)
        wave.released = not from_measurement
        self.committed_j -= wave.predicted_j
        self.spent_j += wave.measured_j
        # split by per-request token share; the last share absorbs the float
        # residue so the per-request sum is *exactly* the settled total
        n = len(wave.rids)
        shares = wave.request_tokens if sum(wave.request_tokens) else [1] * n
        total_share = sum(shares)
        handed = 0.0
        for k, (rid, share) in enumerate(zip(wave.rids, shares)):
            req = self._by_rid[rid]
            d = wave.measured_j - handed if k == n - 1 else (
                wave.measured_j * share / total_share
            )
            handed += d
            req.measured_j += d
            self.client_energy_j[req.client] = (
                self.client_energy_j.get(req.client, 0.0) + d
            )
        if from_measurement and wave.decoded_tokens:
            self.pricer.update(wave.decoded_tokens, wave.measured_j)

    def reconcile(self, wave_index: int, measured_j: float) -> None:
        """Land the attributed energy of one wave.

        Splits by token share across the wave's requests (so per-request
        totals sum exactly to the ledger total), releases the wave's
        predicted commitment from the budget, charges the measured energy,
        and feeds the pricer's correction loop.
        """
        wave = self.waves[wave_index]
        if wave.measured_j is not None:
            raise ValueError(f"wave {wave_index} already settled")
        self._settle(wave, measured_j, from_measurement=True)

    def release_wave(self, wave_index: int) -> None:
        """Settle a wave whose energy could not be measured (e.g. the ring
        evicted its span): charge its *predicted* energy so the budget
        commitment is not leaked forever, without feeding the pricer."""
        wave = self.waves[wave_index]
        if wave.measured_j is not None:
            raise ValueError(f"wave {wave_index} already settled")
        self._settle(wave, wave.predicted_j, from_measurement=False)

    # ------------------------------------------------------------ reports
    def unreconciled(self) -> list[int]:
        return [w.index for w in self.waves if w.measured_j is None]

    def report_rows(self) -> list[dict]:
        rows = []
        for req in sorted(self._by_rid.values(), key=lambda r: r.rid):
            rows.append(
                {
                    "rid": req.rid,
                    "client": req.client,
                    "tokens": req.done_tokens,
                    "predicted_j": req.predicted_j,
                    "measured_j": req.measured_j,
                    "mj_per_token": req.measured_mj_per_token,
                    "finished": req.finished,
                }
            )
        return rows


def format_report_rows(rows: Sequence[dict]) -> str:
    """Render `report_rows` output as the per-request SLO accounting table."""
    lines = ["  rid client    tokens  predicted J  measured J  mJ/token"]
    for row in rows:
        lines.append(
            f"  {row['rid']:>3} {row['client']:<9} {row['tokens']:>5}  "
            f"{row['predicted_j']:>11.4f} {row['measured_j']:>11.4f}  "
            f"{row['mj_per_token']:>8.3f}"
        )
    return "\n".join(lines)
