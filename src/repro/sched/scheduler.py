"""Energy-SLO admission and billing over a priced request queue.

The scheduling half of the closed loop: where the governor holds a power
cap by actuating the plant, the scheduler decides *which work* runs by
pricing every queued request in joules before it is admitted and
reconciling those predictions against the energy the sensor fleet
actually measured (step-interval / per-wave `EnergyLedger`s from
`repro.attrib`).

The serving substrate is **continuous batching at step granularity**:

* :class:`EnergyPricer` — predicted J/token for an architecture, built
  from per-kernel attribution artifacts (an attributed `EnergyLedger`, a
  `SignatureLibrary` of per-kernel waveforms, or the declared phase
  timeline of the TPU model) and corrected online by an EWMA of the
  measured/predicted ratio;
* :class:`Request` — one queued generation request with its predicted
  and measured energy accounting and its outstanding per-request budget
  commitment;
* :class:`ContinuousBatch` — the slot model: requests :meth:`admit` into
  free slots of a fixed-shape decode batch, every decode step bills real
  tokens per occupied slot (:meth:`step_billing`), completions and
  evictions free slots immediately (:meth:`retire`), and measured energy
  lands per **step interval** (:meth:`settle_interval`), split across the
  requests occupying slots in that interval by token share;
* :class:`EnergySloScheduler` — the wave-granularity compatibility shim
  over the same core (pricing, budget commitments, ledger-splitting):
  `next_wave` / `complete_wave` / `reconcile` admit and settle whole
  waves at once.  A wave is the degenerate one-interval case of the slot
  model; `policies.py` and `compare_policies` run unchanged on either.

Budget accounting is per-request across three pools that always sum
against the budget: ``committed_j`` (admitted but not yet decoded),
``inflight_j`` (decoded but not yet settled step intervals — the wave
shim settles admission-to-reconciliation in one move, so its inflight is
folded into ``committed_j``) and ``spent_j`` (settled, measured or
released-at-prediction).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .policies import Policy, SchedContext

if TYPE_CHECKING:  # pragma: no cover
    from repro.attrib import EnergyLedger
    from repro.attrib.signatures import SignatureLibrary


@dataclass
class Request:
    """One generation request moving through the scheduler."""

    rid: int
    client: str = "default"
    prompt_len: int = 0
    gen_len: int = 0
    arrival_s: float = 0.0
    payload: object = None  # opaque (e.g. the prompt token array)
    predicted_j: float = 0.0
    measured_j: float = 0.0
    done_tokens: int = 0
    finished: bool = False
    evicted: bool = False
    #: outstanding admission commitment against the joules budget, and the
    #: tokens that commitment still covers (amortised out per decode step)
    committed_j: float = 0.0
    committed_tokens: int = 0

    @property
    def measured_mj_per_token(self) -> float:
        return self.measured_j / self.done_tokens * 1e3 if self.done_tokens else 0.0


@dataclass
class EnergyPricer:
    """Predicted J/token for one architecture, reconciled against reality.

    ``j_per_token`` is the base per-kernel prediction; ``correction`` is
    an EWMA of measured/base ratios fed back from attributed step-interval
    (or wave) ledgers, so systematic model error (the same bias the
    governor's PI integrator absorbs) washes out of admission pricing
    after a few settlements.
    """

    j_per_token: float
    alpha: float = 0.25
    correction: float = 1.0
    n_updates: int = 0

    def price_tokens(self, n_tokens: int) -> float:
        return self.j_per_token * self.correction * max(int(n_tokens), 0)

    def update(self, tokens: int, measured_j: float) -> float:
        """Fold one measured interval in; returns the instantaneous ratio."""
        base = self.j_per_token * tokens
        if base <= 0 or measured_j <= 0:
            return self.correction
        ratio = measured_j / base
        self.correction = (1.0 - self.alpha) * self.correction + self.alpha * ratio
        self.n_updates += 1
        return ratio

    # ------------------------------------------------------------ builders
    @classmethod
    def from_ledger(cls, ledger: "EnergyLedger", tokens: int, **kw) -> "EnergyPricer":
        """Price from an attributed ledger covering ``tokens`` of decode."""
        if tokens <= 0:
            raise ValueError("tokens must be positive")
        return cls(j_per_token=ledger.total_energy_j / tokens, **kw)

    @classmethod
    def from_signatures(
        cls,
        library: "SignatureLibrary",
        tokens_per_step: int,
        kernels: Sequence[str] | None = None,
        **kw,
    ) -> "EnergyPricer":
        """Price from per-kernel power signatures: Σ mean_w · duration per step.

        This is the `attrib.signatures` path: each kernel's signature
        carries its mean occurrence power and duration, so one modelled
        serving step costs the sum over its kernels — no markers needed on
        the pricing side.
        """
        names = list(kernels) if kernels is not None else list(library.signatures)
        step_j = 0.0
        for name in names:
            sig = library.signatures[name]
            step_j += sig.mean_w * sig.duration_s
        if tokens_per_step <= 0:
            raise ValueError("tokens_per_step must be positive")
        return cls(j_per_token=step_j / tokens_per_step, **kw)

    @classmethod
    def from_phases(cls, phases, chip, tokens_per_step: int, dvfs=None, **kw) -> "EnergyPricer":
        """Price from the declared per-kernel phase timeline (model-only)."""
        step_j = sum(p.power(chip, dvfs) * p.duration_s for p in phases)
        if tokens_per_step <= 0:
            raise ValueError("tokens_per_step must be positive")
        return cls(j_per_token=step_j / tokens_per_step, **kw)


# --------------------------------------------------------------------- core
class _SloCore:
    """Shared pricing/budget/settlement machinery under both granularities.

    Owns the queue, the request index, the budget pools, and the exact
    ledger-splitting settlement (`_split_settled`): settled energy is
    divided across requests by share with the last share absorbing the
    float residue, so per-request totals always sum *exactly* to the
    settled total — the SLO invariant every billing test pins.
    """

    def __init__(
        self,
        pricer: EnergyPricer,
        policy: Policy,
        budget_j: float = math.inf,
        cap_w: float | None = None,
        power_of_batch=None,
    ):
        self.pricer = pricer
        self.policy = policy
        self.budget_j = float(budget_j)
        self.cap_w = cap_w
        self.power_of_batch = power_of_batch
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.rejected: list[Request] = []
        self.spent_j = 0.0  # settled energy (measured or released)
        self.committed_j = 0.0  # admitted-but-unsettled predicted energy
        self.inflight_j = 0.0  # decoded-but-unsettled predicted energy
        self.client_energy_j: dict[str, float] = {}
        self._by_rid: dict[int, Request] = {}

    # ---------------------------------------------------------- admission
    @property
    def remaining_budget_j(self) -> float:
        return self.budget_j - self.spent_j - self.committed_j - self.inflight_j

    def submit(self, req: Request) -> None:
        req.predicted_j = self.pricer.price_tokens(req.gen_len)
        self.queue.append(req)
        self._by_rid[req.rid] = req
        self.client_energy_j.setdefault(req.client, 0.0)

    def _context(self, now_s: float) -> SchedContext:
        return SchedContext(
            max_batch=self._admission_bound(),
            remaining_budget_j=self.remaining_budget_j,
            cap_w=self.cap_w,
            power_of_batch=self.power_of_batch,
            client_energy_j=dict(self.client_energy_j),
            now_s=now_s,
        )

    def _admission_bound(self) -> int:  # pragma: no cover - overridden
        raise NotImplementedError

    def _reject_hopeless(self) -> None:
        """Drop queued requests that cannot fit the budget even once every
        in-flight commitment resolves — an SLO decision surfaced in
        ``rejected`` rather than a silent starve."""
        hard_remaining = self.budget_j - self.spent_j
        for req in list(self.queue):
            if self.pricer.price_tokens(req.gen_len - req.done_tokens) > hard_remaining:
                self.queue.remove(req)
                self.rejected.append(req)

    # --------------------------------------------------------- settlement
    def _split_settled(
        self, rids: Sequence[int], shares: Sequence[float], energy_j: float
    ) -> None:
        """Split settled energy across requests by share, exactly."""
        n = len(rids)
        total_share = sum(shares)
        if n == 0 or total_share <= 0:
            return
        handed = 0.0
        for k, (rid, share) in enumerate(zip(rids, shares)):
            req = self._by_rid[rid]
            d = energy_j - handed if k == n - 1 else energy_j * share / total_share
            handed += d
            req.measured_j += d
            self.client_energy_j[req.client] = (
                self.client_energy_j.get(req.client, 0.0) + d
            )

    # ------------------------------------------------------------ reports
    def report_rows(self) -> list[dict]:
        rows = []
        for req in sorted(self._by_rid.values(), key=lambda r: r.rid):
            rows.append(
                {
                    "rid": req.rid,
                    "client": req.client,
                    "tokens": req.done_tokens,
                    "predicted_j": req.predicted_j,
                    "measured_j": req.measured_j,
                    "mj_per_token": req.measured_mj_per_token,
                    "finished": req.finished,
                }
            )
        return rows


# ------------------------------------------------------------ step model
@dataclass
class StepRecord:
    """One decode step over the live batch: who ran, who got billed."""

    index: int
    interval: int  # the settlement interval this step belongs to
    rids: tuple[int, ...]  # requests occupying active slots this step
    tokens: tuple[int, ...]  # real tokens billed per occupying request
    decoded_tokens: int  # tokens the hardware ran, padded slots included

    @property
    def billed_tokens(self) -> int:
        return sum(self.tokens)


@dataclass
class IntervalRecord:
    """One settlement interval: a batch of decode steps bracketed by the
    step clock (markers), with its per-request occupancy matrix collapsed
    to token counts — the generalisation of a wave's token shares."""

    index: int
    steps: int = 0
    #: rid -> real tokens billed inside this interval (insertion-ordered)
    occupancy: dict[int, int] = field(default_factory=dict)
    #: tokens the hardware decoded, padded slots included — the pricer's
    #: correction denominator
    decoded_tokens: int = 0
    predicted_j: float = 0.0  # commitment moved in from the steps billed
    measured_j: float | None = None  # None until settled/released
    released: bool = False  # settled from prediction, not measurement

    @property
    def tokens(self) -> int:
        return sum(self.occupancy.values())


#: slot lifecycle: free -> active (admitted) -> draining (request finished
#: or evicted; the fixed-shape batch still decodes the slot as padding,
#: excluded from billing) -> active/free again at the next admission
SLOT_FREE = "free"
SLOT_ACTIVE = "active"
SLOT_DRAINING = "draining"


class ContinuousBatch(_SloCore):
    """Continuous batching priced in joules, at step granularity.

    The live decode batch is ``n_slots`` fixed slots (the compiled batch
    shape).  Requests join mid-decode (:meth:`admit`), are billed real
    tokens per step (:meth:`step_billing` — padded/draining slots bill
    nothing), and leave the moment they finish or are evicted
    (:meth:`retire`), freeing the slot for the next admission.

    Energy lands per **step interval**: :meth:`seal_interval` closes the
    batch of steps since the last seal (the serve loop brackets each with
    one marker occurrence), and :meth:`settle_interval` splits the
    measured interval energy across the requests that occupied slots in
    it, by real-token share — the same exact-sum ledger splitting the
    wave shim uses, driven by the interval's occupancy matrix instead of
    a per-wave token share.  Settlement may lag by any number of
    intervals; :meth:`release_interval` settles an unmeasurable interval
    at its predicted energy so budget commitments never leak.

    Admission enforces the power cap at step granularity: the policy's
    ``batch_limit`` bounds the number of *live* slots, so a cap-strict
    policy holds the modelled batch power under the cap at every step
    boundary even as completions and arrivals churn the batch.
    """

    def __init__(
        self,
        pricer: EnergyPricer,
        policy: Policy,
        n_slots: int,
        budget_j: float = math.inf,
        cap_w: float | None = None,
        power_of_batch=None,
    ):
        if n_slots < 1:
            raise ValueError("n_slots must be >= 1")
        super().__init__(pricer, policy, budget_j, cap_w, power_of_batch)
        self.n_slots = int(n_slots)
        self.slot_rids: list[int | None] = [None] * self.n_slots
        self.slot_states: list[str] = [SLOT_FREE] * self.n_slots
        self.evicted: list[Request] = []
        self.steps: list[StepRecord] = []
        self.intervals: list[IntervalRecord] = []  # sealed intervals
        self.overhead_j = 0.0  # settled energy no live request occupied
        self._cur = IntervalRecord(index=0)

    # ------------------------------------------------------------- state
    @property
    def current_interval(self) -> int:
        """Index the next :meth:`seal_interval` will close (the open one)."""
        return self._cur.index

    @property
    def n_active(self) -> int:
        return sum(1 for s in self.slot_states if s == SLOT_ACTIVE)

    @property
    def live_rids(self) -> list[int]:
        return [
            rid
            for rid, s in zip(self.slot_rids, self.slot_states)
            if s == SLOT_ACTIVE and rid is not None
        ]

    def _admission_bound(self) -> int:
        return self.n_slots

    def _slot_of(self, rid: int) -> int:
        for i, (r, s) in enumerate(zip(self.slot_rids, self.slot_states)):
            if r == rid and s == SLOT_ACTIVE:
                return i
        raise KeyError(f"request {rid} occupies no active slot")

    # ---------------------------------------------------------- admission
    def admit(self, now_s: float = 0.0) -> list[tuple[int, Request]]:
        """Fill reusable slots from the queue; returns (slot, request) pairs.

        The policy orders the queue and bounds the *live* batch (cap
        enforcement at step granularity); the budget walk then admits
        every request whose re-priced remaining cost fits — skipped, not
        blocked, so cheaper requests behind an expensive head keep the
        batch full.  Each admission takes a per-request commitment
        against the budget, amortised back out token-by-token as the
        request decodes.  When nothing fits a free slot *and* no
        commitment is pending resolution, hopeless requests are rejected.
        """
        reusable = [
            i for i, s in enumerate(self.slot_states) if s != SLOT_ACTIVE
        ]
        if not self.queue or not reusable:
            return []
        ctx = self._context(now_s)
        order = self.policy.order(self.queue, ctx)
        limit = min(self.policy.batch_limit(self.queue, ctx), self.n_slots)
        room = limit - self.n_active
        admitted: list[tuple[int, Request]] = []
        predicted = 0.0
        remaining = self.remaining_budget_j
        chosen: list[Request] = []
        for qi in order:
            if len(chosen) >= min(room, len(reusable)):
                break
            req = self.queue[qi]
            price = self.pricer.price_tokens(req.gen_len - req.done_tokens)
            if predicted + price > remaining:
                continue
            chosen.append(req)
            predicted += price
        for slot, req in zip(reusable, chosen):
            self.queue.remove(req)
            price = self.pricer.price_tokens(req.gen_len - req.done_tokens)
            req.predicted_j = price
            req.committed_j = price
            req.committed_tokens = max(req.gen_len - req.done_tokens, 0)
            self.committed_j += price
            self.slot_rids[slot] = req.rid
            self.slot_states[slot] = SLOT_ACTIVE
            admitted.append((slot, req))
        if not admitted and room > 0 and not (self.committed_j or self.inflight_j):
            self._reject_hopeless()
        if admitted:
            rec = obs_trace.active()
            if rec is not None:
                rec.instant("sched:admit", track="sched", value=float(len(admitted)))
            reg = obs_metrics.active()
            if reg is not None:
                reg.counter("sched_admitted_total", "requests admitted").inc(
                    len(admitted)
                )
        return admitted

    # ------------------------------------------------------------ billing
    def step_billing(
        self, slot_tokens: int = 1, decoded_slots: int | None = None
    ) -> StepRecord:
        """Bill one decode step of the live batch.

        Every active slot's request is credited ``slot_tokens`` real
        tokens (clamped at its remaining ``gen_len``); its admission
        commitment moves pro rata into the current interval's predicted
        pool (``inflight_j``), so the budget view is unchanged by the
        move.  Requests that finish retire immediately — their slot
        drains and is reusable at the next :meth:`admit`.  Padded slots
        (free/draining) bill nothing but count in ``decoded_tokens``:
        the fixed compiled batch shape ran them, and the pricer's
        correction must price what the hardware actually did.
        """
        rids: list[int] = []
        tokens: list[int] = []
        for slot, (rid, state) in enumerate(
            zip(self.slot_rids, self.slot_states)
        ):
            if state != SLOT_ACTIVE or rid is None:
                continue
            req = self._by_rid[rid]
            d = min(int(slot_tokens), max(req.gen_len - req.done_tokens, 0))
            if d > 0:
                move = (
                    req.committed_j * d / req.committed_tokens
                    if req.committed_tokens > 0
                    else 0.0
                )
                req.committed_j -= move
                req.committed_tokens -= d
                self.committed_j -= move
                self.inflight_j += move
                self._cur.predicted_j += move
                self._cur.occupancy[rid] = self._cur.occupancy.get(rid, 0) + d
                req.done_tokens += d
                rids.append(rid)
                tokens.append(d)
            if req.done_tokens >= req.gen_len:
                self._finish(req, slot)
        n_decoded = self.n_slots if decoded_slots is None else int(decoded_slots)
        decoded = int(slot_tokens) * n_decoded
        self._cur.steps += 1
        self._cur.decoded_tokens += decoded
        rec = StepRecord(
            index=len(self.steps),
            interval=self._cur.index,
            rids=tuple(rids),
            tokens=tuple(tokens),
            decoded_tokens=decoded,
        )
        self.steps.append(rec)
        return rec

    def _release_commitment(self, req: Request) -> None:
        self.committed_j -= req.committed_j
        req.committed_j = 0.0
        req.committed_tokens = 0

    def _finish(self, req: Request, slot: int) -> None:
        self._release_commitment(req)
        self.slot_states[slot] = SLOT_DRAINING
        if not req.finished:
            req.finished = True
            self.finished.append(req)

    def retire(self, rid: int, requeue: bool = False) -> Request:
        """Evict one live request, freeing its slot immediately.

        Its outstanding commitment is released; tokens already billed
        stay billed (their intervals settle normally — no double billing,
        no leak).  With ``requeue`` the request rejoins the queue to be
        re-admitted (and re-priced) later; otherwise it lands in
        ``evicted``.
        """
        slot = self._slot_of(rid)
        req = self._by_rid[rid]
        self._release_commitment(req)
        self.slot_states[slot] = SLOT_DRAINING
        if requeue:
            self.queue.append(req)
        else:
            req.evicted = True
            self.evicted.append(req)
        rec = obs_trace.active()
        if rec is not None:
            rec.instant(
                "sched:retire:requeue" if requeue else "sched:retire:evict",
                track="sched", value=float(rid),
            )
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter(
                "sched_retired_total", "requests evicted or requeued",
                mode="requeue" if requeue else "evict",
            ).inc()
        return req

    # --------------------------------------------------------- settlement
    def seal_interval(self) -> IntervalRecord | None:
        """Close the current step interval; returns it (None when empty).

        The serve loop calls this once per marker sync: the sealed
        interval's index lines up 1:1 with the marker occurrence that
        opened it, so measured marker-window energy settles by index.
        """
        if self._cur.steps == 0:
            return None
        sealed = self._cur
        self.intervals.append(sealed)
        self._cur = IntervalRecord(index=sealed.index + 1)
        rec = obs_trace.active()
        if rec is not None:
            rec.instant(
                f"sched:seal interval={sealed.index}", track="sched",
                value=float(sealed.decoded_tokens),
            )
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter("sched_intervals_sealed_total", "step intervals sealed").inc()
        return sealed

    def _settle(self, rec: IntervalRecord, energy_j: float, from_measurement: bool) -> None:
        if rec.measured_j is not None:
            raise ValueError(f"interval {rec.index} already settled")
        rec.measured_j = float(energy_j)
        rec.released = not from_measurement
        self.inflight_j -= rec.predicted_j
        self.spent_j += rec.measured_j
        if rec.occupancy:
            self._split_settled(
                list(rec.occupancy), list(rec.occupancy.values()), rec.measured_j
            )
        else:
            # the hardware drew power but no live request occupied a slot
            # (all padding): surfaced as overhead, never silently dropped
            self.overhead_j += rec.measured_j
        if from_measurement and rec.decoded_tokens:
            self.pricer.update(rec.decoded_tokens, rec.measured_j)
        trec = obs_trace.active()
        if trec is not None:
            trec.instant(
                f"sched:{'settle' if from_measurement else 'release'}"
                f" interval={rec.index}",
                track="sched", value=rec.measured_j,
            )
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter(
                "sched_intervals_settled_total",
                "intervals settled (measured) or released (predicted)",
                mode="measured" if from_measurement else "released",
            ).inc()
            reg.counter(
                "sched_settled_joules_total", "energy landed on intervals",
            ).inc(rec.measured_j)

    def settle_interval(self, index: int, measured_j: float) -> None:
        """Land the attributed energy of one sealed step interval.

        Splits by real-token share across the interval's occupancy matrix
        (per-request totals sum exactly to the settled total), releases
        the interval's predicted pool from the budget, charges the
        measured energy, and feeds the pricer's correction loop.
        """
        self._settle(self.intervals[index], measured_j, from_measurement=True)

    def release_interval(self, index: int) -> None:
        """Settle an interval whose energy could not be measured (ring
        evicted the span, markers lost to a fault): charge its *predicted*
        energy so the budget commitment is not leaked, without feeding the
        pricer."""
        self._settle(self.intervals[index], self.intervals[index].predicted_j,
                     from_measurement=False)

    def unsettled(self) -> list[int]:
        return [r.index for r in self.intervals if r.measured_j is None]

    @property
    def billed_j(self) -> float:
        """Per-request settled energy total (== spent_j − overhead_j)."""
        return float(sum(r.measured_j for r in self._by_rid.values()))


# ------------------------------------------------------- wave compat shim
@dataclass
class WaveRecord:
    """One scheduled wave and its energy accounting."""

    index: int
    rids: list[int]
    tokens: int = 0  # tokens credited to real requests (gen_len-clamped)
    #: tokens the hardware actually decoded, including padded batch slots —
    #: the denominator the pricer's J/token correction must use
    decoded_tokens: int = 0
    request_tokens: list[int] = field(default_factory=list)
    predicted_j: float = 0.0
    measured_j: float | None = None  # None until reconciled/released
    released: bool = False  # settled from prediction, not measurement


class EnergySloScheduler(_SloCore):
    """Wave-granularity compatibility shim over the continuous-batch core.

    Lifecycle per wave: :meth:`next_wave` (policy orders the queue, the
    scheduler admits a budget-feasible prefix), :meth:`complete_wave`
    (tokens decoded), :meth:`reconcile` (attributed wave energy lands,
    split across the wave's requests by token share, budget and pricer
    updated).  Reconciliation is allowed to lag by any number of waves.

    Commitments are per-request (each admitted request carries its own
    ``committed_j``), matching the step-granularity core; a wave's
    commitment is just the sum over its requests.  A wave is the
    degenerate one-interval case of :class:`ContinuousBatch`: one
    admission, one settlement, token shares as the occupancy matrix.
    `compare_policies` and the policy surface run identically on both.
    """

    def __init__(
        self,
        pricer: EnergyPricer,
        policy: Policy,
        max_batch: int,
        budget_j: float = math.inf,
        cap_w: float | None = None,
        power_of_batch=None,
    ):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        super().__init__(pricer, policy, budget_j, cap_w, power_of_batch)
        self.max_batch = int(max_batch)
        self.waves: list[WaveRecord] = []

    def _admission_bound(self) -> int:
        return self.max_batch

    def next_wave(self, now_s: float = 0.0) -> list[Request] | None:
        """Select the next wave, or None when the queue is empty / starved.

        The policy orders the queue and bounds the batch; the scheduler
        then walks that order admitting every request whose *re-priced*
        predicted energy still fits the remaining budget.  Admission is
        deliberately work-conserving: a request too expensive for the
        current remainder is skipped (not a barrier), so cheaper requests
        behind it keep the batch full — an expensive head-of-line request
        waits until commitments resolve or is eventually rejected as
        hopeless (predicted energy above the spent-adjusted budget alone),
        an SLO decision surfaced in ``rejected`` rather than a silent
        starve.
        """
        if not self.queue:
            return None
        ctx = self._context(now_s)
        order = self.policy.order(self.queue, ctx)
        limit = min(self.policy.batch_limit(self.queue, ctx), self.max_batch)
        if limit < 1:
            return None
        chosen: list[Request] = []
        predicted = 0.0
        remaining = self.remaining_budget_j
        for qi in order:
            if len(chosen) >= limit:
                break
            req = self.queue[qi]
            price = self.pricer.price_tokens(req.gen_len - req.done_tokens)
            if predicted + price > remaining:
                continue
            req.predicted_j = price
            chosen.append(req)
            predicted += price
        if not chosen:
            # Nothing fits *right now*.  Only requests that cannot fit the
            # budget even once every in-flight commitment resolves are
            # hopeless and rejected; the rest stay queued — the caller can
            # reconcile pending waves (freeing committed energy) and retry.
            self._reject_hopeless()
            return None
        for req in chosen:
            self.queue.remove(req)
            req.committed_j = req.predicted_j
            req.committed_tokens = max(req.gen_len - req.done_tokens, 0)
        wave = WaveRecord(
            index=len(self.waves), rids=[r.rid for r in chosen], predicted_j=predicted
        )
        self.waves.append(wave)
        self.committed_j += predicted
        return chosen

    # --------------------------------------------------------- completion
    def complete_wave(
        self,
        wave_index: int,
        tokens_per_request: int,
        decoded_tokens: int | None = None,
    ) -> None:
        """Record the tokens a wave decoded.

        Per-request credit is clamped at each request's remaining
        ``gen_len`` (a short request padded into a long wave does not get
        phantom tokens); ``decoded_tokens`` is what the hardware actually
        ran — including padded batch slots — and defaults to
        ``tokens_per_request × n_requests`` when no padding happened.
        """
        wave = self.waves[wave_index]
        wave.request_tokens = []
        for rid in wave.rids:
            req = self._by_rid[rid]
            d = min(tokens_per_request, max(req.gen_len - req.done_tokens, 0))
            req.done_tokens += d
            wave.request_tokens.append(d)
            if req.done_tokens >= req.gen_len and not req.finished:
                req.finished = True
                self.finished.append(req)
        wave.tokens = sum(wave.request_tokens)
        wave.decoded_tokens = (
            decoded_tokens
            if decoded_tokens is not None
            else tokens_per_request * len(wave.rids)
        )

    def _settle(self, wave: WaveRecord, energy_j: float, from_measurement: bool) -> None:
        wave.measured_j = float(energy_j)
        wave.released = not from_measurement
        for rid in wave.rids:
            req = self._by_rid[rid]
            self.committed_j -= req.committed_j
            req.committed_j = 0.0
            req.committed_tokens = 0
        # split by per-request token share; exact-sum residue handling is
        # the shared core's (same machinery as step-interval settlement)
        shares = (
            [float(t) for t in wave.request_tokens]
            if sum(wave.request_tokens)
            else [1.0] * len(wave.rids)
        )
        self._split_settled(wave.rids, shares, wave.measured_j)
        self.spent_j += wave.measured_j
        if from_measurement and wave.decoded_tokens:
            self.pricer.update(wave.decoded_tokens, wave.measured_j)

    def reconcile(self, wave_index: int, measured_j: float) -> None:
        """Land the attributed energy of one wave.

        Splits by token share across the wave's requests (so per-request
        totals sum exactly to the ledger total), releases the wave's
        predicted commitment from the budget, charges the measured energy,
        and feeds the pricer's correction loop.
        """
        wave = self.waves[wave_index]
        if wave.measured_j is not None:
            raise ValueError(f"wave {wave_index} already settled")
        self._settle(wave, measured_j, from_measurement=True)

    def release_wave(self, wave_index: int) -> None:
        """Settle a wave whose energy could not be measured (e.g. the ring
        evicted its span): charge its *predicted* energy so the budget
        commitment is not leaked forever, without feeding the pricer."""
        wave = self.waves[wave_index]
        if wave.measured_j is not None:
            raise ValueError(f"wave {wave_index} already settled")
        self._settle(wave, wave.predicted_j, from_measurement=False)

    # ------------------------------------------------------------ reports
    def unreconciled(self) -> list[int]:
        return [w.index for w in self.waves if w.measured_j is None]


def format_report_rows(rows: Sequence[dict]) -> str:
    """Render `report_rows` output as the per-request SLO accounting table."""
    lines = ["  rid client    tokens  predicted J  measured J  mJ/token"]
    for row in rows:
        lines.append(
            f"  {row['rid']:>3} {row['client']:<9} {row['tokens']:>5}  "
            f"{row['predicted_j']:>11.4f} {row['measured_j']:>11.4f}  "
            f"{row['mj_per_token']:>8.3f}"
        )
    return "\n".join(lines)
