"""Admission/batching policies for the energy-SLO scheduler.

A policy answers two questions per wave: in what order should queued
requests be admitted (``order``), and how large may the wave be
(``batch_limit``)?  The scheduler applies the joules budget on top, so
policies stay pure ranking/limiting logic and are directly comparable.

Three built-ins, benchmark-comparable via :func:`compare_policies`:

* ``throughput-max`` — fill every wave FIFO to the batch limit: most
  tokens/s, no regard for power or fairness;
* ``cap-strict``    — bound the wave batch so the *modelled* wave power
  stays under the cap (admission-side capping, complementing the
  governor's actuation-side cap);
* ``energy-fair``   — round-robin over clients ordered by cumulative
  measured energy, so one heavy client cannot starve the rest of the
  joules budget.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable, Mapping, Sequence

if TYPE_CHECKING:  # pragma: no cover
    from .scheduler import Request


@dataclass(frozen=True)
class SchedContext:
    """What the scheduler knows at wave-selection time."""

    max_batch: int
    remaining_budget_j: float
    cap_w: float | None = None
    #: modelled full-clock wave power for a batch size (from `OperatingGrid`)
    power_of_batch: Callable[[int], float] | None = None
    client_energy_j: Mapping[str, float] = field(default_factory=dict)
    now_s: float = 0.0


class Policy:
    """Base: FIFO order, full batches. Subclasses override either hook."""

    name = "fifo"

    def order(self, queue: Sequence["Request"], ctx: SchedContext) -> list[int]:
        return sorted(range(len(queue)), key=lambda i: (queue[i].arrival_s, queue[i].rid))

    def batch_limit(self, queue: Sequence["Request"], ctx: SchedContext) -> int:
        return ctx.max_batch

    def __repr__(self) -> str:  # pragma: no cover - debugging nicety
        return f"<{type(self).__name__} {self.name!r}>"


class ThroughputMaxPolicy(Policy):
    """Largest waves, FIFO admission: the tokens/s-at-any-cost baseline."""

    name = "throughput-max"


class CapStrictPolicy(Policy):
    """Never *schedule* a wave whose modelled power exceeds the cap.

    Complements the governor: the governor trims actuation when the
    measured fleet runs hot; this policy refuses to queue work that the
    model already predicts will blow the cap.  Falls back to batch 1 so
    progress is never fully blocked (a single-slot wave under a cap the
    hardware cannot meet is the governor's problem, not admission's).
    """

    name = "cap-strict"

    def __init__(self, headroom: float = 1.0):
        self.headroom = float(headroom)

    def batch_limit(self, queue: Sequence["Request"], ctx: SchedContext) -> int:
        if ctx.cap_w is None or ctx.power_of_batch is None:
            return ctx.max_batch
        best = 1
        for b in range(1, ctx.max_batch + 1):
            if ctx.power_of_batch(b) <= ctx.cap_w * self.headroom:
                best = b
        return best


class EnergyFairPolicy(Policy):
    """Round-robin clients by cumulative measured energy (least first).

    Orders queue slots by interleaving clients, with the least-charged
    client's requests first — so the joules budget drains evenly across
    clients instead of first-come-first-burned.
    """

    name = "energy-fair"

    def order(self, queue: Sequence["Request"], ctx: SchedContext) -> list[int]:
        per_client: dict[str, list[int]] = {}
        for i in sorted(
            range(len(queue)), key=lambda i: (queue[i].arrival_s, queue[i].rid)
        ):
            per_client.setdefault(queue[i].client, []).append(i)
        clients = sorted(
            per_client, key=lambda c: (ctx.client_energy_j.get(c, 0.0), c)
        )
        out: list[int] = []
        rank = 0
        while len(out) < len(queue):
            for c in clients:
                slots = per_client[c]
                if rank < len(slots):
                    out.append(slots[rank])
            rank += 1
        return out


POLICIES: dict[str, Callable[[], Policy]] = {
    ThroughputMaxPolicy.name: ThroughputMaxPolicy,
    CapStrictPolicy.name: CapStrictPolicy,
    EnergyFairPolicy.name: EnergyFairPolicy,
}


def get_policy(name: str) -> Policy:
    try:
        return POLICIES[name]()
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; choose from {sorted(POLICIES)}")


# ------------------------------------------------------------------ compare
@dataclass(frozen=True)
class PolicyScore:
    """One policy's run over the canned comparison workload."""

    name: str
    tokens_per_s: float
    j_per_token: float
    peak_wave_w: float
    fairness_spread_j: float  # max - min cumulative client energy
    waves: int
    finished: int


def _execute_churn(
    sched,
    requests,
    power_of_batch: Callable[[int], float],
    time_of_batch: Callable[[int], float],
    measured_bias: float,
    steps_per_interval: int,
) -> PolicyScore:
    """Analytic step executor: churn over a `ContinuousBatch` slot model.

    Requests arrive mid-decode by ``arrival_s``, admissions happen at step
    -interval boundaries, completions retire slots immediately, and each
    step's power/time follow the *live occupancy* (not the compiled batch
    shape) so policies are scored on what the batch actually did.
    """
    pending = sorted(requests, key=lambda r: (r.arrival_s, r.rid))
    total_tokens = 0
    total_time = 0.0
    total_j = 0.0
    peak_w = 0.0
    now = 0.0
    while True:
        while pending and pending[0].arrival_s <= now + 1e-12:
            sched.submit(pending.pop(0))
        sched.admit(now)
        if not sched.live_rids:
            if sched.queue:
                break  # budget-starved: nothing admissible ever again
            if pending:
                now = max(now, pending[0].arrival_s)
                continue
            break
        interval_j = 0.0
        for _ in range(max(steps_per_interval, 1)):
            if not sched.live_rids:
                break
            occ = sched.n_active
            watts = power_of_batch(occ)
            dt = time_of_batch(occ)
            rec = sched.step_billing(1, decoded_slots=occ)
            interval_j += watts * dt
            total_tokens += rec.billed_tokens
            total_time += dt
            peak_w = max(peak_w, watts)
            now += dt
            # mid-interval arrivals queue up; admitted at the next boundary
            while pending and pending[0].arrival_s <= now + 1e-12:
                sched.submit(pending.pop(0))
        sealed = sched.seal_interval()
        if sealed is not None:
            measured = interval_j * measured_bias
            sched.settle_interval(sealed.index, measured)
            total_j += measured
    energies = list(sched.client_energy_j.values()) or [0.0]
    return PolicyScore(
        name=sched.policy.name,
        tokens_per_s=total_tokens / total_time if total_time else 0.0,
        j_per_token=total_j / total_tokens if total_tokens else 0.0,
        peak_wave_w=peak_w,
        fairness_spread_j=max(energies) - min(energies),
        waves=len(sched.intervals),
        finished=len(sched.finished),
    )


def compare_policies(
    n_requests: int = 24,
    n_clients: int = 3,
    max_batch: int = 8,
    gen_len_range: tuple[int, int] = (16, 64),
    cap_w: float | None = None,
    j_per_token: float | None = None,
    budget_frac: float | None = None,
    power_of_batch: Callable[[int], float] | None = None,
    time_of_batch: Callable[[int], float] | None = None,
    measured_bias: float = 1.1,
    seed: int = 0,
    policies: Sequence[str] | None = None,
    churn: bool = False,
    arrival_spread_s: float = 0.05,
    steps_per_interval: int = 4,
) -> dict[str, PolicyScore]:
    """Run each policy over one synthetic workload; analytic execution.

    Every policy sees the identical request set (same seed): per-batch time
    and power come from the supplied batch models (defaults: linear power,
    constant step time), measured energy is the prediction scaled by
    ``measured_bias`` so the pricer's reconciliation loop is exercised.
    ``budget_frac`` scarcifies the joules budget to that fraction of the
    workload's total predicted cost — fairness only differentiates
    policies when there is not enough energy for everyone.  Scores are
    directly comparable — this is what the sched tests pin the policy
    ranking with.

    Two executors share the scoring surface:

    * the default **wave** executor (`EnergySloScheduler`): serial waves,
      each decoding every member to the longest request — the legacy
      granularity, kept byte-identical for the pinned ranking tests;
    * ``churn=True`` runs the **step** executor (`ContinuousBatch`):
      arrivals spread over ``arrival_spread_s`` join the live batch
      mid-decode, completions free slots immediately, and power follows
      the per-step occupancy.  ``PolicyScore.waves`` then counts sealed
      step intervals and ``peak_wave_w`` the peak *step* power.
    """
    import numpy as np

    from .scheduler import ContinuousBatch, EnergyPricer, EnergySloScheduler, Request

    power_of_batch = power_of_batch or (lambda b: 80.0 + 15.0 * b)
    time_of_batch = time_of_batch or (lambda b: 1e-3)
    if j_per_token is None:
        # price consistently with the wave-execution models, so predictions
        # track measurements up to `measured_bias` and the budget is honest
        j_per_token = (
            power_of_batch(max_batch) * time_of_batch(max_batch) / max_batch
        )
    rng = np.random.default_rng(seed)
    gen_lens = rng.integers(gen_len_range[0], gen_len_range[1] + 1, size=n_requests)
    clients = [f"client{int(rng.integers(n_clients))}" for _ in range(n_requests)]
    budget_j = math.inf
    if budget_frac is not None:
        budget_j = budget_frac * j_per_token * float(np.sum(gen_lens))
    arrivals = None
    if churn:
        # drawn *after* the shared draws so the wave path stays byte-identical
        arrivals = np.sort(rng.uniform(0.0, arrival_spread_s, size=n_requests))

    out: dict[str, PolicyScore] = {}
    for pname in policies or sorted(POLICIES):
        policy = get_policy(pname)
        if churn:
            sched = ContinuousBatch(
                EnergyPricer(j_per_token=j_per_token),
                policy,
                n_slots=max_batch,
                budget_j=budget_j,
                cap_w=cap_w,
                power_of_batch=power_of_batch,
            )
            out[pname] = _execute_churn(
                sched,
                [
                    Request(rid=rid, client=clients[rid],
                            gen_len=int(gen_lens[rid]),
                            arrival_s=float(arrivals[rid]))
                    for rid in range(n_requests)
                ],
                power_of_batch,
                time_of_batch,
                measured_bias,
                steps_per_interval,
            )
            continue
        sched = EnergySloScheduler(
            EnergyPricer(j_per_token=j_per_token),
            policy,
            max_batch=max_batch,
            budget_j=budget_j,
            cap_w=cap_w,
            power_of_batch=power_of_batch,
        )
        for rid in range(n_requests):
            sched.submit(
                Request(rid=rid, client=clients[rid], gen_len=int(gen_lens[rid]))
            )
        total_tokens = 0
        total_time = 0.0
        total_j = 0.0
        peak_w = 0.0
        now = 0.0
        while True:
            wave = sched.next_wave(now)
            if wave is None:
                break
            b = len(wave)
            steps = max(r.gen_len for r in wave)
            # one wave decodes each admitted request to completion (padded
            # slots keep decoding to the longest request, as serve.py does)
            sched.complete_wave(sched.waves[-1].index, steps)
            tokens = steps * b
            t_wave = time_of_batch(b) * steps
            watts = power_of_batch(b)
            measured = watts * t_wave * measured_bias
            sched.reconcile(sched.waves[-1].index, measured)
            total_tokens += tokens
            total_time += t_wave
            total_j += measured
            peak_w = max(peak_w, watts)
            now += t_wave
        energies = list(sched.client_energy_j.values()) or [0.0]
        out[pname] = PolicyScore(
            name=pname,
            tokens_per_s=total_tokens / total_time if total_time else 0.0,
            j_per_token=total_j / total_tokens if total_tokens else 0.0,
            peak_wave_w=peak_w,
            fairness_spread_j=max(energies) - min(energies),
            waves=len(sched.waves),
            finished=len(sched.finished),
        )
    return out
