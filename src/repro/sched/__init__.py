"""`repro.sched` — closed-loop energy-aware serving over the sensor fleet.

The first subsystem where measurement closes the loop back into the
workload: 20 kHz fleet telemetry (`repro.stream`) and per-kernel energy
attribution (`repro.attrib`) feed a controller and a scheduler that
*drive* the serving plant instead of just watching it.

* `governor`  — `PowerCapGovernor`: PI power-cap control (anti-windup,
  hysteresis, minimum dwell) actuating modelled DVFS states × decode
  batch (`OperatingGrid`) over a `VirtualPlant` of sensor devices;
* `scheduler` — `ContinuousBatch`: joule-priced continuous batching at
  step granularity (requests join/leave the live decode batch per step;
  per-request budget commitments; measured step-interval energy split
  across slot occupants), plus `EnergySloScheduler`, the wave-granularity
  compatibility shim over the same core (`EnergyPricer` from attrib
  ledgers / per-kernel signatures / model phases, measured-vs-predicted
  reconciliation per wave);
* `policies`  — throughput-max, cap-strict and energy-fair policies plus
  `compare_policies`, the benchmark-comparable harness (wave and churn
  executors).

Integration points: `launch.serve` (the serving step loop is scheduler
driven), `benchmarks/governor_cap.py` (cap adherence at 20 kHz vs
builtin-counter telemetry rates), `benchmarks/serving_churn.py`
(step-vs-wave billing error under churn), `examples/governor_serve.py`.
"""
from .governor import (
    GovernorConfig,
    GovernorStatus,
    OperatingGrid,
    OperatingPoint,
    PiController,
    PowerCapGovernor,
    SampledPowerReader,
    VirtualPlant,
    decode_cost_of_batch,
    settle_time,
    time_over_cap,
)
from .policies import (
    POLICIES,
    CapStrictPolicy,
    EnergyFairPolicy,
    Policy,
    PolicyScore,
    SchedContext,
    ThroughputMaxPolicy,
    compare_policies,
    get_policy,
)
from .scheduler import (
    ContinuousBatch,
    EnergyPricer,
    EnergySloScheduler,
    IntervalRecord,
    Request,
    StepRecord,
    WaveRecord,
    format_report_rows,
)

__all__ = [
    "GovernorConfig",
    "GovernorStatus",
    "OperatingGrid",
    "OperatingPoint",
    "PiController",
    "PowerCapGovernor",
    "SampledPowerReader",
    "VirtualPlant",
    "decode_cost_of_batch",
    "settle_time",
    "time_over_cap",
    "POLICIES",
    "CapStrictPolicy",
    "EnergyFairPolicy",
    "Policy",
    "PolicyScore",
    "SchedContext",
    "ThroughputMaxPolicy",
    "compare_policies",
    "get_policy",
    "ContinuousBatch",
    "EnergyPricer",
    "EnergySloScheduler",
    "IntervalRecord",
    "Request",
    "StepRecord",
    "WaveRecord",
    "format_report_rows",
]
