"""`repro.sched` — closed-loop energy-aware serving over the sensor fleet.

The first subsystem where measurement closes the loop back into the
workload: 20 kHz fleet telemetry (`repro.stream`) and per-kernel energy
attribution (`repro.attrib`) feed a controller and a scheduler that
*drive* the serving plant instead of just watching it.

* `governor`  — `PowerCapGovernor`: PI power-cap control (anti-windup,
  hysteresis, minimum dwell) actuating modelled DVFS states × decode
  batch (`OperatingGrid`) over a `VirtualPlant` of sensor devices;
* `scheduler` — `EnergySloScheduler`: joule-priced admission and wave
  batching (`EnergyPricer` from attrib ledgers / per-kernel signatures /
  model phases), with measured-vs-predicted reconciliation per wave;
* `policies`  — throughput-max, cap-strict and energy-fair policies plus
  `compare_policies`, the benchmark-comparable harness.

Integration points: `launch.serve` (the serving wave loop is scheduler
driven), `benchmarks/governor_cap.py` (cap adherence at 20 kHz vs
builtin-counter telemetry rates), `examples/governor_serve.py`.
"""
from .governor import (
    GovernorConfig,
    GovernorStatus,
    OperatingGrid,
    OperatingPoint,
    PiController,
    PowerCapGovernor,
    SampledPowerReader,
    VirtualPlant,
    decode_cost_of_batch,
    settle_time,
    time_over_cap,
)
from .policies import (
    POLICIES,
    CapStrictPolicy,
    EnergyFairPolicy,
    Policy,
    PolicyScore,
    SchedContext,
    ThroughputMaxPolicy,
    compare_policies,
    get_policy,
)
from .scheduler import (
    EnergyPricer,
    EnergySloScheduler,
    Request,
    WaveRecord,
    format_report_rows,
)

__all__ = [
    "GovernorConfig",
    "GovernorStatus",
    "OperatingGrid",
    "OperatingPoint",
    "PiController",
    "PowerCapGovernor",
    "SampledPowerReader",
    "VirtualPlant",
    "decode_cost_of_batch",
    "settle_time",
    "time_over_cap",
    "POLICIES",
    "CapStrictPolicy",
    "EnergyFairPolicy",
    "Policy",
    "PolicyScore",
    "SchedContext",
    "ThroughputMaxPolicy",
    "compare_policies",
    "get_policy",
    "EnergyPricer",
    "EnergySloScheduler",
    "Request",
    "WaveRecord",
    "format_report_rows",
]
