"""`repro.launch` — mesh, input specs, dry-run, roofline, train/serve CLIs.

Importing this package never touches jax device state (meshes are built
by functions, the dry-run sets XLA_FLAGS itself).
"""
