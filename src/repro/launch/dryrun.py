import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks the device count on first
# init).  Only the dry-run forces 512 host devices; tests/benches see 1.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Per cell this produces (cached as JSON under experiments/dryrun/):

* proof-of-compile on the production mesh — 16×16 (pod) and 2×16×16
  (multi-pod);
* `memory_analysis()` (bytes per device) and `cost_analysis()`;
* the collective schedule (op kinds / counts / ring wire bytes);
* compositional exact costs (repro.launch.components) and the three
  roofline terms (repro.launch.roofline).

Usage:
    python -m repro.launch.dryrun --arch grok-1-314b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
    python -m repro.launch.dryrun ... --skip-costs   (compile proof only)
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCH_IDS, ALIASES, SHAPES, RunConfig, get_config
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as rl
from repro.launch.components import compute_cell_costs
from repro.launch.specs import build_cell, default_run_config

DEFAULT_OUT = "experiments/dryrun"


def run_cell(arch: str, shape_name: str, multi_pod: bool, run_cfg=None,
             skip_costs: bool = False, tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    n_dev = 512 if multi_pod else 256
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    run_cfg = run_cfg or default_run_config(shape.kind)
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, run_cfg)
    lowered = jax.jit(
        cell.fn, out_shardings=cell.out_shardings, donate_argnums=cell.donate
    ).lower(*cell.args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = rl.memory_analysis_dict(compiled)
    print(compiled.memory_analysis())
    ca = rl.cost_analysis_dict(compiled)
    print({k: v for k, v in ca.items() if k in ("flops", "bytes accessed")})
    colls = rl.collective_wire_bytes(compiled.as_text())

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "n_devices": n_dev,
        "status": "ok",
        "lower_s": t_lower,
        "compile_s": t_compile,
        "memory": mem,
        "full_step_cost_analysis": {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "note": "scan bodies counted once; see components for exact costs",
        },
        "full_step_collectives": colls,
        "run_config": {
            "attn_impl": run_cfg.attn_impl, "q_chunk": run_cfg.q_chunk,
            "kv_chunk": run_cfg.kv_chunk, "remat": run_cfg.remat,
            "moe_impl": run_cfg.moe_impl, "ce_chunk": run_cfg.ce_chunk,
            "skip_masked_blocks": run_cfg.skip_masked_blocks,
        },
        "tag": tag,
    }

    if not skip_costs:
        costs = compute_cell_costs(cfg, shape, run_cfg, mesh)
        per_dev = costs["per_device"]
        report = rl.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name, cost=per_dev,
            model_flops_global=rl.model_flops(cfg, shape), n_devices=n_dev,
            memory=mem, collectives=colls, components=costs["components"],
        )
        result["roofline"] = report.to_dict()
    return result


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--skip-costs", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--tag", default="baseline")
    # hillclimb overrides
    ap.add_argument("--attn-impl", default=None)
    ap.add_argument("--q-chunk", type=int, default=None)
    ap.add_argument("--kv-chunk", type=int, default=None)
    ap.add_argument("--skip-masked-blocks", action="store_true")
    ap.add_argument("--moe-impl", default=None)
    ap.add_argument("--ce-chunk", type=int, default=None)
    ap.add_argument("--remat", default=None)
    ap.add_argument("--ce-impl", default=None)
    ap.add_argument("--decode-seq-shard", action="store_true")
    ap.add_argument("--constrain-activations", action="store_true")
    ap.add_argument("--accum", type=int, default=None)
    ap.add_argument("--bf16-params", action="store_true")
    args = ap.parse_args(argv)

    archs = ARCH_IDS if args.arch == "all" else [ALIASES.get(args.arch, args.arch)]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)

    failures = 0
    for arch in archs:
        cfg = get_config(arch)
        for shape_name in shapes:
            if shape_name == "long_500k" and not cfg.supports_long_context:
                print(f"SKIP {arch} long_500k (full attention; DESIGN.md §4)")
                continue
            for mp in meshes:
                from dataclasses import replace as _r

                run_cfg = default_run_config(SHAPES[shape_name].kind)
                for field in ("attn_impl", "moe_impl", "remat", "ce_impl"):
                    v = getattr(args, field)
                    if v is not None:
                        run_cfg = _r(run_cfg, **{field: v})
                for field in ("q_chunk", "kv_chunk", "ce_chunk"):
                    v = getattr(args, field)
                    if v is not None:
                        run_cfg = _r(run_cfg, **{field: v})
                if args.skip_masked_blocks:
                    run_cfg = _r(run_cfg, skip_masked_blocks=True)
                if args.decode_seq_shard:
                    run_cfg = _r(run_cfg, decode_seq_shard=True)
                if args.constrain_activations:
                    run_cfg = _r(run_cfg, constrain_activations=True)
                if args.accum is not None:
                    run_cfg = _r(run_cfg, accum_steps=args.accum)
                if args.bf16_params:
                    run_cfg = _r(run_cfg, bf16_params=True)
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                out_path = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}__{args.tag}.json"
                )
                label = f"{arch} × {shape_name} × {mesh_name}"
                print(f"=== {label} ===", flush=True)
                try:
                    result = run_cell(
                        arch, shape_name, mp, run_cfg,
                        skip_costs=args.skip_costs, tag=args.tag,
                    )
                except Exception as e:  # a failing cell is a bug — record it
                    traceback.print_exc()
                    result = {
                        "arch": arch, "shape": shape_name, "mesh": mesh_name,
                        "status": "error", "error": f"{type(e).__name__}: {e}",
                        "tag": args.tag,
                    }
                    failures += 1
                with open(out_path, "w") as f:
                    json.dump(result, f, indent=1)
                print(f"-> {out_path} [{result['status']}]", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
