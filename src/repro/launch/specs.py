"""Per-cell input specs: ShapeDtypeStruct stand-ins for every model input.

`build_cell(arch, shape, mesh)` returns everything the dry-run needs to
``jax.jit(fn, ...).lower(*args)`` one (architecture × input-shape × mesh)
cell: the step callable, sharded ShapeDtypeStructs for params / optimizer
state / batch / caches, and the out-shardings.  Nothing is allocated.

Enc-dec split (whisper, DESIGN.md §4): an assigned seq_len S becomes
T_enc = S/2 stub frame embeddings + T_dec = S/2 decoder tokens.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, RunConfig, SHAPES, ShapeSpec, get_config
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.train.step import TrainStepConfig, make_train_step

from . import mesh as mesh_lib


def _sds(tree, shardings):
    """Attach shardings to a matching eval_shape tree."""
    return jax.tree.map(
        lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s), tree, shardings
    )


def batch_shapes(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b, s = shape.global_batch, shape.seq_len
    # train batches carry the shifted target (+1); prefill consumes s tokens
    extra = 1 if shape.kind == "train" else 0
    if cfg.is_encdec:
        enc, dec = s // 2, s // 2
        return {
            "frames": jax.ShapeDtypeStruct((b, enc, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((b, dec + extra), jnp.int32),
        }
    return {"tokens": jax.ShapeDtypeStruct((b, s + extra), jnp.int32)}


@dataclass
class Cell:
    arch: str
    shape: ShapeSpec
    kind: str
    fn: Callable
    args: tuple
    out_shardings: Any
    model: Any
    donate: tuple = ()
    meta: dict | None = None


def default_run_config(kind: str) -> RunConfig:
    if kind == "train":
        return RunConfig(remat="layer")
    return RunConfig(remat="none")


def input_specs(arch: str, shape_name: str, mesh, run: RunConfig | None = None) -> Cell:
    """The dry-run entry point (the name the assignment asks for)."""
    return build_cell(arch, shape_name, mesh, run)


def build_cell(arch: str, shape_name: str, mesh, run: RunConfig | None = None) -> Cell:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.name == "long_500k" and not cfg.supports_long_context:
        raise ValueError(f"{arch} is pure full-attention: long_500k is skipped (DESIGN.md §4)")
    run = run or default_run_config(shape.kind)
    if run.constrain_activations:
        from repro.models import sharding_ctx

        sharding_ctx.set_mesh(mesh)
    model = build_model(cfg, run)

    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if run.bf16_params:
        params_shape = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype
            ),
            params_shape,
        )
    p_sh = mesh_lib.params_shardings(mesh, params_shape)
    params_sds = _sds(params_shape, p_sh)

    if shape.kind == "train":
        opt_shape = jax.eval_shape(
            lambda p: init_opt_state(p, master_weights=run.bf16_params), params_shape
        )
        o_sh = mesh_lib.opt_state_shardings(mesh, opt_shape)
        opt_sds = _sds(opt_shape, o_sh)
        bshape = batch_shapes(cfg, shape)
        b_sh = mesh_lib.batch_shardings(mesh, bshape)
        batch_sds = _sds(bshape, b_sh)
        fn = make_train_step(model, AdamWConfig(), TrainStepConfig(run.accum_steps))
        return Cell(
            arch, shape, "train", fn, (params_sds, opt_sds, batch_sds),
            out_shardings=(p_sh, o_sh, None), model=model, donate=(0, 1),
        )

    if shape.kind == "prefill":
        bshape = batch_shapes(cfg, shape)
        b_sh = mesh_lib.batch_shardings(mesh, bshape)
        batch_sds = _sds(bshape, b_sh)

        if cfg.is_encdec:
            def fn(params, batch):
                return model.prefill(params, batch)
        else:
            def fn(params, batch):
                return model.prefill(params, batch["tokens"])

        return Cell(
            arch, shape, "prefill", fn, (params_sds, batch_sds),
            out_shardings=None, model=model,
        )

    # decode: one new token against a seq_len cache
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        cache_shape = jax.eval_shape(
            lambda: model.init_cache(b, max_len=s // 2, enc_len=s // 2)
        )
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(b, max_len=s))
    c_sh = mesh_lib.cache_shardings(mesh, cache_shape, seq_shard=run.decode_seq_shard)
    cache_sds = _sds(cache_shape, c_sh)
    tok_sh = mesh_lib.batch_shardings(
        mesh, {"t": jax.ShapeDtypeStruct((b,), jnp.int32)}
    )["t"]
    tok_sds = jax.ShapeDtypeStruct((b,), jnp.int32, sharding=tok_sh)

    def fn(params, cache, token):
        return model.decode_step(params, cache, token)

    return Cell(
        arch, shape, "decode", fn, (params_sds, cache_sds, tok_sds),
        out_shardings=(None, c_sh), model=model, donate=(1,),
    )
