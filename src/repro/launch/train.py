"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
        --steps 100 --ckpt-dir /tmp/ckpt

`--smoke` selects the reduced config (CPU-runnable); the full configs are
for real accelerators (and are exercised shape-wise by the dry-run).
Every run emits per-step energy telemetry through the TPU power model,
and `--psrun` wraps the whole job PowerSensor3-style (total J, avg W,
sensor-verified).
"""
from __future__ import annotations

import argparse
import json
from dataclasses import replace

import jax

from repro.configs import ALIASES, RunConfig, get_config, smoke_config
from repro.data import SyntheticTokens
from repro.launch import mesh as mesh_lib
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt_state
from repro.power import EnergyTelemetry, StepCost
from repro.train import FaultInjector, LoopConfig, train


def make_recording_attributor(path, telemetry, seed: int = 0, **kwargs):
    """A `StepAttributor` that also archives its sensor session.

    Taps the attributor's virtual-sensor ring after every step and writes
    a `repro.replay` trace archive (markers included) on ``finish()`` —
    so a training run's measured per-kernel energy can be re-attributed
    offline from the archive instead of re-running the job.
    """
    from repro.attrib import StepAttributor
    from repro.replay import SessionRecorder

    class _RecordingAttributor(StepAttributor):
        def __init__(self):
            super().__init__(telemetry, seed=seed, **kwargs)
            self.recorder = SessionRecorder(
                self.sensor, name="train", meta={"launcher": "train", "seed": seed}
            )

        def on_step(self) -> None:
            super().on_step()
            self.recorder.capture()

        def finish(self, min_coverage: float = 0.5):
            # archive before super() closes (and releases) the sensor
            self.sensor.poll()
            archive = self.recorder.save(path, extra_meta={"steps": self._steps})
            print(f"recorded {archive.n_frames} frames to {path} "
                  f"(replay: repro.replay.replay_sensor)")
            return super().finish(min_coverage)

    return _RecordingAttributor()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--crash-at", type=int, default=-1)
    ap.add_argument("--no-resume", action="store_true")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 -> (data=2, model=4)")
    ap.add_argument("--history-out", default=None)
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="attribute every step through the virtual sensor and "
                         "record the session to a replayable trace archive")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(attn_impl="full" if args.seq <= 512 else "chunked",
                    remat="none" if args.smoke else "layer", lr_chunk=16)
    model = build_model(cfg, run)
    data = SyntheticTokens(cfg, global_batch=args.batch, seq_len=args.seq, seed=args.seed)

    shardings = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = mesh_lib.make_mesh((d, m), ("data", "model"))
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(args.seed))
        opt_shape = jax.eval_shape(init_opt_state, params_shape)
        batch_shape = jax.eval_shape(lambda: data.batch_at(0))
        shardings = {
            "params": mesh_lib.params_shardings(mesh, params_shape),
            "opt": mesh_lib.opt_state_shardings(mesh, opt_shape),
            "batch": mesh_lib.batch_shardings(mesh, batch_shape),
        }

    # energy telemetry: per-step cost from the analytic model estimate
    n = cfg.param_count_estimate()
    tokens_per_step = args.batch * args.seq
    cost = StepCost(
        flops=6.0 * n * tokens_per_step,
        hbm_bytes=12.0 * n + 4.0 * tokens_per_step * cfg.d_model * cfg.n_layers,
        ici_bytes=0.0,
    )
    telemetry = EnergyTelemetry(
        cost_per_step=cost, n_layers=cfg.n_layers,
        useful_flops_per_step=6.0 * n * tokens_per_step,
    )

    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(5, args.steps // 20),
                          total_steps=args.steps)
    loop_cfg = LoopConfig(
        steps=args.steps, log_every=args.log_every, ckpt_every=args.ckpt_every,
        ckpt_dir=args.ckpt_dir, resume=not args.no_resume, seed=args.seed,
        accum_steps=args.accum,
    )
    injector = FaultInjector(args.crash_at) if args.crash_at >= 0 else None
    attributor = (
        make_recording_attributor(args.record, telemetry, seed=args.seed)
        if args.record
        else None
    )
    result = train(model, data, opt_cfg, loop_cfg, telemetry=telemetry,
                   fault_injector=injector, shardings=shardings,
                   attributor=attributor)
    summary = telemetry.summary()
    print(f"finished at step {result.stopped_at} (preempted={result.preempted})")
    if summary:
        print(
            f"energy(model): {summary['total_joules']:.1f} J total, "
            f"{summary['j_per_token']*1e3:.3f} mJ/token, "
            f"{summary['modelled_step_s']*1e3:.2f} ms/step on {telemetry.chip.name}"
        )
    if result.straggler_events:
        print(f"straggler events: {len(result.straggler_events)}")
    if args.history_out:
        with open(args.history_out, "w") as f:
            json.dump(result.history, f)
    return result


if __name__ == "__main__":
    main()
