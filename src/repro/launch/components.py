"""Compositional cost extraction (see roofline.py docstring).

`cost_analysis()` counts scan bodies once, so exact per-cell costs come
from two-point extrapolation over depth: lower the cell's step with
L=1 and L=2 layers (scans unrolled where they carry real work), then

    cost(L) = fixed + L · layer   ⇒   layer = c2 − c1, fixed = c1 − layer.

FLOPs/bytes are measured UNSHARDED on the global shapes (per-device =
global / n_devices under even sharding) — this keeps the unrolled
lowerings off the SPMD partitioner.  Collective wire bytes are measured
from SHARDED L∈{1,2} lowerings with the layer loop unrolled (python
loop) but inner scans intact (collectives live at layer boundaries).
The optimizer update is elementwise over stacked params (no scan) and is
lowered once at full size.

Hybrid (zamba2) extrapolates over layer *groups* (6 Mamba layers + the
shared attention block); the 3-layer tail is counted as half a group's
Mamba share (documented approximation, <2 % of depth).
"""
from __future__ import annotations

from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import ArchConfig, RunConfig, ShapeSpec
from repro.models import build_model
from repro.optim import AdamWConfig, init_opt_state, apply_updates
from repro.power.tpu_model import StepCost

from . import mesh as mesh_lib
from .roofline import collective_wire_bytes


def _reduced_cfgs(cfg: ArchConfig) -> tuple[ArchConfig, ArchConfig, float]:
    """(cfg_L1, cfg_L2, multiplier) for two-point depth extrapolation."""
    if cfg.family == "hybrid":
        g = cfg.attn_every
        mult = cfg.n_layers // g + (cfg.n_layers % g) / g * 0.5
        return (
            replace(cfg, n_layers=g),
            replace(cfg, n_layers=2 * g),
            mult,
        )
    if cfg.is_encdec:
        return (
            replace(cfg, n_layers=2, enc_layers=1, dec_layers=1),
            replace(cfg, n_layers=4, enc_layers=2, dec_layers=2),
            float(cfg.enc_layers),  # enc_layers == dec_layers for whisper
        )
    return (
        replace(cfg, n_layers=1),
        replace(cfg, n_layers=2),
        float(cfg.n_layers),
    )


def _unrolled(run: RunConfig) -> RunConfig:
    return replace(run, scan_layers=False, scan_unroll=True)


def _cost_of(lowered) -> StepCost:
    from .roofline import cost_analysis_dict

    ca = cost_analysis_dict(lowered.compile())
    return StepCost(
        flops=float(ca.get("flops", 0.0)),
        hbm_bytes=float(ca.get("bytes accessed", 0.0)),
        ici_bytes=0.0,
    )


def _coll_of(lowered) -> float:
    return collective_wire_bytes(lowered.compile().as_text())["total"]


def _step_fn_and_args(cfg: ArchConfig, shape: ShapeSpec, run: RunConfig, mesh=None):
    """Build (fn, args) for the cell's step at this cfg size.

    With `mesh` the args carry shardings; otherwise unsharded global
    shapes on the default (single) device.
    """
    from .specs import batch_shapes  # local import to avoid a cycle

    if run.constrain_activations:
        from repro.models import sharding_ctx

        sharding_ctx.set_mesh(mesh)  # None for the unsharded cost lowerings
    model = build_model(cfg, run)
    params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if run.bf16_params:
        params_shape = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if jnp.issubdtype(l.dtype, jnp.floating) else l.dtype
            ),
            params_shape,
        )
    if mesh is not None:
        p_sh = mesh_lib.params_shardings(mesh, params_shape)
        params_shape = jax.tree.map(
            lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
            params_shape, p_sh,
        )
    if shape.kind == "train":
        bshape = batch_shapes(cfg, shape)
        if mesh is not None:
            b_sh = mesh_lib.batch_shardings(mesh, bshape)
            bshape = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                bshape, b_sh,
            )

        def fn(params, batch):
            return jax.value_and_grad(lambda p: model.loss_fn(p, batch)[0])(params)

        return fn, (params_shape, bshape)
    if shape.kind == "prefill":
        bshape = batch_shapes(cfg, shape)
        if mesh is not None:
            b_sh = mesh_lib.batch_shardings(mesh, bshape)
            bshape = jax.tree.map(
                lambda l, s: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s),
                bshape, b_sh,
            )

        if cfg.is_encdec:
            def fn(params, batch):
                return model.prefill(params, batch)
        else:
            def fn(params, batch):
                return model.prefill(params, batch["tokens"])

        return fn, (params_shape, bshape)
    # decode
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        cache_shape = jax.eval_shape(lambda: model.init_cache(b, max_len=s // 2, enc_len=s // 2))
    else:
        cache_shape = jax.eval_shape(lambda: model.init_cache(b, max_len=s))
    tok = jax.ShapeDtypeStruct((b,), jnp.int32)
    if mesh is not None:
        c_sh = mesh_lib.cache_shardings(mesh, cache_shape, seq_shard=run.decode_seq_shard)
        cache_shape = jax.tree.map(
            lambda l, s_: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=s_),
            cache_shape, c_sh,
        )

    def fn(params, cache, token):
        return model.decode_step(params, cache, token)

    return fn, (params_shape, cache_shape, tok)


def compute_cell_costs(cfg: ArchConfig, shape: ShapeSpec, run: RunConfig, mesh,
                       include_collectives: bool = True) -> dict:
    """Returns global flops/bytes, per-device collective bytes, components."""
    c1_cfg, c2_cfg, mult = _reduced_cfgs(cfg)
    run_u = _unrolled(run)

    # ---- flops / bytes: unsharded two-point -------------------------------
    fn1, args1 = _step_fn_and_args(c1_cfg, shape, run_u, mesh=None)
    fn2, args2 = _step_fn_and_args(c2_cfg, shape, run_u, mesh=None)
    c1 = _cost_of(jax.jit(fn1).lower(*args1))
    c2 = _cost_of(jax.jit(fn2).lower(*args2))
    layer = StepCost(c2.flops - c1.flops, c2.hbm_bytes - c1.hbm_bytes, 0.0)
    fixed = StepCost(c1.flops - layer.flops, c1.hbm_bytes - layer.hbm_bytes, 0.0)
    total = StepCost(
        max(fixed.flops, 0.0) + mult * max(layer.flops, 0.0),
        max(fixed.hbm_bytes, 0.0) + mult * max(layer.hbm_bytes, 0.0),
        0.0,
    )

    # ---- optimizer update (train only): elementwise, lowered once ---------
    opt_cost = StepCost(0.0, 0.0, 0.0)
    if shape.kind == "train":
        model = build_model(cfg, run)
        params_shape = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        opt_shape = jax.eval_shape(
            lambda p: init_opt_state(p, master_weights=run.bf16_params), params_shape
        )
        grads_shape = params_shape

        def opt_fn(p, g, s):
            return apply_updates(p, g, s, AdamWConfig())

        opt_cost = _cost_of(jax.jit(opt_fn).lower(params_shape, grads_shape, opt_shape))
        total = total + opt_cost

    # ---- collective wire bytes: sharded two-point -------------------------
    coll_per_dev = 0.0
    coll_parts = {}
    if include_collectives and mesh is not None:
        run_c = replace(run, scan_layers=False)
        fn1s, args1s = _step_fn_and_args(c1_cfg, shape, run_c, mesh=mesh)
        fn2s, args2s = _step_fn_and_args(c2_cfg, shape, run_c, mesh=mesh)
        w1 = _coll_of(jax.jit(fn1s).lower(*args1s))
        w2 = _coll_of(jax.jit(fn2s).lower(*args2s))
        layer_w = max(w2 - w1, 0.0)
        fixed_w = max(w1 - layer_w, 0.0)
        coll_per_dev = fixed_w + mult * layer_w
        coll_parts = {"fixed": fixed_w, "per_layer": layer_w, "multiplier": mult}
        if shape.kind == "train":
            # gradient reduction across pods (params replicated per pod)
            if "pod" in mesh.shape and mesh.shape["pod"] > 1:
                import numpy as np

                n_params = cfg.param_count_estimate()
                g = mesh.shape["pod"]
                pod_ar = 2.0 * (n_params * 4 / (mesh.shape["data"] * mesh.shape["model"])) * (g - 1) / g
                coll_per_dev += pod_ar
                coll_parts["pod_grad_allreduce"] = pod_ar

    n_dev = mesh.size if mesh is not None else 1
    return {
        "global": total,
        "per_device": StepCost(total.flops / n_dev, total.hbm_bytes / n_dev, coll_per_dev),
        "components": {
            "layer": {"flops": layer.flops, "hbm_bytes": layer.hbm_bytes, "count": mult},
            "fixed": {"flops": fixed.flops, "hbm_bytes": fixed.hbm_bytes},
            "optimizer": {"flops": opt_cost.flops, "hbm_bytes": opt_cost.hbm_bytes},
            "collectives": coll_parts,
        },
    }
