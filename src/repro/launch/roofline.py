"""Roofline extraction from compiled dry-run artifacts.

Three per-device terms (TPU v5e-class constants from `repro.power`):

    t_compute    = HLO_FLOPs / peak_FLOP/s        (197 TF/s bf16)
    t_memory     = HLO_bytes / HBM_bw             (819 GB/s)
    t_collective = collective_bytes / ICI_bw      (4 × 50 GB/s links)

`cost_analysis()` counts a `lax.scan` body ONCE (verified empirically),
so per-cell costs are assembled **compositionally**: small per-component
lowerings (one layer fwd / fwd+bwd, embed+head+loss, optimizer update)
with their scans unrolled, multiplied by static repeat counts.  The full
step is still compiled — that artifact is the proof-of-compile, the
memory analysis and the collective *schedule*; the component sums are the
cost numbers.  Collective bytes use ring-algorithm wire formulas with
group sizes parsed from `replica_groups`.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.power.tpu_model import V5E, StepCost, TpuChipSpec

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\]\S*))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _bytes_of_shape(tok: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(tok):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_wire_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by op kind (ring formulas), plus op counts."""
    out = {"all-gather": 0.0, "all-reduce": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0}
    counts = dict.fromkeys(out, 0)
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_tok, kind = m.group(1), m.group(2)
        size = _bytes_of_shape(shape_tok)
        g = 1
        gm = _GROUPS_RE.search(line)
        if gm:
            g = int(gm.group(2))
        else:
            gb = _GROUPS_BRACE_RE.search(line)
            if gb:
                g = len(gb.group(1).split(","))
        if g <= 1:
            continue
        frac = (g - 1) / g
        if kind == "all-gather":
            wire = size * frac  # result shape is the gathered size
        elif kind == "all-reduce":
            wire = 2.0 * size * frac
        elif kind == "reduce-scatter":
            wire = size * frac
        elif kind == "all-to-all":
            wire = size * frac
        else:  # collective-permute
            wire = size
        out[kind] += wire
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def cost_of_lowered(lowered) -> StepCost:
    compiled = lowered.compile()
    ca = cost_analysis_dict(compiled)
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = collective_wire_bytes(compiled.as_text())["total"]
    return StepCost(flops=flops, hbm_bytes=byts, ici_bytes=coll)


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    cost: StepCost  # per device, per step
    model_flops_global: float
    n_devices: int
    chip: TpuChipSpec = field(default_factory=lambda: V5E)
    memory: dict | None = None
    collectives: dict | None = None
    components: dict | None = None

    @property
    def t_compute(self) -> float:
        return self.cost.flops / self.chip.peak_flops_bf16

    @property
    def t_memory(self) -> float:
        return self.cost.hbm_bytes / self.chip.hbm_bw

    @property
    def t_collective(self) -> float:
        return self.cost.ici_bytes / self.chip.ici_bw

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def step_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs (global) — remat/redundancy waste."""
        hlo_global = self.cost.flops * self.n_devices
        return self.model_flops_global / hlo_global if hlo_global else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of peak at the modelled step time —
        the MFU-analogue this container can compute without wall clocks."""
        if self.step_time <= 0:
            return 0.0
        useful_per_dev = self.model_flops_global / self.n_devices
        return useful_per_dev / self.step_time / self.chip.peak_flops_bf16

    def to_dict(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "n_devices": self.n_devices,
            "flops_per_dev": self.cost.flops,
            "hbm_bytes_per_dev": self.cost.hbm_bytes,
            "coll_bytes_per_dev": self.cost.ici_bytes,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "step_time_s": self.step_time,
            "model_flops_global": self.model_flops_global,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "memory": self.memory,
            "collectives": self.collectives,
            "components": self.components,
        }


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D prefill, 2·N·B decode.

    Enc-dec: each token passes through only half the stack (T_enc frames
    through the encoder, T_dec tokens through the decoder), so the
    effective token count is shape.tokens / 2.
    """
    n = cfg.param_count_estimate()
    tokens = shape.tokens / 2 if cfg.is_encdec else shape.tokens
    if shape.kind == "train":
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # one token per sequence


def cost_analysis_dict(compiled) -> dict:
    """`Compiled.cost_analysis()` normalised across jax versions: older
    releases return a one-element list of dicts, newer ones a plain dict."""
    ca = compiled.cost_analysis()
    if not ca:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


def memory_analysis_dict(compiled) -> dict:
    ma = compiled.memory_analysis()
    if ma is None:
        return {}
    keys = [
        "argument_size_in_bytes",
        "output_size_in_bytes",
        "temp_size_in_bytes",
        "alias_size_in_bytes",
        "generated_code_size_in_bytes",
    ]
    return {k: int(getattr(ma, k, 0)) for k in keys}
