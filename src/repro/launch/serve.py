"""Serving launcher: batched prefill + decode with energy telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 16 --prompt-len 64 --gen-len 32

Implements a minimal continuous-batching server loop: a queue of
synthetic requests, a fixed decode batch, slot recycling on completion.
Reports tokens/s (wall, CPU) and modelled J/token (TPU power model).

With ``--fleet N`` (default 2, ``--fleet 0`` disables) a `FleetMonitor`
over N virtual PowerSensor3 devices rides along: each device plays the
modelled per-shard serving power, request waves are bracketed with
time-synced markers, and per-request-wave **measured** J/token is
attributed from marker-aligned ring-buffer interval queries — the
psrun-style external check on the model's own telemetry.
"""
from __future__ import annotations

import argparse
import string
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config, smoke_config
from repro.models import build_model
from repro.power import EnergyTelemetry, StepCost

_WAVE_CHARS = string.ascii_uppercase + string.ascii_lowercase + string.digits


def _make_fleet(n_devices: int, total_watts: float, seed: int):
    """N virtual sensor devices, each playing one shard of the serving power."""
    from repro.core import ConstantLoad
    from repro.stream import make_virtual_fleet

    volts = 12.0
    per_dev = max(total_watts, 1e-3) / n_devices
    return make_virtual_fleet(
        [ConstantLoad(volts, per_dev / volts) for _ in range(n_devices)],
        seed=seed,
        window_s=0.5,
        ring_capacity=1 << 18,  # ~13 s of history per device at 20 kHz
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=2,
                    help="virtual PowerSensor3 devices for measured J/token (0 = off)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(attn_impl="full", remat="none", lr_chunk=16)
    model = build_model(cfg, run)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    max_len = args.prompt_len + args.gen_len
    b = args.decode_batch
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    decode = jax.jit(model.decode_step)

    pending = [
        rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    n = cfg.param_count_estimate()
    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2.0 * n * b, 2.0 * n, 0.0),
        n_layers=cfg.n_layers, useful_flops_per_step=2.0 * n * b,
    )

    fleet = None
    if args.fleet > 0:
        modelled_watts = (
            telemetry.modelled_step_joules / telemetry.modelled_step_time_s
            if telemetry.modelled_step_time_s
            else 0.0
        )
        fleet = _make_fleet(args.fleet, modelled_watts, args.seed)

    done_tokens = 0
    wave_tokens: list[int] = []
    # measured energy per wave, resolved incrementally (one wave after its
    # closing marker lands) so long runs never outlive the ring retention
    wave_reports: dict[int, tuple[float, int]] = {}
    max_waves = len(_WAVE_CHARS) - 1

    def _resolve_wave(k: int) -> None:
        if fleet is None or k < 0 or k in wave_reports or k >= max_waves:
            return
        per_dev = fleet.interval(_WAVE_CHARS[k], _WAVE_CHARS[k + 1])
        if per_dev:
            wave_reports[k] = (
                sum(iv.total_energy_j for iv in per_dev.values()), len(per_dev),
            )

    t0 = time.perf_counter()
    batch_idx = 0
    t_wave = t0
    while pending:
        batch = pending[:b]
        pending = pending[b:]
        while len(batch) < b:  # pad the last wave
            batch.append(batch[-1])
        if fleet is not None and batch_idx < max_waves:
            fleet.mark_all(_WAVE_CHARS[batch_idx])  # last char reserved as closer
        tokens = jnp.asarray(np.stack(batch))
        if cfg.is_encdec:
            frames = jnp.asarray(
                rng.standard_normal((b, args.prompt_len, cfg.d_model)), jnp.float32
            )
            logits, cache = jax.jit(
                lambda p, fr, t: model.prefill(p, {"frames": fr, "tokens": t}, max_len=max_len)
            )(params, frames, tokens)
        else:
            logits, cache = prefill(params, tokens)
        for i in range(args.gen_len):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
            logits, cache = decode(params, cache, tok)
            telemetry.record_step(batch_idx * args.gen_len + i, 0.0, b)
            done_tokens += b
        wave_tokens.append(b * args.gen_len)
        if fleet is not None:
            # devices play modelled power over the wave's wall time
            now = time.perf_counter()
            fleet.advance(now - t_wave)
            t_wave = now
            # this wave's advance flushed the previous wave's closing marker
            _resolve_wave(batch_idx - 1)
        batch_idx += 1
    if fleet is not None:
        fleet.mark_all(_WAVE_CHARS[min(batch_idx, max_waves)])  # closing bracket
        fleet.advance(0.01)  # flush the closing marker onto the stream
        if batch_idx <= max_waves:  # past that, the closer's time is wrong
            _resolve_wave(batch_idx - 1)
    dt = time.perf_counter() - t0
    s = telemetry.summary()
    print(f"served {args.requests} requests, {done_tokens} tokens in {dt:.2f}s "
          f"({done_tokens/dt:.1f} tok/s wall on CPU)")
    print(f"modelled: {s['j_per_token']*1e3:.3f} mJ/token, "
          f"{s['modelled_step_s']*1e3:.3f} ms/decode-step on {telemetry.chip.name}")
    if fleet is not None:
        snap = fleet.snapshot()
        print(f"fleet: {snap.aggregate.n_devices} devices, "
              f"{snap.aggregate.mean_w:.1f} W windowed mean, "
              f"{snap.aggregate.energy_j:.2f} J in window")
        for k in sorted(wave_reports):
            wave_j, n_dev = wave_reports[k]
            print(f"  wave {k}: measured {wave_j:.3f} J over "
                  f"{n_dev} devices -> "
                  f"{wave_j / wave_tokens[k] * 1e3:.3f} mJ/token")
        missing = batch_idx - len(wave_reports)
        if missing:
            print(f"  ({missing} waves not individually attributed: "
                  f"marker alphabet exhausted or ring history evicted)")
        fleet.close()


if __name__ == "__main__":
    main()
