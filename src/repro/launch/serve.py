"""Serving launcher: batched prefill + decode with energy telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 16 --prompt-len 64 --gen-len 32

Implements a minimal continuous-batching server loop: a queue of
synthetic requests, a fixed decode batch, slot recycling on completion.
Reports tokens/s (wall, CPU) and modelled J/token (TPU power model).

With ``--fleet N`` (default 2, ``--fleet 0`` disables) a `FleetMonitor`
over N virtual PowerSensor3 devices rides along: each device plays the
modelled per-shard serving power, every request wave is bracketed with
one occurrence of a single time-synced marker char, and per-wave
**measured** J/token comes from `repro.attrib.attribute` over the ring
buffers — occurrence-indexed, so any number of waves attribute cleanly
(the old per-wave marker *alphabet* wrapped after 62 waves and silently
returned the first occurrence's interval).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.attrib import EnergyLedger, KernelSpan, attribute_block, render_text
from repro.configs import RunConfig, get_config, smoke_config
from repro.models import build_model
from repro.power import EnergyTelemetry, StepCost

#: one char brackets every wave; wave k spans occurrences k .. k+1
_WAVE_MARK = "W"


def _make_fleet(n_devices: int, total_watts: float, seed: int):
    """N virtual sensor devices, each playing one shard of the serving power."""
    from repro.core import ConstantLoad
    from repro.stream import make_virtual_fleet

    volts = 12.0
    per_dev = max(total_watts, 1e-3) / n_devices
    return make_virtual_fleet(
        [ConstantLoad(volts, per_dev / volts) for _ in range(n_devices)],
        seed=seed,
        window_s=0.5,
        ring_capacity=1 << 18,  # ~13 s of history per device at 20 kHz
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=2,
                    help="virtual PowerSensor3 devices for measured J/token (0 = off)")
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(attn_impl="full", remat="none", lr_chunk=16)
    model = build_model(cfg, run)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    max_len = args.prompt_len + args.gen_len
    b = args.decode_batch
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    decode = jax.jit(model.decode_step)

    pending = [
        rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    n = cfg.param_count_estimate()
    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2.0 * n * b, 2.0 * n, 0.0),
        n_layers=cfg.n_layers, useful_flops_per_step=2.0 * n * b,
    )

    fleet = None
    if args.fleet > 0:
        modelled_watts = (
            telemetry.modelled_step_joules / telemetry.modelled_step_time_s
            if telemetry.modelled_step_time_s
            else 0.0
        )
        fleet = _make_fleet(args.fleet, modelled_watts, args.seed)

    done_tokens = 0
    wave_tokens: list[int] = []
    # measured per-wave energy, resolved incrementally (one wave after its
    # closing marker lands) so long runs never outlive the ring retention
    wave_ledger = EnergyLedger()
    wave_devices: dict[int, int] = {}  # wave index -> devices that attributed

    def _resolve_wave(k: int) -> None:
        """Attribute wave k (occurrences k..k+1 of the wave marker)."""
        if fleet is None or k < 0 or k in wave_devices:
            return
        n_dev = 0
        for name in fleet.names:
            hit = fleet.marker_window(name, _WAVE_MARK, occurrence=k, occurrence_b=k + 1)
            if hit is None:
                continue
            t0, t1, block = hit
            led = attribute_block(
                block, [KernelSpan(f"wave{k}", t0, t1)], min_coverage=0.9
            )
            if led.entries:
                wave_ledger.absorb(led)
                n_dev += 1
        if n_dev:
            wave_devices[k] = n_dev

    t0 = time.perf_counter()
    batch_idx = 0
    t_wave = t0
    while pending:
        batch = pending[:b]
        pending = pending[b:]
        while len(batch) < b:  # pad the last wave
            batch.append(batch[-1])
        if fleet is not None:
            fleet.mark_all(_WAVE_MARK)
        tokens = jnp.asarray(np.stack(batch))
        if cfg.is_encdec:
            frames = jnp.asarray(
                rng.standard_normal((b, args.prompt_len, cfg.d_model)), jnp.float32
            )
            logits, cache = jax.jit(
                lambda p, fr, t: model.prefill(p, {"frames": fr, "tokens": t}, max_len=max_len)
            )(params, frames, tokens)
        else:
            logits, cache = prefill(params, tokens)
        for i in range(args.gen_len):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
            logits, cache = decode(params, cache, tok)
            telemetry.record_step(batch_idx * args.gen_len + i, 0.0, b)
            done_tokens += b
        wave_tokens.append(b * args.gen_len)
        if fleet is not None:
            # devices play modelled power over the wave's wall time
            now = time.perf_counter()
            fleet.advance(now - t_wave)
            t_wave = now
            # this wave's advance flushed the previous wave's closing marker
            _resolve_wave(batch_idx - 1)
        batch_idx += 1
    if fleet is not None:
        fleet.mark_all(_WAVE_MARK)  # closing bracket of the last wave
        fleet.advance(0.01)  # flush the closing marker onto the stream
        _resolve_wave(batch_idx - 1)
    dt = time.perf_counter() - t0
    s = telemetry.summary()
    print(f"served {args.requests} requests, {done_tokens} tokens in {dt:.2f}s "
          f"({done_tokens/dt:.1f} tok/s wall on CPU)")
    print(f"modelled: {s['j_per_token']*1e3:.3f} mJ/token, "
          f"{s['modelled_step_s']*1e3:.3f} ms/decode-step on {telemetry.chip.name}")
    if fleet is not None:
        snap = fleet.snapshot()
        print(f"fleet: {snap.aggregate.n_devices} devices, "
              f"{snap.aggregate.mean_w:.1f} W windowed mean, "
              f"{snap.aggregate.energy_j:.2f} J in window")
        print(render_text(wave_ledger, title="per-wave measured energy"))
        for k in sorted(wave_devices):
            entry = wave_ledger.entries[f"wave{k}"]
            print(f"  wave {k}: measured {entry.energy_j:.3f} J over "
                  f"{wave_devices[k]} devices -> "
                  f"{entry.energy_j / wave_tokens[k] * 1e3:.3f} mJ/token")
        missing = batch_idx - len(wave_devices)
        if missing:
            print(f"  ({missing} waves not individually attributed: "
                  f"ring history evicted)")
        fleet.close()


if __name__ == "__main__":
    main()
