"""Serving launcher: batched prefill + decode with energy telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 16 --prompt-len 64 --gen-len 32

Implements a minimal continuous-batching server loop: a queue of
synthetic requests, a fixed decode batch, slot recycling on completion.
Reports tokens/s (wall, CPU) and modelled J/token (TPU power model).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RunConfig, get_config, smoke_config
from repro.models import build_model
from repro.power import EnergyTelemetry, StepCost


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(attn_impl="full", remat="none", lr_chunk=16)
    model = build_model(cfg, run)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    max_len = args.prompt_len + args.gen_len
    b = args.decode_batch
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    decode = jax.jit(model.decode_step)

    pending = [
        rng.integers(2, cfg.vocab_size, size=args.prompt_len).astype(np.int32)
        for _ in range(args.requests)
    ]
    n = cfg.param_count_estimate()
    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2.0 * n * b, 2.0 * n, 0.0),
        n_layers=cfg.n_layers, useful_flops_per_step=2.0 * n * b,
    )

    done_tokens = 0
    t0 = time.perf_counter()
    batch_idx = 0
    while pending:
        batch = pending[:b]
        pending = pending[b:]
        while len(batch) < b:  # pad the last wave
            batch.append(batch[-1])
        tokens = jnp.asarray(np.stack(batch))
        if cfg.is_encdec:
            frames = jnp.asarray(
                rng.standard_normal((b, args.prompt_len, cfg.d_model)), jnp.float32
            )
            logits, cache = jax.jit(
                lambda p, fr, t: model.prefill(p, {"frames": fr, "tokens": t}, max_len=max_len)
            )(params, frames, tokens)
        else:
            logits, cache = prefill(params, tokens)
        for i in range(args.gen_len):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
            logits, cache = decode(params, cache, tok)
            telemetry.record_step(batch_idx * args.gen_len + i, 0.0, b)
            done_tokens += b
        batch_idx += 1
    dt = time.perf_counter() - t0
    s = telemetry.summary()
    print(f"served {args.requests} requests, {done_tokens} tokens in {dt:.2f}s "
          f"({done_tokens/dt:.1f} tok/s wall on CPU)")
    print(f"modelled: {s['j_per_token']*1e3:.3f} mJ/token, "
          f"{s['modelled_step_s']*1e3:.3f} ms/decode-step on {telemetry.chip.name}")


if __name__ == "__main__":
    main()
