"""Serving launcher: continuous batching priced in joules.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 16 --prompt-len 64 --gen-len 32 --policy energy-fair

The main loop is a **step loop** over a fixed compiled decode batch,
driven by `repro.sched.ContinuousBatch`: requests join and leave the
live batch per decode step instead of per wave.

Slot lifecycle: each of the ``--decode-batch`` slots is *free*, *active*
(occupied by a live request) or *draining* (its request finished or was
evicted; the compiled batch shape still decodes the slot as padding,
which is excluded from billing and throughput, and the slot is reusable
at the next admission).  Admission happens between steps: the policy
(``--policy``: throughput-max, cap-strict, energy-fair) orders the queue
and bounds the number of live slots — so cap-strict holds the modelled
batch power under ``--cap-w`` at step boundaries even as completions and
arrivals churn the batch — and every admitted request takes a
per-request joules commitment against ``--budget-j``.  Admitted prompts
are prefilled at the compiled batch shape and their cache rows scattered
into the live decode cache (chunked prefill admission; batch-global
leaves such as the decode position clock are kept live).

Cache backends (``--kv``):

* ``dense`` (default) — one ``(L, B, S_max, Hkv, Dh)`` slab sized for the
  whole run; admission scatters freshly prefilled rows into the admitted
  slots (`_scatter_slots`), and a batch-global position clock marches
  every slot forward together, so a slot's row holds dead history until
  it is overwritten.
* ``paged`` — the slab becomes a `repro.kernels.paged_attention` page
  pool (``--page-size`` tokens per page).  Admission **allocates pages**
  (one all-or-nothing `PagedKVPool` reservation covering prompt +
  generation) and packs the prefilled rows into them; each decode step
  attends through per-slot page tables at per-slot *ragged* lengths via
  the paged flash-decode kernel — free/draining slots decode as
  ``kv_len == 0`` padding whose attention output is exact zeros (never
  NaN) — and retire **frees the pages** back to the pool for the next
  admission to reuse.  Needs attention layers (dense/moe families only);
  prefill runs at ``prompt_len``, not the run-global ``S_max``, so cache
  memory scales with *live* tokens instead of worst-case sequence length.

Step-interval attribution: with ``--fleet N`` (default 2, ``--fleet 0``
disables), every batch of ``--steps-per-sync`` decode steps — one *step
interval* — is bracketed by one occurrence of a single time-synced
marker char on every virtual PowerSensor3 device.  The measured interval
energy, attributed from the ring buffers via `repro.attrib`, is split
across the requests occupying slots during that interval by real-token
share and reconciled into the scheduler, correcting the `EnergyPricer`
online.  Wave markers are the degenerate one-interval case of the same
machinery.

Degraded-telemetry billing rules (what lands on a request's bill when
measurement is imperfect):

    condition                               billing rule
    --------------------------------------  ------------------------------
    interval measured on all devices        measured J, split by token share
    some devices missing the span           measured J scaled up by
                                            n_devices / n_measured (shards
                                            are identical by construction)
    span evicted / markers lost (faults)    released at *predicted* J —
                                            budget commitment settled, the
                                            pricer correction not fed
    padded (free/draining) slots            never billed; counted only in
                                            the pricer's decoded-token
                                            correction denominator
    no live request in the interval         settled as fleet overhead, not
                                            billed to any request
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.attrib import EnergyLedger, KernelSpan, attribute_block, render_text
from repro.configs import RunConfig, get_config, smoke_config
from repro.models import build_model
from repro.obs import trace as obs_trace
from repro.power import EnergyTelemetry, StepCost
from repro.sched import (
    POLICIES,
    ContinuousBatch,
    EnergyPricer,
    Request,
    format_report_rows,
    get_policy,
)

#: one char brackets every step interval; interval k spans occurrences
#: k .. k+1 of it (wave-era goldens use the same char, one wave = one
#: interval)
_STEP_MARK = "W"


def _make_fleet(n_devices: int, total_watts: float, seed: int):
    """N virtual sensor devices, each playing one shard of the serving power."""
    from repro.core import ConstantLoad
    from repro.stream import make_virtual_fleet

    volts = 12.0
    per_dev = max(total_watts, 1e-3) / n_devices
    return make_virtual_fleet(
        [ConstantLoad(volts, per_dev / volts) for _ in range(n_devices)],
        seed=seed,
        window_s=0.5,
        ring_capacity=1 << 18,  # ~13 s of history per device at 20 kHz
    )


def _cache_batch_axes(prefill_fn, params, example_inputs):
    """Which axis of every cache leaf is the batch axis (-1 = batch-global).

    Probed abstractly (`jax.eval_shape`, nothing runs) by prefilling the
    same prompt shape at batch 1 and batch 2 and diffing leaf shapes: the
    axis that grew is the batch axis; leaves that didn't grow (the decode
    position clock, shared norms) are batch-global and must *keep their
    live value* when new requests scatter in.
    """

    def rebatch(x, bb):
        return jax.ShapeDtypeStruct((bb,) + tuple(x.shape[1:]), x.dtype)

    def probe(bb):
        inputs = jax.tree.map(lambda x: rebatch(x, bb), example_inputs)
        _, cache = jax.eval_shape(prefill_fn, params, inputs)
        return cache

    c1, c2 = probe(1), probe(2)

    def axis(l1, l2):
        for a, (s1, s2) in enumerate(zip(l1.shape, l2.shape)):
            if s1 != s2:
                return a
        return -1

    return jax.tree.map(axis, c1, c2)


def _scatter_slots(live, fresh, axes, slots):
    """Copy the freshly prefilled rows of ``slots`` into the live cache.

    Per-leaf along its probed batch axis; batch-global leaves (axis -1)
    keep the live value so the shared decode clock never rewinds.
    """
    idx = jnp.asarray(slots, dtype=jnp.int32)

    def one(lv, fr, ax):
        if ax < 0:
            return lv
        lv0 = jnp.moveaxis(lv, ax, 0)
        fr0 = jnp.moveaxis(fr, ax, 0)
        return jnp.moveaxis(lv0.at[idx].set(fr0[idx]), 0, ax)

    return jax.tree.map(one, live, fresh, axes)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-batch", type=int, default=4,
                    help="compiled decode batch shape = number of slots")
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--kv", default="dense", choices=("dense", "paged"),
                    help="decode cache backend: one dense slab per layer, or "
                         "a paged pool with per-slot page tables")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (paged backend only)")
    ap.add_argument("--fleet", type=int, default=2,
                    help="virtual PowerSensor3 devices for measured J/token (0 = off)")
    ap.add_argument("--policy", default="throughput-max", choices=sorted(POLICIES))
    ap.add_argument("--clients", type=int, default=3,
                    help="synthetic clients round-robined across requests")
    ap.add_argument("--budget-j", type=float, default=0.0,
                    help="total joules budget for admission (0 = unlimited)")
    ap.add_argument("--cap-w", type=float, default=0.0,
                    help="fleet power cap for cap-strict admission (0 = uncapped)")
    ap.add_argument("--steps-per-sync", type=int, default=4,
                    help="decode steps per marker-bracketed step interval")
    ap.add_argument("--arrive-every", type=int, default=0,
                    help="request j arrives at decode step j*N (0 = all upfront) "
                         "— mid-decode churn")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="record the fleet session to a trace archive "
                         "(replayable via repro.replay; needs --fleet > 0)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="enable the flight recorder and write a "
                         "Chrome-trace-event JSON (Perfetto-loadable)")
    ap.add_argument("--metrics", default=None, metavar="PATH",
                    help="enable metrics and write a Prometheus text "
                         "snapshot at exit")
    args = ap.parse_args(argv)
    if args.record and args.fleet <= 0:
        ap.error("--record needs a sensor fleet (--fleet > 0)")

    if args.trace or args.metrics:
        from repro import obs

        obs.enable()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if args.kv == "paged" and (cfg.is_encdec or cfg.family not in ("dense", "moe")):
        ap.error(f"--kv paged needs dense/moe attention layers; "
                 f"{args.arch} is family {cfg.family!r}")
    run = RunConfig(attn_impl="full", remat="none", lr_chunk=16)
    model = build_model(cfg, run)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    b = args.decode_batch
    paged = args.kv == "paged"
    # dense: the position clock is batch-global — one cache serves every
    # request that ever occupies a slot, so its length must cover the whole
    # run.  paged: prefill only needs the prompt rows (decode growth lives
    # in pool pages at per-slot ragged lengths).
    if paged:
        max_len = args.prompt_len
    else:
        max_len = args.prompt_len + min(args.requests * args.gen_len, 4096)

    def _prefill_tokens(p, t):
        return model.prefill(p, t, max_len=max_len)

    def _prefill_encdec(p, inputs):
        return model.prefill(p, inputs, max_len=max_len)

    # both prefill paths jitted ONCE, next to the decoder — the compiled
    # batch shape is fixed, so admission never recompiles
    prefill = jax.jit(_prefill_tokens)
    prefill_encdec = jax.jit(_prefill_encdec)
    decode = jax.jit(model.decode_step)

    pool = None
    pcache = None
    if paged:
        from repro.kernels.paged_attention import (
            PagedKVPool, pack_prefill_pages, pages_for,
        )

        ps = args.page_size
        # one reservation per slot covers prompt + full generation, plus a
        # page of slack; +1 for the reserved null page
        table_width = pages_for(args.prompt_len + args.gen_len, ps) + 1
        pool = PagedKVPool(n_pages=1 + b * table_width, page_size=ps)
        pcache = model.init_paged_cache(pool.n_pages, ps)
        decode_paged = jax.jit(model.decode_step_paged)

    def _sweep_pool():
        """Free the pages of every request that left the live batch."""
        if pool is None:
            return
        for rid in pool.rids - set(sched.live_rids):
            pool.free(rid)

    def _make_inputs(prompts: np.ndarray):
        tokens = jnp.asarray(prompts)
        if cfg.is_encdec:
            frames = jnp.asarray(
                rng.standard_normal((b, args.prompt_len, cfg.d_model)), jnp.float32
            )
            return {"frames": frames, "tokens": tokens}
        return tokens

    def _prefill(inputs):
        if cfg.is_encdec:
            return prefill_encdec(params, inputs)
        return prefill(params, inputs)

    n = cfg.param_count_estimate()
    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2.0 * n * b, 2.0 * n, 0.0),
        n_layers=cfg.n_layers, useful_flops_per_step=2.0 * n * b,
    )

    # joule-priced admission: the per-kernel phase timeline prices one decode
    # step, the measured interval ledgers correct that price online
    pricer = EnergyPricer.from_phases(
        telemetry.phases, telemetry.chip, tokens_per_step=b, dvfs=telemetry.dvfs
    )
    modelled_watts = (
        telemetry.modelled_step_joules / telemetry.modelled_step_time_s
        if telemetry.modelled_step_time_s
        else 0.0
    )
    sched = ContinuousBatch(
        pricer,
        get_policy(args.policy),
        n_slots=b,
        budget_j=args.budget_j if args.budget_j > 0 else math.inf,
        cap_w=args.cap_w if args.cap_w > 0 else None,
        # modelled batch power scales weakly with live slots on this fleet
        # model: expose the telemetry estimate so cap-strict has something
        # to bound at every step-boundary admission
        power_of_batch=lambda bb: modelled_watts * (0.5 + 0.5 * bb / b) if b else 0.0,
    )
    pending = [
        Request(
            rid=rid,
            client=f"client{rid % max(args.clients, 1)}",
            prompt_len=args.prompt_len,
            gen_len=args.gen_len,
            payload=rng.integers(
                2, cfg.vocab_size, size=args.prompt_len
            ).astype(np.int32),
        )
        for rid in range(args.requests)
    ]

    fleet = None
    recorder = None
    if args.fleet > 0:
        fleet = _make_fleet(args.fleet, modelled_watts, args.seed)
        if args.record:
            from repro.replay import SessionRecorder

            recorder = SessionRecorder(
                fleet,
                meta={"launcher": "serve", "arch": args.arch,
                      "policy": args.policy, "seed": args.seed},
            )

    # measured per-interval energy, resolved incrementally (one interval
    # after its closing marker lands) so long runs never outlive the ring
    interval_ledger = EnergyLedger()
    interval_devices: dict[int, int] = {}  # interval -> devices that attributed
    interval_occ: dict[int, int] = {}  # interval -> its opening marker occurrence
    n_marks = 0  # total markers issued (flush marks shift occurrences)

    def _mark_fleet() -> None:
        nonlocal n_marks
        if fleet is not None:
            fleet.mark_all(_STEP_MARK)
            n_marks += 1

    def _resolve_interval(k: int) -> None:
        """Attribute step interval k (its marker occurrence pair) and settle.

        The fleet plays modelled watts over *wall* time (the marker span),
        so raw measured joules are inflated by the span/modelled time ratio
        (huge on CPU, ~1 on real hardware); the scheduler is settled on
        the modelled time base — each device's joules scaled by
        ``modelled interval time / span`` — so predicted and measured J
        stay in the same units and a ``--budget-j`` set from modelled
        numbers keeps meaning something.  The raw sensor joules stay in
        ``interval_ledger`` untouched.
        """
        if fleet is None or k < 0 or k in interval_devices or k not in interval_occ:
            return
        occ = interval_occ[k]  # the interval closes at the *next* marker
        modelled_s = telemetry.modelled_step_time_s * sched.intervals[k].steps
        n_dev = 0
        energy = 0.0
        for name in fleet.names:
            hit = fleet.marker_window(
                name, _STEP_MARK, occurrence=occ, occurrence_b=occ + 1
            )
            if hit is None:
                continue
            t0, t1, block = hit
            led = attribute_block(
                block, [KernelSpan(f"int{k}", t0, t1)], min_coverage=0.9
            )
            if led.entries:
                interval_ledger.absorb(led)
                dev_j = led.total_energy_j
                if modelled_s > 0 and t1 > t0:
                    dev_j *= modelled_s / (t1 - t0)
                energy += dev_j
                n_dev += 1
                orec = obs_trace.active()
                if orec is not None:
                    # attributed interval on the device timeline: the span
                    # the exporter aligns against control-plane spans
                    orec.device_span(f"int{k}", t0, t1,
                                     track=f"attr:{name}",
                                     value=led.total_energy_j)
        if n_dev:
            interval_devices[k] = n_dev
            # devices are identical shards: scale up for any whose ring had
            # already evicted the span, instead of silently undercounting
            energy *= len(fleet.names) / n_dev
            sched.settle_interval(k, energy)

    def _flush_and_settle(release_rest: bool) -> None:
        """Flush the open interval's closing marker; settle what measured,
        optionally release the rest at prediction."""
        if fleet is not None and sched.intervals:
            _mark_fleet()
            fleet.advance(0.01)
            for kk in list(sched.unsettled()):
                _resolve_interval(kk)
        if release_rest:
            for kk in list(sched.unsettled()):
                sched.release_interval(kk)

    t0 = time.perf_counter()
    t_sync = t0
    step_count = 0  # decode steps executed (the churn arrival clock)
    billed_tokens = 0  # real-request tokens (padded slots excluded)
    decoded_tokens = 0  # what the hardware ran, padded slots included
    logits = None
    cache = None
    cache_axes = None
    while True:
        # churn arrivals: request j reaches the queue at decode step j*N
        while pending and (
            args.arrive_every <= 0
            or step_count >= (pending[0].rid * args.arrive_every)
        ):
            sched.submit(pending.pop(0))
        admitted = sched.admit(time.perf_counter() - t0)
        if not sched.live_rids:
            if sched.queue and sched.unsettled():
                # blocked on in-flight interval settlements, not the hard
                # budget: flush the open interval's closing marker, settle,
                # release what can never measure, and retry admission
                _flush_and_settle(release_rest=True)
                admitted = sched.admit(time.perf_counter() - t0)
            if not admitted:
                if sched.queue:
                    break  # starved by the budget: accounted below
                if pending:
                    # idle until the next churn arrival is due
                    step_count = pending[0].rid * args.arrive_every
                    continue
                break
        if admitted:
            # chunked prefill admission at the compiled batch shape: the
            # admitted slots' prompt rows are real, the rest placeholder,
            # and only the admitted rows scatter into the live cache
            adm = dict(admitted)  # slot -> request
            filler = admitted[0][1].payload
            prompts = np.stack(
                [adm[i].payload if i in adm else filler for i in range(b)]
            )
            new_logits, new_cache = _prefill(_make_inputs(prompts))
            slots = [slot for slot, _ in admitted]
            if paged:
                # paged admission: allocate each request's reservation and
                # pack its prefilled rows into the granted pages — no dense
                # scatter, and draining occupants were swept back already
                _sweep_pool()
                kp, vp = pcache["layers"]["k"], pcache["layers"]["v"]
                for slot, req in admitted:
                    pages = pool.alloc(req.rid, req.prompt_len + req.gen_len)
                    assert pages is not None, "pool holds one reservation per slot"
                    pool.note_tokens(req.rid, req.prompt_len)
                    kp, vp = pack_prefill_pages(
                        kp, vp,
                        new_cache["layers"]["k"][:, slot],
                        new_cache["layers"]["v"][:, slot],
                        jnp.asarray(pages, jnp.int32),
                    )
                pcache = {"layers": {"k": kp, "v": vp}}
                idx = jnp.asarray(slots, dtype=jnp.int32)
                logits = (new_logits if logits is None
                          else logits.at[idx].set(new_logits[idx]))
            elif cache is None:
                logits, cache = new_logits, new_cache
            else:
                if cache_axes is None:
                    cache_axes = _cache_batch_axes(
                        _prefill_encdec if cfg.is_encdec else _prefill_tokens,
                        params,
                        _make_inputs(prompts),
                    )
                idx = jnp.asarray(slots, dtype=jnp.int32)
                logits = logits.at[idx].set(new_logits[idx])
                cache = _scatter_slots(cache, new_cache, cache_axes, slots)
        # one step interval: marker bracket + up to --steps-per-sync steps
        k = sched.current_interval
        interval_occ[k] = n_marks
        _mark_fleet()
        orec = obs_trace.active()
        int_t0_us = obs_trace.now_us() if orec is not None else 0
        for _ in range(max(args.steps_per_sync, 1)):
            if not sched.live_rids:
                break
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
            if paged:
                # per-slot ragged state from the pool: draining/free slots
                # decode as kv_len == 0 padding (exact-zero attention)
                live_set = set(sched.live_rids)
                slot_r = [r if r in live_set else None for r in sched.slot_rids]
                table = jnp.asarray(pool.table(slot_r, table_width))
                lens = jnp.asarray(pool.kv_lens(slot_r))
                live_m = jnp.asarray([r is not None for r in slot_r])
                logits, pcache = decode_paged(
                    params, pcache, tok, table, lens, live_m
                )
                for r in slot_r:
                    if r is not None:
                        assert pool.append(r), "reservation covers the generation"
            else:
                logits, cache = decode(params, cache, tok)
            rec = sched.step_billing(1)
            _sweep_pool()
            telemetry.record_step(step_count, 0.0, b)
            step_count += 1
            billed_tokens += rec.billed_tokens
            decoded_tokens += rec.decoded_tokens
        sealed = sched.seal_interval()
        if orec is not None and sealed is not None:
            orec.span_at(f"interval {sealed.index}", int_t0_us,
                         obs_trace.now_us(), track="serve",
                         value=float(sealed.decoded_tokens))
        if sealed is None:
            interval_occ.pop(k, None)
            continue
        if fleet is None:
            # no sensors to measure against: settle at prediction right away
            # so budget commitments never pile up unreleased
            sched.release_interval(sealed.index)
        else:
            # devices play modelled power over the interval's wall time
            now = time.perf_counter()
            fleet.advance(now - t_sync)
            t_sync = now
            # this interval's advance flushed the previous one's closing
            # marker: settle everything that is now attributable
            for kk in list(sched.unsettled()):
                _resolve_interval(kk)
            if recorder is not None:
                # tap the rings once per interval: eviction between taps
                # would punch (counted) holes in the archive
                recorder.capture()
    n_intervals = len(sched.intervals)
    # closing bracket of the last interval, then settle or release the rest
    _flush_and_settle(release_rest=True)
    # anything still queued when the loop gave up was starved by the budget:
    # account for it as rejected rather than dropping it silently
    if sched.queue or pending:
        sched.rejected.extend(sched.queue)
        sched.rejected.extend(pending)
        sched.queue.clear()
        pending.clear()
    dt = time.perf_counter() - t0
    s = telemetry.summary()
    print(f"served {len(sched.finished)}/{args.requests} requests "
          f"({len(sched.rejected)} rejected by SLO), {billed_tokens} tokens in "
          f"{dt:.2f}s ({billed_tokens/dt:.1f} tok/s wall on CPU) "
          f"over {step_count} decode steps / {n_intervals} {args.policy} intervals")
    if decoded_tokens:
        print(f"slot utilization: {billed_tokens}/{decoded_tokens} decoded "
              f"tokens billed ({billed_tokens/decoded_tokens:.0%}; padded "
              f"slots excluded from billing and throughput)")
    if s:
        print(f"modelled: {s['j_per_token']*1e3:.3f} mJ/token, "
              f"{s['modelled_step_s']*1e3:.3f} ms/decode-step on {telemetry.chip.name}")
    if pool is not None:
        _sweep_pool()
        st = pool.stats()
        print(f"paged KV: page size {st.page_size}, "
              f"{st.high_water}/{st.n_pages - 1} pages high water, "
              f"{st.allocs} allocs / {st.frees} frees "
              f"({st.reused_pages} reused, {st.alloc_failures} refused), "
              f"{st.in_use} in use at exit")
    if fleet is not None:
        snap = fleet.snapshot()
        print(f"fleet: {snap.aggregate.n_devices} devices, "
              f"{snap.aggregate.mean_w:.1f} W windowed mean, "
              f"{snap.aggregate.energy_j:.2f} J in window")
        print(render_text(
            interval_ledger, title="per-interval measured energy (raw sensor J)"
        ))
        print("per-request energy SLO accounting, modelled time base "
              f"(pricer correction {pricer.correction:.3f} after "
              f"{pricer.n_updates} intervals):")
        print(format_report_rows(sched.report_rows()))
        released = sum(1 for r in sched.intervals if r.released)
        if released:
            print(f"  ({released} intervals settled at prediction: "
                  f"ring history evicted)")
        if sched.overhead_j:
            print(f"  (fleet overhead not billed to any request: "
                  f"{sched.overhead_j:.4f} J)")
        if recorder is not None:
            archive = recorder.save(
                args.record, extra_meta={"intervals": n_intervals}
            )
            print(f"recorded {archive.n_frames} frames / {len(archive)} devices "
                  f"to {args.record} (replay: repro.replay.ReplayFleet)")
        fleet.close()
    if args.trace:
        from repro.obs import export as obs_export

        orec = obs_trace.active()
        obs_export.write_chrome_trace(
            orec, args.trace,
            metadata={"launcher": "serve", "arch": args.arch,
                      "policy": args.policy, "seed": args.seed},
        )
        print(f"wrote flight-recorder trace ({orec.head} events) to "
              f"{args.trace} — load in Perfetto / chrome://tracing")
    if args.metrics:
        from repro.obs import export as obs_export
        from repro.obs import metrics as obs_metrics

        with open(args.metrics, "w") as fh:
            fh.write(obs_export.prometheus_text(obs_metrics.active()))
        print(f"wrote metrics snapshot to {args.metrics}")


if __name__ == "__main__":
    main()
