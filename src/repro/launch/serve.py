"""Serving launcher: scheduler-driven batching with energy telemetry.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
        --requests 16 --prompt-len 64 --gen-len 32 --policy energy-fair

The wave loop is driven by `repro.sched.EnergySloScheduler`: every
request is priced in joules at submission (per-kernel phase timeline →
`EnergyPricer`), a policy (``--policy``: throughput-max, cap-strict,
energy-fair) selects each wave under the joules budget (``--budget-j``)
and optional fleet power cap (``--cap-w``), and the measured energy of
every wave — attributed from the virtual sensor fleet's ring buffers —
is reconciled back into the scheduler, correcting the pricer online.

With ``--fleet N`` (default 2, ``--fleet 0`` disables) a `FleetMonitor`
over N virtual PowerSensor3 devices rides along: each device plays the
modelled per-shard serving power, every request wave is bracketed with
one occurrence of a single time-synced marker char, and per-wave
**measured** J/token comes from `repro.attrib.attribute` over the ring
buffers — occurrence-indexed, so any number of waves attribute cleanly.
"""
from __future__ import annotations

import argparse
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.attrib import EnergyLedger, KernelSpan, attribute_block, render_text
from repro.configs import RunConfig, get_config, smoke_config
from repro.models import build_model
from repro.power import EnergyTelemetry, StepCost
from repro.sched import (
    POLICIES,
    EnergyPricer,
    EnergySloScheduler,
    Request,
    format_report_rows,
    get_policy,
)

#: one char brackets every wave; wave k spans occurrences k .. k+1
_WAVE_MARK = "W"


def _make_fleet(n_devices: int, total_watts: float, seed: int):
    """N virtual sensor devices, each playing one shard of the serving power."""
    from repro.core import ConstantLoad
    from repro.stream import make_virtual_fleet

    volts = 12.0
    per_dev = max(total_watts, 1e-3) / n_devices
    return make_virtual_fleet(
        [ConstantLoad(volts, per_dev / volts) for _ in range(n_devices)],
        seed=seed,
        window_s=0.5,
        ring_capacity=1 << 18,  # ~13 s of history per device at 20 kHz
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--decode-batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--fleet", type=int, default=2,
                    help="virtual PowerSensor3 devices for measured J/token (0 = off)")
    ap.add_argument("--policy", default="throughput-max", choices=sorted(POLICIES))
    ap.add_argument("--clients", type=int, default=3,
                    help="synthetic clients round-robined across requests")
    ap.add_argument("--budget-j", type=float, default=0.0,
                    help="total joules budget for admission (0 = unlimited)")
    ap.add_argument("--cap-w", type=float, default=0.0,
                    help="fleet power cap for cap-strict admission (0 = uncapped)")
    ap.add_argument("--record", default=None, metavar="PATH",
                    help="record the fleet session to a trace archive "
                         "(replayable via repro.replay; needs --fleet > 0)")
    args = ap.parse_args(argv)
    if args.record and args.fleet <= 0:
        ap.error("--record needs a sensor fleet (--fleet > 0)")

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    run = RunConfig(attn_impl="full", remat="none", lr_chunk=16)
    model = build_model(cfg, run)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    max_len = args.prompt_len + args.gen_len
    b = args.decode_batch
    prefill = jax.jit(lambda p, t: model.prefill(p, t, max_len=max_len))
    decode = jax.jit(model.decode_step)

    n = cfg.param_count_estimate()
    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2.0 * n * b, 2.0 * n, 0.0),
        n_layers=cfg.n_layers, useful_flops_per_step=2.0 * n * b,
    )

    # joule-priced admission: the per-kernel phase timeline prices one decode
    # step, the measured wave ledgers correct that price online
    pricer = EnergyPricer.from_phases(
        telemetry.phases, telemetry.chip, tokens_per_step=b, dvfs=telemetry.dvfs
    )
    modelled_watts = (
        telemetry.modelled_step_joules / telemetry.modelled_step_time_s
        if telemetry.modelled_step_time_s
        else 0.0
    )
    sched = EnergySloScheduler(
        pricer,
        get_policy(args.policy),
        max_batch=b,
        budget_j=args.budget_j if args.budget_j > 0 else math.inf,
        cap_w=args.cap_w if args.cap_w > 0 else None,
        # modelled wave power scales weakly with batch on this fleet model:
        # expose the telemetry estimate so cap-strict has something to bound
        power_of_batch=lambda bb: modelled_watts * (0.5 + 0.5 * bb / b) if b else 0.0,
    )
    for rid in range(args.requests):
        sched.submit(Request(
            rid=rid,
            client=f"client{rid % max(args.clients, 1)}",
            prompt_len=args.prompt_len,
            gen_len=args.gen_len,
            payload=rng.integers(
                2, cfg.vocab_size, size=args.prompt_len
            ).astype(np.int32),
        ))

    fleet = None
    recorder = None
    if args.fleet > 0:
        fleet = _make_fleet(args.fleet, modelled_watts, args.seed)
        if args.record:
            from repro.replay import SessionRecorder

            recorder = SessionRecorder(
                fleet,
                meta={"launcher": "serve", "arch": args.arch,
                      "policy": args.policy, "seed": args.seed},
            )

    done_tokens = 0
    # measured per-wave energy, resolved incrementally (one wave after its
    # closing marker lands) so long runs never outlive the ring retention
    wave_ledger = EnergyLedger()
    wave_devices: dict[int, int] = {}  # wave index -> devices that attributed
    wave_occ: dict[int, int] = {}  # wave index -> its opening marker occurrence
    n_marks = 0  # total wave markers issued (flush marks shift occurrences)
    modelled_wave_s = telemetry.modelled_step_time_s * args.gen_len

    def _mark_fleet() -> None:
        nonlocal n_marks
        if fleet is not None:
            fleet.mark_all(_WAVE_MARK)
            n_marks += 1

    def _resolve_wave(k: int) -> None:
        """Attribute wave k (occurrences k..k+1) and reconcile it.

        The fleet plays modelled watts over *wall* time (the marker span),
        so raw measured joules are inflated by the span/modelled time ratio
        (huge on CPU, ~1 on real hardware); the scheduler is reconciled on
        the modelled time base — each device's joules scaled by
        ``modelled_wave_s / span`` — so predicted and measured J stay in
        the same units and a ``--budget-j`` set from modelled numbers keeps
        meaning something.  The raw sensor joules stay in ``wave_ledger``
        untouched.
        """
        if fleet is None or k < 0 or k in wave_devices or k not in wave_occ:
            return
        occ = wave_occ[k]  # the wave closes at the *next* marker, occ + 1
        n_dev = 0
        energy = 0.0
        for name in fleet.names:
            hit = fleet.marker_window(name, _WAVE_MARK, occurrence=occ, occurrence_b=occ + 1)
            if hit is None:
                continue
            t0, t1, block = hit
            led = attribute_block(
                block, [KernelSpan(f"wave{k}", t0, t1)], min_coverage=0.9
            )
            if led.entries:
                wave_ledger.absorb(led)
                dev_j = led.total_energy_j
                if modelled_wave_s > 0 and t1 > t0:
                    dev_j *= modelled_wave_s / (t1 - t0)
                energy += dev_j
                n_dev += 1
        if n_dev:
            wave_devices[k] = n_dev
            # devices are identical shards: scale up for any whose ring had
            # already evicted the span, instead of silently undercounting
            energy *= len(fleet.names) / n_dev
            sched.reconcile(k, energy)

    t0 = time.perf_counter()
    t_wave = t0
    while True:
        wave = sched.next_wave(time.perf_counter() - t0)
        if wave is None and sched.queue and fleet is not None and sched.unreconciled():
            # blocked on in-flight commitments, not the hard budget: flush
            # the pending wave's closing marker, reconcile, and retry
            _mark_fleet()
            fleet.advance(0.01)
            for kk in list(sched.unreconciled()):
                _resolve_wave(kk)
            for kk in list(sched.unreconciled()):
                # closing marker just flushed yet still unattributable: the
                # span is gone from the ring — settle at prediction now so
                # the freed commitment can admit what is still queued
                sched.release_wave(kk)
            wave = sched.next_wave(time.perf_counter() - t0)
        if wave is None:
            break
        k = sched.waves[-1].index
        batch = [r.payload for r in wave]
        while len(batch) < b:  # pad the last wave to the compiled batch shape
            batch.append(batch[-1])
        wave_occ[k] = n_marks
        _mark_fleet()
        tokens = jnp.asarray(np.stack(batch))
        if cfg.is_encdec:
            frames = jnp.asarray(
                rng.standard_normal((b, args.prompt_len, cfg.d_model)), jnp.float32
            )
            logits, cache = jax.jit(
                lambda p, fr, t: model.prefill(p, {"frames": fr, "tokens": t}, max_len=max_len)
            )(params, frames, tokens)
        else:
            logits, cache = prefill(params, tokens)
        for i in range(args.gen_len):
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32) % cfg.vocab_size
            logits, cache = decode(params, cache, tok)
            telemetry.record_step(k * args.gen_len + i, 0.0, b)
            done_tokens += b
        sched.complete_wave(k, args.gen_len, decoded_tokens=b * args.gen_len)
        if fleet is None:
            # no sensors to measure against: settle at prediction right away
            # so budget commitments never pile up unreleased
            sched.release_wave(k)
        if fleet is not None:
            # devices play modelled power over the wave's wall time
            now = time.perf_counter()
            fleet.advance(now - t_wave)
            t_wave = now
            # this wave's advance flushed the previous wave's closing marker
            _resolve_wave(k - 1)
            if recorder is not None:
                # tap the rings once per wave: eviction between taps would
                # punch (counted) holes in the archive
                recorder.capture()
    n_waves = len(sched.waves)
    if fleet is not None and n_waves:
        _mark_fleet()  # closing bracket of the last wave
        fleet.advance(0.01)  # flush the closing marker onto the stream
        for kk in list(sched.unreconciled()):
            _resolve_wave(kk)
    # waves whose span the ring already evicted can never be measured:
    # release them so their budget commitment is settled, not leaked
    for kk in list(sched.unreconciled()):
        sched.release_wave(kk)
    # anything still queued when the loop gave up was starved by the budget:
    # account for it as rejected rather than dropping it silently
    if sched.queue:
        sched.rejected.extend(sched.queue)
        sched.queue.clear()
    dt = time.perf_counter() - t0
    s = telemetry.summary()
    print(f"served {len(sched.finished)}/{args.requests} requests "
          f"({len(sched.rejected)} rejected by SLO), {done_tokens} tokens in "
          f"{dt:.2f}s ({done_tokens/dt:.1f} tok/s wall on CPU) "
          f"over {n_waves} {args.policy} waves")
    if s:
        print(f"modelled: {s['j_per_token']*1e3:.3f} mJ/token, "
              f"{s['modelled_step_s']*1e3:.3f} ms/decode-step on {telemetry.chip.name}")
    if fleet is not None:
        snap = fleet.snapshot()
        print(f"fleet: {snap.aggregate.n_devices} devices, "
              f"{snap.aggregate.mean_w:.1f} W windowed mean, "
              f"{snap.aggregate.energy_j:.2f} J in window")
        print(render_text(wave_ledger, title="per-wave measured energy (raw sensor J)"))
        print("per-request energy SLO accounting, modelled time base "
              f"(pricer correction {pricer.correction:.3f} after "
              f"{pricer.n_updates} waves):")
        print(format_report_rows(sched.report_rows()))
        missing = n_waves - len(wave_devices)
        if missing:
            print(f"  ({missing} waves not individually attributed: "
                  f"ring history evicted)")
        if recorder is not None:
            archive = recorder.save(args.record, extra_meta={"waves": n_waves})
            print(f"recorded {archive.n_frames} frames / {len(archive)} devices "
                  f"to {args.record} (replay: repro.replay.ReplayFleet)")
        fleet.close()


if __name__ == "__main__":
    main()
