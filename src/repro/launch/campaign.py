"""Measurement-campaign launcher: run a declarative plan against a fleet.

Executes a :class:`repro.net.MeasurementPlan` — remote receivers and/or
local virtual rigs served through the loopback `DeviceServer` — with the
plan's safety interlocks armed (``vmax``, ``max_hours``,
``abort_on_anomaly``).

Usage:
    python -m repro.launch.campaign --demo                 # built-in plan
    python -m repro.launch.campaign --plan plan.json
    python -m repro.launch.campaign --plan plan.json --dry-run
    python -m repro.launch.campaign --demo --json out.json
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.net import Interlocks, MeasurementPlan, PlanDevice, run_plan


def demo_plan(duration_s: float = 0.5) -> MeasurementPlan:
    """A self-contained two-rig virtual campaign (no hardware needed)."""
    return MeasurementPlan(
        name="demo",
        devices=(
            PlanDevice(name="rig0", load="constant", volts=12.0, amps=3.0),
            PlanDevice(name="rig1", load="square", volts=12.0, amps=6.0),
        ),
        duration_s=duration_s,
        window_s=0.1,
        tick_s=0.02,
        interlocks=Interlocks(vmax_v=13.0, max_hours=0.01),
    )


def describe(plan: MeasurementPlan) -> str:
    lines = [f"plan {plan.name!r}: {plan.duration_s:.3g} s, "
             f"window {plan.window_s:.3g} s, tick {plan.tick_s:.3g} s"]
    for d in plan.devices:
        where = d.endpoint or f"virtual {d.load} {d.volts:g} V / {d.amps:g} A"
        lines.append(f"  {d.name}: {where} ({d.module})")
    il = plan.interlocks
    lines.append(
        f"  interlocks: vmax={il.vmax_v} max_hours={il.max_hours} "
        f"abort_on_anomaly={il.abort_on_anomaly}"
    )
    if plan.scenario:
        lines.append(f"  fault scenario: {plan.scenario}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--plan", help="path to a MeasurementPlan JSON file")
    src.add_argument("--demo", action="store_true",
                     help="run the built-in two-rig virtual demo plan")
    ap.add_argument("--duration", type=float, default=None,
                    help="override the plan's duration_s")
    ap.add_argument("--dry-run", action="store_true",
                    help="validate and describe the plan, then exit")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the PlanResult as JSON")
    args = ap.parse_args(argv)

    if args.demo:
        plan = demo_plan()
    else:
        with open(args.plan) as fh:
            plan = MeasurementPlan.from_json(fh.read())
    if args.duration is not None:
        plan = MeasurementPlan.from_dict(
            {**plan.to_dict(), "duration_s": args.duration}
        )

    print(describe(plan))
    if args.dry_run:
        return 0

    result = run_plan(plan)
    status = "ABORTED" if result.aborted else "completed"
    print(
        f"{status}: {result.elapsed_s:.3f} s, {result.n_readings} readings, "
        f"mean {result.mean_power_w:.2f} W, peak {result.peak_power_w:.2f} W"
    )
    if result.reason:
        print(f"  reason: {result.reason}")
    for name, st in sorted(result.health.items()):
        ls = result.link_stats.get(name, {})
        print(f"  {name}: {st}, {ls.get('frames', 0)} frames, "
              f"{ls.get('reconnects', 0)} reconnects")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(result.to_dict(), fh, indent=2)
        print(f"wrote {args.json}")
    return 1 if result.aborted else 0


if __name__ == "__main__":
    sys.exit(main())
