"""Production mesh + sharding rules.

Mesh: `(data=16, model=16)` single pod (256 chips) and
`(pod=2, data=16, model=16)` for the 2-pod 512-chip dry-run.  Defined as
FUNCTIONS so importing this module never touches jax device state.

Sharding policy (the baseline; §Perf hillclimbs tweak it):

* params — FSDP over `data` (ZeRO-3-style: XLA inserts the all-gathers) ×
  tensor-parallel over `model` on the *flat* projection dims (every
  assigned d_model/d_ff is divisible by 16; heads are NOT always, which
  is why rules shard flattened head×head_dim axes — see DESIGN.md §5).
  Pods replicate params (pure DP between pods: gradient all-reduce over
  `pod` only), the standard multi-pod layout given slow cross-pod links.
* optimizer m/v — same spec as their param.
* activations — batch over (`pod`, `data`).
* decode caches — batch over data when divisible; sequence over `data`
  for the B=1 long-context cells; heads/feature dims over `model`.

All rules are divisibility-checked against the actual mesh: a dim is only
sharded if evenly divisible, so every (arch × shape × mesh) cell lowers.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P


def _axis_type_kwargs(n_axes: int) -> dict:
    """`axis_types=` only exists on newer jax (AxisType landed post-0.4.x);
    older versions default every axis to Auto anyway, so omit it there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]) -> Mesh:
    """Generic mesh helper (tests/examples use small meshes like (1,1))."""
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


# ---------------------------------------------------------------------------
# rule machinery
# ---------------------------------------------------------------------------
def _axis_size(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([_axis_size(mesh, n) for n in name]))
    return int(mesh.shape[name]) if name in mesh.shape else 0


def _fit(mesh: Mesh, shape: tuple[int, ...], spec: tuple) -> P:
    """Drop axes that don't exist in the mesh or don't divide the dim."""
    out = []
    for dim, ax in zip(shape, spec):
        if ax is None:
            out.append(None)
            continue
        size = _axis_size(mesh, ax)
        if size <= 1 or dim % size != 0:
            out.append(None)
        else:
            out.append(ax)
    return P(*out)


def dp_axes(mesh: Mesh):
    """The pure-data-parallel axes of this mesh (batch dim sharding)."""
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def fsdp_axis(mesh: Mesh):
    """Parameter-sharding axis (within-pod FSDP)."""
    return "data"


#: path-pattern -> spec template (matched against '/'-joined tree path).
#: 'F' = fsdp axis placeholder, 'M' = model axis.
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed$", ("M", "F")),  # (V, d): vocab-parallel
    (r"head$", ("F", "M")),
    (r"dec_pos$", (None, "M")),
    (r"enc_in$", ("F", "M")),
    # attention
    (r"(wq|wk|wv)$", ("F", "M")),
    (r"(bq|bk|bv)$", ("M",)),
    (r"attn/wo$", ("M", "F")),
    # mlp
    (r"(wi|wg)$", ("F", "M")),
    (r"wo2$", ("M", "F")),
    # moe (E, d, ff) / (E, ff, d); router (d, E)
    (r"router$", ("F", None)),
    (r"moe/(wi|wg)$", (None, "F", "M")),
    (r"moe/wo$", (None, "M", "F")),
    # mamba2
    (r"ssm/(wz|wx)$", ("F", "M")),
    (r"ssm/conv$", (None, "M")),
    (r"ssm/conv_b$", ("M",)),
    (r"ssm/(wB|wC)$", ("F", None)),
    (r"ssm/wdt$", ("F", "M")),
    (r"ssm/norm_y$", ("M",)),
    (r"ssm/out$", ("M", "F")),
    # rwkv6 time-mix / channel-mix
    (r"tm/(wr|wk|wv|wg)$", ("F", "M")),
    (r"tm/wo$", ("M", "F")),
    (r"tm/wA$", ("F", None)),
    (r"tm/wB$", (None, "M")),
    (r"tm/(mu)$", (None, "M")),
    (r"tm/(w0|ln_x)$", ("M",)),
    (r"cm/(wr|wk)$", ("F", "M")),
    (r"cm/wv$", ("M", "F")),
    (r"cm/mu$", (None, "M")),
]


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
    return "/".join(parts)


def param_spec(mesh: Mesh, path, leaf) -> P:
    """PartitionSpec for one parameter leaf.

    Stacked layer dims (leading n_layers/group dims) are never sharded;
    rules apply to the trailing dims that match the rule's arity.
    """
    s = _path_str(path)
    shape = tuple(leaf.shape)
    for pat, template in _PARAM_RULES:
        if re.search(pat, s):
            tmpl = [
                {"F": fsdp_axis(mesh), "M": "model"}.get(a, a) if isinstance(a, str) else a
                for a in template
            ]
            n_lead = len(shape) - len(tmpl)
            if n_lead < 0:
                return P()
            full = (None,) * n_lead + tuple(tmpl)
            return _fit(mesh, shape, full)
    # norms, biases, scalars: replicate
    return P()


def params_shardings(mesh: Mesh, params_shape: Any):
    """Tree of NamedShardings matching a params (shape) pytree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, param_spec(mesh, path, leaf)), params_shape
    )


def opt_state_shardings(mesh: Mesh, opt_shape: Any):
    """m/v follow their params; step is replicated."""
    def spec_of(path, leaf):
        s = _path_str(path)
        if s.startswith(("m/", "v/", "master/")):
            sub_path = path[1:]
            return NamedSharding(mesh, param_spec(mesh, sub_path, leaf))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(spec_of, opt_shape)


# ---------------------------------------------------------------------------
# activations / batches / caches
# ---------------------------------------------------------------------------
def batch_shardings(mesh: Mesh, batch_shape: Any):
    """tokens (B, S): batch over dp axes. frames (B, T, d): same."""
    dp = dp_axes(mesh)

    def spec_of(path, leaf):
        shape = tuple(leaf.shape)
        return NamedSharding(mesh, _fit(mesh, shape, (dp,) + (None,) * (len(shape) - 1)))

    return jax.tree_util.tree_map_with_path(spec_of, batch_shape)


def cache_spec(mesh: Mesh, path, leaf, seq_shard: bool = False) -> P:
    """Decode-cache sharding (see module docstring).

    ``seq_shard``: prefer splitting the cache *sequence* over `model`
    when the KV heads don't divide it (flash-decode-style split-S: XLA
    partial-softmaxes over the shards with small combine collectives) —
    the §Perf fix for the involuntary-resharding pathology the baseline
    head_dim sharding triggers.
    """
    s = _path_str(path)
    shape = tuple(leaf.shape)
    dp = dp_axes(mesh)
    if s.endswith("pos"):
        return P()

    def try_spec(spec):
        return _fit(mesh, shape, spec)

    if re.search(r"(^|/)(k|v|self_k|self_v|cross_k|cross_v)$", s):
        # (L, B, S, Hkv, hd): batch over dp; heads over model; if heads
        # don't divide: split-S over model (seq_shard) or head_dim (base);
        # if batch unshardable (B=1 long-context), sequence over data
        spec = try_spec((None, dp, None, "model", None))
        if spec[1] is None:
            spec = try_spec((None, None, "data", "model", None))
            if spec[3] is None:  # few kv heads: shard head_dim
                spec = try_spec((None, None, "data", None, "model"))
        elif spec[3] is None:
            if seq_shard:
                spec = try_spec((None, dp, "model", None, None))
            else:
                spec = try_spec((None, dp, None, None, "model"))
        return spec
    if s.endswith("ssm") or s.endswith("wkv"):
        # (..., B, H, N, P) state: batch over dp, heads over model
        n = len(shape)
        spec = try_spec((None,) * (n - 4) + (dp, "model", None, None))
        if spec[n - 4] is None:
            spec = try_spec((None,) * (n - 4) + (None, "model", "data", None))
        if spec[n - 3] is None:
            spec = try_spec((None,) * (n - 4) + (None, None, "data", "model"))
        return spec
    if s.endswith("conv"):
        # (..., B, K-1, d_in)
        n = len(shape)
        spec = try_spec((None,) * (n - 3) + (dp, None, "model"))
        if spec[n - 3] is None:
            spec = try_spec((None,) * (n - 3) + (None, None, "model"))
        return spec
    if "shift" in s:
        # (L, B, d)
        spec = try_spec((None, dp, "model"))
        if spec[1] is None:
            spec = try_spec((None, None, "model"))
        return spec
    # default: batch over dp on dim 1 if it divides
    if len(shape) >= 2:
        return try_spec((None, dp) + (None,) * (len(shape) - 2))
    return P()


def cache_shardings(mesh: Mesh, cache_shape: Any, seq_shard: bool = False):
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, cache_spec(mesh, path, leaf, seq_shard)),
        cache_shape,
    )


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
