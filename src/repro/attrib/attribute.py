"""Marker-aligned energy attribution: watts back to named kernels.

Takes a decoded power trace (a `stream.FrameBlock` or raw arrays), a set
of **spans** — named time intervals for each kernel occurrence — and
produces an :class:`EnergyLedger`: per-kernel joules, average/peak watts,
total duration and occurrence count, aggregated across repeated steps.

Spans come from three sources:

* :func:`marker_spans` — consecutive occurrences of one marker char from
  ``PowerSensor.markers()`` (what `launch.serve` uses per request wave;
  occurrence-indexed, so the ledger never wraps an alphabet);
* :func:`timeline_spans` — a *declared* kernel timeline (e.g.
  ``power.tpu_model.phases_for_step``) laid out from per-step anchor
  markers, optionally stretched to the measured step length;
* `repro.attrib.segment` — marker-free changepoints, via
  :func:`spans_from_segments`.

:class:`StepAttributor` packages the train-loop integration: it plays the
modelled per-step phase trace through the full virtual-sensor chain,
brackets every step with a marker, and on ``finish()`` returns the ledger
measured *through the sensor* rather than assumed from the model.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Iterable, Sequence

import numpy as np

from repro.stream.aggregate import cumulative_energy
from repro.stream.ring import FrameBlock

from .segment import Segmentation


@dataclass(frozen=True)
class KernelSpan:
    """One occurrence of a named kernel in device time."""

    name: str
    t0_s: float
    t1_s: float

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s


@dataclass
class LedgerEntry:
    """Aggregate of all attributed occurrences of one kernel."""

    name: str
    count: int = 0
    energy_j: float = 0.0
    duration_s: float = 0.0
    peak_w: float = 0.0
    #: span time actually backed by samples (gaps in the trace excluded);
    #: ``energy_j`` is extrapolated across gaps, and ``coverage_frac``
    #: is the explicit uncertainty of that extrapolation
    covered_s: float = 0.0

    @property
    def avg_w(self) -> float:
        return self.energy_j / self.duration_s if self.duration_s > 0 else 0.0

    @property
    def j_per_occurrence(self) -> float:
        return self.energy_j / self.count if self.count else 0.0

    @property
    def coverage_frac(self) -> float:
        """Fraction of the attributed time that samples actually covered."""
        return (
            min(self.covered_s / self.duration_s, 1.0)
            if self.duration_s > 0
            else 1.0
        )


@dataclass
class EnergyLedger:
    """Per-kernel energy accounting over one or more attributed windows."""

    entries: dict[str, LedgerEntry] = field(default_factory=dict)
    #: integral of the whole attributed trace window(s), attributed or not
    trace_energy_j: float = 0.0
    t0_s: float = 0.0
    t1_s: float = 0.0
    #: spans dropped because the ring no longer retained enough of them
    skipped_spans: int = 0

    @property
    def total_energy_j(self) -> float:
        return float(sum(e.energy_j for e in self.entries.values()))

    @property
    def attributed_fraction(self) -> float:
        return self.total_energy_j / self.trace_energy_j if self.trace_energy_j else 0.0

    def ranked(self) -> list[LedgerEntry]:
        """Entries sorted by energy, biggest consumer first."""
        return sorted(self.entries.values(), key=lambda e: -e.energy_j)

    @property
    def coverage_frac(self) -> float:
        """Sample coverage over all attributed time (1.0 = gap-free)."""
        dur = sum(e.duration_s for e in self.entries.values())
        cov = sum(e.covered_s for e in self.entries.values())
        return min(cov / dur, 1.0) if dur > 0 else 1.0

    def add_occurrence(
        self,
        name: str,
        energy_j: float,
        duration_s: float,
        peak_w: float,
        covered_s: float | None = None,
    ) -> None:
        e = self.entries.setdefault(name, LedgerEntry(name))
        e.count += 1
        e.energy_j += energy_j
        e.duration_s += duration_s
        e.peak_w = max(e.peak_w, peak_w)
        e.covered_s += duration_s if covered_s is None else covered_s

    def absorb(self, other: "EnergyLedger") -> "EnergyLedger":
        """Merge another ledger in place (multi-device / multi-window)."""
        was_empty = not self.entries and self.trace_energy_j == 0.0
        for name, e in other.entries.items():
            mine = self.entries.setdefault(name, LedgerEntry(name))
            mine.count += e.count
            mine.energy_j += e.energy_j
            mine.duration_s += e.duration_s
            mine.peak_w = max(mine.peak_w, e.peak_w)
            mine.covered_s += e.covered_s
        self.trace_energy_j += other.trace_energy_j
        self.skipped_spans += other.skipped_spans
        if other.entries or other.trace_energy_j:
            self.t0_s = other.t0_s if was_empty else min(self.t0_s, other.t0_s)
            self.t1_s = other.t1_s if was_empty else max(self.t1_s, other.t1_s)
        return self


# --------------------------------------------------------------------- spans
def interval_spans(
    markers: Iterable[tuple[str, float]],
    char: str,
    names: Sequence[str] | None = None,
    start: int = 0,
) -> list[KernelSpan]:
    """Spans for the step intervals bracketed by one marker char.

    The serving loop emits one occurrence of ``char`` per step interval (a
    batch of decode steps); interval ``k`` runs from occurrence ``k`` to
    occurrence ``k+1``.  Occurrence-indexed by construction, so repeated
    brackets (step intervals, request waves, tuning trials) never collide
    the way a wrapping marker alphabet does.  ``start`` skips already
    settled intervals while keeping *global* interval indices in the
    default names (``f"{char}{k}"``) — the index the scheduler settles by.
    """
    ts = [t for c, t in markers if c == char]
    spans = []
    for k in range(max(int(start), 0), len(ts) - 1):
        j = k - start
        name = names[j] if names is not None and j < len(names) else f"{char}{k}"
        spans.append(KernelSpan(name, ts[k], ts[k + 1]))
    return spans


def marker_spans(
    markers: Iterable[tuple[str, float]],
    char: str,
    names: Sequence[str] | None = None,
) -> list[KernelSpan]:
    """Spans between consecutive occurrences of one marker char.

    The degenerate one-interval-per-wave case of :func:`interval_spans`
    (``start=0``): span ``k`` runs from occurrence ``k`` to occurrence
    ``k+1`` of ``char``.  Default names are ``f"{char}{k}"``.  Kept as the
    wave-era entry point; existing goldens replay bit-identically through
    either.
    """
    return interval_spans(markers, char, names=names, start=0)


def attribute_intervals(
    block: FrameBlock,
    markers: Iterable[tuple[str, float]],
    char: str,
    start: int = 0,
    pair: int | None = None,
    min_coverage: float = 0.0,
    gap_factor: float = 3.0,
) -> dict[int, LedgerEntry]:
    """Attribute every retained step interval at once: {interval: entry}.

    One `attribute` pass over all intervals of ``char`` from occurrence
    ``start`` on, keyed by *global* interval index — what the continuous
    batch settles `settle_interval(k, entry.energy_j)` against.  Intervals
    the ring evicted or the gap logic rejects are simply absent (the
    caller releases those at prediction); present entries carry the same
    gap-aware energy/coverage semantics as any other attribution.
    """
    spans = interval_spans(markers, char, start=start)
    ledger = attribute_block(
        block, spans, pair=pair, min_coverage=min_coverage, gap_factor=gap_factor
    )
    out: dict[int, LedgerEntry] = {}
    for name, entry in ledger.entries.items():
        out[int(name[len(char):])] = entry
    return out


def timeline_spans(
    phases: Sequence,
    anchors: Sequence[float],
    stretch: bool = True,
    t_end: float | None = None,
) -> list[KernelSpan]:
    """Lay a declared kernel timeline out from per-step anchor markers.

    ``phases`` is anything with ``.name`` / ``.duration_s`` (e.g.
    `power.tpu_model.Phase`) or ``(name, duration_s)`` tuples; one copy of
    the timeline is placed at every anchor.  With ``stretch=True`` the
    declared durations are rescaled so each step exactly fills the gap to
    the next anchor (or to ``t_end`` for the last one) — aligning the
    modelled timeline to the *measured* step length.
    """
    items = [
        (p.name, p.duration_s) if hasattr(p, "duration_s") else (p[0], float(p[1]))
        for p in phases
    ]
    total = sum(d for _, d in items)
    anchors = sorted(float(a) for a in anchors)
    spans: list[KernelSpan] = []
    for k, a in enumerate(anchors):
        if k + 1 < len(anchors):
            budget = anchors[k + 1] - a
        elif t_end is not None:
            budget = t_end - a
        else:
            budget = total
        scale = budget / total if stretch and total > 0 and budget > 0 else 1.0
        t = a
        for name, dur in items:
            spans.append(KernelSpan(name, t, t + dur * scale))
            t += dur * scale
    return spans


def spans_from_segments(
    seg: Segmentation, names: Sequence[str] | None = None
) -> list[KernelSpan]:
    """Wrap detected segments as spans (names default ``seg0..segN-1``)."""
    return [
        KernelSpan(
            names[i] if names is not None and i < len(names) else f"seg{i}",
            s.t0_s,
            s.t1_s,
        )
        for i, s in enumerate(seg.segments)
    ]


# ----------------------------------------------------------------- attribute
def attribute(
    times_s: np.ndarray,
    watts: np.ndarray,
    spans: Sequence[KernelSpan],
    min_coverage: float = 0.0,
    gap_factor: float = 3.0,
) -> EnergyLedger:
    """Integrate a 1-D power series over each span; aggregate by name.

    Span energies come from one cumulative trapezoid prefix plus two
    binary searches per span — O(n + m log n) for n samples, m spans.
    Span edges are quantised to sample boundaries (≤ one 50 µs frame of
    slack at 20 kHz).

    Gap-aware: inter-sample steps longer than ``gap_factor`` × the median
    frame interval are *delivery gaps* (dropouts, disconnects), not data.
    Energy is integrated over the covered segments only and extrapolated
    across the gaps by ``1 / coverage_frac``, with the coverage recorded
    per entry — a gap is surfaced as uncertainty, never silently
    under-counted as zero watts nor bridged as fake samples.

    ``min_coverage`` guards against spans too hollow to extrapolate
    (ring evicted the head, the gap swallowed the whole span): those are
    dropped and tallied in ``ledger.skipped_spans``.
    """
    t = np.asarray(times_s, dtype=np.float64)
    w = np.asarray(watts, dtype=np.float64)
    ledger = EnergyLedger()
    if t.size < 2 or not spans:
        ledger.skipped_spans = len(spans)
        return ledger
    cumE = cumulative_energy(t, w)
    dts = np.diff(t)
    dt_est = float(np.median(dts))
    gap_thresh = gap_factor * dt_est
    bad = dts > gap_thresh
    # segment-level prefixes: energy and gap time over covered steps only
    seg_e = 0.5 * (w[1:] + w[:-1]) * dts
    cum_e_cov = np.concatenate([[0.0], np.cumsum(np.where(bad, 0.0, seg_e))])
    cum_gap = np.concatenate([[0.0], np.cumsum(np.where(bad, dts, 0.0))])
    lo = np.searchsorted(t, [s.t0_s for s in spans], side="left")
    hi = np.searchsorted(t, [s.t1_s for s in spans], side="left")
    ledger.trace_energy_j = float(cumE[-1])
    ledger.t0_s, ledger.t1_s = float(t[0]), float(t[-1])
    for span, a, b in zip(spans, lo, hi):
        n = int(b - a)
        dur = span.duration_s
        if n < 2 or dur <= 0:
            ledger.skipped_spans += 1
            continue
        # uncovered time: interior gaps plus edge gaps beyond one frame
        # (edge slack of ≤ dt_est is quantisation, not a gap)
        gap_s = float(cum_gap[b - 1] - cum_gap[a])
        gap_s += max(float(t[a]) - span.t0_s - dt_est, 0.0)
        gap_s += max(span.t1_s - float(t[b - 1]) - dt_est, 0.0)
        coverage = min(max(1.0 - gap_s / dur, 0.0), 1.0)
        if coverage <= 0.0 or coverage < min_coverage:
            ledger.skipped_spans += 1
            continue
        e_cov = float(cum_e_cov[b - 1] - cum_e_cov[a])
        ledger.add_occurrence(
            span.name,
            energy_j=e_cov / coverage,
            duration_s=dur,
            peak_w=float(w[a:b].max()),
            covered_s=coverage * dur,
        )
    return ledger


def attribute_block(
    block: FrameBlock,
    spans: Sequence[KernelSpan],
    pair: int | None = None,
    min_coverage: float = 0.0,
    gap_factor: float = 3.0,
) -> EnergyLedger:
    """`attribute` over a `FrameRing` view (pair=None sums across pairs)."""
    w = block.total_watts if pair is None else block.watts[:, pair]
    return attribute(
        block.times_s, w, spans, min_coverage=min_coverage, gap_factor=gap_factor
    )


def refine_spans(
    spans: Sequence[KernelSpan], seg: Segmentation, tol_s: float = 2e-3
) -> list[KernelSpan]:
    """Snap span edges to the nearest *detected* changepoint within tol_s.

    Declared timelines carry model error; measured changepoints don't.
    Edges with no changepoint nearby are left where the timeline put them.
    """
    if seg.boundaries_s.size == 0:
        return list(spans)
    b = seg.boundaries_s

    def snap(x: float) -> float:
        j = int(np.argmin(np.abs(b - x)))
        return float(b[j]) if abs(b[j] - x) <= tol_s else x

    out = []
    for s in spans:
        t0, t1 = snap(s.t0_s), snap(s.t1_s)
        out.append(replace(s, t0_s=t0, t1_s=t1) if t1 > t0 else s)
    return out


# ------------------------------------------------------------- train bridge
class StepAttributor:
    """Bracket every training/serving step with markers on a virtual
    sensor playing the modelled phase trace; ``finish()`` → energy ledger.

    The declared timeline is ``telemetry.phases`` (from
    ``power.tpu_model.phases_for_step``); each ``on_step()`` marks the
    step start and advances the device by one modelled step, so the
    marker stream and the 20 kHz frame stream stay time-synced exactly as
    the paper's ``psrun -m`` does.
    """

    def __init__(
        self,
        telemetry,
        seed: int = 0,
        volts: float = 12.0,
        module: str = "pcie8pin-20a",
        ring_capacity: int | None = None,
        marker: str = "S",
    ):
        from repro.core import PowerSensor, TraceLoad, make_device
        from repro.core.host import DEFAULT_RING_CAPACITY
        from repro.power.trace import render_phases

        self.telemetry = telemetry
        self.marker = marker
        self._phases = list(telemetry.phases)
        trace = render_phases(self._phases, telemetry.chip, telemetry.dvfs)
        self._step_s = float(trace.times_s[-1])
        dev = make_device([module], TraceLoad(
            times_s=trace.times_s,
            watts=trace.watts,
            volts=volts,
            repeat=True,
        ), seed=seed)
        self._ps = PowerSensor(
            dev, ring_capacity=ring_capacity or DEFAULT_RING_CAPACITY
        )
        self._steps = 0
        self._closed = False

    @property
    def sensor(self):
        return self._ps

    def on_step(self) -> None:
        """Mark the step start and play one modelled step through the chain."""
        self._ps.mark(self.marker)
        self._ps.run_for(self._step_s)
        self._steps += 1

    def finish(self, min_coverage: float = 0.5) -> EnergyLedger:
        """Flush, attribute every retained step, and release the sensor."""
        self._ps.poll()
        anchors = [t for c, t in self._ps.markers if c == self.marker]
        block = self._ps.ring.latest()
        ledger = EnergyLedger()
        if anchors:
            spans = timeline_spans(
                self._phases, anchors, stretch=True, t_end=anchors[-1] + self._step_s
            )
            ledger = attribute_block(block, spans, min_coverage=min_coverage)
        if not self._closed:
            self._ps.close()
            self._closed = True
        return ledger
