"""Vectorised changepoint segmentation of 20 kHz power traces.

The paper's Fig. 5 argument — a fast sensor resolves *individual kernels*
in the power trace — only pays off if software can carve the trace into
those kernels.  This module does the carving, marker-free:

1. **Edge detection**: box-smooth the trace, take a lagged difference, and
   apply hysteresis thresholding (enter an edge region above ``k_hi`` σ,
   extend it down to ``k_lo`` σ).  Each qualifying region contributes one
   changepoint at its derivative extremum.
2. **Binary-segmentation refinement**: within each resulting segment,
   split at the variance-reduction optimum whenever the gain beats a
   BIC-style penalty — this recovers slow ramps and small steps the
   derivative test misses.

Everything operates on numpy arrays with cumulative-sum prefix tricks —
no per-sample Python loops — and plugs directly into `stream.FrameRing`
views via :func:`segment_block` (``ring.latest()`` / ``ring.window(...)``
return the `FrameBlock`s this consumes).

Downstream: `repro.attrib.attribute` turns segments + markers into energy
ledgers; `repro.attrib.signatures` identifies unlabeled segments.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.stream.aggregate import cumulative_energy
from repro.stream.ring import FrameBlock


@dataclass(frozen=True)
class Segment:
    """One homogeneous-power interval of a trace."""

    i0: int  # first sample index (inclusive)
    i1: int  # last sample index (exclusive)
    t0_s: float
    t1_s: float
    mean_w: float
    peak_w: float
    energy_j: float

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s

    def __len__(self) -> int:
        return self.i1 - self.i0


@dataclass(frozen=True)
class Segmentation:
    """Changepoint decomposition of one power trace."""

    segments: list[Segment]
    boundaries_s: np.ndarray  # internal changepoint times, (n_segments - 1,)
    noise_w: float  # estimated per-sample noise std

    def __len__(self) -> int:
        return len(self.segments)

    @property
    def total_energy_j(self) -> float:
        return float(sum(s.energy_j for s in self.segments))

    def nearest_boundary(self, t_s: float) -> float | None:
        """The detected boundary closest to ``t_s`` (None if no boundaries)."""
        if self.boundaries_s.size == 0:
            return None
        return float(self.boundaries_s[np.argmin(np.abs(self.boundaries_s - t_s))])


def _boxcar(x: np.ndarray, win: int) -> np.ndarray:
    """Centered moving average via one cumulative sum (edges shrink)."""
    if win <= 1:
        return x.astype(np.float64, copy=True)
    cs = np.concatenate([[0.0], np.cumsum(x, dtype=np.float64)])
    n = x.size
    idx = np.arange(n)
    lo = np.clip(idx - win // 2, 0, n)
    hi = np.clip(idx + (win - win // 2), 0, n)
    return (cs[hi] - cs[lo]) / np.maximum(hi - lo, 1)


def _noise_std(w: np.ndarray) -> float:
    """Robust per-sample noise estimate: MAD of first differences / √2."""
    if w.size < 3:
        return 0.0
    d = np.diff(w)
    return float(1.4826 * np.median(np.abs(d - np.median(d))) / np.sqrt(2.0))


def _hysteresis_changepoints(
    d: np.ndarray, t_lo: float, t_hi: float
) -> np.ndarray:
    """Changepoint indices from hysteresis regions of the edge signal ``d``.

    A region is a maximal run with ``|d| >= t_lo``; it qualifies if it
    contains at least one sample with ``|d| >= t_hi``, and contributes the
    index of its ``|d|`` maximum.
    """
    mag = np.abs(d)
    above = mag >= t_lo
    if not above.any():
        return np.empty(0, dtype=np.int64)
    edges = np.flatnonzero(np.diff(np.concatenate([[False], above, [False]])))
    starts, ends = edges[0::2], edges[1::2]
    strong = np.concatenate([[0], np.cumsum(mag >= t_hi)])
    keep = (strong[ends] - strong[starts]) > 0
    return np.array(
        [s + int(np.argmax(mag[s:e])) for s, e in zip(starts[keep], ends[keep])],
        dtype=np.int64,
    )


def _enforce_min_separation(
    cps: np.ndarray, strength: np.ndarray, min_sep: int
) -> np.ndarray:
    """Greedily drop the weaker of any two changepoints closer than min_sep."""
    if cps.size <= 1:
        return cps
    order = np.argsort(cps)
    cps, strength = cps[order], strength[order]
    kept: list[int] = []  # indices into cps
    for i in range(cps.size):
        if kept and cps[i] - cps[kept[-1]] < min_sep:
            if strength[i] > strength[kept[-1]]:
                kept[-1] = i
        else:
            kept.append(i)
    return cps[kept]


def _binary_refine(
    w: np.ndarray,
    bounds: np.ndarray,
    min_size: int,
    penalty_j2: float,
    max_depth: int,
    guard: int = 0,
) -> list[int]:
    """Binary segmentation inside each [a, b): split at the best variance
    reduction while the gain exceeds ``penalty_j2``.  Prefix sums make each
    candidate sweep one vector expression.

    ``guard`` shrinks each *initial* segment before refining: detected
    edges carry a couple of samples of localisation jitter, and without
    the guard the misassigned edge samples manufacture variance gain that
    gets "fixed" by a spurious split ``min_size`` away from the real edge.
    """
    s1 = np.concatenate([[0.0], np.cumsum(w, dtype=np.float64)])
    s2 = np.concatenate([[0.0], np.cumsum(w * w, dtype=np.float64)])

    def sse(a: int, b: int) -> float:
        m = b - a
        return float(s2[b] - s2[a] - (s1[b] - s1[a]) ** 2 / m) if m > 0 else 0.0

    found: list[int] = []
    stack = [
        (int(a) + guard, int(b) - guard, 0) for a, b in zip(bounds[:-1], bounds[1:])
    ]
    while stack:
        a, b, depth = stack.pop()
        if depth >= max_depth or b - a < 2 * min_size:
            continue
        js = np.arange(a + min_size, b - min_size + 1)
        if js.size == 0:
            continue
        nl, nr = js - a, b - js
        left = s2[js] - s2[a] - (s1[js] - s1[a]) ** 2 / nl
        right = s2[b] - s2[js] - (s1[b] - s1[js]) ** 2 / nr
        gains = sse(a, b) - left - right
        k = int(np.argmax(gains))
        if gains[k] > penalty_j2:
            j = int(js[k])
            found.append(j)
            stack.append((a, j, depth + 1))
            stack.append((j, b, depth + 1))
    return found


def segment_trace(
    times_s: np.ndarray,
    watts: np.ndarray,
    smooth_s: float = 5e-4,
    edge_lag_s: float = 3e-4,
    k_hi: float = 8.0,
    k_lo: float = 3.0,
    min_seg_s: float = 2e-3,
    refine: bool = True,
    penalty: float = 25.0,
    max_depth: int = 8,
) -> Segmentation:
    """Segment one (times, watts) trace into homogeneous-power intervals.

    Defaults are tuned for the 20 kHz virtual-sensor noise floor (Table I:
    sub-watt σ per sample) but degrade gracefully on sparse builtin-counter
    series: all sample-count parameters are derived from the observed
    sample interval, so a 10 Hz trace simply loses temporal resolution —
    which is exactly the paper's Fig. 5 point.
    """
    t = np.asarray(times_s, dtype=np.float64)
    w = np.asarray(watts, dtype=np.float64)
    if w.ndim != 1:
        raise ValueError("segment_trace wants a 1-D power series")
    n = w.size
    if n < 4:
        return _single_segment(t, w)

    dt = float(np.median(np.diff(t)))
    if dt <= 0:
        return _single_segment(t, w)
    win = max(1, int(round(smooth_s / dt)))
    lag = max(1, int(round(edge_lag_s / dt)))
    min_sep = max(2, int(round(min_seg_s / dt)))

    s = _boxcar(w, win)
    d = np.zeros(n)
    if n > lag:
        d[lag // 2 : lag // 2 + n - lag] = s[lag:] - s[:-lag]

    sigma = _noise_std(w)
    # floors keep noiseless synthetic traces from tripping on float dust
    span = float(w.max() - w.min())
    sigma_eff = max(sigma, 1e-3 * span, 1e-12)
    sigma_d = sigma_eff / np.sqrt(win) * np.sqrt(2.0)
    cps = _hysteresis_changepoints(d, k_lo * sigma_d, k_hi * sigma_d)
    cps = _enforce_min_separation(cps, np.abs(d[cps]), min_sep)
    cps = cps[(cps >= min_sep) & (cps <= n - min_sep)]

    bounds = np.unique(np.concatenate([[0], cps, [n]]))
    if refine:
        extra = _binary_refine(
            w,
            bounds,
            min_sep,
            penalty * sigma_eff**2 * np.log(max(n, 2)),
            max_depth,
            guard=max(3, win // 2 + lag),
        )
        if extra:
            bounds = np.unique(np.concatenate([bounds, extra]))
    return _build(t, w, bounds, sigma)


def segment_block(
    block: FrameBlock, pair: int | None = None, **kwargs
) -> Segmentation:
    """Segment a `FrameRing` view (``ring.latest()`` / ``ring.window(...)``).

    ``pair`` selects one sensor pair; None sums across pairs (total power).
    """
    w = block.total_watts if pair is None else block.watts[:, pair]
    return segment_trace(block.times_s, w, **kwargs)


def _single_segment(t: np.ndarray, w: np.ndarray) -> Segmentation:
    if w.size == 0:
        return Segmentation([], np.empty(0), 0.0)
    e = float(np.trapezoid(w, t)) if w.size > 1 else 0.0
    seg = Segment(0, w.size, float(t[0]), float(t[-1]), float(w.mean()), float(w.max()), e)
    return Segmentation([seg], np.empty(0), _noise_std(w))


def _build(t: np.ndarray, w: np.ndarray, bounds: np.ndarray, sigma: float) -> Segmentation:
    cumE = cumulative_energy(t, w)
    s1 = np.concatenate([[0.0], np.cumsum(w, dtype=np.float64)])
    segs: list[Segment] = []
    for a, b in zip(bounds[:-1], bounds[1:]):
        a, b = int(a), int(b)
        segs.append(
            Segment(
                i0=a,
                i1=b,
                t0_s=float(t[a]),
                t1_s=float(t[b - 1]),
                mean_w=float((s1[b] - s1[a]) / (b - a)),
                peak_w=float(w[a:b].max()),
                energy_j=float(cumE[b - 1] - cumE[a]),
            )
        )
    return Segmentation(segs, t[bounds[1:-1]], sigma)


def active_spans(
    seg: Segmentation, thresh_w: float | None = None
) -> list[tuple[float, float]]:
    """Merge consecutive above-threshold segments into (t0, t1) spans.

    Default threshold is the midpoint between the lowest and highest
    segment mean — separating kernel bursts from the idle floor, which is
    what `power.tuner`'s attribution-backed strategy scores launches with.
    """
    if not seg.segments:
        return []
    means = np.array([s.mean_w for s in seg.segments])
    if thresh_w is None:
        thresh_w = float((means.min() + means.max()) / 2.0)
    spans: list[tuple[float, float]] = []
    last_i1 = None
    for s, hot in zip(seg.segments, means > thresh_w):
        if hot:
            if spans and last_i1 == s.i0:  # contiguous hot segments merge
                spans[-1] = (spans[-1][0], s.t1_s)
            else:
                spans.append((s.t0_s, s.t1_s))
            last_i1 = s.i1
    return spans
