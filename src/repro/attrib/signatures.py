"""Per-kernel power signatures: normalised waveforms + nearest matching.

Once a labelled trace has been attributed (markers + declared timeline,
see `repro.attrib.attribute`), each kernel's occurrences share a power
*shape* — the Fig. 5/7 observation that individual kernels are visually
identifiable at 20 kHz.  This module makes that operational:

* :func:`build_library` averages every occurrence of every span into a
  :class:`KernelSignature` — the waveform resampled to a fixed grid and
  normalised to relative deviation from its mean, plus duration and
  mean-power scalars;
* :meth:`SignatureLibrary.match` scores an unlabeled interval against the
  whole library at once (stacked L2 over shapes + log-scale penalties on
  duration and mean power) and returns the nearest kernel;
* :func:`identify_segments` labels a marker-free segmentation of a fresh
  trace — kernels recognised with no markers and no timeline at all.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from .attribute import KernelSpan
from .segment import Segmentation


def _watt_prefix(watts: np.ndarray) -> np.ndarray:
    """Shared cumulative sum so many spans resample one trace in one pass."""
    return np.concatenate([[0.0], np.cumsum(watts, dtype=np.float64)])


def _resample(
    times_s: np.ndarray,
    watts: np.ndarray,
    t0: float,
    t1: float,
    n_points: int,
    prefix: np.ndarray | None = None,
) -> np.ndarray:
    """Fixed-grid resampling; bin-averages when the interval is sample-rich
    (knocks the 20 kHz per-sample noise down by √(samples/bin))."""
    edges = np.linspace(t0, t1, n_points + 1)
    idx = np.searchsorted(times_s, edges)
    counts = np.diff(idx)
    if counts.min() >= 2:
        if prefix is None:
            prefix = _watt_prefix(watts)
        return (prefix[idx[1:]] - prefix[idx[:-1]]) / counts
    return np.interp((edges[:-1] + edges[1:]) / 2.0, times_s, watts)


def _normalise(wave: np.ndarray) -> np.ndarray:
    """Relative deviation from the mean, NOT a z-score: z-scoring a flat
    kernel amplifies pure sensor noise to unit variance and swamps the
    duration/power scalars; relative deviation keeps flat kernels flat."""
    mu = float(wave.mean())
    return (wave - mu) / max(abs(mu), 1e-9)


@dataclass
class KernelSignature:
    """Averaged, normalised power waveform of one kernel."""

    name: str
    shape: np.ndarray  # (n_points,) relative-deviation waveform (mean over occurrences)
    duration_s: float  # mean occurrence duration
    mean_w: float  # mean occurrence power
    count: int = 1  # occurrences folded in

    def fold(self, shape: np.ndarray, duration_s: float, mean_w: float) -> None:
        """Running-mean another occurrence into this signature."""
        k = self.count
        self.shape = (self.shape * k + shape) / (k + 1)
        self.duration_s = (self.duration_s * k + duration_s) / (k + 1)
        self.mean_w = (self.mean_w * k + mean_w) / (k + 1)
        self.count = k + 1


@dataclass
class SignatureLibrary:
    """Named signatures + vectorised nearest-signature matching."""

    n_points: int = 64
    #: distance weights: shape L2 is 1.0; these scale the scalar penalties
    duration_weight: float = 0.5
    power_weight: float = 0.5
    signatures: dict[str, KernelSignature] = field(default_factory=dict)

    def __len__(self) -> int:
        return len(self.signatures)

    def add_occurrence(
        self,
        name: str,
        times_s: np.ndarray,
        watts: np.ndarray,
        t0: float,
        t1: float,
        prefix: np.ndarray | None = None,
    ) -> None:
        wave = _resample(times_s, watts, t0, t1, self.n_points, prefix=prefix)
        shape = _normalise(wave)
        dur, mw = t1 - t0, float(wave.mean())
        sig = self.signatures.get(name)
        if sig is None:
            self.signatures[name] = KernelSignature(name, shape, dur, mw)
        else:
            sig.fold(shape, dur, mw)

    # ------------------------------------------------------------- matching
    def _distances(
        self, shape: np.ndarray, duration_s: float, mean_w: float
    ) -> tuple[list[str], np.ndarray]:
        names = list(self.signatures)
        mat = np.stack([self.signatures[n].shape for n in names])
        durs = np.array([self.signatures[n].duration_s for n in names])
        mws = np.array([self.signatures[n].mean_w for n in names])
        d_shape = np.mean((mat - shape[None, :]) ** 2, axis=1)
        d_dur = np.log(np.maximum(duration_s, 1e-9) / np.maximum(durs, 1e-9)) ** 2
        d_pow = np.log(np.maximum(mean_w, 1e-9) / np.maximum(mws, 1e-9)) ** 2
        return names, d_shape + self.duration_weight * d_dur + self.power_weight * d_pow

    def match(
        self,
        times_s: np.ndarray,
        watts: np.ndarray,
        t0: float,
        t1: float,
        prefix: np.ndarray | None = None,
    ) -> tuple[str, float]:
        """Nearest signature for the interval [t0, t1]: (name, distance)."""
        if not self.signatures:
            raise ValueError("empty signature library")
        wave = _resample(
            np.asarray(times_s), np.asarray(watts), t0, t1, self.n_points, prefix=prefix
        )
        names, dist = self._distances(_normalise(wave), t1 - t0, float(wave.mean()))
        k = int(np.argmin(dist))
        return names[k], float(dist[k])

    # -------------------------------------------------------- serialisation
    def to_json(self) -> str:
        return json.dumps(
            {
                "n_points": self.n_points,
                "duration_weight": self.duration_weight,
                "power_weight": self.power_weight,
                "signatures": [
                    {
                        "name": s.name,
                        "shape": s.shape.tolist(),
                        "duration_s": s.duration_s,
                        "mean_w": s.mean_w,
                        "count": s.count,
                    }
                    for s in self.signatures.values()
                ],
            }
        )

    @classmethod
    def from_json(cls, text: str) -> "SignatureLibrary":
        obj = json.loads(text)
        lib = cls(
            n_points=obj["n_points"],
            duration_weight=obj["duration_weight"],
            power_weight=obj["power_weight"],
        )
        for s in obj["signatures"]:
            lib.signatures[s["name"]] = KernelSignature(
                s["name"], np.asarray(s["shape"]), s["duration_s"], s["mean_w"], s["count"]
            )
        return lib


def build_library(
    times_s: np.ndarray,
    watts: np.ndarray,
    spans: Sequence[KernelSpan],
    n_points: int = 64,
) -> SignatureLibrary:
    """Fold every labelled span of a trace into a signature library."""
    lib = SignatureLibrary(n_points=n_points)
    t = np.asarray(times_s, dtype=np.float64)
    w = np.asarray(watts, dtype=np.float64)
    prefix = _watt_prefix(w)
    for s in spans:
        if s.duration_s > 0:
            lib.add_occurrence(s.name, t, w, s.t0_s, s.t1_s, prefix=prefix)
    return lib


def identify_segments(
    times_s: np.ndarray,
    watts: np.ndarray,
    seg: Segmentation,
    library: SignatureLibrary,
    max_distance: float | None = None,
) -> list[tuple[KernelSpan, float]]:
    """Label a marker-free segmentation from a signature library.

    Returns ``(span, distance)`` per segment, with ``span.name`` set to the
    nearest signature — or ``"?"`` when ``max_distance`` is given and no
    signature comes close enough.
    """
    t = np.asarray(times_s, dtype=np.float64)
    w = np.asarray(watts, dtype=np.float64)
    prefix = _watt_prefix(w)
    out: list[tuple[KernelSpan, float]] = []
    for s in seg.segments:
        if s.duration_s <= 0:
            continue
        name, dist = library.match(t, w, s.t0_s, s.t1_s, prefix=prefix)
        if max_distance is not None and dist > max_distance:
            name = "?"
        out.append((KernelSpan(name, s.t0_s, s.t1_s), dist))
    return out
