"""Energy-ranked attribution reports: text, CSV and JSON emitters.

The consumer-facing end of `repro.attrib`: an :class:`EnergyLedger`
(from `attribute`) rendered as the table the paper's case studies print —
kernels ranked by energy, with share-of-total, average/peak power,
occurrence counts and per-occurrence joules.
"""
from __future__ import annotations

import csv
import io
import json

from .attribute import EnergyLedger

_FIELDS = [
    "name",
    "count",
    "energy_j",
    "share",
    "j_per_occurrence",
    "avg_w",
    "peak_w",
    "duration_s",
]


def _rows(ledger: EnergyLedger) -> list[dict]:
    total = ledger.total_energy_j
    return [
        {
            "name": e.name,
            "count": e.count,
            "energy_j": e.energy_j,
            "share": e.energy_j / total if total > 0 else 0.0,
            "j_per_occurrence": e.j_per_occurrence,
            "avg_w": e.avg_w,
            "peak_w": e.peak_w,
            "duration_s": e.duration_s,
        }
        for e in ledger.ranked()
    ]


def render_text(
    ledger: EnergyLedger, top: int | None = None, title: str = "energy ledger"
) -> str:
    """Fixed-width, energy-ranked table (biggest consumer first)."""
    rows = _rows(ledger)
    shown = rows if top is None else rows[:top]
    name_w = max([len(r["name"]) for r in shown] + [6])
    lines = [
        f"# {title}: {ledger.total_energy_j:.3f} J attributed "
        f"({ledger.attributed_fraction * 100.0:.1f}% of trace window)",
        f"{'kernel':<{name_w}} {'n':>4} {'energy_j':>10} {'share':>6} "
        f"{'J/occ':>10} {'avg_w':>8} {'peak_w':>8} {'time_s':>8}",
    ]
    for r in shown:
        lines.append(
            f"{r['name']:<{name_w}} {r['count']:>4d} {r['energy_j']:>10.3f} "
            f"{r['share'] * 100.0:>5.1f}% {r['j_per_occurrence']:>10.4f} "
            f"{r['avg_w']:>8.1f} {r['peak_w']:>8.1f} {r['duration_s']:>8.3f}"
        )
    if top is not None and len(rows) > top:
        rest = sum(r["energy_j"] for r in rows[top:])
        lines.append(f"... {len(rows) - top} more entries, {rest:.3f} J")
    if ledger.skipped_spans:
        lines.append(
            f"# {ledger.skipped_spans} spans skipped "
            f"(too few samples or history evicted)"
        )
    return "\n".join(lines)


def render_csv(ledger: EnergyLedger) -> str:
    buf = io.StringIO()
    w = csv.DictWriter(buf, fieldnames=_FIELDS)
    w.writeheader()
    for r in _rows(ledger):
        w.writerow(r)
    return buf.getvalue()


def render_json(ledger: EnergyLedger, indent: int | None = None) -> str:
    return json.dumps(
        {
            "total_energy_j": ledger.total_energy_j,
            "trace_energy_j": ledger.trace_energy_j,
            "attributed_fraction": ledger.attributed_fraction,
            "t0_s": ledger.t0_s,
            "t1_s": ledger.t1_s,
            "skipped_spans": ledger.skipped_spans,
            "entries": _rows(ledger),
        },
        indent=indent,
    )


def write_report(ledger: EnergyLedger, path_or_file, fmt: str = "text") -> None:
    """Write a report; ``fmt`` is one of ``text`` / ``csv`` / ``json``."""
    renderers = {"text": render_text, "csv": render_csv, "json": render_json}
    if fmt not in renderers:
        raise ValueError(f"unknown report format {fmt!r}")
    text = renderers[fmt](ledger)
    if isinstance(path_or_file, (str, bytes)):
        with open(path_or_file, "w") as f:
            f.write(text)
    else:
        path_or_file.write(text)
