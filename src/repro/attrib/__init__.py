"""`repro.attrib` — per-kernel energy attribution over 20 kHz power traces.

The consumer the sensor stack was missing: maps watts back to kernels,
phases and code regions (the paper's Fig. 5 "identify GPU behavior at
high temporal granularity" claim, made operational).

* `segment`    — vectorised changepoint segmentation (derivative +
  hysteresis edges, binary-segmentation refinement) straight off
  `stream.FrameRing` views;
* `attribute`  — marker-aligned energy ledgers: segments × markers ×
  declared kernel timelines → per-kernel J / avg / peak / count, plus
  step-interval attribution (`interval_spans` / `attribute_intervals`)
  for the continuous-batching serve loop — wave markers are the
  degenerate one-interval case;
* `signatures` — normalised per-kernel waveforms + nearest-signature
  matching so unlabeled segments in fresh traces can be identified;
* `report`     — energy-ranked text / CSV / JSON emitters.

Integration points: `train.loop` (per-step ledgers via `StepAttributor`),
`launch.serve` (per-request step-interval attribution), `power.tuner`
(attribution-backed variant scoring), `benchmarks/attrib_accuracy.py`
(the 20 kHz-vs-builtin-counter granularity experiment).
"""
from .attribute import (
    EnergyLedger,
    KernelSpan,
    LedgerEntry,
    StepAttributor,
    attribute,
    attribute_block,
    attribute_intervals,
    interval_spans,
    marker_spans,
    refine_spans,
    spans_from_segments,
    timeline_spans,
)
from .report import render_csv, render_json, render_text, write_report
from .segment import (
    Segment,
    Segmentation,
    active_spans,
    segment_block,
    segment_trace,
)
from .signatures import (
    KernelSignature,
    SignatureLibrary,
    build_library,
    identify_segments,
)

__all__ = [
    "EnergyLedger",
    "KernelSpan",
    "LedgerEntry",
    "StepAttributor",
    "attribute",
    "attribute_block",
    "attribute_intervals",
    "interval_spans",
    "marker_spans",
    "refine_spans",
    "spans_from_segments",
    "timeline_spans",
    "render_csv",
    "render_json",
    "render_text",
    "write_report",
    "Segment",
    "Segmentation",
    "active_spans",
    "segment_block",
    "segment_trace",
    "KernelSignature",
    "SignatureLibrary",
    "build_library",
    "identify_segments",
]
