"""`repro.power` — TPU-native adaptation of the paper's methodology.

The chip model (`tpu_model`), trace rendering (`trace`), the PMT-analogue
multi-backend meter interface (`pmt`), the energy-aware autotuner
(`tuner`) and training-loop telemetry (`energy`).  See DESIGN.md §2.2.
"""
from .energy import EnergyTelemetry, StepEnergyRecord
from .pmt import (
    BuiltinCounterMeter,
    GroundTruthMeter,
    Measurement,
    PowerMeter,
    PowerSensor3Meter,
    RaplLikeMeter,
    compare_meters,
)
from .trace import RenderedTrace, render_phases, trace_as_load
from .tpu_model import (
    DEFAULT_LADDER,
    V5E,
    DvfsLadder,
    DvfsState,
    Phase,
    StepCost,
    TpuChipSpec,
    phases_for_step,
    step_duration,
    step_energy,
)
from .tuner import (
    AttributionStrategy,
    EnergyTuner,
    KernelVariantModel,
    MeasurementStrategy,
    TuneRecord,
    TuneResultSet,
    attribution_strategy,
    builtin_counter_strategy,
    fast_sensor_strategy,
    tuning_speedup,
)

__all__ = [
    "EnergyTelemetry",
    "StepEnergyRecord",
    "BuiltinCounterMeter",
    "GroundTruthMeter",
    "Measurement",
    "PowerMeter",
    "PowerSensor3Meter",
    "RaplLikeMeter",
    "compare_meters",
    "RenderedTrace",
    "render_phases",
    "trace_as_load",
    "DEFAULT_LADDER",
    "V5E",
    "DvfsLadder",
    "DvfsState",
    "Phase",
    "StepCost",
    "TpuChipSpec",
    "phases_for_step",
    "step_duration",
    "step_energy",
    "AttributionStrategy",
    "EnergyTuner",
    "KernelVariantModel",
    "MeasurementStrategy",
    "TuneRecord",
    "TuneResultSet",
    "attribution_strategy",
    "builtin_counter_strategy",
    "fast_sensor_strategy",
    "tuning_speedup",
]
