"""Power Measurement Toolkit analogue (paper §V-A1): one interface, many
sensor backends, so applications can swap the PowerSensor3 for the
"built-in counter" and see exactly why the paper built external hardware.

Backends
--------
* `PowerSensor3Meter`   — the faithful `repro.core` stack sampling the true
  trace at 20 kHz through the virtual sensor (Table-I noise included).
* `BuiltinCounterMeter` — NVML-class on-board counter: updates at ~10 Hz.
  Two flavours, mirroring NVML's API evolution (paper §II-A / Fig 7a):
  ``mode="average"`` returns a trailing-window average (the pre-530-driver
  'legacy' reading), ``mode="instant"`` returns point samples at the update
  times.
* `RaplLikeMeter`       — 1 kHz cumulative energy counter (CPU-style RAPL):
  accurate energy, limited transient visibility.
* `GroundTruthMeter`    — the trace itself (for test oracles).

All meters consume a ground-truth power trace (times, watts) — in this
repo that is a `RenderedTrace` from the TPU model or any `repro.core.dut`
load — and report what *they* would have measured.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass
class Measurement:
    """What a meter reports for one workload window."""

    meter: str
    sample_times_s: np.ndarray
    sample_watts: np.ndarray
    energy_j: float
    true_energy_j: float
    update_rate_hz: float

    @property
    def energy_error_frac(self) -> float:
        if self.true_energy_j == 0:
            # a zero-truth window must not report perfect accuracy when the
            # meter measured energy anyway: the error is unbounded, not 0
            if self.energy_j == 0:
                return 0.0
            return float("inf") if self.energy_j > 0 else float("-inf")
        return (self.energy_j - self.true_energy_j) / self.true_energy_j

    def captures_transient(self, t0: float, t1: float, min_samples: int = 2) -> bool:
        """Does this meter place >= min_samples inside [t0, t1)?"""
        n = np.sum((self.sample_times_s >= t0) & (self.sample_times_s < t1))
        return bool(n >= min_samples)


def _true_energy(times: np.ndarray, watts: np.ndarray) -> float:
    return float(np.trapezoid(watts, times))


class PowerMeter:
    name = "abstract"
    update_rate_hz = 0.0

    def measure(self, times: np.ndarray, watts: np.ndarray) -> Measurement:
        raise NotImplementedError


class GroundTruthMeter(PowerMeter):
    name = "ground-truth"
    update_rate_hz = float("inf")

    def measure(self, times, watts):
        e = _true_energy(times, watts)
        return Measurement(self.name, times, watts, e, e, self.update_rate_hz)


@dataclass
class PowerSensor3Meter(PowerMeter):
    """Runs the full virtual-hardware chain: TraceLoad → firmware → host."""

    module: str = "pcie8pin-20a"
    volts: float = 12.0
    seed: int = 0
    calibrated: bool = True
    name: str = "powersensor3"
    update_rate_hz: float = 20_000.0

    def measure(self, times, watts):
        from repro.core import ConstantLoad, PowerSensor, TraceLoad, make_device
        from repro.core.calibration import calibrate
        from repro.core.host import DEFAULT_RING_CAPACITY, Joules

        t_end = float(times[-1])
        # ring must retain the whole trace at 20 kHz
        capacity = max(DEFAULT_RING_CAPACITY, int(t_end * 20_000 * 1.05) + 4096)
        dev = make_device([self.module], ConstantLoad(self.volts, 0.0), seed=self.seed)
        ps = PowerSensor(dev, ring_capacity=capacity)
        if self.calibrated:
            calibrate(ps, {0: self.volts}, n_samples=8000)
        dev.firmware.dut.loads[0] = TraceLoad(
            times_s=np.asarray(times),
            watts=np.asarray(watts),
            volts=self.volts,
            t_offset_s=dev.t_s,  # playback starts now, not at device boot
        )
        seq0 = ps.ring.head  # first frame of the playback window
        a = ps.read()
        ps.run_for(t_end)
        b = ps.read()
        block = ps.ring.since(seq0)
        ts = block.times_s
        ws = block.watts[:, 0]
        # device clock started before the trace (calibration); re-zero
        if len(ts):
            ts = ts - ts[0]
        return Measurement(
            self.name, ts, ws, Joules(a, b), _true_energy(times, watts), self.update_rate_hz
        )


@dataclass
class BuiltinCounterMeter(PowerMeter):
    """NVML-style on-board sensor: ~10 Hz updates (paper §II-A, Fig 7a)."""

    update_rate_hz: float = 10.0
    mode: str = "average"  # "average" (legacy) | "instant" (driver >= 530)
    window_s: float = 1.0  # averaging window of the legacy reading
    phase_jitter: float = 0.0

    @property
    def name(self) -> str:
        return f"builtin-{self.mode}"

    def measure(self, times, watts):
        times = np.asarray(times)
        watts = np.asarray(watts)
        t_end = float(times[-1])
        dt = 1.0 / self.update_rate_hz
        sample_ts = np.arange(self.phase_jitter * dt, t_end, dt)
        # dense grid for window averaging
        grid = np.arange(0.0, t_end, 1e-4)
        dense = np.interp(grid, times, watts)
        if self.mode == "instant":
            vals = np.interp(sample_ts, times, watts)
        else:
            from repro.stream.aggregate import windowed_mean_at

            vals = windowed_mean_at(grid, dense, sample_ts, self.window_s)
        # energy as an application would compute it: trapezoid over readings
        energy = float(np.trapezoid(vals, sample_ts)) if len(sample_ts) > 1 else 0.0
        # extend to full window with edge-hold (application has no better info)
        if len(sample_ts) > 1:
            energy += vals[0] * sample_ts[0] + vals[-1] * (t_end - sample_ts[-1])
        return Measurement(self.name, sample_ts, vals, energy, _true_energy(times, watts), self.update_rate_hz)


@dataclass
class RaplLikeMeter(PowerMeter):
    """1 kHz cumulative-energy counter (RAPL-style, paper §II)."""

    update_rate_hz: float = 1000.0
    name: str = "rapl-like"

    def measure(self, times, watts):
        times = np.asarray(times)
        watts = np.asarray(watts)
        t_end = float(times[-1])
        ts = np.arange(0.0, t_end, 1.0 / self.update_rate_hz)
        vals = np.interp(ts, times, watts)
        e = float(np.trapezoid(vals, ts)) if len(ts) > 1 else 0.0
        return Measurement(self.name, ts, vals, e, _true_energy(times, watts), self.update_rate_hz)


def compare_meters(
    times: np.ndarray,
    watts: np.ndarray,
    meters: list[PowerMeter] | None = None,
) -> dict[str, Measurement]:
    """The Fig 7 experiment: same workload, every meter."""
    if meters is None:
        meters = [
            GroundTruthMeter(),
            PowerSensor3Meter(),
            BuiltinCounterMeter(mode="instant"),
            BuiltinCounterMeter(mode="average"),
        ]
    return {m.name: m.measure(times, watts) for m in meters}
