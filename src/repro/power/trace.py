"""Render phase schedules into 20 kHz-resolvable power traces.

The output (times, watts) arrays plug directly into
`repro.core.dut.TraceLoad`, closing the loop: *adapted* TPU workload →
*faithful* sensor stack (DESIGN.md §2.2).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .tpu_model import V5E, DvfsState, Phase, TpuChipSpec


@dataclass
class RenderedTrace:
    times_s: np.ndarray
    watts: np.ndarray
    #: (phase name, start time) for marker correlation
    phase_marks: list[tuple[str, float]]

    @property
    def duration_s(self) -> float:
        return float(self.times_s[-1])

    @property
    def energy_j(self) -> float:
        return float(np.trapezoid(self.watts, self.times_s))

    def sampled(self, fs_hz: float = 20_000.0) -> tuple[np.ndarray, np.ndarray]:
        t = np.arange(0.0, self.duration_s, 1.0 / fs_hz)
        return t, np.interp(t, self.times_s, self.watts)


def render_phases(
    phases: list[Phase],
    chip: TpuChipSpec = V5E,
    dvfs: DvfsState | None = None,
    idle_before_s: float = 0.0,
    idle_after_s: float = 0.0,
    ramp_s: float = 0.0,
    repeat: int = 1,
) -> RenderedTrace:
    """Piecewise trace: each phase holds its average power for its duration.

    ``ramp_s`` adds a linear clock-ramp into the first phase (the paper's
    RTX 4000 Ada takes ~100 ms to reach peak clocks — GPUs ramp; we keep
    the knob so the Fig 7 comparison can show it).
    """
    times: list[float] = [0.0]
    watts: list[float] = [chip.p_static]
    marks: list[tuple[str, float]] = []
    t = 0.0
    if idle_before_s > 0:
        t += idle_before_s
        times.append(t)
        watts.append(chip.p_static)
    for r in range(repeat):
        for i, ph in enumerate(phases):
            p = ph.power(chip, dvfs)
            if ramp_s > 0 and r == 0 and i == 0:
                # linear ramp to the first phase's power
                n = 8
                for k in range(1, n + 1):
                    frac = k / n
                    times.append(t + ramp_s * frac)
                    watts.append(chip.p_static + (p - chip.p_static) * frac)
                t += ramp_s
            marks.append((ph.name if repeat == 1 else f"{ph.name}@{r}", t))
            times.append(t + 1e-9)
            watts.append(p)
            t += ph.duration_s
            times.append(t)
            watts.append(p)
    if idle_after_s > 0:
        times.append(t + 1e-9)
        watts.append(chip.p_static)
        t += idle_after_s
        times.append(t)
        watts.append(chip.p_static)
    return RenderedTrace(np.asarray(times), np.asarray(watts), marks)


def trace_as_load(trace: RenderedTrace, volts: float = 12.0, repeat: bool = False):
    from repro.core.dut import TraceLoad

    return TraceLoad(times_s=trace.times_s, watts=trace.watts, volts=volts, repeat=repeat)
