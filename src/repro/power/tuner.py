"""Energy-aware kernel autotuner (the paper's Kernel Tuner case study, §V-A2).

The paper's methodology:

* enumerate functionally equivalent kernel variants (block dims, fragment
  counts, double buffering) × GPU clock frequencies;
* measure **time and energy** per variant — with PowerSensor3 a variant's
  energy comes from a handful of launches (7 trials) read through markers;
  with the 10 Hz on-board counter each variant must run continuously for
  1–2 s to collect enough samples, stretching tuning by hours (3.25×
  on the Tensor-Core Beamformer);
* report the TFLOP/s vs TFLOP/J Pareto front (Fig 8/10).

Here the variants are Pallas kernel configurations (block shapes, compute
schedule) × DVFS states; per-variant time/energy comes from the TPU model
(`modelled=True`, the CPU container cannot time a TPU) through the full
virtual-sensor chain, so measurement noise and sampling artefacts are
faithfully present.

Three measurement strategies are provided: the fast marker-bracketed
sensor (`fast_sensor_strategy`), the slow builtin counter
(`builtin_counter_strategy`), and — new — `attribution_strategy`, which
needs no markers at all: it recovers each launch burst from the measured
trace by changepoint segmentation and scores the variant on attributed
per-launch energy (see `repro.attrib`).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Callable, Iterable

import numpy as np

from .pmt import BuiltinCounterMeter, PowerSensor3Meter, PowerMeter
from .tpu_model import V5E, DvfsState, Phase, StepCost, TpuChipSpec
from .trace import render_phases


@dataclass(frozen=True)
class KernelVariantModel:
    """A tunable kernel: config -> (time_s, StepCost) on the target chip."""

    name: str
    useful_flops: float
    model: Callable[[dict, TpuChipSpec, DvfsState], tuple[float, StepCost]]
    search_space: dict[str, tuple] = field(default_factory=dict)

    def configs(self) -> Iterable[dict]:
        keys = list(self.search_space)
        for combo in itertools.product(*(self.search_space[k] for k in keys)):
            yield dict(zip(keys, combo))


@dataclass
class TuneRecord:
    config: dict
    dvfs_scale: float
    time_s: float
    joules: float
    tuning_cost_s: float
    meter: str

    @property
    def tflops(self) -> float:
        return self._useful / self.time_s / 1e12 if self.time_s > 0 else 0.0

    @property
    def tflop_per_j(self) -> float:
        return self._useful / self.joules / 1e12 if self.joules > 0 else 0.0

    _useful: float = 0.0


@dataclass
class MeasurementStrategy:
    """How a variant's energy is obtained — the axis of the 3.25× claim."""

    meter: PowerMeter
    n_trials: int = 7
    #: per-variant fixed overhead: compile + launch + host sync
    overhead_s: float = 0.4
    #: minimum continuous runtime the meter needs for a stable reading
    min_window_s: float = 0.0

    def evaluate(
        self, time_s: float, phases: list[Phase], chip: TpuChipSpec, dvfs: DvfsState
    ) -> tuple[float, float]:
        """Returns (joules_per_launch_as_reported, tuning_cost_s)."""
        run_s = max(self.n_trials * time_s, self.min_window_s)
        n_launches = max(self.n_trials, int(np.ceil(run_s / max(time_s, 1e-9))))
        idle_s = 0.002
        trace = render_phases(phases, chip, dvfs, idle_before_s=idle_s, repeat=n_launches)
        meas = self.meter.measure(trace.times_s, trace.watts)
        # subtract the pre-workload idle window (baseline subtraction — what
        # the marker mechanism gives the paper's Kernel Tuner integration)
        joules = (meas.energy_j - chip.p_static * idle_s) / n_launches
        return joules, run_s + self.overhead_s


def fast_sensor_strategy(seed: int = 0) -> MeasurementStrategy:
    """PowerSensor3: 7 launches are enough (markers give per-kernel energy)."""
    return MeasurementStrategy(PowerSensor3Meter(seed=seed), n_trials=7, min_window_s=0.0)


def builtin_counter_strategy() -> MeasurementStrategy:
    """On-board 10 Hz counter: stretch each variant to >= 2 s (paper §V-A2)."""
    return MeasurementStrategy(
        BuiltinCounterMeter(mode="instant"), n_trials=7, min_window_s=2.0
    )


@dataclass
class AttributionStrategy(MeasurementStrategy):
    """Score variants from *segmented* measurements, not whole-window energy.

    Each trial renders one launch **burst** (enough back-to-back launches
    to clear the 20 kHz resolution floor) separated by idle gaps.  The
    measured trace is then carved marker-free by
    `repro.attrib.segment_trace`; bursts are recovered as above-threshold
    spans and attributed individually, and the variant is scored by the
    **median per-launch energy** — robust to baseline drift, stray
    transients and outlier launches, which whole-window integration (and
    its single idle-baseline subtraction) folds straight into the score.
    """

    #: a burst must span at least this long to segment cleanly at 20 kHz
    min_burst_s: float = 0.004
    #: idle gap separating bursts (also the pre/post padding)
    gap_s: float = 0.004

    def evaluate(
        self, time_s: float, phases: list[Phase], chip: TpuChipSpec, dvfs: DvfsState
    ) -> tuple[float, float]:
        from repro.attrib import active_spans, attribute, KernelSpan, segment_trace

        per_burst = max(1, int(np.ceil(self.min_burst_s / max(time_s, 1e-9))))
        sched = [Phase("gap", self.gap_s)] + list(phases) * per_burst
        trace = render_phases(
            sched, chip, dvfs, repeat=self.n_trials, idle_after_s=self.gap_s
        )
        meas = self.meter.measure(trace.times_s, trace.watts)
        seg = segment_trace(meas.sample_times_s, meas.sample_watts)
        spans = [
            KernelSpan(f"burst{i}", t0, t1)
            for i, (t0, t1) in enumerate(active_spans(seg))
        ]
        run_s = float(trace.times_s[-1])
        if not spans:  # degenerate trace: fall back to whole-window scoring
            joules = (meas.energy_j - chip.p_static * (run_s - self.n_trials
                      * time_s * per_burst)) / (self.n_trials * per_burst)
            return joules, run_s + self.overhead_s
        ledger = attribute(meas.sample_times_s, meas.sample_watts, spans)
        burst_j = np.array([e.energy_j for e in ledger.entries.values()])
        return float(np.median(burst_j) / per_burst), run_s + self.overhead_s


def attribution_strategy(seed: int = 0, n_trials: int = 7) -> AttributionStrategy:
    """Marker-free PowerSensor3 scoring via trace segmentation (attrib)."""
    return AttributionStrategy(
        PowerSensor3Meter(seed=seed), n_trials=n_trials, min_window_s=0.0
    )


@dataclass
class TuneResultSet:
    records: list[TuneRecord]
    total_tuning_time_s: float
    meter: str

    def pareto_front(self) -> list[TuneRecord]:
        """Non-dominated set maximising (tflops, tflop_per_j)."""
        # tie-break on efficiency so equal-speed, lower-efficiency points
        # never precede (and shadow) their dominating twins
        recs = sorted(self.records, key=lambda r: (-r.tflops, -r.tflop_per_j))
        front: list[TuneRecord] = []
        best_eff = -1.0
        last_tflops = None
        for r in recs:
            if r.tflop_per_j > best_eff and r.tflops != last_tflops:
                front.append(r)
                best_eff = r.tflop_per_j
            last_tflops = r.tflops
        return front

    def fastest(self) -> TuneRecord:
        return max(self.records, key=lambda r: r.tflops)

    def most_efficient(self) -> TuneRecord:
        return max(self.records, key=lambda r: r.tflop_per_j)


class EnergyTuner:
    def __init__(self, chip: TpuChipSpec = V5E):
        self.chip = chip

    def tune(
        self,
        kernel: KernelVariantModel,
        strategy: MeasurementStrategy,
        dvfs_states: list[DvfsState] | None = None,
        max_configs: int | None = None,
        exact_energy: bool = False,
    ) -> TuneResultSet:
        """Evaluate the full (config × dvfs) space with one strategy.

        ``exact_energy=True`` bypasses the virtual meter (fast, for large
        sweeps) and integrates the model trace directly; the Fig 8
        benchmark uses the real meter on a subsample to keep fidelity.
        """
        dvfs_states = dvfs_states or [DvfsState(1.0)]
        records: list[TuneRecord] = []
        total_cost = 0.0
        for i, cfg in enumerate(kernel.configs()):
            if max_configs is not None and i >= max_configs:
                break
            for dv in dvfs_states:
                time_s, cost = kernel.model(cfg, self.chip, dv)
                phases = [
                    Phase(
                        kernel.name,
                        time_s,
                        flops=cost.flops,
                        hbm_bytes=cost.hbm_bytes,
                        ici_bytes=cost.ici_bytes,
                    )
                ]
                if exact_energy:
                    from .tpu_model import step_energy

                    joules = step_energy(phases, self.chip, dv)
                    run_s = max(strategy.n_trials * time_s, strategy.min_window_s)
                    tcost = run_s + strategy.overhead_s
                else:
                    joules, tcost = strategy.evaluate(time_s, phases, self.chip, dv)
                total_cost += tcost
                rec = TuneRecord(
                    config=dict(cfg),
                    dvfs_scale=dv.scale,
                    time_s=time_s,
                    joules=joules,
                    tuning_cost_s=tcost,
                    meter=strategy.meter.name,
                )
                rec._useful = kernel.useful_flops
                records.append(rec)
        return TuneResultSet(records, total_cost, strategy.meter.name)


def tuning_speedup(
    kernel: KernelVariantModel,
    chip: TpuChipSpec = V5E,
    dvfs_states: list[DvfsState] | None = None,
    max_configs: int | None = None,
) -> tuple[float, TuneResultSet, TuneResultSet]:
    """Reproduce the paper's 3.25× tuning-time comparison (modelled costs).

    Uses exact energies for both strategies (the *costs* differ by
    methodology, the energies don't) so large spaces sweep quickly.
    """
    tuner = EnergyTuner(chip)
    fast = tuner.tune(
        kernel, fast_sensor_strategy(), dvfs_states, max_configs, exact_energy=True
    )
    slow = tuner.tune(
        kernel, builtin_counter_strategy(), dvfs_states, max_configs, exact_energy=True
    )
    return slow.total_tuning_time_s / fast.total_tuning_time_s, fast, slow
