"""Analytical TPU-chip power/performance model (the PowerSensor3 "DUT").

Hardware-adaptation layer (DESIGN.md §2.2): the paper measures GPUs through
physical rails; our target is a TPU v5e-class chip, so the device under
test becomes an analytical model driven by **compiled HLO** — the same
quantities the roofline analysis extracts from the dry-run:

    P(t) = P_static + e_flop · flop_rate(t) + e_hbm · hbm_rate(t)
                    + e_ici · ici_rate(t)

Hardware constants (per chip, the numbers used throughout this repo):

* peak compute  : 197 TFLOP/s bf16
* HBM bandwidth : 819 GB/s
* ICI           : ~50 GB/s/link, 4 links (2D torus)
* HBM capacity  : 16 GiB

Energy constants are engineering estimates (documented, not vendor data):
at full MXU utilisation the dynamic compute power is ~89 W, at full HBM
streaming ~74 W, giving a ~220 W busy chip over a 55 W static floor —
consistent with public v5e TDP-class figures.  The *relative* phenomena
the paper demonstrates (transients, phase dips, energy-vs-speed Pareto)
are what the reproduction targets; see DESIGN.md §7.

DVFS: TPUs expose limited frequency control compared to `nvidia-smi -lgc`,
but the mechanism the paper tunes over (clock scaling) is modelled here:
``time ∝ 1/s`` for compute-bound phases and dynamic power ``∝ s·V(s)²``
with a linear voltage/frequency curve — the classic CMOS model.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class TpuChipSpec:
    name: str = "tpu-v5e-sim"
    peak_flops_bf16: float = 197e12
    hbm_bw: float = 819e9
    ici_links: int = 4
    ici_bw_per_link: float = 50e9
    hbm_bytes: int = 16 * 2**30
    vmem_bytes: int = 128 * 2**20
    mxu_dim: int = 128

    # energy model constants (J per op / per byte) + static floor (W)
    p_static: float = 55.0
    e_flop: float = 0.45e-12
    e_hbm_byte: float = 90e-12
    e_ici_byte: float = 70e-12

    @property
    def ici_bw(self) -> float:
        return self.ici_links * self.ici_bw_per_link

    @property
    def p_peak(self) -> float:
        return (
            self.p_static
            + self.e_flop * self.peak_flops_bf16
            + self.e_hbm_byte * self.hbm_bw
        )

    # ------------------------------------------------------------- power
    def power(
        self,
        flop_rate: float = 0.0,
        hbm_rate: float = 0.0,
        ici_rate: float = 0.0,
        dvfs: "DvfsState | None" = None,
    ) -> float:
        dyn = (
            self.e_flop * flop_rate
            + self.e_hbm_byte * hbm_rate
            + self.e_ici_byte * ici_rate
        )
        if dvfs is not None:
            dyn *= dvfs.power_factor
        return self.p_static + dyn

    # ------------------------------------------------------------- roofline
    def roofline_times(
        self, flops: float, hbm_bytes: float, ici_bytes: float, dvfs: "DvfsState | None" = None
    ) -> tuple[float, float, float]:
        """(t_compute, t_memory, t_collective) — the three §Roofline terms."""
        scale = dvfs.scale if dvfs else 1.0
        return (
            flops / (self.peak_flops_bf16 * scale),
            hbm_bytes / self.hbm_bw,
            ici_bytes / self.ici_bw,
        )

    def step_time(self, flops: float, hbm_bytes: float, ici_bytes: float, **kw) -> float:
        return max(self.roofline_times(flops, hbm_bytes, ici_bytes, **kw))


V5E = TpuChipSpec()


@dataclass(frozen=True)
class DvfsState:
    """Clock/voltage scaling state. scale = f/f_max ∈ (0, 1]."""

    scale: float = 1.0
    v_floor: float = 0.65  # V(s)/V(1) at s→0 intercept

    @property
    def voltage_ratio(self) -> float:
        return self.v_floor + (1.0 - self.v_floor) * self.scale

    @property
    def power_factor(self) -> float:
        """dynamic power ∝ f · V², normalised to 1 at full clock."""
        return self.scale * self.voltage_ratio**2

    @classmethod
    def sweep(cls, lo: float = 0.6, hi: float = 1.0, n: int = 9) -> list["DvfsState"]:
        return [cls(scale=lo + i * (hi - lo) / (n - 1)) for i in range(n)]


@dataclass(frozen=True)
class DvfsLadder:
    """Discrete DVFS operating points a governor can actuate.

    Real clock control is quantised (`nvidia-smi -lgc` accepts a table of
    frequencies, not a continuum); the closed-loop governor in
    `repro.sched` steps this ladder rather than an ideal analogue knob.
    Scales are kept sorted ascending so ``index`` 0 is the power floor and
    ``len(ladder) - 1`` is full clock.
    """

    scales: tuple[float, ...] = (0.5, 0.55, 0.6, 0.65, 0.7, 0.75, 0.8, 0.85, 0.9, 0.95, 1.0)
    v_floor: float = 0.65

    def __post_init__(self) -> None:
        if not self.scales:
            raise ValueError("empty DVFS ladder")
        if any(s <= 0 or s > 1.0 for s in self.scales):
            raise ValueError("DVFS scales must be in (0, 1]")
        if list(self.scales) != sorted(self.scales):
            object.__setattr__(self, "scales", tuple(sorted(self.scales)))

    def __len__(self) -> int:
        return len(self.scales)

    def clamp(self, index: int) -> int:
        return min(max(index, 0), len(self.scales) - 1)

    def state(self, index: int) -> DvfsState:
        return DvfsState(scale=self.scales[self.clamp(index)], v_floor=self.v_floor)

    def states(self) -> list[DvfsState]:
        return [DvfsState(scale=s, v_floor=self.v_floor) for s in self.scales]

    def nearest(self, scale: float) -> int:
        """Index of the ladder point closest to an ideal (continuous) scale."""
        diffs = [abs(s - scale) for s in self.scales]
        return diffs.index(min(diffs))


DEFAULT_LADDER = DvfsLadder()


# ---------------------------------------------------------------------------
# step costs and phase schedules
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class StepCost:
    """Per-device, per-step cost triple — the contract between the dry-run
    roofline extraction (`repro.launch.roofline`) and the power model."""

    flops: float
    hbm_bytes: float
    ici_bytes: float

    def __add__(self, o: "StepCost") -> "StepCost":
        return StepCost(self.flops + o.flops, self.hbm_bytes + o.hbm_bytes, self.ici_bytes + o.ici_bytes)

    def scaled(self, k: float) -> "StepCost":
        return StepCost(self.flops * k, self.hbm_bytes * k, self.ici_bytes * k)


@dataclass(frozen=True)
class Phase:
    """One power phase: a named interval with average resource rates."""

    name: str
    duration_s: float
    flops: float = 0.0
    hbm_bytes: float = 0.0
    ici_bytes: float = 0.0

    def power(self, chip: TpuChipSpec, dvfs: DvfsState | None = None) -> float:
        if self.duration_s <= 0:
            return chip.p_static
        return chip.power(
            self.flops / self.duration_s,
            self.hbm_bytes / self.duration_s,
            self.ici_bytes / self.duration_s,
            dvfs=dvfs,
        )


def phases_for_step(
    cost: StepCost,
    n_layers: int,
    chip: TpuChipSpec = V5E,
    dvfs: DvfsState | None = None,
    layer_fraction: float = 0.9,
    efficiency: float = 0.85,
    overlap_collectives: bool = False,
) -> list[Phase]:
    """Schedule a train/serve step into power phases.

    The structure mirrors what the paper observes on real accelerators
    (Fig 7): per-layer compute bursts separated by collective phases, then
    an optimizer/gradient-sync tail.  ``layer_fraction`` of the cost is
    attributed to the layer loop, the rest to embed/head/optimizer.

    With ``overlap_collectives`` the ICI time hides under compute (the
    classic distributed-optimization trick); power during overlapped
    phases includes both rate terms.
    """
    scale = dvfs.scale if dvfs else 1.0
    lf, tail = layer_fraction, 1.0 - layer_fraction
    layer = cost.scaled(lf / n_layers)
    t_comp = max(
        layer.flops / (chip.peak_flops_bf16 * scale * efficiency),
        layer.hbm_bytes / (chip.hbm_bw * efficiency),
    )
    t_coll = layer.ici_bytes / (chip.ici_bw * efficiency)
    phases: list[Phase] = []
    for i in range(n_layers):
        if overlap_collectives:
            t = max(t_comp, t_coll)
            phases.append(
                Phase(f"layer{i}", t, layer.flops, layer.hbm_bytes, layer.ici_bytes)
            )
        else:
            phases.append(Phase(f"layer{i}", t_comp, layer.flops, layer.hbm_bytes, 0.0))
            if t_coll > 0:
                phases.append(Phase(f"coll{i}", t_coll, 0.0, 0.0, layer.ici_bytes))
    tail_cost = cost.scaled(tail)
    t_tail = max(
        tail_cost.flops / (chip.peak_flops_bf16 * scale * efficiency),
        tail_cost.hbm_bytes / (chip.hbm_bw * efficiency),
        tail_cost.ici_bytes / (chip.ici_bw * efficiency),
    )
    phases.append(
        Phase("opt+sync", t_tail, tail_cost.flops, tail_cost.hbm_bytes, tail_cost.ici_bytes)
    )
    return phases


def step_duration(phases: list[Phase]) -> float:
    return sum(p.duration_s for p in phases)


def step_energy(phases: list[Phase], chip: TpuChipSpec = V5E, dvfs: DvfsState | None = None) -> float:
    return sum(p.power(chip, dvfs) * p.duration_s for p in phases)
