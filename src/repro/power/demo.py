"""Small demo traces used by `repro.core.tools` workloads and docs."""
from __future__ import annotations

import numpy as np

from .tpu_model import StepCost, V5E, phases_for_step
from .trace import render_phases


def demo_train_trace() -> tuple[np.ndarray, np.ndarray]:
    """One synthetic ~100M-model train step on the v5e model (per chip)."""
    cost = StepCost(flops=2.5e12, hbm_bytes=6.0e11, ici_bytes=2.0e10)
    phases = phases_for_step(cost, n_layers=12, chip=V5E)
    tr = render_phases(phases, V5E, idle_before_s=0.01, idle_after_s=0.01)
    return tr.times_s, tr.watts
