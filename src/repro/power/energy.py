"""Per-step energy telemetry for the training/serving loops.

This is the "energy as a first-class metric" integration the paper argues
for: every trainer step emits a `StepEnergyRecord` (J/step, J/token,
TFLOP/J), computed from the step's HLO-derived `StepCost` through the TPU
power model — and optionally verified through the full virtual-sensor
chain (`psrun`-style wrapping).
"""
from __future__ import annotations

import csv
import io
from dataclasses import asdict, dataclass, field

from .tpu_model import (
    V5E,
    DvfsState,
    StepCost,
    TpuChipSpec,
    phases_for_step,
    step_duration,
    step_energy,
)


@dataclass
class StepEnergyRecord:
    step: int
    wall_time_s: float  # host wall time (CPU here; TPU in production)
    modelled_time_s: float  # TPU-model step time
    joules: float
    tokens: int
    useful_flops: float

    @property
    def j_per_token(self) -> float:
        return self.joules / self.tokens if self.tokens else 0.0

    @property
    def tflop_per_j(self) -> float:
        return self.useful_flops / self.joules / 1e12 if self.joules else 0.0

    @property
    def avg_watts(self) -> float:
        return self.joules / self.modelled_time_s if self.modelled_time_s else 0.0


@dataclass
class EnergyTelemetry:
    """Attach to a training loop; records one entry per step."""

    cost_per_step: StepCost
    n_layers: int
    useful_flops_per_step: float = 0.0
    chip: TpuChipSpec = field(default_factory=lambda: V5E)
    dvfs: DvfsState = field(default_factory=DvfsState)
    overlap_collectives: bool = False
    records: list[StepEnergyRecord] = field(default_factory=list)

    def __post_init__(self) -> None:
        self._phases = phases_for_step(
            self.cost_per_step,
            self.n_layers,
            self.chip,
            self.dvfs,
            overlap_collectives=self.overlap_collectives,
        )
        self._step_time = step_duration(self._phases)
        self._step_energy = step_energy(self._phases, self.chip, self.dvfs)

    @property
    def phases(self) -> list:
        """The declared per-step kernel timeline (`repro.attrib` consumes
        this as the ground truth to lay out between step markers)."""
        return list(self._phases)

    @property
    def modelled_step_time_s(self) -> float:
        return self._step_time

    @property
    def modelled_step_joules(self) -> float:
        return self._step_energy

    def record_step(self, step: int, wall_time_s: float, tokens: int) -> StepEnergyRecord:
        rec = StepEnergyRecord(
            step=step,
            wall_time_s=wall_time_s,
            modelled_time_s=self._step_time,
            joules=self._step_energy,
            tokens=tokens,
            useful_flops=self.useful_flops_per_step,
        )
        self.records.append(rec)
        return rec

    # ------------------------------------------------------------------
    def total_joules(self) -> float:
        return sum(r.joules for r in self.records)

    def summary(self) -> dict:
        if not self.records:
            return {}
        n = len(self.records)
        return {
            "steps": n,
            "total_joules": self.total_joules(),
            "j_per_step": self.total_joules() / n,
            "j_per_token": self.total_joules() / max(1, sum(r.tokens for r in self.records)),
            "avg_modelled_watts": self.records[-1].avg_watts,
            "tflop_per_j": self.records[-1].tflop_per_j,
            "modelled_step_s": self._step_time,
        }

    def write_csv(self, path_or_file) -> None:
        f = open(path_or_file, "w", newline="") if isinstance(path_or_file, str) else path_or_file
        w = csv.DictWriter(
            f,
            fieldnames=[
                "step", "wall_time_s", "modelled_time_s", "joules", "tokens", "useful_flops",
            ],
        )
        w.writeheader()
        for r in self.records:
            w.writerow(asdict(r))
        if isinstance(path_or_file, str):
            f.close()

    # ------------------------------------------------------------------
    def verify_with_sensor(self, n_steps: int = 3, seed: int = 0) -> dict:
        """psrun-style cross-check: run n steps through the virtual sensor
        and compare against the model integral (catches model drift)."""
        import math

        from .pmt import PowerSensor3Meter
        from .trace import render_phases

        # the 20 kHz sensor needs enough signal: cover >= 0.25 s of frames
        if self._step_time > 0:
            n_steps = max(n_steps, math.ceil(0.25 / self._step_time))
        n_steps = min(n_steps, 100_000)
        trace = render_phases(self._phases, self.chip, self.dvfs, repeat=n_steps)
        meas = PowerSensor3Meter(seed=seed).measure(trace.times_s, trace.watts)
        model_j = self._step_energy * n_steps
        return {
            "sensor_joules": meas.energy_j,
            "model_joules": model_j,
            "rel_err": (meas.energy_j - model_j) / model_j if model_j else 0.0,
        }
