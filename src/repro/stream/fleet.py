"""FleetMonitor: one queryable snapshot API over N PowerSensor devices.

Scales the host side from "one sensor, one script" to a fleet of devices
feeding live consumers (paper §III-C's lightweight-receiver design, applied
per device).  The monitor

* owns named `PowerSensor` instances (any object with the PowerSensor
  surface: ``poll``, ``read``, ``mark``, ``ring``, ``markers``, ``device``);
* drains them **round-robin** (``poll(k)`` / ``poll_all()``) or via one
  background receiver thread per device (``start_threads``);
* exposes `snapshot()`: per-device windowed stats (from each device's ring
  buffer) plus fleet aggregates computed as the sum over devices;
* answers **marker-aligned interval queries**: energy / average power per
  device between two named markers, straight from the ring buffer.

For *per-kernel* accounting on top of these primitives — changepoint
segmentation of ring views, marker-aligned energy ledgers, power
signatures — see `repro.attrib` (`segment_block` / `attribute_block`
consume the same `FrameBlock`s that `interval()` reads).

Degraded-telemetry semantics (the contract `repro.faultlab` tests):

=============  ==============================  =================================
state          entered when                    effect on fleet queries
=============  ==============================  =================================
healthy        frames younger than             contributes its windowed power
               ``stale_after_s``
stale          no frames for                   excluded from `fleet_power`; the
               ``stale_after_s``               healthy sum is rescaled by the
                                               known fleet fraction (quorum)
lost           no frames for ``lost_after_s``  excluded, and counted against
               *or* its receiver thread died   ``min_quorum_frac``
link-lost      its transport ``read()``        mapped to ``lost`` immediately
(lost)         raised out of a fleet poll      (the poller survives; the error
               (socket died mid-poll)          is held until a later poll
                                               succeeds — reacquire — and is
                                               surfaced via `stop_threads`)
attach-grace   the device was just added, or   staleness is measured from the
(healthy)      `FleetHead` reacquired its      *attach time*, not from an
               link (`note_attach`)            empty ring's epoch — a fresh
                                               device gets ``stale_after_s``
                                               of grace to deliver its first
                                               frame instead of being born
                                               ``lost`` (and emitting a bogus
                                               lost→healthy transition)
backpressure   a bounded link buffer filled    no frame loss and no health
               (`repro.net` receive queues,    change: the reader pauses, the
               server send windows)            sender blocks on the socket,
                                               and the stall is *counted*
                                               (``backpressure_waits``), so a
                                               slow consumer shows up in link
                                               stats instead of as drops
=============  ==============================  =================================

Lock-free reader rules (what `fleet_power` / `window_power_w` see while
the receiver — solo or pooled — is mid-publish):

* `FrameRing.append` runs under the receiver lock and brackets its slice
  writes with a seqlock ``version`` counter (odd while mutating).  Hot
  readers (`tail_mean_watts`) take **no lock**: they snapshot the version,
  reduce, and retry if the version moved.  A reader therefore never
  observes a torn frame — each individual slice store is atomic under the
  GIL, and any read that overlapped a publish is discarded and retried;
* health scans read preallocated per-device mirrors (``last_time_s``,
  ``head``) that the ring updates *after* the version counter closes, so
  a mirror value never refers to frames that are not yet readable;
* block readers (`marker_window`, `snapshot`, `tail_window`) still take
  the receiver lock — they return multi-array copies whose consistency a
  version counter alone cannot vouch for.

When *no* device is healthy, `fleet_power` holds the last good reading
for up to ``holdover_s`` (``holdover=True``); the reading is flagged
``stale`` whenever quorum drops below ``min_quorum_frac``, and consumers
(the power-cap governor) must treat a stale reading as a safety event,
not a number.

Observability under degradation (mirrored in the README table): every
health transition lands one ``health:<from>-><to>`` trace instant plus a
``fleet_health_transitions_total`` increment; stale / holdover readings
are counted per reading (``fleet_stale_reads_total`` /
``fleet_holdover_reads_total``) while stale entry/exit are *edge* events
on the trace timeline; the signature watchdog skips stale/lost devices
(``watchdog_skipped_total``) and freezes their cursors, so recovery
resumes from fresh data instead of re-judging the past.

This module deliberately avoids importing `repro.core` at module scope —
`repro.core.host` imports `repro.stream.ring`, and keeping this side lazy
keeps the package import-cycle free.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Iterable, Mapping

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

from .aggregate import WindowStats, window_stats
from .ring import FrameBlock, FrameRing

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.host import PowerSensor, State


@dataclass(frozen=True)
class DeviceSnapshot:
    name: str
    state: "State"
    window: WindowStats


@dataclass(frozen=True)
class FleetAggregate:
    """Fleet-wide totals: the sum over per-device windowed stats."""

    n_devices: int
    n_frames: int
    mean_w: float  # sum of per-device windowed mean watts
    peak_w: float  # sum of per-device peaks (synchronous-peak upper bound)
    ewma_w: float
    energy_j: float


@dataclass(frozen=True)
class FleetSnapshot:
    time_s: float
    devices: dict[str, DeviceSnapshot]
    aggregate: FleetAggregate


@dataclass(frozen=True)
class DeviceHealth:
    """One device's telemetry liveness at a point in time."""

    name: str
    state: str  # 'healthy' | 'stale' | 'lost'
    staleness_s: float  # now − newest retained frame time
    last_frame_s: float
    receiver_alive: bool  # False when a started poller thread died
    dropped_frames: int

    @property
    def healthy(self) -> bool:
        return self.state == "healthy"


@dataclass(frozen=True)
class FleetPowerReading:
    """Quorum-aware fleet power: a number plus how much to trust it.

    ``power_w`` is the healthy-device sum rescaled by the known fleet
    fraction (``n_total / n_healthy``); ``raw_power_w`` is the unscaled
    healthy sum.  ``stale`` means the estimate must not be trusted for
    control (quorum below ``min_quorum_frac``, or no healthy device at
    all); ``holdover`` means ``power_w`` is the *last good* reading, held
    because nothing fresh exists.  ``data_age_s`` is the age of the data
    behind ``power_w`` (0 for a live reading).
    """

    power_w: float
    raw_power_w: float
    n_healthy: int
    n_total: int
    quorum_frac: float
    stale: bool
    holdover: bool
    time_s: float
    data_age_s: float = 0.0


@dataclass(frozen=True)
class IntervalStats:
    """Marker-aligned interval query result for one device."""

    t0_s: float
    t1_s: float
    n_frames: int
    energy_j: np.ndarray  # per pair
    mean_w: np.ndarray  # per pair

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s

    @property
    def total_energy_j(self) -> float:
        return float(self.energy_j.sum())

    @property
    def total_mean_w(self) -> float:
        return float(self.mean_w.sum())


class FleetMonitor:
    """Own, poll, and aggregate over a fleet of PowerSensor devices."""

    def __init__(
        self,
        sensors: Mapping[str, "PowerSensor"] | None = None,
        window_s: float = 1.0,
        pct: float = 95.0,
        stale_after_s: float | None = None,
        lost_after_s: float | None = None,
        min_quorum_frac: float = 0.5,
        holdover_s: float | None = None,
    ):
        self._sensors: dict[str, PowerSensor] = {}
        self.window_s = float(window_s)
        self.pct = float(pct)
        # degraded-telemetry thresholds (see the module docstring table)
        self.stale_after_s = (
            max(2.0 * self.window_s, 0.005)
            if stale_after_s is None
            else float(stale_after_s)
        )
        self.lost_after_s = (
            10.0 * self.stale_after_s if lost_after_s is None else float(lost_after_s)
        )
        self.min_quorum_frac = float(min_quorum_frac)
        self.holdover_s = (
            5.0 * self.stale_after_s if holdover_s is None else float(holdover_s)
        )
        self._last_good: tuple[float, float] | None = None  # (time, power_w)
        # transports whose read() raised out of a fleet poll: the device
        # is reported `lost` (not crashed-silent) until a poll succeeds
        self._poll_errors: dict[str, BaseException] = {}
        self._rr = 0  # round-robin cursor
        self._last_health: dict[str, str] = {}  # for obs transition events
        self._stale_streak = False  # edge-trigger for stale-read events
        # attach times: health grace windows start here, not at frame 0
        self._attach_t: dict[str, float] = {}
        # preallocated per-device vectors for the health/power hot path:
        # rings mirror (last_time_s, head) into slots via bind_stats, so a
        # 1 kHz fleet_power tick does vector arithmetic instead of a dict
        # loop over N dataclasses (see _health_vectors)
        self._vnames: list[str] = []
        self._vsensors: list = []
        self._v_last_t = np.zeros(0)
        self._v_head = np.zeros(0, dtype=np.int64)
        self._v_attach = np.zeros(0)
        self._v_err = np.zeros(0, dtype=bool)
        self._v_alive = np.zeros(0, dtype=bool)
        self._prev_code = np.zeros(0, dtype=np.int8)  # -1 = never sighted
        self._unmirrored: list[int] = []  # duck rings without bind_stats
        self._pool = None  # optional PooledDecoder (see enable_pool)
        if sensors:
            for name, ps in sensors.items():
                self.add(name, ps)

    # ------------------------------------------------------------ membership
    def add(self, name: str, sensor: "PowerSensor") -> None:
        if name in self._sensors:
            raise ValueError(f"duplicate device name {name!r}")
        self._sensors[name] = sensor
        # label the receiver's own trace events with the fleet name
        if getattr(sensor, "obs_name", None) is None:
            try:
                sensor.obs_name = name
            except AttributeError:  # duck-typed sensor with __slots__
                pass
        self._rebuild_vectors()
        # health grace starts now: a device joining a long-running fleet
        # must not be born `lost` just because its ring is still empty
        self.note_attach(name)

    def note_attach(self, name: str) -> None:
        """(Re)start ``name``'s health grace window at the fleet's now.

        Called on `add` and by `FleetHead` after a redial reacquires a
        link: staleness is measured from this attach time (or the newest
        frame, whichever is later), so a fresh or reacquired device gets
        ``stale_after_s`` to deliver its first frame instead of reading
        ``staleness = now`` off an empty/frozen ring and instantly
        classifying `lost` (which also emitted a spurious lost→healthy
        transition on the first frame).
        """
        t = self._now_s()
        self._attach_t[name] = t
        i = self._vnames.index(name) if name in self._sensors else -1
        if i >= 0:
            self._v_attach[i] = t

    def _rebuild_vectors(self) -> None:
        """Rebuild the preallocated health mirrors after membership changes."""
        names = list(self._sensors)
        self._vnames = names
        self._vsensors = [self._sensors[nm] for nm in names]
        n = len(names)
        self._v_last_t = np.zeros(n)
        self._v_head = np.zeros(n, dtype=np.int64)
        self._v_attach = np.array(
            [self._attach_t.get(nm, 0.0) for nm in names]
        ) if n else np.zeros(0)
        self._v_err = np.array([nm in self._poll_errors for nm in names], dtype=bool)
        self._v_alive = np.ones(n, dtype=bool)
        code_of = {"healthy": 0, "stale": 1, "lost": 2}
        self._prev_code = np.array(
            [code_of.get(self._last_health.get(nm), -1) for nm in names],
            dtype=np.int8,
        )
        self._unmirrored = []
        for i, ps in enumerate(self._vsensors):
            ring = getattr(ps, "ring", None)
            if ring is not None and hasattr(ring, "bind_stats"):
                ring.bind_stats(self._v_last_t, self._v_head, i)
            else:
                self._unmirrored.append(i)

    def __len__(self) -> int:
        return len(self._sensors)

    def __getitem__(self, name: str) -> "PowerSensor":
        return self._sensors[name]

    @property
    def names(self) -> list[str]:
        return list(self._sensors)

    # ------------------------------------------------------------ polling
    def _safe_poll(self, name: str, ps: "PowerSensor") -> int:
        """Poll one device; a raising transport maps to `lost`, not a crash.

        A socket that dies mid-``read()`` raises out of ``poll()``; killing
        the whole fleet poller for one bad link would silently freeze every
        *other* device's ring.  The error is recorded (driving the device's
        health to ``lost``, surfaced later by `stop_threads`) and cleared
        again by the first successful poll — the reacquire path.
        """
        try:
            n = ps.poll()
        except BaseException as exc:
            self._mark_poll_error(name, exc)
            return 0
        self._clear_poll_error(name)
        return n

    def _mark_poll_error(self, name: str, exc: BaseException) -> None:
        fresh = name not in self._poll_errors
        self._poll_errors[name] = exc
        try:
            self._v_err[self._vnames.index(name)] = True
        except ValueError:
            pass
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter(
                "fleet_poll_errors_total",
                "transport read() failures escaping a device poll",
                device=name,
            ).inc()
        if fresh:
            rec = obs_trace.active()
            if rec is not None:
                rec.device_instant(
                    f"link:poll-error:{type(exc).__name__}",
                    self._now_s(), track=f"health:{name}",
                )

    def _clear_poll_error(self, name: str) -> None:
        if self._poll_errors.pop(name, None) is not None:
            try:
                self._v_err[self._vnames.index(name)] = False
            except ValueError:
                pass

    def poll(self, k: int = 1) -> int:
        """Drain the next ``k`` devices round-robin. Returns frames seen."""
        names = self.names
        if not names:
            return 0
        total = 0
        for _ in range(min(k, len(names))):
            name = names[self._rr % len(names)]
            self._rr += 1
            total += self._safe_poll(name, self._sensors[name])
        return total

    def poll_all(self) -> int:
        if self._pool is not None:
            res = self._pool.poll()
            if self._poll_errors:  # reacquired links clear on first success
                for nm in res.polled:
                    self._clear_poll_error(nm)
            for nm, exc in res.errors.items():
                self._mark_poll_error(nm, exc)
            return res.frames
        return self.poll(len(self._sensors))

    def enable_pool(self):
        """Switch `poll_all` to the fused fleet-wide decode path.

        Builds a `repro.stream.pool.PooledDecoder` over this monitor's
        sensors (membership changes are picked up live).  Decoded output
        is bit-identical to per-device polling; only the cost changes —
        one fused numpy pass instead of N full receiver passes.
        """
        if self._pool is None:
            from .pool import PooledDecoder

            self._pool = PooledDecoder(self._sensors)
        return self._pool

    @property
    def pool(self):
        """The attached `PooledDecoder` (None: per-device polling)."""
        return self._pool

    @property
    def poll_errors(self) -> dict[str, BaseException]:
        """Live view of per-device transport errors (cleared on reacquire)."""
        return dict(self._poll_errors)

    def start_threads(self, real_time_factor: float = 0.0, tick_s: float = 0.01) -> None:
        """One lightweight receiver thread per device (§III-C, per device)."""
        for ps in self._sensors.values():
            ps.start_thread(real_time_factor=real_time_factor, tick_s=tick_s)

    def stop_threads(self, timeout_s: float = 5.0) -> dict[str, BaseException]:
        """Stop every receiver thread, joining each with a timeout.

        Returns ``{device: error}`` for every receiver that died mid-poll
        or refused to join — a dead poller previously vanished here while
        `window_power_w` kept serving its frozen ring forever.  The errors
        are also warned so unchecked callers still get a signal.
        """
        errors: dict[str, BaseException] = dict(self._poll_errors)
        for name, ps in self._sensors.items():
            try:
                err = ps.stop_thread(timeout_s=timeout_s)
            except TypeError:  # duck-typed sensor without the timeout param
                err = ps.stop_thread()
            if err is not None:
                errors[name] = err
        if errors:
            detail = "; ".join(f"{n}: {e!r}" for n, e in errors.items())
            warnings.warn(f"fleet receiver thread(s) failed — {detail}", RuntimeWarning)
        return errors

    # ------------------------------------------------------------ sim helpers
    def advance(self, dt_s: float) -> None:
        """Advance every (virtual) device's clock and drain it."""
        for ps in self._sensors.values():
            ps.device.advance(dt_s)
        self.poll_all()

    def run_for(self, seconds: float, chunk_s: float = 0.5) -> None:
        remaining = seconds
        while remaining > 1e-12:
            step = min(chunk_s, remaining)
            self.advance(step)
            remaining -= step

    # ------------------------------------------------------------ markers
    def mark_all(self, char: str = "M") -> None:
        for ps in self._sensors.values():
            ps.mark(char)

    def _marker_time(self, ps: "PowerSensor", char: str, occurrence: int = 0) -> float | None:
        hits = [t for c, t in ps.markers if c == char]
        if occurrence >= len(hits):
            return None
        return hits[occurrence]

    def marker_window(
        self,
        device: str,
        char_a: str,
        char_b: str | None = None,
        occurrence: int = 0,
        occurrence_b: int | None = None,
    ) -> tuple[float, float, FrameBlock] | None:
        """One device's ring frames between two marker occurrences.

        Returns ``(t0, t1, block)`` — the marker times plus a locked read
        of the frames between them — or None when either marker is
        missing, out of order, under-sampled, or no longer fully retained
        (an evicted head would silently undercount).  ``char_b`` defaults
        to ``char_a``, so one repeated char brackets an unbounded sequence
        of intervals — wave ``k`` is ``occurrence=k, occurrence_b=k+1`` —
        with no wrapping marker alphabet to collide.

        This is the raw-frames core under `interval()`; consumers that do
        their own integration (e.g. `repro.attrib.attribute_block`) start
        here instead of reaching into the ring and lock directly.
        """
        if char_b is None:
            char_b = char_a
        if occurrence_b is None:
            occurrence_b = occurrence
        ps = self._sensors[device]
        # one pass over the (copied) marker list serves both lookups
        hits_a = [t for c, t in ps.markers if c == char_a]
        hits_b = hits_a if char_b == char_a else [t for c, t in ps.markers if c == char_b]
        if occurrence >= len(hits_a) or occurrence_b >= len(hits_b):
            return None
        t0, t1 = hits_a[occurrence], hits_b[occurrence_b]
        if t1 <= t0:
            return None
        block = self._locked_ring_read(ps, lambda: ps.ring.window(t0, t1))
        if len(block) < 2:
            return None
        # evicted head: first retained frame starts well after t0.  The
        # frame interval is estimated as the *median* inter-frame dt — the
        # first two frames alone are unreliable exactly when it matters
        # (a delivery gap at the window's leading edge inflates their dt,
        # making this check too lenient and silently accepting a window
        # that is missing its leading coverage)
        frame_dt = float(np.median(np.diff(block.times_s)))
        if block.times_s[0] - t0 > 2.0 * frame_dt:
            return None
        return t0, t1, block

    def marker_windows(
        self,
        device: str,
        char: str,
        start_occurrence: int = 0,
    ) -> list[tuple[int, float, float, FrameBlock]]:
        """All retained step intervals of one repeated marker char.

        Returns ``(k, t0, t1, block)`` for every interval ``k`` (occurrence
        ``k`` → ``k+1`` of ``char``) from ``start_occurrence`` on that the
        ring still fully retains, with `marker_window`'s integrity rules
        applied per interval.  Unretainable intervals are *skipped, not a
        stop*: after a fault or head eviction swallows interval ``k``,
        later intervals may still be intact — the continuous-batching
        settle loop releases the missing ones at prediction and settles
        the rest from measurement.
        """
        ps = self._sensors[device]
        hits = [t for c, t in ps.markers if c == char]
        out: list[tuple[int, float, float, FrameBlock]] = []
        for k in range(max(int(start_occurrence), 0), len(hits) - 1):
            hit = self.marker_window(device, char, occurrence=k, occurrence_b=k + 1)
            if hit is None:
                continue
            t0, t1, block = hit
            out.append((k, t0, t1, block))
        return out

    def interval(
        self,
        char_a: str,
        char_b: str,
        occurrence: int = 0,
        occurrence_b: int | None = None,
    ) -> dict[str, IntervalStats]:
        """Per-device energy/power between markers `char_a` and `char_b`.

        ``occurrence`` indexes repeated markers; ``occurrence_b`` (default:
        same as ``occurrence``) indexes the closing marker independently —
        see `marker_window()`, which this integrates over per device.

        Devices missing either marker, or whose ring no longer retains the
        *whole* span (eviction would silently undercount), are omitted.
        """
        out: dict[str, IntervalStats] = {}
        for name in self._sensors:
            hit = self.marker_window(name, char_a, char_b, occurrence, occurrence_b)
            if hit is None:
                continue
            t0, t1, block = hit
            out[name] = IntervalStats(
                t0_s=t0,
                t1_s=t1,
                n_frames=len(block),
                energy_j=np.trapezoid(block.watts, block.times_s, axis=0),
                mean_w=block.watts.mean(axis=0),
            )
        return out

    # ------------------------------------------------------------ snapshots
    @staticmethod
    def _locked_ring_read(ps: "PowerSensor", fn):
        """Read from a sensor's ring under its receiver lock (thread mode)."""
        lock = getattr(ps, "_lock", None)
        if lock is None:
            return fn()
        with lock:
            return fn()

    @classmethod
    def _ring_tail_mean(cls, ps: "PowerSensor", window_s: float) -> float:
        """Trailing-window mean power, lock-free where the ring allows it.

        `FrameRing.tail_mean_watts` is seqlock-protected (see the module
        docstring's lock-free reader rules) so the hot path never takes
        the receiver lock; duck-typed rings without the version counter
        keep the locked read.
        """
        ring = ps.ring
        if isinstance(ring, FrameRing):
            return ring.tail_mean_watts(window_s)
        return cls._locked_ring_read(ps, lambda: ring.tail_mean_watts(window_s))

    def read_all(self) -> dict[str, "State"]:
        return {name: ps.read() for name, ps in self._sensors.items()}

    # ------------------------------------------------------------ health
    def _now_s(self) -> float:
        """The fleet's 'now': the newest clock any device can vouch for."""
        best = 0.0
        for ps in self._sensors.values():
            t = getattr(ps.device, "t_s", None)
            best = max(best, ps.ring.last_time_s if t is None else float(t))
        return best

    _STATE_NAMES = ("healthy", "stale", "lost")

    def _health_vectors(self, now: float) -> tuple[np.ndarray, np.ndarray]:
        """(codes, staleness) over the preallocated per-device mirrors.

        The `fleet_power` hot path: no dict, no dataclasses, no per-device
        ring attribute reads — the rings mirror (last_time_s, head) into
        shared slots on every append (`FrameRing.bind_stats`), and health
        classification is three vector ops.  Codes: 0 healthy / 1 stale /
        2 lost.  Also emits the health-transition obs events (diffed
        against the previous codes, so steady state emits nothing).
        """
        for i in self._unmirrored:  # duck rings without the stats mirror
            ring = self._vsensors[i].ring
            self._v_last_t[i] = ring.last_time_s if len(ring) else 0.0
            self._v_head[i] = len(ring)
        has_frames = self._v_head > 0
        # grace window: staleness runs from the newest frame or the attach
        # time, whichever is later — never from an empty ring's epoch
        eff_last = np.where(
            has_frames,
            np.maximum(self._v_last_t, self._v_attach),
            self._v_attach,
        )
        staleness = np.maximum(now - eff_last, 0.0)
        alive = self._v_alive
        alive[:] = True
        for i, ps in enumerate(self._vsensors):
            if not getattr(ps, "receiver_ok", True):
                alive[i] = False
        np.logical_and(alive, ~self._v_err, out=alive)
        codes = np.where(
            ~alive | (staleness > self.lost_after_s),
            np.int8(2),
            np.where(staleness > self.stale_after_s, np.int8(1), np.int8(0)),
        )
        changed = np.flatnonzero(codes != self._prev_code)
        for i in changed:
            name = self._vnames[i]
            state = self._STATE_NAMES[codes[i]]
            prev = self._last_health.get(name)
            self._last_health[name] = state
            self._prev_code[i] = codes[i]
            if prev is not None and prev != state:
                rec = obs_trace.active()
                if rec is not None:
                    rec.device_instant(
                        f"health:{prev}->{state}", now,
                        track=f"health:{name}", value=float(staleness[i]),
                    )
                reg = obs_metrics.active()
                if reg is not None:
                    reg.counter(
                        "fleet_health_transitions_total",
                        "device health state changes",
                        device=name, to=state,
                    ).inc()
        return codes, staleness

    def device_health(self, now_s: float | None = None) -> dict[str, DeviceHealth]:
        """Per-device health states (see the module docstring table)."""
        now = self._now_s() if now_s is None else float(now_s)
        codes, staleness = self._health_vectors(now)
        out: dict[str, DeviceHealth] = {}
        for i, name in enumerate(self._vnames):
            ps = self._vsensors[i]
            out[name] = DeviceHealth(
                name=name,
                state=self._STATE_NAMES[codes[i]],
                staleness_s=float(staleness[i]),
                last_frame_s=float(self._v_last_t[i]) if self._v_head[i] > 0 else 0.0,
                receiver_alive=bool(self._v_alive[i]),
                dropped_frames=int(getattr(ps, "dropped_frames", 0)),
            )
        return out

    def fleet_power(
        self,
        window_s: float | None = None,
        poll: bool = True,
        now_s: float | None = None,
    ) -> FleetPowerReading:
        """Quorum-based fleet power with explicit staleness semantics.

        Healthy devices contribute their trailing-window ring power; the
        sum is rescaled by the known fleet fraction so a partial quorum
        still estimates *fleet* watts.  Stale/lost devices are excluded —
        their rings only hold the past — instead of silently freezing the
        total.  With no healthy device at all the last good reading is
        held for ``holdover_s`` (``holdover=True``); any reading whose
        quorum is below ``min_quorum_frac`` is flagged ``stale``.
        """
        window_s = self.window_s if window_s is None else float(window_s)
        if poll:
            self.poll_all()
        now = self._now_s() if now_s is None else float(now_s)
        codes, _ = self._health_vectors(now)
        n_total = len(self._sensors)
        healthy_idx = np.flatnonzero(codes == 0)
        n_healthy = int(healthy_idx.size)
        quorum = n_healthy / n_total if n_total else 0.0
        if n_healthy:
            # lock-free seqlock reads: the governor's tick never contends
            # with the receiver lock (duck rings fall back to locked reads)
            raw = 0.0
            for i in healthy_idx:
                raw += self._ring_tail_mean(self._vsensors[i], window_s)
            power = raw * n_total / n_healthy
            stale = quorum < self.min_quorum_frac
            if not stale:
                self._last_good = (now, power)
            self._note_reading(now, power, quorum, stale, holdover=False)
            return FleetPowerReading(
                power_w=power,
                raw_power_w=raw,
                n_healthy=n_healthy,
                n_total=n_total,
                quorum_frac=quorum,
                stale=stale,
                holdover=False,
                time_s=now,
            )
        # nothing healthy: holdover semantics, always flagged stale
        if self._last_good is not None:
            t_good, p_good = self._last_good
            age = max(now - t_good, 0.0)
            self._note_reading(now, p_good, 0.0, True, holdover=age <= self.holdover_s)
            return FleetPowerReading(
                power_w=p_good,
                raw_power_w=0.0,
                n_healthy=0,
                n_total=n_total,
                quorum_frac=0.0,
                stale=True,
                holdover=age <= self.holdover_s,
                time_s=now,
                data_age_s=age,
            )
        self._note_reading(now, 0.0, 0.0, True, holdover=False)
        return FleetPowerReading(
            power_w=0.0,
            raw_power_w=0.0,
            n_healthy=0,
            n_total=n_total,
            quorum_frac=0.0,
            stale=True,
            holdover=False,
            time_s=now,
            data_age_s=math.inf,
        )

    def _note_reading(
        self, now: float, power_w: float, quorum: float, stale: bool, holdover: bool
    ) -> None:
        """Obs hooks for one `fleet_power` reading (no-ops when disabled).

        Stale entry/exit are *edge* events on the trace timeline (a 1 kHz
        control loop would otherwise flood the ring); counters accumulate
        per reading so scrape-side rates stay meaningful.
        """
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter("fleet_power_reads_total", "fleet_power readings").inc()
            if stale:
                reg.counter(
                    "fleet_stale_reads_total",
                    "fleet_power readings flagged stale (quorum below floor)",
                ).inc()
            if holdover:
                reg.counter(
                    "fleet_holdover_reads_total",
                    "stale readings served from the held last-good value",
                ).inc()
            reg.gauge("fleet_power_w", "latest fleet power estimate").set(power_w)
            reg.gauge("fleet_quorum_frac", "latest healthy-device fraction").set(quorum)
        if stale != self._stale_streak:
            self._stale_streak = stale
            rec = obs_trace.active()
            if rec is not None:
                rec.device_instant(
                    "fleet:stale-enter" if stale else "fleet:stale-exit",
                    now, track="fleet", value=quorum,
                )

    def window_power_w(self, window_s: float | None = None, poll: bool = True) -> float:
        """Fleet-summed trailing-window mean power — the governor's fast hook.

        Unlike `snapshot()` this never materialises `FrameBlock` copies:
        each device answers from its ring's maintained per-frame totals
        (`FrameRing.tail_mean_watts`), so a control loop can poll it every
        millisecond without competing with the 20 kHz receive path.

        Quorum-based since the fault-injection lab landed: stale and lost
        devices are excluded and the healthy sum is rescaled by the known
        fleet fraction — callers that need the staleness/holdover flags
        use `fleet_power` (this is its ``power_w`` field).
        """
        return self.fleet_power(window_s, poll=poll).power_w

    def device_window_power_w(
        self, window_s: float | None = None, poll: bool = True
    ) -> dict[str, float]:
        """Per-device trailing-window mean power (same fast path)."""
        window_s = self.window_s if window_s is None else float(window_s)
        out: dict[str, float] = {}
        if poll and self._pool is not None:
            self.poll_all()
        for name, ps in self._sensors.items():
            if poll and self._pool is None:
                self._safe_poll(name, ps)
            out[name] = self._ring_tail_mean(ps, window_s)
        return out

    def snapshot(self, window_s: float | None = None) -> FleetSnapshot:
        """One queryable view of the whole fleet: per-device + aggregate."""
        window_s = self.window_s if window_s is None else float(window_s)
        devices: dict[str, DeviceSnapshot] = {}
        for name, ps in self._sensors.items():
            state = ps.read()  # drains the device, then snapshots
            block = self._locked_ring_read(ps, lambda: ps.ring.tail_window(window_s))
            stats = window_stats(block, pct=self.pct)
            devices[name] = DeviceSnapshot(name=name, state=state, window=stats)
        snaps = devices.values()
        agg = FleetAggregate(
            n_devices=len(devices),
            n_frames=sum(d.window.n_frames for d in snaps),
            mean_w=sum(d.window.total_mean_w for d in snaps),
            peak_w=sum(d.window.total_peak_w for d in snaps),
            ewma_w=sum(d.window.total_ewma_w for d in snaps),
            energy_j=sum(d.window.total_energy_j for d in snaps),
        )
        t = max((d.state.time_s for d in snaps), default=0.0)
        return FleetSnapshot(time_s=t, devices=devices, aggregate=agg)

    def close(self) -> None:
        self.stop_threads()
        for ps in self._sensors.values():
            ps.close()


def make_virtual_fleet(
    loads: Iterable,
    module: str = "pcie8pin-20a",
    seed: int = 0,
    window_s: float = 1.0,
    ring_capacity: int = 1 << 16,
    **monitor_kwargs,
) -> FleetMonitor:
    """Build a FleetMonitor over virtual devices, one per load.

    Extra keyword arguments (``stale_after_s``, ``min_quorum_frac``, ...)
    are forwarded to the `FleetMonitor`.
    """
    from repro.core import PowerSensor, make_device

    fleet = FleetMonitor(window_s=window_s, **monitor_kwargs)
    for i, load in enumerate(loads):
        dev = make_device([module], load, seed=seed * 1009 + i)
        fleet.add(f"dev{i}", PowerSensor(dev, ring_capacity=ring_capacity))
    return fleet
