"""`repro.stream` — streaming telemetry over decoded PowerSensor3 frames.

Scales the host side from "one sensor, one script" to a fleet of devices
feeding live consumers:

* `FrameRing` / `FrameBlock` — preallocated numpy ring buffer of decoded
  frames (time/V/A/W per pair); the receiver's output, every consumer's
  input (no dump-file text round-trips);
* `window_stats` / `windowed_mean_at` / `sliding_mean` — cumulative-sum
  vectorised windowed aggregation (mean/peak/percentile/EWMA/energy);
* `FleetMonitor` — owns N `PowerSensor`s, polls them round-robin or via
  per-device threads, and serves per-device + aggregate snapshots and
  marker-aligned interval queries.
"""
from .aggregate import (
    WindowStats,
    cumulative_energy,
    sliding_mean,
    window_stats,
    windowed_mean_at,
)
from .fleet import (
    DeviceSnapshot,
    FleetAggregate,
    FleetMonitor,
    FleetSnapshot,
    IntervalStats,
    make_virtual_fleet,
)
from .ring import FrameBlock, FrameRing

__all__ = [
    "WindowStats",
    "cumulative_energy",
    "sliding_mean",
    "window_stats",
    "windowed_mean_at",
    "DeviceSnapshot",
    "FleetAggregate",
    "FleetMonitor",
    "FleetSnapshot",
    "IntervalStats",
    "make_virtual_fleet",
    "FrameBlock",
    "FrameRing",
]
