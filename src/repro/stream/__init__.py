"""`repro.stream` — streaming telemetry over decoded PowerSensor3 frames.

Scales the host side from "one sensor, one script" to a fleet of devices
feeding live consumers:

* `FrameRing` / `FrameBlock` — preallocated numpy ring buffer of decoded
  frames (time/V/A/W per pair); the receiver's output, every consumer's
  input (no dump-file text round-trips);
* `window_stats` / `windowed_mean_at` / `sliding_mean` — cumulative-sum
  vectorised windowed aggregation (mean/peak/percentile/EWMA/energy);
* `FleetMonitor` — owns N `PowerSensor`s, polls them round-robin or via
  per-device threads, and serves per-device + aggregate snapshots and
  marker-aligned interval queries.  Degradation-aware: per-device health
  states (healthy / stale / lost), quorum-rescaled `fleet_power` with
  holdover semantics and an explicit staleness flag — see the
  degraded-telemetry table in `repro.stream.fleet`'s docstring and the
  fault-injection lab in `repro.faultlab` that exercises it;
* `PooledDecoder` — the fleet-scale receive path: accumulates raw bytes
  from N links and decodes every frame-regular device in one fused numpy
  pass (stacked per-device conversion tables), publishing to the rings
  via their seqlock so hot readers stay lock-free.  Bit-identical to
  per-device polling; enable with `FleetMonitor.enable_pool()`.
"""
from .aggregate import (
    WindowStats,
    cumulative_energy,
    sliding_mean,
    window_stats,
    windowed_mean_at,
)
from .fleet import (
    DeviceHealth,
    DeviceSnapshot,
    FleetAggregate,
    FleetMonitor,
    FleetPowerReading,
    FleetSnapshot,
    IntervalStats,
    make_virtual_fleet,
)
from .pool import PooledDecoder, PoolResult
from .ring import FrameBlock, FrameRing

__all__ = [
    "WindowStats",
    "cumulative_energy",
    "sliding_mean",
    "window_stats",
    "windowed_mean_at",
    "DeviceHealth",
    "DeviceSnapshot",
    "FleetAggregate",
    "FleetMonitor",
    "FleetPowerReading",
    "FleetSnapshot",
    "IntervalStats",
    "make_virtual_fleet",
    "FrameBlock",
    "FrameRing",
    "PooledDecoder",
    "PoolResult",
]
