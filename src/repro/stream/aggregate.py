"""Windowed aggregation over decoded frame blocks — all cumulative-sum
vectorised, no per-frame Python loops.

Two layers:

* one-shot stats over a block (`window_stats`): per-pair mean / peak /
  percentile watts, EWMA, trapezoidal energy;
* sliding-window series (`windowed_mean_at`, `sliding_mean`): prefix-sum +
  binary-search evaluation of trailing-window averages at arbitrary query
  times, O(n log n) total instead of O(n · window) — this is also what the
  legacy NVML-style meter model in `repro.power.pmt` uses.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .ring import FrameBlock


@dataclass(frozen=True)
class WindowStats:
    """Aggregate statistics of one time window of frames (per pair + total)."""

    t0_s: float
    t1_s: float
    n_frames: int
    mean_w: np.ndarray  # (n_pairs,)
    peak_w: np.ndarray  # (n_pairs,) per-pair max
    pct_w: np.ndarray  # (n_pairs,) percentile of per-frame watts
    ewma_w: np.ndarray  # (n_pairs,) exponentially weighted toward t1
    energy_j: np.ndarray  # (n_pairs,) trapezoidal integral
    pct: float = 95.0

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s

    @property
    def total_mean_w(self) -> float:
        return float(self.mean_w.sum())

    @property
    def total_peak_w(self) -> float:
        return float(self.peak_w.sum())

    @property
    def total_energy_j(self) -> float:
        return float(self.energy_j.sum())

    @property
    def total_ewma_w(self) -> float:
        return float(self.ewma_w.sum())


def _empty_stats(n_pairs: int, pct: float) -> WindowStats:
    z = np.zeros(n_pairs)
    return WindowStats(0.0, 0.0, 0, z, z.copy(), z.copy(), z.copy(), z.copy(), pct)


def window_stats(
    block: FrameBlock, pct: float = 95.0, ewma_tau_s: float = 0.05
) -> WindowStats:
    """Vectorised aggregate stats over a frame block."""
    n = len(block)
    if n == 0:
        return _empty_stats(block.watts.shape[1] if block.watts.ndim == 2 else 0, pct)
    w = block.watts
    t = block.times_s
    if n > 1:
        energy = np.trapezoid(w, t, axis=0)
    else:
        energy = np.zeros(w.shape[1])
    # EWMA snapshot: weights decay exponentially away from the window end
    decay = np.exp((t - t[-1]) / max(ewma_tau_s, 1e-12))
    ewma = (w * decay[:, None]).sum(axis=0) / decay.sum()
    return WindowStats(
        t0_s=float(t[0]),
        t1_s=float(t[-1]),
        n_frames=n,
        mean_w=w.mean(axis=0),
        peak_w=w.max(axis=0),
        pct_w=np.percentile(w, pct, axis=0),
        ewma_w=ewma,
        energy_j=energy,
        pct=pct,
    )


def cumulative_energy(times_s: np.ndarray, watts: np.ndarray) -> np.ndarray:
    """Running trapezoidal integral, same shape as `watts` (first row 0)."""
    watts = np.asarray(watts, dtype=np.float64)
    one_d = watts.ndim == 1
    w = watts[:, None] if one_d else watts
    t = np.asarray(times_s, dtype=np.float64)
    out = np.zeros_like(w)
    if t.size > 1:
        seg = 0.5 * (w[1:] + w[:-1]) * np.diff(t)[:, None]
        np.cumsum(seg, axis=0, out=out[1:])
    return out[:, 0] if one_d else out


def windowed_mean_at(
    grid_times: np.ndarray,
    grid_values: np.ndarray,
    query_times: np.ndarray,
    window_s: float,
) -> np.ndarray:
    """Trailing-window mean of a regular series, evaluated at query times.

    For each query time ``t`` this returns the mean of ``grid_values`` over
    samples with ``max(grid[0], t - window) <= grid <= t`` — exactly the
    legacy per-query Python loop, but via one prefix sum and two
    searchsorted calls.  Empty windows fall back to the first grid value.
    """
    grid_times = np.asarray(grid_times, dtype=np.float64)
    grid_values = np.asarray(grid_values, dtype=np.float64)
    query_times = np.asarray(query_times, dtype=np.float64)
    if grid_times.size == 0:
        return np.zeros_like(query_times)
    prefix = np.concatenate([[0.0], np.cumsum(grid_values, dtype=np.float64)])
    lo_t = np.maximum(query_times - window_s, grid_times[0])
    lo = np.searchsorted(grid_times, lo_t, side="left")
    hi = np.searchsorted(grid_times, query_times, side="right")
    count = hi - lo
    sums = prefix[hi] - prefix[lo]
    return np.where(count > 0, sums / np.maximum(count, 1), grid_values[0])


def sliding_mean(
    times_s: np.ndarray,
    values: np.ndarray,
    window_s: float,
    stride_s: float,
) -> tuple[np.ndarray, np.ndarray]:
    """Downsampled trailing-window mean series over an irregular series.

    Returns ``(sample_times, means)`` with sample times every ``stride_s``
    across the span of ``times_s``.
    """
    times_s = np.asarray(times_s, dtype=np.float64)
    if times_s.size == 0:
        return np.zeros(0), np.zeros(0)
    qs = np.arange(times_s[0], times_s[-1] + stride_s * 0.5, stride_s)
    return qs, windowed_mean_at(times_s, values, qs, window_s)
