"""Vectorised fixed-point text formatting for the continuous-mode dump.

CPython's ``%``-float formatting costs ~2 µs/row, which made the dump the
receiver's bottleneck even after batching it into a single C-level format
call.  This module renders the dump schema

    <t:%.6f> <pair:%d> <V:%.4f> <A:%.4f> <W:%.4f>\\n

entirely with integer digit arithmetic on a byte matrix: every row gets a
fixed cell layout, pad cells (unused leading-digit positions, absent minus
signs) are masked out, and the compacted bytes decode to the same text the
printf path produces — except for values whose scaled product lands within
1 ULP of a decimal rounding boundary (e.g. ``5118.10005``), where the last
digit may differ by one: printf rounds the exact double, the fast path
rounds the float64 product.  Harmless for dump data (4th-decimal noise),
but don't rely on byte equality at constructed ties.

Values outside the supported fixed-point range (|V|,|A| < 10^4, |W| < 10^6,
0 <= t < 10^6, non-finite anything) fall back to the printf path for the
whole block — correctness never depends on the fast path.
"""
from __future__ import annotations

import numpy as np

_PRINTF_FMT = "%.6f %d %.4f %.4f %.4f\n"


def _printf_block(rows: np.ndarray) -> str:
    """One C-level %-format for the whole block (the fallback path)."""
    return (_PRINTF_FMT * rows.shape[0]) % tuple(rows.ravel().tolist())


def _int_digits(out, keep, col, ip, width):
    """Write ``ip`` right-aligned at cells [col, col+width); mask pad cells.

    ``out``/``keep`` are (width_total, n) — cell-major, so each cell write
    is one contiguous row.
    """
    pow10 = 1
    for j in range(width):
        c = col + width - 1 - j
        np.add(48, (ip // pow10) % 10, out=out[c], casting="unsafe")
        if j:
            keep[c] = ip >= pow10
        pow10 *= 10


def _frac_digits(out, col, frac, width):
    """Write ``frac`` zero-padded at cells [col, col+width)."""
    pow10 = 10 ** (width - 1)
    for j in range(width):
        np.add(48, (frac // pow10) % 10, out=out[col + j], casting="unsafe")
        pow10 //= 10


def _signed_fixed(out, keep, col, values, int_width, dec):
    """Render ``values`` as [-]int.frac at [col, col+1+int_width+1+dec)."""
    scale = 10**dec
    scaled = np.round(np.abs(values) * scale).astype(np.int64)
    keep[col] = np.signbit(values)  # printf keeps the sign of -0.0001...
    out[col] = ord("-")
    _int_digits(out, keep, col + 1, scaled // scale, int_width)
    out[col + 1 + int_width] = ord(".")
    _frac_digits(out, col + 2 + int_width, scaled % scale, dec)
    return col + 2 + int_width + dec


def format_dump_block(
    times_s: np.ndarray,
    pairs: np.ndarray,
    volts: np.ndarray,
    amps: np.ndarray,
    watts: np.ndarray,
) -> str:
    """Format n dump rows; byte-compatible with the printf schema."""
    n = len(times_s)
    if n == 0:
        return ""
    in_range = (
        np.all(np.isfinite(times_s))
        and np.all(np.isfinite(volts))
        and np.all(np.isfinite(amps))
        and np.all(np.isfinite(watts))
        and times_s.min(initial=0.0) >= 0.0
        and times_s.max(initial=0.0) < 1e6 - 5e-7
        and np.abs(volts).max(initial=0.0) < 1e4 - 5e-5
        and np.abs(amps).max(initial=0.0) < 1e4 - 5e-5
        and np.abs(watts).max(initial=0.0) < 1e6 - 5e-5
        and pairs.min(initial=0) >= 0
        and pairs.max(initial=0) <= 9
    )
    if not in_range:
        return _printf_block(
            np.column_stack([times_s, pairs.astype(np.float64), volts, amps, watts])
        )

    # cell layout: t[6+1+6] sp pair sp v[1+4+1+4] sp a[1+4+1+4] sp w[1+6+1+4] nl
    width = 13 + 1 + 1 + 1 + 10 + 1 + 10 + 1 + 12 + 1
    # cell-major (width, n): each cell fills one contiguous row, transposed
    # to row-major only for the final compaction
    out = np.full((width, n), ord(" "), dtype=np.uint8)
    keep = np.ones((width, n), dtype=bool)

    t_scaled = np.round(times_s * 1e6).astype(np.int64)
    _int_digits(out, keep, 0, t_scaled // 10**6, 6)
    out[6] = ord(".")
    _frac_digits(out, 7, t_scaled % 10**6, 6)
    np.add(48, pairs, out=out[14], casting="unsafe")
    col = _signed_fixed(out, keep, 16, volts, 4, 4)
    col = _signed_fixed(out, keep, col + 1, amps, 4, 4)
    col = _signed_fixed(out, keep, col + 1, watts, 6, 4)
    out[col] = ord("\n")
    flat = np.ascontiguousarray(out.T).ravel()
    return flat[np.ascontiguousarray(keep.T).ravel()].tobytes().decode("ascii")
