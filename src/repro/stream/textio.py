"""Vectorised fixed-point text formatting for the continuous-mode dump.

CPython's ``%``-float formatting costs ~2 µs/row, which made the dump the
receiver's bottleneck even after batching it into a single C-level format
call.  This module renders the dump schema

    <t:%.6f> <pair:%d> <V:%.4f> <A:%.4f> <W:%.4f>\\n

entirely with integer digit arithmetic on a byte matrix: every row gets a
fixed cell layout, pad cells (unused leading-digit positions, absent minus
signs) are masked out, and the compacted bytes decode to the same text the
printf path produces.  Values whose scaled product lands near a decimal
rounding boundary (e.g. ``5118.10005``) are re-rounded in extended
precision (`_round_scaled`): printf rounds the *exact* double, and the
float64 product alone can land a constructed tie one last-digit off — a
drift the archive/dump parity test pins.  A double can never be an exact
decimal tie (the boundary has a factor 5⁴ in its denominator), so 80-bit
extended precision always decides the same way printf does.

Values outside the supported fixed-point range (|V|,|A| < 10^4, |W| < 10^6,
0 <= t < 10^6, non-finite anything) fall back to the printf path for the
whole block — correctness never depends on the fast path.
"""
from __future__ import annotations

import numpy as np

_PRINTF_FMT = "%.6f %d %.4f %.4f %.4f\n"


def _printf_block(rows: np.ndarray) -> str:
    """One C-level %-format for the whole block (the fallback path)."""
    return (_PRINTF_FMT * rows.shape[0]) % tuple(rows.ravel().tolist())


def _round_scaled(values: np.ndarray, scale: int) -> np.ndarray:
    """``round(values · scale)`` with printf's exact-double rounding.

    The float64 product carries ~1 ULP of error, enough to flip the last
    digit when the exact value sits within that of a decimal boundary.
    Entries near a boundary are re-rounded exactly (`Decimal` represents
    the double with no error; exact decimal ties are impossible for
    binary doubles), so the result always matches the correctly-rounded
    printf output — on every platform, including those where
    ``np.longdouble`` is just float64.  The exact path only ever sees
    the handful of near-tie entries, never the bulk of the block.
    """
    prod = values * float(scale)
    scaled = np.round(prod)
    frac = prod - np.floor(prod)
    near = np.abs(frac - 0.5) < 1e-6
    if np.any(near):
        from decimal import ROUND_HALF_EVEN, Decimal

        exp = Decimal(1)
        scaled[near] = [
            float(
                (Decimal(x) * scale).quantize(exp, rounding=ROUND_HALF_EVEN)
            )
            for x in values[near].tolist()
        ]
    return scaled.astype(np.int64)


def _int_digits(out, keep, col, ip, width):
    """Write ``ip`` right-aligned at cells [col, col+width); mask pad cells.

    ``out``/``keep`` are (width_total, n) — cell-major, so each cell write
    is one contiguous row.
    """
    pow10 = 1
    for j in range(width):
        c = col + width - 1 - j
        np.add(48, (ip // pow10) % 10, out=out[c], casting="unsafe")
        if j:
            keep[c] = ip >= pow10
        pow10 *= 10


def _frac_digits(out, col, frac, width):
    """Write ``frac`` zero-padded at cells [col, col+width)."""
    pow10 = 10 ** (width - 1)
    for j in range(width):
        np.add(48, (frac // pow10) % 10, out=out[col + j], casting="unsafe")
        pow10 //= 10


def _signed_fixed(out, keep, col, values, int_width, dec):
    """Render ``values`` as [-]int.frac at [col, col+1+int_width+1+dec)."""
    scale = 10**dec
    scaled = _round_scaled(np.abs(values), scale)
    keep[col] = np.signbit(values)  # printf keeps the sign of -0.0001...
    out[col] = ord("-")
    _int_digits(out, keep, col + 1, scaled // scale, int_width)
    out[col + 1 + int_width] = ord(".")
    _frac_digits(out, col + 2 + int_width, scaled % scale, dec)
    return col + 2 + int_width + dec


def format_dump_block(
    times_s: np.ndarray,
    pairs: np.ndarray,
    volts: np.ndarray,
    amps: np.ndarray,
    watts: np.ndarray,
) -> str:
    """Format n dump rows; byte-compatible with the printf schema."""
    n = len(times_s)
    if n == 0:
        return ""
    in_range = (
        np.all(np.isfinite(times_s))
        and np.all(np.isfinite(volts))
        and np.all(np.isfinite(amps))
        and np.all(np.isfinite(watts))
        and times_s.min(initial=0.0) >= 0.0
        and times_s.max(initial=0.0) < 1e6 - 5e-7
        and np.abs(volts).max(initial=0.0) < 1e4 - 5e-5
        and np.abs(amps).max(initial=0.0) < 1e4 - 5e-5
        and np.abs(watts).max(initial=0.0) < 1e6 - 5e-5
        and pairs.min(initial=0) >= 0
        and pairs.max(initial=0) <= 9
    )
    if not in_range:
        return _printf_block(
            np.column_stack([times_s, pairs.astype(np.float64), volts, amps, watts])
        )

    # cell layout: t[6+1+6] sp pair sp v[1+4+1+4] sp a[1+4+1+4] sp w[1+6+1+4] nl
    width = 13 + 1 + 1 + 1 + 10 + 1 + 10 + 1 + 12 + 1
    # cell-major (width, n): each cell fills one contiguous row, transposed
    # to row-major only for the final compaction
    out = np.full((width, n), ord(" "), dtype=np.uint8)
    keep = np.ones((width, n), dtype=bool)

    t_scaled = _round_scaled(times_s, 10**6)
    _int_digits(out, keep, 0, t_scaled // 10**6, 6)
    out[6] = ord(".")
    _frac_digits(out, 7, t_scaled % 10**6, 6)
    np.add(48, pairs, out=out[14], casting="unsafe")
    col = _signed_fixed(out, keep, 16, volts, 4, 4)
    col = _signed_fixed(out, keep, col + 1, amps, 4, 4)
    col = _signed_fixed(out, keep, col + 1, watts, 6, 4)
    out[col] = ord("\n")
    flat = np.ascontiguousarray(out.T).ravel()
    return flat[np.ascontiguousarray(keep.T).ravel()].tobytes().decode("ascii")


def parse_dump(
    text: str,
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, np.ndarray, list]:
    """Parse continuous-mode dump text back into arrays + marker events.

    The inverse of the dump schema: returns ``(times_s, pairs, volts,
    amps, watts, markers)`` where ``markers`` is the ``[(char, t_s), ...]``
    list the ``M <char> <t>`` lines encode.  Used by the dump/archive
    parity tests — a text dump parsed back must match the binary trace
    archive of the same session to within the dump's fixed-point
    quantisation (half of the last printed digit).
    """
    rows: list[list[float]] = []
    markers: list[tuple[str, float]] = []
    for line in text.splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("M "):
            _, char, t = line.split()
            markers.append((char, float(t)))
            continue
        parts = line.split()
        if len(parts) != 5:
            raise ValueError(f"malformed dump row: {line!r}")
        rows.append([float(x) for x in parts])
    arr = np.asarray(rows, dtype=np.float64).reshape(-1, 5)
    return (
        arr[:, 0],
        arr[:, 1].astype(np.int64),
        arr[:, 2],
        arr[:, 3],
        arr[:, 4],
        markers,
    )
