"""Preallocated ring buffer of decoded 20 kHz frames.

This replaces the "dump file as API" pattern: the receiver appends decoded
(time, V, A, W)-per-pair frame blocks with two slice assignments (no
per-frame Python work), and consumers — snapshots, windowed aggregation,
the PMT meter backend, the fleet monitor — query it without ever
round-tripping through text.

Frames are addressed two ways:

* by **sequence number**: ``head`` is the total number of frames ever
  appended; frame ``seq`` is retained while ``head - len(ring) <= seq``;
* by **device time**: ``window(t0, t1)`` binary-searches the (sorted)
  retained timestamps.

All reads return chronologically-ordered copies, so callers can hold the
result while the receiver keeps appending.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class FrameBlock:
    """A chronologically ordered block of decoded frames (copies)."""

    seq0: int  # sequence number of the first frame in the block
    times_s: np.ndarray  # (n,)
    volts: np.ndarray  # (n, n_pairs)
    amps: np.ndarray  # (n, n_pairs)
    watts: np.ndarray  # (n, n_pairs)

    def __len__(self) -> int:
        return int(self.times_s.size)

    @property
    def total_watts(self) -> np.ndarray:
        """(n,) summed over pairs."""
        return self.watts.sum(axis=1)


class FrameRing:
    """Fixed-capacity ring of decoded frames, vectorised append and query."""

    def __init__(self, capacity: int, n_pairs: int):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = int(capacity)
        self.n_pairs = int(n_pairs)
        self.times_s = np.zeros(self.capacity)
        self.volts = np.zeros((self.capacity, self.n_pairs))
        self.amps = np.zeros((self.capacity, self.n_pairs))
        self.watts = np.zeros((self.capacity, self.n_pairs))
        # per-frame summed-pair watts, maintained on append so trailing-window
        # power queries (the governor's 1 kHz poll) never copy frame blocks
        self.wtot = np.zeros(self.capacity)
        self.head = 0  # total frames ever appended (monotonic)
        # seqlock publication counter: odd while an append is mutating the
        # ring, bumped even once the new head is visible.  Lock-free readers
        # (`tail_mean_watts`) snapshot it before and after a read and retry
        # on any change, so they never observe a half-written block — there
        # is exactly one writer (the receiver, under its own lock), and the
        # GIL makes each individual counter/slice store atomic
        self.version = 0
        # optional fleet stats slot: (last_times, heads, idx) shared arrays
        # updated after every append so FleetMonitor health scans read
        # preallocated vectors instead of N ring attributes (see bind_stats)
        self._stats: tuple[np.ndarray, np.ndarray, int] | None = None

    def __len__(self) -> int:
        return min(self.head, self.capacity)

    @property
    def last_time_s(self) -> float:
        if self.head == 0:
            return 0.0
        return float(self.times_s[(self.head - 1) % self.capacity])

    def bind_stats(
        self, last_times: np.ndarray, heads: np.ndarray, idx: int
    ) -> None:
        """Mirror (last_time_s, head) into shared fleet arrays on append.

        `FleetMonitor` preallocates one slot per device; the ring writes
        two scalars per append and the fleet health scan becomes pure
        vector arithmetic instead of N attribute reads under N locks.
        """
        self._stats = (last_times, heads, int(idx))
        last_times[idx] = self.last_time_s
        heads[idx] = self.head

    # ------------------------------------------------------------------ write
    def append(
        self,
        times_s: np.ndarray,
        volts: np.ndarray,
        amps: np.ndarray,
        watts: np.ndarray,
        wtot: np.ndarray | None = None,
    ) -> None:
        """Append a block of n frames (two slice writes, O(n) C-side).

        ``wtot`` optionally carries precomputed per-frame summed-pair watts
        (the pooled decoder reduces the whole fleet batch in one pass);
        when omitted it is computed here, with identical float semantics.
        """
        n = len(times_s)
        if n == 0:
            return
        cap = self.capacity
        if n > cap:  # only the trailing `cap` frames survive anyway
            drop = n - cap
            self.head += drop  # account for the frames that never land
            times_s, volts, amps, watts = (
                times_s[drop:], volts[drop:], amps[drop:], watts[drop:],
            )
            wtot = None if wtot is None else wtot[drop:]
            n = cap
        if wtot is None:
            wtot = watts.sum(axis=1)
        start = self.head % cap
        end = start + n
        self.version += 1  # odd: publish in progress
        try:
            if end <= cap:
                sl = slice(start, end)
                self.times_s[sl] = times_s
                self.volts[sl] = volts
                self.amps[sl] = amps
                self.watts[sl] = watts
                self.wtot[sl] = wtot
            else:
                k = cap - start
                self.times_s[start:] = times_s[:k]
                self.volts[start:] = volts[:k]
                self.amps[start:] = amps[:k]
                self.watts[start:] = watts[:k]
                self.wtot[start:] = wtot[:k]
                self.times_s[: end - cap] = times_s[k:]
                self.volts[: end - cap] = volts[k:]
                self.amps[: end - cap] = amps[k:]
                self.watts[: end - cap] = watts[k:]
                self.wtot[: end - cap] = wtot[k:]
            self.head += n
        finally:
            self.version += 1  # even: new head visible
        if self._stats is not None:
            last_times, heads, idx = self._stats
            last_times[idx] = float(times_s[-1])
            heads[idx] = self.head

    # ------------------------------------------------------------------ read
    def _block(self, lo: int, hi: int) -> FrameBlock:
        """Frames with sequence numbers [lo, hi), both already retained."""
        cap = self.capacity

        def gather(arr):
            i0, i1 = lo % cap, hi % cap
            if lo == hi:
                return arr[:0].copy()
            if i0 < i1:
                return arr[i0:i1].copy()
            return np.concatenate([arr[i0:], arr[:i1]])

        return FrameBlock(
            seq0=lo,
            times_s=gather(self.times_s),
            volts=gather(self.volts),
            amps=gather(self.amps),
            watts=gather(self.watts),
        )

    def latest(self, n: int | None = None) -> FrameBlock:
        """The most recent ``n`` frames (all retained frames if None)."""
        avail = len(self)
        n = avail if n is None else min(int(n), avail)
        return self._block(self.head - n, self.head)

    def since(self, seq: int) -> FrameBlock:
        """Frames with sequence number >= seq (clamped to what's retained)."""
        lo = max(int(seq), self.head - len(self))
        return self._block(min(lo, self.head), self.head)

    def _search_time(self, t_s: float) -> int:
        """Logical offset (0..len) of the first retained frame with time >= t.

        Binary search over the (up to) two contiguous physical segments —
        no copy of the retained span is made.
        """
        cap = self.capacity
        n = len(self)
        start = (self.head - n) % cap
        len_a = min(n, cap - start)
        i = int(np.searchsorted(self.times_s[start : start + len_a], t_s))
        if i < len_a or len_a == n:
            return i
        return len_a + int(np.searchsorted(self.times_s[: n - len_a], t_s))

    def window(self, t0_s: float, t1_s: float) -> FrameBlock:
        """Frames with t0 <= time < t1 (within the retained span)."""
        base = self.head - len(self)
        lo = base + self._search_time(t0_s)
        hi = base + self._search_time(t1_s)
        return self._block(lo, max(lo, hi))

    def tail_mean_watts(self, window_s: float) -> float:
        """Mean summed-pair power over the trailing ``window_s`` seconds.

        The incremental hook the closed-loop governor polls every control
        tick: slice reductions over the maintained per-frame totals — no
        FrameBlock copy, no per-frame Python work.  An empty ring reads 0;
        a window narrower than one frame reads the newest frame.

        **Lock-free**: readers do not take the receiver lock.  The ring's
        seqlock ``version`` is snapshotted before and after the reduction;
        a concurrent append changes it (or leaves it odd), and the read is
        retried.  A returned value is therefore always computed from a
        consistent ring state — never a torn frame.

        **Time-weighted under dropout**: a gap-free window (every
        inter-frame dt within 2x the window median) reduces as the plain
        frame-count mean — bit-identical to the historical semantics the
        golden corpus pins.  When a delivery gap sits inside the window,
        frames are weighted by the time they cover (zero-order hold: the
        frame before the gap vouches for it, the newest frame covers one
        nominal interval), so the mean no longer skews toward whichever
        side of the gap delivered more frames.
        """
        while True:
            v0 = self.version
            if not (v0 & 1):
                out = self._tail_mean_unlocked(window_s)
                if self.version == v0:
                    return out
            time.sleep(0)  # writer mid-publish: yield and retry

    def _tail_mean_unlocked(self, window_s: float) -> float:
        n = len(self)
        if n == 0:
            return 0.0
        cap = self.capacity
        lo = (self.head - n) + self._search_time(self.last_time_s - window_s)
        m = self.head - lo
        if m <= 0:
            return float(self.wtot[(self.head - 1) % cap])
        i0, i1 = lo % cap, self.head % cap
        if i0 < i1:
            w = self.wtot[i0:i1]
            total = float(w.sum())
            dts = np.diff(self.times_s[i0:i1])
        else:
            total = float(self.wtot[i0:].sum() + self.wtot[:i1].sum())
            w = None  # materialised only on the (rare) gap path
            t = np.concatenate([self.times_s[i0:], self.times_s[:i1]])
            dts = np.diff(t)
        if m == 1 or dts.size == 0:
            return total / m
        med = float(np.median(dts))
        if med <= 0.0 or float(dts.max()) <= 2.0 * med:
            return total / m  # gap-free: exact historical count mean
        if w is None:
            w = np.concatenate([self.wtot[i0:], self.wtot[:i1]])
        num = float((w[:-1] * dts).sum()) + float(w[-1]) * med
        den = float(dts.sum()) + med
        if den <= 0.0:  # only reachable from a torn read; retried anyway
            return total / m
        return num / den

    def tail_window(self, window_s: float) -> FrameBlock:
        """The trailing ``window_s`` seconds of frames."""
        n = len(self)
        if n == 0:
            return self._block(self.head, self.head)
        lo = (self.head - n) + self._search_time(self.last_time_s - window_s)
        return self._block(lo, self.head)
