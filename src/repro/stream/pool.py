"""PooledDecoder: one fused decode pass over a fleet of receiver links.

The per-device receiver (`repro.core.host.PowerSensor`) spends most of a
poll on fixed numpy-call overhead — `decode_packets`, the frame-regularity
check, 10-bit timestamp reconstruction, and the affine conversion are each
a dozen small array ops whose cost barely depends on the batch size.  At
fleet scale (64+ links ticked at 1 kHz, ~20 frames per link per tick) that
overhead is the head node's bottleneck, not the arithmetic.

The pooled decoder amortises it across the whole fleet:

* **phase A** (per device, under its receiver lock): take the link's byte
  batch — residual + everything the transport has queued (`SocketDevice`'s
  ``\\0live`` coalesced backlog is exactly this input) — plus the arrival
  stamp, pending count, timestamp state, and held instantaneous values.
  The sensor's ``_pool_batch`` flag is raised so a concurrent direct
  ``poll()`` no-ops instead of interleaving a second decode;
* **phase B** (no locks): concatenate every even, resync-clean buffer and
  decode it with *one* set of bit ops; devices whose batch is a whole
  number of constant-layout frames are grouped by frame layout and
  converted in one fused multiply-add per group, with the per-device
  affine tables stacked along a device axis.  Timestamp reconstruction
  runs as one segmented integer cumsum (exact, so per-device float
  semantics are preserved bit for bit);
* **phase C** (per device, under its lock): publish each device's slice
  through `PowerSensor._commit_batch` — the same energy/ring/marker/obs
  tail the solo receiver uses — and clear the flag.

Anything irregular — odd-length buffers, resync junk, partial trailing
frames, mixed per-frame layouts, markers on a disabled channel 0 —
falls back to the device's own `_ingest` (phase C, under its lock), the
exact code path a solo `poll()` runs.  Every float op on the pooled path
is elementwise or a per-device contiguous reduction, so the decoded
times/volts/amps/energies are **bit-identical** to the per-device path;
`tests/test_pool.py` and the golden corpus pin this.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Mapping

import numpy as np

from repro.obs import metrics as obs_metrics

if TYPE_CHECKING:  # pragma: no cover
    from repro.core.host import PowerSensor


@dataclass(slots=True)
class _Meta:
    """Cached per-device frame-layout tables (invalidated by config writes)."""

    gen: int  # sensor's _conv_gen when built
    per: int  # packets per frame (1 timestamp + enabled channels)
    layout: bytes  # the frame's channel-id row, as bytes (cache key)
    ch_ids: np.ndarray  # (per-1,) channel id of each data column
    a_row: np.ndarray  # (per-1,) affine gain per column
    b_row: np.ndarray  # (per-1,) affine offset per column
    vcols: np.ndarray  # data columns carrying enabled voltage channels
    icols: np.ndarray  # data columns carrying enabled current channels
    vpairs: np.ndarray  # target pair index per vcol
    ipairs: np.ndarray  # target pair index per icol
    mk_col: int  # marker-bearing packet column (-1: no channel 0)
    colkey: tuple  # group key: identical => identical column scatter


@dataclass(slots=True)
class _Batch:
    """One device's in-flight poll batch (phase A capture)."""

    name: str
    ps: "PowerSensor"
    buf: bytes
    arrival_s: float | None
    pending: int
    last_ts10: int | None
    dev_time_us: float
    # references captured under the receiver lock: `_commit_batch`
    # *replaces* these arrays (never mutates in place), so the refs stay
    # frozen at their phase-A values without copying
    inst_v: np.ndarray
    inst_i: np.ndarray
    has_v: np.ndarray
    has_i: np.ndarray
    per: int
    conv_gen: int
    lin_a: np.ndarray
    lin_b: np.ndarray
    ch_enabled: np.ndarray
    ch_is_volt: np.ndarray
    meta: _Meta | None = None
    committed: bool = False


@dataclass
class PoolResult:
    """Outcome of one pooled poll."""

    frames: int = 0
    errors: dict[str, BaseException] = field(default_factory=dict)
    polled: list[str] = field(default_factory=list)  # successful reads
    fused_devices: int = 0  # devices decoded on the fused path
    fallback_devices: int = 0  # devices routed through _ingest


class PooledDecoder:
    """Decode N receiver links' byte batches in one fused numpy pass."""

    def __init__(self, sensors: Mapping[str, "PowerSensor"]):
        # live reference (e.g. FleetMonitor's dict): membership changes
        # are picked up on the next poll, no rebuild protocol needed
        self._sensors = sensors
        self._meta: dict[str, _Meta] = {}
        # per-device packets-per-frame, keyed by conversion generation
        # (saves a numpy reduction per device per poll)
        self._per: dict[str, tuple[int, int]] = {}
        # per-group stacked conversion/mask tables, keyed by the member
        # (name, gen) tuple — stable fleets hit this every poll
        self._stacks: dict[tuple, tuple] = {}
        self.polls = 0
        self.fused_frames = 0
        self.fallback_batches = 0

    # ------------------------------------------------------------ phase A
    def _capture(self, result: PoolResult) -> list[_Batch]:
        batches: list[_Batch] = []
        for name, ps in self._sensors.items():
            if not hasattr(ps, "_ingest"):  # duck-typed sensor: solo poll
                try:
                    result.frames += int(ps.poll())
                    result.polled.append(name)
                except BaseException as exc:
                    result.errors[name] = exc
                continue
            with ps._lock:
                if ps._pool_batch:  # another pool owns it; skip this tick
                    continue
                dev = ps.device
                try:
                    read_batch = getattr(dev, "read_batch", None)
                    if read_batch is not None:
                        data, arrival_s, pending = read_batch()
                    else:
                        data = dev.read()
                        arrival_s = getattr(dev, "t_s", None)
                        pending = int(getattr(dev, "pending_bytes", 0) or 0)
                except BaseException as exc:
                    result.errors[name] = exc
                    continue
                result.polled.append(name)
                buf = ps._residual + data if ps._residual else data
                if not buf:
                    continue
                ps._residual = b""
                ps._pool_batch = True
                gen = ps._conv_gen
                pc = self._per.get(name)
                if pc is not None and pc[0] == gen:
                    per = pc[1]
                else:
                    per = 1 + int(ps._ch_enabled.sum())
                    self._per[name] = (gen, per)
                batches.append(
                    _Batch(
                        name=name,
                        ps=ps,
                        buf=buf,
                        arrival_s=(
                            None if arrival_s is None else float(arrival_s)
                        ),
                        pending=int(pending),
                        last_ts10=ps._last_ts10,
                        dev_time_us=ps._device_time_us,
                        inst_v=ps._inst_v,
                        inst_i=ps._inst_i,
                        has_v=ps._pair_has_v,
                        has_i=ps._pair_has_i,
                        per=per,
                        conv_gen=gen,
                        lin_a=ps._lin_a,
                        lin_b=ps._lin_b,
                        ch_enabled=ps._ch_enabled,
                        ch_is_volt=ps._ch_is_volt,
                    )
                )
        return batches

    # ------------------------------------------------------------ layout meta
    def _meta_for(
        self, b: _Batch, row: np.ndarray, layout: bytes | None = None
    ) -> _Meta:
        if layout is None:
            layout = row.tobytes()
        m = self._meta.get(b.name)
        if m is not None and m.gen == b.conv_gen and m.layout == layout:
            return m
        ch_ids = row.copy()
        en = b.ch_enabled[ch_ids]
        iv = b.ch_is_volt[ch_ids]
        vcols = np.flatnonzero(en & iv)
        icols = np.flatnonzero(en & ~iv)
        pair_of = ch_ids >> 1
        ch0 = np.flatnonzero(ch_ids == 0)
        m = _Meta(
            gen=b.conv_gen,
            per=b.per,
            layout=layout,
            ch_ids=ch_ids,
            a_row=b.lin_a[ch_ids],
            b_row=b.lin_b[ch_ids],
            vcols=vcols,
            icols=icols,
            vpairs=pair_of[vcols],
            ipairs=pair_of[icols],
            mk_col=int(1 + ch0[0]) if ch0.size else -1,
            colkey=(
                b.per,
                layout,
                vcols.tobytes(),
                icols.tobytes(),
            ),
        )
        self._meta[b.name] = m
        return m

    # ------------------------------------------------------------ the poll
    def poll(self) -> PoolResult:
        """One pooled receive pass over every link. Never raises for a
        single bad transport — per-device errors land in ``errors`` (the
        `FleetMonitor._safe_poll` contract, applied fleet-wide)."""
        result = PoolResult()
        self.polls += 1
        batches = self._capture(result)
        if not batches:
            return result
        try:
            self._decode(batches, result)
        finally:
            # exception safety: un-own anything not yet committed so the
            # bytes re-enter the stream on the next (solo or pooled) poll
            for b in batches:
                if not b.committed:
                    ps = b.ps
                    with ps._lock:
                        ps._pool_batch = False
                        ps._residual = b.buf + ps._residual
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter("pool_polls_total", "pooled decode passes").inc()
            if result.frames:
                reg.counter(
                    "pool_frames_total", "frames published by pooled polls"
                ).inc(result.frames)
            if result.fallback_devices:
                reg.counter(
                    "pool_fallback_batches_total",
                    "per-device batches routed through the solo decode path",
                ).inc(result.fallback_devices)
        return result

    def _decode(self, batches: list[_Batch], result: PoolResult) -> None:
        fallback: list[_Batch] = []
        pooled: list[_Batch] = []
        for b in batches:
            (pooled if not (len(b.buf) & 1) and b.per >= 2 else fallback).append(b)

        ids = vals = marks = is_ts = None
        if pooled:
            cat = b"".join(b.buf for b in pooled)
            arr = np.frombuffer(cat, dtype=np.uint8)
            a0 = arr[0::2]
            a1 = arr[1::2]
            # one resync-cleanliness check for the whole fleet: any dirty
            # byte routes everything through the solo path (corruption is
            # a chaos event; its accounting must match `_ingest` exactly)
            if not bool((a0 & 0x80).all()) or bool((a1 & 0x80).any()):
                fallback.extend(pooled)
                pooled = []
            else:
                ids = ((a0 >> 3) & 0x7).astype(np.int64)
                marks = ((a0 >> 6) & 0x1).astype(np.int64)
                vals = ((a0 & 0x7).astype(np.int64) << 7) | (a1 & 0x7F)
                is_ts = (ids == 7) & (marks == 1)

        groups: dict[tuple, list[tuple[_Batch, int, int]]] = {}
        if pooled:
            lens = np.array([len(b.buf) >> 1 for b in pooled])
            starts = np.zeros(len(pooled) + 1, dtype=np.int64)
            np.cumsum(lens, out=starts[1:])
            pers = np.array([b.per for b in pooled])
            # uniform-fleet fast path: every device shares one frame length
            # and one layout row => three whole-array checks replace all
            # per-device regularity scans
            uniform = False
            if int(pers.min()) == int(pers.max()):
                per = int(pers[0])
                if ids.size and ids.size % per == 0 and not (lens % per).any():
                    ts_mat = is_ts.reshape(-1, per)
                    ids_mat = ids.reshape(-1, per)
                    uniform = bool(
                        ts_mat[:, 0].all()
                        and not ts_mat[:, 1:].any()
                        and (ids_mat[:, 1:] == ids_mat[0, 1:]).all()
                    )
            # uniform fleets share one layout row: hash it to bytes once,
            # not once per device
            u_row = ids[1:per] if uniform else None
            u_layout = u_row.tobytes() if uniform else None
            for i, b in enumerate(pooled):
                s, e = int(starts[i]), int(starts[i + 1])
                if uniform:
                    b.meta = self._meta_for(b, u_row, u_layout)
                elif self._segment_regular(b, ids, is_ts, s, e):
                    b.meta = self._meta_for(b, ids[s + 1 : s + b.per])
                else:
                    fallback.append(b)
                    continue
                groups.setdefault(b.meta.colkey, []).append((b, s, e))

        for members in groups.values():
            self._decode_group(members, vals, marks, result)
            result.fused_devices += len(members)

        for b in fallback:
            ps = b.ps
            with ps._lock:
                ps._pool_batch = False
                b.committed = True
                try:
                    result.frames += max(int(ps._ingest(b.buf)), 0)
                except BaseException as exc:
                    result.errors[b.name] = exc
            result.fallback_devices += 1
        self.fallback_batches += len(fallback)

    @staticmethod
    def _segment_regular(b, ids, is_ts, s: int, e: int) -> bool:
        """`PowerSensor._frames_regular`, applied to one pooled segment."""
        per = b.per
        cnt = e - s
        if cnt == 0 or cnt % per:
            return False
        ts_mat = is_ts[s:e].reshape(-1, per)
        if not ts_mat[:, 0].all() or ts_mat[:, 1:].any():
            return False
        return bool((ids[s:e].reshape(-1, per)[:, 1:] == ids[s + 1 : s + per]).all())

    def _decode_group(self, members, vals, marks, result: PoolResult) -> None:
        """Fused decode of one layout group; publishes per-device slices.

        Every float op here is elementwise (multiply-add, V*I) or a
        per-device contiguous reduction, and the timestamp math is exact
        int64 until the final per-element float add — so each device's
        slice is bit-identical to what its solo receiver would produce.
        """
        g = len(members)
        per = members[0][0].per
        if g == 1:
            b, s, e = members[0]
            g_vals = vals[s:e].reshape(-1, per)
            g_marks = marks[s:e].reshape(-1, per)
        else:
            g_vals = np.concatenate([vals[s:e] for _, s, e in members]).reshape(-1, per)
            g_marks = np.concatenate([marks[s:e] for _, s, e in members]).reshape(-1, per)
        n_rows = g_vals.shape[0]
        rows_per = np.array([(e - s) // per for _, s, e in members])
        rows0 = int(rows_per[0])
        # equal row counts let every per-device op below run as a
        # broadcast over a (g, rows, ·) view instead of np.repeat'ing the
        # per-device tables out to n_rows — same per-element arithmetic,
        # no materialised repeats.  Steady fleets hit this every poll.
        uniform = bool((rows_per == rows0).all())
        rs = np.zeros(g, dtype=np.int64)
        np.cumsum(rows_per[:-1], out=rs[1:])
        last_rows = rs + rows_per - 1

        # one gather pass over the members' captured scalar state
        has_prev = np.empty(g, dtype=bool)
        prev = np.empty(g, dtype=np.int64)
        dev_us = np.empty(g)
        arrival = np.empty(g)
        pending = np.empty(g, dtype=np.int64)
        for i, (b, _, _) in enumerate(members):
            lt = b.last_ts10
            has_prev[i] = lt is not None
            prev[i] = 0 if lt is None else lt
            dev_us[i] = b.dev_time_us
            arrival[i] = np.nan if b.arrival_s is None else b.arrival_s
            pending[i] = b.pending

        # ---- timestamps: one segmented exact-integer cumsum -------------
        ts_vals = g_vals[:, 0]
        deltas = np.empty(n_rows, dtype=np.int64)
        if n_rows > 1:
            deltas[1:] = (ts_vals[1:] - ts_vals[:-1]) % 1024
        deltas[0] = 0
        first_ts = ts_vals[rs]
        deltas[rs] = np.where(has_prev, (first_ts - prev) % 1024, 0)
        cum = np.cumsum(deltas)
        base = np.where(has_prev, dev_us, first_ts.astype(np.float64))
        if uniform:
            rel = cum.reshape(g, rows0) - (cum[rs] - deltas[rs])[:, None]
            times = (base[:, None] + rel).reshape(-1)
        else:
            rel = cum - np.repeat(cum[rs] - deltas[rs], rows_per)
            times = np.repeat(base, rows_per) + rel

        # ---- arrival-clock re-anchor (same rule as `_process`) ----------
        with np.errstate(invalid="ignore"):
            wraps = np.floor((arrival * 1e6 - times[last_rows]) / 1024.0 + 0.5)
        apply = (pending == 0) & np.isfinite(wraps) & (wraps > 0)
        if apply.any():
            shift = np.where(apply, wraps * 1024.0, 0.0)
            if uniform:
                times = (times.reshape(g, rows0) + shift[:, None]).reshape(-1)
            else:
                times = times + np.repeat(shift, rows_per)
        times_s = times / 1e6

        # ---- conversion: stacked affine tables, one fused multiply-add --
        meta0 = members[0][0].meta
        codes = g_vals[:, 1:]
        skey = tuple((b.name, b.conv_gen) for b, _, _ in members)
        stacks = self._stacks.get(skey)
        if stacks is None:
            if len(self._stacks) > 256:  # churning fleets: bound the cache
                self._stacks.clear()
            stacks = (
                np.stack([b.meta.a_row for b, _, _ in members]),
                np.stack([b.meta.b_row for b, _, _ in members]),
                np.stack([b.has_v for b, _, _ in members]),
                np.stack([b.has_i for b, _, _ in members]),
            )
            self._stacks[skey] = stacks
        a_stack, b_stack, hasv_stack, hasi_stack = stacks
        # held instantaneous values, same `np.where` as the solo path but
        # computed once for the whole group (elementwise: bit-identical)
        held_v = np.where(hasv_stack, np.stack([b.inst_v for b, _, _ in members]), 0.0)
        held_i = np.where(hasi_stack, np.stack([b.inst_i for b, _, _ in members]), 0.0)
        n_pairs = held_v.shape[1]
        e_stack = None
        if uniform:
            phys3 = (
                codes.reshape(g, rows0, per - 1) * a_stack[:, None, :]
                + b_stack[:, None, :]
            )
            volts3 = np.empty((g, rows0, n_pairs))
            volts3[:] = held_v[:, None, :]
            amps3 = np.empty((g, rows0, n_pairs))
            amps3[:] = held_i[:, None, :]
            if meta0.vcols.size:
                volts3[:, :, meta0.vpairs] = phys3[:, :, meta0.vcols]
            if meta0.icols.size:
                amps3[:, :, meta0.ipairs] = phys3[:, :, meta0.icols]
            watts3 = volts3 * amps3
            # per-device energy sums, fused: reducing axis 1 of the
            # (g, rows, pairs) view adds the same rows in the same
            # sequential order as each device's own contiguous
            # `sum(axis=0)` — bit-identical, one numpy call instead of g
            e_stack = watts3.sum(axis=1)
            volts = volts3.reshape(n_rows, n_pairs)
            amps = amps3.reshape(n_rows, n_pairs)
            watts = watts3.reshape(n_rows, n_pairs)
        else:
            phys = codes * np.repeat(a_stack, rows_per, axis=0) + np.repeat(
                b_stack, rows_per, axis=0
            )
            volts = np.repeat(held_v, rows_per, axis=0)
            amps = np.repeat(held_i, rows_per, axis=0)
            if meta0.vcols.size:
                volts[:, meta0.vpairs] = phys[:, meta0.vcols]
            if meta0.icols.size:
                amps[:, meta0.ipairs] = phys[:, meta0.icols]
            watts = volts * amps
        wtot = watts.sum(axis=1)

        # ---- markers: extracted only when the batch carries any ---------
        mk_by_dev: dict[int, np.ndarray] = {}
        if meta0.mk_col >= 0:
            col = g_marks[:, meta0.mk_col]
            if col.any():
                mk_rows = np.flatnonzero(col)
                dev_of = np.searchsorted(rs, mk_rows, side="right") - 1
                for d in np.unique(dev_of):
                    mk_by_dev[int(d)] = mk_rows[dev_of == d] - rs[d]
        empty_mk = np.empty(0, dtype=np.int64)

        # ---- phase C: per-device publish under each receiver lock -------
        new_ts10 = ts_vals[last_rows]
        new_time_us = times[last_rows]
        for i, (b, _, _) in enumerate(members):
            r0 = int(rs[i])
            r1 = r0 + int(rows_per[i])
            ps = b.ps
            with ps._lock:
                ps._pool_batch = False
                b.committed = True
                ps._last_ts10 = int(new_ts10[i])
                ps._device_time_us = float(new_time_us[i])
                result.frames += ps._commit_batch(
                    times_s[r0:r1],
                    volts[r0:r1],
                    amps[r0:r1],
                    watts[r0:r1],
                    mk_by_dev.get(i, empty_mk),
                    wtot=wtot[r0:r1],
                    e_seg=None if e_stack is None else e_stack[i],
                )
        self.fused_frames += n_rows
