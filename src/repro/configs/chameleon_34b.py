"""chameleon-34b [vlm]: early-fusion decoder, VQ image tokens.

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536 [arXiv:2405.09818].
VQ image tokens are ordinary ids in the 65536 vocab (early fusion); the
VQ tokenizer frontend is a STUB per the assignment.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="dense",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    frontend="vlm",
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="chameleon-34b-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    frontend="vlm",
)
