"""Architecture + run configuration system.

`ArchConfig` describes *what* the model is (one file per assigned
architecture, exact public-literature configs).  `RunConfig` describes
*how* it runs (attention impl, chunk sizes, remat, MoE dispatch, CE
chunking — the §Perf hillclimbing levers).  `ShapeSpec` describes the
assigned input-shape cells.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field, replace


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None
    qkv_bias: bool = False
    act: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    # moe
    n_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    # ssm / hybrid
    ssm_state: int = 0
    attn_every: int = 0  # hybrid: shared attention block after every k SSM layers
    # encdec
    enc_layers: int = 0
    dec_layers: int = 0
    # modality
    frontend: str | None = None  # 'audio' | 'vlm' | None (stub per assignment)
    tie_embeddings: bool = False
    vocab_pad_multiple: int = 256
    # long-context capability: pure full-attention archs skip long_500k
    supports_long_context: bool = False

    @property
    def head_dim_(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def vocab_padded(self) -> int:
        m = self.vocab_pad_multiple
        return (self.vocab_size + m - 1) // m * m

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def param_count_estimate(self) -> float:
        """Analytic N for MODEL_FLOPS = 6·N·D (active params for MoE)."""
        d, ff, v = self.d_model, self.d_ff, self.vocab_padded
        hd = self.head_dim_
        attn = d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if self.family == "moe":
            k = self.experts_per_token
            mlp_active = 3 * d * ff * k
        elif self.family in ("ssm",):
            mlp_active = 0  # rwkv layers counted in their own structure below
        else:
            mlp_active = 3 * d * ff if self.act == "swiglu" else 2 * d * ff
        if self.family == "ssm":  # rwkv6: tm ~ 5 d² + cm 2·d·ff + d·ff
            layer = 6 * d * d + 3 * d * ff
        elif self.family == "hybrid":
            d_in = 2 * d
            ssm_layer = 2 * d * d_in + d_in * d  # in/out projections dominate
            layer = ssm_layer
        elif self.is_encdec:
            # decoder layers carry an extra cross-attention block
            layer = attn * 1.5 + mlp_active
        else:
            layer = attn + mlp_active
        n = self.n_layers * layer + v * d * (1 if self.tie_embeddings else 2)
        if self.family == "hybrid" and self.attn_every:
            n += (self.n_layers // self.attn_every) * 0  # shared block counted once
            n += attn + 3 * d * ff
        return float(n)


@dataclass(frozen=True)
class RunConfig:
    """Performance/runtime knobs — the §Perf levers."""

    attn_impl: str = "chunked"  # full | chunked
    q_chunk: int = 512
    kv_chunk: int = 1024
    skip_masked_blocks: bool = False  # causal block skipping (hillclimb)
    remat: str = "layer"  # none | layer
    scan_layers: bool = True
    scan_unroll: int = 1  # full-unroll for cost lowering
    moe_impl: str = "einsum"  # einsum | sort (hillclimb)
    moe_group: int | None = None
    ce_chunk: int = 0  # 0 = dense CE; >0 = sequence-chunked CE (hillclimb)
    ce_impl: str = "gather"  # gather | onehot (vocab-sharding-friendly gold pick)
    decode_seq_shard: bool = False  # split-S decode cache sharding (hillclimb)
    constrain_activations: bool = False  # Megatron-style layout pinning (hillclimb)
    accum_steps: int = 1  # microbatch gradient accumulation (memory lever)
    bf16_params: bool = False  # bf16 weights + f32 master in opt state (hillclimb)
    lr_chunk: int = 32  # linear-recurrence chunk
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    decode_cache_dtype: str = "bfloat16"

    def for_cost_lowering(self) -> "RunConfig":
        """Variant whose scans fully unroll (exact cost_analysis)."""
        return replace(self, scan_layers=False, scan_unroll=8)


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "whisper_base",
    "chameleon_34b",
    "phi35_moe",
    "grok1_314b",
    "qwen25_3b",
    "phi3_mini",
    "qwen15_4b",
    "granite_20b",
    "zamba2_7b",
    "rwkv6_3b",
]

#: public `--arch` aliases (assignment ids) -> module names
ALIASES = {
    "whisper-base": "whisper_base",
    "chameleon-34b": "chameleon_34b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "grok-1-314b": "grok1_314b",
    "qwen2.5-3b": "qwen25_3b",
    "phi3-mini-3.8b": "phi3_mini",
    "qwen1.5-4b": "qwen15_4b",
    "granite-20b": "granite_20b",
    "zamba2-7b": "zamba2_7b",
    "rwkv6-3b": "rwkv6_3b",
}


def _module(arch: str):
    arch = ALIASES.get(arch, arch).replace("-", "_").replace(".", "")
    return importlib.import_module(f"repro.configs.{arch}")


def get_config(arch: str) -> ArchConfig:
    return _module(arch).CONFIG


def smoke_config(arch: str) -> ArchConfig:
    return _module(arch).SMOKE


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cells(include_skipped: bool = False) -> list[tuple[str, str]]:
    """All assigned (arch, shape) dry-run cells; long_500k only where the
    architecture family supports sub-quadratic long context (DESIGN.md §4)."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            if shape.name == "long_500k" and not cfg.supports_long_context:
                if include_skipped:
                    out.append((arch, shape.name + ":skipped"))
                continue
            out.append((arch, shape.name))
    return out
