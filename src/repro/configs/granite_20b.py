"""granite-20b [dense]: llama-arch code model, MQA (kv=1).

52L d_model=6144 48H (kv=1) d_ff=24576 vocab=49152 [arXiv:2405.04324].
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="granite-20b",
    family="dense",
    n_layers=52,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="granite-20b-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=1,
    d_ff=256,
    vocab_size=512,
)
