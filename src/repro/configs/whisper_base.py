"""whisper-base [audio]: enc-dec, conv frontend stubbed.

6L d_model=512 8H (kv=8) d_ff=2048 vocab=51865 [arXiv:2212.04356].
Assignment note: `[audio]` specifies the transformer BACKBONE only; the
conv frontend is a STUB — `input_specs()` provides precomputed frame
embeddings (B, T_enc, d).  Enc-dec split of an assigned seq_len S:
T_enc = S/2 frames, T_dec = S/2 tokens (DESIGN.md §4).
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base",
    family="encdec",
    n_layers=12,  # 6 encoder + 6 decoder
    enc_layers=6,
    dec_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    d_ff=2048,
    vocab_size=51865,
    act="gelu",  # whisper uses plain GELU MLPs
    frontend="audio",
    supports_long_context=False,  # full attention -> long_500k skipped
)

SMOKE = ArchConfig(
    name="whisper-base-smoke",
    family="encdec",
    n_layers=4,
    enc_layers=2,
    dec_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    act="gelu",
    frontend="audio",
)
