"""rwkv6-3b [ssm]: Finch — attention-free, data-dependent decay.

32L d_model=2560 d_ff=8960 vocab=65536 [arXiv:2404.05892].
n_heads below is the RWKV head count (d_model / 64); no KV heads exist.
Constant-size state: runs long_500k.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,  # d_model / 64
    n_kv_heads=0,
    d_ff=8960,
    vocab_size=65536,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="rwkv6-3b-smoke",
    family="ssm",
    n_layers=3,
    d_model=128,
    n_heads=2,
    n_kv_heads=0,
    d_ff=256,
    vocab_size=512,
    supports_long_context=True,
)
