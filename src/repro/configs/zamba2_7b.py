"""zamba2-7b [hybrid]: Mamba-2 backbone + shared attention blocks.

81L d_model=3584 32H (kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242].  The shared transformer block (one set of weights)
is applied after every `attn_every` Mamba-2 layers — zamba2's signature
parameter-sharing trick.  Sub-quadratic: runs long_500k.
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    attn_every=6,
    supports_long_context=True,
)

SMOKE = ArchConfig(
    name="zamba2-7b-smoke",
    family="hybrid",
    n_layers=7,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    attn_every=3,
    supports_long_context=True,
)
