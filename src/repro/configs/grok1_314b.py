"""grok-1-314b [moe]: 8 experts, top-2 — the largest assigned model.

64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072 [hf:xai-org/grok-1].
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=32768,
    vocab_size=131072,
    n_experts=8,
    experts_per_token=2,
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="grok-1-smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=256,
    vocab_size=512,
    n_experts=4,
    experts_per_token=2,
)
