"""phi3-mini-3.8b [dense]: RoPE SwiGLU, MHA (kv=32).

32L d_model=3072 32H (kv=32) d_ff=8192 vocab=32064 [arXiv:2404.14219].
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab_size=32064,
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="phi3-mini-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=8,
    d_ff=256,
    vocab_size=512,
)
