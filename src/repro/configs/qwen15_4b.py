"""qwen1.5-4b [dense]: QKV bias, kv=20.

40L d_model=2560 20H (kv=20) d_ff=6912 vocab=151936 [hf:Qwen/Qwen1.5].
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-4b",
    family="dense",
    n_layers=40,
    d_model=2560,
    n_heads=20,
    n_kv_heads=20,
    d_ff=6912,
    vocab_size=151936,
    qkv_bias=True,
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="qwen1.5-4b-smoke",
    family="dense",
    n_layers=3,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    d_ff=256,
    vocab_size=512,
    qkv_bias=True,
)
