"""phi3.5-moe-42b-a6.6b [moe]: 16 experts, top-2.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064
[hf:microsoft/Phi-3.5-MoE-instruct].
"""
from repro.configs import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    n_experts=16,
    experts_per_token=2,
    supports_long_context=False,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-smoke",
    family="moe",
    n_layers=3,
    d_model=128,
    n_heads=8,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    n_experts=4,
    experts_per_token=2,
)
