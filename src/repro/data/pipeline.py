"""Deterministic synthetic token pipeline.

Design goals (the properties fault-tolerance and tests rely on):

* **O(1) random access**: batch `i` is a pure function of (seed, i) via a
  counter-based Philox generator — resuming from a checkpoint reproduces
  the exact uninterrupted stream (test_fault pins this bitwise).
* **host sharding**: each host generates only its slice of the global
  batch (`host_id`/`n_hosts`), the multi-host layout of a real cluster.
* **document structure**: Zipf-distributed tokens packed into documents
  separated by EOS — gives the loss some structure to learn (quickstart
  shows a falling loss) while staying dependency-free.
* serialisable state: `state_dict()` is just the next step index.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.configs import ArchConfig


@dataclass
class SyntheticTokens:
    cfg: ArchConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1
    zipf_a: float = 1.3
    mean_doc_len: int = 128
    step: int = 0

    def __post_init__(self) -> None:
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts
        # learnable structure: a fixed random bigram successor table;
        # next-token = successor(cur) with prob 0.5 else zipf sample
        rng = np.random.default_rng(np.random.Philox(key=self.seed))
        self._succ = rng.integers(0, self.cfg.vocab_size, size=self.cfg.vocab_size)

    # ------------------------------------------------------------------
    def _rng_for(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.Philox(key=self.seed + 1, counter=[0, 0, self.host_id, step])
        )

    def batch_at(self, step: int) -> dict:
        rng = self._rng_for(step)
        b, s = self.host_batch, self.seq_len + 1
        # zipf truncated to vocab
        raw = rng.zipf(self.zipf_a, size=(b, s)).astype(np.int64)
        base = raw % max(1, self.cfg.vocab_size - 2) + 2
        use_succ = rng.random((b, s)) < 0.5
        toks = base.copy()
        toks[:, 1:] = np.where(
            use_succ[:, 1:], self._succ[toks[:, :-1]] , base[:, 1:]
        )
        # EOS document boundaries
        eos_mask = rng.random((b, s)) < 1.0 / self.mean_doc_len
        toks = np.where(eos_mask, 1, toks)  # token 1 = EOS
        batch = {"tokens": toks.astype(np.int32)}
        if self.cfg.is_encdec:
            batch["frames"] = rng.standard_normal(
                (b, self.seq_len, self.cfg.d_model)
            ).astype(np.float32)
        return batch

    # ------------------------------------------------------------------
    def __iter__(self):
        return self

    def __next__(self) -> dict:
        batch = self.batch_at(self.step)
        self.step += 1
        return batch

    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.seed, "host_id": self.host_id}

    def load_state_dict(self, state: dict) -> None:
        assert state["seed"] == self.seed, "resuming with a different data seed"
        self.step = int(state["step"])


@dataclass
class PipelineStats:
    """Bandwidth/occupancy counters — the SSD-case-study analogue hooks
    (`benchmarks/fig12_storage.py`) read these."""

    bytes_produced: int = 0
    batches: int = 0

    def observe(self, batch: dict) -> None:
        self.bytes_produced += sum(a.nbytes for a in batch.values())
        self.batches += 1
