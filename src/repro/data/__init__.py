from .pipeline import PipelineStats, SyntheticTokens

__all__ = ["PipelineStats", "SyntheticTokens"]
