"""Self-observability layer: flight recorder, metrics, exporters, watchdog.

The package splits into four modules:

- :mod:`repro.obs.trace`   -- preallocated ring-buffer span/counter/event
  recorder (the flight recorder proper).  numpy + stdlib only, so hot
  paths anywhere in the tree can import it without cycles.
- :mod:`repro.obs.metrics` -- counters / gauges / fixed-log-bucket
  histograms with a process-global registry.
- :mod:`repro.obs.export`  -- Prometheus text snapshots and
  Chrome-trace-event JSON (loadable in Perfetto / chrome://tracing).
- :mod:`repro.obs.watch`   -- streaming signature watchdog over live
  ``FleetMonitor`` windows, plus the ``PartTimeSampler`` nvidia-smi-style
  negative baseline (imported lazily: it pulls in attrib/stream).

Instrumented call sites follow the pattern::

    from repro.obs import trace

    rec = trace.active()
    if rec is not None:
        rec.counter("rx.frames", float(n), track="rx")

which costs one module-attribute read and an ``is None`` test when
tracing is disabled (the default).
"""

from __future__ import annotations

from repro.obs import export, metrics, trace
from repro.obs.trace import TraceRecorder
from repro.obs.metrics import MetricsRegistry

__all__ = [
    "trace",
    "metrics",
    "export",
    "watch",
    "TraceRecorder",
    "MetricsRegistry",
    "enable",
    "disable",
]


def enable(capacity: int = 1 << 16) -> tuple[TraceRecorder, MetricsRegistry]:
    """Install a fresh global recorder + registry and return both."""
    rec = trace.install(TraceRecorder(capacity=capacity))
    reg = metrics.install(MetricsRegistry())
    return rec, reg


def disable() -> None:
    """Uninstall the global recorder and registry (tracing back to no-op)."""
    trace.uninstall()
    metrics.uninstall()


def __getattr__(name: str):
    if name == "watch":  # lazy: watch imports attrib/stream machinery
        import importlib

        return importlib.import_module("repro.obs.watch")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
