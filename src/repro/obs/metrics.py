"""Counters, gauges, and fixed-log-bucket histograms with a global registry.

Same activation pattern as :mod:`repro.obs.trace`: call sites fetch the
process-global registry via :func:`active` and skip updates when it is
``None``.  Metrics are cumulative process-lifetime aggregates (what you
scrape); the trace ring is the time-resolved view (what you replay).

Labels are passed as keyword arguments and become part of the series
key, matching the Prometheus data model::

    reg.counter("fleet_health_transitions_total", device="dev0", to="stale").inc()

numpy + stdlib only — hot paths import this module.
"""

from __future__ import annotations

import math
import threading

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "install",
    "uninstall",
    "active",
]

_LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict[str, str]) -> _LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _format_labels(labels: _LabelKey) -> str:
    if not labels:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in labels)
    return "{" + inner + "}"


class Counter:
    """Monotonically increasing value."""

    kind = "counter"

    def __init__(self) -> None:
        self._value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        if n < 0:
            raise ValueError("counters only go up")
        with self._lock:
            self._value += n

    @property
    def value(self) -> float:
        return self._value


class Gauge:
    """Last-write-wins sampled value."""

    kind = "gauge"

    def __init__(self) -> None:
        self._value = 0.0

    def set(self, v: float) -> None:
        self._value = float(v)

    @property
    def value(self) -> float:
        return self._value


class Histogram:
    """Fixed log-spaced buckets between ``lo`` and ``hi``.

    ``per_decade`` buckets per power of ten, plus an overflow bucket.
    Exposed in Prometheus exposition as cumulative ``_bucket{le=...}``
    series with ``_sum`` and ``_count``.
    """

    kind = "histogram"

    def __init__(self, lo: float = 1e-6, hi: float = 10.0, per_decade: int = 4):
        if not (lo > 0 and hi > lo):
            raise ValueError("need 0 < lo < hi")
        if per_decade <= 0:
            raise ValueError("per_decade must be positive")
        n_decades = math.log10(hi / lo)
        n = max(1, math.ceil(n_decades * per_decade))
        step = 10.0 ** (1.0 / per_decade)
        self.bounds = [lo * step**i for i in range(n + 1)]
        self._counts = [0] * (len(self.bounds) + 1)  # + overflow
        self._sum = 0.0
        self._count = 0
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._sum += v
            self._count += 1
            for i, b in enumerate(self.bounds):
                if v <= b:
                    self._counts[i] += 1
                    return
            self._counts[-1] += 1

    @property
    def count(self) -> int:
        return self._count

    @property
    def sum(self) -> float:
        return self._sum

    def cumulative(self) -> list[tuple[float, int]]:
        """(upper_bound, cumulative_count) pairs, ending with +inf."""
        out: list[tuple[float, int]] = []
        running = 0
        for b, c in zip(self.bounds, self._counts[:-1]):
            running += c
            out.append((b, running))
        out.append((math.inf, running + self._counts[-1]))
        return out

    def quantile(self, q: float) -> float:
        """Upper bound of the bucket holding the q-quantile (crude)."""
        if not 0.0 <= q <= 1.0:
            raise ValueError("q must be in [0, 1]")
        if self._count == 0:
            return float("nan")
        target = q * self._count
        for b, running in self.cumulative():
            if running >= target:
                return b
        return math.inf


class MetricsRegistry:
    """Get-or-create store of labelled metric series."""

    def __init__(self) -> None:
        self._series: dict[tuple[str, _LabelKey], Counter | Gauge | Histogram] = {}
        self._help: dict[str, str] = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, help: str, labels: dict[str, str], **kwargs):
        key = (name, _label_key(labels))
        with self._lock:
            m = self._series.get(key)
            if m is None:
                m = cls(**kwargs)
                self._series[key] = m
                if help:
                    self._help[name] = help
            elif not isinstance(m, cls):
                raise TypeError(
                    f"metric {name!r} already registered as {m.kind}"
                )
            return m

    def counter(self, name: str, help: str = "", **labels: str) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels: str) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(
        self, name: str, help: str = "", *, lo: float = 1e-6,
        hi: float = 10.0, per_decade: int = 4, **labels: str,
    ) -> Histogram:
        return self._get(Histogram, name, help, labels,
                         lo=lo, hi=hi, per_decade=per_decade)

    def series(self) -> list[tuple[str, _LabelKey, Counter | Gauge | Histogram]]:
        """(name, labels, metric) triples, sorted by name then labels."""
        with self._lock:
            items = sorted(self._series.items())
        return [(name, labels, m) for (name, labels), m in items]

    def help_text(self, name: str) -> str:
        return self._help.get(name, "")

    def get_value(self, name: str, **labels: str) -> float | None:
        """Value of a counter/gauge series, or None if absent."""
        m = self._series.get((name, _label_key(labels)))
        if m is None or isinstance(m, Histogram):
            return None
        return m.value


def format_labels(labels: _LabelKey, extra: dict[str, str] | None = None) -> str:
    """Render a label key (plus extras) as ``{k="v",...}`` or ``""``."""
    if extra:
        merged = dict(labels)
        merged.update(extra)
        labels = _label_key(merged)
    return _format_labels(labels)


# -- module-global active registry ----------------------------------------

_active: MetricsRegistry | None = None


def install(reg: MetricsRegistry | None = None) -> MetricsRegistry:
    global _active
    if reg is None:
        reg = MetricsRegistry()
    _active = reg
    return reg


def uninstall() -> MetricsRegistry | None:
    global _active
    reg, _active = _active, None
    return reg


def active() -> MetricsRegistry | None:
    return _active
