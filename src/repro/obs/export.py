"""Exporters: Prometheus text snapshots and Chrome-trace-event JSON.

The Chrome trace output is the "JSON array of event objects" dialect
that Perfetto and chrome://tracing both load: spans become ``"ph": "X"``
complete events, instants ``"ph": "i"``, counter samples ``"ph": "C"``.

Clock alignment: wall-clock tracks are emitted relative to the
recorder's start (``t0_us``).  Device-clock tracks (fault windows,
attribution intervals) are shifted onto the same timeline using the
recorder's first wall/device anchor pair when one exists; otherwise they
are emitted raw under a separate ``device-time`` process so nothing is
silently misaligned.
"""

from __future__ import annotations

import json
import math
from typing import IO

from repro.obs.metrics import Histogram, MetricsRegistry, format_labels
from repro.obs.trace import COUNTER, DEVICE, INSTANT, SPAN, TraceRecorder

__all__ = [
    "prometheus_text",
    "chrome_trace_events",
    "chrome_trace_json",
    "write_chrome_trace",
]

_WALL_PID = 1
_DEVICE_PID = 2


def prometheus_text(reg: MetricsRegistry) -> str:
    """Render a registry in the Prometheus text exposition format."""
    lines: list[str] = []
    seen_meta: set[str] = set()
    for name, labels, metric in reg.series():
        if name not in seen_meta:
            seen_meta.add(name)
            help_text = reg.help_text(name)
            if help_text:
                lines.append(f"# HELP {name} {help_text}")
            lines.append(f"# TYPE {name} {metric.kind}")
        if isinstance(metric, Histogram):
            for bound, cum in metric.cumulative():
                le = "+Inf" if math.isinf(bound) else repr(bound)
                lines.append(
                    f"{name}_bucket{format_labels(labels, {'le': le})} {cum}"
                )
            lines.append(f"{name}_sum{format_labels(labels)} {metric.sum!r}")
            lines.append(f"{name}_count{format_labels(labels)} {metric.count}")
        else:
            lines.append(f"{name}{format_labels(labels)} {metric.value!r}")
    return "\n".join(lines) + ("\n" if lines else "")


def chrome_trace_events(rec: TraceRecorder) -> list[dict]:
    """Convert retained ring events to Chrome trace-event dicts."""
    offset = rec.device_offset_us()
    t0 = rec.t0_us
    out: list[dict] = []
    tids: dict[tuple[int, str], int] = {}

    def tid_for(pid: int, track: str) -> int:
        key = (pid, track)
        tid = tids.get(key)
        if tid is None:
            tid = len([k for k in tids if k[0] == pid]) + 1
            tids[key] = tid
            out.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": tid,
                "args": {"name": track},
            })
        return tid

    out.append({"name": "process_name", "ph": "M", "pid": _WALL_PID,
                "tid": 0, "args": {"name": "repro"}})
    if offset is None:
        out.append({"name": "process_name", "ph": "M", "pid": _DEVICE_PID,
                    "tid": 0, "args": {"name": "device-time"}})

    for ev in rec.events():
        if ev.clock == DEVICE:
            if offset is None:
                pid, ts = _DEVICE_PID, ev.t_us
            else:
                pid, ts = _WALL_PID, ev.t_us + offset - t0
        else:
            pid, ts = _WALL_PID, ev.t_us - t0
        tid = tid_for(pid, ev.track)
        if ev.kind == SPAN:
            out.append({"name": ev.name, "ph": "X", "pid": pid, "tid": tid,
                        "ts": ts, "dur": ev.dur_us,
                        "args": {"value": ev.value}})
        elif ev.kind == INSTANT:
            out.append({"name": ev.name, "ph": "i", "pid": pid, "tid": tid,
                        "ts": ts, "s": "t", "args": {"value": ev.value}})
        elif ev.kind == COUNTER:
            out.append({"name": ev.name, "ph": "C", "pid": pid, "tid": tid,
                        "ts": ts, "args": {ev.name: ev.value}})
    return out


def chrome_trace_json(rec: TraceRecorder, metadata: dict | None = None) -> str:
    doc = {
        "traceEvents": chrome_trace_events(rec),
        "displayTimeUnit": "ms",
        "otherData": {
            "recorded_events": rec.head,
            "dropped_events": rec.dropped,
            **(metadata or {}),
        },
    }
    return json.dumps(doc)


def write_chrome_trace(
    rec: TraceRecorder, path_or_file: str | IO[str],
    metadata: dict | None = None,
) -> None:
    text = chrome_trace_json(rec, metadata)
    if hasattr(path_or_file, "write"):
        path_or_file.write(text)  # type: ignore[union-attr]
    else:
        with open(path_or_file, "w") as fh:
            fh.write(text)
