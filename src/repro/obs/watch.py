"""Streaming anomaly watchdog over live fleet windows (ROADMAP item 4).

:class:`SignatureWatchdog` runs the :mod:`repro.attrib.signatures`
matcher incrementally over each device's ring: every ``check()`` pulls
the window since the device's cursor, changepoint-segments it, and
scores each *complete* segment against a library of known-good kernel
signatures.  Two anomaly kinds come out:

- ``unknown-signature`` — no library entry within ``max_distance``
  (a kernel shape the fleet has never run, or a badly distorted one);
- ``power-deviation``  — the shape matches a known kernel but its mean
  power is off by more than ``power_tol`` (thermal throttling, a stuck
  DVFS rung, a misbehaving device).

:class:`PartTimeSampler` is the negative baseline the benchmark pins:
an nvidia-smi-style part-time power counter ("Part-time Power
Measurements", PAPERS.md) that reads instantaneous power at ~10 Hz with
sample-and-hold.  Excursions shorter than its sampling period land
between samples and are structurally invisible to it, while the 20 kHz
watchdog sees every segment.

Degraded-telemetry semantics (see the table in ``stream/fleet.py``):
stale and lost devices are *skipped*, not judged — their rings only
hold the past, and matching old windows would re-raise stale anomalies
forever.  Skips are counted in ``watchdog_skipped_total`` and the
device's cursor freezes until it recovers.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Callable

from repro.attrib.segment import segment_block
from repro.attrib.signatures import SignatureLibrary
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

if TYPE_CHECKING:  # pragma: no cover - typing only
    from repro.stream.fleet import FleetMonitor

__all__ = ["Anomaly", "SignatureWatchdog", "PartTimeSampler"]


@dataclass(frozen=True)
class Anomaly:
    """One flagged segment on one device."""

    device: str
    kind: str  # "unknown-signature" | "power-deviation"
    name: str  # nearest signature name ("?" when none close enough)
    t0_s: float
    t1_s: float
    distance: float
    mean_w: float
    expected_w: float | None = None

    @property
    def duration_s(self) -> float:
        return self.t1_s - self.t0_s


@dataclass
class _Cursor:
    t_s: float
    primed: bool = False  # first segment after attach is dropped unjudged


class SignatureWatchdog:
    """Incremental signature matching over a live ``FleetMonitor``.

    ``check()`` is cheap enough to call from the same loop that polls
    the fleet; each call consumes only the ring data that arrived since
    the previous call, so work scales with stream time, not ring size.
    """

    def __init__(
        self,
        fleet: "FleetMonitor",
        library: SignatureLibrary,
        *,
        max_distance: float = 0.25,
        power_tol: float = 0.2,
        min_window_s: float = 0.01,
        min_duration_s: float = 1e-3,
        segment_kwargs: dict | None = None,
    ):
        if len(library) == 0:
            raise ValueError("watchdog needs a non-empty signature library")
        self.fleet = fleet
        self.library = library
        self.max_distance = float(max_distance)
        self.power_tol = float(power_tol)
        self.min_window_s = float(min_window_s)
        self.min_duration_s = float(min_duration_s)
        self.segment_kwargs = dict(segment_kwargs or {})
        self.anomalies: list[Anomaly] = []
        self.n_checks = 0
        self.n_segments = 0
        self._cursors: dict[str, _Cursor] = {}

    # ------------------------------------------------------------ internals
    def _emit(self, anom: Anomaly) -> None:
        self.anomalies.append(anom)
        rec = obs_trace.active()
        if rec is not None:
            rec.device_span(
                f"anomaly:{anom.kind}:{anom.name}", anom.t0_s, anom.t1_s,
                track=f"watchdog:{anom.device}", value=anom.mean_w,
            )
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter(
                "watchdog_anomalies_total",
                "anomalous segments flagged by the signature watchdog",
                device=anom.device, kind=anom.kind,
            ).inc()

    def _judge(self, device: str, seg, times_s, watts) -> None:
        self.n_segments += 1
        name, dist = self.library.match(times_s, watts, seg.t0_s, seg.t1_s)
        if dist > self.max_distance:
            self._emit(Anomaly(device, "unknown-signature", "?",
                               seg.t0_s, seg.t1_s, dist, seg.mean_w))
            return
        sig = self.library.signatures[name]
        ref = max(abs(sig.mean_w), 1e-9)
        if abs(seg.mean_w - sig.mean_w) / ref > self.power_tol:
            self._emit(Anomaly(device, "power-deviation", name,
                               seg.t0_s, seg.t1_s, dist, seg.mean_w,
                               expected_w=sig.mean_w))

    # ------------------------------------------------------------ public
    def check(self, poll: bool = False) -> list[Anomaly]:
        """Consume new ring data on every healthy device; return new anomalies."""
        from repro.stream.fleet import FleetMonitor  # locked ring reads

        if poll:
            self.fleet.poll_all()
        self.n_checks += 1
        before = len(self.anomalies)
        reg = obs_metrics.active()
        if reg is not None:
            reg.counter("watchdog_checks_total",
                        "watchdog check passes").inc()
        health = self.fleet.device_health()
        for name in self.fleet.names:
            ps = self.fleet[name]
            state = health[name].state
            if state != "healthy":
                # stale/lost: freeze the cursor, count the skip (see table)
                if reg is not None:
                    reg.counter(
                        "watchdog_skipped_total",
                        "device windows skipped while stale/lost",
                        device=name, state=state,
                    ).inc()
                continue
            last = ps.ring.last_time_s if len(ps.ring) else 0.0
            cur = self._cursors.get(name)
            if cur is None:
                cur = self._cursors[name] = _Cursor(t_s=last)
                continue
            if last - cur.t_s < self.min_window_s:
                continue
            block = FleetMonitor._locked_ring_read(
                ps, lambda ps=ps, t0=cur.t_s, t1=last: ps.ring.window(t0, t1)
            )
            if block.times_s.size < 8:
                continue
            seg = segment_block(block, **self.segment_kwargs)
            if len(seg.segments) < 2:
                continue  # nothing complete yet: the lone segment is open
            times, watts = block.times_s, block.total_watts
            # the trailing segment is still in progress — leave it for the
            # next pass by parking the cursor at its start
            for s in seg.segments[:-1]:
                if s.duration_s < self.min_duration_s:
                    continue
                if not cur.primed:
                    cur.primed = True  # first segment may straddle attach
                    continue
                self._judge(name, s, times, watts)
            cur.t_s = seg.segments[-1].t0_s
        return self.anomalies[before:]


class PartTimeSampler:
    """nvidia-smi-style part-time power counter (the negative baseline).

    Reads instantaneous power through ``read_fn(t_s)`` at ``rate_hz``
    with sample-and-hold between updates — the documented behaviour the
    "Part-time Power Measurements" paper measured (and the same model
    as ``repro.power.pmt.BuiltinCounterMeter``, here in streaming form).
    ``poll(now_s)`` takes every sample that has come due; ``detect``
    flags readings outside a power band, which is the best a shape-blind
    sampler can do.
    """

    def __init__(
        self,
        read_fn: Callable[[float], float],
        rate_hz: float = 10.0,
        phase_s: float = 0.0,
    ):
        if rate_hz <= 0:
            raise ValueError("rate_hz must be positive")
        self.read_fn = read_fn
        self.period_s = 1.0 / float(rate_hz)
        self._next_t = float(phase_s)
        self.samples: list[tuple[float, float]] = []

    def poll(self, now_s: float) -> int:
        """Take every sample due by ``now_s``; returns how many were taken."""
        n = 0
        while self._next_t <= now_s:
            self.samples.append((self._next_t, float(self.read_fn(self._next_t))))
            self._next_t += self.period_s
            n += 1
        return n

    @property
    def values(self) -> list[float]:
        return [w for _, w in self.samples]

    def detect(self, lo_w: float, hi_w: float) -> list[tuple[float, float]]:
        """Samples outside [lo_w, hi_w] — the sampler's whole anomaly story."""
        return [(t, w) for t, w in self.samples if not (lo_w <= w <= hi_w)]
