"""Flight recorder: a preallocated ring buffer of spans, instants, counters.

Design goals, in order:

1. **Near-zero cost when disabled.**  Call sites hold no recorder; they
   ask :func:`active` for the module-global and skip everything when it
   is ``None``.  That is one attribute read and one identity test.
2. **Bounded, allocation-free recording.**  All event storage is
   preallocated numpy columns; recording writes six scalars under a
   lock.  When the ring wraps, the oldest events are overwritten
   (flight-recorder semantics) and ``dropped`` counts them.
3. **Two clock domains.**  Control-plane events are stamped with the
   wall monotonic clock (``time.perf_counter_ns() // 1000``, µs).
   Device-side overlays (fault windows, marker-delimited attribution
   intervals) live on the virtual device clock, in seconds.  Recorded
   ``anchor`` pairs let the exporter shift device-time tracks onto the
   wall timeline so one Perfetto view aligns both.

Only numpy + stdlib may be imported here: ``repro.core.host`` and
``repro.stream.fleet`` import this module from their hot paths.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

__all__ = [
    "SPAN",
    "INSTANT",
    "COUNTER",
    "WALL",
    "DEVICE",
    "TraceEvent",
    "TraceRecorder",
    "install",
    "uninstall",
    "active",
    "now_us",
]

# event kinds
SPAN = 0  # t_us = start, dur_us = duration  (Chrome phase "X")
INSTANT = 1  # point event                     (Chrome phase "i")
COUNTER = 2  # value sample on a counter track (Chrome phase "C")

# clock domains for tracks
WALL = 0  # monotonic microseconds (perf_counter)
DEVICE = 1  # virtual device seconds, stored as microseconds

_KIND_NAMES = {SPAN: "span", INSTANT: "instant", COUNTER: "counter"}
_CLOCK_NAMES = {WALL: "wall", DEVICE: "device"}


def now_us() -> int:
    """Current wall (monotonic) time in microseconds."""
    return time.perf_counter_ns() // 1000


@dataclass(frozen=True)
class TraceEvent:
    """One decoded ring entry, oldest-first order from :meth:`events`."""

    kind: int
    name: str
    track: str
    clock: int
    t_us: int
    dur_us: int
    value: float

    @property
    def kind_name(self) -> str:
        return _KIND_NAMES[self.kind]

    @property
    def t1_us(self) -> int:
        return self.t_us + self.dur_us


class _Span:
    """Context manager recording a wall-clock span on exit."""

    __slots__ = ("_rec", "_name", "_track", "_value", "_t0")

    def __init__(self, rec: "TraceRecorder", name: str, track: str, value: float):
        self._rec = rec
        self._name = name
        self._track = track
        self._value = value

    def __enter__(self) -> "_Span":
        self._t0 = now_us()
        return self

    def __exit__(self, *exc) -> None:
        t1 = now_us()
        self._rec.span_at(
            self._name, self._t0, t1, track=self._track, value=self._value
        )


class TraceRecorder:
    """Preallocated, thread-safe ring buffer of trace events.

    ``capacity`` is the number of retained events; older events are
    overwritten once the ring wraps.  ``head`` counts every event ever
    recorded (monotonic), so ``dropped == max(0, head - capacity)``.
    """

    def __init__(self, capacity: int = 1 << 16):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.capacity = int(capacity)
        self._kind = np.zeros(self.capacity, dtype=np.uint8)
        self._name_id = np.zeros(self.capacity, dtype=np.uint32)
        self._track_id = np.zeros(self.capacity, dtype=np.uint16)
        self._t_us = np.zeros(self.capacity, dtype=np.int64)
        self._dur_us = np.zeros(self.capacity, dtype=np.int64)
        self._value = np.zeros(self.capacity, dtype=np.float64)
        self._lock = threading.Lock()
        self.head = 0
        # string interning: names and tracks are small, bounded sets
        self._names: list[str] = []
        self._name_ids: dict[str, int] = {}
        self._tracks: list[str] = []
        self._track_ids: dict[str, int] = {}
        self._track_clock: dict[int, int] = {}
        # wall<->device correspondence points: (wall_us, device_us)
        self._anchors: list[tuple[int, int]] = []
        self.t0_us = now_us()

    # -- interning ---------------------------------------------------------

    def _intern_name(self, name: str) -> int:
        nid = self._name_ids.get(name)
        if nid is None:
            nid = len(self._names)
            if nid > 0xFFFFFFFF:
                raise RuntimeError("too many distinct trace names")
            self._names.append(name)
            self._name_ids[name] = nid
        return nid

    def _intern_track(self, track: str, clock: int) -> int:
        tid = self._track_ids.get(track)
        if tid is None:
            tid = len(self._tracks)
            if tid > 0xFFFF:
                raise RuntimeError("too many distinct trace tracks")
            self._tracks.append(track)
            self._track_ids[track] = tid
            self._track_clock[tid] = clock
        return tid

    def track_clock(self, track: str) -> int:
        """Clock domain a track was first recorded under."""
        return self._track_clock[self._track_ids[track]]

    # -- recording ---------------------------------------------------------

    def _record(
        self, kind: int, name: str, track: str, clock: int, t_us: int,
        dur_us: int, value: float,
    ) -> None:
        with self._lock:
            i = self.head % self.capacity
            self._kind[i] = kind
            self._name_id[i] = self._intern_name(name)
            self._track_id[i] = self._intern_track(track, clock)
            self._t_us[i] = t_us
            self._dur_us[i] = dur_us
            self._value[i] = value
            self.head += 1

    def span_at(
        self, name: str, t0_us: int, t1_us: int, *, track: str = "main",
        clock: int = WALL, value: float = 0.0,
    ) -> None:
        """Record a completed span [t0_us, t1_us] on ``track``."""
        self._record(SPAN, name, track, clock, int(t0_us),
                     max(0, int(t1_us) - int(t0_us)), value)

    def span(self, name: str, *, track: str = "main", value: float = 0.0) -> _Span:
        """Context manager: record a wall-clock span around the block."""
        return _Span(self, name, track, value)

    def instant(
        self, name: str, *, t_us: int | None = None, track: str = "main",
        clock: int = WALL, value: float = 0.0,
    ) -> None:
        """Record a point event."""
        if t_us is None:
            t_us = now_us()
        self._record(INSTANT, name, track, clock, int(t_us), 0, value)

    def counter(
        self, name: str, value: float, *, t_us: int | None = None,
        track: str = "counters", clock: int = WALL,
    ) -> None:
        """Record one sample of a numeric counter series."""
        if t_us is None:
            t_us = now_us()
        self._record(COUNTER, name, track, clock, int(t_us), 0, float(value))

    def device_span(
        self, name: str, t0_s: float, t1_s: float, *, track: str = "device",
        value: float = 0.0,
    ) -> None:
        """Record a span stamped in device seconds (stored as µs)."""
        self.span_at(name, round(t0_s * 1e6), round(t1_s * 1e6),
                     track=track, clock=DEVICE, value=value)

    def device_instant(
        self, name: str, t_s: float, *, track: str = "device", value: float = 0.0,
    ) -> None:
        self.instant(name, t_us=round(t_s * 1e6), track=track,
                     clock=DEVICE, value=value)

    def anchor(self, device_s: float, wall_us: int | None = None) -> None:
        """Record that device time ``device_s`` corresponds to ``wall_us``."""
        if wall_us is None:
            wall_us = now_us()
        with self._lock:
            self._anchors.append((int(wall_us), round(device_s * 1e6)))

    def anchor_once(self, device_s: float, wall_us: int | None = None) -> None:
        """Record an anchor only if none exists yet (hot-path friendly)."""
        if not self._anchors:
            self.anchor(device_s, wall_us)

    # -- reading -----------------------------------------------------------

    @property
    def dropped(self) -> int:
        """Events overwritten after the ring wrapped."""
        return max(0, self.head - self.capacity)

    def __len__(self) -> int:
        return min(self.head, self.capacity)

    @property
    def anchors(self) -> list[tuple[int, int]]:
        with self._lock:
            return list(self._anchors)

    def device_offset_us(self) -> int | None:
        """Wall µs minus device µs from the first anchor, or None."""
        with self._lock:
            if not self._anchors:
                return None
            wall, dev = self._anchors[0]
        return wall - dev

    def events(self) -> list[TraceEvent]:
        """Decode retained events, oldest first."""
        with self._lock:
            n = min(self.head, self.capacity)
            if n == 0:
                return []
            if self.head <= self.capacity:
                order = np.arange(n)
            else:
                start = self.head % self.capacity
                order = np.concatenate(
                    [np.arange(start, self.capacity), np.arange(start)]
                )
            kinds = self._kind[order].copy()
            name_ids = self._name_id[order].copy()
            track_ids = self._track_id[order].copy()
            t_us = self._t_us[order].copy()
            dur_us = self._dur_us[order].copy()
            values = self._value[order].copy()
            names = list(self._names)
            tracks = list(self._tracks)
            clocks = dict(self._track_clock)
        return [
            TraceEvent(
                kind=int(kinds[i]),
                name=names[name_ids[i]],
                track=tracks[track_ids[i]],
                clock=clocks[int(track_ids[i])],
                t_us=int(t_us[i]),
                dur_us=int(dur_us[i]),
                value=float(values[i]),
            )
            for i in range(n)
        ]

    def events_named(self, name: str) -> list[TraceEvent]:
        return [e for e in self.events() if e.name == name]

    def counter_total(self, name: str) -> float:
        """Sum of all retained samples of a counter series."""
        return float(sum(e.value for e in self.events()
                         if e.kind == COUNTER and e.name == name))


# -- module-global active recorder ----------------------------------------

_active: TraceRecorder | None = None


def install(rec: TraceRecorder | None = None) -> TraceRecorder:
    """Make ``rec`` (or a fresh recorder) the process-global recorder."""
    global _active
    if rec is None:
        rec = TraceRecorder()
    _active = rec
    return rec


def uninstall() -> TraceRecorder | None:
    """Remove and return the global recorder (tracing becomes a no-op)."""
    global _active
    rec, _active = _active, None
    return rec


def active() -> TraceRecorder | None:
    """The installed recorder, or None when tracing is disabled."""
    return _active
