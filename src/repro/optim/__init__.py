from .adamw import AdamWConfig, apply_updates, init_opt_state, schedule_lr
from .compression import ErrorFeedbackCompressor, dequantize_int8, quantize_int8

__all__ = [
    "AdamWConfig",
    "apply_updates",
    "init_opt_state",
    "schedule_lr",
    "ErrorFeedbackCompressor",
    "dequantize_int8",
    "quantize_int8",
]
