"""Gradient compression with error feedback (distributed-optimization tool).

int8 per-tensor-scaled quantisation + an error-feedback residual: the
classic trick for slow interconnects (1-bit Adam / EF-SGD family).  At
the pjit level gradient reduction is implicit, so the compressor is
exposed as an explicit transform around the gradient tree — production
use slots it into a `shard_map` manual-collective step; here it ships
with exact error-feedback semantics and tests, and the roofline reports
how much collective traffic it would remove (×4 vs f32, ×2 vs bf16).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


@dataclass
class ErrorFeedbackCompressor:
    """Stateful EF compressor over a grad pytree (residual carried)."""

    residual: dict | None = None

    def init(self, grads):
        self.residual = jax.tree.map(jnp.zeros_like, grads)
        return self

    def compress_decompress(self, grads):
        """Simulate the wire round trip; returns (decompressed, wire_bytes)."""
        if self.residual is None:
            self.init(grads)

        wire_bytes = 0
        outs = []
        flat_g, treedef = jax.tree.flatten(grads)
        flat_r = treedef.flatten_up_to(self.residual)
        new_r = []
        for g, r in zip(flat_g, flat_r):
            target = g.astype(jnp.float32) + r
            q, s = quantize_int8(target)
            deq = dequantize_int8(q, s)
            new_r.append(target - deq)  # error feedback
            outs.append(deq.astype(g.dtype))
            wire_bytes += q.size + 4  # int8 payload + scale
        self.residual = treedef.unflatten(new_r)
        return treedef.unflatten(outs), wire_bytes

    @staticmethod
    def uncompressed_bytes(grads) -> int:
        return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(grads))
