"""AdamW with warmup+cosine schedule and global-norm clipping (pytrees).

f32 master weights live in `params`; m/v mirror the param tree.  The
`apply` function is pure and jit/pjit-friendly (m/v inherit the params'
shardings through propagation).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.params import global_norm


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # cosine | linear | constant
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    frac = jnp.clip(
        (step - cfg.warmup_steps) / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0
    )
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    else:
        decay = jnp.float32(1.0)
    return cfg.lr * warm * decay


def init_opt_state(params, master_weights: bool = False) -> dict:
    """m/v mirror params.  With ``master_weights`` the f32 master copy
    lives here and `params` can be bf16 — halving the FSDP all-gather wire
    (the §Perf grok lever: gathers move 2-byte weights, the 4-byte master
    only sees local elementwise updates)."""
    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
    state = {
        "m": zeros,
        "v": jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params),
        "step": jnp.int32(0),
    }
    if master_weights:
        state["master"] = jax.tree.map(lambda x: x.astype(jnp.float32), params)
    return state


def apply_updates(params, grads, state, cfg: AdamWConfig):
    """Returns (new_params, new_state, stats)."""
    step = state["step"]
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9)) if cfg.clip_norm else 1.0
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    masters = state.get("master")

    def upd(p, g, m, v, master):
        ref = master if master is not None else p
        g = g.astype(jnp.float32) * scale
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        m_hat = m_new / (1 - b1 ** (step.astype(jnp.float32) + 1))
        v_hat = v_new / (1 - b2 ** (step.astype(jnp.float32) + 1))
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps) + cfg.weight_decay * ref
        new_ref = ref - lr * delta
        return new_ref.astype(p.dtype), m_new, v_new, new_ref

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_master = treedef.flatten_up_to(masters) if masters is not None else [None] * len(flat_p)
    out = [upd(p, g, m, v, mw)
           for p, g, m, v, mw in zip(flat_p, flat_g, flat_m, flat_v, flat_master)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {
        "m": treedef.unflatten([o[1] for o in out]),
        "v": treedef.unflatten([o[2] for o in out]),
        "step": step + 1,
    }
    if masters is not None:
        new_state["master"] = treedef.unflatten([o[3] for o in out])
    stats = {"lr": lr, "grad_norm": gnorm}
    return new_p, new_state, stats
