"""Training loop: loss decreases, telemetry, accumulation equivalence."""
import jax
import numpy as np
import pytest

from repro.configs import RunConfig, smoke_config
from repro.data import SyntheticTokens
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.power import EnergyTelemetry, StepCost
from repro.train import LoopConfig, train

RUN = RunConfig(attn_impl="full", remat="none", lr_chunk=8)


def _setup(arch="qwen25_3b", batch=8, seq=32):
    cfg = smoke_config(arch)
    model = build_model(cfg, RUN)
    data = SyntheticTokens(cfg, global_batch=batch, seq_len=seq, seed=3)
    return cfg, model, data


def test_loss_decreases():
    cfg, model, data = _setup()
    opt = AdamWConfig(lr=5e-3, warmup_steps=5, total_steps=60)
    res = train(model, data, opt, LoopConfig(steps=60, log_every=0, ckpt_every=0))
    first = np.mean([h["loss"] for h in res.history[:5]])
    last = np.mean([h["loss"] for h in res.history[-5:]])
    assert last < first - 0.2, (first, last)


def test_telemetry_attached():
    cfg, model, data = _setup()
    tel = EnergyTelemetry(
        cost_per_step=StepCost(1e12, 1e11, 1e9), n_layers=cfg.n_layers,
        useful_flops_per_step=1e12,
    )
    opt = AdamWConfig(lr=1e-3, total_steps=5)
    res = train(model, data, opt, LoopConfig(steps=5, log_every=0, ckpt_every=0),
                telemetry=tel)
    assert len(tel.records) == 5
    assert all("joules" in h for h in res.history)
    assert tel.summary()["total_joules"] > 0


def test_attributor_emits_per_kernel_ledger():
    """train(attributor=...) brackets steps with markers on the virtual
    sensor and lands a per-kernel energy ledger in the result."""
    from repro.attrib import StepAttributor

    cfg, model, data = _setup()
    tel = EnergyTelemetry(
        cost_per_step=StepCost(1e12, 1e11, 1e9), n_layers=cfg.n_layers,
        useful_flops_per_step=1e12,
    )
    opt = AdamWConfig(lr=1e-3, total_steps=4)
    res = train(model, data, opt, LoopConfig(steps=4, log_every=0, ckpt_every=0),
                telemetry=tel, attributor=StepAttributor(tel, seed=21))
    ledger = res.energy_ledger
    assert ledger is not None
    assert set(ledger.entries) == {p.name for p in tel.phases}
    assert all(e.count == 4 for e in ledger.entries.values())
    # measured-through-the-sensor total tracks the model integral
    assert ledger.total_energy_j == pytest.approx(
        tel.modelled_step_joules * 4, rel=0.05
    )


def test_grad_accumulation_matches_full_batch():
    cfg, model, data = _setup(batch=8, seq=32)
    opt = AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=3, clip_norm=0.0)
    r1 = train(model, data, opt, LoopConfig(steps=3, log_every=0, ckpt_every=0, accum_steps=1))
    data2 = SyntheticTokens(cfg, global_batch=8, seq_len=32, seed=3)
    r2 = train(model, data2, opt, LoopConfig(steps=3, log_every=0, ckpt_every=0, accum_steps=4))
    l1 = [h["loss"] for h in r1.history]
    l2 = [h["loss"] for h in r2.history]
    np.testing.assert_allclose(l1, l2, rtol=2e-2)  # bf16 + mean-of-means


def test_history_records_complete():
    cfg, model, data = _setup()
    opt = AdamWConfig(total_steps=4)
    res = train(model, data, opt, LoopConfig(steps=4, log_every=0, ckpt_every=0))
    for h in res.history:
        assert {"step", "loss", "grad_norm", "lr", "step_time_s"} <= set(h)
        assert np.isfinite(h["loss"])
