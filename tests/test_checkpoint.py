"""Checkpointing: atomicity, round-trip, pruning, async, elastic remesh."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _tree():
    return {
        "params": {"w": jnp.arange(12.0).reshape(3, 4), "b": jnp.ones((4,))},
        "opt": {"m": {"w": jnp.zeros((3, 4))}, "step": jnp.int32(7)},
        "tup": (jnp.ones(2), jnp.zeros(3)),
    }


def test_roundtrip(tmp_path):
    path = str(tmp_path / "c1")
    tree = _tree()
    ckpt.save(path, tree, extra={"step": 7, "data_state": {"step": 7, "seed": 0}})
    tree2, extra = ckpt.restore(path)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(tree2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # structure preserved (incl. tuple)
    assert isinstance(tree2["tup"], tuple)


def test_atomic_no_tmp_left(tmp_path):
    path = str(tmp_path / "c2")
    ckpt.save(path, _tree())
    assert not os.path.exists(path + ".tmp")
    assert os.path.exists(os.path.join(path, ckpt.MANIFEST))


def test_overwrite_existing(tmp_path):
    path = str(tmp_path / "c3")
    ckpt.save(path, {"x": jnp.zeros(3)})
    ckpt.save(path, {"x": jnp.ones(3)})
    tree, _ = ckpt.restore(path)
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.ones(3))


def test_available_and_prune(tmp_path):
    d = str(tmp_path)
    for s in (10, 20, 30, 40):
        ckpt.save(ckpt.step_path(d, s), {"x": jnp.zeros(1)}, extra={"step": s})
    assert ckpt.available_steps(d) == [10, 20, 30, 40]
    assert ckpt.latest_step(d) == 40
    ckpt.prune(d, keep_last=2)
    assert ckpt.available_steps(d) == [30, 40]


def test_async_checkpointer(tmp_path):
    d = str(tmp_path)
    saver = ckpt.AsyncCheckpointer(d, keep_last=2)
    for s in (1, 2, 3):
        saver.save_async(s, {"x": jnp.full((4,), float(s))}, extra={"step": s})
    saver.wait()
    assert ckpt.available_steps(d) == [2, 3]
    tree, extra = ckpt.restore(ckpt.step_path(d, 3))
    np.testing.assert_array_equal(np.asarray(tree["x"]), np.full(4, 3.0))


def test_elastic_restore_with_shardings(tmp_path):
    """A checkpoint loads under a (different) mesh via shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    path = str(tmp_path / "c4")
    tree = {"w": jnp.arange(16.0).reshape(4, 4)}
    ckpt.save(path, tree)
    mesh = make_mesh((1, 1), ("data", "model"))
    sh = {"w": NamedSharding(mesh, P(None, None))}
    tree2, _ = ckpt.restore(path, shardings=sh)
    assert tree2["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(tree2["w"]), np.asarray(tree["w"]))
