"""Wire-protocol unit + property tests (byte-exact round-trip)."""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import protocol
from repro.core.protocol import SensorConfigBlock


def test_single_packet_roundtrip():
    raw = protocol.encode_packets([3], [1023], [1])
    assert len(raw) == 2
    ids, vals, marks, consumed = protocol.decode_packets(raw)
    assert consumed == 2
    assert ids[0] == 3 and vals[0] == 1023 and marks[0] == 1


def test_first_second_byte_flags():
    raw = protocol.encode_packets([0], [0], [0])
    assert raw[0] & 0x80 and not raw[1] & 0x80


def test_out_of_range_rejected():
    with pytest.raises(ValueError):
        protocol.encode_packets([0], [1024], [0])
    with pytest.raises(ValueError):
        protocol.encode_packets([8], [0], [0])


@settings(max_examples=200, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.integers(0, 7), st.integers(0, 1023), st.integers(0, 1)
        ),
        min_size=1,
        max_size=64,
    )
)
def test_roundtrip_property(packets):
    ids, vals, marks = map(np.array, zip(*packets))
    raw = protocol.encode_packets(ids, vals, marks)
    dids, dvals, dmarks, consumed = protocol.decode_packets(raw)
    assert consumed == len(raw)
    np.testing.assert_array_equal(dids, ids)
    np.testing.assert_array_equal(dvals, vals)
    np.testing.assert_array_equal(dmarks, marks)


def test_resync_on_garbage_prefix():
    raw = protocol.encode_packets([1, 2], [100, 200], [0, 0])
    noisy = bytes([0x01]) + raw  # stray second-byte first
    ids, vals, marks, consumed = protocol.decode_packets(noisy)
    np.testing.assert_array_equal(ids, [1, 2])
    np.testing.assert_array_equal(vals, [100, 200])


def test_partial_packet_left_unconsumed():
    raw = protocol.encode_packets([1], [100], [0])
    ids, vals, marks, consumed = protocol.decode_packets(raw[:1])
    assert len(ids) == 0
    assert consumed <= 1


def test_timestamp_detection():
    ids = np.array([7, 7, 0])
    marks = np.array([1, 0, 1])
    ts = protocol.is_timestamp(ids, marks)
    np.testing.assert_array_equal(ts, [True, False, False])


def test_timestamp_unwrap():
    # frames every 50 µs, 10-bit wrap at 1024
    true_t = np.arange(0, 5000, 50)
    wrapped = true_t % 1024
    rec = protocol.unwrap_timestamps(wrapped)
    np.testing.assert_array_equal(rec, true_t)


@settings(max_examples=100, deadline=None)
@given(st.integers(0, 1023), st.integers(1, 500))
def test_timestamp_unwrap_property(start, n):
    true_t = start + np.arange(n) * 50
    rec = protocol.unwrap_timestamps(true_t % 1024)
    np.testing.assert_array_equal(np.diff(rec), 50)


def test_config_block_roundtrip():
    blk = SensorConfigBlock(
        name="pcie8p.i", type_code=0, enabled=True, vref=3.3,
        sensitivity=0.0825, offset_cal=-0.12, gain_cal=1.002,
    )
    blk2 = SensorConfigBlock.unpack(blk.pack())
    assert blk2.name == blk.name
    assert blk2.type_code == blk.type_code
    assert blk2.enabled == blk.enabled
    np.testing.assert_allclose(
        [blk2.vref, blk2.sensitivity, blk2.offset_cal, blk2.gain_cal],
        [blk.vref, blk.sensitivity, blk.offset_cal, blk.gain_cal],
        rtol=1e-6,
    )


def test_config_conversion_current_channel():
    blk = SensorConfigBlock(type_code=0, enabled=True, vref=3.3, sensitivity=0.165)
    # mid-rail code -> 0 A
    mid_code = 0.5 * 1023
    assert abs(blk.raw_to_physical(mid_code)) < 1e-9
    # full-scale -> +10 A
    np.testing.assert_allclose(blk.raw_to_physical(1023), 10.0, rtol=1e-3)


def test_config_conversion_voltage_channel():
    blk = SensorConfigBlock(type_code=1, enabled=True, vref=3.3, sensitivity=0.2)
    np.testing.assert_allclose(blk.raw_to_physical(1023), 16.5, rtol=1e-3)


# ----------------------------------------------------------- resync edge cases
def test_orphan_second_bytes_mid_stream_are_dropped():
    """Stray second-bytes *between* packets (not just as a prefix) resync."""
    raw1 = protocol.encode_packets([1], [100], [0])
    raw2 = protocol.encode_packets([2], [200], [0])
    noisy = raw1 + bytes([0x05]) + raw2 + bytes([0x7F, 0x03]) + raw1
    ids, vals, marks, consumed = protocol.decode_packets(noisy)
    np.testing.assert_array_equal(ids, [1, 2, 1])
    np.testing.assert_array_equal(vals, [100, 200, 100])
    assert consumed == len(noisy)


def test_trailing_first_byte_carries_across_two_calls():
    """A packet split across reads decodes once the second half arrives."""
    raw = protocol.encode_packets([3, 4], [300, 400], [0, 1])
    part1, part2 = raw[:3], raw[3:]  # second packet split after its first byte
    ids1, vals1, marks1, c1 = protocol.decode_packets(part1)
    np.testing.assert_array_equal(ids1, [3])
    assert c1 == 2  # the dangling first byte stays unconsumed
    residual = part1[c1:]
    ids2, vals2, marks2, c2 = protocol.decode_packets(residual + part2)
    np.testing.assert_array_equal(ids2, [4])
    np.testing.assert_array_equal(vals2, [400])
    np.testing.assert_array_equal(marks2, [1])
    assert c2 == 2


def test_marker_bit_on_nonzero_nontimestamp_id_is_plain_data():
    """id != 0 with the marker bit set is neither a timestamp nor a marker
    (the paper reserves it as unused) — it must decode as ordinary data."""
    raw = protocol.encode_packets([5], [17], [1])
    ids, vals, marks, consumed = protocol.decode_packets(raw)
    np.testing.assert_array_equal(ids, [5])
    np.testing.assert_array_equal(vals, [17])
    np.testing.assert_array_equal(marks, [1])
    np.testing.assert_array_equal(protocol.is_timestamp(ids, marks), [False])
