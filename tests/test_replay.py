"""Archive codec + replay transport unit tests.

The hypothesis tier pins the round-trip invariant of the trace archive:
random frame batches — random enabled-channel layouts, ring-wraparound
order, marker bytes, dropped-frame gaps (including multi-wrap gaps) —
encode → save → load → decode to bit-identical frames; and anything
short of a fully consistent archive (truncation, corruption, version
skew, inconsistent members) fails with a versioned `ArchiveError`, never
garbage frames.

Runs under real `hypothesis` when installed, else under the deterministic
shim from ``tests/conftest.py``.
"""
import io

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.core.protocol import SensorConfigBlock, conversion_tables
from repro.replay import (
    ARCHIVE_VERSION,
    ArchiveError,
    DeviceTrace,
    ReplayDevice,
    SessionRecorder,
    TraceArchive,
    encode_device,
    load_bytes,
    replay_sensor,
    save_bytes,
)
from repro.stream.ring import FrameRing

MAX_PAIRS = 4


def _configs(enabled_mask: int) -> list[SensorConfigBlock]:
    """8 config blocks with a given enabled bitmask, realistic constants.

    Values are round-tripped through the packed wire format, exactly like
    a live host's EEPROM download — archive configs are always
    pack-representable.
    """
    blocks = []
    for sid in range(8):
        blk = SensorConfigBlock(
            name=f"ch{sid}",
            type_code=sid % 2,  # even = current, odd = voltage
            enabled=bool(enabled_mask >> sid & 1),
            vref=3.3,
            sensitivity=0.09 if sid % 2 == 0 else 0.151,
            offset_cal=0.013 * sid,
            gain_cal=1.0 + 0.003 * sid,
        )
        blocks.append(SensorConfigBlock.unpack(blk.pack()))
    return blocks


def _random_session(n: int, enabled_mask: int, seed: int):
    """A synthetic decoded session: frames via the receiver's own affine,
    times with dropped-frame gaps (some crossing 10-bit wraps), markers."""
    rng = np.random.default_rng(seed)
    configs = _configs(enabled_mask)
    lin_a, lin_b, enabled, is_volt = conversion_tables(configs)
    ch_ids = np.flatnonzero(enabled)

    # frame clock: 50 µs steps with occasional gaps (sub-wrap and multi-wrap)
    deltas = np.full(n, 50, dtype=np.int64)
    gaps = rng.random(n) < 0.1
    deltas[gaps] = rng.choice([150, 600, 1024, 1074, 5000, 123456], size=int(gaps.sum()))
    deltas[0] = 0
    times_us = 17 + np.cumsum(deltas)
    times_s = times_us / 1e6

    codes = rng.integers(0, 1024, size=(n, ch_ids.size))
    volts = np.zeros((n, MAX_PAIRS))
    amps = np.zeros((n, MAX_PAIRS))
    for j, sid in enumerate(ch_ids.tolist()):
        col = codes[:, j] * lin_a[sid] + lin_b[sid]
        (volts if is_volt[sid] else amps)[:, sid >> 1] = col

    mark_frames = np.flatnonzero(rng.random(n) < 0.15)
    markers = [
        (chr(65 + int(rng.integers(26))), float(times_s[f])) for f in mark_frames
    ]
    return configs, times_s, volts, amps, markers


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 200), st.integers(1, 255), st.integers(0, 2**31 - 1))
def test_roundtrip_random_frame_batches(n, enabled_mask, seed):
    configs, times_s, volts, amps, markers = _random_session(n, enabled_mask, seed)
    trace = encode_device("dev", configs, "fw-test", times_s, volts, amps, markers)
    assert trace.n_quantised == 0
    assert trace.n_time_quantised == 0
    assert trace.dropped_markers == 0

    archive = TraceArchive(meta={"seed": seed})
    archive.add(trace)
    loaded = load_bytes(save_bytes(archive))
    tr2 = loaded.devices["dev"]
    block = tr2.decode()
    np.testing.assert_array_equal(block.times_s, times_s)
    np.testing.assert_array_equal(block.volts, volts)
    np.testing.assert_array_equal(block.amps, amps)
    np.testing.assert_array_equal(block.watts, volts * amps)
    assert tr2.markers == sorted(markers, key=lambda m: m[1])
    assert loaded.meta["seed"] == seed


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 200), st.integers(1, 255), st.integers(0, 2**31 - 1))
def test_truncated_archives_fail_loudly(n, enabled_mask, seed):
    configs, times_s, volts, amps, markers = _random_session(n, enabled_mask, seed)
    archive = TraceArchive()
    archive.add(encode_device("dev", configs, "fw", times_s, volts, amps, markers))
    raw = save_bytes(archive)
    rng = np.random.default_rng(seed)
    cut = int(rng.integers(1, len(raw)))
    with pytest.raises(ArchiveError):
        load_bytes(raw[:cut])


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 100), st.integers(0, 2**31 - 1))
def test_corrupted_archives_fail_loudly(n, seed):
    configs, times_s, volts, amps, markers = _random_session(n, 0x0F, seed)
    archive = TraceArchive()
    archive.add(encode_device("dev", configs, "fw", times_s, volts, amps, markers))
    raw = bytearray(save_bytes(archive))
    rng = np.random.default_rng(seed + 1)
    # flip a handful of payload bytes past the zip local header
    for pos in rng.integers(40, len(raw), size=8):
        raw[int(pos)] ^= 0xFF
    try:
        loaded = load_bytes(bytes(raw))
    except ArchiveError:
        return  # loud failure: exactly the contract
    # zip CRCs can miss flips that land in an already-read region of the
    # central directory; if the load survived, the data must be *valid*
    # (validation passed), i.e. decodable without garbage values
    block = loaded.devices["dev"].decode()
    assert np.all(np.isfinite(block.watts))


def test_version_skew_fails_with_versioned_error():
    configs, times_s, volts, amps, markers = _random_session(10, 3, 0)
    archive = TraceArchive()
    archive.add(encode_device("dev", configs, "fw", times_s, volts, amps, markers))
    raw = save_bytes(archive)
    # rewrite the header with a future version
    import json
    import zipfile

    buf_in = io.BytesIO(raw)
    buf_out = io.BytesIO()
    with zipfile.ZipFile(buf_in) as zin, zipfile.ZipFile(buf_out, "w") as zout:
        for item in zin.infolist():
            data = zin.read(item.filename)
            if item.filename == "header.npy":
                hdr = json.loads(str(np.load(io.BytesIO(data))[()]))
                hdr["version"] = ARCHIVE_VERSION + 1
                arr_buf = io.BytesIO()
                np.save(arr_buf, np.asarray(json.dumps(hdr)))
                data = arr_buf.getvalue()
            zout.writestr(item, data)
    with pytest.raises(ArchiveError, match="version"):
        TraceArchive.load(io.BytesIO(buf_out.getvalue()))


def test_not_an_archive_fails():
    with pytest.raises(ArchiveError):
        load_bytes(b"definitely not a zip")
    # an npz that isn't a trace archive
    buf = io.BytesIO()
    np.savez(buf, foo=np.arange(3))
    with pytest.raises(ArchiveError, match="header"):
        TraceArchive.load(io.BytesIO(buf.getvalue()))


def test_inconsistent_members_fail():
    configs, times_s, volts, amps, _ = _random_session(20, 3, 4)
    trace = encode_device("dev", configs, "fw", times_s, volts, amps, [])
    # out-of-range codes
    bad = DeviceTrace(**{**trace.__dict__, "codes": trace.codes + 2000})
    a = TraceArchive()
    a.add(bad)
    with pytest.raises(ArchiveError, match="ADC code"):
        load_bytes(save_bytes(a))
    # non-monotonic times
    t_bad = trace.times_us.copy()
    if t_bad.size > 1:
        t_bad[-1] = t_bad[0]
        a = TraceArchive()
        a.add(DeviceTrace(**{**trace.__dict__, "times_us": t_bad}))
        with pytest.raises(ArchiveError, match="monotonic"):
            load_bytes(save_bytes(a))
    # marker that points at no recorded frame
    a = TraceArchive()
    a.add(
        DeviceTrace(
            **{
                **trace.__dict__,
                "marker_chars": "X",
                "marker_times_us": np.array([trace.times_us[0] + 7], dtype=np.int64),
            }
        )
    )
    with pytest.raises(ArchiveError, match="marker"):
        load_bytes(save_bytes(a))


def test_lossy_encode_is_counted_not_silent():
    configs = _configs(0x03)
    # values nowhere near the affine lattice, fractional-µs times
    times_s = np.array([0.0000005, 0.0000507])
    volts = np.zeros((2, MAX_PAIRS))
    amps = np.zeros((2, MAX_PAIRS))
    volts[:, 0] = [1.2345, 3.14159]
    amps[:, 0] = [0.7, 0.9]
    trace = encode_device("dev", configs, "fw", times_s, volts, amps, [])
    assert trace.n_quantised > 0
    assert trace.n_time_quantised > 0


def test_ring_wraparound_order_survives_capture():
    """Capture from a ring that wrapped: archive stays chronological."""
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 4.0), seed=3)
    ps = PowerSensor(dev, ring_capacity=256)  # wraps every 12.8 ms
    rec = SessionRecorder(ps, name="d")
    for _ in range(10):
        ps.run_for(0.01, chunk_s=0.01)  # 200 frames per capture
        rec.capture()
    archive = rec.finalize()
    tr = archive.devices["d"]
    assert rec.lost_frames == 0
    assert len(tr) == 2000
    assert np.all(np.diff(tr.times_us) > 0)
    block = tr.decode()
    # the retained live tail matches the archive's tail bit for bit
    live = ps.ring.latest()
    np.testing.assert_array_equal(block.times_s[-len(live):], live.times_s)
    np.testing.assert_array_equal(block.watts[-len(live):], live.watts)
    ps.close()


def test_eviction_between_captures_is_loud():
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 4.0), seed=3)
    ps = PowerSensor(dev, ring_capacity=128)
    rec = SessionRecorder(ps, name="d")
    ps.run_for(0.02, chunk_s=0.02)  # 400 frames through a 128-frame ring
    rec.capture()
    archive = rec.finalize()
    assert rec.lost_frames == 400 - 128
    assert archive.devices["d"].lost_frames == 400 - 128
    ps.close()


# ---------------------------------------------------------------------------
# the replay transport
# ---------------------------------------------------------------------------
def _recorded_trace(seconds=0.05, seed=0, marks=3):
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 4.0), seed=seed)
    ps = PowerSensor(dev)
    rec = SessionRecorder(ps, name="d")
    for k in range(marks):
        ps.mark(chr(65 + k))
        ps.run_for(seconds / marks, chunk_s=0.01)
        rec.capture()
    archive = rec.finalize()
    live_block = ps.ring.latest()
    live_markers = list(ps.markers)
    ps.close()
    return archive.devices["d"], live_block, live_markers


def test_replay_through_real_receiver_is_bit_identical():
    trace, live_block, live_markers = _recorded_trace()
    ps = replay_sensor(trace)
    while not ps.device.exhausted:
        ps.poll()
    block = ps.ring.latest()
    np.testing.assert_array_equal(block.times_s, live_block.times_s)
    np.testing.assert_array_equal(block.volts, live_block.volts)
    np.testing.assert_array_equal(block.amps, live_block.amps)
    np.testing.assert_array_equal(block.watts, live_block.watts)
    assert ps.markers == live_markers
    assert ps.version == "ps3-sim 1.2.0"
    assert ps.dropped_frames == 0
    ps.close()


def test_replay_realtime_pacing():
    trace, live_block, _ = _recorded_trace(seconds=0.04)
    ps = replay_sensor(trace, realtime=True)
    ps.poll()
    assert len(ps.ring) <= 1  # nothing released until the clock advances
    released = 0
    for _ in range(8):
        ps.device.advance(0.005)
        ps.poll()
        assert len(ps.ring) >= released  # frames arrive with the clock
        released = len(ps.ring)
    while not ps.device.exhausted:
        ps.device.advance(0.005)
        ps.poll()
    block = ps.ring.latest()
    np.testing.assert_array_equal(block.times_s, live_block.times_s)
    np.testing.assert_array_equal(block.watts, live_block.watts)
    ps.close()


def test_replay_chunked_and_size_capped_reads():
    trace, live_block, _ = _recorded_trace(seconds=0.03)
    dev = ReplayDevice(trace, chunk_frames=37)
    dev.write(b"S")
    out = bytearray()
    while not dev.exhausted:
        chunk = dev.read(101)  # odd cap: splits packets mid-frame
        if not chunk:
            break
        out.extend(chunk)
    # every frame's bytes were delivered exactly once
    per = 2 * (1 + trace.channel_ids.size)
    assert len(out) >= len(trace) * per


def test_replay_device_ignores_live_marks():
    trace, _, _ = _recorded_trace()
    ps = replay_sensor(trace)
    ps.mark("Z")  # a live mark during replay has no frame to ride on
    while not ps.device.exhausted:
        ps.poll()
    assert "Z" not in [c for c, _ in ps.markers]
    ps.close()


def test_replay_marker_with_disabled_ch0():
    """Markers replay as bare sensor-0 packets when ch0 wasn't recorded."""
    dev = make_device([None, "pcie8pin-20a"], ConstantLoad(12.0, 4.0), seed=1)
    ps = PowerSensor(dev)
    rec = SessionRecorder(ps, name="d")
    ps.mark("Q")
    ps.run_for(0.02, chunk_s=0.01)
    rec.capture()
    trace = rec.finalize().devices["d"]
    live_markers = list(ps.markers)
    live_block = ps.ring.latest()
    ps.close()
    assert 0 not in trace.channel_ids
    assert live_markers and live_markers[0][0] == "Q"

    rps = replay_sensor(trace)
    while not rps.device.exhausted:
        rps.poll()
    assert rps.markers == live_markers
    np.testing.assert_array_equal(rps.ring.latest().watts, live_block.watts)
    rps.close()


def test_empty_trace_with_markers_fails_loudly():
    configs = _configs(0x03)
    trace = encode_device(
        "dev", configs, "fw", np.empty(0), np.empty((0, MAX_PAIRS)),
        np.empty((0, MAX_PAIRS)), [],
    )
    a = TraceArchive()
    a.add(
        DeviceTrace(
            **{
                **trace.__dict__,
                "marker_chars": "X",
                "marker_times_us": np.array([50], dtype=np.int64),
            }
        )
    )
    with pytest.raises(ArchiveError, match="marker"):
        load_bytes(save_bytes(a))


def test_drain_finishes_a_realtime_fleet():
    from repro.replay import ReplayFleet

    trace, live_block, _ = _recorded_trace(seconds=0.03)
    archive = TraceArchive()
    archive.add(trace)
    fleet = ReplayFleet(archive, realtime=True)
    assert fleet.drain() == len(trace)  # releases the clock, no busy-wait
    np.testing.assert_array_equal(
        fleet["d"].ring.latest().watts, live_block.watts
    )
    fleet.close()


def test_replay_device_swallows_whole_config_write():
    """A set_config on a replay-backed sensor must not let the packed
    payload bytes re-parse as commands (0x53 'S'/0x58 'X' live inside
    packed float32 calibration values)."""
    trace, live_block, _ = _recorded_trace(seconds=0.02)
    ps = replay_sensor(trace)
    ps.set_config(0, trace.configs[0])  # writes b'W' + sid + 30-byte block
    assert ps.device.streaming  # payload byte 'X' must not stop the stream
    while not ps.device.exhausted:
        ps.poll()
    # and no version-string bytes were injected into the frame stream
    np.testing.assert_array_equal(ps.ring.latest().watts, live_block.watts)
    ps.close()


def test_bare_npy_payload_fails_loudly():
    buf = io.BytesIO()
    np.save(buf, np.arange(4))
    with pytest.raises(ArchiveError, match="not a ps3 trace archive"):
        TraceArchive.load(io.BytesIO(buf.getvalue()))
