"""Synthetic data pipeline: determinism, structure, stats."""
import numpy as np
import pytest

from repro.configs import smoke_config
from repro.data import PipelineStats, SyntheticTokens


def _data(**kw):
    cfg = smoke_config("qwen25_3b")
    defaults = dict(global_batch=4, seq_len=64, seed=5)
    defaults.update(kw)
    return SyntheticTokens(cfg, **defaults)


def test_shapes_and_dtype():
    d = _data()
    b = next(d)
    assert b["tokens"].shape == (4, 65)
    assert b["tokens"].dtype == np.int32


def test_random_access_equals_iteration():
    d1, d2 = _data(), _data()
    seq = [next(d1)["tokens"] for _ in range(5)]
    np.testing.assert_array_equal(seq[3], d2.batch_at(3)["tokens"])


def test_different_steps_differ():
    d = _data()
    assert not np.array_equal(d.batch_at(0)["tokens"], d.batch_at(1)["tokens"])


def test_tokens_in_vocab():
    d = _data()
    t = d.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < d.cfg.vocab_size


def test_has_document_structure():
    d = _data(seq_len=2048)
    t = d.batch_at(0)["tokens"]
    eos_frac = (t == 1).mean()
    assert 0.002 < eos_frac < 0.05  # ~1/mean_doc_len


def test_bigram_structure_learnable():
    """Successor pairs appear far above chance (the loss has signal)."""
    d = _data(seq_len=4096)
    t = d.batch_at(0)["tokens"]
    succ = d._succ
    hits = (t[:, 1:] == succ[t[:, :-1]]).mean()
    assert hits > 0.2  # ~0.5 by construction, chance ~1/vocab


def test_encdec_batch_has_frames():
    cfg = smoke_config("whisper_base")
    d = SyntheticTokens(cfg, global_batch=2, seq_len=32, seed=0)
    b = next(d)
    assert b["frames"].shape == (2, 32, cfg.d_model)


def test_pipeline_stats():
    d = _data()
    st = PipelineStats()
    for _ in range(3):
        st.observe(next(d))
    assert st.batches == 3 and st.bytes_produced > 0
