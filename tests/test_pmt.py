"""PMT meter-comparison layer: the Fig 7 phenomena."""
import numpy as np
import pytest

from repro.core.dut import GpuKernelLoad
from repro.power import (
    BuiltinCounterMeter,
    GroundTruthMeter,
    PowerSensor3Meter,
    RaplLikeMeter,
    compare_meters,
)


@pytest.fixture(scope="module")
def workload():
    """GPU-kernel-shaped trace with phase dips (the Fig 7 shape)."""
    # phase_s deliberately not a multiple of the 10 Hz builtin period —
    # a 4 ms dip has ~4% chance per dip of hitting a 10 Hz sample tick
    g = GpuKernelLoad(t_start_s=0.1, ramp_s=0.1, n_phases=5, phase_s=0.21, dip_s=0.004)
    t = np.linspace(0.0, g.t_total, 200_000)
    v, a = g.sample(t)
    return t, v * a, g


def test_ground_truth_meter(workload):
    t, w, _ = workload
    m = GroundTruthMeter().measure(t, w)
    assert m.energy_j == pytest.approx(np.trapezoid(w, t), rel=1e-9)


def test_powersensor3_energy_accuracy(workload):
    t, w, _ = workload
    m = PowerSensor3Meter(seed=1).measure(t, w)
    assert abs(m.energy_error_frac) < 0.02  # within 2% of true energy
    assert m.update_rate_hz == 20_000


def test_powersensor3_sees_interphase_dips(workload):
    """The dips between kernel phases are visible at 20 kHz (paper Fig 7a)."""
    t, w, g = workload
    m = PowerSensor3Meter(seed=2).measure(t, w)
    # second dip window
    t_dip = g.t_start_s + g.ramp_s + g.phase_s
    assert m.captures_transient(t_dip, t_dip + g.dip_s, min_samples=10)
    sel = (m.sample_times_s >= t_dip) & (m.sample_times_s < t_dip + g.dip_s)
    # measured power in the dip is clearly below the plateau
    assert m.sample_watts[sel].mean() < 0.8 * g.peak_w


def test_builtin_counter_misses_dips(workload):
    t, w, g = workload
    m = BuiltinCounterMeter(mode="instant").measure(t, w)
    t_dip = g.t_start_s + g.ramp_s + g.phase_s
    assert not m.captures_transient(t_dip, t_dip + g.dip_s, min_samples=1)


def test_builtin_average_lags_transients(workload):
    """Legacy averaged reading cannot represent the ramp (Fig 7a inset)."""
    t, w, g = workload
    inst = BuiltinCounterMeter(mode="instant").measure(t, w)
    avg = BuiltinCounterMeter(mode="average", window_s=1.0).measure(t, w)
    # during the ramp the averaged reading is far below instantaneous
    t_probe = g.t_start_s + g.ramp_s
    wi = np.interp(t_probe, inst.sample_times_s, inst.sample_watts)
    wa = np.interp(t_probe, avg.sample_times_s, avg.sample_watts)
    assert wa < 0.75 * wi


def test_builtin_energy_error_worse_than_ps3(workload):
    t, w, _ = workload
    ps3 = PowerSensor3Meter(seed=3).measure(t, w)
    avg = BuiltinCounterMeter(mode="average", window_s=1.0).measure(t, w)
    assert abs(ps3.energy_error_frac) < abs(avg.energy_error_frac)


def test_rapl_like_energy_ok_but_low_rate(workload):
    t, w, _ = workload
    m = RaplLikeMeter().measure(t, w)
    assert abs(m.energy_error_frac) < 0.02
    assert m.update_rate_hz == 1000


def test_energy_error_frac_zero_truth_is_not_perfect():
    """A zero-truth window with nonzero measured energy must report an
    unbounded error, never a perfect 0.0."""
    from repro.power import Measurement

    def meas(energy, true):
        t = np.array([0.0, 1.0])
        return Measurement("x", t, np.zeros(2), energy, true, 1.0)

    assert meas(0.0, 0.0).energy_error_frac == 0.0
    assert meas(0.5, 0.0).energy_error_frac == float("inf")
    assert meas(-0.5, 0.0).energy_error_frac == float("-inf")
    assert meas(1.1, 1.0).energy_error_frac == pytest.approx(0.1)


def test_compare_meters_returns_all(workload):
    t, w, _ = workload
    res = compare_meters(t, w)
    assert {"ground-truth", "powersensor3", "builtin-instant", "builtin-average"} <= set(res)
