"""Firmware emulation: timing arithmetic, streaming, commands, markers."""
import numpy as np
import pytest

from repro.core import protocol
from repro.core.dut import ConstantLoad, SquareWaveLoad
from repro.core.firmware import (
    CONV_US,
    FIRMWARE_VERSION,
    FRAME_US,
    SAMPLE_RATE_HZ,
    make_device,
)
from repro.core.protocol import (
    CMD_READ_CONFIG,
    CMD_START_STREAM,
    CMD_STOP_STREAM,
    CMD_VERSION,
    CONFIG_BLOCK_SIZE,
    SensorConfigBlock,
)


def test_paper_timing_arithmetic():
    # §III-B: 25 cycles @ 24 MHz = 1.04 µs; 8 ch × 6 avg = 50 µs = 20 kHz
    assert CONV_US == pytest.approx(1.0417, abs=1e-3)
    assert FRAME_US == pytest.approx(50.0, rel=1e-3)
    assert SAMPLE_RATE_HZ == pytest.approx(20_000, rel=1e-3)


def test_sample_rate_is_20khz():
    dev = make_device(["slot-10a-12v"], ConstantLoad(12.0, 1.0), seed=0)
    dev.write(CMD_START_STREAM)
    dev.advance(1.0)
    raw = dev.read()
    ids, vals, marks, _ = protocol.decode_packets(raw)
    n_frames = int(np.sum(protocol.is_timestamp(ids, marks)))
    assert n_frames == 20_000


def test_no_stream_before_start():
    dev = make_device(["slot-10a-12v"], ConstantLoad(12.0, 1.0), seed=0)
    dev.advance(0.1)
    assert dev.read() == b""


def test_stop_stream():
    dev = make_device(["slot-10a-12v"], ConstantLoad(12.0, 1.0), seed=0)
    dev.write(CMD_START_STREAM)
    dev.advance(0.01)
    dev.read()
    dev.write(CMD_STOP_STREAM)
    dev.advance(0.01)
    assert dev.read() == b""


def test_version_command():
    dev = make_device(["slot-10a-12v"], ConstantLoad(12.0, 1.0), seed=0)
    dev.write(CMD_VERSION)
    out = dev.read()
    assert out.rstrip(b"\0").decode() == FIRMWARE_VERSION


def test_config_read_write_roundtrip():
    dev = make_device(["usb-c"], ConstantLoad(20.0, 2.0), seed=0)
    dev.write(CMD_READ_CONFIG + bytes([0]))
    blk = SensorConfigBlock.unpack(dev.read(CONFIG_BLOCK_SIZE))
    assert blk.enabled and blk.type_code == 0
    blk.offset_cal = 0.123
    dev.write(protocol.CMD_WRITE_CONFIG + bytes([0]) + blk.pack())
    dev.write(CMD_READ_CONFIG + bytes([0]))
    blk2 = SensorConfigBlock.unpack(dev.read(CONFIG_BLOCK_SIZE))
    assert blk2.offset_cal == pytest.approx(0.123, rel=1e-6)


def test_frames_not_duplicated_across_advances():
    dev = make_device(["slot-10a-12v"], ConstantLoad(12.0, 1.0), seed=0)
    dev.write(CMD_START_STREAM)
    for _ in range(100):
        dev.advance(0.001)  # odd chunk sizes
    raw = dev.read()
    ids, vals, marks, _ = protocol.decode_packets(raw)
    ts = vals[protocol.is_timestamp(ids, marks)]
    unwrapped = protocol.unwrap_timestamps(ts)
    assert np.all(np.diff(unwrapped) == 50)


def test_disabled_channels_not_transmitted():
    dev = make_device(["slot-10a-12v", None, None, None], ConstantLoad(12.0, 1.0), seed=0)
    dev.write(CMD_START_STREAM)
    dev.advance(0.01)
    ids, vals, marks, _ = protocol.decode_packets(dev.read())
    data = ~protocol.is_timestamp(ids, marks)
    assert set(np.unique(ids[data])) == {0, 1}


def test_step_response_visible_at_20khz():
    """Fig 5: a 3.3 A -> 8 A step must settle within a few samples."""
    dev = make_device(
        ["slot-10a-12v"],
        SquareWaveLoad(volts=12.0, amps_lo=3.3, amps_hi=8.0, freq_hz=100.0, slew_tau_s=25e-6),
        seed=0,
    )
    dev.write(CMD_START_STREAM)
    dev.advance(0.02)  # two full periods
    ids, vals, marks, _ = protocol.decode_packets(dev.read())
    data = (~protocol.is_timestamp(ids, marks)) & (ids == 0)
    blk = dev.firmware.eeprom[0]
    amps = blk.raw_to_physical(vals[data])
    # both levels visible
    assert amps.max() > 7.0 and amps.min() < 4.3
    # transitions present: |diff| > 2 A within one sample proves 20 kHz
    assert np.max(np.abs(np.diff(amps))) > 2.0
