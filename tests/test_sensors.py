"""Sensor physics: Table I accuracy model + noise statistics."""
import numpy as np
import pytest

from repro.core.sensors import MODULE_CATALOG, SensorModule, adc_quantize, table1

# Paper Table I: (module, E_u mV, E_i A, E_p W)
TABLE1_PAPER = {
    "slot-10a-12v": (28.6, 0.35, 4.2),
    "slot-10a-3v3": (19.9, 0.35, 1.2),
    "usb-c": (28.6, 0.35, 7.0),
    "pcie8pin-20a": (28.6, 0.41, 5.0),
}


@pytest.mark.parametrize("key", list(TABLE1_PAPER))
def test_table1_matches_paper(key):
    spec = MODULE_CATALOG[key]
    eu, ei, ep = TABLE1_PAPER[key]
    assert spec.voltage_error * 1e3 == pytest.approx(eu, rel=0.02)
    assert spec.current_error == pytest.approx(ei, rel=0.03)
    assert spec.power_error == pytest.approx(ep, rel=0.05)


def test_table1_report_has_all_modules():
    rows = table1()
    assert {r["module"] for r in rows} >= set(TABLE1_PAPER)


def test_current_sensitivity_maps_full_scale():
    spec = MODULE_CATALOG["slot-10a-12v"]
    # +10 A must land at vref (full scale), -10 A at 0
    assert spec.current_sensitivity * spec.max_amps == pytest.approx(3.3 / 2)


def test_hall_noise_statistics():
    mod = SensorModule(MODULE_CATALOG["slot-10a-12v"], seed=3)
    rng = np.random.default_rng(0)
    amps = np.zeros(200_000)
    pins = mod.current_pin_volts(amps, rng)
    # std of pin voltage = sensitivity * hall noise rms
    measured = pins.std() / mod.spec.current_sensitivity
    assert measured == pytest.approx(mod.spec.hall_noise_arms, rel=0.02)


def test_manufacturing_offset_is_deterministic_per_seed():
    a = SensorModule(MODULE_CATALOG["usb-c"], seed=7)
    b = SensorModule(MODULE_CATALOG["usb-c"], seed=7)
    c = SensorModule(MODULE_CATALOG["usb-c"], seed=8)
    assert a.hall_offset_amps == b.hall_offset_amps
    assert a.hall_offset_amps != c.hall_offset_amps


def test_adc_quantize_clips_and_rounds():
    np.testing.assert_array_equal(adc_quantize(np.array([-1.0, 0.0, 3.3, 99.0])), [0, 0, 1023, 1023])
    assert adc_quantize(3.3 / 1023 * 100.4) == 100


def test_power_error_formula():
    # E_p = sqrt((U Ei)^2 + (I Eu)^2 + (Ei Eu)^2), paper §III-A
    spec = MODULE_CATALOG["slot-10a-12v"]
    ei, eu = spec.current_error, spec.voltage_error
    expect = np.sqrt((12.0 * ei) ** 2 + (10.0 * eu) ** 2 + (ei * eu) ** 2)
    assert spec.power_error == pytest.approx(expect, rel=1e-9)
