"""Tests for the closed-loop energy governor + energy-SLO scheduler."""
import math

import numpy as np
import pytest

from repro.power import DEFAULT_LADDER, V5E, DvfsLadder, phases_for_step
from repro.sched import (
    EnergyPricer,
    EnergySloScheduler,
    GovernorConfig,
    OperatingGrid,
    PiController,
    PowerCapGovernor,
    Request,
    SampledPowerReader,
    SchedContext,
    VirtualPlant,
    compare_policies,
    decode_cost_of_batch,
    get_policy,
    settle_time,
    time_over_cap,
)

N_PARAMS = 40e6


def make_grid(chunk=8, batches=(1, 2, 4, 8, 16, 32)):
    return OperatingGrid(
        decode_cost_of_batch(2.0 * N_PARAMS, 2.0 * N_PARAMS, tokens_per_slot_step=chunk),
        n_layers=4,
        batches=batches,
        tokens_per_slot_step=chunk,
    )


# ------------------------------------------------------------------- ladder
def test_dvfs_ladder_sorted_clamped_and_nearest():
    lad = DvfsLadder(scales=(1.0, 0.5, 0.75))
    assert lad.scales == (0.5, 0.75, 1.0)
    assert lad.clamp(-3) == 0 and lad.clamp(99) == len(lad) - 1
    assert lad.state(len(lad) + 5).scale == 1.0
    assert lad.nearest(0.70) == 1
    with pytest.raises(ValueError):
        DvfsLadder(scales=())
    with pytest.raises(ValueError):
        DvfsLadder(scales=(0.0, 1.0))


def test_dvfs_ladder_states_monotone_power_factor():
    pf = [s.power_factor for s in DEFAULT_LADDER.states()]
    assert all(b > a for a, b in zip(pf, pf[1:]))


# --------------------------------------------------------------------- grid
def test_grid_has_idle_floor_and_unbounded_top():
    grid = make_grid()
    assert grid.idle.batch == 0
    assert grid.idle.watts == pytest.approx(V5E.p_static)
    top = grid.best_under(math.inf)
    assert top.tokens_per_s == max(p.tokens_per_s for p in grid.points)
    assert grid.best_under(V5E.p_static) is grid.idle


def test_grid_best_under_monotone_in_budget():
    grid = make_grid()
    budgets = np.linspace(V5E.p_static, grid.max_watts + 10.0, 40)
    last_tps = -1.0
    for b in budgets:
        p = grid.best_under(float(b))
        assert p.watts <= b + 1e-9
        assert p.tokens_per_s >= last_tps - 1e-9
        last_tps = p.tokens_per_s


def test_grid_respects_max_batch_and_demand_zero():
    grid = make_grid()
    p = grid.best_under(math.inf, max_batch=4)
    assert 0 < p.batch <= 4
    assert grid.best_under(math.inf, max_batch=0) is grid.idle


def test_grid_next_above_and_below_walk_the_frontier():
    grid = make_grid()
    # climb from idle: strictly increasing watts AND tokens/s, ends at top
    pt = grid.idle
    seen = 0
    while True:
        up = grid.next_above(pt)
        if up is None:
            break
        assert up.watts > pt.watts and up.tokens_per_s > pt.tokens_per_s
        pt, seen = up, seen + 1
    assert pt.tokens_per_s == grid.best_under(math.inf).tokens_per_s
    assert seen >= 3
    # one rung down from the top is strictly cheaper
    down = grid.next_below(pt)
    assert down is not None and down.watts < pt.watts
    assert grid.next_below(grid.idle) is None


def test_grid_power_of_batch_increases_with_batch():
    grid = make_grid()
    assert grid.power_of_batch(32) > grid.power_of_batch(1) > V5E.p_static


# ----------------------------------------------------------------------- pi
def test_pi_integrator_clamps_at_bounds():
    pi = PiController(kp=1.0, ki=10.0, i_lo=-5.0, i_hi=5.0)
    for _ in range(1000):
        pi.update(100.0, 0.01)
    assert pi.integral == pytest.approx(5.0)
    for _ in range(1000):
        pi.update(-100.0, 0.01)
    assert pi.integral == pytest.approx(-5.0)


def test_pi_conditional_antiwindup_freezes_into_saturation():
    pi = PiController(kp=0.0, ki=10.0, i_lo=-50.0, i_hi=50.0)
    pi.update(1.0, 0.1)
    frozen = pi.integral
    # pinned at full throttle and still asking for more: freeze
    pi.update(1.0, 0.1, saturated_hi=True)
    assert pi.integral == frozen
    # error reversing direction integrates even while saturated
    pi.update(-1.0, 0.1, saturated_hi=True)
    assert pi.integral < frozen


def test_sampled_reader_holds_between_updates():
    calls = []

    def read(now):
        calls.append(now)
        return float(len(calls))

    r = SampledPowerReader(read, rate_hz=10.0)
    assert r(0.0) == 1.0
    assert r(0.05) == 1.0  # held: next refresh not due until 0.1
    assert r(0.099) == 1.0
    assert r(0.1) == 2.0
    assert len(calls) == 2
    with pytest.raises(ValueError):
        SampledPowerReader(read, rate_hz=0.0)


# ------------------------------------------------------------------ metrics
def test_time_over_cap_and_settle_metrics_on_synthetic_log():
    log = [(0.0, 100.0), (1.0, 250.0), (1.5, 180.0), (3.0, 230.0), (3.5, 190.0)]
    cap = 200.0
    # over cap on [1.0, 1.5) and [3.0, 3.5) out of [0, 4): 1.0 / 4.0
    assert time_over_cap(log, cap, 0.0, 4.0, tol=0.0) == pytest.approx(0.25)
    # with a 30% band nothing is over
    assert time_over_cap(log, cap, 0.0, 4.0, tol=0.30) == 0.0
    # last excursion after the step at t=1 ends at 3.5
    assert settle_time(log, cap, 1.0, 4.0, tol=0.0) == pytest.approx(2.5)
    # never over after 3.6
    assert settle_time(log, cap, 3.6, 4.0, tol=0.0) == 0.0
    # still over at run end counts as the full remainder
    assert settle_time(log[:4], cap, 3.0, 4.0, tol=0.0) == pytest.approx(1.0)


# ------------------------------------------------------- governor, unit-ish
def _run_loop(grid, cap_w, rate_hz=None, duration=0.4, t_step=0.12, seed=1,
              biases=(1.12, 0.94), calibrate_samples=4000):
    plant = VirtualPlant(
        grid, n_devices=len(biases), biases=list(biases), seed=seed,
        calibrate_samples=calibrate_samples,
    )
    cfg = GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0)
    reader = None
    if rate_hz is not None:
        reader = SampledPowerReader(
            lambda now: plant.fleet.window_power_w(cfg.window_s), rate_hz
        )
    gov = PowerCapGovernor(plant, cfg, read_power=reader)
    gov.run(duration, demand_of_t=lambda t: 0 if t < t_step else 32)
    toc = time_over_cap(plant.log, cap_w, 0.0, duration, tol=0.02)
    settle = settle_time(plant.log, cap_w, t_step, duration, tol=0.02)
    return plant, gov, toc, settle


def test_governor_holds_cap_after_load_step():
    grid = make_grid()
    cap = 0.72 * 2 * grid.max_watts
    plant, gov, toc, settle = _run_loop(grid, cap)
    try:
        assert toc < 0.05, f"time over cap {toc:.1%}"
        assert settle < 0.100, f"settle {settle * 1e3:.0f} ms"
        # converged somewhere useful: above idle, at/below the band ceiling
        assert 2 * V5E.p_static < plant.true_fleet_w <= cap * 1.02
        assert plant.point.batch > 0
    finally:
        plant.close()


def test_governor_does_not_oscillate_at_steady_state():
    grid = make_grid()
    cap = 0.72 * 2 * grid.max_watts
    plant, gov, toc, settle = _run_loop(grid, cap)
    try:
        # no actuation churn after the loop settles (+ one dwell of slack)
        t_quiet = 0.12 + settle + 2 * gov.cfg.min_dwell_s
        late_switches = [s for s in gov.history if s.time_s > t_quiet and s.switched]
        assert len(late_switches) <= 1, [s.time_s for s in late_switches]
    finally:
        plant.close()


def test_governor_parks_at_idle_when_demand_drops():
    grid = make_grid()
    cap = 0.72 * 2 * grid.max_watts
    plant = VirtualPlant(grid, n_devices=2, biases=[1.0, 1.0], seed=3,
                         calibrate_samples=0)
    gov = PowerCapGovernor(plant, GovernorConfig(cap_w=cap, kp=0.15, ki=80.0))
    try:
        gov.run(0.35, demand_of_t=lambda t: 32 if t < 0.2 else 0)
        assert plant.point is grid.idle
        assert plant.true_fleet_w == pytest.approx(2 * V5E.p_static)
    finally:
        plant.close()


def test_governor_builtin_rate_telemetry_violates_cap():
    grid = make_grid()
    cap = 0.72 * 2 * grid.max_watts
    plant, gov, toc, settle = _run_loop(grid, cap, rate_hz=10.0)
    try:
        # the same controller on 10 Hz sample-and-hold demonstrably fails
        assert toc > 0.05 or settle > 0.100, (toc, settle)
    finally:
        plant.close()


def test_governor_faster_telemetry_is_never_worse():
    grid = make_grid()
    cap = 0.72 * 2 * grid.max_watts
    p20, _, toc20, settle20 = _run_loop(grid, cap)
    p10, _, toc10, settle10 = _run_loop(grid, cap, rate_hz=10.0)
    p20.close()
    p10.close()
    assert toc20 <= toc10 + 1e-9
    assert settle20 <= settle10 + 1e-9


def test_virtual_plant_bias_and_log_bookkeeping():
    grid = make_grid()
    plant = VirtualPlant(grid, n_devices=2, biases=[1.2, 0.8], seed=0,
                         calibrate_samples=0)
    try:
        top = grid.best_under(math.inf)
        plant.apply(top, 1.0)
        w = plant.true_device_watts(top)
        dyn = top.watts - V5E.p_static
        assert w[0] == pytest.approx(V5E.p_static + 1.2 * dyn)
        assert w[1] == pytest.approx(V5E.p_static + 0.8 * dyn)
        assert plant.log[-1] == (1.0, pytest.approx(sum(w)))
        with pytest.raises(ValueError):
            VirtualPlant(grid, n_devices=3, biases=[1.0], calibrate_samples=0)
    finally:
        plant.close()


# ------------------------------------------------------------------- pricer
def test_pricer_from_phases_and_correction_converges():
    phases = phases_for_step(
        decode_cost_of_batch(2.0 * N_PARAMS, 2.0 * N_PARAMS)(4), n_layers=4
    )
    pricer = EnergyPricer.from_phases(phases, V5E, tokens_per_step=4)
    step_j = sum(p.power(V5E) * p.duration_s for p in phases)
    assert pricer.price_tokens(4) == pytest.approx(step_j)
    # reality runs 30% hot: the EWMA walks the correction toward 1.3
    for _ in range(40):
        pricer.update(tokens=4, measured_j=1.3 * step_j)
    assert pricer.correction == pytest.approx(1.3, rel=1e-3)
    assert pricer.price_tokens(4) == pytest.approx(1.3 * step_j, rel=1e-3)


def test_pricer_from_ledger_and_signatures():
    from repro.attrib import EnergyLedger, KernelSpan, build_library

    ledger = EnergyLedger()
    ledger.add_occurrence("decode", energy_j=2.0, duration_s=1.0, peak_w=3.0)
    p = EnergyPricer.from_ledger(ledger, tokens=100)
    assert p.j_per_token == pytest.approx(0.02)

    # per-kernel signatures: two kernels whose mean_w x duration sum to the
    # step energy
    t = np.linspace(0.0, 1.0, 2001)
    w = np.where(t < 0.4, 100.0, 50.0)
    lib = build_library(t, w, [KernelSpan("a", 0.0, 0.4), KernelSpan("b", 0.4, 1.0)])
    p2 = EnergyPricer.from_signatures(lib, tokens_per_step=10)
    expected = (100.0 * 0.4 + 50.0 * 0.6) / 10.0
    assert p2.j_per_token == pytest.approx(expected, rel=0.02)
    with pytest.raises(ValueError):
        EnergyPricer.from_ledger(ledger, tokens=0)


# ---------------------------------------------------------------- scheduler
def _fill(sched, n=8, gen=10, clients=2):
    for rid in range(n):
        sched.submit(Request(rid=rid, client=f"c{rid % clients}", gen_len=gen))


def test_scheduler_accounting_sums_to_wave_ledgers():
    sched = EnergySloScheduler(
        EnergyPricer(j_per_token=0.5), get_policy("throughput-max"), max_batch=3
    )
    _fill(sched, n=8, gen=10)
    measured = [7.31, 6.02, 5.555]
    k = 0
    while True:
        wave = sched.next_wave()
        if wave is None:
            break
        sched.complete_wave(sched.waves[-1].index, 10)
        sched.reconcile(sched.waves[-1].index, measured[k])
        k += 1
    assert k == 3
    rows = sched.report_rows()
    # SLO invariant: per-request measured J sums exactly to the ledger totals
    assert sum(r["measured_j"] for r in rows) == pytest.approx(sum(measured), abs=1e-12)
    assert sum(sched.client_energy_j.values()) == pytest.approx(sum(measured), abs=1e-12)
    per_wave = [sum(r["measured_j"] for r in rows if r["rid"] in w.rids)
                for w in sched.waves]
    for got, want in zip(per_wave, measured):
        assert got == pytest.approx(want, abs=1e-12)
    assert all(r["finished"] for r in rows)
    assert sched.unreconciled() == []


def test_scheduler_budget_admission_and_rejection():
    # budget covers exactly 4 of 8 identical requests
    sched = EnergySloScheduler(
        EnergyPricer(j_per_token=1.0), get_policy("throughput-max"),
        max_batch=2, budget_j=4.0 * 10.0,
    )
    _fill(sched, n=8, gen=10)
    served = []
    while True:
        wave = sched.next_wave()
        if wave is None:
            break
        served.extend(r.rid for r in wave)
        sched.complete_wave(sched.waves[-1].index, 10)
        sched.reconcile(sched.waves[-1].index, 10.0 * len(wave))
    assert len(served) == 4
    assert len(sched.rejected) == 4
    assert sched.spent_j == pytest.approx(40.0)
    assert sched.remaining_budget_j == pytest.approx(0.0)


def test_scheduler_reconcile_lags_and_double_reconcile_raises():
    sched = EnergySloScheduler(
        EnergyPricer(j_per_token=0.1), get_policy("throughput-max"), max_batch=4
    )
    _fill(sched, n=8, gen=5)
    w0 = sched.next_wave()
    w1 = sched.next_wave()
    assert w0 is not None and w1 is not None
    sched.complete_wave(0, 5)
    sched.complete_wave(1, 5)
    assert sched.unreconciled() == [0, 1]
    sched.reconcile(1, 2.0)  # out of order is fine
    sched.reconcile(0, 3.0)
    with pytest.raises(ValueError):
        sched.reconcile(0, 1.0)
    assert sched.spent_j == pytest.approx(5.0)


def test_scheduler_reconcile_feeds_pricer_correction():
    pricer = EnergyPricer(j_per_token=1.0, alpha=1.0)  # no smoothing
    sched = EnergySloScheduler(pricer, get_policy("throughput-max"), max_batch=4)
    _fill(sched, n=4, gen=10)
    sched.next_wave()
    sched.complete_wave(0, 10)
    sched.reconcile(0, measured_j=60.0)  # 40 tokens predicted at 40 J
    assert pricer.correction == pytest.approx(1.5)
    # the *next* admission is re-priced with the correction
    sched.submit(Request(rid=99, gen_len=10))
    assert sched.queue[-1].predicted_j == pytest.approx(15.0)


# ----------------------------------------------------------------- policies
def test_policy_registry_and_unknown_name():
    for name in ("throughput-max", "cap-strict", "energy-fair"):
        assert get_policy(name).name == name
    with pytest.raises(ValueError):
        get_policy("nope")


def test_cap_strict_limits_batch_to_cap():
    pol = get_policy("cap-strict")
    ctx = SchedContext(
        max_batch=8, remaining_budget_j=math.inf, cap_w=150.0,
        power_of_batch=lambda b: 80.0 + 15.0 * b,
    )
    # 80 + 15b <= 150 -> b <= 4
    assert pol.batch_limit([], ctx) == 4
    # cap below batch-1 power still admits one slot (progress guarantee)
    ctx2 = SchedContext(
        max_batch=8, remaining_budget_j=math.inf, cap_w=50.0,
        power_of_batch=lambda b: 80.0 + 15.0 * b,
    )
    assert pol.batch_limit([], ctx2) == 1
    # no power model: no limiting
    ctx3 = SchedContext(max_batch=8, remaining_budget_j=math.inf)
    assert pol.batch_limit([], ctx3) == 8


def test_energy_fair_orders_starved_client_first():
    pol = get_policy("energy-fair")
    queue = [
        Request(rid=0, client="hog", gen_len=1),
        Request(rid=1, client="hog", gen_len=1),
        Request(rid=2, client="starved", gen_len=1),
    ]
    ctx = SchedContext(
        max_batch=2, remaining_budget_j=math.inf,
        client_energy_j={"hog": 100.0, "starved": 1.0},
    )
    order = pol.order(queue, ctx)
    assert order[0] == 2  # the starved client's request leads
    assert sorted(order) == [0, 1, 2]


def test_policy_ranking_stable_across_seeds():
    cap = 150.0
    spreads_tm, spreads_ef = [], []
    for seed in (0, 1, 2):
        scores = compare_policies(
            n_requests=48, n_clients=3, max_batch=8, cap_w=cap,
            budget_frac=0.5, seed=seed,
        )
        tm, cs, ef = (
            scores["throughput-max"], scores["cap-strict"], scores["energy-fair"]
        )
        # structural, per-seed: batch-limited cap-strict never out-serves
        assert tm.tokens_per_s >= cs.tokens_per_s - 1e-9
        # cap-strict never schedules a wave modelled over the cap
        assert cs.peak_wave_w <= cap + 1e-9
        assert tm.peak_wave_w > cap  # the baseline does
        assert all(s.waves > 0 for s in (tm, cs, ef))
        spreads_tm.append(tm.fairness_spread_j)
        spreads_ef.append(ef.fairness_spread_j)
    # fairness is statistical, not per-draw (a FIFO arrival order can be
    # accidentally balanced): over the seed ensemble, energy-fair spreads
    # the scarce budget across clients far more evenly than FIFO
    assert sum(spreads_ef) < 0.6 * sum(spreads_tm), (spreads_ef, spreads_tm)


def test_complete_wave_clamps_credit_at_gen_len():
    sched = EnergySloScheduler(
        EnergyPricer(j_per_token=1.0), get_policy("throughput-max"), max_batch=2
    )
    sched.submit(Request(rid=0, client="a", gen_len=4))
    sched.submit(Request(rid=1, client="b", gen_len=16))
    wave = sched.next_wave()
    assert len(wave) == 2
    sched.complete_wave(0, 16)  # ragged: decoded to the longest request
    w = sched.waves[0]
    assert w.request_tokens == [4, 16]  # short request NOT over-credited
    assert w.tokens == 20
    assert w.decoded_tokens == 32  # 2 slots x 16 steps actually ran
    sched.reconcile(0, 10.0)
    rows = {r["rid"]: r for r in sched.report_rows()}
    assert rows[0]["tokens"] == 4 and rows[1]["tokens"] == 16
    # energy split follows the clamped token shares, summing exactly
    assert rows[0]["measured_j"] == pytest.approx(10.0 * 4 / 20)
    assert rows[1]["measured_j"] == pytest.approx(10.0 * 16 / 20)
    # pricer ratio uses the decoded (padded) tokens: 10 J / 32 tokens
    assert sched.pricer.correction < 1.0


def test_release_wave_settles_commitment_without_pricer_update():
    pricer = EnergyPricer(j_per_token=1.0, alpha=1.0)
    sched = EnergySloScheduler(
        pricer, get_policy("throughput-max"), max_batch=2, budget_j=100.0
    )
    _fill(sched, n=2, gen=10)
    sched.next_wave()
    sched.complete_wave(0, 10)
    assert sched.committed_j == pytest.approx(20.0)
    sched.release_wave(0)  # e.g. ring evicted the span: unmeasurable
    assert sched.committed_j == pytest.approx(0.0)
    assert sched.spent_j == pytest.approx(20.0)  # charged at prediction
    assert sched.waves[0].released
    assert pricer.n_updates == 0  # a guess must not train the pricer
    assert sum(r.measured_j for r in sched.finished) == pytest.approx(20.0)
    with pytest.raises(ValueError):
        sched.release_wave(0)


def test_next_wave_keeps_queue_when_blocked_by_commitments():
    # budget fits both requests, but only one wave can be in flight at once
    sched = EnergySloScheduler(
        EnergyPricer(j_per_token=1.0), get_policy("throughput-max"),
        max_batch=1, budget_j=15.0,
    )
    _fill(sched, n=2, gen=10, clients=1)
    w0 = sched.next_wave()
    assert w0 is not None
    # in-flight commitment (10 J) blocks the second request (10 J > 5 left)
    assert sched.next_wave() is None
    assert len(sched.queue) == 1  # NOT rejected: it fits once wave 0 settles
    assert sched.rejected == []
    sched.complete_wave(0, 10)
    sched.reconcile(0, 4.0)  # ran cheaper than predicted
    w1 = sched.next_wave()  # commitment released: admissible now
    assert w1 is not None and w1[0].rid == 1
    # truly hopeless requests (over the spent-adjusted budget alone) DO go
    sched.complete_wave(1, 10)
    sched.reconcile(1, 4.0)  # spent 8 of 15; correction EWMA is now < 1
    hopeless_gen = int(10.0 / sched.pricer.price_tokens(1)) + 1
    sched.submit(Request(rid=9, client="c0", gen_len=hopeless_gen))
    assert sched.next_wave() is None
    assert [r.rid for r in sched.rejected] == [9]


# -------------------------------------------------- continuous batch (step)
def _batch(n_slots=4, budget=math.inf, policy="throughput-max", **kw):
    from repro.sched import ContinuousBatch

    return ContinuousBatch(
        EnergyPricer(j_per_token=1.0),
        get_policy(policy),
        n_slots=n_slots,
        budget_j=budget,
        **kw,
    )


def test_continuous_batch_admits_mid_run_and_bills_only_real_tokens():
    sched = _batch(n_slots=2)
    sched.submit(Request(rid=0, client="a", gen_len=3))
    assert [r.rid for (_, r) in sched.admit(0.0)] == [0]
    # 2-slot compiled batch, 1 live request: padded slot decodes, never bills
    rec = sched.step_billing(1, decoded_slots=2)
    assert list(rec.rids) == [0]
    assert rec.billed_tokens == 1 and rec.decoded_tokens == 2
    sched.submit(Request(rid=1, client="b", gen_len=2))
    slots = sched.admit(0.01)  # joins the live batch mid-decode
    assert [r.rid for (_, r) in slots] == [1]
    assert sched.n_active == 2
    rec = sched.step_billing(1)
    assert sorted(rec.rids) == [0, 1] and rec.billed_tokens == 2
    for _ in range(2):
        sched.step_billing(1)
    # rid 1 (gen 2) finished at step 3; rid 0 (gen 3) at step 4
    iv = sched.seal_interval()
    assert iv is not None
    assert iv.occupancy == {0: 3, 1: 2}
    assert sched.n_active == 0 and len(sched.finished) == 2
    sched.settle_interval(iv.index, 10.0)
    rows = {r["rid"]: r for r in sched.report_rows()}
    # settled energy splits by per-interval token share, summing exactly
    assert rows[0]["measured_j"] == pytest.approx(10.0 * 3 / 5)
    assert rows[1]["measured_j"] == pytest.approx(10.0 * 2 / 5)
    assert sched.billed_j + sched.overhead_j == pytest.approx(sched.spent_j)


def test_continuous_batch_retire_requeue_and_empty_interval_overhead():
    sched = _batch(n_slots=2)
    sched.submit(Request(rid=0, client="a", gen_len=4))
    sched.submit(Request(rid=1, client="a", gen_len=4))
    sched.admit(0.0)
    sched.step_billing(1)
    sched.retire(0, requeue=True)  # preempted: tokens keep, back to queue
    assert [r.rid for r in sched.queue] == [0]
    assert sched.queue[0].done_tokens == 1
    sched.retire(1)  # evicted outright
    assert [r.rid for r in sched.evicted] == [1]
    iv = sched.seal_interval()
    sched.settle_interval(iv.index, 4.0)
    # settled energy for the part-run interval still lands somewhere real
    assert sched.billed_j + sched.overhead_j == pytest.approx(4.0)
    # an interval with zero live occupancy settles entirely to overhead
    sched.admit(0.0)
    sched.step_billing(1)
    sched.retire(0)
    empty = sched.seal_interval()
    before = sched.overhead_j
    # interval had rid 0's tokens; next interval with no one is impossible
    # to seal (no steps), so assert the API refuses instead
    assert sched.seal_interval() is None
    sched.settle_interval(empty.index, 2.0)
    assert sched.overhead_j >= before


def test_continuous_batch_budget_commitment_and_hopeless_rejection():
    sched = _batch(n_slots=2, budget=10.0)
    sched.submit(Request(rid=0, client="a", gen_len=6))
    sched.submit(Request(rid=1, client="a", gen_len=6))
    sched.admit(0.0)
    # only one fits the 10 J budget at 1 J/token; the other is NOT hopeless
    # (6 J fits once the first settles cheap), so it stays queued
    assert sched.n_active == 1
    assert len(sched.queue) == 1 and sched.rejected == []
    assert sched.committed_j == pytest.approx(6.0)
    # a hopeless request (over the whole budget) is NOT rejected while a
    # commitment is pending resolution — rejection waits for settled truth
    sched.submit(Request(rid=2, client="b", gen_len=99))
    sched.admit(0.0)
    assert sched.rejected == []
    for _ in range(6):
        sched.step_billing(1, decoded_slots=1)
    assert sched.committed_j == pytest.approx(0.0)  # moved to inflight
    assert sched.inflight_j == pytest.approx(6.0)
    iv = sched.seal_interval()
    sched.settle_interval(iv.index, 3.0)  # ran cheaper than predicted
    assert sched.inflight_j == pytest.approx(0.0)
    assert sched.spent_j == pytest.approx(3.0)
    admitted = sched.admit(0.0)  # 7 J now free: rid 1 admits
    assert [r.rid for (_, r) in admitted] == [1]
    # ...and with no commitments shielding it, rid 2 would now be culled
    # once nothing else fits; drain rid 1 and ask again
    for _ in range(6):
        sched.step_billing(1, decoded_slots=1)
    iv = sched.seal_interval()
    sched.settle_interval(iv.index, 3.0)
    sched.admit(0.0)
    assert [r.rid for r in sched.rejected] == [2]


def test_continuous_batch_release_interval_charges_prediction():
    sched = _batch(n_slots=2)
    pricer = sched.pricer
    sched.submit(Request(rid=0, client="a", gen_len=2))
    sched.admit(0.0)
    sched.step_billing(1)
    sched.step_billing(1)
    iv = sched.seal_interval()
    assert sched.unsettled() == [iv.index]
    sched.release_interval(iv.index)  # ring evicted: unmeasurable
    assert sched.unsettled() == []
    assert sched.intervals[iv.index].released
    assert sched.spent_j == pytest.approx(iv.predicted_j)
    assert pricer.n_updates == 0  # a guess must not train the pricer
    with pytest.raises(ValueError):
        sched.release_interval(iv.index)
    with pytest.raises(ValueError):
        sched.settle_interval(iv.index, 1.0)


def test_compare_policies_churn_all_policies_finish_and_cap_holds():
    cap = 80.0 + 15.0 * 5  # full 8-batch would model over the cap
    scores = compare_policies(
        n_requests=24, max_batch=8, cap_w=cap, seed=3, churn=True,
        arrival_spread_s=0.05, steps_per_interval=4,
    )
    tm, cs, ef = (
        scores["throughput-max"], scores["cap-strict"], scores["energy-fair"]
    )
    for s in (tm, cs, ef):
        assert s.finished == 24
        assert s.waves > 0  # sealed step intervals
        assert s.tokens_per_s > 0 and math.isfinite(s.j_per_token)
    # cap-strict bounds the *live step* power under churn, not just waves
    assert cs.peak_wave_w <= cap + 1e-9
    assert tm.peak_wave_w > cap
    # step intervals are strictly finer than the serial waves would be
    wave_scores = compare_policies(n_requests=24, max_batch=8, cap_w=cap, seed=3)
    assert cs.waves > wave_scores["cap-strict"].waves


def test_compare_policies_churn_flag_leaves_wave_path_byte_identical():
    # churn arrivals are drawn after the shared rng draws, so the default
    # executor must produce the identical scores it always did
    a = compare_policies(n_requests=12, seed=7)
    b = compare_policies(n_requests=12, seed=7, churn=False)
    assert a == b


def test_continuous_batch_billing_conserves_over_random_churn():
    """Property: across random occupancy patterns — staggered arrivals,
    random per-step token counts, evictions, requeues, released intervals
    — per-request billed joules plus unbilled overhead reproduce the
    settled ledger total exactly (1e-12-grade, like the split tests)."""
    from repro.sched import ContinuousBatch

    for seed in range(12):
        rng = np.random.default_rng(seed)
        sched = ContinuousBatch(
            EnergyPricer(j_per_token=float(rng.uniform(0.5, 2.0))),
            get_policy("throughput-max"),
            n_slots=int(rng.integers(2, 5)),
        )
        pending = [
            Request(
                rid=rid,
                client=f"c{rid % 3}",
                gen_len=int(rng.integers(1, 9)),
                arrival_s=float(rng.uniform(0.0, 0.05)),
            )
            for rid in range(int(rng.integers(4, 10)))
        ]
        pending.sort(key=lambda r: r.arrival_s)
        expected_spent = 0.0
        now, guard = 0.0, 0
        while (pending or sched.queue or sched.live_rids) and guard < 400:
            guard += 1
            while pending and pending[0].arrival_s <= now:
                sched.submit(pending.pop(0))
            sched.admit(now)
            if not sched.live_rids:
                now = pending[0].arrival_s if pending else now + 1e-3
                continue
            for _ in range(int(rng.integers(1, 4))):
                if not sched.live_rids:
                    break
                sched.step_billing(int(rng.integers(1, 3)))
                if sched.live_rids and rng.random() < 0.15:
                    victim = int(rng.choice(sched.live_rids))
                    sched.retire(victim, requeue=bool(rng.random() < 0.5))
                now += 1e-3
            iv = sched.seal_interval()
            if iv is None:
                continue
            if rng.random() < 0.25:
                sched.release_interval(iv.index)
                expected_spent += iv.predicted_j
            else:
                measured = float(rng.uniform(0.1, 5.0))
                sched.settle_interval(iv.index, measured)
                expected_spent += measured
        assert guard < 400, f"seed {seed}: executor did not converge"
        assert sched.unsettled() == []
        assert sched.spent_j == pytest.approx(expected_spent, abs=1e-9)
        rows_j = sum(r["measured_j"] for r in sched.report_rows())
        assert rows_j == pytest.approx(sched.billed_j, abs=1e-9)
        # the conservation invariant, at residue-splitting precision
        assert abs(sched.billed_j + sched.overhead_j - sched.spent_j) < 1e-9
        assert sched.billed_j >= -1e-12 and sched.overhead_j >= -1e-12
