"""Signature watchdog and part-time-sampler baseline over live fleets.

A shrunk version of the ``benchmarks/obs_overhead`` watchdog scenario:
two devices replay the same serve step (gap/A/gap/B/gap/C) and one runs
a single occurrence of kernel B at 1.5x power.  The 20 kHz watchdog must
flag exactly that window, stay quiet on the clean device, and the 10 Hz
`PartTimeSampler` must miss the excursion entirely.  Degraded-telemetry
semantics (stale devices skipped, cursor frozen) and the sampler's unit
behaviour are pinned separately.
"""
import numpy as np
import pytest

from repro import obs
from repro.attrib.attribute import KernelSpan
from repro.attrib.signatures import SignatureLibrary, build_library
from repro.core import ConstantLoad
from repro.core.dut import TraceLoad
from repro.obs.trace import DEVICE
from repro.obs.watch import Anomaly, PartTimeSampler, SignatureWatchdog
from repro.stream import make_virtual_fleet

STEP_PATTERN = [
    ("gap", 4e-3, 40.0),
    ("A", 6e-3, 80.0),
    ("gap", 4e-3, 40.0),
    ("B", 8e-3, 150.0),
    ("gap", 4e-3, 40.0),
    ("C", 6e-3, 110.0),
]
STEP_S = sum(d for _, d, _ in STEP_PATTERN)  # 32 ms
N_STEPS = 14
WARM_STEPS = 4
TAMPER_STEP = 9
TAMPER_FACTOR = 1.5


def _pattern_arrays(n_steps, tamper_step=None):
    eps = 1e-6
    ts, ws = [0.0], [STEP_PATTERN[0][2]]
    t = 0.0
    for k in range(n_steps):
        for name, dur, w in STEP_PATTERN:
            if k == tamper_step and name == "B":
                w *= TAMPER_FACTOR
            ts += [t + eps, t + dur]
            ws += [w, w]
            t += dur
    return np.asarray(ts), np.asarray(ws)


def _tamper_window():
    offs = 0.0
    for name, dur, _ in STEP_PATTERN:
        if name == "B":
            break
        offs += dur
    t0 = TAMPER_STEP * STEP_S + offs
    return t0, t0 + dict((n, d) for n, d, _ in STEP_PATTERN)["B"]


@pytest.fixture(scope="module")
def scenario():
    """Run the two-device tamper scenario once; share the outcome."""
    obs.disable()
    rec, reg = obs.enable()
    clean_t, clean_w = _pattern_arrays(N_STEPS)
    tamp_t, tamp_w = _pattern_arrays(N_STEPS, tamper_step=TAMPER_STEP)
    fleet = make_virtual_fleet(
        [TraceLoad(times_s=clean_t, watts=clean_w),
         TraceLoad(times_s=tamp_t, watts=tamp_w)],
        ring_capacity=1 << 16,
    )
    try:
        warm_s = WARM_STEPS * STEP_S
        fleet.advance(warm_s)
        block = fleet["dev0"].ring.window(0.0, warm_s)
        spans = []
        for k in range(WARM_STEPS):
            t = k * STEP_S
            for name, dur, _ in STEP_PATTERN:
                spans.append(KernelSpan(name, t, t + dur))
                t += dur
        lib = build_library(block.times_s, block.total_watts, spans)

        dog = SignatureWatchdog(fleet, lib)
        dog.check()  # attach cursors
        sampler = PartTimeSampler(
            lambda t: float(np.interp(t, tamp_t, tamp_w)), rate_hz=10.0
        )
        now, total_s = warm_s, N_STEPS * STEP_S
        while now < total_s - 1e-9:
            step = min(2 * STEP_S, total_s - now)
            fleet.advance(step)
            now += step
            sampler.poll(now)
            dog.check()
        # no new ring data: repeated checks must not re-raise anomalies
        idle_news = [dog.check(), dog.check()]
    finally:
        fleet.close()
        obs.disable()
    return dict(dog=dog, sampler=sampler, rec=rec, reg=reg,
                idle_news=idle_news)


def test_watchdog_flags_tampered_kernel(scenario):
    t0, t1 = _tamper_window()
    dog = scenario["dog"]
    hits = [a for a in dog.anomalies
            if a.device == "dev1" and a.t0_s < t1 and a.t1_s > t0]
    assert hits, f"no anomaly overlapping [{t0:.3f}, {t1:.3f}) s"
    a = hits[0]
    assert a.kind == "power-deviation" and a.name == "B"
    # mean power lands near 1.5x the signature's expectation
    assert a.expected_w == pytest.approx(150.0, rel=0.1)
    assert a.mean_w / a.expected_w == pytest.approx(TAMPER_FACTOR, rel=0.15)
    assert a.duration_s == pytest.approx(a.t1_s - a.t0_s)


def test_watchdog_clean_device_quiet_no_strays(scenario):
    dog = scenario["dog"]
    t0, t1 = _tamper_window()
    assert [a for a in dog.anomalies if a.device == "dev0"] == []
    strays = [a for a in dog.anomalies
              if a.device == "dev1" and not (a.t0_s < t1 and a.t1_s > t0)]
    assert strays == []
    assert dog.n_segments > 2 * (N_STEPS - WARM_STEPS)  # really judged shapes


def test_watchdog_idle_checks_raise_nothing_new(scenario):
    assert scenario["idle_news"] == [[], []]


def test_part_time_sampler_misses_the_excursion(scenario):
    sampler = scenario["sampler"]
    honest_peak = max(w for _, _, w in STEP_PATTERN)
    assert len(sampler.samples) >= 3
    # the 8 ms excursion (225 W) lands between 100 ms samples
    assert sampler.detect(0.0, honest_peak * 1.1) == []
    assert max(sampler.values) <= honest_peak * 1.1


def test_watchdog_emits_obs_series(scenario):
    reg, rec, dog = scenario["reg"], scenario["rec"], scenario["dog"]
    assert reg.get_value("watchdog_checks_total") == float(dog.n_checks)
    flagged = reg.get_value("watchdog_anomalies_total",
                            device="dev1", kind="power-deviation")
    assert flagged == float(len(dog.anomalies))
    spans = [e for e in rec.events()
             if e.name.startswith("anomaly:power-deviation")]
    assert len(spans) == len(dog.anomalies)
    assert all(e.track == "watchdog:dev1" and e.clock == DEVICE for e in spans)


# --------------------------------------------------------- degraded fleet
def test_watchdog_skips_stale_device_and_freezes_cursor():
    from repro.faultlab import Disconnect, Scenario, inject

    t = np.linspace(0.0, 0.01, 64)
    lib = build_library(t, np.full(64, 50.0), [KernelSpan("k", 0.0, 0.01)])
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 2.0), ConstantLoad(12.0, 3.0)],
        stale_after_s=0.05, lost_after_s=10.0,
    )
    obs.disable()
    _rec, reg = obs.enable()
    try:
        inject(fleet, Scenario(faults=(Disconnect(0.05, 5.0, devices=("dev0",)),)))
        fleet.advance(0.04)
        dog = SignatureWatchdog(fleet, lib)
        dog.check()  # both healthy: cursors attach
        assert set(dog._cursors) == {"dev0", "dev1"}
        frozen = dog._cursors["dev0"].t_s
        fleet.advance(0.3)  # dev0 goes silent and turns stale
        assert fleet.device_health()["dev0"].state == "stale"
        dog.check()
        dog.check()
        assert reg.get_value("watchdog_skipped_total",
                             device="dev0", state="stale") == 2.0
        assert dog._cursors["dev0"].t_s == frozen  # cursor did not move
        assert all(a.device != "dev0" for a in dog.anomalies)
    finally:
        fleet.close()
        obs.disable()


# ------------------------------------------------------------- unit tier
def test_watchdog_rejects_empty_library():
    fleet = make_virtual_fleet([ConstantLoad(12.0, 1.0)])
    try:
        with pytest.raises(ValueError, match="non-empty signature library"):
            SignatureWatchdog(fleet, SignatureLibrary())
    finally:
        fleet.close()


def test_judge_flags_unknown_signature():
    from types import SimpleNamespace

    t = np.linspace(0.0, 0.01, 64)
    lib = build_library(t, np.full(64, 50.0), [KernelSpan("k", 0.0, 0.01)])
    fleet = make_virtual_fleet([ConstantLoad(12.0, 1.0)])
    try:
        # a strict matcher: any measurable shape distance is "unknown"
        dog = SignatureWatchdog(fleet, lib, max_distance=1e-6)
        w = np.abs(np.linspace(-100.0, 100.0, 64)) + 20.0
        seg = SimpleNamespace(t0_s=0.0, t1_s=0.01, mean_w=float(w.mean()))
        dog._judge("dev0", seg, t, w)
        (a,) = dog.anomalies
        assert a.kind == "unknown-signature" and a.name == "?"
        assert a.distance > dog.max_distance
        assert a.expected_w is None
    finally:
        fleet.close()


def test_sampler_rate_validation():
    with pytest.raises(ValueError, match="rate_hz"):
        PartTimeSampler(lambda t: 0.0, rate_hz=0.0)


def test_sampler_poll_schedule_and_detect():
    sampler = PartTimeSampler(lambda t: 100.0 * t, rate_hz=10.0)
    assert sampler.poll(0.25) == 3  # samples at 0.0, 0.1, 0.2
    assert sampler.poll(0.25) == 0  # nothing newly due
    assert sampler.poll(0.5) == 3  # 0.3, 0.4, 0.5
    assert [t for t, _ in sampler.samples] == pytest.approx(
        [0.0, 0.1, 0.2, 0.3, 0.4, 0.5])
    assert sampler.values == pytest.approx([0.0, 10.0, 20.0, 30.0, 40.0, 50.0])
    assert sampler.detect(5.0, 45.0) == [(0.0, 0.0), (0.5, 50.0)]


def test_sampler_phase_offsets_schedule():
    sampler = PartTimeSampler(lambda t: 1.0, rate_hz=10.0, phase_s=0.05)
    sampler.poll(0.2)
    assert [t for t, _ in sampler.samples] == pytest.approx([0.05, 0.15])


def test_anomaly_duration():
    a = Anomaly("dev0", "unknown-signature", "?", 1.0, 1.25, 0.9, 80.0)
    assert a.duration_s == pytest.approx(0.25)
    assert a.expected_w is None
