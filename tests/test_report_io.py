"""Report renderers and dump-text parsing (the consumer-facing I/O edges).

Covers `attrib.report` (text / CSV / JSON emitters and `write_report`
dispatch) and the error paths of `stream.textio.parse_dump` — the two
surfaces other tools consume, so their formats and failure modes are
pinned here rather than implied by the happy-path parity tests.
"""
import csv
import io
import json

import numpy as np
import pytest

from repro.attrib.attribute import EnergyLedger
from repro.attrib.report import (
    render_csv,
    render_json,
    render_text,
    write_report,
)
from repro.stream.textio import format_dump_block, parse_dump


def _ledger(skipped: int = 0) -> EnergyLedger:
    led = EnergyLedger(trace_energy_j=20.0, t0_s=0.0, t1_s=2.0,
                       skipped_spans=skipped)
    led.add_occurrence("attn", 6.0, 0.5, 200.0)
    led.add_occurrence("attn", 6.0, 0.5, 210.0)
    led.add_occurrence("ffn", 3.0, 0.4, 150.0)
    led.add_occurrence("gap", 1.0, 0.6, 30.0)
    return led  # total 16 J of the 20 J trace window -> 80 %


# ------------------------------------------------------------- render_text
def test_render_text_header_and_ranking():
    text = render_text(_ledger())
    lines = text.splitlines()
    assert lines[0] == (
        "# energy ledger: 16.000 J attributed (80.0% of trace window)"
    )
    # ranked biggest-first: attn (12 J), ffn (3 J), gap (1 J)
    names = [ln.split()[0] for ln in lines[2:]]
    assert names == ["attn", "ffn", "gap"]
    # attn row: 2 occurrences, 12 J, 75 % share
    assert lines[2].split()[1:4] == ["2", "12.000", "75.0%"]


def test_render_text_top_truncation_footer():
    text = render_text(_ledger(), top=1)
    assert "ffn" not in text
    # the 2 hidden entries sum to 4 J
    assert text.splitlines()[-1] == "... 2 more entries, 4.000 J"


def test_render_text_skipped_spans_footer():
    text = render_text(_ledger(skipped=3), title="case study")
    assert text.startswith("# case study:")
    assert text.splitlines()[-1] == (
        "# 3 spans skipped (too few samples or history evicted)"
    )
    assert "spans skipped" not in render_text(_ledger())


def test_render_text_empty_ledger():
    text = render_text(EnergyLedger())
    assert "0.000 J attributed (0.0% of trace window)" in text
    assert len(text.splitlines()) == 2  # header + column row only


# -------------------------------------------------------------- render_csv
def test_render_csv_schema_and_rows():
    rows = list(csv.DictReader(io.StringIO(render_csv(_ledger()))))
    assert [r["name"] for r in rows] == ["attn", "ffn", "gap"]
    attn = rows[0]
    assert int(attn["count"]) == 2
    assert float(attn["energy_j"]) == pytest.approx(12.0)
    assert float(attn["share"]) == pytest.approx(0.75)
    assert float(attn["j_per_occurrence"]) == pytest.approx(6.0)
    assert float(attn["avg_w"]) == pytest.approx(12.0)
    assert float(attn["peak_w"]) == pytest.approx(210.0)  # max over occurrences


# ------------------------------------------------------------- render_json
def test_render_json_roundtrip():
    doc = json.loads(render_json(_ledger(skipped=1)))
    assert doc["total_energy_j"] == pytest.approx(16.0)
    assert doc["trace_energy_j"] == pytest.approx(20.0)
    assert doc["attributed_fraction"] == pytest.approx(0.8)
    assert (doc["t0_s"], doc["t1_s"]) == (0.0, 2.0)
    assert doc["skipped_spans"] == 1
    assert [e["name"] for e in doc["entries"]] == ["attn", "ffn", "gap"]


def test_render_json_indent():
    assert "\n" not in render_json(_ledger())
    assert render_json(_ledger(), indent=2).count("\n") > 5


# ------------------------------------------------------------ write_report
def test_write_report_to_path(tmp_path):
    for fmt, probe in (("text", "# energy ledger"), ("csv", "name,count"),
                       ("json", '"total_energy_j"')):
        p = tmp_path / f"report.{fmt}"
        write_report(_ledger(), str(p), fmt=fmt)
        assert probe in p.read_text()


def test_write_report_to_file_like():
    buf = io.StringIO()
    write_report(_ledger(), buf, fmt="csv")
    assert buf.getvalue().startswith("name,count,energy_j")


def test_write_report_unknown_format():
    with pytest.raises(ValueError, match="unknown report format 'yaml'"):
        write_report(_ledger(), io.StringIO(), fmt="yaml")


# -------------------------------------------------------------- parse_dump
def test_parse_dump_roundtrip_with_formatter():
    n = 16
    t = np.linspace(0.0, 0.015, n)
    pairs = np.arange(n, dtype=np.int64) % 4
    v = np.full(n, 12.0625)
    a = np.linspace(0.5, 2.0, n)
    w = v * a
    text = format_dump_block(t, pairs, v, a, w)
    rt, rp, rv, ra, rw = parse_dump(text)[:5]
    assert rp.dtype == np.int64 and list(rp) == list(pairs)
    # round-trip within the dump's fixed-point quantisation
    np.testing.assert_allclose(rt, t, atol=5e-7)
    np.testing.assert_allclose(rv, v, atol=5e-5)
    np.testing.assert_allclose(ra, a, atol=5e-5)
    np.testing.assert_allclose(rw, w, atol=5e-5)


def test_parse_dump_markers_comments_blanks():
    text = (
        "# continuous dump\n"
        "\n"
        "0.000100 0 12.0000 1.0000 12.0000\n"
        "M S 0.000150\n"
        "0.000200 1 12.0000 2.0000 24.0000\n"
        "   \n"
        "M E 0.000250\n"
    )
    t, pairs, v, a, w, markers = parse_dump(text)
    assert t.size == 2 and list(pairs) == [0, 1]
    assert markers == [("S", 0.00015), ("E", 0.00025)]


def test_parse_dump_malformed_row_raises():
    with pytest.raises(ValueError, match="malformed dump row"):
        parse_dump("0.1 0 12.0 1.0\n")  # 4 fields
    with pytest.raises(ValueError, match=r"'0\.1 0 12\.0 1\.0 12\.0 junk'"):
        parse_dump("0.1 0 12.0 1.0 12.0 junk\n")  # 6 fields, repr in message


def test_parse_dump_non_numeric_field_raises():
    with pytest.raises(ValueError):
        parse_dump("0.1 0 twelve 1.0 12.0\n")


def test_parse_dump_empty_input():
    t, pairs, v, a, w, markers = parse_dump("")
    assert t.size == pairs.size == v.size == a.size == w.size == 0
    assert t.shape == (0,) and markers == []
    # comments/markers only is also an empty frame set
    t2, _, _, _, _, markers2 = parse_dump("# nothing\nM S 1.0\n")
    assert t2.size == 0 and markers2 == [("S", 1.0)]
