"""Host library: energy integration, interval/continuous modes, markers."""
import io

import numpy as np
import pytest

from repro.core import (
    ConstantLoad,
    Joules,
    PowerSensor,
    SquareWaveLoad,
    TraceLoad,
    Watt,
    make_device,
    seconds,
)
from repro.core.dut import CompositeLoad


def _ps(load, modules=("slot-10a-12v",), seed=0):
    return PowerSensor(make_device(list(modules), load, seed=seed))


def test_interval_mode_energy():
    ps = _ps(ConstantLoad(12.0, 8.0), seed=1)
    a = ps.read()
    ps.run_for(0.5)
    b = ps.read()
    assert seconds(a, b) == pytest.approx(0.5, rel=1e-3)
    # uncalibrated per-device error allowed: Table I worst case ±4.2 W
    assert Watt(a, b) == pytest.approx(96.0, abs=4.2)
    assert Joules(a, b) == pytest.approx(96.0 * 0.5, abs=4.2 * 0.5)


def test_energy_additivity():
    ps = _ps(ConstantLoad(12.0, 4.0), seed=2)
    a = ps.read()
    ps.run_for(0.2)
    m = ps.read()
    ps.run_for(0.3)
    b = ps.read()
    assert Joules(a, m) + Joules(m, b) == pytest.approx(Joules(a, b), rel=1e-9)


def test_multi_module_pairs():
    load = CompositeLoad({0: ConstantLoad(12.0, 5.0), 1: ConstantLoad(3.3, 3.0)})
    ps = _ps(load, modules=("slot-10a-12v", "slot-10a-3v3"), seed=3)
    a = ps.read()
    ps.run_for(0.3)
    b = ps.read()
    assert Watt(a, b, pair=0) == pytest.approx(60.0, abs=4.3)
    assert Watt(a, b, pair=1) == pytest.approx(9.9, abs=1.3)
    assert Watt(a, b) == pytest.approx(69.9, abs=5.0)


def test_square_wave_average():
    # 50% duty 3.3/8 A at 12 V -> mean ~ 67.8 W
    ps = _ps(SquareWaveLoad(12.0, 3.3, 8.0, freq_hz=100.0), seed=4)
    a = ps.read()
    ps.run_for(0.5)
    b = ps.read()
    assert Watt(a, b) == pytest.approx(12 * (3.3 + 8) / 2, abs=4.5)


def test_trace_load_energy_matches_integral():
    times = np.array([0.0, 0.1, 0.2, 0.4])
    watts = np.array([10.0, 50.0, 50.0, 0.0])
    true_j = np.trapezoid(watts, times)
    ps = _ps(TraceLoad(times_s=times, watts=watts, volts=12.0), seed=5)
    a = ps.read()
    ps.run_for(0.4)
    b = ps.read()
    assert Joules(a, b) == pytest.approx(true_j, rel=0.1)


def test_continuous_dump_has_20khz_records_and_markers():
    ps = _ps(ConstantLoad(12.0, 2.0), seed=6)
    buf = io.StringIO()
    ps.set_dump_file(buf)
    ps.run_for(0.01)
    ps.mark("A")
    ps.run_for(0.01)
    ps.set_dump_file(None)
    lines = buf.getvalue().splitlines()
    data = [l for l in lines if l and l[0].isdigit()]
    marks = [l for l in lines if l.startswith("M ")]
    assert len(data) == pytest.approx(400, abs=5)  # 0.02 s at 20 kHz
    assert len(marks) == 1 and marks[0].split()[1] == "A"


def test_marker_time_sync():
    ps = _ps(ConstantLoad(12.0, 2.0), seed=7)
    ps.run_for(0.1)
    ps.mark("X")
    ps.run_for(0.05)
    (char, t) = ps.markers[0]
    assert char == "X"
    assert t == pytest.approx(0.1, abs=0.001)  # within a frame or two


def test_both_modes_simultaneously():
    """Paper: interval + continuous modes can be active at the same time."""
    ps = _ps(ConstantLoad(12.0, 6.0), seed=8)
    buf = io.StringIO()
    ps.set_dump_file(buf)
    a = ps.read()
    ps.run_for(0.05)
    b = ps.read()
    assert Joules(a, b) > 0
    assert len(buf.getvalue().splitlines()) > 900


def test_dump_subsampling():
    ps = _ps(ConstantLoad(12.0, 2.0), seed=9)
    buf = io.StringIO()
    ps.set_dump_file(buf, every=20)  # 1 kHz
    ps.run_for(0.1)
    data = [l for l in buf.getvalue().splitlines() if l and l[0].isdigit()]
    assert len(data) == pytest.approx(100, abs=3)


def test_background_thread_receiver():
    ps = _ps(ConstantLoad(12.0, 3.0), seed=10)
    ps.start_thread(real_time_factor=50.0, tick_s=0.002)
    import time

    time.sleep(0.15)
    ps.stop_thread()
    st = ps.read()
    assert st.n_samples > 1000  # thread advanced + polled


def test_table2_noise_vs_averaging():
    """Table II: averaging blocks of samples reduces std ~ 1/sqrt(N)."""
    ps = _ps(ConstantLoad(12.0, 1.0), seed=11)
    buf = io.StringIO()
    ps.set_dump_file(buf)
    ps.run_for(1.0)
    ps.set_dump_file(None)
    watts = np.array(
        [float(l.split()[4]) for l in buf.getvalue().splitlines() if l and l[0].isdigit()]
    )
    std_20k = watts.std()
    avg40 = watts[: len(watts) // 40 * 40].reshape(-1, 40).mean(axis=1)
    std_500 = avg40.std()
    ratio = std_20k / std_500
    assert ratio == pytest.approx(np.sqrt(40), rel=0.25)
    # paper Table II at 1 A load: std 0.72 W at 20 kHz, 0.117 W at 0.5 kHz.
    # our theoretical model (datasheet noise only) gives the same order:
    assert 0.2 < std_20k < 1.2


def test_set_dump_file_closes_owned_handles(tmp_path):
    """Handles opened by set_dump_file are closed on replace/clear/close."""
    ps = _ps(ConstantLoad(12.0, 2.0), seed=12)
    p1, p2 = tmp_path / "a.dump", tmp_path / "b.dump"
    ps.set_dump_file(str(p1))
    h1 = ps._dump
    ps.run_for(0.005)
    ps.set_dump_file(str(p2))  # replacement closes the first handle
    h2 = ps._dump
    assert h1.closed
    ps.run_for(0.005)
    ps.set_dump_file(None)  # clearing closes too
    assert h2.closed
    assert p1.read_text().startswith("# t_s pair")
    assert len(p2.read_text().splitlines()) > 50

    ps.set_dump_file(str(p1))
    h3 = ps._dump
    ps.close()  # close() also releases an owned handle
    assert h3.closed


def test_set_dump_file_does_not_close_caller_streams():
    ps = _ps(ConstantLoad(12.0, 2.0), seed=13)
    buf = io.StringIO()
    ps.set_dump_file(buf)
    ps.run_for(0.005)
    ps.set_dump_file(None)
    assert not buf.closed  # caller-owned stream stays open


def test_marker_survives_disabled_ch0():
    """Markers ride sensor-0 packets; disabling ch0 must not swallow them.

    The firmware emits bare sensor-0 packets for pending markers when ch0
    is disabled, and the host extracts the marker bit *before* its
    enabled-channel filter — so the event lands, time-synced, while the
    disabled pair's power correctly reads 0."""
    from dataclasses import replace

    ps = _ps(ConstantLoad(12.0, 2.0), seed=20)
    ps.run_for(0.05)
    ps.set_config(0, replace(ps.get_config(0), enabled=False))
    ps.run_for(0.05)
    ps.mark("D")
    ps.run_for(0.05)
    assert len(ps.markers) == 1
    char, t = ps.markers[0]
    assert char == "D"
    assert t == pytest.approx(0.1, abs=0.002)
    st = ps.read()
    assert st.instant_watts[0] == 0.0  # current channel disabled: no power
    assert st.instant_volts[0] == pytest.approx(12.0, abs=0.5)  # voltage ch still on
    # the marker-carrying packet's ADC value must not leak into energy
    e_mark = st.consumed_joules[0]
    ps.run_for(0.2)
    assert ps.read().consumed_joules[0] == pytest.approx(e_mark, abs=1e-9)


def test_dump_header_written_once_per_fresh_file():
    ps = _ps(ConstantLoad(12.0, 2.0), seed=14)
    fresh = io.StringIO()
    ps.set_dump_file(fresh)
    assert fresh.getvalue().count("# t_s pair") == 1
    ps.set_dump_file(None)
    used = io.StringIO()
    used.write("0.000000 0 1.0 1.0 1.0\n")  # stream already in use
    ps.set_dump_file(used)
    assert "# t_s pair" not in used.getvalue()
    ps.set_dump_file(None)
