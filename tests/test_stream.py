"""Streaming telemetry subsystem: ring buffer, windowed aggregation,
receiver fast/generic path equivalence, and the FleetMonitor."""
import numpy as np
import pytest

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.core import protocol
from repro.core.host import MAX_PAIRS
from repro.stream import (
    FleetMonitor,
    FrameRing,
    make_virtual_fleet,
    window_stats,
    windowed_mean_at,
)
from repro.stream.textio import _printf_block, format_dump_block


def _fill(n, pairs=2, t0=0.0):
    t = t0 + np.arange(n) * 50e-6
    v = np.tile(np.arange(1.0, pairs + 1.0), (n, 1)) + np.arange(n)[:, None] * 1e-3
    a = np.ones((n, pairs)) * 0.5
    return t, v, a, v * a


# --------------------------------------------------------------------- ring
def test_ring_append_and_latest_ordering():
    r = FrameRing(64, 2)
    t, v, a, w = _fill(10)
    r.append(t, v, a, w)
    assert len(r) == 10 and r.head == 10
    blk = r.latest()
    np.testing.assert_array_equal(blk.times_s, t)
    np.testing.assert_array_equal(blk.watts, w)
    assert len(r.latest(3)) == 3
    np.testing.assert_array_equal(r.latest(3).times_s, t[-3:])


def test_ring_wraparound_keeps_newest_in_order():
    r = FrameRing(16, 2)
    all_t = []
    for k in range(5):  # 5 x 7 = 35 frames through a 16-slot ring
        t, v, a, w = _fill(7, t0=k * 7 * 50e-6)
        r.append(t, v, a, w)
        all_t.append(t)
    full_t = np.concatenate(all_t)
    assert r.head == 35 and len(r) == 16
    blk = r.latest()
    np.testing.assert_allclose(blk.times_s, full_t[-16:])
    assert np.all(np.diff(blk.times_s) > 0)  # chronological


def test_ring_block_larger_than_capacity():
    r = FrameRing(8, 1)
    t = np.arange(20) * 1.0
    x = t[:, None]
    r.append(t, x, x, x)
    assert r.head == 20 and len(r) == 8
    np.testing.assert_array_equal(r.latest().times_s, t[-8:])


def test_ring_window_and_since_queries():
    r = FrameRing(128, 1)
    t = np.arange(100) * 0.01
    x = t[:, None]
    r.append(t[:60], x[:60], x[:60], x[:60])
    seq = r.head
    r.append(t[60:], x[60:], x[60:], x[60:])
    blk = r.since(seq)
    assert blk.seq0 == 60 and len(blk) == 40
    np.testing.assert_allclose(blk.times_s, t[60:])
    win = r.window(0.25, 0.50)
    np.testing.assert_allclose(win.times_s, t[(t >= 0.25) & (t < 0.50)])
    # seq older than retention clamps to what's still there
    assert len(r.since(-5)) == 100


# ---------------------------------------------------------------- aggregate
def test_window_stats_matches_direct_numpy():
    r = FrameRing(256, 3)
    rng = np.random.default_rng(0)
    t = np.sort(rng.uniform(0, 1, 200))
    w = rng.uniform(0, 50, (200, 3))
    v = np.sqrt(w)
    r.append(t, v, w / np.maximum(v, 1e-9), w)
    st = window_stats(r.latest(), pct=90.0)
    np.testing.assert_allclose(st.mean_w, w.mean(axis=0))
    np.testing.assert_allclose(st.peak_w, w.max(axis=0))
    np.testing.assert_allclose(st.pct_w, np.percentile(w, 90.0, axis=0))
    np.testing.assert_allclose(st.energy_j, np.trapezoid(w, t, axis=0))
    assert st.total_mean_w == pytest.approx(float(w.mean(axis=0).sum()))
    assert st.n_frames == 200


def test_windowed_mean_matches_python_loop():
    rng = np.random.default_rng(1)
    grid = np.arange(0.0, 2.0, 1e-3)
    dense = rng.uniform(0, 100, grid.size)
    queries = np.sort(rng.uniform(-0.1, 2.1, 50))
    window = 0.25
    fast = windowed_mean_at(grid, dense, queries, window)
    for q, got in zip(queries, fast):
        lo = max(0.0, q - window)
        sel = (grid >= lo) & (grid <= q)
        want = dense[sel].mean() if np.any(sel) else dense[0]
        assert got == pytest.approx(want, rel=1e-9)


# ------------------------------------------------------------------ textio
def test_format_dump_block_matches_printf():
    rng = np.random.default_rng(2)
    n = 500
    t = np.sort(rng.uniform(0, 5000, n))
    p = rng.integers(0, MAX_PAIRS, n)
    v = rng.uniform(-20, 20, n)
    a = rng.uniform(-3, 3, n)
    w = v * a
    assert format_dump_block(t, p, v, a, w) == _printf_block(
        np.column_stack([t, p.astype(np.float64), v, a, w])
    )


def test_format_dump_block_out_of_range_falls_back():
    t = np.array([0.5])
    p = np.array([0])
    big = np.array([1.5e4])  # exceeds the fixed-point field
    out = format_dump_block(t, p, big, big, big * big)
    assert out == "0.500000 0 15000.0000 15000.0000 225000000.0000\n"


# ------------------------------------------------------- receiver <-> ring
def _ps(load, modules=("slot-10a-12v",), seed=0, **kw):
    return PowerSensor(make_device(list(modules), load, seed=seed), **kw)


def test_receiver_fills_ring():
    ps = _ps(ConstantLoad(12.0, 4.0), seed=3)
    ps.run_for(0.2)
    st = ps.read()
    assert len(ps.ring) == st.n_samples > 3000
    blk = ps.ring.latest()
    assert np.all(np.diff(blk.times_s) > 0)
    assert blk.watts[:, 0].mean() == pytest.approx(48.0, abs=4.3)
    stats = ps.snapshot(window_s=0.1)
    assert stats.mean_w[0] == pytest.approx(48.0, abs=4.3)
    assert 0.09 < stats.duration_s < 0.11


def test_generic_path_matches_regular_path():
    """Splitting the same packet stream at a non-frame boundary (forcing the
    scatter path) must produce the same energy and ring contents."""
    ps_a = _ps(ConstantLoad(12.0, 2.0), seed=4)
    ps_b = _ps(ConstantLoad(12.0, 2.0), seed=4)
    dev = ps_a.device
    dev.advance(0.05)
    raw = dev.read()
    ids, vals, marks, consumed = protocol.decode_packets(raw)
    assert consumed == len(raw)
    ps_a._process(ids, vals, marks)
    # feed the identical packets to ps_b in two ragged pieces
    cut = (len(ids) // 2) + 3  # not a multiple of the frame size
    ps_b._process(ids[:cut], vals[:cut], marks[:cut])
    ps_b._process(ids[cut:], vals[cut:], marks[cut:])
    np.testing.assert_allclose(ps_b._energy, ps_a._energy, rtol=1e-12)
    assert len(ps_b.ring) == len(ps_a.ring)
    np.testing.assert_allclose(
        ps_b.ring.latest().watts, ps_a.ring.latest().watts, rtol=1e-12
    )


def test_read_holds_last_observed_value_per_pair():
    """A frame with no data packets for a pair must not flicker V/I to 0."""
    ps = _ps(ConstantLoad(12.0, 2.0), seed=5)
    ps.run_for(0.01)
    before = ps.read()
    assert before.instant_watts[0] > 0
    # inject two bare timestamp frames (no data packets at all)
    ids = np.array([protocol.TIMESTAMP_SENSOR_ID] * 2)
    vals = np.array([100, 150])
    marks = np.array([1, 1])
    ps._process(ids, vals, marks)
    after = ps.read()
    assert after.instant_volts[0] == pytest.approx(before.instant_volts[0])
    assert after.instant_amps[0] == pytest.approx(before.instant_amps[0])
    assert after.instant_watts[0] == pytest.approx(before.instant_watts[0])


def test_marker_bit_on_nonzero_data_id_is_not_a_marker_event():
    ps = _ps(ConstantLoad(12.0, 2.0), seed=6)
    ids = np.array([protocol.TIMESTAMP_SENSOR_ID, 5])
    vals = np.array([100, 40])
    marks = np.array([1, 1])  # marker bit on sensor id 5: not ts, not marker
    ps._process(ids, vals, marks)
    assert ps.markers == []


# ------------------------------------------------------------------- fleet
def test_fleet_monitor_eight_devices_per_device_and_aggregate():
    watts = [10.0 * (i + 1) for i in range(8)]  # 10..80 W
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, w / 12.0) for w in watts], seed=7, window_s=1.0
    )
    assert len(fleet) == 8
    fleet.run_for(0.3)
    snap = fleet.snapshot(window_s=0.25)
    assert snap.aggregate.n_devices == 8
    for i, name in enumerate(fleet.names):
        dev = snap.devices[name]
        assert dev.window.total_mean_w == pytest.approx(watts[i], abs=5.0)
        assert dev.window.n_frames > 4000
    # aggregate must equal the sum over the per-device windowed stats
    assert snap.aggregate.mean_w == pytest.approx(
        sum(d.window.total_mean_w for d in snap.devices.values()), rel=1e-12
    )
    assert snap.aggregate.energy_j == pytest.approx(
        sum(d.window.total_energy_j for d in snap.devices.values()), rel=1e-12
    )
    assert snap.aggregate.mean_w == pytest.approx(sum(watts), abs=5.0 * 8)
    fleet.close()


def test_fleet_marker_aligned_interval_query():
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 2.0), ConstantLoad(12.0, 4.0)], seed=8
    )
    fleet.run_for(0.05)
    fleet.mark_all("A")
    fleet.run_for(0.2)
    fleet.mark_all("B")
    fleet.run_for(0.05)
    per_dev = fleet.interval("A", "B")
    assert set(per_dev) == {"dev0", "dev1"}
    for name, expect_w in (("dev0", 24.0), ("dev1", 48.0)):
        iv = per_dev[name]
        assert iv.duration_s == pytest.approx(0.2, abs=0.005)
        assert iv.total_mean_w == pytest.approx(expect_w, abs=4.3)
        assert iv.total_energy_j == pytest.approx(expect_w * 0.2, abs=1.0)
    fleet.close()


def test_fleet_round_robin_poll():
    fleet = make_virtual_fleet([ConstantLoad(12.0, 1.0)] * 3, seed=9)
    for ps in (fleet[n] for n in fleet.names):
        ps.device.advance(0.01)
    # 3 single-device round-robin polls drain each device exactly once
    for _ in range(3):
        assert fleet.poll(1) > 0
    assert fleet.poll(1) == 0  # everything drained
    fleet.close()


def test_fleet_background_threads_smoke():
    fleet = make_virtual_fleet([ConstantLoad(12.0, 1.0)] * 2, seed=10)
    fleet.start_threads(real_time_factor=20.0, tick_s=0.002)
    import time

    time.sleep(0.1)
    fleet.stop_threads()
    snap = fleet.snapshot()
    assert snap.aggregate.n_frames > 1000
    fleet.close()


def test_cumulative_energy_shapes_and_values():
    from repro.stream import cumulative_energy

    t = np.array([0.0, 0.1, 0.3, 0.6])
    w2 = np.array([[10.0, 1.0], [20.0, 1.0], [20.0, 1.0], [0.0, 1.0]])
    cum = cumulative_energy(t, w2)
    assert cum.shape == w2.shape
    np.testing.assert_allclose(cum[0], [0.0, 0.0])
    np.testing.assert_allclose(cum[-1], np.trapezoid(w2, t, axis=0))
    # 1-D input keeps its shape
    cum1 = cumulative_energy(t, w2[:, 0])
    assert cum1.shape == (4,)
    np.testing.assert_allclose(cum1, cum[:, 0])


def test_disabled_pair_stops_accruing_energy_and_power():
    """Disabling a pair's channels mid-run must zero its power everywhere —
    the last-observed hold applies to transient gaps, not disabled pairs."""
    from dataclasses import replace

    ps = _ps(ConstantLoad(12.0, 2.0), seed=15)
    ps.run_for(0.2)
    e_before = ps.read().consumed_joules[0]
    assert e_before > 0
    for sid in (0, 1):
        ps.set_config(sid, replace(ps.get_config(sid), enabled=False))
    ps.run_for(0.5)
    st = ps.read()
    assert st.consumed_joules[0] == pytest.approx(e_before, abs=1e-9)
    assert st.instant_watts[0] == 0.0
    stats = ps.snapshot(window_s=0.3)
    assert stats.mean_w[0] == pytest.approx(0.0, abs=1e-12)


def test_fleet_interval_occurrence_indexed_same_char():
    """One repeated marker char brackets unbounded intervals: wave k is
    interval('W', 'W', occurrence=k, occurrence_b=k+1) — no alphabet wrap."""
    fleet = make_virtual_fleet([ConstantLoad(12.0, 2.0)], seed=16)
    fleet.run_for(0.02)
    durations = (0.1, 0.2, 0.05)
    fleet.mark_all("W")
    for d in durations:
        fleet.run_for(d)
        fleet.mark_all("W")
    fleet.run_for(0.02)
    for k, d in enumerate(durations):
        iv = fleet.interval("W", "W", occurrence=k, occurrence_b=k + 1)["dev0"]
        assert iv.duration_s == pytest.approx(d, abs=0.005)
        # uncalibrated per-device error allowed: Table I worst case ±4.2 W
        assert iv.total_energy_j == pytest.approx(24.0 * d, abs=4.3 * d)
    # same-occurrence open/close is an empty interval, not the first wave
    assert fleet.interval("W", "W", occurrence=1) == {}
    fleet.close()


def test_fleet_marker_interval_spans_ring_wraparound():
    """A marker interval whose frames physically wrap the ring must still
    integrate correctly (the retained span crosses the buffer seam)."""
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 2.0)], seed=17, ring_capacity=10_000  # ~0.5 s
    )
    fleet.run_for(0.4)  # fill most of the ring
    fleet.mark_all("A")
    fleet.run_for(0.2)  # head wraps past the physical end
    fleet.mark_all("B")
    fleet.run_for(0.02)
    ps = fleet["dev0"]
    assert ps.ring.head > ps.ring.capacity  # wrapped for sure
    iv = fleet.interval("A", "B")["dev0"]
    assert iv.duration_s == pytest.approx(0.2, abs=0.005)
    assert iv.total_mean_w == pytest.approx(24.0, abs=4.3)
    assert iv.total_energy_j == pytest.approx(24.0 * 0.2, abs=1.0)
    fleet.close()


def test_fleet_interval_omits_evicted_spans():
    """An interval whose head the ring has already evicted must be omitted,
    not silently undercounted."""
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 2.0)], seed=11, ring_capacity=10_000  # ~0.5 s
    )
    fleet.run_for(0.05)
    fleet.mark_all("A")
    fleet.run_for(1.0)  # pushes the 'A' region out of the ring
    fleet.mark_all("B")
    fleet.run_for(0.05)
    assert fleet.interval("A", "B") == {}
    fleet.close()


# --------------------------------------------- windowed power hooks (sched)
def test_ring_tail_mean_watts_matches_block_mean():
    r = FrameRing(64, 2)
    t, v, a, w = _fill(40)
    r.append(t, v, a, w)
    want = r.tail_window(1e-3).total_watts.mean()
    assert r.tail_mean_watts(1e-3) == pytest.approx(want)
    # whole-history window
    assert r.tail_mean_watts(10.0) == pytest.approx(w.sum(axis=1).mean())
    # narrower than one frame: the newest frame's total
    assert r.tail_mean_watts(1e-9) == pytest.approx(w[-1].sum())
    assert FrameRing(8, 2).tail_mean_watts(1.0) == 0.0


def test_ring_tail_mean_watts_across_wraparound():
    r = FrameRing(16, 2)
    for k in range(5):  # wraps several times
        t, v, a, w = _fill(7, t0=k * 7 * 50e-6)
        r.append(t, v, a, w)
    blk = r.latest()
    for win in (2e-4, 5e-4, 1.0):
        sel = blk.times_s >= blk.times_s[-1] - win
        want = blk.total_watts[sel].mean()
        assert r.tail_mean_watts(win) == pytest.approx(want)


def test_ring_tail_mean_time_weighted_under_delivery_gap():
    """Regression: a dropout inside the window used to skew the mean
    toward whichever side of the gap delivered more frames (plain count
    mean).  With zero-order hold the frame before the gap vouches for the
    gap's duration."""
    ring = FrameRing(64, 1)

    def blk(ts, w):
        ts = np.asarray(ts, float)
        w = np.asarray(w, float).reshape(-1, 1)
        ones = np.ones_like(w)
        ring.append(ts, w, ones, w * ones)

    t1 = np.arange(10) * 1e-3  # 10 frames @ 1 kHz, 10 W
    t2 = t1[-1] + 0.080 + np.arange(10) * 1e-3  # 80 ms hole, then 50 W
    blk(t1, np.full(10, 10.0))
    blk(t2, np.full(10, 50.0))

    times = np.concatenate([t1, t2])
    w = np.concatenate([np.full(10, 10.0), np.full(10, 50.0)])
    dts = np.diff(times)
    med = float(np.median(dts))
    want = (float((w[:-1] * dts).sum()) + w[-1] * med) / (
        float(dts.sum()) + med
    )
    got = ring.tail_mean_watts(1.0)
    assert got == pytest.approx(want)
    # the pre-fix count mean (30 W) is nowhere near the covered-time mean
    assert abs(got - w.mean()) > 5.0
    # a gap-free trailing window still reduces as the exact count mean
    assert ring.tail_mean_watts(5e-3) == pytest.approx(50.0)


class _RingSensor:
    """Duck-typed sensor: just a ring and a marker list (no transport)."""

    def __init__(self, ring, markers):
        self.ring = ring
        self.markers = markers
        self.device = None
        self.dropped_frames = 0


def test_marker_window_rejects_leading_gap_with_inflated_first_dt():
    """Regression: the eviction check estimated the frame interval from
    the first two frames only — a delivery gap at the window's leading
    edge inflated that estimate and silently accepted a window missing
    its leading coverage.  The median inter-frame dt is robust to it."""
    ring = FrameRing(256, 1)
    times = np.concatenate([[1.4, 1.6], 1.65 + np.arange(8) * 0.05])
    n = times.size
    ones = np.ones((n, 1))
    ring.append(times, 12.0 * ones, 2.0 * ones, 24.0 * ones)

    mon = FleetMonitor()
    mon.add("gap", _RingSensor(ring, [("A", 1.0), ("B", 2.05)]))
    # t0=1.0: first retained frame starts 0.4 s late.  first-dt estimate
    # = 0.2 → 0.4 > 2*0.2 is False → pre-fix accepted; median dt = 0.05
    # → 0.4 > 0.1 → rejected
    assert mon.marker_window("gap", "A", "B") is None
    # a window whose head actually is retained still passes
    mon.add("ok", _RingSensor(ring, [("A", 1.35), ("B", 2.05)]))
    hit = mon.marker_window("ok", "A", "B")
    assert hit is not None
    t0, t1, block = hit
    assert (t0, t1) == (1.35, 2.05)
    assert len(block) == n


def test_late_attached_device_gets_grace_not_lost():
    """Regression: a device added to a long-running fleet read
    ``staleness = now`` off its empty ring and was born `lost`."""
    mon = FleetMonitor(stale_after_s=0.05, lost_after_s=0.2)
    dev0 = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 2.0))
    ps0 = PowerSensor(dev0)
    mon.add("dev0", ps0)
    dev0.advance(0.5)
    ps0.poll()  # fleet 'now' is ~0.5 s

    dev1 = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 1.0))
    ps1 = PowerSensor(dev1)
    mon.add("dev1", ps1)  # ring still empty: pre-fix staleness = 0.5 → lost
    h = mon.device_health()
    assert h["dev1"].state == "healthy"
    assert h["dev1"].staleness_s < mon.stale_after_s

    # the grace is a window, not immunity: still silent after
    # lost_after_s from the attach time → genuinely lost
    dev0.advance(0.5)
    ps0.poll()
    assert mon.device_health()["dev1"].state == "lost"

    # and delivering frames ends the grace bookkeeping entirely
    dev1.advance(1.01)
    ps1.poll()
    assert mon.device_health()["dev1"].state == "healthy"


def test_fleet_window_power_sums_devices():
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 1.0), ConstantLoad(12.0, 2.0)], seed=5
    )
    fleet.run_for(0.2)
    total = fleet.window_power_w(0.05)
    # the no-copy hook must agree exactly with the FrameBlock-based path
    want = sum(
        fleet[name].ring.tail_window(0.05).total_watts.mean()
        for name in fleet.names
    )
    assert total == pytest.approx(want, rel=1e-9)
    # and land near physical truth (uncalibrated offsets allow a few watts)
    assert total == pytest.approx(12.0 + 24.0, abs=12.0)
    per_dev = fleet.device_window_power_w(0.05, poll=False)
    assert set(per_dev) == {"dev0", "dev1"}
    assert sum(per_dev.values()) == pytest.approx(total, rel=1e-6)
    assert per_dev["dev1"] > per_dev["dev0"]
    fleet.close()


# ------------------------------------------------------- thread lifecycle
def _threaded_fleet(n=2, seed=23):
    fleet = make_virtual_fleet(
        [ConstantLoad(12.0, 1.5) for _ in range(n)], seed=seed
    )
    fleet.start_threads(real_time_factor=20.0, tick_s=0.002)
    return fleet


def test_fleet_close_without_stop_threads_does_not_deadlock_or_leak():
    import threading
    import time

    before = threading.active_count()
    fleet = _threaded_fleet()
    time.sleep(0.05)
    assert threading.active_count() >= before + 2
    done = threading.Event()

    def _close():
        fleet.close()  # close() without an explicit stop_threads() first
        done.set()

    closer = threading.Thread(target=_close, daemon=True)
    closer.start()
    closer.join(timeout=10.0)
    assert done.is_set(), "fleet.close() deadlocked with receiver threads live"
    # receiver threads fully reaped, nothing leaked
    for name in fleet.names:
        assert fleet[name]._thread is None
    deadline = time.monotonic() + 2.0
    while threading.active_count() > before and time.monotonic() < deadline:
        time.sleep(0.01)
    assert threading.active_count() <= before


def test_fleet_stop_threads_idempotent_and_restartable():
    import time

    fleet = _threaded_fleet(n=1)
    time.sleep(0.03)
    fleet.stop_threads()
    fleet.stop_threads()  # second stop is a no-op, not an error
    h0 = fleet["dev0"].ring.head
    assert h0 > 0
    fleet.start_threads(real_time_factor=20.0, tick_s=0.002)
    time.sleep(0.05)
    fleet.stop_threads()
    assert fleet["dev0"].ring.head > h0  # restarted threads kept streaming
    fleet.close()


def test_marker_window_consistent_under_concurrent_polling():
    import threading
    import time

    fleet = _threaded_fleet(n=2)
    try:
        time.sleep(0.05)
        fleet.mark_all("A")
        time.sleep(0.10)
        fleet.mark_all("B")
        time.sleep(0.05)  # let the closing marker flush through the stream

        results: list = []
        errors: list = []

        def _query():
            try:
                for _ in range(40):
                    hit = fleet.marker_window("dev0", "A", "B")
                    if hit is not None:
                        t0, t1, block = hit
                        results.append((t0, t1, len(block)))
            except Exception as e:  # pragma: no cover - failure path
                errors.append(e)

        readers = [threading.Thread(target=_query) for _ in range(3)]
        for r in readers:
            r.start()
        for r in readers:
            r.join(timeout=10.0)
        assert not errors
        assert results, "no reader ever saw the marker window"
        t0s = {r[0] for r in results}
        t1s = {r[1] for r in results}
        # the span is pinned: every concurrent read agrees on both markers
        assert len(t0s) == 1 and len(t1s) == 1
        (t0,), (t1,) = t0s, t1s
        assert t1 > t0
        # and the frame count for the closed span is stable across reads
        assert len({r[2] for r in results}) == 1
    finally:
        fleet.close()


def test_interval_concurrent_with_polling_is_consistent():
    import threading
    import time

    fleet = _threaded_fleet(n=2)
    try:
        time.sleep(0.05)
        fleet.mark_all("A")
        time.sleep(0.10)
        fleet.mark_all("B")
        time.sleep(0.05)
        snaps = []

        def _query():
            for _ in range(20):
                iv = fleet.interval("A", "B")
                if iv:
                    snaps.append({k: (v.t0_s, v.t1_s, v.total_energy_j)
                                  for k, v in iv.items()})

        readers = [threading.Thread(target=_query) for _ in range(2)]
        for r in readers:
            r.start()
        for r in readers:
            r.join(timeout=10.0)
        assert snaps
        # closed spans re-read identically while the receiver keeps
        # appending.  A device may legitimately drop *out* of the result
        # mid-run (its ring evicting past the opening marker flips
        # `marker_window` to None under the retention rules) — but every
        # read that does include a device must report the same pinned span
        per_dev: dict = {}
        for s in snaps:
            for k, v in s.items():
                per_dev.setdefault(k, set()).add(v)
        assert per_dev
        for k, vals in per_dev.items():
            assert len(vals) == 1, (k, vals)
    finally:
        fleet.close()


def test_window_power_concurrent_with_threaded_receiver():
    import time

    fleet = _threaded_fleet(n=2)
    try:
        time.sleep(0.1)
        # polling from the main thread while receiver threads run must not
        # race the ring (lock-guarded) and must read a sane fleet power
        vals = [fleet.window_power_w(0.05) for _ in range(20)]
        assert all(np.isfinite(v) for v in vals)
        assert vals[-1] == pytest.approx(2 * 18.0, abs=12.0)
    finally:
        fleet.close()


# ------------------------------------------------------- dump/archive parity
def test_dump_text_parses_back_to_the_archive():
    """Continuous-mode dump ≡ trace archive, to the dump's quantisation.

    The same session is captured both ways — `set_dump_file` text and a
    `repro.replay` archive — then the parsed-back dump must match the
    archive's full-precision frames to within half of the last printed
    digit (5e-7 s, 5e-5 V/A/W), and the marker lines must be exact.
    A drift beyond half-ULP means the fixed-point fast path rounded a
    value differently than the exact double (the near-tie bug
    `_round_scaled` fixes).
    """
    import io as _io

    from repro.core import SweepLoad
    from repro.replay import SessionRecorder
    from repro.stream.textio import parse_dump

    dev = make_device(
        ["pcie8pin-20a", "hc-50a"],
        SweepLoad(steps=np.arange(-6.0, 6.5, 1.0), dwell_s=0.01),
        seed=5,
    )
    ps = PowerSensor(dev)
    sink = _io.StringIO()
    ps.set_dump_file(sink)
    rec = SessionRecorder(ps, name="d")
    for k in range(3):
        ps.mark(chr(65 + k))
        ps.run_for(0.04, chunk_s=0.007)
        rec.capture()
    archive = rec.finalize()
    ps.set_dump_file(None)
    ps.close()

    t, pairs, volts, amps, watts, markers = parse_dump(sink.getvalue())
    tr = archive.devices["d"]
    block = tr.decode()
    dumped_pairs = np.flatnonzero(
        [blk.enabled for blk in tr.configs[0::2]]
    )
    n, p = len(block), dumped_pairs.size
    assert t.size == n * p

    true_t = np.repeat(block.times_s, p)
    true_pairs = np.tile(dumped_pairs, n)
    np.testing.assert_array_equal(pairs, true_pairs)
    assert np.abs(t - true_t).max() <= 5e-7
    for parsed, true in (
        (volts, block.volts),
        (amps, block.amps),
        (watts, block.watts),
    ):
        err = np.abs(parsed - true[:, dumped_pairs].ravel())
        assert err.max() <= 5e-5, err.max()
    assert markers == tr.markers


def test_fast_path_matches_printf_at_decimal_ties():
    """The near-tie regression `_round_scaled` fixed: constructed values
    whose scaled product sits within a float64 ULP of a rounding boundary
    must still format byte-identically to printf's exact-double rounding."""
    rng = np.random.default_rng(11)
    k = rng.integers(0, 10**8, 4000)
    v = np.clip((k + 0.5) / 1e4, 0.0, 1e4 - 1.0)
    t = np.round(np.sort(rng.uniform(0, 10, v.size)) * 1e6) / 1e6
    pairs = np.zeros(v.size, dtype=np.int64)
    z = np.zeros(v.size)
    fast = format_dump_block(t, pairs, v, -v, z)
    slow = _printf_block(np.column_stack([t, pairs.astype(float), v, -v, z]))
    assert fast == slow


def test_round_scaled_exact_path_matches_printf_without_longdouble():
    """The near-tie re-round must not depend on platform longdouble
    precision: the exact Decimal path alone decides like printf."""
    from repro.stream.textio import _round_scaled

    v = np.array([5118.10005, 9486.49445, 2492.28635, 0.00005, 1.00015])
    got = _round_scaled(v, 10**4)
    expected = np.array(
        [int(("%.4f" % x).replace(".", "")) for x in v], dtype=np.int64
    )
    np.testing.assert_array_equal(got, expected)
