"""`repro.net` tier: framing, loopback conformance, backpressure, plans.

The load-bearing test here is the golden-corpus parity sweep: every
committed golden scenario replayed through ``DeviceServer`` →
``SocketDevice`` → an *unmodified* ``PowerSensor`` must produce rings,
markers, and drop counters bit-identical to the in-process replay path.
"""
import hashlib
import time

import numpy as np
import pytest

from repro.core import ConstantLoad, PowerSensor, make_device
from repro.core.protocol import CMD_START_STREAM
from repro.net import (
    DeviceServer,
    FleetHead,
    Framer,
    Interlocks,
    MeasurementPlan,
    PlanDevice,
    SocketDevice,
    pack_frame,
    parse_endpoint,
    run_plan,
)
from repro.net import link as net_link
from repro.replay import TraceArchive
from repro.replay.replay import ReplayDevice, replay_sensor

GOLDEN_SCENARIOS = [
    "serve-wave",
    "serve-churn",
    "governor-step",
    "chaos-dropout",
    "chaos-disconnect",
]


def _wait(predicate, timeout_s=10.0, tick_s=0.002):
    deadline = time.monotonic() + timeout_s
    while time.monotonic() < deadline:
        if predicate():
            return True
        time.sleep(tick_s)
    return predicate()


# ---------------------------------------------------------------- framing
def test_framer_reassembles_one_byte_dribble():
    frames = [
        (net_link.T_HELLO, b"dev0"),
        (net_link.T_DATA, b"\x00" * 8 + b"payload"),
        (net_link.T_EOF, b""),
        (net_link.T_CMD, bytes(range(256))),
    ]
    wire = b"".join(pack_frame(t, p) for t, p in frames)
    fr = Framer()
    out = []
    for i in range(len(wire)):  # worst-case partial sends: 1 byte each
        out.extend(fr.feed(wire[i : i + 1]))
    assert out == frames
    assert fr.pending == 0


def test_framer_mixed_splits_and_coalesced_feeds():
    frames = [(net_link.T_DATA, bytes([i]) * i) for i in range(1, 40)]
    wire = b"".join(pack_frame(t, p) for t, p in frames)
    for step in (3, 7, 64, len(wire)):
        fr = Framer()
        out = []
        for i in range(0, len(wire), step):
            out.extend(fr.feed(wire[i : i + step]))
        assert out == frames, step


def test_framer_rejects_oversized_payload():
    fr = Framer()
    bad = net_link.HDR.pack(net_link.T_DATA, net_link.MAX_PAYLOAD + 1)
    with pytest.raises(net_link.LinkError):
        fr.feed(bad)


def test_parse_endpoint():
    assert parse_endpoint("tcp:127.0.0.1:9000") == ("tcp", ("127.0.0.1", 9000))
    assert parse_endpoint("unix:/tmp/x.sock") == ("unix", ("/tmp/x.sock",))
    with pytest.raises(ValueError):
        parse_endpoint("udp:127.0.0.1:9000")
    with pytest.raises(ValueError):
        parse_endpoint("tcp:9000")


# ---------------------------------------------------------------- loopback
def test_handshake_and_live_stream_over_tcp():
    inner = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 3.0))
    srv = DeviceServer({"dev0": inner}, drive=True)
    dev = SocketDevice(srv.endpoint, device="dev0")
    try:
        ps = PowerSensor(dev)
        assert ps.version.startswith("ps3")
        assert len(ps.configs) == 8  # full EEPROM download crossed the wire
        assert _wait(lambda: (ps.poll(), len(ps.ring))[1] > 400)
        # command traffic interleaved with live stream traffic
        ps.mark("A")
        assert _wait(lambda: (ps.poll(), ps.markers)[1])
        assert ps.markers[0][0] == "A"
        assert ps.dropped_bytes == 0
        assert ps.dropped_frames == 0
        st = ps.read()
        assert st.total_watts == pytest.approx(36.0, rel=0.2)
        ps.stop_streaming()  # post-stop drain poll must not stall
    finally:
        dev.close()
        srv.close()


def test_unix_socket_endpoint():
    inner = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 2.0))
    srv = DeviceServer({"dev0": inner}, endpoint="unix:auto", drive=True)
    assert srv.endpoint.startswith("unix:")
    dev = SocketDevice(srv.endpoint, device="dev0")
    try:
        ps = PowerSensor(dev)
        assert _wait(lambda: (ps.poll(), len(ps.ring))[1] > 100)
        assert ps.dropped_bytes == 0
    finally:
        dev.close()
        srv.close()


def test_unknown_device_refused():
    srv = DeviceServer({"dev0": make_device(["pcie8pin-20a"], ConstantLoad())})
    try:
        with pytest.raises(net_link.LinkError, match="unknown device"):
            SocketDevice(srv.endpoint, device="nope")
    finally:
        srv.close()


def test_busy_device_refused():
    srv = DeviceServer({"dev0": make_device(["pcie8pin-20a"], ConstantLoad())})
    dev = SocketDevice(srv.endpoint, device="dev0")
    try:
        with pytest.raises(net_link.LinkError, match="busy"):
            SocketDevice(srv.endpoint, device="dev0")
    finally:
        dev.close()
        srv.close()


# ------------------------------------------------------- golden conformance
def _drain_inprocess(trace):
    ps = replay_sensor(trace)
    ps.device.release_all()
    while True:
        if ps.poll() == 0 and (ps.device.exhausted or not ps.device.streaming):
            return ps


def _drain_socket(trace, dev_name):
    cap = max(1 << max(len(trace) - 1, 1).bit_length(), 1024)
    srv = DeviceServer({dev_name: ReplayDevice(trace)})
    sdev = SocketDevice(srv.endpoint, device=dev_name)
    try:
        ps = PowerSensor(sdev, ring_capacity=cap)
        ps.expect_markers(trace.marker_chars)
        assert _wait(
            lambda: (ps.poll(), sdev.exhausted)[1], timeout_s=30.0, tick_s=0.0
        )
        while ps.poll():
            pass
        return ps
    finally:
        sdev.close()
        srv.close()


@pytest.mark.parametrize("scenario", GOLDEN_SCENARIOS)
def test_golden_corpus_socket_replay_is_bit_identical(scenario):
    arc = TraceArchive.load(f"tests/goldens/{scenario}.npz")
    for dev_name, trace in arc.devices.items():
        ref = _drain_inprocess(trace)
        ps = _drain_socket(trace, dev_name)
        a, b = ref.ring.latest(), ps.ring.latest()
        assert len(a) == len(b), (scenario, dev_name)
        assert np.array_equal(a.times_s, b.times_s), (scenario, dev_name)
        assert np.array_equal(a.volts, b.volts), (scenario, dev_name)
        assert np.array_equal(a.amps, b.amps), (scenario, dev_name)
        assert ref.markers == ps.markers, (scenario, dev_name)
        assert ref.dropped_bytes == ps.dropped_bytes
        assert ref.dropped_frames == ps.dropped_frames
        ra, rb = ref.read(), ps.read()
        assert ra.consumed_joules == rb.consumed_joules


# ------------------------------------------------------------ backpressure
class _Fountain:
    """A device that streams a deterministic byte pattern on demand."""

    def __init__(self, total_bytes: int, chunk: int = 1 << 16):
        self._left = int(total_bytes)
        self._chunk = int(chunk)
        self._pos = 0
        self._pattern = bytes(range(256)) * (chunk // 256 + 1)
        self.digest = hashlib.sha256()
        self.t_s = 0.0
        self.pending_bytes = 0

    def write(self, data: bytes) -> None:
        pass

    def read(self, max_bytes=None) -> bytes:
        n = min(self._chunk, self._left)
        if n <= 0:
            return b""
        self._left -= n
        start = self._pos % 256
        self._pos += n
        out = self._pattern[start : start + n]
        self.digest.update(out)
        self.t_s += n * 1e-6
        return out

    def advance(self, dt_s: float) -> None:
        pass

    @property
    def exhausted(self) -> bool:
        return self._left <= 0


def test_server_slow_consumer_backpressure_no_loss():
    total = 16 << 20  # enough to fill kernel buffers + server out window
    fountain = _Fountain(total, chunk=1 << 18)
    srv = DeviceServer({"dev0": fountain}, max_out_bytes=1 << 17)
    dev = SocketDevice(srv.endpoint, device="dev0", max_buffered_chunks=1)
    try:
        dev.write(CMD_START_STREAM)  # leave handshake mode; reads non-block
        # do not read: the client queue caps, its reader stalls, kernel
        # buffers fill, the server's out window fills → pump pauses
        assert _wait(
            lambda: srv.stats().get("dev0", {}).get("backpressure_events", 0)
            > 0,
            timeout_s=20.0,
        )
        assert dev.backpressure_waits > 0
        # now drain everything: delayed, never dropped
        digest = hashlib.sha256()
        got = 0
        deadline = time.monotonic() + 60.0
        while got < total and time.monotonic() < deadline:
            data = dev.read()
            if not data:
                time.sleep(0.001)
                continue
            digest.update(data)
            got += len(data)
        assert got == total
        assert digest.hexdigest() == fountain.digest.hexdigest()
        assert _wait(lambda: dev.exhausted, timeout_s=10.0)
    finally:
        dev.close()
        srv.close()


def test_client_bounded_buffer_counts_stalls():
    total = 1 << 20
    fountain = _Fountain(total, chunk=1 << 14)
    srv = DeviceServer({"dev0": fountain})
    dev = SocketDevice(srv.endpoint, device="dev0", max_buffered_chunks=2)
    try:
        dev.write(CMD_START_STREAM)
        assert _wait(lambda: dev.backpressure_waits > 0, timeout_s=20.0)
        got = 0
        deadline = time.monotonic() + 30.0
        while got < total and time.monotonic() < deadline:
            data = dev.read()
            got += len(data)
            if not data:
                time.sleep(0.001)
        assert got == total
        assert dev.buffered_chunks <= 2
    finally:
        dev.close()
        srv.close()


# ------------------------------------------------------------ fleet head
def test_dropped_link_maps_to_lost_then_reacquires():
    devices = {
        f"dev{i}": make_device(
            ["pcie8pin-20a"], ConstantLoad(12.0, 2.0 + i), seed=i
        )
        for i in range(2)
    }
    srv = DeviceServer(devices, drive=True)
    head = FleetHead(
        {n: srv.endpoint for n in devices},
        window_s=0.05,
        stale_after_s=0.05,
        lost_after_s=0.25,
    )
    try:
        head.run_for(0.2)
        assert all(h.healthy for h in head.device_health().values())
        srv.drop("dev0")
        # poll the monitor alone (no reconnect) to observe the lost state
        assert _wait(
            lambda: (
                head.monitor.poll_all(),
                head.device_health()["dev0"].state,
            )[1]
            == "lost",
            timeout_s=10.0,
        )
        assert "dev0" in head.monitor.poll_errors
        assert head.device_health()["dev1"].healthy
        reading = head.monitor.fleet_power(poll=False)
        assert reading.n_healthy == 1
        # full poll() maintains the fleet: redial, restream, reacquire
        h0 = head["dev0"].ring.head
        assert _wait(
            lambda: (
                head.poll(),
                head.device_health()["dev0"].healthy
                and head["dev0"].ring.head > h0 + 50,
            )[1],
            timeout_s=10.0,
            tick_s=0.005,
        )
        assert head.reconnects["dev0"] >= 1
        assert head.monitor.poll_errors == {}
        stats = head.link_stats()
        assert stats["dev0"]["state"] == "healthy"
        assert stats["dev0"]["reconnects"] >= 1
        assert stats["dev1"]["reconnects"] == 0
    finally:
        head.close()
        srv.close()


# ------------------------------------------------------------ plan runner
def test_measurement_plan_json_roundtrip():
    plan = MeasurementPlan(
        name="campaign-a",
        devices=(
            PlanDevice(name="rig0", endpoint="tcp:10.0.0.5:9000"),
            PlanDevice(name="rig1", load="square", volts=12.0, amps=8.0),
        ),
        duration_s=2.5,
        window_s=0.2,
        interlocks=Interlocks(vmax_v=13.0, max_hours=1.0, abort_on_anomaly=True),
        scenario="dropout-burst",
    )
    back = MeasurementPlan.from_json(plan.to_json())
    assert back == plan


def test_run_plan_virtual_loopback_completes():
    plan = MeasurementPlan(
        name="smoke",
        devices=(
            PlanDevice(name="rig0", load="constant", volts=12.0, amps=3.0),
        ),
        duration_s=0.25,
        window_s=0.05,
        tick_s=0.01,
    )
    result = run_plan(plan)
    assert result.completed and not result.aborted
    assert result.n_readings > 0
    assert result.mean_power_w == pytest.approx(36.0, rel=0.2)
    assert result.health == {"rig0": "healthy"}
    assert result.link_stats["rig0"]["dropped_frames"] == 0


def test_vmax_interlock_aborts():
    plan = MeasurementPlan(
        name="overvolt",
        devices=(
            PlanDevice(name="rig0", load="constant", volts=12.0, amps=3.0),
        ),
        duration_s=5.0,
        window_s=0.05,
        tick_s=0.01,
        interlocks=Interlocks(vmax_v=5.0),  # a 12 V rail must trip this
    )
    t0 = time.monotonic()
    result = run_plan(plan)
    assert result.aborted
    assert "vmax" in result.reason
    assert time.monotonic() - t0 < 4.0  # tripped, not run to completion


def test_max_hours_interlock_aborts():
    plan = MeasurementPlan(
        name="runaway",
        devices=(
            PlanDevice(name="rig0", load="constant", volts=12.0, amps=3.0),
        ),
        duration_s=30.0,
        tick_s=0.01,
        interlocks=Interlocks(max_hours=0.1 / 3600.0),  # 100 ms ceiling
    )
    t0 = time.monotonic()
    result = run_plan(plan)
    assert result.aborted
    assert "max_hours" in result.reason
    assert time.monotonic() - t0 < 10.0


def test_abort_on_anomaly_requires_library():
    plan = MeasurementPlan(
        name="watched",
        devices=(PlanDevice(name="rig0"),),
        interlocks=Interlocks(abort_on_anomaly=True),
    )
    with pytest.raises(ValueError, match="signature library"):
        run_plan(plan)


def test_run_plan_rejects_unknown_scenario():
    plan = MeasurementPlan(
        name="bad",
        devices=(PlanDevice(name="rig0"),),
        scenario="not-a-scenario",
    )
    with pytest.raises(ValueError, match="unknown scenario"):
        run_plan(plan)
