"""Sharding rules + a miniature end-to-end pjit dry-run on 8 virtual devices.

The 512-device production dry-run needs its own process (XLA_FLAGS are
locked at first jax init), so this test launches `repro.launch.dryrun`-
equivalent lowering in a SUBPROCESS with 8 forced host devices and a
(2, 4) mesh — structure-identical to the production path.
"""
import json
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import smoke_config
from repro.launch import mesh as mesh_lib
from repro.models import build_model


def test_param_specs_divisible():
    """Every rule-produced spec divides the actual dims (all 10 archs)."""
    from repro.configs import ARCH_IDS, get_config

    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

        def check(path, leaf):
            spec = mesh_lib.param_spec(FakeMesh, path, leaf)
            for dim, ax in zip(leaf.shape, spec):
                if ax is not None:
                    size = 16 if not isinstance(ax, tuple) else 16
                    assert dim % FakeMesh.shape.get(ax if isinstance(ax, str) else "data", 1) == 0

        jax.tree_util.tree_map_with_path(check, shapes)


def test_major_params_are_sharded():
    """The big 2D projections must not silently fall through to replicated."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = smoke_config("qwen25_3b")
    from dataclasses import replace

    cfg = replace(cfg, d_model=256, d_ff=512, vocab_size=4096)
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    sharded = {}

    def check(path, leaf):
        spec = mesh_lib.param_spec(FakeMesh, path, leaf)
        name = mesh_lib._path_str(path)
        if leaf.size >= 256 * 256:
            sharded[name] = any(s is not None for s in spec)

    jax.tree_util.tree_map_with_path(check, shapes)
    assert sharded and all(sharded.values()), sharded


_SUBPROC = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    from repro.configs import RunConfig, SHAPES, smoke_config
    from repro.launch import mesh as mesh_lib
    from repro.launch.specs import build_cell
    import repro.launch.specs as specs
    from dataclasses import replace

    mesh = mesh_lib.make_mesh((2, 4), ("data", "model"))
    arch, shape_name = sys.argv[1], sys.argv[2]

    # shrink the cell: patch SHAPES to a tiny variant with the same kind
    kind = SHAPES[shape_name].kind
    import repro.configs as C
    tiny = C.ShapeSpec(shape_name, seq_len=64, global_batch=8, kind=kind)
    C.SHAPES = dict(C.SHAPES); C.SHAPES[shape_name] = tiny
    specs.SHAPES = C.SHAPES

    import repro.configs
    cfg = smoke_config(arch)
    # route get_config -> smoke config for this subprocess
    import repro.launch.specs as sp
    sp.get_config = lambda a: cfg

    run = RunConfig(attn_impl="full", remat="none", lr_chunk=8, moe_group=64)
    cell = build_cell(arch, shape_name, mesh, run)
    lowered = jax.jit(cell.fn, out_shardings=cell.out_shardings).lower(*cell.args)
    compiled = lowered.compile()
    from repro.launch.roofline import collective_wire_bytes, cost_analysis_dict
    ca = cost_analysis_dict(compiled)
    colls = collective_wire_bytes(compiled.as_text())
    print(json.dumps({
        "flops": float(ca.get("flops", 0.0)),
        "coll_total": colls["total"],
        "counts": colls["counts"],
    }))
    """
)


@pytest.mark.parametrize(
    "arch,shape",
    [
        ("qwen25_3b", "train_4k"),
        ("phi35_moe", "train_4k"),
        ("zamba2_7b", "decode_32k"),
        ("rwkv6_3b", "long_500k"),
        ("whisper_base", "prefill_32k"),
    ],
)
def test_mini_dryrun_subprocess(arch, shape):
    out = subprocess.run(
        [sys.executable, "-c", _SUBPROC, arch, shape],
        capture_output=True, text=True, timeout=600,
        env={**__import__("os").environ, "PYTHONPATH": "src"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-3000:]
    rec = json.loads(out.stdout.strip().splitlines()[-1])
    assert rec["flops"] > 0
    # sharded params guarantee at least one all-gather somewhere
    assert sum(rec["counts"].values()) > 0
