"""Chaos test tier, part 2: the stack *surviving* injected degradation.

Covers the consumers of the ring under faults: `FleetMonitor` health
states / quorum power / holdover, receiver-thread death surfacing,
`PowerCapGovernor` stale-telemetry safety, `attrib.attribute` gap
coverage, and the host's `dropped_frames` accounting.
"""
import threading
import time

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.attrib import KernelSpan, attribute
from repro.core import ConstantLoad, PowerSensor, make_device
from repro.faultlab import Disconnect, Dropout, Scenario, inject
from repro.sched import (
    GovernorConfig,
    OperatingGrid,
    PowerCapGovernor,
    VirtualPlant,
    decode_cost_of_batch,
    time_over_cap,
)
from repro.stream import make_virtual_fleet


def _fleet(n=2, window_s=0.02, **kw):
    return make_virtual_fleet(
        [ConstantLoad(12.0, 2.0 + i) for i in range(n)], window_s=window_s, **kw
    )


# ----------------------------------------------------------- health states
def test_health_transitions_through_disconnect():
    fleet = _fleet(2, lost_after_s=0.15)
    sc = Scenario(faults=(Disconnect(0.1, 0.4, devices=("dev0",)),))
    inject(fleet, sc)
    states = {"dev0": [], "dev1": []}
    t = 0.0
    while t < 0.6 - 1e-12:
        fleet.advance(0.01)
        t += 0.01
        h = fleet.device_health()
        for n in states:
            states[n].append(h[n].state)
    seen0 = set(states["dev0"])
    # the disconnected device walks healthy -> stale -> lost -> healthy
    assert {"healthy", "stale", "lost"} <= seen0
    assert states["dev0"][-1] == "healthy"  # reacquired after reconnect
    assert set(states["dev1"]) == {"healthy"}
    fleet.close()


def test_quorum_rescaled_fleet_power():
    fleet = _fleet(4)
    fleet.run_for(0.2)
    full = fleet.fleet_power()
    assert full.n_healthy == 4 and not full.stale and full.quorum_frac == 1.0
    sc = Scenario(faults=(Disconnect(0.0, 10.0, devices=("dev2",)),))
    inject(fleet, sc)
    fleet.run_for(0.3)
    part = fleet.fleet_power()
    assert part.n_healthy == 3
    assert part.quorum_frac == pytest.approx(0.75)
    assert not part.stale  # above the 0.5 quorum floor
    assert not part.holdover
    # rescaled by the known fleet fraction: still a *fleet* estimate.
    # loads are 2/3/4/5 A at 12 V; missing dev2 (4 A = 1/4 of 168 W) makes
    # the unscaled healthy sum err by ~17 %, the rescaled one by ~5 %
    assert part.power_w == pytest.approx(full.power_w, rel=0.08)
    assert part.raw_power_w < 0.8 * full.power_w
    fleet.close()


def test_holdover_and_staleness_flags_when_all_lost():
    fleet = _fleet(2, window_s=0.02)
    fleet.run_for(0.2)
    good = fleet.fleet_power()
    assert not good.stale
    sc = Scenario(faults=(Disconnect(0.0, 10.0),))  # everything, forever
    inject(fleet, sc)
    fleet.run_for(2 * fleet.stale_after_s)
    held = fleet.fleet_power()
    assert held.stale and held.holdover and held.n_healthy == 0
    assert held.power_w == pytest.approx(good.power_w, rel=0.05)
    assert held.data_age_s > 0
    # beyond the holdover window the reading stays flagged, holdover ends
    fleet.run_for(fleet.holdover_s + fleet.stale_after_s)
    dead = fleet.fleet_power()
    assert dead.stale and not dead.holdover
    fleet.close()


def test_min_quorum_frac_marks_reading_stale():
    fleet = _fleet(2, min_quorum_frac=0.8)
    fleet.run_for(0.1)
    sc = Scenario(faults=(Disconnect(0.0, 10.0, devices=("dev0",)),))
    inject(fleet, sc)
    fleet.run_for(0.2)
    r = fleet.fleet_power()
    assert r.n_healthy == 1
    assert r.stale  # 0.5 quorum < 0.8 floor: not trustworthy for control
    fleet.close()


# ------------------------------------------------- receiver-thread lifecycle
def test_dead_poller_thread_is_surfaced_not_frozen():
    fleet = _fleet(2)
    fleet.run_for(0.05)
    boom = RuntimeError("receiver exploded mid-poll")

    def bad_poll():
        raise boom

    fleet["dev0"].poll = bad_poll
    fleet.start_threads()
    deadline = time.time() + 5.0
    while fleet["dev0"].receiver_ok and time.time() < deadline:
        time.sleep(0.005)
    assert not fleet["dev0"].receiver_ok
    assert fleet["dev0"].thread_error is boom
    # the dead receiver shows up as a lost device, so quorum power no
    # longer serves its frozen ring as live fleet data
    h = fleet.device_health()
    assert h["dev0"].state == "lost"
    assert not h["dev0"].receiver_alive
    r = fleet.fleet_power(poll=False)
    assert r.n_healthy == 1
    with pytest.warns(RuntimeWarning, match="dev0"):
        errors = fleet.stop_threads()
    assert errors == {"dev0": boom}
    del fleet["dev0"].__dict__["poll"]  # restore for clean close
    fleet["dev0"]._thread_error = None  # acknowledged; close() quietly
    fleet.close()


def test_stop_threads_joins_with_timeout():
    fleet = _fleet(1)
    ps = fleet["dev0"]
    # a wedged receiver: ignores the stop event entirely
    ps._thread_stop.clear()
    ps._thread = threading.Thread(target=lambda: time.sleep(30.0), daemon=True)
    ps._thread.start()
    errors = None
    with pytest.warns(RuntimeWarning):
        errors = fleet.stop_threads(timeout_s=0.05)
    assert isinstance(errors["dev0"], TimeoutError)
    assert not ps.receiver_ok  # the timeout stays surfaced
    ps._thread_error = None  # clear for close()
    fleet.close()


class _WedgeTransport:
    """Pass-through transport whose read() can be gated shut, wedging the
    receiver thread inside the poll lock — the zombie-poller scenario."""

    def __init__(self, inner):
        self.inner = inner
        self.gate = threading.Event()
        self.gate.set()  # open: pass-through
        self.wedged = threading.Event()  # a reader is stuck on the gate

    def write(self, data):
        self.inner.write(data)

    def read(self, max_bytes=None):
        if not self.gate.is_set():
            self.wedged.set()
            self.gate.wait()
        return self.inner.read(max_bytes)

    def advance(self, dt_s):
        self.inner.advance(dt_s)

    @property
    def t_s(self):
        return self.inner.t_s

    @property
    def pending_bytes(self):
        return getattr(self.inner, "pending_bytes", 0)


def test_restarted_receiver_fences_zombie_poller():
    """A receiver detached past its join timeout must not interleave its
    stale batch into the ring once a fresh receiver is running: the
    generation fence drops the zombie's frames (counted, not silent)."""
    from repro.core import ConstantLoad, PowerSensor, make_device

    ps = PowerSensor(make_device(["pcie8pin-20a"], ConstantLoad(12.0, 3.0)))
    wedge = _WedgeTransport(ps.device)
    ps.device = wedge

    # wedge the receiver inside device.read() — it holds ps._lock there
    wedge.gate.clear()
    ps.start_thread()
    assert wedge.wedged.wait(5.0)
    # queue real frames behind the gate (the zombie will read them later)
    wedge.inner.advance(0.01)
    h0 = ps.ring.head

    err = ps.stop_thread(timeout_s=0.05)
    assert isinstance(err, TimeoutError)
    ps.start_thread()  # restarted receiver: blocks on the lock for now
    assert ps.receiver_ok  # the timeout error was cleared by the restart

    wedge.gate.set()  # zombie's read() returns ... into the fence
    deadline = time.time() + 5.0
    while ps.fenced_bytes == 0 and time.time() < deadline:
        time.sleep(0.005)
    assert ps.fenced_bytes > 0  # the zombie's batch was dropped, counted
    assert ps.ring.head == h0  # ... and never landed in the ring
    assert ps.stop_thread() is None  # the new receiver shuts down cleanly

    # the stream resumes cleanly through the restarted path
    wedge.inner.advance(0.01)
    ps.poll()
    assert ps.ring.head > h0
    ps.close()


class _DeadLinkTransport:
    """Transport whose read() raises — a socket that died mid-stream."""

    def __init__(self, inner, exc):
        self.inner = inner
        self.exc = exc
        self.broken = True

    def write(self, data):
        if not self.broken:
            self.inner.write(data)

    def read(self, max_bytes=None):
        if self.broken:
            raise self.exc
        return self.inner.read(max_bytes)

    def advance(self, dt_s):
        self.inner.advance(dt_s)

    @property
    def t_s(self):
        return self.inner.t_s

    @property
    def pending_bytes(self):
        return 0 if self.broken else getattr(self.inner, "pending_bytes", 0)


def test_transport_read_error_maps_to_lost_not_crash():
    """A transport read() raising out of a fleet poll must not kill the
    poller: the device goes `lost`, the error surfaces via stop_threads,
    and a later successful poll reacquires it."""
    fleet = _fleet(2)
    fleet.run_for(0.1)
    boom = ConnectionError("link reset by peer")
    inner = fleet["dev0"].device
    fleet["dev0"].device = _DeadLinkTransport(inner, boom)

    # round-robin polling survives the raising link (dev1 keeps flowing)
    before = fleet["dev1"].ring.head
    fleet.run_for(0.05)
    assert fleet["dev1"].ring.head > before
    h = fleet.device_health()
    assert h["dev0"].state == "lost"
    assert not h["dev0"].receiver_alive
    assert h["dev1"].state == "healthy"
    r = fleet.fleet_power(poll=True)  # must not raise either
    assert r.n_healthy == 1
    with pytest.warns(RuntimeWarning, match="dev0"):
        errors = fleet.stop_threads()
    assert errors["dev0"] is boom

    # reacquire: the link comes back, the first good poll clears the error
    fleet["dev0"].device.broken = False
    fleet.run_for(0.05)
    assert fleet.device_health()["dev0"].state == "healthy"
    assert fleet.poll_errors == {}
    assert fleet.stop_threads() == {}
    fleet.close()


def test_stop_thread_returns_none_on_clean_shutdown():
    fleet = _fleet(1)
    fleet.start_threads()
    time.sleep(0.05)
    assert fleet["dev0"].receiver_ok
    assert fleet.stop_threads() == {}
    assert fleet["dev0"].receiver_ok
    fleet.close()


# --------------------------------------------------------- governor safety
def _grid():
    cost = decode_cost_of_batch(2.0 * 40e6, 2.0 * 40e6, tokens_per_slot_step=8)
    return OperatingGrid(
        cost, n_layers=4, batches=(1, 2, 4, 8, 16, 32), tokens_per_slot_step=8
    )


def test_governor_treats_stale_telemetry_as_safety_event():
    grid = _grid()
    plant = VirtualPlant(grid, n_devices=2, seed=0)
    cap_w = 0.72 * 2 * grid.max_watts
    cfg = GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0)
    # the whole fleet disappears mid-run, then comes back
    inject(plant.fleet, Scenario(faults=(Disconnect(0.25, 0.35),), seed=1))
    gov = PowerCapGovernor(plant, cfg)
    gov.run(0.6, demand_of_t=lambda t: 32)

    stale = [s for s in gov.history if s.stale]
    assert stale, "full-fleet disconnect never flagged stale"
    # shed to the conservative rung and hold: never above the safety
    # fraction of the cap while flying blind
    n = plant.n_devices
    assert all(s.point.watts * n <= cfg.stale_shed_frac * cap_w + 1e-6 for s in stale)
    # integrator frozen while stale: the PI budget does not wind
    budgets = {round(s.budget_w, 6) for s in stale}
    assert len(budgets) == 1
    # the cap held through the whole disconnect -> reconnect cycle
    assert time_over_cap(plant.log, cap_w, 0.0, 0.6, tol=0.02) < 0.05
    # recovery: a fresh (non-stale) reading within 200 ms of reconnect
    rec = [s for s in gov.history if s.time_s >= 0.35 and not s.stale]
    assert rec and rec[0].time_s - 0.35 < 0.2
    # and the plant climbs back toward the cap afterwards
    late = [s for s in gov.history if s.time_s > 0.5]
    assert np.mean([s.point.watts * n for s in late]) > 0.8 * cap_w
    plant.close()


def test_governor_partial_quorum_keeps_the_cap():
    """One device lost of two: quorum telemetry must still hold the band."""
    grid = _grid()
    plant = VirtualPlant(grid, n_devices=2, seed=3)
    cap_w = 0.72 * 2 * grid.max_watts
    cfg = GovernorConfig(cap_w=cap_w, kp=0.15, ki=80.0)
    inject(
        plant.fleet,
        Scenario(faults=(Disconnect(0.2, 0.35, devices=(plant.fleet.names[0],)),)),
    )
    gov = PowerCapGovernor(plant, cfg)
    gov.run(0.6, demand_of_t=lambda t: 32)
    assert time_over_cap(plant.log, cap_w, 0.0, 0.6, tol=0.02) < 0.05
    plant.close()


# ------------------------------------------------------------ attrib gaps
def _gapped_trace(w0=100.0, dur=1.0, gap0=0.4, gap1=0.6, dt=1e-3):
    t = np.arange(0.0, dur, dt)
    keep = (t < gap0) | (t >= gap1)
    return t[keep], np.full(keep.sum(), w0)


def test_attribute_surfaces_gap_as_coverage():
    t, w = _gapped_trace()
    led = attribute(t, w, [KernelSpan("k", 0.0, 1.0)])
    e = led.entries["k"]
    # the 0.2 s gap is surfaced, not silently under-counted as 0 W
    assert e.coverage_frac == pytest.approx(0.8, abs=0.01)
    assert led.coverage_frac == pytest.approx(0.8, abs=0.01)
    # and energy is extrapolated across it: ~100 J, not ~80 J
    assert e.energy_j == pytest.approx(100.0, rel=0.02)


def test_attribute_gapless_span_is_fully_covered():
    t = np.arange(0.0, 1.0, 1e-3)
    w = np.full(t.size, 50.0)
    led = attribute(t, w, [KernelSpan("k", 0.1, 0.9)])
    e = led.entries["k"]
    assert e.coverage_frac == pytest.approx(1.0, abs=1e-6)
    assert e.energy_j == pytest.approx(40.0, rel=0.01)


def test_attribute_min_coverage_drops_hollow_spans():
    t, w = _gapped_trace(gap0=0.41, gap1=0.59)
    # span living almost entirely inside the gap
    led = attribute(
        t, w, [KernelSpan("hollow", 0.42, 0.58)], min_coverage=0.5
    )
    assert led.skipped_spans == 1
    assert "hollow" not in led.entries


@settings(max_examples=15, deadline=None)
@given(
    st.floats(min_value=0.05, max_value=0.6),
    st.floats(min_value=0.02, max_value=0.3),
)
def test_attribute_gap_extrapolation_property(gap_start, gap_width):
    """Any single gap: coverage ≈ 1 − gap/dur and energy within 3 %."""
    t, w = _gapped_trace(gap0=gap_start, gap1=min(gap_start + gap_width, 0.95))
    width = min(gap_start + gap_width, 0.95) - gap_start
    led = attribute(t, w, [KernelSpan("k", 0.0, 1.0)])
    e = led.entries["k"]
    assert e.coverage_frac == pytest.approx(1.0 - width, abs=0.02)
    assert e.energy_j == pytest.approx(100.0, rel=0.03)
    assert np.isfinite(e.energy_j) and e.energy_j >= 0


def test_chaos_run_attribution_coverage_end_to_end():
    """Dropout over a live sensor: the marker span's coverage reports it."""
    from repro.attrib import attribute_block, marker_spans
    from repro.faultlab import ChaosRun

    sc = Scenario(faults=(Dropout(0.08, 0.12),), seed=5)
    rep = ChaosRun(sc, n_devices=1, seed=6).run(0.2, mark_every_s=0.05)
    try:
        ps = rep.fleet["dev0"]
        spans = marker_spans(ps.markers, "C")
        led = attribute_block(ps.ring.latest(), spans)
        # the gap lands in span C1 (0.05-0.10) and C2 (0.10-0.15)
        assert led.coverage_frac < 0.95
        hit = [e for e in led.entries.values() if e.coverage_frac < 0.9]
        assert hit, "no span surfaced the injected dropout"
        assert all(np.isfinite(e.energy_j) and e.energy_j >= 0 for e in led.entries.values())
    finally:
        rep.close()


# ----------------------------------------------------- dropped-frame counts
def test_clean_stream_drops_nothing():
    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 3.0), seed=1)
    ps = PowerSensor(dev)
    ps.run_for(0.3)
    assert ps.dropped_frames == 0
    assert ps.dropped_bytes == 0
