"""Per-kernel energy attribution subsystem (`repro.attrib`)."""
import io
import json

import numpy as np
import pytest

from repro.attrib import (
    EnergyLedger,
    KernelSpan,
    StepAttributor,
    active_spans,
    attribute,
    attribute_block,
    build_library,
    identify_segments,
    marker_spans,
    refine_spans,
    render_csv,
    render_json,
    render_text,
    segment_trace,
    timeline_spans,
    write_report,
)

# synthetic 5-kernel workload (distinct adjacent powers) + inter-step gap
PHASES = [
    ("gap", 0.006, 55.0),
    ("embed", 0.012, 95.0),
    ("attn", 0.028, 185.0),
    ("coll", 0.008, 75.0),
    ("ffn", 0.022, 150.0),
    ("opt", 0.016, 115.0),
]
STEP_S = sum(d for _, d, _ in PHASES)


def _trace(steps=2, noise_w=0.7, dt=50e-6, seed=0):
    """Piecewise-constant multi-step trace + true boundaries/energies."""
    rng = np.random.default_rng(seed)
    t_list, w_list, bounds = [], [], []
    t = 0.0
    for _ in range(steps):
        for name, dur, p in PHASES:
            n = int(round(dur / dt))
            t_list.append(t + np.arange(n) * dt)
            w_list.append(np.full(n, p))
            bounds.append(t)
            t += n * dt
    times = np.concatenate(t_list)
    watts = np.concatenate(w_list)
    if noise_w:
        watts = watts + rng.normal(0, noise_w, times.size)
    true_e = {name: dur * p * steps for name, dur, p in PHASES}
    return times, watts, np.array(bounds[1:]), true_e


# ------------------------------------------------------------------ segment
def test_segmentation_recovers_all_boundaries():
    times, watts, true_bounds, _ = _trace(steps=2)
    seg = segment_trace(times, watts)
    assert len(seg) == len(true_bounds) + 1
    for b in true_bounds:
        assert abs(seg.nearest_boundary(b) - b) <= 2e-3


def test_segmentation_constant_trace_is_one_segment():
    t = np.arange(0, 0.2, 50e-6)
    w = np.full(t.size, 80.0) + np.random.default_rng(1).normal(0, 0.5, t.size)
    seg = segment_trace(t, w)
    assert len(seg) == 1
    assert seg.segments[0].mean_w == pytest.approx(80.0, abs=0.5)


def test_segmentation_refinement_catches_subthreshold_step():
    """A 3 W step in 1 W noise is invisible to an (artificially blunted)
    hysteresis pass but recovered by the binary-segmentation refinement."""
    t = np.arange(0, 1.0, 1e-3)
    w = np.where(t < 0.5, 100.0, 103.0) + np.random.default_rng(2).normal(0, 1.0, t.size)
    blunt = dict(k_hi=30.0)
    assert len(segment_trace(t, w, refine=False, **blunt)) == 1
    seg = segment_trace(t, w, **blunt)
    assert len(seg) == 2
    assert abs(seg.boundaries_s[0] - 0.5) < 0.01


def test_segment_stats_match_numpy():
    times, watts, _, _ = _trace(steps=1, noise_w=0.0)
    seg = segment_trace(times, watts)
    for s in seg.segments:
        sl = slice(s.i0, s.i1)
        assert s.mean_w == pytest.approx(watts[sl].mean())
        assert s.peak_w == pytest.approx(watts[sl].max())
        assert s.energy_j == pytest.approx(np.trapezoid(watts[sl], times[sl]))
    assert seg.total_energy_j == pytest.approx(
        np.trapezoid(watts, times), rel=0.02
    )


def test_active_spans_merges_hot_segments():
    t = np.arange(0, 0.1, 1e-4)
    w = np.where((t > 0.02) & (t < 0.05), 150.0, 50.0)
    spans = active_spans(segment_trace(t, w))
    assert len(spans) == 1
    t0, t1 = spans[0]
    assert t0 == pytest.approx(0.02, abs=1e-3)
    assert t1 == pytest.approx(0.05, abs=1e-3)


# ---------------------------------------------------------------- attribute
def test_attribute_exact_energies_and_aggregation():
    times, watts, _, true_e = _trace(steps=3, noise_w=0.0)
    anchors = [k * STEP_S for k in range(3)]
    spans = timeline_spans([(n, d) for n, d, _ in PHASES], anchors, t_end=3 * STEP_S)
    ledger = attribute(times, watts, spans)
    assert set(ledger.entries) == set(true_e)
    for name, e in ledger.entries.items():
        assert e.count == 3
        assert e.energy_j == pytest.approx(true_e[name], rel=0.02)
    assert ledger.ranked()[0].name == "attn"  # biggest consumer first
    assert 0.9 < ledger.attributed_fraction <= 1.0 + 1e-9


def test_attribute_min_coverage_skips_sparse_spans():
    t = np.arange(0, 1.0, 0.1)  # 10 Hz
    w = np.full(t.size, 100.0)
    spans = [KernelSpan("tiny", 0.31, 0.33), KernelSpan("wide", 0.0, 0.9)]
    ledger = attribute(t, w, spans, min_coverage=0.5)
    assert "tiny" not in ledger.entries  # 0 samples inside
    assert "wide" in ledger.entries
    assert ledger.skipped_spans == 1


def test_marker_spans_are_occurrence_indexed():
    markers = [("W", 0.1), ("X", 0.15), ("W", 0.3), ("W", 0.7)]
    spans = marker_spans(markers, "W", names=["wave0", "wave1"])
    assert [s.name for s in spans] == ["wave0", "wave1"]
    assert spans[0].t0_s == 0.1 and spans[0].t1_s == 0.3
    assert spans[1].t0_s == 0.3 and spans[1].t1_s == 0.7


def test_timeline_spans_stretch_to_anchors():
    spans = timeline_spans(
        [("a", 0.1), ("b", 0.3)], anchors=[0.0, 0.8], t_end=1.6
    )
    # declared step is 0.4 s but anchors are 0.8 s apart: stretched 2x
    assert spans[0].duration_s == pytest.approx(0.2)
    assert spans[1].duration_s == pytest.approx(0.6)
    assert spans[2].t0_s == pytest.approx(0.8)
    assert spans[3].t1_s == pytest.approx(1.6)


def test_refine_spans_snaps_to_detected_boundaries():
    times, watts, true_bounds, _ = _trace(steps=1)
    seg = segment_trace(times, watts)
    # declared timeline 1 ms off: snapping recovers the measured edges
    off = [KernelSpan("x", true_bounds[0] + 1e-3, true_bounds[1] - 1e-3)]
    snapped = refine_spans(off, seg, tol_s=2e-3)[0]
    assert abs(snapped.t0_s - true_bounds[0]) < 2e-4
    assert abs(snapped.t1_s - true_bounds[1]) < 2e-4


def test_ledger_absorb_merges_devices():
    a, b = EnergyLedger(), EnergyLedger()
    a.add_occurrence("k", 1.0, 0.5, 100.0)
    a.trace_energy_j = 2.0
    b.add_occurrence("k", 3.0, 0.5, 120.0)
    b.trace_energy_j = 4.0
    a.absorb(b)
    e = a.entries["k"]
    assert e.count == 2 and e.energy_j == 4.0 and e.peak_w == 120.0
    assert a.trace_energy_j == 6.0
    assert e.j_per_occurrence == pytest.approx(2.0)


# --------------------------------------------------------------- signatures
def test_signature_library_identifies_fresh_trace():
    times, watts, _, _ = _trace(steps=2, seed=3)
    anchors = [0.0, STEP_S]
    spans = timeline_spans([(n, d) for n, d, _ in PHASES], anchors, t_end=2 * STEP_S)
    lib = build_library(times, watts, spans)
    assert len(lib) == len(PHASES)
    # fresh noise realisation, same workload
    t2, w2, _, _ = _trace(steps=1, seed=4)
    seg = segment_trace(t2, w2)
    labels = [s.name for s, _ in identify_segments(t2, w2, seg, lib)]
    assert labels == [n for n, _, _ in PHASES]


def test_signature_library_json_roundtrip():
    times, watts, _, _ = _trace(steps=1, seed=5)
    spans = timeline_spans([(n, d) for n, d, _ in PHASES], [0.0], t_end=STEP_S)
    lib = build_library(times, watts, spans)
    from repro.attrib import SignatureLibrary

    lib2 = SignatureLibrary.from_json(lib.to_json())
    assert set(lib2.signatures) == set(lib.signatures)
    name, dist = lib2.match(times, watts, 0.006, 0.018)  # the embed window
    assert name == "embed" and dist < 0.5


# ------------------------------------------------------------------- report
def _small_ledger():
    led = EnergyLedger()
    led.add_occurrence("big", 10.0, 1.0, 20.0)
    led.add_occurrence("small", 1.0, 0.5, 5.0)
    led.trace_energy_j = 12.0
    return led


def test_render_text_is_energy_ranked():
    out = render_text(_small_ledger())
    assert out.index("big") < out.index("small")
    assert "91.7%" in out  # 11 J attributed of 12 J trace


def test_render_csv_parses():
    import csv as _csv

    rows = list(_csv.DictReader(io.StringIO(render_csv(_small_ledger()))))
    assert rows[0]["name"] == "big"
    assert float(rows[0]["energy_j"]) == pytest.approx(10.0)


def test_render_json_and_write_report(tmp_path):
    obj = json.loads(render_json(_small_ledger()))
    assert obj["total_energy_j"] == pytest.approx(11.0)
    assert obj["entries"][0]["name"] == "big"
    p = tmp_path / "ledger.json"
    write_report(_small_ledger(), str(p), fmt="json")
    assert json.loads(p.read_text())["total_energy_j"] == pytest.approx(11.0)
    with pytest.raises(ValueError):
        write_report(_small_ledger(), str(p), fmt="xml")


# ------------------------------------------- end-to-end through the sensor
def test_sensor_chain_attribution_beats_builtin_counter():
    """The acceptance experiment at test scale: 5 distinct kernel phases
    through the full virtual chain at 20 kHz — boundaries within ±2 ms,
    energies within 5% — while a 10 Hz counter demonstrably fails."""
    from repro.core import ConstantLoad, PowerSensor, TraceLoad, make_device
    from repro.core.calibration import calibrate
    from repro.power import BuiltinCounterMeter, V5E, Phase, render_phases

    phases = []
    for name, dur, watts in PHASES:
        rate = max(watts - V5E.p_static, 0.0) / V5E.e_hbm_byte
        phases.append(Phase(name, dur, hbm_bytes=rate * dur))
    steps = 2
    step = render_phases(phases, V5E)
    step_s = float(step.times_s[-1])

    dev = make_device(["pcie8pin-20a"], ConstantLoad(12.0, 0.0), seed=6)
    ps = PowerSensor(dev, ring_capacity=1 << 16)
    calibrate(ps, {0: 12.0}, n_samples=4000)
    seq0 = ps.ring.head
    dev.firmware.dut.loads[0] = TraceLoad(
        times_s=step.times_s, watts=step.watts, volts=12.0,
        repeat=True, t_offset_s=dev.t_s,
    )
    anchors = []
    for _ in range(steps):
        ps.mark("S")
        ps.run_for(step_s)
    ps.poll()
    block = ps.ring.since(seq0)
    anchors = [t for c, t in ps.markers if c == "S"]
    ps.close()

    true_e = {p.name: p.power(V5E) * p.duration_s * steps for p in phases}
    offs = np.cumsum([p.duration_s for p in phases])[:-1]
    true_bounds = [a + o for a in anchors for o in offs] + anchors[1:]

    # 20 kHz: segmentation finds every boundary, attribution within 5%
    t, w = block.times_s, block.watts[:, 0]
    seg = segment_trace(t, w)
    for b in true_bounds:
        assert abs(seg.nearest_boundary(b) - b) <= 2e-3
    spans = timeline_spans(phases, anchors, t_end=anchors[-1] + step_s)
    ledger = attribute(t, w, spans)
    for name, tj in true_e.items():
        assert ledger.entries[name].energy_j == pytest.approx(tj, rel=0.05)

    # 10 Hz builtin counter: misses phases entirely or errs > 25%
    full = render_phases(phases, V5E, repeat=steps)
    m = BuiltinCounterMeter(mode="instant", update_rate_hz=10.0).measure(
        full.times_s, full.watts
    )
    spans10 = timeline_spans(phases, [k * step_s for k in range(steps)])
    led10 = attribute(m.sample_times_s, m.sample_watts, spans10)
    worst = max(
        abs(led10.entries[n].energy_j - tj) / tj if n in led10.entries else 1.0
        for n, tj in true_e.items()
    )
    assert worst > 0.25


def test_attribute_block_over_ring_views():
    from repro.core import ConstantLoad, PowerSensor, make_device

    ps = PowerSensor(make_device(["slot-10a-12v"], ConstantLoad(12.0, 4.0), seed=7))
    ps.run_for(0.05)
    ps.mark("A")
    ps.run_for(0.1)
    ps.mark("A")
    ps.run_for(0.02)
    spans = marker_spans(ps.markers, "A", names=["win"])
    ledger = attribute_block(ps.ring.latest(), spans, min_coverage=0.9)
    e = ledger.entries["win"]
    assert e.duration_s == pytest.approx(0.1, abs=0.005)
    assert e.energy_j == pytest.approx(48.0 * 0.1, abs=1.0)


# -------------------------------------------------------------- integrations
def test_step_attributor_ledger_matches_model():
    from repro.power import EnergyTelemetry, StepCost

    telemetry = EnergyTelemetry(
        cost_per_step=StepCost(2e12, 5e10, 0.0), n_layers=2,
        useful_flops_per_step=2e12,
    )
    att = StepAttributor(telemetry, seed=8)
    for _ in range(3):
        att.on_step()
    ledger = att.finish()
    names = {p.name for p in telemetry.phases}
    assert set(ledger.entries) == names
    total_model = telemetry.modelled_step_joules * 3
    assert ledger.total_energy_j == pytest.approx(total_model, rel=0.05)
    for e in ledger.entries.values():
        assert e.count == 3


def test_tuner_attribution_strategy_tracks_exact_energy():
    from repro.power import (
        EnergyTuner,
        KernelVariantModel,
        StepCost,
        attribution_strategy,
        fast_sensor_strategy,
    )

    flops = 2 * 2048**3

    def model(cfg, chip, dvfs):
        eff = 0.9 if cfg["block"] == 128 else 0.6
        t = flops / (chip.peak_flops_bf16 * eff * dvfs.scale)
        return t, StepCost(flops=flops, hbm_bytes=2 * 2048**2, ici_bytes=0.0)

    k = KernelVariantModel("toy", flops, model, {"block": (64, 128)})
    tuner = EnergyTuner()
    exact = tuner.tune(k, fast_sensor_strategy(), exact_energy=True)
    attr = tuner.tune(k, attribution_strategy(seed=9))
    for e, a in zip(exact.records, attr.records):
        assert a.joules == pytest.approx(e.joules, rel=0.15)
    # attribution agrees with the marker method on the winner
    assert attr.most_efficient().config == exact.most_efficient().config


# ------------------------------------- segmentation regressions (edge cases)
def _assert_contiguous(seg, n):
    """Segments must tile [0, n) exactly: i0=0, i1=n, no gaps or overlaps."""
    assert seg.segments[0].i0 == 0
    assert seg.segments[-1].i1 == n
    for a, b in zip(seg.segments[:-1], seg.segments[1:]):
        assert a.i1 == b.i0


def test_segment_block_at_ring_wraparound_pins_boundary_index():
    """Segmenting a wrapped ring view must find the step edge at the exact
    retained-block index, not at a physical-buffer offset."""
    from repro.attrib import segment_block
    from repro.stream import FrameRing

    dt = 50e-6
    ring = FrameRing(4000, 1)  # retains 0.2 s; we push 0.3 s through it
    rng = np.random.default_rng(3)
    step_t = 0.22  # lands inside the retained window, after the wrap
    for k in range(6):  # 6 x 0.05 s appends
        t = k * 0.05 + np.arange(1000) * dt
        w = np.where(t < step_t, 80.0, 160.0) + rng.normal(0, 0.5, t.size)
        w = w[:, None]
        ring.append(t, np.full_like(w, 12.0), w / 12.0, w)
    assert ring.head > ring.capacity  # wrapped for sure
    block = ring.latest()
    seg = segment_block(block)
    _assert_contiguous(seg, len(block))
    assert len(seg) == 2
    expected_idx = int(np.searchsorted(block.times_s, step_t))
    assert abs(seg.segments[0].i1 - expected_idx) <= 2  # pinned to the index
    assert seg.segments[0].mean_w == pytest.approx(80.0, abs=1.0)
    assert seg.segments[1].mean_w == pytest.approx(160.0, abs=1.0)


def test_segment_all_flat_trace_is_single_full_span_segment():
    dt = 50e-6
    t = np.arange(4000) * dt
    w = np.full(t.size, 123.0)  # exactly flat: zero noise floor
    seg = segment_trace(t, w)
    assert len(seg) == 1
    assert seg.boundaries_s.size == 0
    s = seg.segments[0]
    assert (s.i0, s.i1) == (0, t.size)  # boundary indices pinned
    assert s.mean_w == pytest.approx(123.0)
    assert s.peak_w == pytest.approx(123.0)
    assert s.energy_j == pytest.approx(123.0 * t[-1], rel=1e-6)


def test_segment_degenerate_tiny_inputs():
    # empty
    seg0 = segment_trace(np.array([]), np.array([]))
    assert len(seg0) == 0 and seg0.boundaries_s.size == 0
    # single sample: one zero-length, zero-energy segment at that index
    seg1 = segment_trace(np.array([1.0]), np.array([50.0]))
    assert len(seg1) == 1
    assert (seg1.segments[0].i0, seg1.segments[0].i1) == (0, 1)
    assert seg1.segments[0].energy_j == 0.0
    assert seg1.segments[0].duration_s == 0.0
    # below the 4-sample floor: still a single contiguous segment
    seg3 = segment_trace(np.array([0.0, 1e-3, 2e-3]), np.array([5.0, 99.0, 5.0]))
    assert len(seg3) == 1
    assert (seg3.segments[0].i0, seg3.segments[0].i1) == (0, 3)


def test_segment_single_sample_spike_keeps_contiguous_cover():
    """A one-sample spike (shorter than min_seg_s) must not fragment the
    segmentation or break index contiguity."""
    dt = 50e-6
    t = np.arange(2000) * dt
    w = np.full(t.size, 70.0)
    w[900] = 400.0  # isolated single-sample spike
    seg = segment_trace(t, w)
    _assert_contiguous(seg, t.size)
    # the spike is too short to stand as its own >= min_seg_s segment
    assert all(len(s) >= 2 for s in seg.segments)
    assert seg.total_energy_j == pytest.approx(np.trapezoid(w, t), rel=1e-3)


def test_segment_cap_clipped_plateau_pins_edges():
    """A ramp clipped flat at a power cap: edges at the exact clip indices."""
    dt = 50e-6
    n = 6000
    t = np.arange(n) * dt
    cap = 150.0
    ramp = 60.0 + 220.0 * t / t[-1]  # would peak at 280 W uncapped
    rng = np.random.default_rng(9)
    w = np.minimum(ramp, cap) + rng.normal(0, 0.4, n)
    seg = segment_trace(t, w)
    _assert_contiguous(seg, n)
    clip_idx = int(np.searchsorted(ramp, cap))
    # one detected boundary lands on the clip onset (within smoothing slack)
    idxs = [s.i0 for s in seg.segments[1:]]
    assert min(abs(i - clip_idx) for i in idxs) <= 40  # 2 ms at 20 kHz
    # the plateau segment itself is flat at the cap
    plateau = seg.segments[-1]
    assert plateau.mean_w == pytest.approx(cap, abs=1.0)
    assert plateau.i1 == n
    # the clipped region is NOT merged into the ramp: boundary strictly
    # after the ramp start and well before the end
    assert 0 < clip_idx < n


def test_attribute_spans_entirely_outside_trace_are_skipped():
    t = np.arange(1000) * 50e-6
    w = np.full(t.size, 100.0)
    led = attribute(t, w, [KernelSpan("past", -1.0, -0.5),
                           KernelSpan("future", 10.0, 11.0),
                           KernelSpan("ok", 0.0, 0.02)])
    assert led.skipped_spans == 2
    assert set(led.entries) == {"ok"}
    assert led.entries["ok"].energy_j == pytest.approx(100.0 * 0.02, rel=5e-3)
